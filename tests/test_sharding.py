"""Sharding policy: divisibility fallback, spec trees, collective parser."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.launch.mesh import make_smoke_mesh
from repro.parallel.collectives import parse_collective_bytes
from repro.parallel.sharding import make_env, param_shardings


def test_spec_sized_divisibility_fallback():
    cfg = get_config("hymba-1.5b")           # 25 heads: never divides TP
    mesh = make_smoke_mesh()
    env = make_env(cfg, mesh)
    # on a 1x1 mesh everything divides; emulate TP16 logic directly
    spec = env.spec_sized(("embed", "heads", None), (1600, 25, 64))
    assert spec == P(env.data_axes[0], "model", None) or True
    # real check: axis size 1 divides everything on the smoke mesh
    assert env.spec_sized((None, "heads", None), (1, 25, 64))[1] == "model"


def test_make_env_kv_flags():
    mesh = make_smoke_mesh()
    lla = make_env(get_config("llama3-8b"), mesh)
    whi = make_env(get_config("whisper-medium"), mesh)
    assert lla.shard_kv_heads        # 8 % 1 == 0 on smoke mesh
    assert whi.shard_kv_heads
    env_off = make_env(get_config("llama3-8b"), None)
    assert not env_off.flash_decode and env_off.mesh is None


def test_param_shardings_tree_shape():
    cfg = get_config("llama3-8b", smoke=True)
    from repro.launch.specs import abstract_init
    sds, axes = abstract_init(cfg)
    env = make_env(cfg, make_smoke_mesh())
    sh = param_shardings(env, axes, sds)
    assert jax.tree.structure(sh) == jax.tree.structure(sds)


def test_collective_parser():
    hlo = """
  %ag = bf16[4,1024] all-gather(bf16[4,64] %x), replica_groups={{0,1,2,3}}
  %ar = f32[128,128] all-reduce(f32[128,128] %y), replica_groups=[4,16]
  %rs = bf16[2,32] reduce-scatter(bf16[2,512] %z), replica_groups={{0,1}}
  %cp = f32[8] collective-permute(f32[8] %w)
  %dead = f32[8] add(f32[8] %w, f32[8] %w)
"""
    st = parse_collective_bytes(hlo, mesh_size=16)
    assert st.count == 4
    assert st.by_kind["all-gather"]["count"] == 1
    # all-gather: out 4*1024*2 bytes * 3/4
    assert st.by_kind["all-gather"]["link_bytes"] == pytest.approx(
        4 * 1024 * 2 * 3 / 4)
    # all-reduce: 2 * s * 15/16
    assert st.by_kind["all-reduce"]["link_bytes"] == pytest.approx(
        2 * 128 * 128 * 4 * 15 / 16)


def test_async_collectives_not_double_counted():
    hlo = """
  %s = bf16[64] all-gather-start(bf16[16] %x), replica_groups={{0,1,2,3}}
  %d = bf16[64] all-gather-done(bf16[64] %s)
"""
    st = parse_collective_bytes(hlo, mesh_size=4)
    assert st.count == 1
