"""Campaign subsystem: spec expansion + content addressing, scheduler
resume layers, artifact-store round trips, aggregation, and the governor's
fleet-deployment path."""
import json
import os

import numpy as np
import pytest

from repro.campaign import (ArtifactStore, CampaignRunner, CampaignSpec,
                            DeviceSpec, MeasureSpec, comparison_markdown,
                            report_markdown, run_campaign)
from repro.campaign.cli import main as cli_main

FAST = MeasureSpec(key="fast", min_measurements=4, max_measurements=5,
                   rse_check_every=4)


def _spec(name="t", seed=0, kinds=("a100", "rtx6000"), retries=2):
    freqs = {"a100": (210.0, 705.0, 1410.0),
             "rtx6000": (300.0, 1200.0, 2100.0),
             "gh200": (345.0, 1155.0, 1980.0)}
    return CampaignSpec(
        name=name,
        devices=tuple(
            DeviceSpec.make(k, "simulated",
                            {"kind": k, "n_cores": 6, "seed": seed},
                            frequencies=freqs[k])
            for k in kinds),
        measures=(FAST,), retries=retries)


# ------------------------------------------------------------------ #
# spec: matrix expansion + content addressing
# ------------------------------------------------------------------ #
def test_spec_expands_matrix():
    spec = CampaignSpec(
        name="m",
        devices=(DeviceSpec.make("d1", options={"kind": "a100"}),
                 DeviceSpec.make("d2", options={"kind": "gh200"})),
        measures=(MeasureSpec(key="fast"), MeasureSpec(key="slow",
                                                       max_measurements=50)))
    keys = [u.key for u in spec.units()]
    assert keys == ["d1@fast", "d1@slow", "d2@fast", "d2@slow"]


def test_spec_rejects_duplicate_keys():
    with pytest.raises(ValueError, match="duplicate device"):
        CampaignSpec("d", devices=(DeviceSpec.make("x"),
                                   DeviceSpec.make("x")))


def test_spec_json_roundtrip_preserves_id(tmp_path):
    spec = _spec()
    path = str(tmp_path / "spec.json")
    spec.save(path)
    reloaded = CampaignSpec.load(path)
    assert reloaded == spec
    assert reloaded.campaign_id() == spec.campaign_id()


def test_campaign_id_is_content_addressed():
    assert _spec(seed=0).campaign_id() == _spec(seed=0).campaign_id()
    assert _spec(seed=0).campaign_id() != _spec(seed=1).campaign_id()
    # option ORDER must not matter (canonicalized)
    a = DeviceSpec.make("d", options={"kind": "a100", "n_cores": 6})
    b = DeviceSpec.make("d", options={"n_cores": 6, "kind": "a100"})
    assert (CampaignSpec("x", (a,)).campaign_id()
            == CampaignSpec("x", (b,)).campaign_id())


def test_measure_spec_rejects_unknown_fields():
    with pytest.raises(ValueError, match="unknown measure fields"):
        MeasureSpec.from_dict({"key": "f", "min_measurments": 3})  # typo


def test_device_spec_rejects_unknown_fields():
    with pytest.raises(ValueError, match="unknown device fields"):
        DeviceSpec.from_dict({"key": "d", "frequncies": [210.0]})  # typo


@pytest.mark.parametrize("bad", ["../escape", "a/b", "a@b", "", "..", "a b"])
def test_spec_rejects_path_unsafe_keys(bad):
    with pytest.raises(ValueError, match="invalid device key"):
        CampaignSpec("k", devices=(DeviceSpec.make(bad),))


def test_device_spec_rejects_empty_frequency_list():
    with pytest.raises(ValueError, match="non-empty"):
        DeviceSpec.make("d", frequencies=[])


# ------------------------------------------------------------------ #
# scheduler + store: run, resume at campaign and unit granularity
# ------------------------------------------------------------------ #
def test_run_and_store_roundtrip(tmp_path):
    store = ArtifactStore(str(tmp_path))
    result = run_campaign(_spec(), store)
    assert result.ok
    assert set(result.outcomes) == {"a100@fast", "rtx6000@fast"}
    campaign = result.campaign

    # reload every table from CSV artifacts and compare bit-for-bit
    for key, table in result.tables().items():
        loaded = campaign.load_table(key)
        assert set(loaded.pairs) == set(table.pairs)
        for p, pr in table.pairs.items():
            lp = loaded.pairs[p]
            np.testing.assert_allclose(lp.latencies, pr.latencies,
                                       rtol=0, atol=1e-9)
            assert lp.clean.size == pr.clean.size
            assert lp.status == pr.status
            assert lp.n_clusters == pr.n_clusters


def test_campaign_level_resume_skips_done_units(tmp_path):
    store = ArtifactStore(str(tmp_path))
    first = run_campaign(_spec(), store)
    assert all(o.status == "done" for o in first.outcomes.values())

    again = run_campaign(_spec(), store)
    assert again.ok
    # nothing re-measured: every unit came back from the store
    assert all(o.status == "loaded" for o in again.outcomes.values())
    assert all(o.session is None for o in again.outcomes.values())


def test_unit_level_resume_after_interrupt(tmp_path):
    """A campaign killed mid-unit resumes at PAIR granularity: the unit's
    embedded session state already holds the finished pairs."""
    store = ArtifactStore(str(tmp_path))
    spec = _spec(kinds=("a100",))
    campaign = store.open(spec)
    (unit,) = spec.units()

    # simulate the interrupted run: two pairs measured, then a crash
    # (manifest still says pending, no result.json)
    pre = unit.build_session(out_dir=campaign.session_dir(unit.key))
    pre.run(pair_subset=[(210.0, 705.0), (705.0, 210.0)])

    result = run_campaign(spec, store)
    assert result.ok
    outcome = result.outcomes[unit.key]
    assert outcome.status == "done"
    # the resumed session never re-measured the two persisted pairs
    measured = {(h["from"], h["to"]) for h in outcome.session.device.history}
    assert (210.0, 705.0) not in measured
    assert len(outcome.table.pairs) == 6


def test_failed_unit_is_retried_then_isolated(tmp_path, monkeypatch):
    store = ArtifactStore(str(tmp_path))
    spec = _spec(kinds=("a100", "rtx6000"), retries=2)
    calls = {"n": 0}
    import repro.campaign.scheduler as sched
    orig = sched.UnitSpec.build_session

    def flaky(self, out_dir=None, executor="serial", **kw):
        if self.device.key == "rtx6000":
            calls["n"] += 1
            raise RuntimeError("board on fire")
        return orig(self, out_dir=out_dir, executor=executor, **kw)

    monkeypatch.setattr(sched.UnitSpec, "build_session", flaky)
    result = CampaignRunner(spec, store).run()
    assert calls["n"] == 2                      # retried per spec.retries
    assert not result.ok
    bad = result.outcomes["rtx6000@fast"]
    assert bad.status == "failed" and "board on fire" in bad.error
    # the healthy unit still completed and persisted
    assert result.outcomes["a100@fast"].status == "done"
    st = result.campaign.unit_states()
    assert st["rtx6000@fast"]["status"] == "failed"
    assert st["a100@fast"]["status"] == "done"


def test_ground_truth_merges_across_saves(tmp_path):
    """Re-saving a unit (retry after a failed save, partial re-measure)
    must keep earlier pairs' stored truths, not clobber them."""
    from repro.core.latency_table import LatencyTable, analyse_pair
    store = ArtifactStore(str(tmp_path))
    c = store.open(_spec(kinds=("a100",)))
    t1 = LatencyTable("a100")
    t1.add(analyse_pair(210.0, 705.0, np.full(6, 5e-3)))
    c.save_unit_result("a100@fast", t1, {(210.0, 705.0): 5e-3})
    t2 = LatencyTable("a100")
    t2.add(analyse_pair(705.0, 210.0, np.full(6, 6e-3)))
    c.save_unit_result("a100@fast", t2, {(705.0, 210.0): 6e-3})
    assert c.ground_truth("a100@fast") == {(210.0, 705.0): 5e-3,
                                           (705.0, 210.0): 6e-3}


def test_ground_truth_persisted_for_simulated_devices(tmp_path):
    store = ArtifactStore(str(tmp_path))
    result = run_campaign(_spec(kinds=("a100",)), store)
    gt = result.campaign.ground_truth("a100@fast")
    table = result.campaign.load_table("a100@fast")
    assert gt                                   # simulator logged the truth
    ok = [(p, pr) for p, pr in table.pairs.items()
          if pr.status == "ok" and p in gt]
    errs = [abs(pr.worst_case - gt[p]) / gt[p] for p, pr in ok]
    assert np.median(errs) < 0.15               # pipeline recovers the model


# ------------------------------------------------------------------ #
# aggregation + governor integration
# ------------------------------------------------------------------ #
def test_report_covers_all_units(tmp_path):
    store = ArtifactStore(str(tmp_path))
    result = run_campaign(_spec(), store)
    md = comparison_markdown(result.campaign)
    assert "a100@fast" in md and "rtx6000@fast" in md
    report = report_markdown(result.campaign)
    assert "Table II" in report and "Campaign" in report


def test_governor_from_campaign(tmp_path):
    from repro.dvfs.governor import Governor
    store = ArtifactStore(str(tmp_path))
    result = run_campaign(_spec(), store)
    # by bare device key (unique) and by full unit key
    g = Governor.from_campaign(result.campaign, "a100")
    assert g.freqs == [210.0, 705.0, 1410.0]
    g2 = Governor.from_campaign(result.campaign, "a100@fast")
    assert g2.freqs == g.freqs
    assert g.latency(210.0, 1410.0) == g2.latency(210.0, 1410.0)
    with pytest.raises(KeyError, match="no finished"):
        Governor.from_campaign(result.campaign, "h100")


# ------------------------------------------------------------------ #
# CLI round trip
# ------------------------------------------------------------------ #
def test_cli_run_ls_report_diff_roundtrip(tmp_path, capsys):
    spec = _spec(kinds=("a100",))
    spec_path = str(tmp_path / "spec.json")
    spec.save(spec_path)
    store = ["--store", str(tmp_path / "store")]

    assert cli_main(store + ["run", spec_path, "--quiet"]) == 0
    cid = spec.campaign_id()
    assert cli_main(store + ["ls"]) == 0
    out = capsys.readouterr().out
    assert cid in out and "1/1" in out

    report_path = str(tmp_path / "report.md")
    assert cli_main(store + ["report", cid[:6], "--out", report_path]) == 0
    assert "Table II" in open(report_path).read()

    # self-diff is clean (exit 0)
    assert cli_main(store + ["diff", cid, cid]) == 0


def test_cli_run_resumes(tmp_path, capsys):
    spec = _spec(kinds=("a100",))
    spec_path = str(tmp_path / "spec.json")
    spec.save(spec_path)
    store = ["--store", str(tmp_path / "store")]
    assert cli_main(store + ["run", spec_path, "--quiet"]) == 0
    capsys.readouterr()
    assert cli_main(store + ["run", spec_path]) == 0
    assert "1 unit(s) loaded from store, 0 to run" in capsys.readouterr().out


# ------------------------------------------------------------------ #
# paths helper (satellite)
# ------------------------------------------------------------------ #
def test_results_dir_honors_env(tmp_path, monkeypatch):
    from repro.core.paths import campaigns_dir, results_dir
    monkeypatch.setenv("REPRO_RESULTS_DIR", str(tmp_path / "rd"))
    assert results_dir("x") == os.path.join(str(tmp_path / "rd"), "x")
    assert campaigns_dir().startswith(str(tmp_path / "rd"))
    p = results_dir("made", create=True)
    assert os.path.isdir(p)
    monkeypatch.delenv("REPRO_RESULTS_DIR")
    assert results_dir("x") == os.path.join("results", "x")


def test_default_store_under_results_dir(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_RESULTS_DIR", str(tmp_path))
    store = ArtifactStore()
    assert store.root == str(tmp_path / "campaigns")
    assert store.list_ids() == []


def test_store_load_by_prefix_and_errors(tmp_path):
    store = ArtifactStore(str(tmp_path))
    c = store.open(_spec(kinds=("a100",)))
    assert store.load(c.campaign_id[:5]).campaign_id == c.campaign_id
    with pytest.raises(KeyError, match="no campaign"):
        store.load("zzz")


def test_manifest_is_valid_json_after_marks(tmp_path):
    store = ArtifactStore(str(tmp_path))
    c = store.open(_spec(kinds=("a100",)))
    c.mark_unit("a100@fast", status="running", attempts=1)
    with open(os.path.join(c.dir, "manifest.json")) as f:
        doc = json.load(f)
    assert doc["units"]["a100@fast"]["status"] == "running"
