"""Online switching-latency estimator: streaming Alg. 2 must agree with
the batch ``detect_switch`` path within the device timer resolution, for
every frequency pair of the default simulated device (acceptance
criterion), and emit actionable provisional estimates mid-kernel."""
import numpy as np
import pytest

from repro.backends import create_backend
from repro.core.calibration import calibrate
from repro.core.stats import FreqStats
from repro.core.switching import detect_switch, measure_switch_once
from repro.core.workload import WorkloadSpec
from repro.trace import TracedBackend, TraceRecorder
from repro.trace.analyze import iter_switch_passes
from repro.trace.online import OnlineSwitchEstimator, stream_pass

# the default simulated device (a100) measured over an evenly spaced
# frequency subset — every ordered pair is exercised
FREQS = [210.0, 705.0, 1095.0, 1410.0]
SPEC = WorkloadSpec(iters_per_kernel=900, flops_per_iter=40e-6,
                    delay_iters=250, confirm_iters=300)


@pytest.fixture(scope="module")
def switch_passes():
    """One pass per ordered frequency pair, recorded through the trace
    layer so online and batch see the identical bits the device produced."""
    rec = TraceRecorder()
    device = TracedBackend(create_backend("simulated", n_cores=4, seed=1),
                           rec)
    cal = calibrate(device, FREQS, SPEC)
    live = []
    for fi in FREQS:
        for ft in FREQS:
            if fi == ft:
                continue
            live.append(((fi, ft),
                         measure_switch_once(device, fi, ft, cal, SPEC)))
    trace = rec.finish()
    passes = list(iter_switch_passes(trace))
    assert len(passes) == len(live)
    timer = float(trace.meta["device"]["timer_resolution_s"])
    return cal, live, passes, timer


def test_trace_reconstruction_matches_live_batch(switch_passes):
    """Replaying a reconstructed pass through detect_switch reproduces the
    live measure_switch_once result exactly (same t_s, same data bits)."""
    cal, live, passes, _ = switch_passes
    for ((fi, ft), sp), pt in zip(live, passes):
        assert (pt.f_init, pt.f_target) == (fi, ft)
        again = detect_switch(pt.data, pt.t_s, cal.baselines[ft])
        assert (sp is None) == (again is None)
        if sp is not None:
            assert again.latency == sp.latency
            assert again.t_s == sp.t_s


def test_online_agrees_with_batch_for_all_pairs(switch_passes):
    """Acceptance: |online - batch| <= timer resolution on every pair of
    the default simulated device (and identical reject decisions)."""
    cal, live, passes, timer = switch_passes
    n_checked = 0
    for ((fi, ft), sp), pt in zip(live, passes):
        final, provisional = stream_pass(pt.data, pt.t_s, cal.baselines[ft])
        assert (final is None) == (sp is None)
        if sp is None:
            continue
        assert abs(final.latency - sp.latency) <= timer
        assert provisional, "no provisional estimate before kernel end"
        assert not provisional[0].final and final.final
        n_checked += 1
    assert n_checked > 0, "every pass was rejected — fixture broken"


def test_provisional_matches_core_candidate(switch_passes):
    cal, live, passes, timer = switch_passes
    for ((fi, ft), sp), pt in zip(live, passes):
        if sp is None:
            continue
        final, provisional = stream_pass(pt.data, pt.t_s, cal.baselines[ft])
        # the final estimate is the max over per-core confirmed latencies,
        # so it appears among the provisional per-core emissions
        assert any(abs(p.latency - final.latency) <= timer
                   for p in provisional)
        # matches the batch per-core picture
        viable = sp.core_latencies[~np.isnan(sp.core_latencies)]
        assert abs(final.latency - float(np.max(viable))) <= timer


def test_estimator_state_machine_synthetic():
    """Deterministic synthetic pass: clean level shift at a known index."""
    target = FreqStats(freq_mhz=705.0, mean=1e-4, std=2e-6, n=100_000)
    n_iters, shift = 300, 120
    durs = np.full(n_iters, 2e-4)          # f_init level, out of band
    durs[shift:] = 1e-4                    # target level from `shift` on
    starts = np.concatenate([[0.0], np.cumsum(durs)[:-1]])
    ends = starts + durs
    t_s = float(starts[40])                # change requested at iter 40
    est = OnlineSwitchEstimator(target, t_s, min_confirm=64)
    provisional = None
    for i in range(n_iters):
        out = est.observe(0, float(starts[i]), float(ends[i]))
        if out is not None:
            provisional = out
            assert i >= shift + 63         # needs min_confirm samples
    final = est.finalize()
    assert provisional is not None
    assert final is not None
    assert final.transition_index == shift
    assert final.latency == pytest.approx(float(ends[shift]) - t_s)
    assert final.latency == provisional.latency


def test_estimator_rejects_pass_through():
    """A single in-band blip that does NOT hold (mean stays at the initial
    level) must not confirm — Alg. 2's pass-through rejection."""
    target = FreqStats(freq_mhz=705.0, mean=1e-4, std=2e-6, n=100_000)
    n_iters = 300
    durs = np.full(n_iters, 2e-4)
    durs[100] = 1e-4                       # lone in-band blip
    starts = np.concatenate([[0.0], np.cumsum(durs)[:-1]])
    ends = starts + durs
    est = OnlineSwitchEstimator(target, float(starts[40]), min_confirm=64)
    for i in range(n_iters):
        assert est.observe(0, float(starts[i]), float(ends[i])) is None
    assert est.finalize() is None
