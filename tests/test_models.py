"""Per-arch smoke tests (assignment requirement): reduced config, one
forward/train step on CPU, output shapes + no NaNs; plus decode-vs-forward
consistency for representative families."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.configs.registry import decode_module, model_module
from repro.parallel.sharding import make_env

ENV = make_env(None, None)


def _batch(cfg, b=2, s=32, seed=0):
    key = jax.random.PRNGKey(seed)
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab)}
    if cfg.family == "vlm":
        batch["img_embeds"] = jax.random.normal(
            key, (b, cfg.vlm.n_patches, cfg.d_model), cfg.compute_dtype)
    if cfg.family == "encdec":
        batch["enc_frames"] = jax.random.normal(
            key, (b, cfg.encdec.n_frames, cfg.d_model), cfg.compute_dtype)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch, smoke=True)
    mod = model_module(cfg)
    params, axes = mod.init(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    logits, aux = mod.forward(params, batch, cfg, ENV)
    assert logits.shape == (2, 32, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    loss, grads = jax.value_and_grad(
        lambda p: mod.loss_fn(p, batch, cfg, ENV))(params)
    assert bool(jnp.isfinite(loss))
    for g in jax.tree.leaves(grads):
        assert bool(jnp.isfinite(g.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode(arch):
    cfg = get_config(arch, smoke=True)
    mod, dec = model_module(cfg), decode_module(cfg)
    params, _ = mod.init(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    logits, cache = dec.prefill(params, batch, cfg, ENV, max_len=64)
    assert logits.shape == (2, cfg.padded_vocab)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, cache = dec.decode_step(params, cache, tok, jnp.int32(32), cfg, ENV)
    assert bool(jnp.isfinite(logits2.astype(jnp.float32)).all())
    assert int(jnp.argmax(logits2[0])) < cfg.vocab     # pad ids masked


@pytest.mark.parametrize("arch", ["llama3-8b", "mamba2-130m",
                                  "deepseek-v2-236b", "whisper-medium"])
def test_decode_matches_forward(arch):
    """Incremental decode must reproduce teacher-forced forward logits."""
    cfg = get_config(arch, smoke=True)
    cfg = dataclasses.replace(cfg, param_dtype=jnp.float32,
                              compute_dtype=jnp.float32)
    if cfg.moe is not None:
        # capacity-based token dropping legitimately differs between a
        # 16-token prefill and the 32-token forward (different T -> different
        # capacity); raise cf so no tokens drop and the cache math is tested
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    mod, dec = model_module(cfg), decode_module(cfg)
    params, _ = mod.init(jax.random.PRNGKey(1), cfg)
    b, s, ctx = 2, 32, 16
    batch = _batch(cfg, b, s, seed=1)
    full_logits, _ = mod.forward(params, batch, cfg, ENV)

    prefill_batch = dict(batch, tokens=batch["tokens"][:, :ctx])
    logits, cache = dec.prefill(params, prefill_batch, cfg, ENV, max_len=s)
    np.testing.assert_allclose(np.asarray(logits),
                               np.asarray(full_logits[:, ctx - 1]),
                               atol=2e-3, rtol=2e-3)
    for i in range(ctx, s):
        tok = batch["tokens"][:, i: i + 1]
        logits, cache = dec.decode_step(params, cache, tok, jnp.int32(i),
                                        cfg, ENV)
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(full_logits[:, i]),
                                   atol=2e-3, rtol=2e-3)


def test_param_count_matches_actual():
    for arch in ("llama3-8b", "mamba2-130m"):
        cfg = get_config(arch, smoke=True)
        mod = model_module(cfg)
        params, _ = mod.init(jax.random.PRNGKey(0), cfg)
        actual = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
        # padded vocab + norm scales make actual slightly larger
        assert actual == pytest.approx(cfg.param_count(), rel=0.12)
