"""Heartbeats, straggler policy, retry, elastic remesh."""
import pytest

from repro.runtime.fault_tolerance import (HeartbeatMonitor, StragglerPolicy,
                                           elastic_remesh, retry_step)


def test_heartbeat_detects_dead_worker():
    clock = [0.0]
    hb = HeartbeatMonitor(3, timeout_s=10.0, clock=lambda: clock[0])
    clock[0] = 5.0
    hb.beat(0); hb.beat(1)
    clock[0] = 12.0
    assert hb.dead() == [2]
    clock[0] = 30.0
    assert set(hb.dead()) == {0, 1, 2}


def test_straggler_policy_evicts_after_budget():
    sp = StragglerPolicy(ratio=1.5, budget=3)
    for _ in range(10):
        assert sp.observe(1.0) == "ok"
    verdicts = [sp.observe(5.0) for _ in range(3)]
    assert verdicts == ["degraded", "degraded", "evict"]
    # healthy step resets the counter
    sp2 = StragglerPolicy(ratio=1.5, budget=3)
    [sp2.observe(1.0) for _ in range(5)]
    sp2.observe(5.0)
    sp2.observe(1.0)
    assert sp2.observe(5.0) == "degraded"


def test_straggler_ewma_not_poisoned():
    sp = StragglerPolicy(ratio=1.5, budget=100)
    [sp.observe(1.0) for _ in range(5)]
    [sp.observe(10.0) for _ in range(5)]       # stragglers
    assert sp._ewma < 1.5                      # EWMA ignored the spikes


def test_retry_step_recovers():
    calls = []

    def flaky(x):
        calls.append(x)
        if len(calls) < 3:
            raise RuntimeError("transient")
        return x * 2

    assert retry_step(flaky, 21, retries=5) == 42
    assert len(calls) == 3
    with pytest.raises(RuntimeError):
        retry_step(lambda: (_ for _ in ()).throw(RuntimeError("x")), retries=2)


def test_elastic_remesh_single_device():
    mesh, dropped = elastic_remesh()
    assert mesh.shape["model"] >= 1 and mesh.shape["data"] >= 1
    assert mesh.size + len(dropped) == len(__import__("jax").devices())
