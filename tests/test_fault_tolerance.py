"""Heartbeats, straggler policy, retry, elastic remesh."""
import pytest

from repro.runtime.fault_tolerance import (HeartbeatMonitor, StragglerPolicy,
                                           elastic_remesh, retry_step)


def test_heartbeat_detects_dead_worker():
    clock = [0.0]
    hb = HeartbeatMonitor(3, timeout_s=10.0, clock=lambda: clock[0])
    clock[0] = 5.0
    hb.beat(0); hb.beat(1)
    clock[0] = 12.0
    assert hb.dead() == [2]
    clock[0] = 30.0
    assert set(hb.dead()) == {0, 1, 2}


def test_heartbeat_zero_workers_edge():
    hb = HeartbeatMonitor(0, timeout_s=1.0, clock=lambda: 99.0)
    assert hb.dead() == []                     # nothing tracked, nothing dead
    hb.beat(7)                                 # never registered: ignored
    assert hb.dead() == []
    hb.register(7)
    assert hb.dead() == []


def test_heartbeat_beat_after_dead_is_dropped():
    """A worker reaped after a timeout must stay gone: a late beat from
    the zombie process cannot resurrect it into the liveness map."""
    clock = [0.0]
    hb = HeartbeatMonitor(2, timeout_s=10.0, clock=lambda: clock[0])
    clock[0] = 15.0
    assert set(hb.dead()) == {0, 1}
    hb.remove(0)                               # driver reaps it
    hb.beat(0)                                 # zombie's queued beat arrives
    assert hb.dead() == [1]
    assert 0 not in hb.last
    hb.register(0)                             # an EXPLICIT replacement is
    assert hb.dead() == [1]                    # tracked from now


def test_heartbeat_dynamic_register_uses_injected_clock():
    clock = [100.0]
    hb = HeartbeatMonitor(0, timeout_s=5.0, clock=lambda: clock[0])
    hb.register("w0")
    clock[0] = 104.0
    hb.register("w1")
    clock[0] = 106.0
    assert hb.dead() == ["w0"]
    hb.beat("w0")
    assert hb.dead() == []
    hb.remove("missing")                       # idempotent


def test_heartbeat_rejects_nonpositive_timeout():
    with pytest.raises(ValueError, match="timeout"):
        HeartbeatMonitor(2, timeout_s=0.0)


def test_straggler_policy_evicts_after_budget():
    sp = StragglerPolicy(ratio=1.5, budget=3)
    for _ in range(10):
        assert sp.observe(1.0) == "ok"
    verdicts = [sp.observe(5.0) for _ in range(3)]
    assert verdicts == ["degraded", "degraded", "evict"]
    # healthy step resets the counter
    sp2 = StragglerPolicy(ratio=1.5, budget=3)
    [sp2.observe(1.0) for _ in range(5)]
    sp2.observe(5.0)
    sp2.observe(1.0)
    assert sp2.observe(5.0) == "degraded"


def test_straggler_ewma_not_poisoned():
    sp = StragglerPolicy(ratio=1.5, budget=100)
    [sp.observe(1.0) for _ in range(5)]
    [sp.observe(10.0) for _ in range(5)]       # stragglers
    assert sp._ewma < 1.5                      # EWMA ignored the spikes


def test_straggler_in_flight_tracking_monotonic_clock():
    """start/elapsed/straggling run on the injected clock, so wall-clock
    steps (NTP) cannot flag or unflag a task."""
    clock = [0.0]
    sp = StragglerPolicy(ratio=2.0, clock=lambda: clock[0])
    sp.start("t0")
    clock[0] = 1.0
    assert sp.elapsed("t0") == 1.0
    assert not sp.straggling("t0")             # no EWMA baseline yet
    assert sp.finish("t0") == "ok"             # first observation seeds EWMA
    assert sp.ewma == 1.0
    sp.start("t1")
    clock[0] = 2.5
    assert not sp.straggling("t1")             # 1.5s < 2 x 1.0
    clock[0] = 3.5
    assert sp.straggling("t1")                 # 2.5s > 2 x 1.0
    sp.start("t1")                             # duplicate dispatch keeps the
    assert sp.elapsed("t1") == 2.5             # original start stamp
    sp.abandon("t1")
    assert sp.elapsed("t1") == 0.0             # unknown after abandon
    assert not sp.straggling("t1")
    assert sp.finish("t1") == "ok"             # unknown: untracked no-op
    assert sp.ewma == 1.0


def test_straggler_finish_folds_duration_into_ewma():
    clock = [0.0]
    sp = StragglerPolicy(ratio=10.0, alpha=0.5, clock=lambda: clock[0])
    sp.start("a"); clock[0] = 2.0
    sp.finish("a")                             # seeds EWMA at 2.0
    sp.start("b"); clock[0] = 6.0
    sp.finish("b")                             # healthy: folds in 4.0
    assert sp.ewma == pytest.approx(3.0)


def test_retry_step_recovers():
    calls = []

    def flaky(x):
        calls.append(x)
        if len(calls) < 3:
            raise RuntimeError("transient")
        return x * 2

    assert retry_step(flaky, 21, retries=5) == 42
    assert len(calls) == 3
    with pytest.raises(RuntimeError):
        retry_step(lambda: (_ for _ in ()).throw(RuntimeError("x")), retries=2)


def test_elastic_remesh_single_device():
    mesh, dropped = elastic_remesh()
    assert mesh.shape["model"] >= 1 and mesh.shape["data"] >= 1
    assert mesh.size + len(dropped) == len(__import__("jax").devices())
