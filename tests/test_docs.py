"""The docs gate, in tier-1: fenced python snippets compile, relative
links resolve, and every built-in backend is documented.  Mirrors CI's
`docs-check` job (`tools/check_docs.py`) so a docs regression fails the
local suite too."""
import importlib.util
import pathlib

ROOT = pathlib.Path(__file__).resolve().parent.parent


def _load_checker():
    # tools/ is a scripts directory, not a package
    spec = importlib.util.spec_from_file_location(
        "check_docs", ROOT / "tools" / "check_docs.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_docs_tree_exists():
    for name in ("architecture.md", "backends.md", "methodology.md"):
        assert (ROOT / "docs" / name).is_file(), name


def test_python_snippets_compile():
    chk = _load_checker()
    errors = [e for p in chk.doc_files() for e in chk.check_snippets(p)]
    assert not errors, "\n".join(errors)


def test_relative_links_resolve():
    chk = _load_checker()
    errors = [e for p in chk.doc_files() for e in chk.check_links(p)]
    assert not errors, "\n".join(errors)


def test_every_builtin_backend_documented():
    chk = _load_checker()
    errors = chk.check_backend_coverage()
    assert not errors, "\n".join(errors)


def test_snippet_extractor_sees_the_real_snippets():
    """Guard against the extractor silently matching nothing (which would
    make the compile gate vacuous)."""
    chk = _load_checker()
    per_file = {p.name: len(chk.python_snippets(p.read_text()))
                for p in chk.doc_files()}
    assert per_file.get("README.md", 0) >= 2
    assert per_file.get("backends.md", 0) >= 2
