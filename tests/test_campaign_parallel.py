"""Process-parallel campaign execution: the fault-tolerant work queue.

Covers the resilience contract end to end against real worker processes:
a worker hard-killed mid-pair has its unit requeued and the recovered
campaign is bit-identical to the serial schedule; a unit that exhausts
its attempt budget lands in ``CampaignResult.failed`` without poisoning
the rest; a silently hung worker is detected by heartbeat timeout and
its unit re-dispatched; a live straggler is speculatively duplicated
with first-result-wins."""
import os

import numpy as np
import pytest

from repro.campaign import (ArtifactStore, CampaignRunner, CampaignSpec,
                            DeviceSpec, MeasureSpec, run_campaign)
from repro.campaign.workqueue import FaultPlan, fault_marker_path

FAST = MeasureSpec(key="fast", min_measurements=4, max_measurements=5,
                   rse_check_every=4)
FREQS = (210.0, 705.0, 1410.0)


def _device(key, seed, kind="a100"):
    return DeviceSpec.make(key, "simulated",
                           {"kind": kind, "n_cores": 6, "seed": seed},
                           frequencies=FREQS)


def _fleet(n=4, retries=3):
    return CampaignSpec("par", devices=tuple(_device(f"u{i}", i)
                                             for i in range(n)),
                        measures=(FAST,), retries=retries)


def _assert_tables_bit_identical(ref, cand):
    assert set(ref.outcomes) == set(cand.outcomes)
    for key in ref.outcomes:
        rt, ct = ref.campaign.load_table(key), cand.campaign.load_table(key)
        rm = ref.outcomes[key].table          # serial in-memory table too:
        assert set(rt.pairs) == set(ct.pairs)  # the store round trip is
        for p, pr in rt.pairs.items():         # part of the contract
            for other in (ct.pairs[p], rm.pairs[p]):
                assert np.array_equal(pr.latencies, other.latencies)
                assert np.array_equal(pr.outlier_mask, other.outlier_mask)
            assert pr.status == ct.pairs[p].status
            assert pr.n_clusters == ct.pairs[p].n_clusters


def test_crashed_worker_unit_requeued_bit_identical(tmp_path):
    """A worker hard-killed (os._exit) two pairs into a unit: the pairs it
    persisted are resumed, the rest measured by a surviving worker, and
    the final tables match the serial schedule byte for byte."""
    spec = _fleet(4)
    ref = run_campaign(spec, ArtifactStore(str(tmp_path / "serial")))
    assert ref.ok

    crash_key = spec.units()[0].key
    cand = CampaignRunner(
        spec, ArtifactStore(str(tmp_path / "proc")), executor="processes",
        max_workers=2,
        fault_plan=FaultPlan.make(crash_after_pairs={crash_key: 2})).run()
    assert cand.ok, [(o.key, o.error) for o in cand.failed()]
    # the kill really fired (marker), was seen (dead worker), and the
    # unit went through the requeue path, burning one attempt
    assert os.path.exists(
        fault_marker_path(cand.campaign, crash_key, "crash"))
    assert cand.stats["crashed_workers"] >= 1
    assert cand.stats["requeued_units"] >= 1
    assert cand.outcomes[crash_key].attempts >= 2
    # ...and the crashed unit's session dir shows a pair-level resume:
    # the first attempt's persisted pairs were never re-measured
    _assert_tables_bit_identical(ref, cand)
    # the oracle rides with the pair files, so the resumed attempt has no
    # ground-truth holes for pairs measured by the dead worker
    table_pairs = set(cand.campaign.load_table(crash_key).pairs)
    assert table_pairs <= set(cand.campaign.ground_truth(crash_key))


def test_unit_exhausting_retries_fails_without_poisoning(tmp_path):
    """A unit whose worker attempt fails every time (unknown device kind
    raises inside the worker) is marked failed after spec.retries total
    attempts while every healthy unit completes."""
    bad = DeviceSpec.make("bad", "simulated",
                          {"kind": "no-such-gpu", "n_cores": 6, "seed": 0},
                          frequencies=FREQS)
    spec = CampaignSpec("mix", devices=(bad, _device("ok0", 1),
                                        _device("ok1", 2)),
                        measures=(FAST,), retries=2)
    result = CampaignRunner(spec, ArtifactStore(str(tmp_path)),
                            executor="processes", max_workers=2).run()
    assert not result.ok
    (failed,) = result.failed()
    assert failed.key == "bad@fast"
    assert failed.attempts == 2                   # spec.retries is TOTAL
    assert "no-such-gpu" in failed.error
    for key in ("ok0@fast", "ok1@fast"):
        assert result.outcomes[key].status == "done"
    states = result.campaign.unit_states()
    assert states["bad@fast"]["status"] == "failed"
    assert states["ok0@fast"]["status"] == "done"


def test_hung_worker_detected_by_heartbeat_and_requeued(tmp_path):
    """A worker that goes silent (sleeps without heartbeats) past the
    timeout is terminated and its unit re-dispatched; the stall fires only
    on the first attempt, so the retry completes."""
    spec = _fleet(2, retries=3)
    stall_key = spec.units()[0].key
    result = CampaignRunner(
        spec, ArtifactStore(str(tmp_path)), executor="processes",
        max_workers=2, heartbeat_timeout_s=3.0, speculate=False,
        fault_plan=FaultPlan.make(stall_s={stall_key: 60.0})).run()
    assert result.ok, [(o.key, o.error) for o in result.failed()]
    assert result.stats["hung_workers"] >= 1
    assert result.stats["requeued_units"] >= 1
    assert result.outcomes[stall_key].attempts >= 2


def test_straggler_unit_speculatively_duplicated(tmp_path):
    """A unit that is slow but alive (beats flowing) gets cloned onto idle
    capacity once its elapsed time exceeds ratio x EWMA; the clean clone
    wins and the campaign completes without burning retry attempts."""
    spec = _fleet(4, retries=2)
    slow_key = spec.units()[0].key
    result = CampaignRunner(
        spec, ArtifactStore(str(tmp_path)), executor="processes",
        max_workers=2, straggler_ratio=1.5, heartbeat_timeout_s=60.0,
        fault_plan=FaultPlan.make(slow_pairs_s={slow_key: 1.0})).run()
    assert result.ok, [(o.key, o.error) for o in result.failed()]
    assert result.stats["speculative_dispatches"] >= 1
    assert result.stats["requeued_units"] == 0
    assert result.stats["crashed_workers"] == 0


def test_processes_records_traces(tmp_path):
    spec = _fleet(1)
    result = CampaignRunner(spec, ArtifactStore(str(tmp_path)),
                            executor="processes", max_workers=1,
                            trace=True).run()
    assert result.ok
    traces = result.campaign.list_traces()
    assert traces.get("u0@fast") == ["session"]


def test_process_campaign_resumes_from_store(tmp_path):
    spec = _fleet(2)
    store = ArtifactStore(str(tmp_path))
    first = CampaignRunner(spec, store, executor="processes",
                           max_workers=2).run()
    assert first.ok
    again = CampaignRunner(spec, store, executor="processes",
                           max_workers=2).run()
    assert again.ok
    assert all(o.status == "loaded" for o in again.outcomes.values())


def test_fault_plan_roundtrip_and_empty():
    assert FaultPlan().empty
    fp = FaultPlan.make(crash_after_pairs={"a": 2}, stall_s={"b": 1.5},
                        slow_pairs_s={"c": 0.2})
    assert not fp.empty
    assert fp.crash_for("a") == 2 and fp.crash_for("b") is None
    assert fp.stall_for("b") == 1.5
    assert fp.slow_for("c") == 0.2


@pytest.mark.slow
def test_speculative_duplicate_discarded_when_original_wins(tmp_path):
    """First-result-wins the other way around: with speculation forced
    early (tiny ratio) onto a unit that is NOT actually slow, whichever
    copy loses is discarded without corrupting artifacts."""
    spec = _fleet(3, retries=2)
    result = CampaignRunner(
        spec, ArtifactStore(str(tmp_path / "proc")), executor="processes",
        max_workers=3, straggler_ratio=0.01).run()
    assert result.ok
    ref = run_campaign(spec, ArtifactStore(str(tmp_path / "serial")))
    _assert_tables_bit_identical(ref, result)


def test_fault_plan_drift_spec_parsing():
    fp = FaultPlan.make(drift_after_pairs={"a": (2, 4.0),
                                           "b": (1, 3.0, 210.0, 705.0)})
    assert not fp.empty
    assert fp.drift_for("a") == (2, 4.0, None, None)
    assert fp.drift_for("b") == (1, 3.0, 210.0, 705.0)
    assert fp.drift_for("c") is None


def test_fault_plan_ramp_and_direction_spec_parsing():
    fp = FaultPlan.make(drift_ramp_pairs={"a": (2, 1.5, 64)},
                        drift_direction="up")
    assert not fp.empty
    assert fp.drift_ramp_for("a") == (2, 1.5, 64)
    assert fp.drift_ramp_for("b") is None
    assert fp.drift_direction == "up"
    with pytest.raises(ValueError, match="drift_direction"):
        FaultPlan.make(drift_direction="sideways")


def test_activate_drift_wraps_the_live_model_idempotently():
    from repro.backends import create_backend
    from repro.campaign.workqueue import activate_drift
    from repro.dvfs.transition_models import ShiftedTransitionModel

    class _Session:
        pass

    s = _Session()
    s.device = create_backend("simulated", n_cores=2, seed=0)
    base = s.device.model
    activate_drift(s, 4.0, 210.0, 705.0)
    model = s.device.model
    assert isinstance(model, ShiftedTransitionModel)
    assert model.inner is base
    assert model.only_pair == (210.0, 705.0)
    activate_drift(s, 4.0, 210.0, 705.0)     # second trip: no re-wrap
    assert s.device.model is model


def test_drift_injection_refuses_untraced_schedules(tmp_path):
    """Without the traced shared-device path a mid-unit model shift would
    never be observed; the worker must fail loudly, not measure garbage."""
    spec = _fleet(1, retries=1)
    key = spec.units()[0].key
    result = CampaignRunner(
        spec, ArtifactStore(str(tmp_path / "bad")), executor="processes",
        max_workers=1,
        fault_plan=FaultPlan.make(drift_after_pairs={key: (1, 4.0)})).run()
    assert not result.ok
    assert "trace" in result.outcomes[key].error


def test_drift_injection_departs_baseline_mid_unit(tmp_path):
    """FaultPlan drift through the process scheduler: the marker proves
    the injection fired, the run still completes, and the batch differ
    flags the drifted tail of the sweep against an uninjected twin."""
    from repro.campaign import diff_campaigns

    spec = _fleet(1)
    key = spec.units()[0].key
    clean = CampaignRunner(
        spec, ArtifactStore(str(tmp_path / "clean")), executor="processes",
        max_workers=1, trace=True).run()
    assert clean.ok

    drifted = CampaignRunner(
        spec, ArtifactStore(str(tmp_path / "drift")), executor="processes",
        max_workers=1, trace=True,
        fault_plan=FaultPlan.make(
            drift_after_pairs={key: (2, 4.0)})).run()
    assert drifted.ok, [(o.key, o.error) for o in drifted.failed()]
    assert os.path.exists(
        fault_marker_path(drifted.campaign, key, "drift"))
    # drift is not a fault: nothing crashed, nothing was requeued
    assert drifted.stats.get("crashed_workers", 0) == 0

    diff = diff_campaigns(clean.campaign, drifted.campaign)
    flagged = diff.flagged()
    n_pairs = len(clean.campaign.load_table(key).pairs)
    assert flagged, "a 4x latency scale must be visible to the differ"
    # the two pairs measured before activation stayed on-baseline
    assert len(flagged) < n_pairs


def test_ramped_direction_gated_drift_only_hits_up_transitions(tmp_path):
    """`drift_ramp_pairs` + `drift_direction="up"`: the scale creeps in
    over the next few draws and only frequency *increases* depart the
    baseline — downward transitions stay bit-comparable, so the batch
    differ flags up-pairs exclusively (the Fig. 4 asymmetry, drifting
    on one side of the matrix)."""
    from repro.campaign import diff_campaigns

    spec = _fleet(1)
    key = spec.units()[0].key
    clean = CampaignRunner(
        spec, ArtifactStore(str(tmp_path / "clean")), executor="processes",
        max_workers=1, trace=True).run()
    assert clean.ok

    drifted = CampaignRunner(
        spec, ArtifactStore(str(tmp_path / "ramp")), executor="processes",
        max_workers=1, trace=True,
        fault_plan=FaultPlan.make(
            drift_ramp_pairs={key: (1, 4.0, 4)},
            drift_direction="up")).run()
    assert drifted.ok, [(o.key, o.error) for o in drifted.failed()]
    assert os.path.exists(
        fault_marker_path(drifted.campaign, key, "drift"))

    diff = diff_campaigns(clean.campaign, drifted.campaign)
    flagged = diff.flagged()
    assert flagged, "a ramped 4x up-scale must be visible to the differ"
    assert all(p.f_target > p.f_init for p in flagged), (
        "direction='up' drift leaked into downward transitions: "
        + str([(p.f_init, p.f_target) for p in flagged]))
