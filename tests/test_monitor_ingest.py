"""Stream ingestion parity: a DeviceStream fed the raw event stream must
learn exactly what the session itself knows — calibration baselines bit
for bit, pass estimates identical to the offline analyzer — and a live
tap must be indistinguishable from replaying the stored trace."""
import numpy as np
import pytest

from repro.backends import create_backend
from repro.core.calibration import calibrate
from repro.core.switching import measure_switch_once
from repro.core.workload import WorkloadSpec
from repro.monitor import DeviceStream
from repro.monitor.ingest import replay_events
from repro.trace import TracedBackend, TraceRecorder
from repro.trace.analyze import iter_switch_passes
from repro.trace.online import stream_pass

FREQS = [210.0, 705.0, 1410.0]
SPEC = WorkloadSpec(iters_per_kernel=900, flops_per_iter=40e-6,
                    delay_iters=250, confirm_iters=300)


@pytest.fixture(scope="module")
def recorded():
    """A calibrated sweep (one pass per ordered pair) recorded with a
    live DeviceStream tap attached from the first event."""
    rec = TraceRecorder()
    live = DeviceStream("dev0")
    rec.add_tap(live.tap())
    device = TracedBackend(create_backend("simulated", n_cores=4, seed=3),
                           rec)
    cal = calibrate(device, FREQS, SPEC)
    n_pairs = 0
    for fi in FREQS:
        for ft in FREQS:
            if fi != ft:
                measure_switch_once(device, fi, ft, cal, SPEC)
                n_pairs += 1
    return cal, live, rec.finish(), n_pairs


def _replayed(trace):
    stream = DeviceStream("dev0")
    estimates = [est for ev in replay_events(trace)
                 if (est := stream.feed(*ev)) is not None]
    return stream, estimates


def test_baselines_learned_from_the_wire_bit_match_calibration(recorded):
    cal, live, _, _ = recorded
    assert set(live.baselines) == set(cal.baselines)
    for f, learned in live.baselines.items():
        ref = cal.baselines[f]
        assert learned.mean == ref.mean
        assert learned.std == ref.std
        assert learned.n == ref.n


def test_live_tap_equals_offline_replay(recorded):
    """The tap sees exactly what the stored trace replays: every counter
    and every learned baseline agree between the two paths."""
    _, live, trace, n_pairs = recorded
    replay, estimates = _replayed(trace)
    assert live.n_events == replay.n_events == trace.n_events
    assert live.n_passes == replay.n_passes == n_pairs
    assert live.n_skipped == replay.n_skipped == 0
    assert live.n_rejected == replay.n_rejected
    assert live.n_provisional == replay.n_provisional
    assert live.last_t == replay.last_t
    assert len(estimates) == n_pairs
    for f, b in live.baselines.items():
        rb = replay.baselines[f]
        assert (b.mean, b.std, b.n) == (rb.mean, rb.std, rb.n)


def test_streamed_estimates_match_offline_analyzer(recorded):
    """Each streamed estimate equals stream_pass run on the offline
    analyzer's reconstruction of the same pass against the session's own
    calibration baselines (which the stream only learned from events)."""
    cal, _, trace, _ = recorded
    _, estimates = _replayed(trace)
    passes = list(iter_switch_passes(trace))
    assert len(estimates) == len(passes)
    for est, sp in zip(estimates, passes):
        assert (est.f_init, est.f_target) == (sp.f_init, sp.f_target)
        assert est.t_s == sp.t_s
        final, provisional = stream_pass(sp.data, sp.t_s,
                                         cal.baselines[sp.f_target])
        if final is None:
            assert est.latency_s is None
        else:
            assert est.latency_s == float(final.latency)
        assert est.n_provisional == len(provisional)
        assert est.device == "dev0"


def test_mid_stream_attachment_skips_until_baseline_known():
    """A stream attached after calibration has no baseline for early
    passes: they are counted as skipped, never guessed at."""
    rec = TraceRecorder()
    device = TracedBackend(create_backend("simulated", n_cores=4, seed=4),
                           rec)
    cal = calibrate(device, FREQS[:2], SPEC)
    n_cal_events = rec.n_events
    measure_switch_once(device, FREQS[0], FREQS[1], cal, SPEC)
    trace = rec.finish()
    stream = DeviceStream("late")
    # drop the whole calibration prefix (where baselines come from):
    # attach right before the measured pass
    events = list(replay_events(trace))
    estimates = [est for ev in events[n_cal_events:]
                 if (est := stream.feed(*ev)) is not None]
    assert stream.n_passes >= 1
    assert stream.n_skipped >= 1
    assert estimates == []


def test_replay_events_is_the_tap_stream():
    """replay_events yields tuples in the exact tap signature order with
    native python types for kind/timestamp."""
    rec = TraceRecorder()
    seen = []
    rec.add_tap(lambda *ev: seen.append(ev))
    device = TracedBackend(create_backend("simulated", n_cores=4, seed=5),
                           rec)
    device.set_frequency(FREQS[0])
    trace = rec.finish()
    replayed = list(replay_events(trace))
    assert len(replayed) == len(seen) == trace.n_events
    for (k, t, cols, data, extra), (lk, lt, lcols, ldata, lextra) in zip(
            replayed, seen):
        assert isinstance(k, int) and isinstance(t, float)
        assert (k, t) == (int(lk), float(lt))
        assert np.array_equal(np.asarray(cols, dtype=np.float64),
                              np.asarray(lcols, dtype=np.float64),
                              equal_nan=True)
        if ldata is None:
            assert data is None
        else:
            assert np.array_equal(data, ldata)
