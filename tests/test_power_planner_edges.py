"""Edge cases of PowerModel.best_frequency and planner.regions_from_cell
(+ the governor's behavior when every region is too short to amortize)."""
import numpy as np
import pytest

from repro.core.latency_table import LatencyTable, analyse_pair
from repro.dvfs.governor import Governor, GovernorConfig
from repro.dvfs.planner import Region, regions_from_cell
from repro.dvfs.power_model import PowerModel

PM = PowerModel(f_max_mhz=1410.0)


# ------------------------------------------------------------------ #
# PowerModel.best_frequency
# ------------------------------------------------------------------ #
def test_best_frequency_empty_frequency_list_falls_back_to_fmax():
    assert PM.best_frequency(1.0, 0.5, []) == 1410.0


def test_best_frequency_sensitivity_zero_picks_lowest():
    """Fully memory-bound: runtime is flat in f, so the energy-minimal
    choice is the lowest clock regardless of the slowdown budget."""
    freqs = [210.0, 705.0, 1410.0]
    assert PM.best_frequency(1.0, 0.0, freqs, max_slowdown=1.0) == 210.0


def test_best_frequency_sensitivity_one_strict_budget_stays_fmax():
    """Perfectly compute-bound with zero slowdown allowance: any downclock
    extends runtime, so f_max is the only admissible choice."""
    freqs = [210.0, 705.0, 1410.0]
    assert PM.best_frequency(1.0, 1.0, freqs, max_slowdown=1.0) == 1410.0


def test_best_frequency_sensitivity_one_budget_buys_one_step():
    """Compute-bound with a 10% budget: eligible clocks are f >= f_max/1.1,
    and cubic dynamic power makes the slowest eligible one optimal."""
    freqs = [float(f) for f in np.arange(210.0, 1411.0, 15.0)]
    best = PM.best_frequency(1.0, 1.0, freqs, max_slowdown=1.1)
    assert best == min(f for f in freqs if 1410.0 / f <= 1.1)


def test_best_frequency_never_picks_inadmissible_slowdown():
    freqs = [210.0, 1410.0]
    best = PM.best_frequency(2.0, 1.0, freqs, max_slowdown=1.05)
    assert best == 1410.0                     # 210 MHz would be 6.7x slower


# ------------------------------------------------------------------ #
# planner.regions_from_cell
# ------------------------------------------------------------------ #
def _cell(comp, mem, coll):
    return {"roofline": {"compute_s": comp, "memory_s": mem,
                         "collective_s": coll}}


def test_regions_memory_fully_overlapped_is_dropped():
    regions = regions_from_cell(_cell(1.0, 0.5, 0.0))
    assert [r.kind for r in regions] == ["compute", "host"]


def test_regions_exposed_memory_is_excess_over_compute():
    regions = regions_from_cell(_cell(1.0, 1.4, 0.2))
    kinds = {r.kind: r.duration_s for r in regions}
    assert kinds["memory"] == pytest.approx(0.4)
    assert kinds["collective"] == pytest.approx(0.2)
    assert kinds["host"] == pytest.approx(0.03 * 1.6)


def test_regions_zero_cell_yields_zero_durations():
    regions = regions_from_cell(_cell(0.0, 0.0, 0.0))
    assert [r.kind for r in regions] == ["compute", "host"]
    assert all(r.duration_s == 0.0 for r in regions)


def test_region_sensitivity_extremes():
    assert Region("compute", 1.0).sensitivity == 1.0
    assert Region("host", 1.0).sensitivity == 0.0


# ------------------------------------------------------------------ #
# governor: all regions shorter than the switching latency
# ------------------------------------------------------------------ #
def _table_with_uniform_latency(latency_s, freqs):
    rng = np.random.default_rng(0)
    table = LatencyTable()
    for fi in freqs:
        for ft in freqs:
            if fi == ft:
                continue
            samples = latency_s * rng.lognormal(0.0, 0.01, 12)
            table.add(analyse_pair(fi, ft, samples))
    return table


def test_governor_suppresses_all_switches_when_regions_too_short():
    freqs = [210.0, 705.0, 1410.0]
    table = _table_with_uniform_latency(50e-3, freqs)
    g = Governor(table, PM, freqs, GovernorConfig(hysteresis=3.0))
    # memory-bound regions (downclock is attractive) but each lasts less
    # than hysteresis x latency -> every change is suppressed
    regions = [Region("memory", 0.1)] * 20
    st = g.simulate(regions)
    assert st.switches == 0
    assert st.suppressed_short == 20
    assert st.switch_overhead_s == 0.0
