"""Gradient compression: error feedback kills quantization bias; training
with compressed grads tracks the uncompressed baseline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests run when installed
from hypothesis import given, settings, strategies as st

from repro.optim import adamw
from repro.optim.compression import compress, init_error


def test_error_feedback_unbiased_accumulation():
    """Constant gradient g: sum of compressed emissions over T steps must
    equal T*g up to one quantum (bias does not accumulate)."""
    g = {"w": jnp.full((64,), 1.0 + 1e-3, jnp.float32)}  # not bf16-exact
    err = init_error(g)
    total = jnp.zeros((64,), jnp.float32)
    T = 200
    for _ in range(T):
        q, err = compress(g, err)
        total = total + q["w"].astype(jnp.float32)
    # residual bias decays as O(quantum / T): one bf16 quantum (~4e-3 at
    # this magnitude) spread over 200 steps leaves ~2e-5 relative error
    np.testing.assert_allclose(np.asarray(total) / T,
                               np.asarray(g["w"]), rtol=1e-4)


def test_compressed_training_tracks_fp32():
    """Least-squares toy problem: Adam with bf16+EF grads converges to the
    same loss neighborhood as fp32 grads."""
    key = jax.random.PRNGKey(0)
    X = jax.random.normal(key, (128, 16))
    w_true = jax.random.normal(jax.random.PRNGKey(1), (16,))
    y = X @ w_true

    def loss_fn(w):
        return jnp.mean((X @ w - y) ** 2)

    cfg = adamw.AdamWConfig(lr=5e-2, weight_decay=0.0)

    def run(compressed):
        w = {"w": jnp.zeros((16,))}
        st_ = adamw.init(w)
        err = init_error(w)
        for _ in range(300):
            g = jax.grad(lambda p: loss_fn(p["w"]))(w)
            if compressed:
                g, err = compress(g, err)
            w, st_, _ = adamw.update(w, g, st_, cfg)
        return float(loss_fn(w["w"]))

    l_fp32 = run(False)
    l_comp = run(True)
    assert l_comp < 1e-2, l_comp
    assert abs(l_comp - l_fp32) < 5e-3


@given(st.integers(0, 1000), st.floats(1e-4, 10.0))
@settings(max_examples=25, deadline=None)
def test_compress_residual_bounded(seed, scale):
    """Property: the error-feedback residual never exceeds one bf16 ULP of
    the corrected gradient (no runaway error state)."""
    g = {"w": scale * jax.random.normal(jax.random.PRNGKey(seed), (32,))}
    err = init_error(g)
    for _ in range(5):
        q, err = compress(g, err)
        corrected = np.abs(np.asarray(g["w"], np.float32)) + 1e-30
        # bf16 has 8 mantissa bits -> relative quantum ~ 2^-8
        assert (np.abs(np.asarray(err["w"])) <=
                corrected * 2.0 ** -7 + 1e-6).all()


def test_train_loop_with_compression():
    """Integration: the grad_compression flag trains and learns."""
    from repro.configs import get_config
    from repro.configs.shapes import ShapeSpec
    from repro.parallel.sharding import make_env
    from repro.runtime.train_loop import TrainConfig, train

    cfg = get_config("llama3-8b", smoke=True)
    shape = ShapeSpec("t", 32, 4, "train")
    m = train(cfg, shape, make_env(cfg, None),
              TrainConfig(steps=20, lr=2e-3, warmup=5, log_every=100,
                          grad_compression=True), verbose=False)
    assert np.mean(m["loss"][-3:]) < np.mean(m["loss"][:3])
