"""O(n log n) analysis engine vs the O(n²) references: sorted-window
DBSCAN must be bit-identical, prefix-sum silhouette within 1e-12, the
vectorized switching confirm must reproduce the per-core loop, and the
running-sum RSE must match a full rescan.  (Deterministic counterparts of
the hypothesis properties in test_analysis_equivalence.py, so the
equivalence guarantee is enforced even where hypothesis is absent.)"""
import math

import numpy as np
import pytest

from repro.core import stats
from repro.core.calibration import calibrate
from repro.core.dbscan import NOISE, adaptive_dbscan, dbscan
from repro.core.evaluation import MeasureConfig, measure_pair
from repro.core.latency_table import LatencyTable, PairResult, analyse_pair
from repro.core.silhouette import silhouette_score
from repro.core.switching import (_confirm_loop, _confirm_vectorized,
                                  measure_switch_once)
from repro.core.workload import WorkloadSpec
from repro.dvfs import make_device


def _datasets():
    for seed in range(12):
        rng = np.random.default_rng(seed)
        n = int(rng.integers(0, 160))
        yield np.concatenate([rng.normal(20e-3, .5e-3, n),
                              rng.uniform(.08, .3, int(rng.integers(0, 6)))])
        yield rng.integers(0, 9, n) / 7.0              # duplicate-heavy
        yield np.full(n, 3.14)                         # all identical
    yield np.array([])                                 # empty
    yield np.array([1.0])                              # below any minPts
    yield np.array([5.0, 5.0, 5.0])                    # n < minPts duplicates


# ------------------------------------------------------------------ #
# DBSCAN
# ------------------------------------------------------------------ #
def test_sorted_dbscan_bit_identical_to_matrix():
    for x in _datasets():
        for eps in (1e-12, 1e-4, 1e-3, 0.3):
            for mp in (2, 3, 5, 40):
                a = dbscan(x, eps, mp)
                b = dbscan(x, eps, mp, impl="matrix")
                np.testing.assert_array_equal(a, b)


def test_sorted_dbscan_exact_on_eps_boundaries():
    """Grid data puts many pairwise distances exactly at (or one ulp off)
    eps — the searchsorted fix-up must keep the reference predicate."""
    rng = np.random.default_rng(5)
    x = rng.integers(0, 25, 90).astype(float) * 0.1
    for eps in (0.1, np.nextafter(0.1, 0), np.nextafter(0.1, 1), 0.2):
        for mp in (2, 3, 6):
            np.testing.assert_array_equal(
                dbscan(x, eps, mp), dbscan(x, eps, mp, impl="matrix"))


def test_adaptive_dbscan_impls_agree_fully():
    for x in _datasets():
        if not x.size:
            continue
        fast = adaptive_dbscan(x)
        ref = adaptive_dbscan(x, impl="matrix")
        np.testing.assert_array_equal(fast.labels, ref.labels)
        assert (fast.eps, fast.min_pts, fast.noise_ratio, fast.n_clusters,
                fast.converged) == (ref.eps, ref.min_pts, ref.noise_ratio,
                                    ref.n_clusters, ref.converged)


def test_dbscan_rejects_unknown_impl():
    with pytest.raises(ValueError):
        dbscan(np.ones(4), 0.1, 2, impl="gpu")
    with pytest.raises(ValueError):
        adaptive_dbscan(np.ones(8), impl="gpu")


def test_sorted_dbscan_multidim_falls_back_to_matrix():
    rng = np.random.default_rng(0)
    x = rng.normal(0, 1, (40, 2))
    np.testing.assert_array_equal(dbscan(x, 0.5, 3),
                                  dbscan(x, 0.5, 3, impl="matrix"))


# ------------------------------------------------------------------ #
# silhouette
# ------------------------------------------------------------------ #
def test_silhouette_impls_agree():
    for seed in range(10):
        rng = np.random.default_rng(seed)
        n = int(rng.integers(3, 220))
        x = rng.integers(0, 12, n) / 7.0 if seed % 2 else rng.uniform(0, 1, n)
        labels = rng.integers(-1, 4, n)
        a = silhouette_score(x, labels)
        b = silhouette_score(x, labels, impl="matrix")
        assert (math.isnan(a) and math.isnan(b)) or abs(a - b) <= 1e-12


def test_silhouette_constant_values_across_labels_exact():
    """Identical values split over several labels: the matrix path gets
    exact zeros for a and b, so the prefix-sum path must too — a rounding
    residue here gets amplified to O(1) by (b-a)/max(a,b)."""
    x = np.full(43, 0.31443998)
    labels = np.random.default_rng(0).integers(-1, 5, 43)
    a = silhouette_score(x, labels)
    b = silhouette_score(x, labels, impl="matrix")
    assert a == b == 0.0
    # two constant clusters at different values: perfectly separated
    x2 = np.array([0.1] * 10 + [0.3] * 10)
    l2 = np.array([0] * 10 + [1] * 10)
    assert silhouette_score(x2, l2) == 1.0
    assert silhouette_score(x2, l2, impl="matrix") == 1.0


def test_silhouette_rejects_unknown_impl():
    with pytest.raises(ValueError):
        silhouette_score(np.ones(6), np.zeros(6, dtype=int), impl="gpu")


def test_switch_once_rejects_unknown_confirm_impl():
    with pytest.raises(ValueError):
        measure_switch_once(None, 0.0, 1.0, None, None, confirm_impl="gpu")


# ------------------------------------------------------------------ #
# vectorized switching confirm
# ------------------------------------------------------------------ #
def _confirm_inputs(seed, n_cores=12, n_iters=300):
    rng = np.random.default_rng(seed)
    durs = rng.lognormal(math.log(40e-6), 0.05, (n_cores, n_iters))
    starts = np.cumsum(durs, axis=1) - durs
    ends = starts + durs
    target = stats.mean_std(rng.lognormal(math.log(40e-6), 0.05, 4000))
    first_hit = rng.integers(0, n_iters, n_cores)
    has_hit = rng.random(n_cores) < 0.8
    return durs, ends, 1e-4, target, first_hit, has_hit


@pytest.mark.parametrize("seed", range(8))
def test_confirm_vectorized_matches_loop(seed):
    durs, ends, t_s, target, first_hit, has_hit = _confirm_inputs(seed)
    for min_confirm in (1, 2, 16, 64, 290):
        ref_lat, ref_idx = _confirm_loop(durs, ends, t_s, target,
                                         first_hit, has_hit, min_confirm,
                                         1.96, 0.02 * target.mean)
        lat, idx = _confirm_vectorized(durs, ends, t_s, target,
                                       first_hit, has_hit, min_confirm,
                                       1.96, 0.02 * target.mean)
        np.testing.assert_array_equal(idx, ref_idx)
        np.testing.assert_array_equal(np.isnan(lat), np.isnan(ref_lat))
        np.testing.assert_allclose(lat[~np.isnan(lat)],
                                   ref_lat[~np.isnan(ref_lat)], rtol=0,
                                   atol=0)        # exact: same ends lookup


def test_confirm_impls_agree_end_to_end():
    """Two identical simulated devices, one pass per confirm impl: the
    SwitchPass must be identical (same RNG stream, same decisions)."""
    spec = WorkloadSpec(iters_per_kernel=1100, flops_per_iter=40e-6,
                        delay_iters=300, confirm_iters=400)
    results = []
    for impl in ("loop", "vectorized"):
        dev = make_device("a100", seed=11, n_cores=8)
        cal = calibrate(dev, [210.0, 1410.0], spec)
        res = measure_switch_once(dev, 210.0, 1410.0, cal, spec,
                                  confirm_impl=impl)
        results.append(res)
    a, b = results
    assert (a is None) == (b is None)
    if a is not None:
        assert a.latency == b.latency
        assert a.transition_index == b.transition_index
        assert a.n_viable == b.n_viable
        np.testing.assert_array_equal(a.core_latencies, b.core_latencies)


# ------------------------------------------------------------------ #
# measure_pair: running-sum RSE + default-config cleanup
# ------------------------------------------------------------------ #
def test_measure_pair_none_default_and_rse_matches_rescan():
    spec = WorkloadSpec(iters_per_kernel=1100, flops_per_iter=40e-6,
                        delay_iters=300, confirm_iters=400)
    dev = make_device("a100", seed=1, n_cores=8)
    cal = calibrate(dev, [210.0, 1410.0], spec)
    pm = measure_pair(dev, 210.0, 1410.0, cal, spec,
                      MeasureConfig(min_measurements=5, max_measurements=8,
                                    rse_check_every=5))
    assert pm.status == "ok"
    assert pm.rse == pytest.approx(stats.rse(pm.latencies), rel=1e-9)
    # None default builds a fresh MeasureConfig per call (no shared
    # default-instance argument)
    import inspect
    sig = inspect.signature(measure_pair)
    assert sig.parameters["mc"].default is None


def test_running_stats_add_remove_matches_numpy():
    rng = np.random.default_rng(3)
    vals = rng.lognormal(-4, 0.05, 60)
    rs = stats.RunningStats()
    for v in vals:
        rs.add(v)
    for v in vals[-5:]:
        rs.remove(v)
    kept = vals[:-5]
    assert rs.n == kept.size
    assert rs.mean == pytest.approx(kept.mean(), rel=1e-12)
    assert rs.std == pytest.approx(kept.std(ddof=1), rel=1e-9)
    assert rs.rse() == pytest.approx(stats.rse(kept), rel=1e-9)
    for v in kept:
        rs.remove(v)
    assert rs.n == 0 and rs.rse() == float("inf")


# ------------------------------------------------------------------ #
# rankdata vectorization
# ------------------------------------------------------------------ #
def test_rankdata_bit_identical_to_tie_loop():
    def rank_ref(x):                 # the pre-vectorization implementation
        x = np.asarray(x, dtype=np.float64).ravel()
        order = np.argsort(x, kind="mergesort")
        ranks = np.empty(x.size, dtype=np.float64)
        sx = x[order]
        edge = np.flatnonzero(np.r_[True, sx[1:] != sx[:-1], True])
        for lo, hi in zip(edge[:-1], edge[1:]):
            ranks[order[lo:hi]] = 0.5 * (lo + hi - 1) + 1.0
        return ranks

    for seed in range(20):
        rng = np.random.default_rng(seed)
        n = int(rng.integers(0, 300))
        x = (rng.integers(0, max(1, n // 4 + 1), n) / 3.0 if seed % 2
             else rng.normal(0, 1, n))
        np.testing.assert_array_equal(stats.rankdata(x), rank_ref(x))


# ------------------------------------------------------------------ #
# per-sample outlier labels in CSV persistence
# ------------------------------------------------------------------ #
def test_save_csv_keeps_duplicate_value_in_clean_and_outlier_apart(tmp_path):
    """A value present in BOTH the clean and outlier sets must be flagged
    per-sample, not per-value: the old round(v,12)-membership hack marked
    every duplicate as an outlier."""
    lat = np.array([20e-3, 20e-3, 21e-3, 150e-3])
    labels = np.array([0, NOISE, 0, NOISE])        # one 20 ms pass is noise
    pr = PairResult(210.0, 1410.0, lat, lat[labels == 0],
                    lat[labels == NOISE], 1, float("nan"), "ok",
                    labels=labels)
    t = LatencyTable(hostname="h", device_index=0)
    t.add(pr)
    (path,) = t.save_csv(str(tmp_path))
    got_lat, got_out = LatencyTable.load_csv(path)
    np.testing.assert_allclose(got_lat, lat, rtol=0, atol=1e-9)
    np.testing.assert_array_equal(got_out, [False, True, False, True])


def test_save_csv_empty_pair_header_only(tmp_path):
    pr = analyse_pair(210.0, 1410.0, np.array([]), status="undetectable")
    t = LatencyTable(hostname="h", device_index=0)
    t.add(pr)
    (path,) = t.save_csv(str(tmp_path))
    lat, out = LatencyTable.load_csv(path)
    assert lat.size == 0 and out.size == 0


def test_analyse_pair_labels_align_with_split():
    rng = np.random.default_rng(0)
    lat = np.concatenate([rng.normal(20e-3, .5e-3, 60),
                          rng.uniform(.1, .3, 4)])
    pr = analyse_pair(210.0, 1410.0, lat)
    assert pr.labels is not None and pr.labels.size == lat.size
    np.testing.assert_array_equal(lat[pr.labels != NOISE], pr.clean)
    np.testing.assert_array_equal(lat[pr.labels == NOISE], pr.outliers)
    # matrix route produces the same PairResult
    ref = analyse_pair(210.0, 1410.0, lat, impl="matrix")
    np.testing.assert_array_equal(pr.labels, ref.labels)
    assert (math.isnan(pr.silhouette) and math.isnan(ref.silhouette)) \
        or abs(pr.silhouette - ref.silhouette) <= 1e-12
