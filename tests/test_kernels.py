"""Per-kernel shape/dtype sweeps against the pure-jnp oracles
(interpret=True executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention, flash_attention_ref
from repro.kernels.microbench import microbench, microbench_ref
from repro.kernels.microbench.ops import make_input
from repro.kernels.ssd.ops import ssd_pallas
from repro.models.ssm import ssd_ref


@pytest.mark.parametrize("cores", [1, 4, 16])
@pytest.mark.parametrize("n_iters,unroll", [(8, 4), (32, 16)])
def test_microbench_matches_ref(cores, n_iters, unroll):
    x = make_input(cores, seed=cores)
    a = microbench(x, n_iters=n_iters, unroll=unroll)
    b = microbench_ref(x, n_iters=n_iters, unroll=unroll)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=0, atol=0)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "b,s,h,kv,dh,dv,causal,blk",
    [(2, 64, 4, 2, 16, 16, True, 32),
     (1, 128, 8, 8, 32, 32, False, 64),
     (2, 64, 4, 1, 16, 8, True, 16),
     (1, 96, 6, 3, 8, 8, True, 32)])
def test_flash_attention_matches_oracle(b, s, h, kv, dh, dv, causal, blk, dtype):
    ks = [jax.random.PRNGKey(i) for i in range(3)]
    q = jax.random.normal(ks[0], (b, s, h, dh), dtype)
    k = jax.random.normal(ks[1], (b, s, kv, dh), dtype)
    v = jax.random.normal(ks[2], (b, s, kv, dv), dtype)
    out = flash_attention(q, k, v, causal=causal, blk_q=blk, blk_k=blk)
    ref = flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("b,l,h,p,n,chunk",
                         [(1, 32, 2, 8, 8, 16), (2, 64, 3, 8, 16, 16),
                          (1, 128, 4, 16, 32, 32)])
def test_ssd_pallas_matches_model_ref(b, l, h, p, n, chunk):
    ks = [jax.random.PRNGKey(i) for i in range(5)]
    x = jax.random.normal(ks[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (b, l, n))
    C = jax.random.normal(ks[4], (b, l, n))
    y1, h1 = ssd_pallas(x, dt, A, B, C, chunk)
    y2, h2 = ssd_ref(x, dt, A, B, C, chunk)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               atol=2e-4, rtol=2e-4)
