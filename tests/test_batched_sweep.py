"""Batched sweep engine: bit-identity against the serial per-pair path
across every measurement status, resumable batched sessions, the
engine/executor/trace combination guards, and the numeric helpers whose
bit-exactness the engine rests on."""
import numpy as np
import pytest

from repro.backends import create_backend
from repro.backends.registry import register_backend
from repro.backends.vmapped_sim import eval_timestamps_lanes
from repro.core import stats as statsmod
from repro.core.batched_sweep import _pairwise_colsum, run_batched_sweep
from repro.core.calibration import calibrate, valid_pairs
from repro.core.evaluation import MeasureConfig
from repro.core.pairtask import PairTask, run_pair_task
from repro.core.session import (LatestConfig, MeasurementSession,
                                SessionConfig)
from repro.core.workload import WorkloadSpec
from repro.campaign.scheduler import CampaignRunner
from repro.dvfs.device_model import SimulatedAccelerator
from repro.dvfs.transition_models import make_device
from repro.trace.analyze import table_digest

SPEC = WorkloadSpec(iters_per_kernel=16, flops_per_iter=128e-3,
                    delay_iters=3, confirm_iters=10)
FREQS = [210.0, 705.0, 1410.0]


def _mc(**kw):
    base = dict(min_measurements=8, max_measurements=24, rse_check_every=8,
                rse_target=0.0, min_confirm=8, max_retries=100)
    base.update(kw)
    return MeasureConfig(**base)


def _grid(mc, **devopts):
    opts = {"kind": "a100", "seed": 11, **devopts}
    dev = create_backend("vmapped-sim", **opts)
    cal = calibrate(dev, FREQS, SPEC)
    pairs = valid_pairs(cal)
    task = PairTask.make("vmapped-sim", opts, cal, SPEC, mc)
    return task, pairs


def _assert_identical(task, pairs):
    """Run both engines over the same grid; every per-pair field must be
    bit-equal.  Returns the (shared) statuses for shape assertions."""
    serial = {p: run_pair_task(task, p) for p in pairs}
    batched = run_batched_sweep(task, pairs)
    assert set(batched) == set(pairs)
    for p in pairs:
        pm_s, gt_s = serial[p]
        pm_b, gt_b = batched[p]
        assert pm_s.status == pm_b.status, p
        assert pm_s.retries == pm_b.retries, p
        assert np.array_equal(pm_s.latencies, pm_b.latencies), p
        assert (pm_s.rse == pm_b.rse
                or (np.isinf(pm_s.rse) and np.isinf(pm_b.rse))), p
        assert repr(gt_s) == repr(gt_b), p
    return {p: batched[p][0].status for p in pairs}


# ---------------------------------------------------------------------- #
# bit-identity across statuses
# ---------------------------------------------------------------------- #

def test_bit_identity_all_ok():
    task, pairs = _grid(_mc())
    statuses = _assert_identical(task, pairs)
    assert len(pairs) == 6
    assert set(statuses.values()) == {"ok"}


def test_bit_identity_power_throttled():
    """set_frequency(1410) arms the power throttle, so every pair touching
    1410 MHz must bail with power_throttled — in both engines, at the
    same pass."""
    task, pairs = _grid(_mc(), power_throttle_freqs=(1410.0,))
    statuses = _assert_identical(task, pairs)
    assert statuses[(210.0, 705.0)] == "ok"
    assert all(s == "power_throttled" for (fi, ft), s in statuses.items()
               if 1410.0 in (fi, ft))


def test_bit_identity_undetectable():
    """An impossible confirmation suffix makes every pass GOTO-retry until
    max_retries trips; retry counts and the undetectable verdict must
    match pass-for-pass."""
    task, pairs = _grid(_mc(min_confirm=10**6, max_retries=2))
    statuses = _assert_identical(task, pairs)
    assert set(statuses.values()) == {"undetectable"}


def test_bit_identity_thermal_rollback():
    """Thermal flags drop the newest throttle_check_every measurements and
    cool down; the rollback (the only caller of RunningStats.remove) must
    fire and both engines must still agree bit-for-bit."""
    task, pairs = _grid(_mc(cooldown_s=1e-3), thermal_throttle_prob=0.3)
    removes = [0]
    orig = statsmod.RunningStats.remove

    def counting(self, v):
        removes[0] += 1
        return orig(self, v)

    with pytest.MonkeyPatch.context() as mp:
        mp.setattr(statsmod.RunningStats, "remove", counting)
        statuses = _assert_identical(task, pairs)
    assert removes[0] > 0                       # rollback path exercised
    assert set(statuses.values()) == {"ok"}


# ---------------------------------------------------------------------- #
# session integration: resume + parity
# ---------------------------------------------------------------------- #

def _session(out_dir=None, engine="serial", executor="serial",
             backend="vmapped-sim", trace=None):
    return MeasurementSession(
        frequencies=FREQS,
        cfg=SessionConfig(
            latest=LatestConfig(measure=_mc(min_measurements=4,
                                            max_measurements=6,
                                            rse_check_every=4)),
            executor=executor, out_dir=out_dir),
        backend=backend,
        backend_options={"kind": "a100", "seed": 2, "n_cores": 6},
        engine=engine, trace=trace)


def test_batched_session_resumes_from_disk(tmp_path, monkeypatch):
    out = str(tmp_path / "sweep")
    subset = [(210.0, 1410.0), (1410.0, 210.0)]

    import repro.core.batched_sweep as bs
    swept = []
    real = bs.run_batched_sweep

    def spy(task, pairs, *, on_result=None):
        swept.append(list(pairs))
        return real(task, pairs, on_result=on_result)

    monkeypatch.setattr(bs, "run_batched_sweep", spy)

    partial = _session(out_dir=out, engine="batched").run(pair_subset=subset)
    assert set(partial.pairs) == set(subset)

    # "crash", then a fresh batched session over the same state dir: the
    # persisted pairs are loaded, only the remaining four enter the engine
    full = _session(out_dir=out, engine="batched").run()
    assert len(full.pairs) == 6
    assert swept == [subset, [p for p in full.pairs if p not in subset]]
    for p in subset:
        assert np.array_equal(full.pairs[p].latencies,
                              partial.pairs[p].latencies)

    # and the resumed batched table equals a fresh serial sweep bit-for-bit
    serial = _session(engine="serial").run()
    assert table_digest(full) == table_digest(serial)


# ---------------------------------------------------------------------- #
# combination guards
# ---------------------------------------------------------------------- #

def test_unknown_engine_rejected():
    with pytest.raises(ValueError, match="unknown engine"):
        _session(engine="fused")


def test_trace_with_batched_engine_rejected():
    with pytest.raises(ValueError, match="trace"):
        _session(engine="batched", trace=object())


def test_explicit_device_with_batched_engine_rejected():
    dev = make_device("a100", seed=0, n_cores=4)
    with pytest.raises(ValueError, match="freshly built"):
        MeasurementSession(dev, FREQS, engine="batched")


def test_threaded_executor_with_batched_engine_rejected():
    with pytest.raises(ValueError, match="executor"):
        _session(engine="batched", executor="threads").run()


def test_non_batchable_backend_rejected():
    @register_backend("sim-nobatch-test", description="guard-test dummy",
                      virtual=True, batchable=False)
    def _factory(kind="a100", *, seed=0, unit_seed=0, n_cores=None,
                 **overrides):
        return make_device(kind, seed=seed, unit_seed=unit_seed,
                           n_cores=n_cores, **overrides)

    with pytest.raises(ValueError, match="split wait protocol"):
        _session(engine="batched", backend="sim-nobatch-test").run()


def test_campaign_processes_with_batched_engine_rejected():
    with pytest.raises(ValueError, match="pick one"):
        CampaignRunner(None, executor="processes", engine="batched")


# ---------------------------------------------------------------------- #
# numeric helpers the identity contract rests on
# ---------------------------------------------------------------------- #

@pytest.mark.parametrize("n", [1, 2, 5, 7, 8, 9, 15, 16, 24, 64, 100,
                               127, 128, 129, 300, 1000])
def test_pairwise_colsum_matches_numpy_mean(n):
    """_pairwise_colsum must reproduce numpy's pairwise-summation tree
    bitwise — the batched confirm's mean must equal the serial
    mean(axis=1) exactly, not just approximately.  The serial detector
    reduces a C-contiguous last axis (numpy's pairwise fast path), so
    that layout is the reference; strided reductions sum differently."""
    rng = np.random.default_rng(n)
    cols = rng.lognormal(0.0, 1.0, (n, 5))
    ours = _pairwise_colsum(cols) / n
    ref = np.mean(np.ascontiguousarray(cols.T), axis=1)
    assert np.array_equal(ours, ref)


@pytest.mark.parametrize("n_iters", [8, 200])
def test_eval_timestamps_lanes_matches_serial(n_iters):
    """Both evaluation regimes (iteration-major loop for short wide
    batches, per-lane windowed fallback for tall skinny ones) must equal
    the single-device serial evaluator bitwise, full bounds and
    ends_only alike."""
    rng = np.random.default_rng(7)
    base, f_max, cores = 1e-3, 1500.0, 3
    timelines = [([0.0], [300.0]),
                 ([0.0, 0.004, 0.009], [1500.0, 700.0, 1200.0])]
    width = max(len(t) for t, _ in timelines) + 1
    ev_t_pad = np.full((width, len(timelines)), np.inf)
    ev_f_pad = np.ones((width, len(timelines)))
    for i, (tt, tf) in enumerate(timelines):
        ev_t_pad[:len(tt), i] = tt
        ev_f_pad[:len(tf), i] = tf
    lane_of_row = np.repeat(np.arange(len(timelines)), cores)
    r = lane_of_row.size
    t0 = rng.uniform(0, 1e-4, r)
    noise_t = rng.lognormal(0.0, 0.05, (n_iters, r))

    got = eval_timestamps_lanes(base, t0, noise_t, lane_of_row,
                                ev_t_pad, ev_f_pad, f_max)
    ends = eval_timestamps_lanes(base, t0, noise_t, lane_of_row,
                                 ev_t_pad, ev_f_pad, f_max, ends_only=True)
    for i, (tt, tf) in enumerate(timelines):
        cols = np.flatnonzero(lane_of_row == i)
        ref = SimulatedAccelerator._eval_timestamps_vectorized(
            base, t0[cols], np.ascontiguousarray(noise_t[:, cols].T),
            np.asarray(tt), np.asarray(tf), f_max)
        assert np.array_equal(got[:, cols], ref.T)
        assert np.array_equal(ends[cols], ref[:, -1])
