"""MeasurementSession: run_latest parity across backends, executor
scheduling, and resume-from-disk of an interrupted sweep."""
import json
import os

import numpy as np
import pytest

from repro.core.evaluation import MeasureConfig
from repro.core.latest import run_latest
from repro.core.session import (LatestConfig, MeasurementSession,
                                SessionConfig)

FAST = MeasureConfig(min_measurements=4, max_measurements=6,
                     rse_check_every=4)
FREQS = [210.0, 705.0, 1410.0]


def _cfg(**kw):
    return SessionConfig(latest=LatestConfig(measure=FAST), **kw)


def _session(out_dir=None, seed=0, backend="simulated", **kw):
    return MeasurementSession(
        frequencies=FREQS, cfg=_cfg(out_dir=out_dir, **kw),
        backend=backend,
        backend_options={"kind": "a100", "seed": seed, "n_cores": 6})


def test_latest_config_measure_not_shared():
    a, b = LatestConfig(), LatestConfig()
    assert a.measure is not b.measure          # default_factory, not one
    assert a.measure == b.measure              # shared frozen instance


@pytest.mark.parametrize("backend", ["simulated", "vmapped-sim"])
def test_run_latest_through_session(backend):
    table = run_latest(frequencies=FREQS, cfg=LatestConfig(measure=FAST),
                       backend=backend,
                       backend_options={"kind": "a100", "seed": 1,
                                        "n_cores": 6})
    assert len(table.pairs) == 6               # all permutations valid
    assert all(p.status == "ok" for p in table.pairs.values())
    # min_measurements passes per pair; the DBSCAN clean cluster may keep
    # fewer when a pair's handful of samples splits into clusters
    assert all(p.latencies.size >= 4 for p in table.pairs.values())
    assert all(p.clean.size >= 1 for p in table.pairs.values())


def test_interrupted_sweep_resumes_from_disk(tmp_path):
    out = str(tmp_path / "sweep")
    subset = [(210.0, 1410.0), (1410.0, 210.0)]
    s1 = _session(out_dir=out, seed=2)
    partial = s1.run(pair_subset=subset)
    assert set(partial.pairs) == set(subset)
    assert os.path.exists(os.path.join(out, "session.json"))
    assert len(os.listdir(os.path.join(out, "pairs"))) == 2

    # "crash", then a fresh session over the same state dir
    s2 = _session(out_dir=out, seed=2)
    full = s2.run()
    assert len(full.pairs) == 6
    # persisted pairs were loaded, not re-measured: the new device never
    # visited those transitions (calibration was reloaded too, so its
    # history only contains the remaining pairs' activity)
    measured = {(h["from"], h["to"]) for h in s2.device.history}
    assert (210.0, 1410.0) not in measured
    # and the loaded numbers match the first run bit-for-bit
    for p in subset:
        assert np.array_equal(full.pairs[p].latencies,
                              partial.pairs[p].latencies)


def test_resume_skips_recalibration(tmp_path):
    out = str(tmp_path / "cal")
    s1 = _session(out_dir=out, seed=3)
    s1.calibrate()
    n_transitions_cal = len(s1.device.history)
    assert n_transitions_cal > 0

    s2 = _session(out_dir=out, seed=3)
    s2.calibrate()
    assert len(s2.device.history) == 0         # loaded, not re-run
    assert set(s2.cal.baselines) == set(s1.cal.baselines)
    for f in FREQS:
        assert s2.cal.baselines[f].mean == pytest.approx(
            s1.cal.baselines[f].mean)
    assert s2.spec == s1.spec


def test_resume_rejects_frequency_mismatch(tmp_path):
    out = str(tmp_path / "mismatch")
    _session(out_dir=out, seed=4).calibrate()
    other = MeasurementSession(
        frequencies=[210.0, 1410.0], cfg=_cfg(out_dir=out),
        backend="simulated",
        backend_options={"kind": "a100", "seed": 4, "n_cores": 6})
    with pytest.raises(ValueError, match="frequencies"):
        other.calibrate()


def test_resume_rejects_config_mismatch(tmp_path):
    out = str(tmp_path / "cfgmm")
    _session(out_dir=out, seed=6).calibrate()
    other = MeasurementSession(
        frequencies=FREQS,
        cfg=SessionConfig(latest=LatestConfig(
            measure=MeasureConfig(min_measurements=9)), out_dir=out),
        backend="simulated",
        backend_options={"kind": "a100", "seed": 6, "n_cores": 6})
    with pytest.raises(ValueError, match="config"):
        other.calibrate()


def test_resume_retries_failed_pairs(tmp_path):
    """A persisted power_throttled/undetectable pair is not 'done': the
    failure may have been transient, so a resume re-measures it."""
    from repro.core.evaluation import PairMeasurement
    out = str(tmp_path / "retry")
    s = _session(out_dir=out, seed=7)
    s.calibrate()
    s._save_pair(PairMeasurement(210.0, 1410.0, np.empty(0),
                                 "power_throttled", 0, float("inf")))
    table = s.run(pair_subset=[(210.0, 1410.0)])
    assert table.pairs[(210.0, 1410.0)].status == "ok"
    assert table.pairs[(210.0, 1410.0)].clean.size >= 4


def test_thread_executor_bit_identical_to_serial():
    """Virtual backends measure every pair on a pair-seeded device, so the
    schedule (and the worker that ran each pair) cannot leak into the
    results: a thread-parallel sweep reproduces the serial table exactly."""
    serial = _session(backend="vmapped-sim").run()
    threaded = _session(executor="threads", max_workers=3,
                        backend="vmapped-sim").run()
    assert set(serial.pairs) == set(threaded.pairs) and len(serial.pairs) == 6
    for p, pr in serial.pairs.items():
        assert np.array_equal(pr.latencies, threaded.pairs[p].latencies)
        assert np.array_equal(pr.labels, threaded.pairs[p].labels)


def test_explicit_device_without_factory_rejects_threads():
    from repro.backends import create_backend
    dev = create_backend("simulated", kind="a100", n_cores=4)
    s = MeasurementSession(dev, FREQS, _cfg(executor="threads",
                                            max_workers=2))
    with pytest.raises(ValueError, match="independent devices"):
        s.run()


def test_explicit_device_rejects_process_executor():
    from repro.backends import create_backend
    dev = create_backend("simulated", kind="a100", n_cores=4)
    s = MeasurementSession(dev, FREQS, _cfg(executor="processes",
                                            max_workers=2))
    with pytest.raises(ValueError, match="process"):
        s.run()


def test_pair_files_are_valid_json(tmp_path):
    out = str(tmp_path / "json")
    s = _session(out_dir=out, seed=5)
    s.run(pair_subset=[(210.0, 1410.0)])
    (name,) = os.listdir(os.path.join(out, "pairs"))
    with open(os.path.join(out, "pairs", name)) as f:
        doc = json.load(f)
    assert doc["status"] == "ok"
    assert len(doc["latencies"]) >= 4
