"""Property-based equivalence of the sorted-window analysis engine with
the O(n²) matrix references: DBSCAN labels bit-identical, silhouette
within 1e-12, on arbitrary 1-D inputs — including all-identical,
duplicate-heavy, and smaller-than-minPts arrays."""
import math

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests run when installed
from hypothesis import given, settings, strategies as st

from repro.core.dbscan import adaptive_dbscan, dbscan
from repro.core.silhouette import silhouette_score

# continuous draws, heavy-duplicate draws (few distinct values), and
# constant arrays — each a regime the sorted path handles differently
_values = st.one_of(
    st.lists(st.floats(0.0, 1.0, allow_nan=False), max_size=120),
    st.lists(st.integers(0, 6).map(lambda k: k / 7.0), max_size=120),
    st.tuples(st.integers(0, 60), st.floats(0.0, 1.0, allow_nan=False))
      .map(lambda t: [t[1]] * t[0]),
)


@given(_values, st.floats(1e-9, 0.5), st.integers(2, 12))
@settings(max_examples=120, deadline=None)
def test_sorted_dbscan_labels_bit_identical(vals, eps, min_pts):
    x = np.asarray(vals, dtype=np.float64)
    np.testing.assert_array_equal(dbscan(x, eps, min_pts),
                                  dbscan(x, eps, min_pts, impl="matrix"))


@given(_values.filter(lambda v: len(v) >= 1))
@settings(max_examples=60, deadline=None)
def test_adaptive_dbscan_result_identical(vals):
    x = np.asarray(vals, dtype=np.float64)
    fast = adaptive_dbscan(x)
    ref = adaptive_dbscan(x, impl="matrix")
    np.testing.assert_array_equal(fast.labels, ref.labels)
    assert (fast.eps, fast.min_pts, fast.noise_ratio, fast.n_clusters,
            fast.converged) == (ref.eps, ref.min_pts, ref.noise_ratio,
                                ref.n_clusters, ref.converged)


@given(st.lists(st.tuples(st.floats(0.0, 1.0, allow_nan=False),
                          st.integers(-1, 4)), max_size=120))
@settings(max_examples=120, deadline=None)
def test_prefix_sum_silhouette_matches_matrix(pairs):
    x = np.asarray([p[0] for p in pairs], dtype=np.float64)
    labels = np.asarray([p[1] for p in pairs], dtype=int)
    a = silhouette_score(x, labels)
    b = silhouette_score(x, labels, impl="matrix")
    assert (math.isnan(a) and math.isnan(b)) or abs(a - b) <= 1e-12


@given(_values, st.integers(2, 8))
@settings(max_examples=60, deadline=None)
def test_silhouette_on_dbscan_labels(vals, min_pts):
    """The composed pipeline (cluster, then score the produced labels)
    agrees across engines end to end."""
    x = np.asarray(vals, dtype=np.float64)
    labels = dbscan(x, 0.05, min_pts)
    a = silhouette_score(x, labels)
    b = silhouette_score(x, labels, impl="matrix")
    assert (math.isnan(a) and math.isnan(b)) or abs(a - b) <= 1e-12
