"""XLA attention paths vs the naive oracle + flash custom-VJP gradients."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests run when installed
from hypothesis import given, settings, strategies as st

from repro.models import layers


def _qkv(b, s, h, kv, dh, dv=None, seed=0, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, s, h, dh), dtype)
    k = jax.random.normal(ks[1], (b, s, kv, dh), dtype)
    v = jax.random.normal(ks[2], (b, s, kv, dv or dh), dtype)
    return q, k, v


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("chunk", [16, 32, 100])
def test_chunked_matches_naive(causal, chunk):
    q, k, v = _qkv(2, 64, 4, 2, 16)
    ref = layers.naive_attention(q, k, v, causal=causal)
    out = layers.chunked_attention(q, k, v, causal=causal, kv_chunk=chunk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_prefill_triangular_matches_naive():
    q, k, v = _qkv(2, 96, 4, 2, 16, seed=1)
    ref = layers.naive_attention(q, k, v, causal=True)
    out = layers.prefill_attention(q, k, v, kv_chunk=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("window", [8, 24, 64])
def test_windowed_matches_naive(window):
    q, k, v = _qkv(2, 64, 4, 2, 16, seed=2)
    ref = layers.naive_attention(q, k, v, causal=True, window=window)
    out = layers.windowed_attention(q, k, v, window=window, q_chunk=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_flash_vjp_matches_naive_grads():
    q, k, v = _qkv(2, 48, 4, 2, 8, seed=3)
    f_ref = lambda q, k, v: (layers.naive_attention(q, k, v) ** 2).sum()
    f_fl = lambda q, k, v: (layers.chunked_attention(q, k, v, kv_chunk=16) ** 2).sum()
    g_ref = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    g_fl = jax.grad(f_fl, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_ref, g_fl):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-5)


@given(st.integers(1, 3), st.sampled_from([16, 32, 48]),
       st.sampled_from([(4, 2), (4, 4), (6, 3)]), st.sampled_from([8, 16]))
@settings(max_examples=20, deadline=None)
def test_chunked_attention_property(b, s, heads, dh):
    """Property: softmax rows are a convex combination — output magnitude
    never exceeds max |v|; and GQA with g=1 equals MHA."""
    h, kv = heads
    q, k, v = _qkv(b, s, h, kv, dh, seed=s + b)
    out = layers.chunked_attention(q, k, v, kv_chunk=16)
    assert float(jnp.abs(out).max()) <= float(jnp.abs(v).max()) + 1e-4


def test_rope_relative_phase():
    """RoPE property: <q_i, k_j> depends only on (i - j)."""
    dh = 16
    q = jnp.ones((1, 8, 1, dh))
    k = jnp.ones((1, 8, 1, dh))
    pos = jnp.arange(8)[None]
    qr = layers.apply_rope(q, pos, 10000.0)
    kr = layers.apply_rope(k, pos, 10000.0)
    dots = jnp.einsum("bqhd,bkhd->qk", qr, kr)
    np.testing.assert_allclose(float(dots[2, 1]), float(dots[5, 4]), rtol=1e-5)
    np.testing.assert_allclose(float(dots[3, 0]), float(dots[7, 4]), rtol=1e-5)
