"""Backend registry: round-trips, protocol conformance, availability
gating, and the vmapped batch fast path."""
import pytest

from repro.backends import (AcceleratorBackend, BackendUnavailableError,
                            VmappedSimAccelerator, create_backend,
                            get_backend, list_backends, register_backend)


def test_builtin_backends_listed():
    names = list_backends()
    assert {"simulated", "vmapped-sim", "cuda-nvml"} <= set(names)


@pytest.mark.parametrize("name", ["simulated", "vmapped-sim"])
def test_create_and_protocol(name):
    dev = create_backend(name, kind="a100", n_cores=4)
    assert isinstance(dev, AcceleratorBackend)     # runtime-checkable
    assert len(dev.frequencies) > 2
    data = dev.run_kernel(16, 40e-6)
    assert data.shape == (4, 16, 2)


def test_unknown_backend_raises_with_known_names():
    with pytest.raises(KeyError, match="simulated"):
        get_backend("definitely-not-a-backend")


def test_cuda_nvml_listed_but_unavailable():
    entry = get_backend("cuda-nvml")
    assert not entry.available           # no pynvml in this environment
    with pytest.raises(BackendUnavailableError, match="pynvml"):
        create_backend("cuda-nvml")


def test_register_roundtrip():
    @register_backend("test-dummy", description="round-trip fixture")
    def make_dummy(**options):
        return create_backend("simulated", **options)

    assert "test-dummy" in list_backends()
    dev = create_backend("test-dummy", kind="gh200", n_cores=2)
    assert dev.cfg.n_cores == 2


def test_domain_backends_registered_with_flags():
    for name in ("multi-domain-sim", "pstate-sim"):
        entry = get_backend(name)
        assert entry.available
        assert entry.virtual                 # pair-seeded parallel sweeps
        assert not entry.batchable           # per-domain effective rates
    assert get_backend("multi-domain-sim").domains == ("core", "uncore")
    assert get_backend("pstate-sim").domains == ("ecore", "pcore")
    # pre-domain backends keep the implicit single domain
    assert get_backend("vmapped-sim").domains == ()


def test_create_backend_canonicalizes_option_spellings():
    """Factory options accept any freqkey spelling; the built device holds
    canonical encoded keys, so differently-spelled options yield the same
    device configuration."""
    from repro.core.freqkey import canon_freq
    a = create_backend("multi-domain-sim",
                       power_throttle_freqs=["core:600"])
    b = create_backend("multi-domain-sim",
                       power_throttle_freqs=[("core", 600.0)])
    assert a.cfg.power_throttle_freqs == (canon_freq("core:600"),)
    assert a.cfg.power_throttle_freqs == b.cfg.power_throttle_freqs
    assert a.cfg.frequencies == b.cfg.frequencies


def test_vmapped_rejects_loop_impl():
    with pytest.raises(ValueError, match="vectorized"):
        create_backend("vmapped-sim", kind="a100", n_cores=2,
                       wait_impl="loop")


def test_vmapped_batch_shape_and_continuity():
    dev = create_backend("vmapped-sim", kind="a100", n_cores=4, seed=0)
    assert isinstance(dev, VmappedSimAccelerator)
    dev.set_frequency(dev.frequencies[-1])
    batch = dev.run_kernel_batch(3, 64, 40e-6)
    assert batch.shape == (3, 4, 64, 2)
    starts, ends = batch[..., 0], batch[..., 1]
    assert (ends >= starts).all()
    # kernels are gapless and ordered: kernel k+1 starts at kernel k's end
    assert (batch[1:, :, 0, 0] >= batch[:-1, :, -1, 1] - 1e-9).all()
