"""Alert sinks and the monitor->scheduler requeue loop.

Delivery: every shipped sink honors the AlertSink protocol, external
sinks wear the retry/dead-letter policy wrapper (a down webhook never
raises into the monitor), and ``monitor watch --sink`` pushes each
stored alert exactly once instead of polling.  Closing the loop:
``watch --requeue`` turns flagged drift alerts into a requeue manifest
that ``campaign run --requeue-from-alerts`` consumes as fresh unit
attempts — and pair-seeded determinism makes the re-measured table
byte-identical to the invalidated one on an undrifted device.
"""
import json
import os

import pytest

from repro.campaign import (ArtifactStore, CampaignSpec, DeviceSpec,
                            MeasureSpec, run_campaign)
from repro.campaign.cluster.retry import (DeadLetterFile, RetryPolicy,
                                          TransportError)
from repro.monitor.sinks import (FileSink, HttpSink, QueueSink,
                                 RetryingSink, make_sink)

FAST = MeasureSpec(key="fast", min_measurements=4, max_measurements=5,
                   rse_check_every=4)
FREQS = (210.0, 705.0, 1410.0)


def _drift_doc(unit_key: str, flagged: bool = True) -> dict:
    """A canonical drift document (the fields alert_summary and the
    requeue filter read), hand-built so sink tests need no live fleet."""
    return {
        "kind": "drift", "campaign_id": "c", "unit_key": unit_key,
        "device": unit_key.split("@", 1)[0],
        "f_init": 210.0, "f_target": 1410.0, "sample_index": 9,
        "t_stream": 1.5,
        "scores": {"cusum": 8.0, "page_hinkley": 6.0},
        "verdict": {"worst_baseline_s": 0.01, "worst_window_s": 0.04,
                    "rel_delta": 3.0, "p_value": 0.001,
                    "flagged": flagged},
        "window": {"samples_s": [0.04], "clean_s": [0.04]},
        "baseline": {"worst_s": 0.01, "mean_s": 0.008, "n_clean": 12},
    }


def test_queue_and_file_sinks_deliver_payloads(tmp_path):
    q = QueueSink()
    q.deliver("a1", "u0@fast", _drift_doc("u0@fast"))
    assert q.items[0]["id"] == "a1"
    assert q.items[0]["unit_key"] == "u0@fast"
    assert q.items[0]["kind"] == "drift"

    path = str(tmp_path / "nested" / "alerts.jsonl")
    fs = FileSink(path)
    fs.deliver("a1", "u0@fast", _drift_doc("u0@fast"))
    fs.deliver("a2", "u1@fast", _drift_doc("u1@fast", flagged=False))
    lines = [json.loads(line) for line in open(path)]
    assert [d["id"] for d in lines] == ["a1", "a2"]


def test_file_sink_unwritable_target_is_retryable(tmp_path):
    blocker = tmp_path / "blocker"
    blocker.write_text("a file, not a directory")
    sink = FileSink(str(blocker / "alerts.jsonl"))
    with pytest.raises(TransportError):
        sink.deliver("a1", "u0@fast", _drift_doc("u0@fast"))


def test_http_sink_posts_json_and_maps_failures():
    calls = []

    def ok_post(url, body, timeout_s):
        calls.append((url, json.loads(body)))
        return 204

    HttpSink("https://hooks.example/x", post=ok_post).deliver(
        "a1", "u0@fast", _drift_doc("u0@fast"))
    (url, payload), = calls
    assert url == "https://hooks.example/x"
    assert payload["id"] == "a1" and payload["kind"] == "drift"

    with pytest.raises(TransportError, match="HTTP 503"):
        HttpSink("https://h/x", post=lambda *a: 503).deliver(
            "a1", "u", _drift_doc("u@fast"))

    def down(url, body, timeout_s):
        raise ConnectionError("refused")

    with pytest.raises(TransportError, match="unreachable"):
        HttpSink("https://h/x", post=down).deliver(
            "a1", "u", _drift_doc("u@fast"))


def test_retrying_sink_rides_out_flaps_and_never_raises(tmp_path):
    statuses = iter([500, 500, 200])
    flaky = HttpSink("https://h/x", post=lambda *a: next(statuses))
    sink = RetryingSink(flaky, policy=RetryPolicy(max_attempts=4,
                                                  base_s=0.001, cap_s=0.002))
    sink.deliver("a1", "u0@fast", _drift_doc("u0@fast"))
    assert sink.delivered == 1 and sink.dead == 0

    dl = DeadLetterFile(str(tmp_path / "dead.jsonl"))
    dead = RetryingSink(HttpSink("https://h/x", post=lambda *a: 503),
                        policy=RetryPolicy(max_attempts=2, base_s=0.001,
                                           cap_s=0.002),
                        dead_letters=dl)
    dead.deliver("a2", "u0@fast", _drift_doc("u0@fast"))   # must not raise
    assert dead.dead == 1 and dead.delivered == 0
    (doc,) = dl.records()
    assert doc["key"] == "a2" and "503" in doc["error"]


def test_make_sink_maps_spec_strings(tmp_path):
    http = make_sink("https://hooks.example/x",
                     dead_letter_path=str(tmp_path / "d.jsonl"))
    assert isinstance(http, RetryingSink)
    assert isinstance(http.sink, HttpSink)
    assert http.dead_letters is not None
    file = make_sink(str(tmp_path / "alerts.jsonl"))
    assert isinstance(file.sink, FileSink)


def test_monitor_service_pushes_alerts_through_its_sink(tmp_path):
    """Every alert the service persists is also handed to the sink, with
    the store's content-addressed id."""
    from repro.monitor.ingest import DeviceStream
    from repro.monitor.service import MonitorService, _DeviceState
    spec = CampaignSpec("svc-sink", devices=(
        DeviceSpec.make("d0", "simulated",
                        {"kind": "a100", "n_cores": 6, "seed": 0},
                        frequencies=FREQS),), measures=(FAST,))
    result = run_campaign(spec, ArtifactStore(str(tmp_path)))
    assert result.ok
    sink = QueueSink()
    service = MonitorService(result.campaign, sink=sink)
    st = _DeviceState(DeviceStream("d0"), "d0@fast", None)
    service._raise_alert(st, _drift_doc("d0@fast"))
    (item,) = sink.items
    assert item["kind"] == "drift" and item["unit_key"] == "d0@fast"
    assert item["id"] in result.campaign.list_alerts()["d0@fast"]


# ------------------------------------------------------------------ #
# the CLI loop: watch --sink / --requeue -> run --requeue-from-alerts
# ------------------------------------------------------------------ #
@pytest.fixture()
def alerted_campaign(tmp_path):
    spec = CampaignSpec("loop", devices=tuple(
        DeviceSpec.make(f"u{i}", "simulated",
                        {"kind": "a100", "n_cores": 6, "seed": i},
                        frequencies=FREQS) for i in range(2)),
        measures=(FAST,))
    store_root = str(tmp_path / "store")
    result = run_campaign(spec, ArtifactStore(store_root))
    assert result.ok
    campaign = result.campaign
    flagged = campaign.save_alert("u0@fast", _drift_doc("u0@fast"))
    benign = campaign.save_alert("u1@fast",
                                 _drift_doc("u1@fast", flagged=False))
    return spec, store_root, campaign, flagged, benign


def test_watch_sink_pushes_each_alert_once_then_exits(alerted_campaign,
                                                      tmp_path, capsys):
    from repro.monitor.cli import main
    spec, root, campaign, flagged, benign = alerted_campaign
    out_path = str(tmp_path / "pushed.jsonl")

    rc = main(["--store", root, "watch", campaign.campaign_id,
               "--sink", out_path])
    out = capsys.readouterr().out
    assert rc == 0
    assert "store polling skipped" in out
    pushed = [json.loads(line) for line in open(out_path)]
    assert {d["id"] for d in pushed} == {flagged, benign}
    # delivery state rides with the campaign: a second watch is a no-op
    assert main(["--store", root, "watch", campaign.campaign_id,
                 "--sink", out_path]) == 0
    assert "0 delivered" in capsys.readouterr().out
    assert len([json.loads(line) for line in open(out_path)]) == 2
    # ...until a NEW alert lands
    campaign.save_alert("u1@fast", _drift_doc("u1@fast", flagged=True))
    assert main(["--store", root, "watch", campaign.campaign_id,
                 "--sink", out_path]) == 0
    assert len([json.loads(line) for line in open(out_path)]) == 3


def test_watch_sink_dead_letters_undeliverable_alerts(alerted_campaign,
                                                      tmp_path, capsys):
    from repro.monitor.cli import main
    spec, root, campaign, *_ = alerted_campaign
    blocker = tmp_path / "blocker"
    blocker.write_text("not a directory")

    rc = main(["--store", root, "watch", campaign.campaign_id,
               "--sink", str(blocker / "alerts.jsonl"),
               "--sink-retries", "2"])
    assert rc == 1
    assert "2 dead-lettered" in capsys.readouterr().out
    dl = DeadLetterFile(os.path.join(campaign.dir, "deadletter",
                                     "sink.jsonl"))
    assert len(dl) == 2


def test_requeue_loop_remeasures_flagged_unit_bit_identical(
        alerted_campaign, tmp_path, capsys):
    """watch --requeue records only the FLAGGED drift's unit; run
    --requeue-from-alerts resets and re-measures it; on an undrifted
    device the fresh table is byte-identical (pair seeding), so the
    campaign digest is unchanged."""
    from repro.campaign.cli import main as campaign_main
    from repro.monitor.cli import main as monitor_main
    spec, root, campaign, flagged, benign = alerted_campaign
    digest_before = campaign.content_digest()

    rc = monitor_main(["--store", root, "watch", campaign.campaign_id,
                       "--sink", str(tmp_path / "p.jsonl"), "--requeue"])
    assert rc == 0
    assert "1 unit(s) requeued" in capsys.readouterr().out
    manifest = campaign.load_requeue()
    assert set(manifest["units"]) == {"u0@fast"}
    entry = manifest["units"]["u0@fast"]
    assert entry["alert_ids"] == [flagged]
    assert "drift" in entry["reason"]

    spec_path = str(tmp_path / "spec.json")
    spec.save(spec_path)
    rc = campaign_main(["--store", root, "run", spec_path,
                        "--requeue-from-alerts"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "reset for re-measurement" in out
    assert campaign.load_requeue() == {"units": {}}     # consumed
    states = campaign.unit_states()
    assert states["u0@fast"]["status"] == "done"
    assert states["u0@fast"]["attempts"] == 1           # a FRESH attempt
    assert campaign.content_digest() == digest_before
    # the evidence trail survives the reset
    assert flagged in campaign.list_alerts()["u0@fast"]


def test_save_requeue_merges_alert_ids(alerted_campaign):
    _, _, campaign, *_ = alerted_campaign
    campaign.save_requeue({"u0@fast": {"reason": "first",
                                       "alert_ids": ["a1"]}})
    campaign.save_requeue({"u0@fast": {"reason": "second",
                                       "alert_ids": ["a2", "a1"]}})
    entry = campaign.load_requeue()["units"]["u0@fast"]
    assert entry["reason"] == "second"
    assert entry["alert_ids"] == ["a1", "a2"]
    campaign.clear_requeue()
    assert campaign.load_requeue() == {"units": {}}


def test_requeue_filter_takes_only_flagged_drift(alerted_campaign):
    """The requeue predicate itself: flagged drift requeues; unflagged
    drift and stale-device alerts leave the measurement alone."""
    import argparse

    from repro.monitor.alerts import stale_alert_doc
    from repro.monitor.cli import _maybe_requeue
    _, _, campaign, *_ = alerted_campaign
    on = argparse.Namespace(requeue=True)
    off = argparse.Namespace(requeue=False)
    flagged = _drift_doc("u0@fast", flagged=True)
    assert not _maybe_requeue(off, campaign, "a1", "u0@fast", flagged)
    assert not _maybe_requeue(on, campaign, "a2", "u0@fast",
                              _drift_doc("u0@fast", flagged=False))
    stale = stale_alert_doc("u1", "u1@fast", 0.0, 60.0, 30.0, "c")
    assert not _maybe_requeue(on, campaign, "a3", "u1@fast", stale)
    assert campaign.load_requeue() == {"units": {}}
    assert _maybe_requeue(on, campaign, "a4", "u0@fast", flagged)
    assert set(campaign.load_requeue()["units"]) == {"u0@fast"}
    campaign.clear_requeue()


def test_watch_poll_mode_still_works(alerted_campaign, capsys):
    from repro.monitor.cli import main
    spec, root, campaign, *_ = alerted_campaign
    rc = main(["--store", root, "watch", campaign.campaign_id,
               "--rounds", "1", "--interval", "0.01"])
    assert rc == 0
    assert "existing alert(s)" in capsys.readouterr().out
