"""End-to-end methodology validation: the pipeline must RECOVER the
simulator's ground-truth switching latencies — the calibration loop the
paper itself cannot run on real silicon."""
import numpy as np
import pytest

from repro.core.calibration import calibrate, valid_pairs
from repro.core.evaluation import MeasureConfig, measure_pair
from repro.core.latest import LatestConfig, run_latest
from repro.core.workload import WorkloadSpec
from repro.dvfs import make_device

FAST = MeasureConfig(min_measurements=5, max_measurements=8, rse_check_every=5)


def _spec():
    return WorkloadSpec(iters_per_kernel=1100, flops_per_iter=40e-6,
                        delay_iters=300, confirm_iters=400)


def test_calibration_orders_frequencies():
    dev = make_device("a100", seed=0, n_cores=8)
    freqs = [210.0, 705.0, 1410.0]
    cal = calibrate(dev, freqs, _spec())
    means = [cal.baselines[f].mean for f in freqs]
    assert means[0] > means[1] > means[2]      # slower clock, longer iters
    assert len(valid_pairs(cal)) == 6          # all pairs distinguishable


def test_single_pair_recovers_truth():
    dev = make_device("a100", seed=1, n_cores=8)
    freqs = [210.0, 1410.0]
    cal = calibrate(dev, freqs, _spec())
    pm = measure_pair(dev, 210.0, 1410.0, cal, _spec(), FAST)
    assert pm.status == "ok" and pm.latencies.size >= 5
    truth = [h["true_latency"] for h in dev.history
             if h["from"] == 210.0 and h["to"] == 1410.0]
    # worst measured within 25% of the true max (comm delay + iteration
    # granularity are part of the DEFINITION of switching latency)
    assert pm.latencies.max() == pytest.approx(max(truth), rel=0.25)


@pytest.mark.parametrize("kind", ["a100", "gh200"])
def test_full_pipeline_ground_truth(kind):
    dev = make_device(kind, seed=2, n_cores=8)
    freqs = [dev.cfg.frequencies[0], dev.cfg.frequencies[-1]]
    table = run_latest(dev, freqs,
                       LatestConfig(base_iter_s=40e-6, measure=FAST))
    assert len(table.pairs) == 2
    for (fi, ft), pr in table.pairs.items():
        truth = np.array([h["true_latency"] for h in dev.history
                          if h["from"] == fi and h["to"] == ft])
        assert pr.status == "ok"
        assert pr.worst_case <= truth.max() * 1.35 + 2e-3
        assert pr.worst_case >= truth.min() * 0.65


def test_undetectable_pair_rejected():
    """Adjacent frequencies whose baselines overlap must be filtered in
    phase 1, not produce bogus latencies."""
    dev = make_device("a100", seed=3, n_cores=4,
                      iter_noise_sigma=0.2)       # huge jitter
    freqs = [1395.0, 1410.0]
    cal = calibrate(dev, freqs, _spec())
    assert valid_pairs(cal) == []


def test_power_throttle_skips_pair():
    dev = make_device("a100", seed=4, n_cores=4,
                      power_throttle_freqs=(1410.0,))
    freqs = [210.0, 1410.0]
    cal = calibrate(dev, freqs, _spec())
    pm = measure_pair(dev, 210.0, 1410.0, cal, _spec(), FAST)
    assert pm.status == "power_throttled"
