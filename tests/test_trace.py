"""Trace subsystem: bit-for-bit record/replay, columnar persistence,
transparent backend wrapping, campaign artifacts and the CLI."""
import json
import os

import numpy as np
import pytest

from repro.backends import create_backend, list_backends
from repro.core.calibration import calibrate
from repro.core.evaluation import MeasureConfig
from repro.core.session import (LatestConfig, MeasurementSession,
                                SessionConfig)
from repro.core.switching import measure_switch_once
from repro.core.workload import WorkloadSpec
from repro.dvfs import make_device
from repro.trace import (Trace, TracedBackend, TraceRecorder,
                         TraceReplayBackend, TraceReplayError,
                         TraceSchemaError)
from repro.trace import schema
from repro.trace.analyze import (analyze_trace, replay_table, replay_session,
                                 table_digest)

FREQS = [210.0, 705.0, 1410.0]


def _fast_cfg() -> SessionConfig:
    return SessionConfig(latest=LatestConfig(measure=MeasureConfig(
        min_measurements=3, max_measurements=5, rse_check_every=3)))


@pytest.fixture(scope="module")
def recorded():
    """One traced sweep shared by the replay/persistence tests."""
    rec = TraceRecorder()
    session = MeasurementSession(
        cfg=_fast_cfg(), backend="vmapped-sim",
        backend_options={"kind": "a100", "n_cores": 4, "seed": 0},
        frequencies=FREQS, trace=rec)
    table = session.run()
    return rec.finish(), table


# ------------------------------------------------------------------ #
# replay determinism (the acceptance-criteria gate)
# ------------------------------------------------------------------ #
def test_replay_reproduces_live_table_bit_for_bit(recorded):
    trace, live = recorded
    replayed = replay_table(trace)
    assert set(replayed.pairs) == set(live.pairs)
    for key, lp in live.pairs.items():
        rp = replayed.pairs[key]
        np.testing.assert_array_equal(rp.latencies, lp.latencies)
        np.testing.assert_array_equal(rp.labels, lp.labels)  # DBSCAN labels
        np.testing.assert_array_equal(rp.clean, lp.clean)
        assert rp.status == lp.status
        assert rp.n_clusters == lp.n_clusters
    assert table_digest(replayed) == table_digest(live)
    assert trace.meta["live_table_digest"] == table_digest(live)


def test_replay_consumes_every_protocol_event(recorded):
    trace, _ = recorded
    session = replay_session(trace)
    session.run()
    assert session.device.remaining_events == 0


def test_analyze_trace_report(recorded):
    trace, live = recorded
    report = analyze_trace(trace)
    assert report.deterministic
    assert report.passes, "no switch passes reconstructed"
    assert report.online_agrees
    assert report.max_delta <= report.timer_resolution_s
    assert report.ok
    # every measured pair shows up among the reconstructed passes
    seen = {(p.f_init, p.f_target) for p in report.passes}
    ok_pairs = {k for k, pr in live.pairs.items() if pr.status == "ok"}
    assert ok_pairs <= seen


# ------------------------------------------------------------------ #
# persistence
# ------------------------------------------------------------------ #
def test_save_load_roundtrip(recorded, tmp_path):
    trace, live = recorded
    path = trace.save(str(tmp_path / "sweep.trace"))
    loaded = Trace.load(path)
    np.testing.assert_array_equal(loaded.kinds, trace.kinds)
    np.testing.assert_array_equal(loaded.cols, trace.cols)
    np.testing.assert_array_equal(loaded.payload, trace.payload)
    assert loaded.extras == trace.extras
    assert loaded.meta["live_table_digest"] == table_digest(live)
    assert table_digest(replay_table(loaded)) == table_digest(live)


def test_schema_version_guard(recorded, tmp_path):
    trace, _ = recorded
    path = trace.save(str(tmp_path / "bad.trace"))
    header = os.path.join(path, schema.HEADER_FILE)
    with open(header) as f:
        lines = f.readlines()
    head = json.loads(lines[0])
    head["schema_version"] = schema.SCHEMA_VERSION + 1
    lines[0] = json.dumps(head) + "\n"
    with open(header, "w") as f:
        f.writelines(lines)
    with pytest.raises(TraceSchemaError, match="schema version"):
        Trace.load(path)


def test_registry_backend(recorded, tmp_path):
    trace, _ = recorded
    assert "trace-replay" in list_backends()
    path = trace.save(str(tmp_path / "reg.trace"))
    dev = create_backend("trace-replay", path=path)
    assert isinstance(dev, TraceReplayBackend)
    # the replay device advertises the recorded device's full table; the
    # swept subset lives in meta["sweep"]
    assert list(dev.frequencies) == trace.meta["device"]["frequencies"]
    assert set(FREQS) <= set(dev.frequencies)
    assert trace.meta["sweep"]["frequencies"] == FREQS
    with pytest.raises(ValueError, match="path="):
        create_backend("trace-replay")


def test_replay_strict_divergence(recorded):
    trace, _ = recorded
    dev = TraceReplayBackend(trace)
    # the recorded stream starts with calibration's set_frequency
    with pytest.raises(TraceReplayError, match="diverged"):
        dev.usleep(1.0)
    dev2 = TraceReplayBackend(trace)
    with pytest.raises(TraceReplayError, match="set_frequency"):
        dev2.set_frequency(-123.0)


# ------------------------------------------------------------------ #
# TracedBackend wrapping
# ------------------------------------------------------------------ #
def test_traced_backend_is_transparent():
    """Same seed, same calls -> the traced device produces bit-identical
    measurements (recording must not perturb the RNG stream)."""
    spec = WorkloadSpec(iters_per_kernel=700, flops_per_iter=40e-6,
                        delay_iters=200, confirm_iters=250)
    plain = create_backend("simulated", kind="a100", n_cores=4, seed=7)
    traced = TracedBackend(
        create_backend("simulated", kind="a100", n_cores=4, seed=7),
        TraceRecorder())
    cal_p = calibrate(plain, FREQS, spec)
    cal_t = calibrate(traced, FREQS, spec)
    for f in FREQS:
        assert cal_p.baselines[f] == cal_t.baselines[f]
    sp = measure_switch_once(plain, 210.0, 1410.0, cal_p, spec)
    st = measure_switch_once(traced, 210.0, 1410.0, cal_t, spec)
    assert (sp is None) == (st is None)
    if sp is not None:
        assert sp.latency == st.latency
        assert sp.t_s == st.t_s
        np.testing.assert_array_equal(sp.core_latencies, st.core_latencies)


def test_traced_payload_roundtrip_is_bit_exact():
    for kind in ("a100", "gh200", "rtx6000"):
        dev = make_device(kind, seed=3, n_cores=5)
        rec = TraceRecorder()
        traced = TracedBackend(dev, rec)
        traced.set_frequency(dev.frequencies[0])
        data = traced.run_kernel(300, 40e-6)
        trace = rec.finish()
        wait_events = np.flatnonzero(trace.kinds == schema.WAIT)
        np.testing.assert_array_equal(trace.wait_payload(int(wait_events[-1])),
                                      data)


def test_throttle_reasons_pass_through():
    dev = make_device("a100", seed=0, n_cores=2,
                      power_throttle_freqs=(705.0,))
    rec = TraceRecorder()
    traced = TracedBackend(dev, rec)
    traced.set_frequency(705.0)
    traced.run_kernel(16, 40e-6)
    flags = traced.throttle_reasons()
    assert flags == {"power"}
    assert traced.throttle_reasons() == set()   # drained from the device
    trace = rec.finish()
    throttle_events = [i for i in range(trace.n_events)
                       if int(trace.kinds[i]) == schema.THROTTLE]
    assert trace.extras[throttle_events[0]]["flags"] == ["power"]
    assert trace.extras.get(throttle_events[1], {}).get("flags", []) == []


def test_warm_kernel_records_no_payload():
    rec = TraceRecorder()
    traced = TracedBackend(make_device("a100", seed=0, n_cores=2), rec)
    rows_before = rec._payload_rows
    traced.warm_kernel(64, 40e-6)
    assert rec._payload_rows == rows_before
    trace = rec.finish()
    assert int(trace.kinds[-1]) == schema.WARM_KERNEL


def test_resumed_session_trace_not_stamped_replayable(tmp_path):
    """A resume loads pairs/calibration the recorder never saw: the trace
    must not claim the bit-for-bit contract, and replay must refuse it
    with a clear error instead of diverging mid-stream."""
    def session(trace=None):
        return MeasurementSession(
            cfg=SessionConfig(latest=_fast_cfg().latest,
                              out_dir=str(tmp_path / "state")),
            backend="vmapped-sim",
            backend_options={"kind": "a100", "n_cores": 3},
            frequencies=[210.0, 1410.0], trace=trace)

    session().run()                       # full sweep, persisted
    rec = TraceRecorder()
    session(trace=rec).run()              # resume: everything loads
    trace = rec.finish()
    assert trace.meta["trace_complete"] is False
    assert "live_table_digest" not in trace.meta
    with pytest.raises(ValueError, match="RESUMED"):
        replay_session(trace)


def test_sweepless_trace_replay_fails_with_clear_error():
    """Traces not recorded through MeasurementSession (governor audits,
    ad-hoc TracedBackend use) get the crafted message, not a KeyError."""
    rec = TraceRecorder()
    TracedBackend(make_device("a100", seed=0, n_cores=2), rec) \
        .run_kernel(16, 40e-6)
    with pytest.raises(ValueError, match="sweep"):
        replay_session(rec.finish())


def test_traced_session_requires_serial_executor():
    session = MeasurementSession(
        cfg=SessionConfig(executor="threads", max_workers=2),
        backend="simulated",
        backend_options={"kind": "a100", "n_cores": 2},
        frequencies=FREQS, trace=TraceRecorder())
    with pytest.raises(ValueError, match="serial"):
        session._ensure_workers(2)


# ------------------------------------------------------------------ #
# governor audit + campaign artifacts
# ------------------------------------------------------------------ #
def test_governor_plan_audited_into_trace():
    from repro.core.latency_table import LatencyTable, analyse_pair
    from repro.dvfs.governor import Governor
    from repro.dvfs.planner import Region
    from repro.dvfs.power_model import PowerModel

    dev = make_device("a100", seed=0, n_cores=2)
    rec = TraceRecorder()
    traced = TracedBackend(dev, rec)
    table = LatencyTable()
    rng = np.random.default_rng(0)
    for fi, ft in [(210.0, 1410.0), (1410.0, 210.0)]:
        table.add(analyse_pair(fi, ft, 5e-3 + 1e-4 * rng.random(12)))
    gov = Governor(table, PowerModel(f_max_mhz=1410.0), [210.0, 1410.0])
    gov.plan(Region("compute", 10.0), traced)
    gov.plan(Region("memory", 10.0), traced)
    trace = rec.finish()
    plans = [i for i in range(trace.n_events)
             if int(trace.kinds[i]) == schema.PLAN]
    assert len(plans) == 2
    assert trace.extras[plans[0]]["region"] == "compute"
    assert "reason" in trace.extras[plans[0]]
    # the audit precedes the issued command
    kinds = [int(k) for k in trace.kinds]
    assert schema.SET_FREQUENCY in kinds
    assert plans[0] < kinds.index(schema.SET_FREQUENCY)


def test_campaign_stores_and_lists_traces(tmp_path):
    from repro.campaign import ArtifactStore, CampaignSpec, run_campaign

    spec = CampaignSpec.from_dict({
        "name": "trace-artifacts",
        "devices": [{"key": "a100", "backend": "vmapped-sim",
                     "options": {"kind": "a100", "n_cores": 3},
                     "frequencies": [210.0, 1410.0]}],
        "measures": [{"key": "fast", "min_measurements": 3,
                      "max_measurements": 5, "rse_check_every": 3}]})
    store = ArtifactStore(str(tmp_path))
    result = run_campaign(spec, store, trace=True)
    assert result.ok
    campaign = result.campaign
    unit = "a100@fast"
    assert campaign.list_traces() == {unit: ["session"]}
    trace = campaign.load_trace(unit)
    assert trace.meta["unit_key"] == unit
    assert trace.meta["campaign_id"] == campaign.campaign_id
    # stored trace replays to the exact table the campaign persisted
    assert table_digest(replay_table(trace)) \
        == trace.meta["live_table_digest"]


# ------------------------------------------------------------------ #
# CLI
# ------------------------------------------------------------------ #
def test_cli_record_replay_analyze_export(tmp_path, capsys):
    from repro.trace.cli import main

    out = str(tmp_path / "cli.trace")
    assert main(["record", "--out", out, "--frequencies", "210", "1410",
                 "--n-cores", "3", "--min-measurements", "2",
                 "--max-measurements", "3", "--quiet"]) == 0
    assert main(["replay", out, "--quiet"]) == 0
    capsys.readouterr()
    assert main(["analyze", out]) == 0
    assert "AGREE" in capsys.readouterr().out
    report = str(tmp_path / "events.jsonl")
    assert main(["export", out, "--out", report]) == 0
    first = json.loads(open(report).readline())
    assert first["kind"] in ("set_frequency", "sync_batch", "batch")
