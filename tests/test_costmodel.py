"""Jaxpr cost model: exact scan multiplication (vs XLA's loop-blind count)."""
import jax
import jax.numpy as jnp
import pytest

from repro import costmodel


def test_xla_cost_analysis_is_loop_blind():
    """Documents WHY the jaxpr counter exists: XLA counts scan bodies once."""
    def one(x, w):
        return x @ w

    def scan10(x, w):
        def f(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(f, x, None, length=10)
        return y

    x = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    w = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    f1 = costmodel.xla_cost_analysis(jax.jit(one).lower(x, w).compile())["flops"]
    f10 = costmodel.xla_cost_analysis(jax.jit(scan10).lower(x, w).compile())["flops"]
    # XLA may unroll tiny loops; at this size the loop survives and the body
    # is counted once (or at most a couple of times) instead of 10x
    assert f10 < 5 * f1                    # the undercount


def test_scan_multiplication_exact():
    D, L, B = 32, 7, 4

    def f(params, x):
        def body(c, w):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, params)
        return y

    params = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
    x = jax.ShapeDtypeStruct((B, D), jnp.float32)
    st = costmodel.cost_of(f, params, x)
    assert st.flops == pytest.approx(L * 2 * B * D * D)


def test_grad_of_checkpoint_scan_counts_8nd():
    """fwd(2ND) + refwd(2ND) + bwd(4ND) under full remat."""
    D, L, B = 64, 10, 8

    def f(params, x):
        def body(c, w):
            return jax.checkpoint(lambda c, w: jnp.tanh(c @ w))(c, w), None
        y, _ = jax.lax.scan(body, x, params)
        return jnp.sum(y * y)

    params = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
    x = jax.ShapeDtypeStruct((B, D), jnp.float32)
    st = costmodel.cost_of(lambda p, x: jax.grad(f)(p, x), params, x)
    one_fwd = L * 2 * B * D * D
    assert st.flops == pytest.approx(4 * one_fwd)      # 8ND = 4 x fwd


def test_dot_general_batched():
    def f(a, b):
        return jnp.einsum("bij,bjk->bik", a, b)
    a = jax.ShapeDtypeStruct((5, 8, 16), jnp.float32)
    b = jax.ShapeDtypeStruct((5, 16, 4), jnp.float32)
    st = costmodel.cost_of(f, a, b)
    assert st.flops == pytest.approx(2 * 5 * 8 * 16 * 4)


def test_bytes_include_dots_and_gathers():
    def f(x, idx):
        return jnp.take(x, idx, axis=0)
    x = jax.ShapeDtypeStruct((100, 64), jnp.float32)
    idx = jax.ShapeDtypeStruct((10,), jnp.int32)
    st = costmodel.cost_of(f, x, idx)
    assert st.bytes >= 2 * 10 * 64 * 4      # gather out bytes counted 2x
