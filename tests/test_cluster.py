"""Multi-node campaign dispatch: the chaos matrix.

The acceptance contract from the cluster layer: under every injected
fault — a node crashing mid-unit, a transport that drops/duplicates/
delays messages, a store that fails writes transiently or partitions
away from the driver — the campaign still completes within
``spec.retries`` total attempts per unit, and the merged store is
*bit-identical* to a serial single-host run of the same spec.  A
permanently failing store isolates to its unit (dead-lettered), never
poisoning the rest.
"""
import json
import os

import numpy as np
import pytest

from repro.campaign import (ArtifactStore, CampaignRunner, CampaignSpec,
                            DeviceSpec, MeasureSpec, run_campaign)
from repro.campaign.workqueue import FaultPlan, fault_marker_path

FAST = MeasureSpec(key="fast", min_measurements=4, max_measurements=5,
                   rse_check_every=4)
FREQS = (210.0, 705.0, 1410.0)


def _device(key, seed, kind="a100"):
    return DeviceSpec.make(key, "simulated",
                           {"kind": kind, "n_cores": 6, "seed": seed},
                           frequencies=FREQS)


def _fleet(n=4, retries=3):
    return CampaignSpec("clu", devices=tuple(_device(f"u{i}", i)
                                             for i in range(n)),
                        measures=(FAST,), retries=retries)


def _run_cluster(spec, store, *, fault_plan=None, nodes=3, **kw):
    return CampaignRunner(spec, store, executor="cluster",
                          max_workers=nodes, fault_plan=fault_plan,
                          **kw).run()


def _assert_store_bit_identical(ref, cand):
    """The tentpole gate: whole-campaign content digest equality, plus
    array-level table equality so a digest bug cannot mask a real
    divergence."""
    assert ref.campaign.content_digest() == cand.campaign.content_digest()
    assert set(ref.outcomes) == set(cand.outcomes)
    for key in ref.outcomes:
        rt, ct = ref.campaign.load_table(key), cand.campaign.load_table(key)
        assert set(rt.pairs) == set(ct.pairs)
        for p, pr in rt.pairs.items():
            assert np.array_equal(pr.latencies, ct.pairs[p].latencies)
            assert np.array_equal(pr.outlier_mask, ct.pairs[p].outlier_mask)
            assert pr.status == ct.pairs[p].status


def test_clean_cluster_run_matches_serial(tmp_path):
    spec = _fleet(4)
    ref = run_campaign(spec, ArtifactStore(str(tmp_path / "serial")))
    assert ref.ok
    cand = _run_cluster(spec, ArtifactStore(str(tmp_path / "cluster")))
    assert cand.ok, [(o.key, o.error) for o in cand.failed()]
    _assert_store_bit_identical(ref, cand)
    # a clean network and store: the chaos counters prove it
    assert cand.stats.get("transport_msg_dropped", 0) == 0
    assert cand.stats.get("store_injected_transient", 0) == 0


def test_node_crash_requeues_unit_bit_identical(tmp_path):
    """A node dying two pairs into a unit: the driver reaps it, requeues
    the in-flight unit, a respawned node resumes from the uploaded pair
    files, and the merged store matches the serial reference."""
    spec = _fleet(4)
    ref = run_campaign(spec, ArtifactStore(str(tmp_path / "serial")))
    assert ref.ok

    crash_key = spec.units()[0].key
    cand = _run_cluster(
        spec, ArtifactStore(str(tmp_path / "cluster")),
        fault_plan=FaultPlan.make(
            node_crash_after_pairs={crash_key: 2}))
    assert cand.ok, [(o.key, o.error) for o in cand.failed()]
    assert os.path.exists(
        fault_marker_path(cand.campaign, crash_key, "node_crash"))
    assert cand.stats["crashed_nodes"] >= 1
    assert cand.stats["requeued_units"] >= 1
    assert cand.stats.get("recovery_s", 0.0) > 0.0
    assert cand.outcomes[crash_key].attempts >= 2
    assert cand.outcomes[crash_key].attempts <= spec.retries
    _assert_store_bit_identical(ref, cand)


def test_single_node_crash_respawns_replacement(tmp_path):
    """With no surviving capacity to absorb the requeue, the driver
    spawns a replacement node; it resumes the crashed unit from the
    store's uploaded pair files."""
    spec = _fleet(2, retries=3)
    ref = run_campaign(spec, ArtifactStore(str(tmp_path / "serial")))
    assert ref.ok
    crash_key = spec.units()[0].key
    cand = _run_cluster(
        spec, ArtifactStore(str(tmp_path / "cluster")), nodes=1,
        fault_plan=FaultPlan.make(node_crash_after_pairs={crash_key: 2}))
    assert cand.ok, [(o.key, o.error) for o in cand.failed()]
    assert cand.stats["crashed_nodes"] >= 1
    assert cand.stats["respawned_nodes"] >= 1
    _assert_store_bit_identical(ref, cand)


def test_transport_chaos_completes_bit_identical(tmp_path):
    """Messages dropped, duplicated, and delayed on every link: dropped
    dispatches/acks surface as heartbeat silence and are requeued;
    duplicated completions are discarded first-result-wins; the store
    still converges to the serial bytes."""
    spec = _fleet(4)
    ref = run_campaign(spec, ArtifactStore(str(tmp_path / "serial")))
    assert ref.ok

    cand = _run_cluster(
        spec, ArtifactStore(str(tmp_path / "cluster")),
        heartbeat_timeout_s=3.0,
        fault_plan=FaultPlan.make(
            transport={"drop_rate": 0.1, "dup_rate": 0.1,
                       "delay_s": 0.02, "seed": 7}))
    assert cand.ok, [(o.key, o.error) for o in cand.failed()]
    chaos = sum(cand.stats.get(f"transport_{k}", 0)
                for k in ("msg_dropped", "msg_duplicated", "msg_delayed",
                          "rpc_dropped", "rpc_duplicated", "rpc_delayed"))
    assert chaos > 0, "the chaos plan must actually have fired"
    _assert_store_bit_identical(ref, cand)


def test_transient_store_failures_and_partition_ridden_out(tmp_path):
    """A store whose first writes for one unit fail, plus a healing
    driver<->store partition window: both are absorbed by the retry
    layer — no unit fails, no attempt is burned on a fault the backoff
    can outlive."""
    spec = _fleet(3)
    ref = run_campaign(spec, ArtifactStore(str(tmp_path / "serial")))
    assert ref.ok

    key = spec.units()[0].key
    cand = _run_cluster(
        spec, ArtifactStore(str(tmp_path / "cluster")),
        fault_plan=FaultPlan.make(store_transient={key: 3},
                                  store_partition=(2, 4)))
    assert cand.ok, [(o.key, o.error) for o in cand.failed()]
    assert cand.stats["store_injected_transient"] == 3
    assert cand.stats["driver_partitioned_ops"] >= 1
    assert cand.stats["driver_retries"] >= 1
    _assert_store_bit_identical(ref, cand)


def test_permanent_store_failure_isolates_and_dead_letters(tmp_path):
    """Writes for one unit fail on every attempt: that unit exhausts its
    budget and lands in ``failed`` with the giving-up evidence in a
    dead-letter file, while every other unit completes."""
    spec = _fleet(4, retries=2)
    key = spec.units()[0].key
    # speculation off: a speculative clone of the doomed unit would add
    # legitimate extra dispatches on top of the failure budget
    cand = _run_cluster(
        spec, ArtifactStore(str(tmp_path)), speculate=False,
        fault_plan=FaultPlan.make(store_permanent=[key]))
    assert not cand.ok
    (failed,) = cand.failed()
    assert failed.key == key
    assert failed.attempts == spec.retries          # TOTAL budget
    for o in cand.outcomes.values():
        if o.key != key:
            assert o.status == "done"
    dl_dir = os.path.join(cand.campaign.dir, "deadletter")
    letters = []
    for name in os.listdir(dl_dir):
        with open(os.path.join(dl_dir, name)) as f:
            letters += [json.loads(line) for line in f if line.strip()]
    assert any(key in doc["key"] for doc in letters)


def test_cluster_resumes_from_store(tmp_path):
    spec = _fleet(2)
    store = ArtifactStore(str(tmp_path))
    first = _run_cluster(spec, store, nodes=2)
    assert first.ok
    again = _run_cluster(spec, store, nodes=2)
    assert again.ok
    assert all(o.status == "loaded" for o in again.outcomes.values())


def test_cluster_refuses_traced_and_batched_schedules(tmp_path):
    spec = _fleet(1)
    store = ArtifactStore(str(tmp_path))
    with pytest.raises(ValueError, match="trace"):
        CampaignRunner(spec, store, executor="cluster", trace=True)
    with pytest.raises(ValueError, match="batched"):
        CampaignRunner(spec, store, executor="cluster", engine="batched")


def test_fault_plan_cluster_fields_roundtrip():
    fp = FaultPlan.make(
        node_crash_after_pairs={"a": 1},
        transport={"drop_rate": 0.2, "seed": 3},
        store_transient={"b": 2}, store_permanent=["c"],
        store_partition=(5, 10))
    assert not fp.empty
    assert fp.node_crash_for("a") == 1 and fp.node_crash_for("b") is None
    assert fp.transport_dict() == {"drop_rate": 0.2, "seed": 3}
    assert fp.store_transient_for("b") == 2
    assert fp.store_transient_for("a") == 0
    assert fp.store_permanent_for("c") and not fp.store_permanent_for("a")
    assert fp.partition_window() == (5, 10)
    assert FaultPlan.make().partition_window() is None
    assert FaultPlan().empty


# ------------------------------------------------------------------ #
# CLI exit codes: the CI contract of `campaign run`
# ------------------------------------------------------------------ #
def _write_spec(tmp_path, spec):
    path = str(tmp_path / "spec.json")
    with open(path, "w") as f:
        json.dump(spec.to_dict(), f)
    return path


def test_cli_run_exits_nonzero_on_failed_unit(tmp_path, capsys):
    from repro.campaign.cli import main
    bad = DeviceSpec.make("bad", "simulated",
                          {"kind": "no-such-gpu", "n_cores": 6, "seed": 0},
                          frequencies=FREQS)
    spec = CampaignSpec("cli-fail", devices=(bad, _device("ok", 1)),
                        measures=(FAST,), retries=1)
    spec_path = _write_spec(tmp_path, spec)
    root = str(tmp_path / "store")

    assert main(["--store", root, "run", spec_path, "--quiet"]) == 1
    assert "FAILED bad@fast" in capsys.readouterr().err
    # the escape hatch for exploratory sweeps that tolerate holes
    assert main(["--store", root, "run", spec_path, "--quiet",
                 "--ok-on-partial"]) == 0
    assert "--ok-on-partial" in capsys.readouterr().err


def test_cli_run_exits_2_on_unloadable_spec(tmp_path, capsys):
    from repro.campaign.cli import main
    missing = str(tmp_path / "nope.json")
    assert main(["--store", str(tmp_path), "run", missing]) == 2
    assert "cannot load spec" in capsys.readouterr().err
    garbled = str(tmp_path / "garbled.json")
    with open(garbled, "w") as f:
        f.write("{not json")
    assert main(["--store", str(tmp_path), "run", garbled]) == 2


def test_cli_run_cluster_executor_end_to_end(tmp_path, capsys):
    from repro.campaign.cli import main
    spec = _fleet(2)
    spec_path = _write_spec(tmp_path, spec)
    root = str(tmp_path / "store")
    assert main(["--store", root, "run", spec_path, "--quiet",
                 "--executor", "cluster", "--nodes", "2"]) == 0
    out = capsys.readouterr().out
    assert "[cluster x2]" in out and "ok:" in out
