"""Heterogeneous frequency-domain backends: the freqkey encoding, the
multi-domain and pstate simulators, domain-dependent switching latency
through the full pipeline, and the single-domain bit-identity contract."""
import numpy as np
import pytest

from repro.backends import create_backend, get_backend
from repro.campaign import aggregate
from repro.campaign.scheduler import run_campaign
from repro.campaign.spec import CampaignSpec, DeviceSpec, MeasureSpec
from repro.campaign.store import ArtifactStore
from repro.core.evaluation import MeasureConfig
from repro.core.freqkey import (DOMAIN_STRIDE, canon_freq, encode_freq,
                                format_freq, freq_domain, freq_mhz,
                                has_domain, spec_form, split_freq,
                                transition_class)
from repro.core.session import (LatestConfig, MeasurementSession,
                                SessionConfig)

FAST = MeasureConfig(min_measurements=4, max_measurements=6,
                     rse_check_every=4)

MD_FREQS = [encode_freq("core", 600), encode_freq("core", 1500),
            encode_freq("uncore", 300), encode_freq("uncore", 600)]


def _cfg(**kw):
    return SessionConfig(latest=LatestConfig(measure=FAST), **kw)


def _md_session(out_dir=None, seed=7, **kw):
    return MeasurementSession(
        frequencies=MD_FREQS, cfg=_cfg(out_dir=out_dir, **kw),
        backend="multi-domain-sim",
        backend_options={"seed": seed, "n_cores": 8})


# ------------------------------------------------------------------ #
# freqkey: the encoding itself
# ------------------------------------------------------------------ #
def test_canon_freq_accepts_every_spelling():
    key = encode_freq("uncore", 450)
    assert canon_freq("uncore:450") == key
    assert canon_freq(("uncore", 450)) == key
    assert canon_freq(["uncore", 450.0]) == key
    assert canon_freq(key) == key                      # idempotent
    assert canon_freq("1410") == 1410.0
    assert canon_freq(1410.0) == 1410.0                # bare passes through


def test_split_format_roundtrip():
    key = canon_freq("ecore:972")
    assert split_freq(key) == ("ecore", 972.0)
    assert format_freq(key) == "ecore:972"
    assert freq_mhz(key) == 972.0
    assert freq_domain(key) == "ecore"
    assert has_domain(key) and not has_domain(1410.0)
    assert split_freq(1410.0) == (None, 1410.0)
    assert format_freq(1410.0) == "1410"


def test_transition_class_labels():
    c6, c15 = canon_freq("core:600"), canon_freq("core:1500")
    u3 = canon_freq("uncore:300")
    assert transition_class(c6, c15) == "core"
    assert transition_class(c6, u3) == "core->uncore"
    assert transition_class(u3, c6) == "uncore->core"
    assert transition_class(210.0, 1410.0) == "core"   # bare = implicit core


def test_unknown_domain_raises_with_canonical_list():
    with pytest.raises(KeyError, match="ecore"):
        encode_freq("gpu", 1000)
    with pytest.raises(KeyError, match="canonical domains"):
        canon_freq("fabric:600")


def test_fractional_and_out_of_range_mhz_rejected():
    # encoded keys must survive pair_seed's %.6g formatting bit-exactly
    with pytest.raises(ValueError, match="whole"):
        encode_freq("core", 892.5)
    with pytest.raises(ValueError, match="range"):
        encode_freq("core", DOMAIN_STRIDE + 1)
    with pytest.raises(ValueError, match="range"):
        encode_freq("core", 0)


def test_spec_form_keeps_bare_floats_as_numbers():
    assert spec_form(1410.0) == 1410.0                 # number, not string
    assert spec_form(canon_freq("uncore:600")) == "uncore:600"


def test_pair_seed_distinguishes_domains():
    """("core", 600) and ("uncore", 600) must never share an RNG stream."""
    from repro.core.pairtask import pair_seed
    c, u = canon_freq("core:600"), canon_freq("uncore:600")
    assert pair_seed(0, c, u) != pair_seed(0, c, c)
    assert pair_seed(0, c, c) != pair_seed(0, u, u)
    assert pair_seed(0, c, c) != pair_seed(0, 600.0, 600.0)


# ------------------------------------------------------------------ #
# multi-domain-sim: latency depends on which domain moves
# ------------------------------------------------------------------ #
def test_ground_truth_ordering_core_uncore_cross():
    dev = create_backend("multi-domain-sim", seed=1)
    m = dev.model
    cc = m.base_latency(canon_freq("core:600"), canon_freq("core:1500"))
    uu = m.base_latency(canon_freq("uncore:300"), canon_freq("uncore:600"))
    xd = m.base_latency(canon_freq("core:600"), canon_freq("uncore:300"))
    assert cc < uu < xd


def test_unsupported_operating_point_names_the_ladder():
    dev = create_backend("multi-domain-sim", seed=1)
    with pytest.raises(ValueError, match="core:600"):
        dev.set_frequency("mem:500")
    with pytest.raises(ValueError, match="unsupported operating point"):
        dev.set_frequency(999.0)                       # bare MHz, no ladder


def test_measured_latency_depends_on_domain():
    """Acceptance gate: through the full phase 1-3 pipeline, core-only,
    uncore-only and cross-domain transitions land in distinct latency
    regimes matching the model's ordering."""
    table = _md_session().run()
    by_class = {}
    for (fi, ft), pr in table.pairs.items():
        assert pr.status == "ok" and pr.clean.size
        by_class.setdefault(transition_class(fi, ft), []).append(pr.mean)
    assert {"core", "uncore", "core->uncore", "uncore->core"} <= set(by_class)
    cc = np.mean(by_class["core"])
    uu = np.mean(by_class["uncore"])
    xd = np.mean(by_class["core->uncore"] + by_class["uncore->core"])
    assert cc < uu < xd


def test_threads_bit_identical_to_serial_multi_domain():
    serial = _md_session().run()
    threaded = _md_session(executor="threads", max_workers=3).run()
    assert set(serial.pairs) == set(threaded.pairs)
    for p, pr in serial.pairs.items():
        assert np.array_equal(pr.latencies, threaded.pairs[p].latencies)
        assert np.array_equal(pr.labels, threaded.pairs[p].labels)


def test_resume_bit_identical_multi_domain(tmp_path):
    out = str(tmp_path / "md")
    subset = [(MD_FREQS[0], MD_FREQS[2]), (MD_FREQS[2], MD_FREQS[0])]
    partial = _md_session(out_dir=out).run(pair_subset=subset)
    resumed = _md_session(out_dir=out).run()
    fresh = _md_session().run()
    assert set(resumed.pairs) == set(fresh.pairs)
    for p, pr in fresh.pairs.items():
        assert np.array_equal(pr.latencies, resumed.pairs[p].latencies)
    for p in subset:
        assert np.array_equal(partial.pairs[p].latencies,
                              resumed.pairs[p].latencies)


def test_batched_engine_rejected_with_clear_error():
    assert not get_backend("multi-domain-sim").batchable
    s = MeasurementSession(
        frequencies=MD_FREQS, cfg=_cfg(), backend="multi-domain-sim",
        backend_options={"seed": 7, "n_cores": 8}, engine="batched")
    with pytest.raises(ValueError, match="batchable"):
        s.run()


def test_asymmetry_skips_cross_domain_pairs():
    table = _md_session().run()
    a = table.asymmetry()
    # 4 same-domain pairs split 2 up / 2 down; 8 cross-domain pairs excluded
    assert a["increase"]["n"] == 2 and a["decrease"]["n"] == 2


def test_trace_record_replay_multi_domain():
    """Encoded operating points ride the trace event stream unchanged:
    a replayed sweep reproduces the live table bit-for-bit."""
    from repro.trace import TraceRecorder
    from repro.trace.analyze import replay_table, table_digest
    rec = TraceRecorder()
    live = MeasurementSession(
        frequencies=MD_FREQS, cfg=_cfg(), backend="multi-domain-sim",
        backend_options={"seed": 7, "n_cores": 8}, trace=rec).run()
    trace = rec.finish()
    replayed = replay_table(trace)
    assert set(replayed.pairs) == set(live.pairs)
    for key, lp in live.pairs.items():
        np.testing.assert_array_equal(replayed.pairs[key].latencies,
                                      lp.latencies)
    assert table_digest(replayed) == table_digest(live)
    assert trace.meta["live_table_digest"] == table_digest(live)


# ------------------------------------------------------------------ #
# pstate-sim: per-cluster ladders + timelog measurement
# ------------------------------------------------------------------ #
def test_pstate_clusters_and_ladders():
    dev = create_backend("pstate-sim", seed=2)
    assert dev.clusters == ("ecore", "pcore")
    ladders = dev.cluster_frequencies()
    assert len(ladders["ecore"]) == 5 and len(ladders["pcore"]) == 15
    assert ladders["ecore"][-1] == 2064.0 and ladders["pcore"][-1] == 3204.0


def test_pstate_timelog_matches_ground_truth_within_sample_period():
    dev = create_backend("pstate-sim", seed=2)
    rate = 200e3
    for pair in [("pcore:600", "pcore:3204"), ("ecore:600", "ecore:2064"),
                 ("ecore:600", "pcore:2988")]:
        lat, samples = dev.measure_pstate_latency(*pair, window_s=0.03,
                                                  rate_hz=rate)
        truth = dev.history[-1]["true_latency"]
        assert abs(lat - truth) <= 1.0 / rate + 1e-9, pair
        assert samples.shape[1] == 2


def test_pstate_cross_cluster_passes_through_default():
    """A cross-cluster trajectory visits the all-default operating point,
    so the timelog sees three effective rates: source, default, target."""
    dev = create_backend("pstate-sim", seed=3)
    dev.set_frequency("ecore:600")
    dev.usleep(0.05)
    dev.set_frequency("pcore:600")
    arrive = dev.history[-1]["arrive_dev"]
    samples = dev.read_timelog(arrive, 0.02, 200e3)
    eff = dev.model.effective_frequency
    seen = set(np.unique(samples[:, 1]))
    assert eff(canon_freq("pcore:3204")) in seen       # default waypoint
    assert samples[-1, 1] == eff(canon_freq("pcore:600"))


def test_pstate_session_runs_cross_cluster_pairs():
    freqs = [encode_freq("ecore", 600), encode_freq("ecore", 2064),
             encode_freq("pcore", 3204)]
    table = MeasurementSession(
        frequencies=freqs, cfg=_cfg(), backend="pstate-sim",
        backend_options={"seed": 5, "n_cores": 6}).run()
    classes = {transition_class(fi, ft) for fi, ft in table.pairs}
    assert "ecore" in classes
    assert {"ecore->pcore", "pcore->ecore"} <= classes
    assert all(p.status == "ok" for p in table.pairs.values())


# ------------------------------------------------------------------ #
# campaign: cross-architecture report + single-domain gating
# ------------------------------------------------------------------ #
def _fast_measure():
    return MeasureSpec(key="fast", min_measurements=4, max_measurements=6,
                       rse_check_every=4)


def test_mixed_campaign_report_covers_three_families(tmp_path):
    spec = CampaignSpec(
        name="cross-arch",
        devices=(
            DeviceSpec.make("rtx", "vmapped-sim",
                            {"kind": "rtx6000", "n_cores": 6}, n_freqs=2),
            DeviceSpec.make("md", "multi-domain-sim", {"n_cores": 8},
                            frequencies=["core:600", "core:1500",
                                         "uncore:300"]),
            DeviceSpec.make("ps", "pstate-sim", {"n_cores": 6},
                            frequencies=["ecore:600", "pcore:600",
                                         "pcore:3204"]),
        ),
        measures=(_fast_measure(),))
    run_campaign(spec, ArtifactStore(str(tmp_path)))
    camp = ArtifactStore(str(tmp_path)).open(spec)
    doc = aggregate.report_dict(camp)
    assert doc["units_done"] == 3
    assert aggregate.campaign_has_domains(camp)
    units = {r["unit"] for r in doc["comparison"] if r.get("n_pairs")}
    assert units == {"rtx@fast", "md@fast", "ps@fast"}
    transitions = {(r["unit"], r["transition"]) for r in doc["domains"]}
    assert ("md@fast", "core->uncore") in transitions
    assert ("ps@fast", "ecore->pcore") in transitions
    assert ("rtx@fast", "core") in transitions         # bare = implicit core
    md = aggregate.report_markdown(camp)
    assert "## Latency by transition class (domain breakdown)" in md


def test_single_domain_campaign_report_has_no_domain_section(tmp_path):
    spec = CampaignSpec(
        name="plain",
        devices=(DeviceSpec.make("rtx", "vmapped-sim",
                                 {"kind": "rtx6000", "n_cores": 6},
                                 n_freqs=2),),
        measures=(_fast_measure(),))
    run_campaign(spec, ArtifactStore(str(tmp_path)))
    camp = ArtifactStore(str(tmp_path)).open(spec)
    assert not aggregate.campaign_has_domains(camp)
    assert "domains" not in aggregate.report_dict(camp)
    assert "transition class" not in aggregate.report_markdown(camp)


def test_spec_spellings_share_campaign_id():
    """Tuple and string operating-point spellings canonicalize to the
    same DeviceSpec, so equivalent specs share artifacts."""
    a = DeviceSpec.make("md", "multi-domain-sim",
                        frequencies=["core:600", "uncore:300"])
    b = DeviceSpec.make("md", "multi-domain-sim",
                        frequencies=[("core", 600), ("uncore", 300.0)])
    assert a == b
    sa = CampaignSpec(name="x", devices=(a,))
    sb = CampaignSpec(name="x", devices=(b,))
    assert sa.campaign_id() == sb.campaign_id()
    # and the canonical JSON round-trips through from_dict
    import json
    rt = CampaignSpec.from_dict(json.loads(sa.canonical_json()))
    assert rt.campaign_id() == sa.campaign_id()


def test_bare_spec_canonical_json_unchanged():
    """Bare-MHz specs keep numeric frequencies in canonical JSON — the
    campaign_id of every pre-domain spec is stable."""
    d = DeviceSpec.make("rtx", "vmapped-sim", frequencies=[210.0, 1410.0])
    assert d.to_dict()["frequencies"] == [210.0, 1410.0]


def test_mixed_spec_rejects_bare_mhz_on_domain_device():
    d = DeviceSpec.make("md", "multi-domain-sim",
                        frequencies=[600.0, "uncore:300"])
    dev = create_backend("multi-domain-sim")
    with pytest.raises(ValueError, match=r"domains \['core', 'uncore'\]"):
        d.resolve_frequencies(dev)


def test_spec_rejects_unknown_domain_at_make_time():
    with pytest.raises(ValueError, match="bad frequency spec"):
        DeviceSpec.make("md", "multi-domain-sim",
                        frequencies=["fabric:600"])
