"""MoE dispatch: shard_map EP path == local path; capacity dropping."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.mesh import make_smoke_mesh
from repro.models import moe as moe_mod
from repro.models.moe import _capacity, _dispatch_compute_combine
from repro.parallel.sharding import make_env


def test_shardmap_equals_local_path():
    cfg = get_config("deepseek-moe-16b", smoke=True)
    key = jax.random.PRNGKey(0)
    p, _ = moe_mod.moe_init(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          cfg.compute_dtype)
    env_local = make_env(cfg, None)
    out_local, aux_local = moe_mod.moe_apply(p, x, cfg, env_local)
    # 1x1 mesh exercises the shard_map code path with identical semantics
    env_mesh = make_env(cfg, make_smoke_mesh())
    out_mesh, aux_mesh = moe_mod.moe_apply(p, x, cfg, env_mesh)
    np.testing.assert_allclose(np.asarray(out_local, np.float32),
                               np.asarray(out_mesh, np.float32),
                               atol=2e-2, rtol=2e-2)
    np.testing.assert_allclose(float(aux_local), float(aux_mesh), rtol=1e-4)


def test_dispatch_respects_capacity():
    t, d, e, k, c = 32, 8, 4, 2, 3
    ids = jnp.zeros((t, k), jnp.int32)          # everyone wants expert 0
    gate = jnp.ones((t, k), jnp.float32) / k
    xt = jnp.ones((t, d), jnp.float32)
    wg = jnp.ones((e, d, 16)) * 0.01
    wu = jnp.ones((e, d, 16)) * 0.01
    wd = jnp.ones((e, 16, d)) * 0.01
    out = _dispatch_compute_combine(xt, gate, ids, wg, wu, wd, e0=0,
                                    n_experts=e, capacity=c,
                                    compute_dtype=jnp.float32)
    nonzero_rows = int((jnp.abs(out).sum(-1) > 0).sum())
    # only `capacity` slots exist for expert 0; with k=2 identical choices a
    # token can occupy two slots, so at most c rows are non-zero
    assert nonzero_rows <= c


def test_aux_loss_uniform_routing_is_one():
    """Switch aux loss equals ~1.0 under perfectly uniform routing."""
    cfg = get_config("deepseek-moe-16b", smoke=True)
    m = cfg.moe
    t = 512
    rng = np.random.default_rng(0)
    probs = np.full((t, m.n_routed), 1.0 / m.n_routed)
    ids = rng.integers(0, m.n_routed, (t, m.top_k))
    me = probs.mean(axis=0)
    load = np.bincount(ids.ravel(), minlength=m.n_routed) / (t * m.top_k)
    aux = m.n_routed * np.sum(me * load)
    assert abs(aux - 1.0) < 0.05


def test_capacity_formula():
    cfg = get_config("deepseek-v2-236b")
    m = cfg.moe
    c = _capacity(m, 65536)
    assert c == int(np.ceil(m.top_k * 65536 * m.capacity_factor / m.n_routed))
