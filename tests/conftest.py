import os
import sys

# src layout import without install; single CPU device (the dry-run sets its
# own 512-device XLA flag in-process and must NOT leak here)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
