import os
import sys

# src layout import without install; single CPU device (the dry-run sets its
# own 512-device XLA flag in-process and must NOT leak here)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_enable_x64", False)


@pytest.fixture(autouse=True, scope="session")
def _results_under_tmp(tmp_path_factory):
    """Tests must never litter the working tree with results/ state: every
    default output path goes through repro.core.paths.results_dir, which
    honors REPRO_RESULTS_DIR — point it at a session tmp dir unless the
    caller already pinned it."""
    if "REPRO_RESULTS_DIR" not in os.environ:
        os.environ["REPRO_RESULTS_DIR"] = str(
            tmp_path_factory.mktemp("results"))
    yield
