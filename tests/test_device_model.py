"""Simulator invariants: frequency scaling, monotonic timestamps, wake-up,
throttle flags, ground-truth bookkeeping."""
import numpy as np
import pytest

from repro.dvfs import make_device


def test_iteration_time_scales_inverse_frequency():
    dev = make_device("a100", seed=0, n_cores=8)
    fmax = max(dev.cfg.frequencies)
    fhalf = dev.cfg.frequencies[len(dev.cfg.frequencies) // 2]
    out = {}
    for f in (fmax, fhalf):
        dev.set_frequency(f)
        dev.usleep(0.5)                      # let the transition finish
        dev.run_kernel(64, 40e-6)            # wake-up burn
        data = dev.run_kernel(256, 40e-6)
        out[f] = np.diff(data, axis=-1).mean()
    ratio = out[fhalf] / out[fmax]
    assert ratio == pytest.approx(fmax / fhalf, rel=0.05)


def test_timestamps_monotonic_and_quantized():
    dev = make_device("gh200", seed=1, n_cores=4)
    data = dev.run_kernel(128, 40e-6)
    starts, ends = data[..., 0], data[..., 1]
    assert (ends >= starts).all()
    assert (starts[:, 1:] >= ends[:, :-1] - 1e-9).all()
    q = dev.cfg.timer_resolution_s
    assert np.allclose(data / q, np.round(data / q), atol=1e-6)


def test_ground_truth_history_records_transitions():
    dev = make_device("a100", seed=2, n_cores=4)
    f1, f2 = dev.cfg.frequencies[0], dev.cfg.frequencies[-1]
    dev.set_frequency(f1)
    dev.set_frequency(f2)
    assert len(dev.history) == 2
    assert dev.history[1]["from"] == f1 and dev.history[1]["to"] == f2
    assert dev.history[1]["true_latency"] > 0


def test_asymmetry_a100():
    """Model calibration: decreases must be faster than increases (Fig. 4)."""
    dev = make_device("a100", seed=3, n_cores=4)
    rng = np.random.default_rng(0)
    lo, hi = dev.cfg.frequencies[2], dev.cfg.frequencies[-2]
    down = [dev.model.sample_latency(hi, lo, rng) for _ in range(50)]
    up = [dev.model.sample_latency(lo, hi, rng) for _ in range(50)]
    assert np.mean(down) < np.mean(up)


def test_gh200_target_dominates():
    """Row pattern (Fig. 3): latency variance across inits << across targets."""
    dev = make_device("gh200", seed=4)
    fs = dev.cfg.frequencies[:: len(dev.cfg.frequencies) // 8][:8]
    by_target = [np.mean([dev.model.base_latency(fi, ft) for fi in fs])
                 for ft in fs]
    by_init = [np.mean([dev.model.base_latency(fi, ft) for ft in fs])
               for fi in fs]
    assert np.std(by_target) > 3 * np.std(by_init)


def test_unsupported_frequency_rejected():
    dev = make_device("a100", n_cores=2)
    with pytest.raises(ValueError):
        dev.set_frequency(123.456)


def test_thermal_throttle_flags():
    dev = make_device("a100", seed=5, n_cores=2, thermal_throttle_prob=1.0)
    dev.run_kernel(32, 40e-6)
    assert "thermal" in dev.throttle_reasons()
    assert dev.throttle_reasons() == set()      # flags are consumed
