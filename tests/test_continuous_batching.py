"""Continuous batching: admission, completion, slot reuse."""
import jax

from repro.configs import get_config
from repro.configs.registry import model_module
from repro.parallel.sharding import make_env
from repro.runtime.continuous_batching import ContinuousBatcher, Request


def _setup(slots=2, ctx=16, max_len=96):
    cfg = get_config("llama3-8b", smoke=True)
    env = make_env(cfg, None)
    mod = model_module(cfg)
    params, _ = mod.init(jax.random.PRNGKey(0), cfg)
    cb = ContinuousBatcher(cfg, env, params, slots=slots, max_len=max_len,
                           ctx_len=ctx)
    return cfg, cb


def _reqs(cfg, n, ctx=16, max_new=6):
    k = jax.random.PRNGKey(1)
    return [Request(i, jax.random.randint(jax.random.fold_in(k, i), (ctx,),
                                          0, cfg.vocab), max_new)
            for i in range(n)]


def test_all_requests_complete():
    cfg, cb = _setup(slots=2)
    reqs = _reqs(cfg, 5)
    stats = cb.run(reqs)
    assert stats.completed == 5
    assert all(r.done and len(r.generated) == 6 for r in reqs)
    assert all(0 <= t < cfg.vocab for r in reqs for t in r.generated)


def test_more_requests_than_slots_reuses_slots():
    cfg, cb = _setup(slots=2)
    reqs = _reqs(cfg, 6, max_new=4)
    stats = cb.run(reqs)
    assert stats.completed == 6
    assert stats.admitted == 6
    # 6 requests x 4 tokens over 2 slots needs >= 12 decode steps
    assert stats.steps >= 12
    assert stats.slot_busy_fraction > 0.5
