"""Statistics layer: CI/t-test/RSE properties + the paper's 2-sigma-vs-2-SE
insight (§V-A) reproduced quantitatively."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests run when installed
from hypothesis import given, settings, strategies as st

from repro.core import stats


def test_mean_std_basic():
    s = stats.mean_std(np.array([1.0, 2.0, 3.0]), freq_mhz=100)
    assert s.mean == pytest.approx(2.0)
    assert s.n == 3
    assert s.se == pytest.approx(s.std / np.sqrt(3))


def test_two_se_band_fails_at_accelerator_scale():
    """Paper §V-A: at n ~ 1e7 the SE band shrinks below the timer resolution
    so almost no iteration lands inside it; the 2-sigma band keeps ~95%."""
    rng = np.random.default_rng(0)
    timer_res = 1e-6
    mean, sigma = 40e-6, 1.0e-6           # 40 us iterations, 1 us jitter
    big = rng.normal(mean, sigma, 2_000_000)
    big = np.round(big / timer_res) * timer_res          # timer quantization
    s = stats.mean_std(big)
    lo_se, hi_se = stats.two_se_band(s)
    lo_sg, hi_sg = stats.two_sigma_band(s)
    frac_se = np.mean((big >= lo_se) & (big <= hi_se))
    frac_sg = np.mean((big >= lo_sg) & (big <= hi_sg))
    assert hi_se - lo_se < timer_res          # band below timer resolution
    assert frac_se < 0.45                     # detection starves
    assert frac_sg > 0.90                     # population band works


def test_ci_excludes_zero_distinguishable():
    rng = np.random.default_rng(1)
    a = stats.mean_std(rng.normal(10.0, 0.1, 1000))
    b = stats.mean_std(rng.normal(10.5, 0.1, 1000))
    assert stats.ci_excludes_zero(a, b)
    c = stats.mean_std(rng.normal(10.0, 0.1, 1000))
    assert not stats.ci_excludes_zero(a, c)


def test_null_hypothesis_tolerance():
    a = stats.FreqStats(0, 1.00, 0.001, 10)
    b = stats.FreqStats(0, 1.001, 0.001, 10)
    assert stats.null_hypothesis_holds(a, b, tol=0.01)
    c = stats.FreqStats(0, 2.0, 0.001, 1000)
    assert not stats.null_hypothesis_holds(a, c, tol=0.01)


@given(st.lists(st.floats(1e-6, 1e-2), min_size=3, max_size=200),
       st.floats(1.5, 3.0))
@settings(max_examples=50, deadline=None)
def test_two_sigma_band_contains_mean(vals, k):
    s = stats.mean_std(np.array(vals))
    lo, hi = stats.two_sigma_band(s, k)
    assert lo <= s.mean <= hi


@given(st.integers(10, 5000))
@settings(max_examples=30, deadline=None)
def test_rse_shrinks_with_n(n):
    rng = np.random.default_rng(42)
    x = rng.normal(1.0, 0.1, n)
    assert stats.rse(x) < stats.rse(x[: max(3, n // 4)]) * 2.5


@given(st.floats(0.1, 10), st.floats(0.001, 0.1), st.integers(50, 500))
@settings(max_examples=30, deadline=None)
def test_welch_symmetry(mu, sigma, n):
    rng = np.random.default_rng(7)
    a = stats.mean_std(rng.normal(mu, sigma, n))
    b = stats.mean_std(rng.normal(mu * 1.5, sigma, n))
    assert stats.welch_t_test(a, b) == pytest.approx(-stats.welch_t_test(b, a))


@given(st.lists(st.floats(1e-6, 1e-2), min_size=2, max_size=64),
       st.data())
@settings(max_examples=100, deadline=None)
def test_running_stats_matches_recompute_through_removals(vals, data):
    """The monitor's sliding window leans on RunningStats.remove: after any
    interleaving of adds and removals the O(1) accumulator must agree with
    a from-scratch recompute over the surviving samples to 1e-12 relative
    (the shifted-sums design exists precisely so near-constant latency
    windows don't cancel catastrophically)."""
    rs = stats.RunningStats()
    window = []
    for v in vals:
        rs.add(v)
        window.append(v)
        if len(window) > 1 and data.draw(st.booleans()):
            victim = window.pop(data.draw(
                st.integers(0, len(window) - 1)))
            rs.remove(victim)
        if not window:
            continue
        arr = np.asarray(window)
        mean = arr.mean()
        assert rs.n == len(window)
        assert rs.mean == pytest.approx(mean, rel=1e-12, abs=1e-300)
        if len(window) >= 2:
            std = arr.std(ddof=1)
            assert rs.std == pytest.approx(std, rel=1e-12, abs=1e-12)
            if mean != 0:
                assert rs.rse() == pytest.approx(
                    std / np.sqrt(len(window)) / abs(mean),
                    rel=1e-12, abs=1e-12)
