"""The cluster layer's retry/backoff policy and store write semantics.

Property tests (hypothesis, when installed) pin the policy contract:
the raw backoff schedule is monotone non-decreasing and capped, total
wait is bounded by ``max_attempts * cap_s``, jittered waits are
deterministic under a fixed seed and always land in
``[raw * (1 - jitter), raw]``.  Concurrent duplicate writers against
the content-addressed store produce exactly one artifact, bit-identical,
with no torn files — the property that makes speculative duplicate
uploads and re-delivered RPCs safe.  The deterministic tests below the
property section enforce the same contract pointwise, so the guarantees
hold even where hypothesis is absent.
"""
import os
import threading

import pytest

from repro.campaign.cluster.remote_store import blob_digest, file_digest
from repro.campaign.cluster.retry import (DeadLetterFile, RetriesExhausted,
                                          RetryPolicy, StoreWriteError,
                                          TransportError, TransportTimeout,
                                          call_with_retry)


# ------------------------------------------------------------------ #
# properties (run when hypothesis is installed)
# ------------------------------------------------------------------ #
def _policies(st):
    return st.builds(
        RetryPolicy,
        max_attempts=st.integers(min_value=1, max_value=12),
        base_s=st.floats(min_value=0.0, max_value=1.0, allow_nan=False),
        cap_s=st.floats(min_value=0.0, max_value=5.0, allow_nan=False),
        jitter=st.floats(min_value=0.0, max_value=1.0, allow_nan=False),
        seed=st.integers(min_value=0, max_value=2**31))


def test_backoff_schedule_properties():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @given(_policies(st))
    @settings(max_examples=80, deadline=None)
    def prop(policy):
        # monotone non-decreasing, capped, and totalling within bound
        waits = [policy.raw_backoff_s(k) for k in range(policy.max_attempts)]
        assert all(b >= a for a, b in zip(waits, waits[1:]))
        assert all(0.0 <= w <= policy.cap_s for w in waits)
        total = sum(waits[:-1]) if waits else 0.0
        assert total == policy.total_backoff_bound_s()
        assert total <= policy.max_attempts * policy.cap_s

    prop()


def test_jittered_backoff_deterministic_and_in_band_property():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @given(_policies(st), st.text(max_size=20), st.integers(0, 11))
    @settings(max_examples=80, deadline=None)
    def prop(policy, op_key, k):
        w1 = policy.backoff_s(k, op_key)
        # deterministic under a fixed seed: a rebuilt policy with the
        # same fields lands on the same wait
        clone = RetryPolicy(**{f: getattr(policy, f) for f in
                               ("max_attempts", "base_s", "cap_s",
                                "jitter", "timeout_s", "seed")})
        assert clone.backoff_s(k, op_key) == w1
        raw = policy.raw_backoff_s(k)
        assert raw * (1.0 - policy.jitter) <= w1 <= raw

    prop()


# ------------------------------------------------------------------ #
# the same contract, pointwise (no hypothesis needed)
# ------------------------------------------------------------------ #
def test_backoff_schedule_pointwise():
    p = RetryPolicy(max_attempts=6, base_s=0.05, cap_s=0.4, jitter=0.0)
    waits = [p.raw_backoff_s(k) for k in range(6)]
    assert waits == [0.05, 0.1, 0.2, 0.4, 0.4, 0.4]
    assert p.total_backoff_bound_s() == sum(waits[:-1])
    # jitter=0: the jittered wait IS the raw wait
    assert p.backoff_s(3, "op") == 0.4


def test_jitter_band_and_determinism_pointwise():
    p = RetryPolicy(base_s=0.1, cap_s=10.0, jitter=0.5, seed=42)
    for k in range(6):
        raw = p.raw_backoff_s(k)
        w = p.backoff_s(k, "store.put:u0")
        assert raw * 0.5 <= w <= raw
        assert w == p.backoff_s(k, "store.put:u0")     # bit-reproducible
    # different op keys decorrelate (retry convoys spread out)
    ws = {p.backoff_s(3, f"op{i}") for i in range(8)}
    assert len(ws) > 1


def test_policy_validation():
    with pytest.raises(ValueError):
        RetryPolicy(max_attempts=0)
    with pytest.raises(ValueError):
        RetryPolicy(jitter=1.5)
    with pytest.raises(ValueError):
        RetryPolicy(base_s=-1.0)
    with pytest.raises(ValueError):
        RetryPolicy().raw_backoff_s(-1)


# ------------------------------------------------------------------ #
# call_with_retry semantics
# ------------------------------------------------------------------ #
def _policy(n=4):
    return RetryPolicy(max_attempts=n, base_s=0.001, cap_s=0.002)


def test_call_with_retry_succeeds_after_transient_failures():
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise TransportTimeout("flap")
        return "ok"

    waits = []
    assert call_with_retry(flaky, _policy(), sleep=waits.append) == "ok"
    assert len(calls) == 3
    assert len(waits) == 2 and all(w > 0 for w in waits)


def test_call_with_retry_dead_letters_on_exhaustion(tmp_path):
    dl = DeadLetterFile(str(tmp_path / "dead.jsonl"), clock=lambda: 42.0)

    def always():
        raise StoreWriteError("store down")

    with pytest.raises(RetriesExhausted) as exc:
        call_with_retry(always, _policy(3), op="store.put", op_key="u0",
                        dead_letters=dl, sleep=lambda s: None)
    assert exc.value.attempts == 3
    assert isinstance(exc.value.last, StoreWriteError)
    assert len(dl) == 1
    (doc,) = dl.records()
    assert doc["op"] == "store.put" and doc["key"] == "u0"
    assert doc["attempts"] == 3 and "store down" in doc["error"]
    assert doc["t"] == 42.0


def test_call_with_retry_propagates_non_retryable_immediately():
    calls = []

    def bug():
        calls.append(1)
        raise ValueError("a bug, not a flake")

    with pytest.raises(ValueError):
        call_with_retry(bug, _policy(), sleep=lambda s: None)
    assert len(calls) == 1


def test_retries_exhausted_is_not_retryable():
    """An outer retry loop must never resurrect a spent operation."""
    from repro.campaign.cluster.retry import RetryableError
    assert not issubclass(RetriesExhausted, RetryableError)
    assert issubclass(TransportTimeout, TransportError)
    assert issubclass(TransportError, RetryableError)


# ------------------------------------------------------------------ #
# concurrent duplicate store writers
# ------------------------------------------------------------------ #
def _server(tmp_path, name="dup"):
    from repro.campaign import ArtifactStore, CampaignSpec, DeviceSpec
    from repro.campaign.cluster.remote_store import StoreServer
    spec = CampaignSpec(name, devices=(DeviceSpec.make("d0"),))
    campaign = ArtifactStore(str(tmp_path / "store")).open(spec)
    return StoreServer(campaign), campaign


@pytest.mark.parametrize("n_writers", [2, 6])
def test_concurrent_duplicate_writers_one_bit_identical_artifact(
        tmp_path, n_writers):
    """N threads racing identical content-addressed writes of the same
    relpath: every write lands (stored or deduped), exactly one file
    exists afterwards, its bytes are exactly the payload (never torn),
    and the store digests it identically to the source."""
    server, campaign = _server(tmp_path)
    data = os.urandom(512)
    digest = blob_digest(data)
    relpath = "units/d0@default/table/race.bin"
    results, errors = [], []
    barrier = threading.Barrier(n_writers)

    def write():
        try:
            barrier.wait()
            results.append(server.put_file(relpath, data, digest))
        except Exception as exc:  # noqa: BLE001 — collected for assert
            errors.append(exc)

    threads = [threading.Thread(target=write) for _ in range(n_writers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert len(results) == n_writers
    assert set(results) <= {"stored", "deduped"}
    path = os.path.join(campaign.dir, relpath)
    with open(path, "rb") as f:
        assert f.read() == data
    assert file_digest(path) == digest
    assert server.list_files("units/d0@default") == {relpath: digest}
    # no tmp debris from the atomic write-then-rename dance
    d = os.path.dirname(path)
    assert [n for n in os.listdir(d) if ".tmp" in n] == []


def test_concurrent_duplicate_writers_property(tmp_path):
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    server, campaign = _server(tmp_path, name="prop")
    rounds = [0]

    @given(st.binary(min_size=1, max_size=256), st.integers(2, 6))
    @settings(max_examples=15, deadline=None)
    def prop(data, n_writers):
        rounds[0] += 1
        relpath = f"units/d0@default/table/r{rounds[0]}.bin"
        digest = blob_digest(data)
        barrier = threading.Barrier(n_writers)
        results, errors = [], []

        def write():
            try:
                barrier.wait()
                results.append(server.put_file(relpath, data, digest))
            except Exception as exc:  # noqa: BLE001
                errors.append(exc)

        threads = [threading.Thread(target=write)
                   for _ in range(n_writers)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errors and len(results) == n_writers
        path = os.path.join(campaign.dir, relpath)
        with open(path, "rb") as f:
            assert f.read() == data
        assert server.list_files("units/d0@default")[relpath] == digest

    prop()


def test_put_file_rejects_corrupt_payload_without_retry(tmp_path):
    """A digest mismatch is a protocol error (corruption in flight), not
    a flake: it must raise a NON-retryable error before touching disk."""
    server, campaign = _server(tmp_path)
    good = b"payload"
    with pytest.raises(ValueError, match="digest"):
        server.put_file("units/d0@default/table/x.bin", b"corrupted",
                        blob_digest(good))
    assert not os.path.exists(
        os.path.join(campaign.dir, "units/d0@default/table/x.bin"))


def test_store_server_rejects_path_escape(tmp_path):
    server, _ = _server(tmp_path)
    for bad in ("../outside", "/etc/passwd", "units/../../x"):
        with pytest.raises(ValueError):
            server.put_file(bad, b"x", blob_digest(b"x"))
