"""Streaming drift detection: sequential triggers (CUSUM, Page-Hinkley),
the batch-rule confirm gate, cooldown, and the unpowered-baseline delta
floor.  Synthetic streams only — service/ingest integration lives in
test_monitor_service.py / test_monitor_ingest.py."""
import numpy as np

from repro.core.latency_table import analyse_pair
from repro.core.stats import Cusum, PageHinkley
from repro.monitor import DriftConfig, PairMonitor

BASE_MEAN, BASE_STD = 15e-3, 0.4e-3


def _baseline(n=24, seed=0):
    rng = np.random.default_rng(seed)
    pr = analyse_pair(705.0, 210.0, rng.normal(BASE_MEAN, BASE_STD, n),
                      with_silhouette=False)
    assert pr.status == "ok" and pr.clean.size
    return pr


def _monitor(baseline=None, **cfg_kw):
    return PairMonitor("u0@fast", 705.0, 210.0,
                       baseline if baseline is not None else _baseline(),
                       DriftConfig(**cfg_kw))


# ------------------------------------------------------------------ #
# detectors
# ------------------------------------------------------------------ #
def test_cusum_quiet_on_stationary_trips_on_shift():
    rng = np.random.default_rng(2)
    c = Cusum(k=0.5, h=5.0)
    for z in rng.normal(0.0, 1.0, 300):
        c.update(z)
        assert not c.tripped
    # sustained 1.5-sigma shift: excess over the allowance is 1.0/sample,
    # so the statistic crosses h=5 within a handful of samples
    steps = 0
    while not c.tripped:
        c.update(1.5)
        steps += 1
    assert steps <= 8
    c.reset()
    assert c.score == 0.0 and not c.tripped


def test_cusum_is_two_sided():
    c = Cusum(k=0.5, h=5.0)
    for _ in range(10):
        c.update(-1.5)                    # latency IMPROVED — still drift
    assert c.tripped


def test_page_hinkley_catches_mean_shift_after_history():
    """PH self-centers on the stream's running mean, so it fires on a
    level change the history makes visible (the shape CUSUM's fixed
    allowance can blur on slow ramps)."""
    ph = PageHinkley(delta=0.05, lam=5.0)
    for _ in range(30):
        ph.update(0.0)
    assert not ph.tripped
    for _ in range(30):
        ph.update(1.0)
    assert ph.tripped


# ------------------------------------------------------------------ #
# PairMonitor: confirm gate + lifecycle
# ------------------------------------------------------------------ #
def test_stationary_stream_never_alerts():
    mon = _monitor()
    rng = np.random.default_rng(5)
    for i, v in enumerate(rng.normal(BASE_MEAN, BASE_STD, 80)):
        assert mon.observe(float(v), t_stream=float(i)) is None
    assert mon.n_seen == 80


def test_shift_detected_within_budget_with_batch_backed_verdict():
    mon = _monitor()
    rng = np.random.default_rng(6)
    event = None
    for i, v in enumerate(rng.normal(3 * BASE_MEAN, BASE_STD, 16)):
        event = mon.observe(float(v), t_stream=10.0 + i)
        if event is not None:
            break
    assert event is not None, "3x shift never confirmed"
    assert event.sample_index <= 8        # the documented budget
    assert event.unit_key == "u0@fast"
    assert (event.f_init, event.f_target) == (705.0, 210.0)
    assert event.t_stream == 10.0 + event.sample_index - 1
    # the confirming verdict is the batch rule's own object: flagged,
    # test-backed (powered on both sides), with the right magnitude
    assert event.drift.flagged
    assert event.drift.p_value == event.drift.p_value        # ran, not NaN
    assert event.drift.rel_delta > 1.0
    assert len(event.window_clean) >= DriftConfig().diff.min_samples
    assert event.baseline_n == mon.baseline.clean.size


def test_cooldown_suppresses_then_rearms():
    cfg_cooldown = 6
    mon = _monitor(cooldown=cfg_cooldown)
    rng = np.random.default_rng(7)
    shifted = rng.normal(3 * BASE_MEAN, BASE_STD, 60)
    events = [i for i, v in enumerate(shifted)
              if mon.observe(float(v)) is not None]
    assert len(events) >= 2, "monitor never re-armed after cooldown"
    # the reset window keeps refilling during the cooldown, so the
    # earliest legal re-alert is cooldown + 1 samples after the last one
    gap = events[1] - events[0]
    assert gap > cfg_cooldown


def test_window_eviction_keeps_detection_alive():
    """A long stationary prefix must not blind the monitor: the sliding
    window evicts old samples, so a late shift still confirms."""
    mon = _monitor(window=16)
    rng = np.random.default_rng(8)
    for v in rng.normal(BASE_MEAN, BASE_STD, 100):
        assert mon.observe(float(v)) is None
    event = None
    for v in rng.normal(3 * BASE_MEAN, BASE_STD, 32):
        event = mon.observe(float(v))
        if event is not None:
            break
    assert event is not None
    assert len(event.window) <= 16


def test_unpowered_baseline_needs_the_wide_delta_floor():
    """With a baseline too small for the Mann-Whitney test the batch rule
    lets the 20% delta decide alone; the monitor demands the much wider
    unpowered_delta margin before paging anyone."""
    small = analyse_pair(
        705.0, 210.0,
        np.random.default_rng(9).normal(BASE_MEAN, BASE_STD, 3),
        with_silhouette=False)
    assert small.clean.size < DriftConfig().diff.min_samples
    rng = np.random.default_rng(10)

    mod = _monitor(baseline=small)
    for v in rng.normal(1.4 * BASE_MEAN, BASE_STD, 40):
        assert mod.observe(float(v)) is None, (
            "a +40% shift on an untestable baseline must not alert")

    big = _monitor(baseline=small)
    event = None
    for v in rng.normal(3 * BASE_MEAN, BASE_STD, 16):
        event = big.observe(float(v))
        if event is not None:
            break
    assert event is not None, "a 3x shift must clear the delta floor"
    assert event.drift.p_value != event.drift.p_value        # NaN: no test
    assert abs(event.drift.rel_delta) > DriftConfig().unpowered_delta
