"""Streaming drift detection: sequential triggers (CUSUM, Page-Hinkley),
the batch-rule confirm gate, cooldown, the unpowered-baseline delta
floor, and the slow-ramp / per-direction drift shapes the FaultPlan can
inject.  Synthetic streams only — service/ingest integration lives in
test_monitor_service.py / test_monitor_ingest.py."""
import numpy as np
import pytest

from repro.campaign.regression import DiffConfig as PairDiffConfig
from repro.core.latency_table import analyse_pair
from repro.core.stats import Cusum, PageHinkley
from repro.dvfs.transition_models import (ShiftedTransitionModel,
                                          TransitionModel)
from repro.monitor import DriftConfig, PairMonitor

BASE_MEAN, BASE_STD = 15e-3, 0.4e-3


def _baseline(n=24, seed=0):
    rng = np.random.default_rng(seed)
    pr = analyse_pair(705.0, 210.0, rng.normal(BASE_MEAN, BASE_STD, n),
                      with_silhouette=False)
    assert pr.status == "ok" and pr.clean.size
    return pr


def _monitor(baseline=None, **cfg_kw):
    return PairMonitor("u0@fast", 705.0, 210.0,
                       baseline if baseline is not None else _baseline(),
                       DriftConfig(**cfg_kw))


# ------------------------------------------------------------------ #
# detectors
# ------------------------------------------------------------------ #
def test_cusum_quiet_on_stationary_trips_on_shift():
    rng = np.random.default_rng(2)
    c = Cusum(k=0.5, h=5.0)
    for z in rng.normal(0.0, 1.0, 300):
        c.update(z)
        assert not c.tripped
    # sustained 1.5-sigma shift: excess over the allowance is 1.0/sample,
    # so the statistic crosses h=5 within a handful of samples
    steps = 0
    while not c.tripped:
        c.update(1.5)
        steps += 1
    assert steps <= 8
    c.reset()
    assert c.score == 0.0 and not c.tripped


def test_cusum_is_two_sided():
    c = Cusum(k=0.5, h=5.0)
    for _ in range(10):
        c.update(-1.5)                    # latency IMPROVED — still drift
    assert c.tripped


def test_page_hinkley_catches_mean_shift_after_history():
    """PH self-centers on the stream's running mean, so it fires on a
    level change the history makes visible (the shape CUSUM's fixed
    allowance can blur on slow ramps)."""
    ph = PageHinkley(delta=0.05, lam=5.0)
    for _ in range(30):
        ph.update(0.0)
    assert not ph.tripped
    for _ in range(30):
        ph.update(1.0)
    assert ph.tripped


# ------------------------------------------------------------------ #
# PairMonitor: confirm gate + lifecycle
# ------------------------------------------------------------------ #
def test_stationary_stream_never_alerts():
    mon = _monitor()
    rng = np.random.default_rng(5)
    for i, v in enumerate(rng.normal(BASE_MEAN, BASE_STD, 80)):
        assert mon.observe(float(v), t_stream=float(i)) is None
    assert mon.n_seen == 80


def test_shift_detected_within_budget_with_batch_backed_verdict():
    mon = _monitor()
    rng = np.random.default_rng(6)
    event = None
    for i, v in enumerate(rng.normal(3 * BASE_MEAN, BASE_STD, 16)):
        event = mon.observe(float(v), t_stream=10.0 + i)
        if event is not None:
            break
    assert event is not None, "3x shift never confirmed"
    assert event.sample_index <= 8        # the documented budget
    assert event.unit_key == "u0@fast"
    assert (event.f_init, event.f_target) == (705.0, 210.0)
    assert event.t_stream == 10.0 + event.sample_index - 1
    # the confirming verdict is the batch rule's own object: flagged,
    # test-backed (powered on both sides), with the right magnitude
    assert event.drift.flagged
    assert event.drift.p_value == event.drift.p_value        # ran, not NaN
    assert event.drift.rel_delta > 1.0
    assert len(event.window_clean) >= DriftConfig().diff.min_samples
    assert event.baseline_n == mon.baseline.clean.size


def test_cooldown_suppresses_then_rearms():
    cfg_cooldown = 6
    mon = _monitor(cooldown=cfg_cooldown)
    rng = np.random.default_rng(7)
    shifted = rng.normal(3 * BASE_MEAN, BASE_STD, 60)
    events = [i for i, v in enumerate(shifted)
              if mon.observe(float(v)) is not None]
    assert len(events) >= 2, "monitor never re-armed after cooldown"
    # the reset window keeps refilling during the cooldown, so the
    # earliest legal re-alert is cooldown + 1 samples after the last one
    gap = events[1] - events[0]
    assert gap > cfg_cooldown


def test_window_eviction_keeps_detection_alive():
    """A long stationary prefix must not blind the monitor: the sliding
    window evicts old samples, so a late shift still confirms."""
    mon = _monitor(window=16)
    rng = np.random.default_rng(8)
    for v in rng.normal(BASE_MEAN, BASE_STD, 100):
        assert mon.observe(float(v)) is None
    event = None
    for v in rng.normal(3 * BASE_MEAN, BASE_STD, 32):
        event = mon.observe(float(v))
        if event is not None:
            break
    assert event is not None
    assert len(event.window) <= 16


def test_unpowered_baseline_needs_the_wide_delta_floor():
    """With a baseline too small for the Mann-Whitney test the batch rule
    lets the 20% delta decide alone; the monitor demands the much wider
    unpowered_delta margin before paging anyone."""
    small = analyse_pair(
        705.0, 210.0,
        np.random.default_rng(9).normal(BASE_MEAN, BASE_STD, 3),
        with_silhouette=False)
    assert small.clean.size < DriftConfig().diff.min_samples
    rng = np.random.default_rng(10)

    mod = _monitor(baseline=small)
    for v in rng.normal(1.4 * BASE_MEAN, BASE_STD, 40):
        assert mod.observe(float(v)) is None, (
            "a +40% shift on an untestable baseline must not alert")

    big = _monitor(baseline=small)
    event = None
    for v in rng.normal(3 * BASE_MEAN, BASE_STD, 16):
        event = big.observe(float(v))
        if event is not None:
            break
    assert event is not None, "a 3x shift must clear the delta floor"
    assert event.drift.p_value != event.drift.p_value        # NaN: no test
    assert abs(event.drift.rel_delta) > DriftConfig().unpowered_delta


# ------------------------------------------------------------------ #
# slow-ramp drift: Page-Hinkley's target shape
# ------------------------------------------------------------------ #
# A creep this slow never hands CUSUM a per-sample excess over its
# allowance, but PH's self-centered statistic accumulates the trend.
# The baseline is near-degenerate (jitter far below the sigma floor) so
# the monitor standardizes against the floor and the batch rule can flag
# a ~1.2% worst-case delta — i.e. the confirm gate is satisfiable while
# the window is still inside CUSUM's blind spot.
RAMP_SLOPE_SIGMA = 0.03              # z-units gained per sample
RAMP_THRESHOLD = 0.012               # batch-rule worst-case delta to flag


def _tight_baseline(n=24, seed=0, jitter=0.02e-3):
    rng = np.random.default_rng(seed)
    pr = analyse_pair(705.0, 210.0, rng.normal(BASE_MEAN, jitter, n),
                      with_silhouette=False)
    assert pr.status == "ok" and pr.clean.size
    return pr


def _ramp_monitor(**cfg_kw):
    cfg = DriftConfig(
        diff=PairDiffConfig(worst_delta_threshold=RAMP_THRESHOLD), **cfg_kw)
    return PairMonitor("u0@fast", 705.0, 210.0, _tight_baseline(), cfg)


def _drive_ramp(mon, n=250, seed=3, jitter=0.02e-3):
    """Feed a slow linear ramp; return the first DriftEvent (or None)."""
    sigma = DriftConfig().sigma_floor_frac * BASE_MEAN
    rng = np.random.default_rng(seed)
    for i in range(n):
        v = BASE_MEAN + RAMP_SLOPE_SIGMA * i * sigma \
            + rng.normal(0.0, jitter)
        event = mon.observe(float(v), t_stream=float(i))
        if event is not None:
            return event
    return None


def test_slow_ramp_page_hinkley_fires_before_cusum():
    """Detection-delay race on the same deterministic creep: a PH-only
    monitor confirms several samples before a CUSUM-only one, and the
    combined monitor's deciding event is PH's (its CUSUM statistic is
    still under threshold when the alert fires)."""
    ph_event = _drive_ramp(_ramp_monitor(cusum_h=float("inf")))
    cu_event = _drive_ramp(_ramp_monitor(ph_lambda=float("inf")))
    assert ph_event is not None and cu_event is not None
    assert ph_event.sample_index <= 40       # detection-delay budget
    delay_gap = cu_event.sample_index - ph_event.sample_index
    assert delay_gap >= 3, (
        f"PH should lead CUSUM on a slow ramp, gap={delay_gap}")

    event = _drive_ramp(_ramp_monitor())
    assert event is not None
    assert event.sample_index == ph_event.sample_index
    cfg = DriftConfig()
    assert event.ph_score >= cfg.ph_lambda           # PH tripped it ...
    assert event.cusum_score < cfg.cusum_h           # ... CUSUM had not
    assert event.drift.flagged
    assert abs(event.drift.rel_delta) > RAMP_THRESHOLD


def test_step_shift_still_beats_the_ramp_budget():
    """Sanity for the budget above: the same monitor confirms an abrupt
    3x step within a handful of samples, so the ramp test's 40-sample
    budget genuinely measures slow-creep delay, not monitor slack."""
    mon = _ramp_monitor()
    rng = np.random.default_rng(4)
    event = None
    for i, v in enumerate(rng.normal(3 * BASE_MEAN, 0.02e-3, 16)):
        event = mon.observe(float(v), t_stream=float(i))
        if event is not None:
            break
    assert event is not None and event.sample_index <= 8


# ------------------------------------------------------------------ #
# injected ramp + per-direction drift (FaultPlan's model wrapper)
# ------------------------------------------------------------------ #
class _FlatModel(TransitionModel):
    """Constant-latency inner model: the wrapper's ramp is the signal."""

    def base_latency(self, f_from, f_to):
        return BASE_MEAN

    def sample_latency(self, f_from, f_to, rng):
        return float(BASE_MEAN * (1.0 + rng.normal(0.0, 0.00133)))


def test_shifted_model_ramp_interpolates_and_plateaus():
    m = ShiftedTransitionModel(_FlatModel(), 3.0, ramp_samples=4)
    rng = np.random.default_rng(0)
    factors = []
    for _ in range(6):
        # base_latency peeks at the current factor without advancing it
        factors.append(m.base_latency(210.0, 705.0) / BASE_MEAN)
        m.sample_latency(210.0, 705.0, rng)
    assert factors == pytest.approx([1.0, 1.5, 2.0, 2.5, 3.0, 3.0])


def test_shifted_model_base_latency_does_not_advance_the_ramp():
    m = ShiftedTransitionModel(_FlatModel(), 2.0, ramp_samples=10)
    for _ in range(50):
        m.base_latency(210.0, 705.0)
    assert m._drawn == 0
    assert m.base_latency(210.0, 705.0) == pytest.approx(BASE_MEAN)


def test_shifted_model_direction_gates_the_shift():
    up = ShiftedTransitionModel(_FlatModel(), 3.0, direction="up")
    assert up.base_latency(210.0, 705.0) == pytest.approx(3 * BASE_MEAN)
    assert up.base_latency(705.0, 210.0) == pytest.approx(BASE_MEAN)
    down = ShiftedTransitionModel(_FlatModel(), 3.0, direction="down")
    assert down.base_latency(210.0, 705.0) == pytest.approx(BASE_MEAN)
    assert down.base_latency(705.0, 210.0) == pytest.approx(3 * BASE_MEAN)
    with pytest.raises(ValueError, match="direction"):
        ShiftedTransitionModel(_FlatModel(), 2.0, direction="sideways")


def test_direction_gated_ramp_detected_only_on_the_drifted_side():
    """End-to-end injection shape: a 'down'-gated slow ramp drifts the
    705->210 stream while the interleaved 210->705 stream stays on
    baseline — one monitor confirms (via PH, within budget), the other
    never alerts, and only the applicable draws advanced the ramp."""
    m = ShiftedTransitionModel(_FlatModel(), 1.12, ramp_samples=200,
                               direction="down")
    cfg = lambda: DriftConfig(                              # noqa: E731
        diff=PairDiffConfig(worst_delta_threshold=RAMP_THRESHOLD))
    base = _tight_baseline()
    mon_down = PairMonitor("u0@fast", 705.0, 210.0, base, cfg())
    mon_up = PairMonitor("u0@fast", 210.0, 705.0, base, cfg())
    rng = np.random.default_rng(3)
    event = None
    rounds = 0
    for i in range(300):
        rounds += 1
        assert mon_up.observe(m.sample_latency(210.0, 705.0, rng),
                              t_stream=float(i)) is None
        event = mon_down.observe(m.sample_latency(705.0, 210.0, rng),
                                 t_stream=float(i))
        if event is not None:
            break
    assert event is not None, "down-gated ramp never confirmed"
    assert event.sample_index <= 60          # detection-delay budget
    assert event.ph_score >= DriftConfig().ph_lambda
    assert event.cusum_score < DriftConfig().cusum_h
    # the up draws were inapplicable: they must not advance the ramp
    assert m._drawn == rounds
