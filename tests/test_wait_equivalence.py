"""The vectorized segment-cumsum wait() must produce IDENTICAL timestamps
to the seed per-iteration loop: same seed -> same RNG draws -> bit-equal
boundaries, across stable kernels, mid-kernel switches, wake-up ramps and
multi-step trajectories (rtx6000 passes through intermediate frequencies).
"""
import numpy as np
import pytest

from repro.dvfs import make_device
from repro.dvfs.device_model import SimulatedAccelerator


def _exercise(impl: str, kind: str, seed: int, sigma: float | None):
    kw = {"wait_impl": impl}
    if sigma is not None:
        kw["iter_noise_sigma"] = sigma
    dev = make_device(kind, seed=seed, n_cores=8, **kw)
    fs = dev.cfg.frequencies
    out = []
    dev.set_frequency(fs[0])
    out.append(dev.run_kernel(200, 40e-6))            # stable kernel
    h = dev.launch_kernel(1000, 40e-6)                # mid-kernel switch
    dev.usleep(0.004)
    dev.set_frequency(fs[-1])
    out.append(dev.wait(h))
    dev.usleep(0.1)                                   # idle -> wake-up ramp
    out.append(dev.run_kernel(500, 40e-6))
    h = dev.launch_kernel(300, 40e-6)                 # switch near the end
    dev.usleep(0.001)
    dev.set_frequency(fs[len(fs) // 2])
    out.append(dev.wait(h))
    return out


@pytest.mark.parametrize("kind", ["a100", "gh200", "rtx6000"])
@pytest.mark.parametrize("seed", [0, 7])
def test_vectorized_matches_loop(kind, seed):
    ref = _exercise("loop", kind, seed, None)
    vec = _exercise("vectorized", kind, seed, None)
    for a, b in zip(ref, vec):
        assert np.array_equal(a, b)


def test_vectorized_matches_loop_high_noise():
    """sigma=0.2 stresses the window-clamp undershoot path."""
    ref = _exercise("loop", "a100", 3, 0.2)
    vec = _exercise("vectorized", "a100", 3, 0.2)
    for a, b in zip(ref, vec):
        assert np.array_equal(a, b)


def test_eval_functions_bit_equal_on_dense_timeline():
    """Direct comparison on a timeline with many short segments (worst case
    for the segment walker)."""
    n, it = 6, 400
    rng = np.random.default_rng(5)
    t0 = np.full(n, 1.0) + rng.uniform(0, 2e-6, n)
    noise = rng.lognormal(0.0, 0.05, (n, it))
    ev_t = np.concatenate([[-np.inf], 1.0 + np.cumsum(
        rng.uniform(2e-4, 1e-3, 12))])
    ev_f = np.concatenate([[210.0], rng.choice(
        [210.0, 705.0, 1410.0], 12)])
    a = SimulatedAccelerator._eval_timestamps_loop(
        40e-6, t0, noise, ev_t, ev_f, 1410.0)
    b = SimulatedAccelerator._eval_timestamps_vectorized(
        40e-6, t0, noise, ev_t, ev_f, 1410.0)
    assert np.array_equal(a, b)


def test_wait_loop_impl_selectable():
    dev = make_device("a100", n_cores=2, wait_impl="loop")
    assert dev.cfg.wait_impl == "loop"
    data = dev.run_kernel(32, 40e-6)
    assert data.shape == (2, 32, 2)
