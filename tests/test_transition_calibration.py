"""Transition-model calibration fidelity vs the paper's Table II / §VII.

The simulators are the stand-ins for real silicon (DESIGN.md §2); these
tests pin their ground-truth distributions to the paper's reported
qualitative structure so a future re-calibration cannot silently drift.
"""
import numpy as np

from repro.dvfs import make_device
from repro.dvfs.transition_models import A100Like, GH200Like, RTXQuadro6000Like


def _samples(model, n_pairs=60, per_pair=20, seed=0):
    rng = np.random.default_rng(seed)
    fs = np.arange(300.0, 2101.0, 15.0)
    out = {}
    for _ in range(n_pairs):
        fi, ft = rng.choice(fs, 2, replace=False)
        out[(fi, ft)] = np.array([model.sample_latency(fi, ft, rng)
                                  for _ in range(per_pair)])
    return out


def test_a100_magnitudes_and_tightness():
    """Paper Table II: A100 worst-case 7.4-22.7 ms band, tight spread."""
    s = _samples(A100Like(), seed=1)
    worst = np.array([v.max() for v in s.values()])
    assert 3e-3 < worst.min() and worst.max() < 30e-3
    # tight per-pair spread: cv below 15%
    cvs = [v.std() / v.mean() for v in s.values()]
    assert np.median(cvs) < 0.15


def test_gh200_extremes_but_predictable():
    """Paper: GH200 reaches ~477 ms on a few targets, most < 100 ms."""
    s = _samples(GH200Like(), n_pairs=200, seed=2)
    worst = np.array([v.max() for v in s.values()])
    assert worst.max() > 150e-3            # the extreme targets exist
    assert np.mean(worst < 100e-3) > 0.7   # but most pairs stay low


def test_rtx6000_erratic():
    """Paper: RTX Quadro 6000 erratic, 0.5-350 ms, widest variability."""
    m = RTXQuadro6000Like()
    s = _samples(m, n_pairs=150, seed=3)
    allv = np.concatenate(list(s.values()))
    assert allv.min() < 5e-3 and allv.max() > 200e-3
    # sub-ms best-case pairs exist (paper: 0.558 ms at 1650->1560)
    fs = np.arange(300.0, 2101.0, 15.0)
    bases = [m.base_latency(fi, ft) for fi in fs for ft in fs if fi != ft]
    assert min(bases) < 1.5e-3
    cvs = np.median([v.std() / v.mean() for v in s.values()])
    a100_cvs = np.median([v.std() / v.mean()
                          for v in _samples(A100Like(), seed=4).values()])
    assert cvs > 2 * a100_cvs              # visibly wider than A100


def test_unit_seed_variability_without_dominance():
    """§VII-C: units differ per pair, none dominates."""
    rng = np.random.default_rng(5)
    fs = [510.0, 1005.0, 1410.0]
    units = [A100Like(unit_seed=u) for u in range(4)]
    worst_counts = np.zeros(4)
    n_pairs = 0
    for fi in fs:
        for ft in fs:
            if fi == ft:
                continue
            n_pairs += 1
            w = [max(m.sample_latency(fi, ft, rng) for _ in range(10))
                 for m in units]
            worst_counts[int(np.argmax(w))] += 1
    assert worst_counts.max() < n_pairs            # no unit always worst


def test_comm_delay_included_in_switching_latency():
    """Switching latency (vs transition latency) includes the CPU->ACC
    command path — §I's distinction."""
    dev = make_device("a100", seed=6, n_cores=2)
    t0 = dev.host_now()
    dev.set_frequency(dev.cfg.frequencies[-1])
    assert dev.history[-1]["arrive_dev"] > dev._dev_time(t0)
    assert dev.host_now() > t0                     # host paid the round-trip
