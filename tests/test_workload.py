"""Workload sizing rules (paper §V bullet list)."""
from repro.core.workload import size_workload


def test_sizing_covers_all_events():
    spec = size_workload(probe_latency_s=50e-3, iter_time_s=40e-6,
                         delay_iters=400, confirm_iters=600)
    switch_iters = spec.iters_per_kernel - spec.delay_iters - spec.confirm_iters
    # 10x rule: switching window covers >= 10 x the probed latency
    assert switch_iters * 40e-6 >= 10 * 50e-3
    assert spec.delay_iters == 400 and spec.confirm_iters == 600


def test_ten_times_longer_retry_semantics():
    s1 = size_workload(probe_latency_s=5e-3, iter_time_s=40e-6)
    s10 = size_workload(probe_latency_s=50e-3, iter_time_s=40e-6)
    grow = (s10.iters_per_kernel - s10.delay_iters - s10.confirm_iters) / \
           (s1.iters_per_kernel - s1.delay_iters - s1.confirm_iters)
    assert 9.0 < grow < 11.0
