"""IEEE-1588 sync: recovered offset within the link-jitter bound,
best-of-n really picks the min-RTT exchange, and degenerate inputs fail
loudly instead of crashing."""
import pytest

from repro.core.clock_sync import sync_from_exchanges, synchronize_timers
from repro.dvfs import make_device


def _exchange(offset: float, d_fwd: float, d_back: float, t1: float = 0.0):
    """Build one (t1, t2, t3, t4) tuple with a known true offset and
    asymmetric forward/backward link delays."""
    t2 = t1 + d_fwd + offset
    t3 = t2 + 2e-6
    t4 = (t3 - offset) + d_back
    return (t1, t2, t3, t4)


@pytest.mark.parametrize("kind", ["a100", "gh200", "rtx6000"])
def test_offset_recovery(kind):
    dev = make_device(kind, seed=0, n_cores=4)
    sync = synchronize_timers(dev, n_exchanges=16)
    true_offset = dev.cfg.clock_offset_s
    # asymmetric comm adds up to ~jitter of error; drift negligible here
    assert abs(sync.offset - true_offset) < 5 * dev.cfg.link_jitter_s
    assert sync.rtt >= 0


def test_sync_improves_with_exchanges():
    dev = make_device("a100", seed=3, n_cores=4)
    s1 = synchronize_timers(dev, n_exchanges=2)
    s16 = synchronize_timers(dev, n_exchanges=32)
    true_offset = dev.cfg.clock_offset_s
    assert abs(s16.offset - true_offset) <= abs(s1.offset - true_offset) + 1e-6


def test_zero_exchanges_raises():
    dev = make_device("a100", seed=0, n_cores=2)
    with pytest.raises(ValueError, match="n_exchanges"):
        synchronize_timers(dev, n_exchanges=0)
    with pytest.raises(ValueError, match="at least one exchange"):
        sync_from_exchanges([])


def test_best_of_n_picks_min_rtt_exchange():
    """One clean exchange among jittery asymmetric ones: the estimate must
    be the clean exchange's offset, and every per-exchange value must be
    exposed for trace recording."""
    true = 1.234
    exchanges = [
        _exchange(true, 50e-6 + 40e-6, 50e-6 + 10e-6),   # asymmetric, slow
        _exchange(true, 50e-6, 50e-6),                   # clean: min RTT
        _exchange(true, 50e-6 + 5e-6, 50e-6 + 80e-6),    # jittery
        _exchange(true, 50e-6 + 25e-6, 50e-6 + 25e-6),   # symmetric, slow
    ]
    sync = sync_from_exchanges(exchanges)
    assert sync.n_exchanges == 4
    assert len(sync.offsets) == 4 and len(sync.rtts) == 4
    assert sync.rtt == min(sync.rtts)
    assert sync.offset == sync.offsets[1]        # the clean exchange
    assert sync.offset == pytest.approx(true, abs=1e-12)
    # asymmetric exchanges bias the per-exchange offset by the asymmetry/2
    assert abs(sync.offsets[0] - true) == pytest.approx(15e-6, abs=1e-9)


def test_device_sync_exposes_per_exchange_offsets():
    dev = make_device("gh200", seed=5, n_cores=2)
    sync = synchronize_timers(dev, n_exchanges=8)
    assert len(sync.offsets) == 8
    assert sync.rtt == min(sync.rtts)
    assert sync.offset == sync.offsets[sync.rtts.index(sync.rtt)]


def test_offset_error_bounded_by_asymmetric_jitter():
    """Jittery asymmetric links: the best-of-n error stays inside the
    worst single-exchange asymmetry bound (rtt/2 of the chosen one)."""
    import numpy as np
    rng = np.random.default_rng(0)
    true = -0.5
    base = 40e-6
    exchanges = [
        _exchange(true, base + rng.uniform(0, 30e-6),
                  base + rng.uniform(0, 30e-6))
        for _ in range(24)
    ]
    sync = sync_from_exchanges(exchanges)
    assert abs(sync.offset - true) <= (sync.rtt - 2e-6) / 2 + 1e-12


# ------------------------------------------------------------------ #
# properties (run when hypothesis is installed)
# ------------------------------------------------------------------ #
def test_rtt_monotone_offset_consistent_property():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    delays = st.floats(1e-6, 1e-3, allow_nan=False)

    @given(st.lists(st.tuples(delays, delays), min_size=1, max_size=32),
           st.floats(-10.0, 10.0, allow_nan=False))
    @settings(max_examples=80, deadline=None)
    def prop(delay_pairs, true_offset):
        exchanges = [_exchange(true_offset, f, b) for f, b in delay_pairs]
        # monotonicity: adding exchanges never worsens the best RTT
        prev = None
        for k in range(1, len(exchanges) + 1):
            s = sync_from_exchanges(exchanges[:k])
            if prev is not None:
                assert s.rtt <= prev + 1e-15
            prev = s.rtt
        # consistency: the chosen offset is the min-RTT exchange's offset,
        # and its error is bounded by that exchange's asymmetry (rtt/2)
        full = sync_from_exchanges(exchanges)
        k = full.rtts.index(min(full.rtts))
        assert full.offset == full.offsets[k]
        assert abs(full.offset - true_offset) <= full.rtt / 2 + 1e-9

    prop()
