"""IEEE-1588 sync: recovered offset within the link-jitter bound."""
import pytest

from repro.core.clock_sync import synchronize_timers
from repro.dvfs import make_device


@pytest.mark.parametrize("kind", ["a100", "gh200", "rtx6000"])
def test_offset_recovery(kind):
    dev = make_device(kind, seed=0, n_cores=4)
    sync = synchronize_timers(dev, n_exchanges=16)
    true_offset = dev.cfg.clock_offset_s
    # asymmetric comm adds up to ~jitter of error; drift negligible here
    assert abs(sync.offset - true_offset) < 5 * dev.cfg.link_jitter_s
    assert sync.rtt >= 0


def test_sync_improves_with_exchanges():
    dev = make_device("a100", seed=3, n_cores=4)
    s1 = synchronize_timers(dev, n_exchanges=2)
    s16 = synchronize_timers(dev, n_exchanges=32)
    true_offset = dev.cfg.clock_offset_s
    assert abs(s16.offset - true_offset) <= abs(s1.offset - true_offset) + 1e-6
