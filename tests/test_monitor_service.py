"""MonitorService end to end: a stored campaign baseline, live taps and
trace replays, drift alerts persisted as deterministic artifacts, stale
device detection on the shared stream clock, and the CLI surface."""
import json

import pytest

from repro.backends import create_backend
from repro.campaign import (ArtifactStore, CampaignSpec, DeviceSpec,
                            MeasureSpec, run_campaign)
from repro.core.session import MeasurementSession, SessionConfig
from repro.dvfs.transition_models import ShiftedTransitionModel
from repro.monitor import MonitorConfig, MonitorService
from repro.monitor.ingest import replay_events
from repro.trace import TracedBackend, TraceRecorder

FAST = MeasureSpec(key="fast", min_measurements=6, max_measurements=8,
                   rse_check_every=6)
KINDS = {"d0": "gh200", "d1": "a100"}
QUIET = 1e9          # parks stale detection where it is not under test


def _quiet_cfg():
    return MonitorConfig(heartbeat_timeout_s=QUIET)


@pytest.fixture(scope="module")
def baseline(tmp_path_factory):
    """A traced two-device baseline campaign the whole module monitors."""
    spec = CampaignSpec(
        "monitor-svc",
        devices=tuple(
            DeviceSpec.make(key, "vmapped-sim",
                            {"kind": kind, "n_cores": 6, "seed": 0,
                             "unit_seed": 0}, n_freqs=2)
            for key, kind in KINDS.items()),
        measures=(FAST,))
    store = ArtifactStore(str(tmp_path_factory.mktemp("svc-store")))
    result = run_campaign(spec, store, trace=True)
    assert result.ok, [o.error for o in result.failed()]
    return result.campaign


def _gen2_session(key: str, *, drift_scale: float | None,
                  monitor: MonitorService | None):
    """A live gen2 device (new measurement seed, same unit physics),
    optionally drifted, optionally tapped into ``monitor``; returns the
    finished recorder's trace."""
    dev = create_backend("vmapped-sim", kind=KINDS[key], n_cores=6, seed=1,
                         unit_seed=0)
    if drift_scale is not None:
        dev.model = ShiftedTransitionModel(dev.model, drift_scale)
    recorder = TraceRecorder()
    traced = TracedBackend(dev, recorder)
    if monitor is not None:
        monitor.attach_recorder(key, recorder)
    session = MeasurementSession(
        traced, DeviceSpec.make(key, n_freqs=2).resolve_frequencies(dev),
        SessionConfig(latest=FAST.to_latest_config()), device_name=key)
    session.run(verbose=False)
    return recorder.finish()


def test_replaying_the_baselines_own_stream_stays_silent(baseline):
    service = MonitorService(baseline, _quiet_cfg())
    for key in KINDS:
        raised = service.replay_trace(baseline.load_trace(f"{key}@fast"),
                                      device=key)
        assert raised == []
    status = service.status()
    assert status["campaign_id"] == baseline.campaign_id
    assert status["n_alerts"] == 0
    for key in KINDS:
        d = status["devices"][key]
        assert d["unit_key"] == f"{key}@fast"
        assert d["events"] > 0 and d["passes"] > 0
        assert d["pairs_watched"] >= 1
        assert not d["stale"]


def test_stationary_gen2_stream_raises_no_false_alerts(baseline):
    service = MonitorService(baseline, _quiet_cfg())
    _gen2_session("d0", drift_scale=None, monitor=service)
    assert service.alerts == []


def test_drifted_device_alerts_live_and_replay_is_bit_identical(baseline):
    service = MonitorService(baseline, _quiet_cfg())
    trace = _gen2_session("d1", drift_scale=4.0, monitor=service)
    drift = [(aid, unit, doc) for aid, unit, doc in service.alerts
             if doc["kind"] == "drift"]
    assert drift, "a 4x transition-model shift must be detected live"
    assert all(unit == "d1@fast" for _, unit, _ in drift)
    assert all(doc["device"] == "d1" for _, _, doc in drift)
    budget = 8
    assert min(doc["sample_index"] for _, _, doc in drift) <= budget
    # every alert is a stored, content-addressed artifact...
    stored = baseline.list_alerts()["d1@fast"]
    assert {aid for aid, _, _ in drift} <= set(stored)
    for aid, unit, doc in drift:
        assert baseline.load_alert(unit, aid) == doc
    # ...and replaying the recorded stream reproduces the alerts bit for
    # bit (same ids), with the store save idempotent
    replay = MonitorService(baseline, _quiet_cfg())
    raised = replay.replay_trace(trace, device="d1")
    assert [aid for aid, _, _ in raised] == [aid for aid, _, _ in
                                             service.alerts]
    assert baseline.list_alerts()["d1@fast"] == stored


def test_silent_device_goes_stale_once_then_revives(baseline):
    t0 = baseline.load_trace("d0@fast")
    t1 = baseline.load_trace("d1@fast")
    ev0, ev1 = list(replay_events(t0)), list(replay_events(t1))
    cut = len(ev1) // 3
    # timeout: d1 falls silent at its cut while d0's stream keeps the
    # service clock advancing well past it
    span = ev0[-1][1] - ev1[cut][1]
    assert span > 0
    service = MonitorService(
        baseline, MonitorConfig(heartbeat_timeout_s=span / 4))
    service.attach("d0")
    service.attach("d1")
    for ev in ev1[:cut]:
        service.handle_event("d1", *ev)
    for ev in ev0:
        service.handle_event("d0", *ev)
    stale = [doc for _, _, doc in service.alerts
             if doc["kind"] == "stale-device"]
    assert len(stale) == 1, "one silence must raise exactly one alert"
    assert stale[0]["device"] == "d1"
    assert stale[0]["silent_s"] >= span / 4
    assert service.status()["devices"]["d1"]["stale"]
    assert not service.status()["devices"]["d0"]["stale"]
    # the device comes back: the stale latch clears, no duplicate alert
    for ev in ev1[cut:]:
        service.handle_event("d1", *ev)
    assert not service.status()["devices"]["d1"]["stale"]
    assert len([doc for _, _, doc in service.alerts
                if doc["kind"] == "stale-device"]) == 1


def test_unit_resolution_matches_governor_rule(baseline):
    service = MonitorService(baseline, _quiet_cfg())
    service.attach("d0")                       # device-prefix resolution
    assert service.status()["devices"]["d0"]["unit_key"] == "d0@fast"
    service.attach("other", unit_key="d1@fast")   # explicit unit key
    assert service.status()["devices"]["other"]["unit_key"] == "d1@fast"
    with pytest.raises(KeyError):
        MonitorService(baseline, _quiet_cfg()).attach("nonexistent")


def test_cli_status_and_replay(baseline, capsys):
    from repro.monitor.cli import main
    root = baseline.dir.rsplit("/", 1)[0]
    cid = baseline.campaign_id

    assert main(["--store", root, "status", cid, "--json"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["campaign_id"] == cid

    # replaying the baseline's own stored trace (unit-key reference) must
    # stay silent even under the CI gate flag
    rc = main(["--store", root, "replay", cid, "d0@fast",
               "--fail-on-alert", "--json"])
    out = json.loads(capsys.readouterr().out)
    assert rc == 0
    assert out["alerts"] == []
    assert out["devices"]["d0"]["passes"] > 0
