"""Executor layer: serial/thread/process scheduling, result ordering,
per-result callbacks, and the picklable-task contract."""
import pytest

from repro.core.executors import (ProcessExecutor, SerialExecutor,
                                  ThreadExecutor, get_executor,
                                  map_pairs_with_callback)


def _square(pair, worker):
    # module-level on purpose: ProcessExecutor pickles tasks by reference
    return pair[0] * pair[0] + pair[1]


PAIRS = [(i, i % 3) for i in range(7)]
WANT = [_square(p, 0) for p in PAIRS]


def test_get_executor_by_name():
    assert isinstance(get_executor("serial"), SerialExecutor)
    assert isinstance(get_executor("threads"), ThreadExecutor)
    proc = get_executor("processes", max_workers=3)
    assert isinstance(proc, ProcessExecutor)
    assert proc.n_workers == 3
    assert proc.requires_picklable_fn
    with pytest.raises(ValueError, match="serial.*threads.*processes"):
        get_executor("fork-bomb")


def test_get_executor_rejects_partial_instances():
    class Half:
        n_workers = 1
    with pytest.raises(TypeError, match="map_pairs"):
        get_executor(Half())


@pytest.mark.parametrize("executor", [SerialExecutor(), ThreadExecutor(3)])
def test_in_process_executors_order_and_callback(executor):
    seen = []
    out = executor.map_pairs(_square, PAIRS,
                             on_result=lambda p, r: seen.append((p, r)))
    assert out == WANT                       # task order, not completion
    assert sorted(seen) == sorted(zip(PAIRS, WANT))
    assert executor.map_pairs(_square, []) == []


def test_process_executor_orders_results_and_calls_back():
    seen = []
    out = ProcessExecutor(max_workers=2).map_pairs(
        _square, PAIRS, on_result=lambda p, r: seen.append((p, r)))
    assert out == WANT
    assert sorted(seen) == sorted(zip(PAIRS, WANT))
    assert ProcessExecutor(2).map_pairs(_square, []) == []


def test_map_pairs_with_callback_wraps_legacy_executors():
    class Legacy:                            # pre-on_result protocol
        n_workers = 1

        def map_pairs(self, fn, pairs):
            return [fn(p, 0) for p in pairs]

    seen = []
    out = map_pairs_with_callback(Legacy(), _square, PAIRS,
                                  lambda p, r: seen.append(p))
    assert out == WANT
    assert seen == PAIRS                     # called after the batch
