"""Governor invariants: timing rule, pair avoidance, energy dominance."""
import numpy as np

from repro.core.latency_table import LatencyTable, analyse_pair
from repro.dvfs.governor import (Governor, GovernorConfig,
                                 oblivious_governor_sim, static_sim)
from repro.dvfs.planner import Region
from repro.dvfs.power_model import PowerModel

FREQS = [500.0, 1000.0, 1500.0, 2000.0]


def _table(lat_s=0.010, bad_pair=None, bad_lat=0.4):
    rng = np.random.default_rng(0)
    t = LatencyTable()
    for fi in FREQS:
        for ft in FREQS:
            if fi == ft:
                continue
            base = bad_lat if (fi, ft) == bad_pair else lat_s
            t.add(analyse_pair(fi, ft, base * rng.lognormal(0, 0.03, 30)))
    return t


def test_never_switches_on_short_regions():
    g = Governor(_table(), PowerModel(2000.0), FREQS,
                 GovernorConfig(hysteresis=3.0))
    short = Region("memory", 0.005)           # 5 ms < 3 x 10 ms
    tgt, reason = g.pick_target(short, 2000.0)
    assert tgt == 2000.0 and reason in ("too_short", "already_optimal")
    long = Region("memory", 1.0)
    tgt2, _ = g.pick_target(long, 2000.0)
    assert tgt2 < 2000.0                      # memory-bound -> downclock


def test_avoids_expensive_pairs():
    bad = (2000.0, 500.0)
    g = Governor(_table(bad_pair=bad), PowerModel(2000.0), FREQS,
                 GovernorConfig(avoid_percentile=90.0))
    r = Region("memory", 1.0)
    tgt, reason = g.pick_target(r, 2000.0)
    assert tgt != 500.0                       # the avoided target
    assert g.allowed(2000.0, tgt)


def test_energy_beats_static_and_oblivious():
    table = _table(lat_s=0.02)
    power = PowerModel(2000.0)
    regions = [Region("compute", 0.3), Region("memory", 0.4),
               Region("collective", 0.2), Region("host", 0.02)] * 20
    g = Governor(table, power, FREQS).simulate(regions)
    st = static_sim(power, FREQS, regions)
    ob = oblivious_governor_sim(table, power, FREQS, regions)
    assert g.energy_j < st.energy_j                    # saves energy
    assert g.time_s <= 1.05 * st.time_s                # ~no slowdown
    # latency-aware beats latency-oblivious on energy-delay product
    assert g.energy_j * g.time_s <= ob.energy_j * ob.time_s
    assert g.switch_overhead_s <= ob.switch_overhead_s


def test_simulate_counts_switches():
    g = Governor(_table(), PowerModel(2000.0), FREQS)
    regions = [Region("compute", 0.5), Region("memory", 0.5)] * 3
    st = g.simulate(regions)
    assert st.switches >= 1
    assert st.energy_j > 0 and st.time_s > 0
