"""DBSCAN (Alg. 3) + silhouette: outlier recall, adaptive convergence."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests run when installed
from hypothesis import given, settings, strategies as st

from repro.core.dbscan import adaptive_dbscan, dbscan, split_clusters
from repro.core.silhouette import silhouette_score


def _dataset(n=200, n_out=6, seed=0):
    rng = np.random.default_rng(seed)
    base = rng.normal(20e-3, 0.5e-3, n - n_out)        # one tight cluster
    outliers = rng.uniform(80e-3, 300e-3, n_out)       # far spikes
    return np.concatenate([base, outliers]), n_out


def test_outliers_detected():
    x, n_out = _dataset()
    res = adaptive_dbscan(x)
    clean, outliers, _ = split_clusters(x, res)
    assert len(outliers) >= n_out                  # all injected spikes caught
    assert res.noise_ratio <= 0.10                 # Alg.3 halting criterion
    assert clean.max() < 40e-3


def test_multi_cluster_silhouette():
    """Paper §VII-B: separated clusters score > 0.4."""
    rng = np.random.default_rng(1)
    a = rng.normal(10e-3, 0.3e-3, 120)
    b = rng.normal(25e-3, 0.3e-3, 60)
    x = np.concatenate([a, b])
    res = adaptive_dbscan(x)
    assert res.n_clusters == 2
    s = silhouette_score(x, res.labels)
    assert s > 0.4


def test_dbscan_all_same_point():
    labels = dbscan(np.ones(50), eps=0.1, min_pts=3)
    assert (labels == 0).all()


def test_adaptive_minpts_range():
    x, _ = _dataset(n=300)
    res = adaptive_dbscan(x)
    assert 2 <= res.min_pts <= max(2, int(np.ceil(0.04 * len(x))))


@given(st.integers(60, 300), st.integers(0, 8), st.integers(0, 1000))
@settings(max_examples=25, deadline=None)
def test_noise_ratio_bounded_on_clustered_data(n, n_out, seed):
    """Property: on one-tight-cluster + few-spikes data (the paper's typical
    shape), adaptive DBSCAN never marks more than ~10% + spikes as noise."""
    rng = np.random.default_rng(seed)
    base = rng.normal(15e-3, 0.4e-3, n)
    spikes = rng.uniform(0.1, 0.4, n_out)
    x = np.concatenate([base, spikes])
    res = adaptive_dbscan(x)
    assert res.noise_ratio <= 0.10 + n_out / len(x) + 1e-9


def test_silhouette_overlapping_clusters_low():
    rng = np.random.default_rng(3)
    x = np.concatenate([rng.normal(10, 1.0, 100), rng.normal(10.5, 1.0, 100)])
    labels = np.array([0] * 100 + [1] * 100)
    assert silhouette_score(x, labels) < 0.4
