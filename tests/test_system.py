"""End-to-end behaviour tests: train-to-convergence on the synthetic
grammar, serving, and the full paper pipeline feeding the governor."""
import jax
import numpy as np

from repro.configs import get_config
from repro.configs.registry import model_module
from repro.configs.shapes import ShapeSpec
from repro.data.synthetic import SyntheticTokens, make_batch
from repro.parallel.sharding import make_env
from repro.runtime.serve_loop import ServeConfig, serve
from repro.runtime.train_loop import TrainConfig, train

ENV = make_env(None, None)


def test_train_loss_decreases():
    cfg = get_config("llama3-8b", smoke=True)
    shape = ShapeSpec("t", 32, 4, "train")
    m = train(cfg, shape, ENV, TrainConfig(steps=60, lr=2e-3, warmup=10,
                                           log_every=100), verbose=False)
    first = np.mean(m["loss"][:5])
    last = np.mean(m["loss"][-5:])
    assert last < first - 0.15, (first, last)   # learns the markov grammar


def test_data_pipeline_deterministic():
    ds = SyntheticTokens(vocab=128, seq_len=16, global_batch=4, seed=3)
    a = ds.batch_at(7)["tokens"]
    b = ds.batch_at(7)["tokens"]
    c = ds.batch_at(8)["tokens"]
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert not np.array_equal(np.asarray(a), np.asarray(c))


def test_serve_end_to_end():
    cfg = get_config("qwen3-32b", smoke=True)
    mod = model_module(cfg)
    params, _ = mod.init(jax.random.PRNGKey(0), cfg)
    shape = ShapeSpec("s", 16, 2, "prefill")
    batch = make_batch(cfg, shape)
    res = serve(cfg, ENV, params, batch, ServeConfig(max_new_tokens=8))
    assert res["tokens"].shape == (2, 8)
    assert int(res["tokens"].max()) < cfg.vocab
    assert res["tokens_per_s"] > 0


def test_paper_pipeline_feeds_governor():
    """Measure a simulated device -> latency table -> governor plans an
    energy-aware schedule for a real dry-run cell's region profile."""
    import glob
    import json

    from repro.core.evaluation import MeasureConfig
    from repro.core.latest import LatestConfig, run_latest
    from repro.dvfs import PowerModel, make_device
    from repro.dvfs.governor import Governor, static_sim
    from repro.dvfs.planner import regions_from_cell

    dev = make_device("a100", seed=0, n_cores=8)
    freqs = [210.0, 705.0, 1095.0, 1410.0]
    table = run_latest(dev, freqs, LatestConfig(
        measure=MeasureConfig(min_measurements=4, max_measurements=4)))
    assert len(table.pairs) >= 6

    from repro.core.paths import results_dir
    cells = glob.glob(results_dir("dryrun", "*train_4k__single.json"))
    regions = None
    if cells:                                    # use the real roofline cell
        cell = json.load(open(cells[0]))
        if cell["status"] == "ok":
            regions = regions_from_cell(cell)
    if regions is None:
        from repro.dvfs.planner import Region
        regions = [Region("compute", 0.3), Region("collective", 0.1)]

    power = PowerModel(f_max_mhz=1410.0)
    g = Governor(table, power, freqs)
    stats = g.simulate(regions * 50)
    base = static_sim(power, freqs, regions * 50)
    assert stats.energy_j <= base.energy_j       # never worse than static
    assert stats.time_s <= 1.1 * base.time_s
