"""Latency table: CSV naming convention, persistence, summaries."""
import numpy as np

from repro.core.latency_table import LatencyTable, analyse_pair


def _table():
    rng = np.random.default_rng(0)
    t = LatencyTable(hostname="karolina1", device_index=2)
    for fi, ft, base in [(210.0, 1410.0, 20e-3), (1410.0, 210.0, 5e-3)]:
        lat = base * rng.lognormal(0, 0.05, 40)
        lat[-1] = base * 8                       # inject one outlier
        t.add(analyse_pair(fi, ft, lat))
    return t


def test_csv_naming_convention():
    t = _table()
    assert t.csv_name(210.0, 1410.0) == "210_1410_karolina1_2.csv"


def test_csv_roundtrip(tmp_path):
    t = _table()
    paths = t.save_csv(str(tmp_path))
    assert len(paths) == 2
    lat, outl = LatencyTable.load_csv(paths[0])
    assert len(lat) == 40
    assert outl.sum() >= 1                      # the injected outlier marked


def test_summary_shape():
    s = _table().summary()
    assert s["n_pairs"] == 2
    assert s["worst_case"]["max_ms"] >= s["worst_case"]["min_ms"]
    assert s["best_case"]["mean_ms"] <= s["worst_case"]["mean_ms"]


def test_outlier_filtered_from_worst_case():
    t = _table()
    pr = t.lookup(210.0, 1410.0)
    assert pr.worst_case < 0.1                  # 160 ms spike excluded
    assert pr.outliers.size >= 1


def test_heatmap_and_asymmetry():
    t = _table()
    m, inits, targets = t.heatmap("worst")
    assert m.shape == (2, 2) and np.isnan(m).sum() == 2
    asym = t.asymmetry()
    assert asym["increase"]["mean_ms"] > asym["decrease"]["mean_ms"]
