"""Regression layer: Mann-Whitney two-sample test + campaign drift diffs
(self-diff clean; injected +30% worst-case drift flags exactly that pair)."""
import glob
import os
import shutil

import numpy as np
import pytest

from repro.campaign import (ArtifactStore, CampaignSpec, DeviceSpec,
                            DiffConfig, MeasureSpec, diff_campaigns,
                            diff_markdown, run_campaign)
from repro.core.stats import mann_whitney_u, rankdata

FAST = MeasureSpec(key="fast", min_measurements=5, max_measurements=6,
                   rse_check_every=5)


def _spec():
    return CampaignSpec(
        name="reg",
        devices=(
            DeviceSpec.make("a100", "simulated",
                            {"kind": "a100", "n_cores": 6},
                            frequencies=(210.0, 705.0, 1410.0)),
            DeviceSpec.make("gh200", "simulated",
                            {"kind": "gh200", "n_cores": 6},
                            frequencies=(345.0, 1155.0, 1980.0))),
        measures=(FAST,))


# ------------------------------------------------------------------ #
# mann-whitney building block
# ------------------------------------------------------------------ #
def test_rankdata_ties_share_mean_rank():
    np.testing.assert_allclose(rankdata([10.0, 20.0, 20.0, 30.0]),
                               [1.0, 2.5, 2.5, 4.0])


def test_mann_whitney_same_distribution_high_p():
    rng = np.random.default_rng(0)
    x, y = rng.normal(5e-3, 1e-4, 40), rng.normal(5e-3, 1e-4, 40)
    _, p = mann_whitney_u(x, y)
    assert p > 0.05


def test_mann_whitney_shifted_distribution_low_p():
    rng = np.random.default_rng(1)
    x = rng.normal(5e-3, 1e-4, 20)
    _, p = mann_whitney_u(x, x * 1.3)
    assert p < 0.01


def test_mann_whitney_degenerate_inputs():
    u, p = mann_whitney_u([], [1.0])
    assert np.isnan(p)
    _, p = mann_whitney_u([2.0, 2.0, 2.0], [2.0, 2.0])   # zero variance
    assert p == 1.0


def test_mann_whitney_matches_scipy_when_available():
    scipy_stats = pytest.importorskip("scipy.stats")
    rng = np.random.default_rng(7)
    x, y = rng.lognormal(0, 0.3, 25), rng.lognormal(0.2, 0.3, 30)
    u, p = mann_whitney_u(x, y)
    ref = scipy_stats.mannwhitneyu(x, y, alternative="two-sided",
                                   method="asymptotic")
    assert u == pytest.approx(ref.statistic)
    assert p == pytest.approx(ref.pvalue, rel=0.05)


# ------------------------------------------------------------------ #
# campaign diffs
# ------------------------------------------------------------------ #
@pytest.fixture(scope="module")
def measured(tmp_path_factory):
    store = ArtifactStore(str(tmp_path_factory.mktemp("store")))
    result = run_campaign(_spec(), store)
    assert result.ok
    return store, result.campaign


def _clone_with_drift(store, campaign, clone_id, scale=1.3,
                      unit="a100@fast", pair=(705.0, 1410.0)):
    """Copy the campaign's artifacts under a new id, scaling one pair's
    samples — the 'silicon drifted since last campaign' scenario."""
    bdir = os.path.join(store.root, clone_id)
    if os.path.isdir(bdir):
        shutil.rmtree(bdir)
    shutil.copytree(campaign.dir, bdir)
    fi, ft = pair
    (csv,) = glob.glob(os.path.join(bdir, "units", unit, "table",
                                    f"{int(fi)}_{int(ft)}_*.csv"))
    lat, out = np.loadtxt(csv, delimiter=",", skiprows=1).reshape(-1, 2).T
    with open(csv, "w") as f:
        f.write("latency_s,is_outlier\n")
        for v, o in zip(lat * scale, out):
            f.write(f"{v:.9f},{int(o)}\n")
    return store.load(clone_id)


def test_self_diff_is_clean(measured):
    _, campaign = measured
    diff = diff_campaigns(campaign, campaign)
    assert diff.clean
    assert len(diff.drifts) == 12              # 6 pairs x 2 devices
    assert not diff.only_in_a and not diff.only_in_b
    assert "0 flagged" in diff_markdown(diff)


def test_injected_drift_flags_exactly_that_pair(measured):
    store, campaign = measured
    drifted = _clone_with_drift(store, campaign, "cdrift30", scale=1.3)
    diff = diff_campaigns(campaign, drifted)
    flagged = diff.flagged()
    assert [(d.unit_key, d.f_init, d.f_target) for d in flagged] == [
        ("a100@fast", 705.0, 1410.0)]
    (d,) = flagged
    assert d.rel_delta == pytest.approx(0.3, abs=0.02)
    assert d.p_value < 0.05
    assert "**DRIFT**" in diff_markdown(diff)


def test_small_drift_below_threshold_not_flagged(measured):
    store, campaign = measured
    nudged = _clone_with_drift(store, campaign, "cdrift05", scale=1.05)
    assert diff_campaigns(campaign, nudged).clean
    # even with a hair-trigger delta threshold, the Mann-Whitney gate keeps
    # a within-noise 5% wiggle from being flagged: the distributions
    # overlap too much for the shift to be significant at these sample
    # counts — exactly the single-outlier protection the AND rule buys
    tight = diff_campaigns(campaign, nudged,
                           DiffConfig(worst_delta_threshold=0.02))
    moved = [d for d in tight.drifts if abs(d.rel_delta) > 0.02]
    assert [(d.f_init, d.f_target) for d in moved] == [(705.0, 1410.0)]
    assert not tight.flagged()
    assert moved[0].p_value > DiffConfig().alpha


def test_reanalyse_recovers_drift_and_keeps_self_diff_clean(measured):
    """DiffConfig(reanalyse=True) re-clusters raw samples through the
    sorted-window engine instead of trusting stored outlier flags; the
    verdicts must match the stored-flag path on both a clean self-diff
    and an injected drift."""
    store, campaign = measured
    diff = diff_campaigns(campaign, campaign, DiffConfig(reanalyse=True))
    assert diff.clean and len(diff.drifts) == 12
    drifted = _clone_with_drift(store, campaign, "cdrift30re", scale=1.3)
    flagged = diff_campaigns(campaign, drifted,
                             DiffConfig(reanalyse=True)).flagged()
    assert [(d.unit_key, d.f_init, d.f_target) for d in flagged] == [
        ("a100@fast", 705.0, 1410.0)]


def test_coverage_change_is_reported_not_flagged(measured):
    store, campaign = measured
    clone = _clone_with_drift(store, campaign, "ccover", scale=1.0)
    # drop one unit's result entirely from the clone
    shutil.rmtree(os.path.join(store.root, "ccover", "units", "gh200@fast"))
    manifest = os.path.join(store.root, "ccover", "manifest.json")
    import json
    doc = json.load(open(manifest))
    doc["units"]["gh200@fast"]["status"] = "failed"
    with open(manifest, "w") as f:
        json.dump(doc, f)
    diff = diff_campaigns(campaign, clone)
    assert diff.clean                           # no latencies moved
    assert len(diff.only_in_a) == 6             # but coverage shrank
    assert "Coverage changed" in diff_markdown(diff)
