"""Checkpoint roundtrip, async save, retention, resume, elastic restore."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, (8, 16)),
            "nested": {"b": jnp.arange(4, dtype=jnp.float32),
                       "step": jnp.int32(7)}}


def test_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    t = _tree()
    ck.save(3, t)
    r = ck.restore(3, t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_and_latest(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save_async(1, _tree(1))
    ck.wait()
    ck.save_async(5, _tree(5))
    ck.wait()
    assert ck.latest_step() == 5


def test_retention_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in range(5):
        ck.save(s, _tree(s))
    assert ck.latest_step() == 4
    with pytest.raises(FileNotFoundError):
        ck.restore(0, _tree())


def test_elastic_restore_with_shardings(tmp_path):
    """A checkpoint saved under one placement restores onto another mesh
    (here: explicit single-device shardings) — the elastic-rescale path."""
    ck = Checkpointer(str(tmp_path))
    t = _tree(2)
    ck.save(0, t)
    dev = jax.devices()[0]
    sh = jax.tree.map(lambda _: jax.sharding.SingleDeviceSharding(dev), t)
    r = ck.restore(0, t, shardings=sh)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_train_resume_equivalence(tmp_path):
    """Training 6 steps straight == training 3, restarting, training 3 —
    checkpoint/restart + step-indexed data make resume bit-exact."""
    from repro.configs import get_config
    from repro.configs.shapes import ShapeSpec
    from repro.parallel.sharding import make_env
    from repro.runtime.train_loop import TrainConfig, train

    cfg = get_config("llama3-8b", smoke=True)
    shape = ShapeSpec("t", 16, 2, "train")
    env = make_env(cfg, None)

    m_straight = train(cfg, shape, env, TrainConfig(
        steps=6, checkpoint_dir=None, log_every=100), verbose=False)

    d = str(tmp_path / "ck")
    train(cfg, shape, env, TrainConfig(steps=3, checkpoint_every=3,
                                       checkpoint_dir=d, log_every=100),
          verbose=False)
    m_resumed = train(cfg, shape, env, TrainConfig(
        steps=6, checkpoint_every=100, checkpoint_dir=d, log_every=100),
        verbose=False)
    assert m_resumed["resumed_at"] == 3
    np.testing.assert_allclose(m_straight["loss"][-1], m_resumed["loss"][-1],
                               rtol=1e-4)
