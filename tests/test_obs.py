"""Span profiler (`repro.obs`): recorder semantics, span-tree invariants
(deterministic + hypothesis property forms), the 3-node chaos-run merge,
dominant-cost naming for the straggler and retry-storm scenarios, the
spans-on/off store bit-identity gate, Perfetto export validation, the
metrics bridge, and the `campaign profile` / `--json` CLI surface."""
import json
import os

import numpy as np
import pytest

from repro import obs
from repro.campaign import (ArtifactStore, CampaignRunner, CampaignSpec,
                            DeviceSpec, MeasureSpec, run_campaign)
from repro.campaign.cluster.retry import RetryPolicy
from repro.campaign.workqueue import FaultPlan
from repro.obs import (SpanRecorder, analyze, build_forest, critical_path,
                       export_to_registry, load_span_rows, self_time,
                       to_trace_events, validate_trace_events, walk)
from repro.obs.profile import (collect_span_rows, profile_campaign,
                               profile_markdown)

FAST = MeasureSpec(key="fast", min_measurements=4, max_measurements=5,
                   rse_check_every=4)
FREQS = (210.0, 705.0, 1410.0)


def _device(key, seed, kind="a100"):
    return DeviceSpec.make(key, "simulated",
                           {"kind": kind, "n_cores": 6, "seed": seed},
                           frequencies=FREQS)


def _fleet(n=3, retries=3, name="obs"):
    return CampaignSpec(name, devices=tuple(_device(f"u{i}", i)
                                            for i in range(n)),
                        measures=(FAST,), retries=retries)


def _assert_store_bit_identical(ref, cand):
    """Spans must never perturb measurement bits: whole-campaign digest
    equality plus array-level table equality."""
    assert ref.campaign.content_digest() == cand.campaign.content_digest()
    assert set(ref.outcomes) == set(cand.outcomes)
    for key in ref.outcomes:
        rt, ct = ref.campaign.load_table(key), cand.campaign.load_table(key)
        assert set(rt.pairs) == set(ct.pairs)
        for p, pr in rt.pairs.items():
            assert np.array_equal(pr.latencies, ct.pairs[p].latencies)
            assert np.array_equal(pr.outlier_mask, ct.pairs[p].outlier_mask)


@pytest.fixture(autouse=True)
def _no_leaked_recorder():
    yield
    obs.uninstall()
    obs.uninstall(thread_only=True)


# ------------------------------------------------------------------ #
# recorder + ambient API
# ------------------------------------------------------------------ #
def _fake_clock(start=100.0, step=0.5):
    t = [start - step]

    def clock():
        t[0] += step
        return t[0]
    return clock


def test_recorder_rows_schema_and_nesting(tmp_path):
    path = str(tmp_path / "a.jsonl")
    rec = SpanRecorder("driver", path=path, clock=_fake_clock())
    with rec.span("campaign.run", "campaign", campaign_id="c1"):
        with rec.span("unit.attempt", "unit", unit="u0") as live:
            assert live.attrs == {"unit": "u0"}
            live.attrs["status"] = "done"    # mutable while open
            rec.event("sched.requeue", "sched", unit="u0")
    rec.close()
    rows = load_span_rows(path)
    assert [r["name"] for r in rows] == ["sched.requeue", "unit.attempt",
                                        "campaign.run"]
    by_name = {r["name"]: r for r in rows}
    root = by_name["campaign.run"]
    child = by_name["unit.attempt"]
    ev = by_name["sched.requeue"]
    assert root["parent"] is None and root["actor"] == "driver"
    assert child["parent"] == root["sid"]        # ambient stack nesting
    assert ev["parent"] == child["sid"] and ev["ph"] == "i"
    assert ev["t0"] == ev["t1"]
    assert child["attrs"] == {"unit": "u0", "status": "done"}
    assert child["t1"] > child["t0"]
    assert all(r["sid"].startswith("driver:") for r in rows)
    assert len({r["sid"] for r in rows}) == 3


def test_begin_end_spans_do_not_touch_the_ambient_stack():
    rec = SpanRecorder("d", clock=_fake_clock())
    with rec.span("outer", "campaign"):
        live = rec.begin("attempt", "unit", unit="u1")
        assert rec.ctx() != live.sid             # stack still on "outer"
        rec.end(live, status="requeued")
    rows = rec.rows()
    attempt = [r for r in rows if r["name"] == "attempt"][0]
    assert attempt["attrs"]["status"] == "requeued"
    assert attempt["parent"] == [r for r in rows
                                 if r["name"] == "outer"][0]["sid"]


def test_load_span_rows_tolerates_torn_tail(tmp_path):
    path = str(tmp_path / "torn.jsonl")
    rec = SpanRecorder("n", path=path, clock=_fake_clock())
    with rec.span("ok", "exec"):
        pass
    rec.close()
    with open(path, "a") as f:
        f.write('{"sid": "n:99", "name": "torn')   # crash mid-append
    rows = load_span_rows(path)
    assert [r["name"] for r in rows] == ["ok"]


def test_ambient_api_is_noop_when_off():
    assert not obs.enabled()
    assert obs.ctx() is None
    assert obs.event("x", "y") is None
    cm = obs.span("x", "y")
    with cm as live:
        assert live is None
    assert obs.span("z", "w") is cm              # shared no-op, no alloc


def test_thread_local_recorder_shadows_process_default_and_suppressed():
    proc = obs.install(SpanRecorder("proc", clock=_fake_clock()))
    local = SpanRecorder("node", clock=_fake_clock())
    assert obs.current() is proc
    obs.install(local, thread_only=True)
    assert obs.current() is local
    with obs.suppressed():
        assert obs.current() is None and not obs.enabled()
    assert obs.current() is local
    obs.uninstall(thread_only=True)
    assert obs.current() is proc


def test_span_records_exception_as_error_attr():
    rec = obs.install(SpanRecorder("d", clock=_fake_clock()))
    with pytest.raises(RuntimeError):
        with obs.span("boom", "exec"):
            raise RuntimeError("nope")
    row = rec.rows()[0]
    assert row["attrs"]["error"] == "RuntimeError"


def test_governor_plan_emits_linked_event():
    from repro.core.latency_table import LatencyTable, analyse_pair
    from repro.dvfs.governor import Governor
    from repro.dvfs.planner import Region
    from repro.dvfs.power_model import PowerModel
    rng = np.random.default_rng(0)
    table = LatencyTable()
    for fi in (500.0, 2000.0):
        for ft in (500.0, 2000.0):
            if fi != ft:
                table.add(analyse_pair(fi, ft,
                                       0.01 * rng.lognormal(0, 0.03, 30)))
    rec = obs.install(SpanRecorder("d", clock=_fake_clock()))
    g = Governor(table, PowerModel(2000.0), [500.0, 2000.0])
    g.plan(Region("memory", 5.0))
    events = [r for r in rec.rows() if r["name"] == "gov.plan"]
    assert len(events) == 1
    attrs = events[0]["attrs"]
    assert {"f_from", "f_to", "reason"} <= set(attrs)
    assert "audit" in attrs                      # None without a traced
    assert attrs["audit"] is None                # backend, but always linked


# ------------------------------------------------------------------ #
# span-tree invariants: deterministic + hypothesis property forms
# ------------------------------------------------------------------ #
def _row(sid, parent, t0, t1, name="s", cat="x", ph="X"):
    return {"sid": sid, "parent": parent, "actor": sid.split(":")[0],
            "name": name, "cat": cat, "ph": ph, "tid": 0,
            "t0": float(t0), "t1": float(t1)}


def _rows_from_plan(plan):
    """(parent_pick, start_frac, dur_frac) triples -> a span forest with
    one fixed root; child intervals may spill outside their parent so the
    clamp path is always exercised."""
    rows = [_row("a:1", None, 0.0, 100.0, name="root", cat="campaign")]
    for i, (pick, f0, f1) in enumerate(plan, start=2):
        parent = rows[pick % len(rows)]
        t0 = -5.0 + f0 * 110.0
        rows.append(_row(f"a:{i}", parent["sid"], t0, t0 + f1 * 40.0))
    return rows


def _assert_tree_invariants(rows):
    roots = build_forest(rows)
    for root in roots:
        for n in walk(root):
            for c in n.children:
                # children clamped into their parent, never inverted
                assert c.t0 >= n.t0 - 1e-9 and c.t1 <= n.t1 + 1e-9
                assert c.t1 >= c.t0
            assert self_time(n) >= 0.0
        segments = critical_path(root)
        total = sum(s.duration for s in segments)
        # the critical path tiles the root exactly: it can never exceed
        # the tree's wall time, and for a single root it equals it
        assert total <= root.duration + 1e-6
        assert abs(total - root.duration) < 1e-6
        if segments:
            assert abs(segments[0].t0 - root.t0) < 1e-9
            assert abs(segments[-1].t1 - root.t1) < 1e-9
            for a, b in zip(segments, segments[1:]):
                assert abs(a.t1 - b.t0) < 1e-9   # contiguous, no overlap
        # every instant is attributed to >= 1 span, so self times can
        # only meet or exceed the root wall (equality when disjoint)
        assert sum(self_time(n) for n in walk(root)) >= root.duration - 1e-6


def test_forest_invariants_on_seeded_random_trees():
    for seed in range(25):
        rng = np.random.default_rng(seed)
        n = int(rng.integers(0, 40))
        plan = [(int(rng.integers(0, 1000)), float(rng.random()),
                 float(rng.random())) for _ in range(n)]
        _assert_tree_invariants(_rows_from_plan(plan))


def test_self_time_sums_to_root_wall_for_disjoint_children():
    for seed in range(25):
        rng = np.random.default_rng(100 + seed)
        rows = []
        counter = [0]

        def build(parent, t0, t1, depth):
            counter[0] += 1
            sid = f"a:{counter[0]}"
            rows.append(_row(sid, parent, t0, t1))
            if depth < 3 and t1 > t0:
                k = int(rng.integers(0, 4))
                if k:
                    cuts = sorted(rng.uniform(t0, t1, 2 * k))
                    for j in range(k):
                        build(sid, cuts[2 * j], cuts[2 * j + 1], depth + 1)

        build(None, 0.0, 100.0, 0)
        (root,) = build_forest(rows)
        total_self = sum(self_time(n) for n in walk(root))
        assert total_self == pytest.approx(root.duration, abs=1e-6)
        crit = sum(s.duration for s in critical_path(root))
        assert crit == pytest.approx(root.duration, abs=1e-6)


def test_prop_forest_invariants_hold_for_arbitrary_plans():
    pytest.importorskip("hypothesis")  # property tests run when installed
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=80, deadline=None)
    @given(st.lists(st.tuples(st.integers(0, 10 ** 6),
                              st.floats(0.0, 1.0), st.floats(0.0, 1.0)),
                    max_size=32))
    def check(plan):
        _assert_tree_invariants(_rows_from_plan(plan))

    check()


def test_prop_critical_path_never_exceeds_any_root():
    pytest.importorskip("hypothesis")  # property tests run when installed
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=60, deadline=None)
    @given(st.lists(st.tuples(st.floats(0.0, 1.0), st.floats(0.0, 1.0)),
                    min_size=1, max_size=24))
    def check(spans):
        # a forest of detached roots (lost parent files): each analyzed
        # root's critical path is bounded by its own wall time
        rows = [_row(f"a:{i + 1}", f"ghost:{i}", 100.0 * f0,
                     100.0 * f0 + 50.0 * f1) for i, (f0, f1)
                in enumerate(spans)]
        for root in build_forest(rows):
            total = sum(s.duration for s in critical_path(root))
            assert total <= root.duration + 1e-6

    check()


def test_analyze_orphan_rows_become_roots_behind_the_campaign_root():
    rows = [
        _row("d:1", None, 0.0, 10.0, name="campaign.run", cat="campaign"),
        _row("d:2", "d:1", 1.0, 9.0, name="unit.attempt", cat="unit"),
        _row("n:1", "lost:7", 2.0, 8.0, name="unit.exec", cat="exec"),
    ]
    doc = analyze(build_forest(rows))
    assert doc["root"]["name"] == "campaign.run"   # longest root wins
    assert doc["spans"] == 3


# ------------------------------------------------------------------ #
# metrics bridge + Perfetto export (synthetic rows)
# ------------------------------------------------------------------ #
def test_bridge_maps_events_to_counters_and_queue_gauges():
    rows = [
        _row("d:1", None, 0.0, 2.0, name="campaign.run", cat="campaign"),
        _row("d:2", "d:1", 0.1, 1.0, name="store.mark", cat="store"),
        _row("d:3", "d:1", 0.2, 0.2, name="sched.requeue", cat="sched",
             ph="i"),
        _row("d:4", "d:1", 0.3, 0.3, name="store.retry", cat="store",
             ph="i"),
        _row("d:5", "d:1", 0.4, 0.4, name="msg.send", cat="msg", ph="i"),
        _row("d:6", "d:1", 0.5, 0.5, name="msg.recv", cat="msg", ph="i"),
        _row("d:7", "d:1", 0.6, 0.6, name="gov.plan", cat="gov", ph="i"),
    ]
    rows[2]["attrs"] = {"queue": 3}
    reg = export_to_registry(rows)
    snap = reg.snapshot()
    assert snap["obs_requeued_units_total"][""] == 1
    assert snap["obs_store_retries_total"][""] == 1
    assert snap["obs_governor_plans_total"][""] == 1
    assert snap["obs_msgs_total"]['{direction="send"}'] == 1
    assert snap["obs_msgs_total"]['{direction="recv"}'] == 1
    assert snap["obs_spans_total"]['{cat="campaign"}'] == 1
    assert snap["obs_spans_total"]['{cat="store"}'] == 1
    assert snap["obs_events_total"]['{name="gov.plan"}'] == 1
    assert snap["obs_queue_depth_peak"][""] == 3.0
    hist = snap["obs_stage_seconds"]['{cat="store"}']
    assert hist["count"] == 1 and hist["sum"] == pytest.approx(0.9)
    # idempotent folding into an existing registry accumulates
    reg2 = export_to_registry(rows, registry=reg)
    assert reg2 is reg
    assert reg.snapshot()["obs_store_retries_total"][""] == 2


def test_trace_event_export_schema_and_relative_timestamps():
    rows = [
        _row("d:1", None, 50.0, 60.0, name="campaign.run", cat="campaign"),
        _row("n:1", "d:1", 51.0, 59.0, name="unit.exec", cat="exec"),
        _row("n:2", "n:1", 52.0, 52.0, name="store.retry", cat="store",
             ph="i"),
    ]
    doc = to_trace_events(rows)
    assert validate_trace_events(doc) == []
    events = doc["traceEvents"]
    metas = [e for e in events if e["ph"] == "M"]
    assert {m["args"]["name"] for m in metas} == {"repro/d", "repro/n"}
    xs = [e for e in events if e["ph"] == "X"]
    assert min(e["ts"] for e in xs) == 0.0       # rebased to the earliest
    exec_ev = [e for e in xs if e["name"] == "unit.exec"][0]
    assert exec_ev["dur"] == pytest.approx(8e6)
    assert exec_ev["args"]["parent"] == "d:1"
    assert validate_trace_events({"traceEvents": []})
    assert validate_trace_events({"traceEvents": [{"ph": "Q"}]})


# ------------------------------------------------------------------ #
# end-to-end: chaos-run merge, bit-identity, dominant-cost naming
# ------------------------------------------------------------------ #
def test_serial_campaign_bit_identical_with_spans_on(tmp_path):
    spec = _fleet(2)
    ref = run_campaign(spec, ArtifactStore(str(tmp_path / "off")))
    assert ref.ok
    cand = CampaignRunner(spec, ArtifactStore(str(tmp_path / "on")),
                          spans=True).run()
    assert cand.ok
    _assert_store_bit_identical(ref, cand)
    assert not ref.campaign.list_span_files()
    files = cand.campaign.list_span_files()
    assert [os.path.basename(p) for p in files] == ["driver.jsonl"]
    rows = collect_span_rows(cand.campaign)
    assert validate_trace_events(to_trace_events(rows)) == []
    doc = analyze(build_forest(rows))
    assert doc["root"]["name"] == "campaign.run"
    # per-pair spans from the measurement session made it into the tree
    assert doc["spans"] > 2 * len(FREQS) * (len(FREQS) - 1)


def test_three_node_chaos_run_merges_into_one_consistent_tree(tmp_path):
    """Node crash + lossy/dup/delayed transport + transient store faults,
    spans on: the store stays bit-identical to a clean serial run, every
    cross-actor parent link resolves in the merged rows, and the requeue
    shows up in the profiled event counters."""
    spec = _fleet(3)
    ref = run_campaign(spec, ArtifactStore(str(tmp_path / "serial")))
    assert ref.ok
    plan = FaultPlan.make(
        node_crash_after_pairs={"u0@fast": 1},
        transport={"drop_rate": 0.05, "dup_rate": 0.05,
                   "delay_s": 0.001, "seed": 7},
        store_transient={"u1@fast": 2})
    cand = CampaignRunner(
        spec, ArtifactStore(str(tmp_path / "chaos")), executor="cluster",
        max_workers=3, heartbeat_timeout_s=5.0, fault_plan=plan,
        spans=True).run()
    assert cand.ok, [(o.key, o.error) for o in cand.failed()]
    assert cand.stats.get("crashed_nodes", 0) >= 1
    _assert_store_bit_identical(ref, cand)

    files = {os.path.basename(p) for p in cand.campaign.list_span_files()}
    assert "driver.jsonl" in files
    assert sum(1 for f in files if f.startswith("node-")) >= 2

    rows = collect_span_rows(cand.campaign)
    sids = {r["sid"] for r in rows}
    orphans = [r for r in rows if r.get("parent") and
               r["parent"] not in sids]
    assert orphans == [], (
        "cross-actor parent links must resolve in the merged rows: "
        + str([(r['sid'], r['parent']) for r in orphans]))
    doc = analyze(build_forest(rows))
    assert doc["root"]["name"] == "campaign.run"
    assert {"driver"} < set(doc["actors"])       # driver + node actors
    assert doc["event_counts"].get("sched.requeue", 0) >= 1
    assert doc["event_counts"].get("store.retry", 0) >= 1
    assert doc["critical_path"]["total_s"] == pytest.approx(
        doc["root"]["wall_s"], rel=1e-6)
    assert validate_trace_events(to_trace_events(rows)) == []


def test_profile_names_the_straggler_as_dominant_cost(tmp_path):
    spec = _fleet(3)
    cand = CampaignRunner(
        spec, ArtifactStore(str(tmp_path / "straggler")),
        executor="cluster", max_workers=3, heartbeat_timeout_s=5.0,
        fault_plan=FaultPlan.make(slow_pairs_s={"u0@fast": 0.15}),
        spans=True).run()
    assert cand.ok, [(o.key, o.error) for o in cand.failed()]
    doc = profile_campaign(cand.campaign)
    dom = doc["dominant"]
    assert dom is not None
    assert dom["label"].startswith("straggler unit u0@fast"), dom["label"]
    assert dom["span"]["unit"] == "u0@fast"
    assert dom["frac"] > 0.3
    md = profile_markdown(doc)
    assert "straggler unit u0@fast" in md


def test_profile_names_the_retry_storm_as_dominant_cost(tmp_path):
    spec = _fleet(2)
    cand = CampaignRunner(
        spec, ArtifactStore(str(tmp_path / "storm")), executor="cluster",
        max_workers=2, heartbeat_timeout_s=5.0,
        retry_policy=RetryPolicy(max_attempts=8, base_s=0.08, cap_s=0.3,
                                 timeout_s=5.0),
        fault_plan=FaultPlan.make(store_transient={"u0@fast": 12}),
        spans=True).run()
    assert cand.ok, [(o.key, o.error) for o in cand.failed()]
    doc = profile_campaign(cand.campaign)
    dom = doc["dominant"]
    assert dom is not None
    assert dom["label"].startswith(
        "remote-store retries / partition healing"), dom["label"]
    assert doc["event_counts"].get("store.retry", 0) >= 12
    # the backoff waits sit inside store spans, so retries dominate
    assert dom["frac"] > 0.4


def test_dead_letters_carry_span_context_into_the_profile(tmp_path):
    spec = _fleet(2)
    cand = CampaignRunner(
        spec, ArtifactStore(str(tmp_path / "dl")), executor="cluster",
        max_workers=2, heartbeat_timeout_s=5.0,
        fault_plan=FaultPlan.make(store_permanent=("u0@fast",)),
        spans=True).run()
    assert not cand.ok                     # the poisoned unit failed ...
    assert "u1@fast" in {o.key for o in cand.outcomes.values()
                         if o.status == "done"}   # ... alone
    doc = profile_campaign(cand.campaign)
    letters = doc["dead_letters"]
    assert letters, "exhausted retries must be dead-lettered"
    linked = [dl for dl in letters if dl["span"]]
    assert linked, "dead letters must carry the active span id"
    for dl in linked:
        assert dl["elapsed_s"] is not None and dl["elapsed_s"] >= 0.0
        assert dl["attempts"] >= 1
        assert isinstance(dl["on_critical_path"], bool)
    md = profile_markdown(doc)
    assert "Dead letters" in md


# ------------------------------------------------------------------ #
# CLI surface: profile + the --json listing/report satellites
# ------------------------------------------------------------------ #
def _write_spec(tmp_path, spec):
    path = str(tmp_path / "spec.json")
    with open(path, "w") as f:
        json.dump(spec.to_dict(), f)
    return path


def test_cli_profile_and_json_surfaces(tmp_path, capsys):
    from repro.campaign.cli import main
    spec = _fleet(1, name="obs-cli")
    spec_path = _write_spec(tmp_path, spec)
    root = str(tmp_path / "store")

    assert main(["--store", root, "run", spec_path, "--quiet"]) == 0
    capsys.readouterr()
    cid = spec.campaign_id()

    # no spans recorded yet: profile exits 1 and says how to fix it
    assert main(["--store", root, "profile", cid]) == 1
    assert "--spans" in capsys.readouterr().out

    # resume the same campaign with spans on, then profile it
    assert main(["--store", root, "run", spec_path, "--quiet",
                 "--spans"]) == 0
    capsys.readouterr()
    perfetto = str(tmp_path / "trace.json")
    metrics = str(tmp_path / "metrics.json")
    assert main(["--store", root, "profile", cid, "--perfetto", perfetto,
                 "--metrics-out", metrics]) == 0
    out = capsys.readouterr().out
    assert "# Campaign profile" in out and "Dominant cost" in out
    with open(perfetto) as f:
        assert validate_trace_events(json.load(f)) == []
    with open(metrics) as f:
        names = set(json.load(f))
    assert "obs_spans_total" in names and "obs_stage_seconds" in names

    assert main(["--store", root, "profile", cid, "--json"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["campaign_id"] == cid
    assert doc["root"]["name"] == "campaign.run"
    assert doc["span_files"] == ["driver.jsonl"]

    assert main(["--store", root, "ls", "--json"]) == 0
    listing = json.loads(capsys.readouterr().out)
    assert [d["campaign_id"] for d in listing] == [cid]
    assert listing[0]["span_files"] == 1
    assert listing[0]["units_done"] == 1

    assert main(["--store", root, "report", "--json", cid]) == 0
    report = json.loads(capsys.readouterr().out)
    assert report["campaign_id"] == cid
    assert report["units_done"] == report["units_total"] == 1
    assert {r["unit"] for r in report["comparison"]} == {"u0@fast"}
    assert "asymmetry" in report

    out_path = str(tmp_path / "profile.md")
    assert main(["--store", root, "profile", cid, "--out", out_path]) == 0
    with open(out_path) as f:
        assert "Dominant cost" in f.read()
