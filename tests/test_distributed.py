"""Real multi-device SPMD correctness: runs a subprocess with 8 host
devices (XLA_FLAGS) and checks that sharded execution is numerically
equivalent to single-device execution for the core paths:

  * train step on a (2,4) ("data","model") mesh == unsharded step
  * flash-decoding (seq-sharded KV, shard_map LSE combine) == plain decode
  * shard_map expert-parallel MoE == local dispatch

This is the strongest runnability evidence the container allows short of
real hardware: the SAME code paths the 512-chip dry-run compiles are
executed and checked for value equality.
"""
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.configs.registry import model_module, decode_module
from repro.launch.specs import abstract_init, make_train_step
from repro.optim import adamw
from repro.parallel.sharding import make_env, param_shardings

mesh = jax.make_mesh((2, 4), ("data", "model"))

def fp32(cfg):
    return dataclasses.replace(cfg, param_dtype=jnp.float32,
                               compute_dtype=jnp.float32)

# ---------------- train step equivalence (llama3 smoke) ----------------- #
cfg = fp32(get_config("llama3-8b", smoke=True))
mod = model_module(cfg)
params, axes = mod.init(jax.random.PRNGKey(0), cfg)
opt = adamw.init(params)
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                      cfg.vocab)}

env1 = make_env(cfg, None)
loss1, p1, _ = jax.jit(make_train_step(cfg, env1))(params, opt, batch)

envN = make_env(cfg, mesh)
p_sh = param_shardings(envN, axes, jax.eval_shape(lambda: params))
params_s = jax.tree.map(lambda x, s: jax.device_put(x, s), params, p_sh)
opt_s = adamw.init(params_s)
batch_s = {"tokens": jax.device_put(batch["tokens"],
                                    NamedSharding(mesh, P("data", None)))}
lossN, pN, _ = jax.jit(make_train_step(cfg, envN))(params_s, opt_s, batch_s)
assert abs(float(loss1) - float(lossN)) < 2e-3, (float(loss1), float(lossN))
d = max(float(jnp.abs(a - np.asarray(b)).max())
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(pN)))
assert d < 2e-3, d
print("train_step sharded==unsharded OK", float(loss1), float(lossN))

# ------------- flash-decoding == plain decode (kv% tp != 0) ------------- #
cfg = fp32(get_config("llama3-8b", smoke=True))   # kv=2, tp=4 -> flash path
dec = decode_module(cfg)
mod = model_module(cfg)
params, axes = mod.init(jax.random.PRNGKey(2), cfg)
b, s, m = 2, 16, 32
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(3), (b, s), 0,
                                      cfg.vocab)}
env1 = make_env(cfg, None)
lg1, cache1 = dec.prefill(params, batch, cfg, env1, m)
tok = jnp.argmax(lg1, -1)[:, None].astype(jnp.int32)
lg1b, _ = dec.decode_step(params, cache1, tok, jnp.int32(s), cfg, env1)

envN = make_env(cfg, mesh)
assert envN.flash_decode, "kv=2 % tp=4 != 0 must enable flash decode"
lgN, cacheN = jax.jit(lambda p, bt: dec.prefill(p, bt, cfg, envN, m))(params, batch)
c_sh = {k: NamedSharding(mesh, envN.spec_sized(ax, cacheN[k].shape))
        for k, ax in dec.cache_spec(cfg, b, m, envN)[1].items()}
cacheN = jax.tree.map(lambda x, s: jax.device_put(x, s), cacheN, c_sh)
lgNb, _ = jax.jit(lambda p, c, t, i: dec.decode_step(p, c, t, i, cfg, envN))(
    params, cacheN, tok, jnp.int32(s))
dd = float(jnp.abs(lg1b - np.asarray(lgNb)).max())
assert dd < 2e-3, dd
print("flash_decode == plain decode OK", dd)

# ------------------- MoE shard_map EP == local dispatch ------------------ #
from repro.models import moe as moe_mod
cfg = fp32(get_config("deepseek-moe-16b", smoke=True))
p, _ = moe_mod.moe_init(jax.random.PRNGKey(4), cfg)
x = jax.random.normal(jax.random.PRNGKey(5), (4, 16, cfg.d_model))
out1, aux1 = moe_mod.moe_apply(p, x, cfg, make_env(cfg, None))
outN, auxN = jax.jit(lambda p, x: moe_mod.moe_apply(p, x, cfg,
                                                    make_env(cfg, mesh)))(p, x)
# EP partitions the capacity per (data-shard, expert): with tokens split
# across 2 data shards the dropping boundary can differ for a few tokens;
# compare the overwhelming majority instead of a strict allclose
diff = jnp.abs(out1 - np.asarray(outN)).max(axis=-1).ravel()
frac_equal = float((diff < 2e-3).mean())
assert frac_equal > 0.95, frac_equal
assert abs(float(aux1) - float(auxN)) < 1e-3
print("moe shard_map ~= local OK", frac_equal)
print("ALL-OK")
"""


@pytest.mark.slow
def test_multi_device_equivalence():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    env["JAX_PLATFORMS"] = "cpu"
    res = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stdout[-3000:] + res.stderr[-3000:]
    assert "ALL-OK" in res.stdout


_ELASTIC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.configs.shapes import ShapeSpec
from repro.parallel.sharding import make_env
from repro.runtime.train_loop import TrainConfig, train
import tempfile, dataclasses

cfg = get_config("llama3-8b", smoke=True)
cfg = dataclasses.replace(cfg, param_dtype=jnp.float32,
                          compute_dtype=jnp.float32)
shape = ShapeSpec("t", 16, 8, "train")

# straight 6-step single-device run = the reference
env0 = make_env(cfg, None)
ref = train(cfg, shape, env0, TrainConfig(steps=6, log_every=100),
            verbose=False)

with tempfile.TemporaryDirectory() as d:
    # 3 steps on a (2,4) mesh, checkpoint...
    mesh_a = jax.make_mesh((2, 4), ("data", "model"))
    env_a = make_env(cfg, mesh_a)
    train(cfg, shape, env_a, TrainConfig(steps=3, checkpoint_every=3,
                                         checkpoint_dir=d, log_every=100),
          verbose=False)
    # ...then ELASTIC RESCALE: resume on a (4,2) mesh (pod loss scenario)
    mesh_b = jax.make_mesh((4, 2), ("data", "model"))
    env_b = make_env(cfg, mesh_b)
    out = train(cfg, shape, env_b, TrainConfig(steps=6, checkpoint_every=100,
                                               checkpoint_dir=d,
                                               log_every=100), verbose=False)
assert out["resumed_at"] == 3, out["resumed_at"]
diff = abs(ref["loss"][-1] - out["loss"][-1])
assert diff < 5e-3, (ref["loss"][-1], out["loss"][-1])
print("ELASTIC-OK", ref["loss"][-1], out["loss"][-1])
"""


@pytest.mark.slow
def test_elastic_rescale_resume():
    """Train on a (2,4) mesh, checkpoint, resume on a (4,2) mesh (pod-loss
    rescale); final loss matches the uninterrupted single-device run."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    env["JAX_PLATFORMS"] = "cpu"
    res = subprocess.run([sys.executable, "-c", _ELASTIC_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stdout[-3000:] + res.stderr[-3000:]
    assert "ELASTIC-OK" in res.stdout
