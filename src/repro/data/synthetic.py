"""Deterministic synthetic token pipeline.

Step-indexed PRNG: batch(step) is a pure function of (seed, step), so a
restarted/elastically-rescaled job resumes bit-identically from a
checkpointed step with no data-state to persist — the fault-tolerance
property large-scale pipelines need (DESIGN.md #4).

The "language" is a second-order Markov chain over the vocab (cheap, yet
gives the LM a learnable signal for the convergence examples/tests).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SyntheticTokens:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    markov_weight: float = 0.8     # P(next = f(prev)) vs uniform

    def batch_at(self, step: int) -> dict:
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        b, s, v = self.global_batch, self.seq_len, self.vocab
        k1, k2, k3 = jax.random.split(key, 3)
        first = jax.random.randint(k1, (b,), 0, v)
        noise = jax.random.randint(k2, (b, s), 0, v)
        use_markov = jax.random.uniform(k3, (b, s)) < self.markov_weight

        def step_fn(prev, xs):
            nz, um = xs
            # deterministic "grammar": affine map over the vocab
            nxt = jnp.where(um, (prev * 31 + 17) % v, nz)
            return nxt, nxt

        _, toks = jax.lax.scan(step_fn, first, (noise.T, use_markov.T))
        return {"tokens": toks.T.astype(jnp.int32)}


def make_batch(cfg, shape, step: int = 0, seed: int = 0) -> dict:
    """Batch for an (arch config, ShapeSpec) cell, incl. modality stubs."""
    ds = SyntheticTokens(cfg.vocab, shape.seq_len, shape.global_batch, seed)
    batch = ds.batch_at(step)
    key = jax.random.fold_in(jax.random.PRNGKey(seed + 1), step)
    if cfg.family == "vlm":
        batch["img_embeds"] = jax.random.normal(
            key, (shape.global_batch, cfg.vlm.n_patches, cfg.d_model),
            cfg.compute_dtype)
    if cfg.family == "encdec":
        batch["enc_frames"] = jax.random.normal(
            key, (shape.global_batch, cfg.encdec.n_frames, cfg.d_model),
            cfg.compute_dtype)
    return batch
