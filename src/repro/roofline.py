"""Roofline terms from compiled dry-run artifacts (TPU v5e-class target).

  compute    = HLO_FLOPs_per_chip / PEAK_FLOPS
  memory     = HLO_bytes_per_chip / HBM_BW
  collective = collective_link_bytes_per_chip / ICI_BW

MODEL_FLOPS = 6 N D (train) / 2 N D (per generated/prefilled token), with
N = active params for MoE.  MODEL_FLOPS/HLO_FLOPs exposes remat/redundancy
waste (a ratio of 0.75 under full remat is expected: fwd+2bwd+refwd).
"""
from __future__ import annotations

import dataclasses

PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link (per-chip serialization proxy)


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: float
    model_flops_total: float
    chips: int

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline-optimistic step time: max of the three terms (assumes
        perfect overlap of compute, HBM and ICI)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def model_flops_ratio(self) -> float:
        total_hlo = self.flops_per_chip * self.chips
        return self.model_flops_total / total_hlo if total_hlo else 0.0

    @property
    def mfu(self) -> float:
        """Model-FLOPs utilization at the roofline-optimistic step time."""
        t = self.step_time_s
        if t <= 0:
            return 0.0
        return self.model_flops_total / (t * self.chips * PEAK_FLOPS)

    def as_dict(self) -> dict:
        return {
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "flops_per_chip": self.flops_per_chip,
            "bytes_per_chip": self.bytes_per_chip,
            "coll_bytes_per_chip": self.coll_bytes_per_chip,
            "model_flops_total": self.model_flops_total,
            "model_flops_ratio": self.model_flops_ratio,
            "mfu_roofline": self.mfu,
            "step_time_s": self.step_time_s,
            "chips": self.chips,
        }


def model_flops(cfg, shape) -> float:
    """6 N D for train, 2 N D for prefill/decode tokens (matmul convention;
    attention score/V FLOPs excluded by definition)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence per step
    return 2.0 * n * shape.global_batch


def terms_from_analysis(cost: dict, coll_link_bytes: float, chips: int,
                        mflops: float) -> RooflineTerms:
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    return RooflineTerms(
        compute_s=flops / PEAK_FLOPS,
        memory_s=byts / HBM_BW,
        collective_s=coll_link_bytes / ICI_BW,
        flops_per_chip=flops,
        bytes_per_chip=byts,
        coll_bytes_per_chip=coll_link_bytes,
        model_flops_total=mflops,
        chips=chips,
    )
