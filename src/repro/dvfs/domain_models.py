"""Transition models for heterogeneous frequency-domain devices.

The single-domain models in :mod:`repro.dvfs.transition_models` are keyed
by bare MHz; the two families here work in the encoded operating-point
space of :mod:`repro.core.freqkey`, where a key names ONE domain's setting
with every other domain at its default:

  MultiDomainModel     independent core and uncore/memory clock ladders
                       ("Exploring Uncore Frequency Scaling for
                       Heterogeneous Computing", PAPERS.md): core
                       transitions are fast PLL relocks, uncore transitions
                       retrain the fabric/memory path and run ~4-6x slower,
                       and a cross-domain move pays BOTH legs plus a
                       coupling penalty (the domains handshake).

  PStateClusterModel   m1n1-style per-cluster pstate registers
                       (AsahiLinux cpu_pstate_latencies.py): e-core and
                       p-core clusters with different frequency ladders,
                       per-cluster ramp cost roughly linear in the MHz
                       distance, and a migration cost when the workload's
                       operating point hops clusters.

Both expose ``effective_frequency(key)``: the workload-visible clock rate
at an operating point, which the device subclasses commit to their event
timelines (``SimulatedAccelerator._timeline_freq``) so the unmodified wait
evaluators, trace recorder and batched stats all keep working in plain
duration space.  Like the GPU models, everything deterministic derives
from ``_pair_hash`` so ground truth is reproducible per (pair, unit_seed).
"""
from __future__ import annotations

import dataclasses

from repro.core.freqkey import (DOMAIN_STRIDE, domain_index, freq_domain,
                                freq_mhz, split_freq)
from repro.dvfs.transition_models import TransitionModel, _pair_hash


def _encode_raw(domain: str, mhz: float) -> float:
    """Encode without the whole-MHz guard: trajectory intermediates and
    thermal caps may be off-ladder values that never become dict keys."""
    return DOMAIN_STRIDE * domain_index(domain) + float(mhz)


@dataclasses.dataclass
class MultiDomainModel(TransitionModel):
    """Core + uncore clock domains with interacting transitions.

    Latency structure (all pair-hash spread, per unit_seed):

    * core->core: 3.5-5 ms down, 7-13 ms up (PLL relock; a100-ish)
    * uncore->uncore: 22-28 ms down, 30-40 ms up (fabric retrain)
    * cross-domain: the leaving domain returns to its default AND the
      entering domain ramps — both legs serialized, scaled by a 1.15-1.35x
      coupling factor.  The trajectory passes through the all-default
      operating point when the core leg completes first.

    ``uncore_floor`` sets how much of the workload's throughput survives
    the slowest uncore setting: effective rate at ``("uncore", v)`` is
    ``core_default * (floor + (1 - floor) * v / uncore_default)``.
    """

    name: str = "multidomain"
    core_default: float = 1500.0
    uncore_default: float = 750.0
    uncore_floor: float = 0.45
    coupling: float = 1.15          # cross-domain penalty floor
    comm_delay_s: float = 50e-6
    wakeup_s: float = 8e-3

    # ---------------------------------------------------------------- #
    # operating point -> workload-visible clock
    # ---------------------------------------------------------------- #
    def _uncore_scale(self, v: float) -> float:
        return self.uncore_floor + \
            (1.0 - self.uncore_floor) * v / self.uncore_default

    def effective_frequency(self, key: float) -> float:
        domain, mhz = split_freq(key)
        if domain in (None, "core"):
            return mhz * self._uncore_scale(self.uncore_default)   # = mhz
        if domain == "uncore":
            return self.core_default * self._uncore_scale(mhz)
        raise ValueError(
            f"multi-domain model has no domain {domain!r} "
            "(core | uncore)")

    @property
    def default_key(self) -> float:
        """The all-default operating point (cross-domain waypoint)."""
        return _encode_raw("core", self.core_default)

    # ---------------------------------------------------------------- #
    # switching latency
    # ---------------------------------------------------------------- #
    def _leg(self, domain: str, v_from: float, v_to: float) -> float:
        """One domain's ladder move, in seconds."""
        if v_from == v_to:
            return 0.0
        u = _pair_hash(v_from, v_to, self.unit_seed + domain_index(domain))
        if domain == "core":
            if v_to < v_from:
                return 3.5e-3 + 1.5e-3 * u
            return 7.0e-3 + 6.0e-3 * u
        if v_to < v_from:
            return 22e-3 + 6e-3 * u
        return 30e-3 + 10e-3 * u

    def _default_of(self, domain: str) -> float:
        return self.core_default if domain == "core" else self.uncore_default

    def base_latency(self, f_from: float, f_to: float) -> float:
        da, va = split_freq(f_from)
        db, vb = split_freq(f_to)
        da, db = da or "core", db or "core"
        if da == db:
            return self._leg(da, va, vb)
        # cross-domain: domain `da` returns to default, `db` ramps from
        # default to vb; legs serialize and couple
        u = _pair_hash(f_from, f_to, self.unit_seed + 11)
        legs = self._leg(da, va, self._default_of(da)) \
            + self._leg(db, self._default_of(db), vb)
        return legs * (self.coupling + 0.2 * u)

    def sample_latency(self, f_from, f_to, rng):
        base = self.base_latency(f_from, f_to)
        da, db = freq_domain(f_from), freq_domain(f_to)
        sigma = 0.04 if da == db == "core" else \
            0.05 if da == db else 0.07
        return float(base * rng.lognormal(0.0, sigma))

    def trajectory(self, f_from, f_to, latency, rng):
        if freq_domain(f_from) == freq_domain(f_to):
            return [(latency, f_to)]
        # the leaving domain's leg lands first: the device passes through
        # the all-default operating point before the target domain settles
        return [(0.45 * latency, self.default_key), (latency, f_to)]


@dataclasses.dataclass
class PStateClusterModel(TransitionModel):
    """Per-cluster pstate-register transitions, m1n1-style.

    A cluster's pstate write costs a fixed register/handshake overhead
    plus a ramp roughly linear in the MHz distance; the e-cluster ramps
    cheaper than the p-cluster, increases cost more than decreases (the
    voltage regulator leads the clock on the way up), and a cross-cluster
    move — the workload's operating point migrating between clusters —
    pays both clusters' legs plus a fixed migration cost.

    ``effective_frequency`` models the clusters' IPC gap: the workload
    runs on the named cluster, so ``("ecore", v)`` delivers
    ``v * e_ipc`` while ``("pcore", v)`` delivers ``v * p_ipc``.
    """

    name: str = "pstate"
    e_ipc: float = 0.55
    p_ipc: float = 1.0
    e_base_s: float = 0.45e-3        # register write + uncontended ramp
    p_base_s: float = 0.7e-3
    e_ramp_s_per_mhz: float = 0.9e-6
    p_ramp_s_per_mhz: float = 1.1e-6
    up_factor: float = 1.4           # regulator leads the clock going up
    migrate_s: float = 2.5e-3        # cross-cluster workload migration
    e_default: float = 2064.0
    p_default: float = 3204.0
    comm_delay_s: float = 20e-6      # MMIO register write, not a driver RPC
    wakeup_s: float = 2e-3

    def effective_frequency(self, key: float) -> float:
        domain, mhz = split_freq(key)
        if domain in (None, "pcore"):
            return mhz * self.p_ipc
        if domain == "ecore":
            return mhz * self.e_ipc
        raise ValueError(
            f"pstate model has no cluster {domain!r} (ecore | pcore)")

    @property
    def default_key(self) -> float:
        return _encode_raw("pcore", self.p_default)

    def _leg(self, cluster: str, v_from: float, v_to: float) -> float:
        if v_from == v_to:
            return 0.0
        base, ramp = ((self.e_base_s, self.e_ramp_s_per_mhz)
                      if cluster == "ecore"
                      else (self.p_base_s, self.p_ramp_s_per_mhz))
        u = _pair_hash(v_from, v_to, self.unit_seed + domain_index(cluster))
        lat = base + ramp * abs(v_to - v_from)
        if v_to > v_from:
            lat *= self.up_factor
        return lat * (0.9 + 0.2 * u)

    def _default_of(self, cluster: str) -> float:
        return self.e_default if cluster == "ecore" else self.p_default

    def base_latency(self, f_from: float, f_to: float) -> float:
        ca, va = split_freq(f_from)
        cb, vb = split_freq(f_to)
        ca, cb = ca or "pcore", cb or "pcore"
        if ca == cb:
            return self._leg(ca, va, vb)
        return self.migrate_s + self._leg(ca, va, self._default_of(ca)) \
            + self._leg(cb, self._default_of(cb), vb)

    def sample_latency(self, f_from, f_to, rng):
        base = self.base_latency(f_from, f_to)
        return float(base * rng.lognormal(0.0, 0.03))

    def trajectory(self, f_from, f_to, latency, rng):
        if freq_domain(f_from, "pcore") == freq_domain(f_to, "pcore"):
            return [(latency, f_to)]
        return [(0.5 * latency, self.default_key), (latency, f_to)]
