"""Simulated accelerator with an independent clock, uniform core frequency,
asynchronous host->device frequency-change commands, wake-up ramps,
throttling and per-core timestamped kernels.

The host-side API mirrors what a CUDA/NVML (or future TPU-platform) backend
would expose, so `repro.core` never sees simulation internals:

  host_now() / usleep(dt)         host clock
  set_frequency(mhz)              async: arrives after comm_delay, completes
                                  after a model-sampled switching latency
  launch_kernel(spec)             non-blocking; device busy until finished
  wait(handle)                    -> per-core (start, end) device timestamps,
                                  quantized to the device timer resolution
  sync_exchange()                 one IEEE-1588 two-way message exchange
  throttle_reasons()              flags since last call (paper §VI checks
                                  every 5 passes)

Kernel timestamps are evaluated lazily at wait() time, when the full
frequency-event history is known.
"""
from __future__ import annotations

import bisect
import dataclasses

import numpy as np

from repro.dvfs.transition_models import TransitionModel


@dataclasses.dataclass(frozen=True)
class DeviceConfig:
    n_cores: int = 108
    frequencies: tuple[float, ...] = tuple(np.arange(210.0, 1411.0, 15.0))
    idle_freq: float | None = None        # default: min frequency
    timer_resolution_s: float = 1e-6      # CUDA global timer ~1 us
    iter_noise_sigma: float = 0.02        # per-iteration lognormal sigma
    core_skew_s: float = 2e-6             # start skew across cores
    launch_overhead_s: float = 8e-6
    outlier_prob: float = 0.002           # driver-event spikes
    outlier_scale: float = 6.0
    clock_offset_s: float = 1.234         # device clock = host + offset
    clock_drift: float = 2e-7             # + drift * elapsed
    link_jitter_s: float = 4e-6           # sync-message jitter
    idle_timeout_s: float = 0.05
    thermal_throttle_prob: float = 0.0    # per-kernel; tests can raise it
    power_throttle_freqs: tuple[float, ...] = ()
    wait_impl: str = "vectorized"         # "vectorized" | "loop" (reference)


@dataclasses.dataclass
class KernelHandle:
    start_dev: float
    n_iters: int
    base_iter_s: float
    seq: int


class SimulatedAccelerator:
    def __init__(self, model: TransitionModel, cfg: DeviceConfig, seed: int = 0):
        self.model = model
        self.cfg = cfg
        self.rng = np.random.default_rng(seed)
        self._host_t = 0.0
        self._t0 = 0.0
        idle = cfg.idle_freq if cfg.idle_freq is not None else min(cfg.frequencies)
        self._idle_freq = idle
        self._set_freq = idle
        self._freq_set = frozenset(cfg.frequencies)
        # committed frequency timeline: sorted [(device_time, freq)], with
        # parallel times/freqs lists so lookups bisect and batch padding
        # slices without rebuilding arrays or unpacking tuples.  Entries are
        # *timeline* frequencies — what iteration durations scale by — which
        # for this class is the setpoint itself (_timeline_freq is identity);
        # multi-domain subclasses map operating-point keys to an effective
        # clock rate here instead.
        idle_eff = self._timeline_freq(idle)
        self._events: list[tuple[float, float]] = [(-np.inf, idle_eff)]
        self._ev_t: list[float] = [-np.inf]
        self._ev_f: list[float] = [idle_eff]
        self._busy_until_dev = -np.inf
        self._last_activity_dev = -np.inf
        self._seq = 0
        self._throttle_flags: set[str] = set()
        self._pending_power_throttle = False
        self.history: list[dict] = []     # ground-truth transition log

    @property
    def frequencies(self) -> tuple[float, ...]:
        """Supported core frequencies (the AcceleratorBackend contract)."""
        return self.cfg.frequencies

    # ------------------------------------------------------------------ #
    # clocks
    # ------------------------------------------------------------------ #
    def host_now(self) -> float:
        return self._host_t

    def _dev_time(self, host_t: float) -> float:
        c = self.cfg
        return host_t + c.clock_offset_s + c.clock_drift * (host_t - self._t0)

    def dev_now(self) -> float:
        return self._dev_time(self._host_t)

    def usleep(self, dt: float) -> None:
        self._host_t += dt

    def sync_exchange(self) -> tuple[float, float, float, float]:
        """One two-way delay-request exchange (IEEE 1588)."""
        j = self.cfg.link_jitter_s
        t1 = self._host_t
        d1 = self.model.comm_delay_s + self.rng.uniform(0, j)
        t2 = self._dev_time(t1 + d1)
        proc = 2e-6
        t3 = t2 + proc
        d2 = self.model.comm_delay_s + self.rng.uniform(0, j)
        self._host_t = t1 + d1 + proc + d2
        t4 = self._host_t
        return t1, t2, t3, t4

    # ------------------------------------------------------------------ #
    # frequency control
    # ------------------------------------------------------------------ #
    def _timeline_freq(self, f: float) -> float:
        """Map a frequency *setpoint* to the timeline frequency iteration
        durations scale by (``dur = base * f_max / f_timeline``).  Identity
        here — a setpoint IS the core clock.  Heterogeneous backends
        (``multi-domain-sim``, ``pstate-sim``) override this to translate a
        domain-encoded operating point (:mod:`repro.core.freqkey`) into the
        workload-visible effective clock rate, keeping every timeline
        consumer (the wait evaluators, the trace recorder's event stream)
        untouched."""
        return f

    def _f_max(self) -> float:
        """The timeline frequency iteration durations are normalized to
        (``base_iter_s`` is the duration at ``_f_max``).  Identity pairing
        of :meth:`_timeline_freq`: ``max(frequencies)`` here, the best
        effective rate over all operating points for multi-domain
        subclasses."""
        return max(self.cfg.frequencies)

    def _thermal_cap(self) -> float:
        """Setpoint a thermal-throttle event caps the device to.  Single
        clock domain: 80% of the top frequency (or the current setpoint if
        already below)."""
        return min(self._set_freq, 0.8 * max(self.cfg.frequencies))

    def _freq_at(self, t_dev: float) -> float:
        i = bisect.bisect_right(self._ev_t, t_dev) - 1
        return self._events[max(0, i)][1]

    def _commit(self, t_dev: float, freq: float) -> None:
        # drop any scheduled events after t_dev (a new command overrides);
        # the common case appends past the end and prunes nothing
        ev_t = self._ev_t
        if t_dev < ev_t[-1]:
            i = bisect.bisect_right(ev_t, t_dev)
            del self._events[i:], ev_t[i:], self._ev_f[i:]
        self._events.append((t_dev, freq))
        ev_t.append(t_dev)
        self._ev_f.append(freq)

    def set_frequency(self, mhz: float) -> None:
        """Issue the (async) frequency-change command from the host."""
        if mhz not in self._freq_set:
            raise ValueError(f"unsupported frequency {mhz}")
        arrive_dev = self._dev_time(self._host_t) + self.model.comm_delay_s
        f_from = self._set_freq
        lat = self.model.sample_latency(f_from, mhz, self.rng)
        for dt, f in self.model.trajectory(f_from, mhz, lat, self.rng):
            self._commit(arrive_dev + dt, self._timeline_freq(f))
        self._set_freq = mhz
        if mhz in self.cfg.power_throttle_freqs:
            self._pending_power_throttle = True
        self.history.append({
            "host_t": self._host_t, "arrive_dev": arrive_dev,
            "from": f_from, "to": mhz, "true_latency": lat,
            "target_reached_dev": arrive_dev + lat,
        })
        # issuing the command costs the host the comm round-trip
        self._host_t += self.model.comm_delay_s

    def throttle_reasons(self) -> set[str]:
        flags, self._throttle_flags = self._throttle_flags, set()
        return flags

    # ------------------------------------------------------------------ #
    # kernels
    # ------------------------------------------------------------------ #
    def launch_kernel(self, n_iters: int, base_iter_s: float) -> KernelHandle:
        """Enqueue a kernel of n_iters iterations; each iteration costs
        base_iter_s at max frequency, scaled by f_max/f(t)."""
        now_dev = self.dev_now() + self.cfg.launch_overhead_s
        start = max(now_dev, self._busy_until_dev)
        # wake-up: device idles down after idle_timeout without work
        if (start - max(self._last_activity_dev, -1e18)) > self.cfg.idle_timeout_s \
                and self._set_freq != self._idle_freq:
            # device had fallen back to idle; it ramps back up after wake-up
            self._commit(start, self._timeline_freq(self._idle_freq))
            self._commit(start + self.model.wakeup_s,
                         self._timeline_freq(self._set_freq))
        if self.cfg.thermal_throttle_prob > 0 and \
                self.rng.random() < self.cfg.thermal_throttle_prob:
            self._throttle_flags.add("thermal")
            self._commit(start, self._timeline_freq(self._thermal_cap()))
            self._commit(start + 5e-3, self._timeline_freq(self._set_freq))
        if self._pending_power_throttle:
            self._throttle_flags.add("power")
        h = KernelHandle(start_dev=start, n_iters=n_iters,
                         base_iter_s=base_iter_s, seq=self._seq)
        self._seq += 1
        return h

    def _wait_draw(self, h: KernelHandle) -> tuple[np.ndarray, np.ndarray]:
        """Consume this kernel's measurement-noise draws: per-core start
        skew and per-iteration noise (with driver-event spikes applied).
        Factored out of :meth:`wait` so batched schedulers
        (:mod:`repro.core.batched_sweep`) replicate the exact RNG stream
        per lane while evaluating many devices' timestamps in one numpy
        program."""
        c = self.cfg
        n, it = c.n_cores, h.n_iters
        t0 = self.rng.uniform(0, c.core_skew_s, n)
        t0 += h.start_dev
        noise = self.rng.lognormal(0.0, c.iter_noise_sigma, (n, it))
        spikes = self.rng.random((n, it)) < c.outlier_prob
        # driver-event spikes, sparse: masked in-place multiply (same bits
        # as fancy-index assignment, no gather/scatter copies)
        np.multiply(noise, c.outlier_scale, out=noise, where=spikes)
        return t0, noise

    def _wait_finalize(self, end_dev: float) -> None:
        """Commit a finished kernel's end time: device busy/activity marks
        plus the host clock blocking until completion.  The second half of
        the :meth:`wait` split (see :meth:`_wait_draw`)."""
        c = self.cfg
        self._busy_until_dev = end_dev
        self._last_activity_dev = end_dev
        # host blocks until completion
        host_end = end_dev - c.clock_offset_s - c.clock_drift * (self._host_t - self._t0)
        self._host_t = max(self._host_t, host_end)

    def wait(self, h: KernelHandle) -> np.ndarray:
        """Block until the kernel finishes; returns device timestamps
        (n_cores, n_iters, 2) [start, end], timer-quantized."""
        c = self.cfg
        f_max = self._f_max()
        t0, noise = self._wait_draw(h)
        ev_t = np.array(self._ev_t)
        ev_f = np.array(self._ev_f)
        if c.wait_impl == "loop":
            bounds = self._eval_timestamps_loop(
                h.base_iter_s, t0, noise, ev_t, ev_f, f_max)
        else:
            bounds = self._eval_timestamps_vectorized(
                h.base_iter_s, t0, noise, ev_t, ev_f, f_max)
        # iteration i runs [bounds[:, i], bounds[:, i+1]]
        starts, ends = bounds[:, :-1], bounds[:, 1:]
        self._wait_finalize(float(bounds[:, -1].max()))
        q = c.timer_resolution_s
        out = np.stack([starts, ends], axis=-1)
        out /= q                               # quantize in place
        np.floor(out, out=out)
        out *= q
        return out

    @staticmethod
    def _eval_timestamps_loop(base_iter_s, t0, noise, ev_t, ev_f, f_max):
        """Seed reference: one Python pass per iteration, frequency looked up
        at each iteration's start time.  Returns the (n_cores, n_iters + 1)
        iteration-boundary timestamps (iteration i runs bounds[:, i] ..
        bounds[:, i+1])."""
        n, it = noise.shape
        t = t0.copy()
        bounds = np.empty((n, it + 1))
        bounds[:, 0] = t
        for i in range(it):
            idx = np.searchsorted(ev_t, t, side="right") - 1
            f = ev_f[np.maximum(idx, 0)]
            dur = base_iter_s * (f_max / f) * noise[:, i]
            t = t + dur
            bounds[:, i + 1] = t
        return bounds

    @staticmethod
    def _eval_timestamps_vectorized(base_iter_s, t0, noise, ev_t, ev_f, f_max):
        """Segment-wise cumulative-sum evaluation: the frequency timeline is
        piecewise constant, so all iterations a core starts inside one
        segment share one duration scale and their end times are a running
        sum.  One numpy pass per crossed segment instead of one Python pass
        per iteration; bit-identical to the loop reference (cumsum with the
        carried-in start time prepended performs the same left-to-right
        additions, and frequency is still sampled at each iteration start).
        """
        n, it = noise.shape
        bounds = np.empty((n, it + 1))
        bounds[:, 0] = t0
        t = t0.copy()
        done = np.zeros(n, dtype=np.int64)
        while (done < it).any():
            # cores sharing the same progress form a group whose remaining
            # noise is one contiguous slice — no per-core gather needed; the
            # start-time skew is tiny, so there are at most 2 such groups
            for d in np.unique(done):
                d = int(d)
                if d >= it:
                    continue
                g = np.nonzero(done == d)[0]
                whole = len(g) == n
                tg = t if whole else t[g]
                seg = np.maximum(
                    np.searchsorted(ev_t, tg, side="right") - 1, 0)
                scale = base_iter_s * (f_max / ev_f[seg])
                nxt = np.minimum(seg + 1, len(ev_t) - 1)
                seg_end = np.where(seg + 1 < len(ev_t), ev_t[nxt], np.inf)
                last = np.isinf(seg_end).all()
                w = it - d
                if not last:
                    # clamp the evaluation window to roughly the iterations
                    # that fit in this segment; an undershoot is benign —
                    # the leftovers are picked up by the next pass, still
                    # inside the same segment
                    est = np.max((seg_end - tg) / scale) * 1.05
                    if np.isfinite(est):
                        w = min(w, max(int(est) + 2, 1))
                if whole:
                    # candidate boundaries computed in place in the output:
                    # entries past this segment are provisional and get
                    # overwritten by the pass that owns them
                    cand = bounds[:, d:d + w + 1]
                    cand[:, 0] = t
                    np.multiply(noise[:, d:d + w], scale[:, None],
                                out=cand[:, 1:])
                else:
                    cand = np.empty((len(g), w + 1))
                    cand[:, 0] = tg
                    np.multiply(noise[g, d:d + w], scale[:, None],
                                out=cand[:, 1:])
                np.add.accumulate(cand, axis=1, out=cand)
                if last:                           # final segment: all fit
                    cnt = np.full(len(g), w, dtype=np.int64)
                else:
                    # an iteration starting exactly at seg_end belongs to
                    # the next segment (searchsorted side="right"), so
                    # strict <; the mask is a per-row prefix since starts
                    # are increasing
                    cnt = (cand[:, :-1] < seg_end[:, None]).sum(axis=1)
                if not whole:
                    # write back the valid prefix (+ its closing boundary)
                    m = np.arange(w + 1)[None, :] <= cnt[:, None]
                    cols = (g[:, None] * (it + 1) + d
                            + np.arange(w + 1)[None, :])[m]
                    bounds.flat[cols] = cand[m]
                adv = cand[np.arange(len(g)), cnt]     # fancy index: a copy
                if whole:
                    t = adv
                else:
                    t[g] = adv
                done[g] = d + cnt
        return bounds

    # convenience: blocking run
    def run_kernel(self, n_iters: int, base_iter_s: float) -> np.ndarray:
        return self.wait(self.launch_kernel(n_iters, base_iter_s))
