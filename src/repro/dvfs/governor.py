"""Energy-aware DVFS governor driven by the MEASURED switching-latency
table — the runtime system the paper motivates (§I, §VIII).

Two decisions per region boundary:
  1. *Timing* — only request a change when the upcoming region lasts at
     least ``hysteresis x worst-case-latency(cur -> tgt)``; shorter regions
     can't amortize the transition (and re-requesting mid-transition leaves
     the clock undefined — COUNTDOWN's Haswell observation, paper §III).
  2. *Pair avoidance* — pairs whose worst-case latency exceeds the
     ``avoid_percentile`` of the table are never used directly; the
     governor picks the nearest allowed target instead (paper §VIII:
     "the runtime system may avoid some frequency transitions, which show
     overhead higher than other frequency pairs").

``simulate`` integrates energy x time over a region stream for this
governor vs. two baselines (latency-oblivious switcher, static f_max);
benchmarks/governor_energy.py reports the comparison.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro import obs
from repro.dvfs.planner import Region
from repro.dvfs.power_model import PowerModel


@dataclasses.dataclass(frozen=True)
class GovernorConfig:
    hysteresis: float = 3.0            # region must be >= h x latency
    avoid_percentile: float = 95.0     # worst-case latency cap
    max_slowdown: float = 1.05
    default_latency_s: float = 0.1     # when a pair was never measured


@dataclasses.dataclass
class GovernorStats:
    switches: int = 0
    suppressed_short: int = 0
    avoided_pairs: int = 0
    energy_j: float = 0.0
    time_s: float = 0.0
    switch_overhead_s: float = 0.0


class Governor:
    def __init__(self, table, power: PowerModel, frequencies,
                 cfg: GovernorConfig = GovernorConfig()):
        self.table = table
        self.power = power
        self.freqs = sorted(frequencies)
        self.cfg = cfg
        ok = [p.worst_case for p in table.pairs.values()
              if p.status == "ok" and p.clean.size]
        self._avoid_cap = (np.percentile(ok, cfg.avoid_percentile)
                          if ok else float("inf"))
        self._f_cur: float | None = None   # planned frequency; None until
                                           # the first plan() call

    def plan(self, region: Region, device=None) -> float:
        """One region-boundary decision: pick the target for ``region``
        from the currently planned frequency, issue the change on
        ``device`` when one is needed, and track the new state.  The one
        entry point for runtime loops (train/serve/continuous batching).

        The first call always issues a command: the device may boot at its
        idle frequency, which the governor cannot observe — planning from
        max(freqs) without aligning the device would leave it idling.

        When ``device`` is a :class:`repro.trace.recorder.TracedBackend`
        (anything exposing ``record_plan``) the decision — including the
        *reason*, which a frequency timeline alone cannot show — is audited
        into the telemetry trace before any command is issued."""
        f_cur = self._f_cur if self._f_cur is not None else max(self.freqs)
        tgt, reason = self.pick_target(region, f_cur)
        audit = getattr(device, "record_plan", None)
        audit_id = None
        if audit is not None:
            audit_id = audit(f_from=f_cur, f_to=tgt, reason=reason,
                             region_kind=region.kind,
                             duration_s=region.duration_s)
        if obs.enabled():
            # span-profiler hook, linked to the telemetry trace's plan
            # audit stream by the event index record_plan returned
            obs.event("gov.plan", "gov", f_from=f_cur, f_to=tgt,
                      reason=reason, region_kind=region.kind,
                      audit=audit_id)
        if device is not None and tgt != self._f_cur:
            device.set_frequency(tgt)
        self._f_cur = tgt
        return tgt

    @classmethod
    def from_campaign(cls, campaign, device_key: str,
                      power: PowerModel | None = None,
                      cfg: GovernorConfig = GovernorConfig()) -> "Governor":
        """Build a governor from a *stored* campaign's measured table — the
        fleet deployment path: measurement ran elsewhere (or earlier), the
        runtime only reads artifacts.

        ``campaign`` is a :class:`repro.campaign.store.Campaign` handle or
        a campaign id resolved through the default store; ``device_key``
        is a unit key (``"a100@fast"``) or a device key (``"a100"``, which
        must match exactly one finished unit).
        """
        if isinstance(campaign, str):
            from repro.campaign.store import ArtifactStore
            campaign = ArtifactStore().load(campaign)
        done = campaign.done_units()
        if device_key in done:
            unit_key = device_key
        else:
            matches = [k for k in done if k.split("@", 1)[0] == device_key]
            if len(matches) != 1:
                raise KeyError(
                    f"device_key {device_key!r} matches {matches or 'no'} "
                    f"finished unit(s) of campaign {campaign.campaign_id} "
                    f"(have: {done}); pass a full unit key")
            unit_key = matches[0]
        table = campaign.load_table(unit_key)
        freqs = sorted({f for pair in table.pairs for f in pair})
        if not freqs:
            raise ValueError(f"unit {unit_key!r} of campaign "
                             f"{campaign.campaign_id} has no measured pairs")
        if power is None:
            power = PowerModel(f_max_mhz=max(freqs))
        return cls(table, power, freqs, cfg)

    @classmethod
    def from_session(cls, session, power: PowerModel | None = None,
                     cfg: GovernorConfig = GovernorConfig(),
                     **run_kwargs) -> "Governor":
        """Build a governor straight from a MeasurementSession: runs (or
        resumes) the sweep and derives frequencies/power from the session,
        so runtimes never touch the device or table plumbing directly."""
        table = session.run(**run_kwargs)
        freqs = sorted(session.frequencies)
        if power is None:
            power = PowerModel(f_max_mhz=max(freqs))
        return cls(table, power, freqs, cfg)

    # ------------------------------------------------------------------ #
    def latency(self, f_from: float, f_to: float) -> float:
        pr = self.table.lookup(f_from, f_to)
        if pr is None or not pr.clean.size:
            return self.cfg.default_latency_s
        return pr.worst_case

    def allowed(self, f_from: float, f_to: float) -> bool:
        return self.latency(f_from, f_to) <= self._avoid_cap

    def pick_target(self, region: Region, f_cur: float) -> tuple[float, str]:
        """(frequency to run the region at, reason)."""
        f_star = self.power.best_frequency(region.duration_s,
                                           region.sensitivity, self.freqs,
                                           max_slowdown=self.cfg.max_slowdown)
        if f_star == f_cur:
            return f_cur, "already_optimal"
        # timing rule
        if region.duration_s < self.cfg.hysteresis * self.latency(f_cur, f_star):
            return f_cur, "too_short"
        # pair-avoidance rule: walk toward f_cur until the pair is allowed
        cand = sorted(self.freqs, key=lambda f: abs(f - f_star))
        for f in cand:
            if f == f_cur:
                return f_cur, "avoided_all"
            if self.allowed(f_cur, f):
                ok_reason = "optimal" if f == f_star else "avoid_detour"
                # re-check timing for the detour target
                if region.duration_s >= self.cfg.hysteresis * self.latency(f_cur, f):
                    return f, ok_reason
        return f_cur, "avoided_all"

    # ------------------------------------------------------------------ #
    def simulate(self, regions: list[Region], f_start: float | None = None
                 ) -> GovernorStats:
        f = f_start if f_start is not None else max(self.freqs)
        st = GovernorStats()
        for r in regions:
            tgt, reason = self.pick_target(r, f)
            if reason == "too_short":
                st.suppressed_short += 1
            if reason in ("avoid_detour", "avoided_all"):
                st.avoided_pairs += 1
            if tgt != f:
                lat = self.latency(f, tgt)
                # during the transition the region runs at the OLD frequency
                st.switch_overhead_s += lat
                t_old = min(lat, self.power.region_time(r.duration_s, f,
                                                        r.sensitivity))
                st.energy_j += self.power.power(f) * t_old
                st.time_s += t_old
                frac_done = t_old / max(self.power.region_time(
                    r.duration_s, f, r.sensitivity), 1e-12)
                rest = Region(r.kind, r.duration_s * max(0.0, 1 - frac_done))
                st.switches += 1
                f = tgt
                r = rest
            t = self.power.region_time(r.duration_s, f, r.sensitivity)
            st.energy_j += self.power.power(f) * t
            st.time_s += t
        return st


def oblivious_governor_sim(table, power: PowerModel, frequencies,
                           regions: list[Region]) -> GovernorStats:
    """Latency-oblivious baseline: always jumps to the energy-optimal
    frequency, pays the (unknown to it) transition every time."""
    g = Governor(table, power, frequencies,
                 GovernorConfig(hysteresis=0.0, avoid_percentile=100.0))
    return g.simulate(regions)


def static_sim(power: PowerModel, frequencies, regions: list[Region],
               f: float | None = None) -> GovernorStats:
    f = f if f is not None else max(frequencies)
    st = GovernorStats()
    for r in regions:
        t = power.region_time(r.duration_s, f, r.sensitivity)
        st.energy_j += power.power(f) * t
        st.time_s += t
    return st
