"""Region classification for training/serving steps (COUNTDOWN-style,
generalized with the *measured* switching latency).

A train step decomposes into phases with different frequency sensitivity:
  compute      fwd/bwd matmuls               sensitivity ~ 1.0
  collective   grad all-reduce / all-gather  sensitivity ~ 0.15
  memory       optimizer update, cache reads sensitivity ~ 0.2
  host         data pipeline, checkpoints    sensitivity ~ 0.0

``regions_from_cell`` derives the durations directly from a dry-run
roofline cell (EXPERIMENTS.md #Dry-run), tying the governor to the actual
compiled workload rather than hand-waved numbers.  The paper's 500 us
short-region rule becomes device-relative: regions shorter than
``min_region_factor x worst-case switching latency`` are never frequency-
scaled (COUNTDOWN's Haswell lesson: re-requesting mid-transition leaves the
clock undefined).
"""
from __future__ import annotations

import dataclasses

# frequency sensitivity of runtime per region kind.  Paper §III/[9,10]:
# memory/collective-bound regions tolerate ~75% clocks with ~no runtime
# impact => near-zero sensitivity; compute scales ~1/f.
SENSITIVITY = {"compute": 1.0, "collective": 0.05, "memory": 0.05, "host": 0.0}


@dataclasses.dataclass(frozen=True)
class Region:
    kind: str                  # compute | collective | memory | host
    duration_s: float          # at f_max

    @property
    def sensitivity(self) -> float:
        return SENSITIVITY[self.kind]


def regions_from_cell(cell: dict, *, host_fraction: float = 0.03) -> list[Region]:
    """Build one step's region list from a dry-run JSON cell."""
    r = cell["roofline"]
    comp, mem, coll = r["compute_s"], r["memory_s"], r["collective_s"]
    # memory term overlaps compute on real hardware; the exposed memory
    # region is the excess over compute (optimizer/cache-bound tail)
    mem_exposed = max(0.0, mem - comp)
    regions = [Region("compute", comp)]
    if mem_exposed > 0:
        regions.append(Region("memory", mem_exposed))
    if coll > 0:
        regions.append(Region("collective", coll))
    step = sum(x.duration_s for x in regions)
    regions.append(Region("host", host_fraction * step))
    return regions


def steps_from_cell(cell: dict, n_steps: int, **kw) -> list[Region]:
    return regions_from_cell(cell, **kw) * n_steps
