"""Accelerator power/energy model for the governor's planning.

P(f) = P_static + c * (f/f_max)^3 * P_dyn_max  (cubic dynamic power).
Runtime scaling with frequency depends on the region's boundedness:
compute-bound time ~ 1/f; memory/collective-bound time is nearly flat
(the paper's §III observation that ~75% clocks trade ~0 runtime for real
energy savings on memory-bound codes).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class PowerModel:
    f_max_mhz: float
    p_static_w: float = 80.0
    p_dyn_max_w: float = 320.0

    def power(self, f_mhz: float) -> float:
        r = f_mhz / self.f_max_mhz
        return self.p_static_w + self.p_dyn_max_w * r ** 3

    def region_time(self, duration_at_fmax: float, f_mhz: float,
                    sensitivity: float) -> float:
        """sensitivity 1.0 = perfectly compute-bound (t ~ 1/f);
        0.0 = fully memory/IO-bound (t flat)."""
        r = self.f_max_mhz / f_mhz
        return duration_at_fmax * (sensitivity * r + (1.0 - sensitivity))

    def region_energy(self, duration_at_fmax: float, f_mhz: float,
                      sensitivity: float) -> float:
        return self.power(f_mhz) * self.region_time(duration_at_fmax, f_mhz,
                                                    sensitivity)

    def best_frequency(self, duration_at_fmax: float, sensitivity: float,
                       frequencies, *, max_slowdown: float = 1.02) -> float:
        """Energy-minimal frequency subject to a runtime constraint
        (paper §III: 'no runtime extension' static-tuning constraint,
        relaxed to max_slowdown)."""
        t0 = self.region_time(duration_at_fmax, self.f_max_mhz, sensitivity)
        best, best_e = self.f_max_mhz, self.region_energy(
            duration_at_fmax, self.f_max_mhz, sensitivity)
        for f in frequencies:
            t = self.region_time(duration_at_fmax, f, sensitivity)
            if t > max_slowdown * t0:
                continue
            e = self.region_energy(duration_at_fmax, f, sensitivity)
            if e < best_e:
                best, best_e = f, e
        return best
