from repro.dvfs.device_model import SimulatedAccelerator, KernelHandle, DeviceConfig
from repro.dvfs.transition_models import (TransitionModel, A100Like, GH200Like,
                                          RTXQuadro6000Like, make_device)
from repro.dvfs.power_model import PowerModel
from repro.dvfs.governor import Governor, GovernorConfig, Region

__all__ = [
    "SimulatedAccelerator", "KernelHandle", "DeviceConfig", "TransitionModel",
    "A100Like", "GH200Like", "RTXQuadro6000Like", "make_device", "PowerModel",
    "Governor", "GovernorConfig", "Region",
]
