"""Per-architecture frequency-transition behavior models.

TPUs expose no user DVFS API (DESIGN.md #2), so the methodology is validated
against simulated accelerators whose *ground-truth* switching behavior is
calibrated to the paper's findings (Table II, Figs. 3-6):

  A100Like          low, tight latencies; pronounced up/down asymmetry
                    (decreases ~4.4-6 ms, increases up to ~23 ms)
  GH200Like         target-frequency dominates (row pattern); mostly <100 ms
                    but a few targets reach ~477 ms; some pairs form 2-5
                    distinct latency clusters (Fig. 5)
  RTXQuadro6000Like erratic: heavy variance, multi-modal, 0.5-350 ms

Every model exposes ground_truth_latency() so tests/benchmarks can check the
measured value against what the simulator actually did — the calibration
loop the paper itself cannot have (it measures real silicon; we measure a
known model and demand the pipeline recover it).
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib

import numpy as np


@functools.lru_cache(maxsize=None)
def _pair_hash(a: float, b: float, salt: int = 0) -> float:
    """Deterministic uniform [0,1) per (from,to) pair.  Cached: the hash is
    pure and a sweep recomputes the same few thousand pairs on every one of
    its ~10^5 transition samples."""
    h = hashlib.sha256(f"{a:.1f}->{b:.1f}|{salt}".encode()).digest()
    return int.from_bytes(h[:8], "little") / 2 ** 64


@dataclasses.dataclass
class TransitionModel:
    name: str = "generic"
    unit_seed: int = 0               # manufacturing-variability knob
    comm_delay_s: float = 50e-6      # CPU -> ACC command latency
    wakeup_s: float = 10e-3

    def base_latency(self, f_from: float, f_to: float) -> float:
        return 10e-3

    def sample_latency(self, f_from: float, f_to: float,
                       rng: np.random.Generator) -> float:
        base = self.base_latency(f_from, f_to)
        return float(base * rng.lognormal(0.0, 0.05))

    # frequency trajectory during the transition: list of (dt_from_arrival,
    # freq); the final entry is (latency, f_to).
    def trajectory(self, f_from: float, f_to: float, latency: float,
                   rng: np.random.Generator) -> list[tuple[float, float]]:
        return [(latency, f_to)]


@dataclasses.dataclass
class A100Like(TransitionModel):
    name: str = "a100"

    def base_latency(self, f_from, f_to):
        u = _pair_hash(f_from, f_to, self.unit_seed)
        if f_to < f_from:                       # decrease: fast, tight
            return 4.4e-3 + 1.6e-3 * u
        return 7.5e-3 + 15.0e-3 * u             # increase: slower

    def sample_latency(self, f_from, f_to, rng):
        base = self.base_latency(f_from, f_to)
        sigma = 0.03 if f_to < f_from else 0.08
        return float(base * rng.lognormal(0.0, sigma))


@dataclasses.dataclass
class GH200Like(TransitionModel):
    name: str = "gh200"
    bad_target_fraction: float = 0.12
    cluster_prob: float = 0.18

    def base_latency(self, f_from, f_to):
        ut = _pair_hash(0.0, f_to, self.unit_seed)       # target-dominated
        uf = _pair_hash(f_from, 0.0, self.unit_seed)
        if ut < self.bad_target_fraction:                # a few bad targets
            return 90e-3 + 380e-3 * (ut / self.bad_target_fraction)
        base = 4.9e-3 + 60e-3 * ut
        return base * (0.9 + 0.2 * uf)                   # weak source effect

    def sample_latency(self, f_from, f_to, rng):
        base = self.base_latency(f_from, f_to)
        u = _pair_hash(f_from, f_to, self.unit_seed + 7)
        lat = base * rng.lognormal(0.0, 0.06)
        if u < 0.35:                                     # multi-cluster pairs
            n_clusters = 2 + int(u * 10) % 4             # 2..5
            k = int(rng.integers(0, n_clusters))
            if rng.random() < self.cluster_prob and k > 0:
                lat = lat * (1.0 + 0.45 * k)
        return float(lat)


@dataclasses.dataclass
class RTXQuadro6000Like(TransitionModel):
    name: str = "rtx6000"

    def base_latency(self, f_from, f_to):
        u = _pair_hash(f_from, f_to, self.unit_seed)
        return 0.6e-3 + 180e-3 * u ** 0.7               # wide spread

    def sample_latency(self, f_from, f_to, rng):
        base = self.base_latency(f_from, f_to)
        mode = rng.random()
        if mode < 0.6:
            lat = base * rng.lognormal(0.0, 0.25)
        elif mode < 0.9:
            lat = base * (1.5 + rng.random()) * rng.lognormal(0.0, 0.2)
        else:                                            # erratic spikes
            lat = base + rng.uniform(0.05, 0.35)
        return float(min(lat, 0.36))

    def trajectory(self, f_from, f_to, latency, rng):
        # erratic devices pass through an intermediate frequency
        if rng.random() < 0.3:
            mid = 0.5 * (f_from + f_to)
            return [(0.6 * latency, mid), (latency, f_to)]
        return [(latency, f_to)]


class ShiftedTransitionModel:
    """Drift-injection wrapper: delegates to ``inner`` but scales sampled
    transition latencies by ``scale`` — for every pair, or (with
    ``only_pair``) for exactly one ``(f_init, f_target)`` transition.

    Two extra drift *shapes* widen what detectors must catch:

    * ``ramp_samples > 0``: instead of stepping to ``scale`` at once, the
      factor interpolates linearly from 1 to ``scale`` over the next
      ``ramp_samples`` affected draws — a slow creep whose per-sample
      increment can stay below a CUSUM allowance while Page-Hinkley's
      self-centering statistic still accumulates it;
    * ``direction``: ``"up"`` shifts only frequency increases
      (``f_to > f_from``), ``"down"`` only decreases — the per-direction
      asymmetry of paper Fig. 4, drifting on one side of the matrix.

    Installing this on a live device's ``model`` mid-stream simulates a
    unit whose switching behavior departs its campaign baseline (aging
    silicon, firmware regression, a swapped board): the ground-truth
    history keeps recording the scaled truth, so detection pipelines are
    checked against what the simulator actually did.  Built for
    :class:`repro.campaign.workqueue.FaultPlan` drift injection; the
    fleet monitor's CI smoke is the consumer."""

    def __init__(self, inner, scale: float,
                 only_pair: tuple[float, float] | None = None, *,
                 ramp_samples: int = 0, direction: str = ""):
        if direction not in ("", "up", "down"):
            raise ValueError(
                f"direction must be '', 'up' or 'down', not {direction!r}")
        self.inner = inner
        self.scale = float(scale)
        self.only_pair = (None if only_pair is None else
                          (float(only_pair[0]), float(only_pair[1])))
        self.ramp_samples = int(ramp_samples)
        self.direction = direction
        self._drawn = 0              # affected sample_latency draws so far

    def _applies(self, f_from: float, f_to: float) -> bool:
        if self.only_pair is not None and \
                (float(f_from), float(f_to)) != self.only_pair:
            return False
        if self.direction == "up" and not f_to > f_from:
            return False
        if self.direction == "down" and not f_to < f_from:
            return False
        return True

    def _factor(self, f_from: float, f_to: float) -> float:
        if not self._applies(f_from, f_to):
            return 1.0
        if self.ramp_samples <= 0:
            return self.scale
        # linear creep toward scale across the ramp window; base_latency
        # queries (no draw) see the current factor without advancing it
        frac = min(1.0, self._drawn / self.ramp_samples)
        return 1.0 + (self.scale - 1.0) * frac

    @property
    def name(self) -> str:
        return f"{self.inner.name}+drift"

    def base_latency(self, f_from: float, f_to: float) -> float:
        return self.inner.base_latency(f_from, f_to) \
            * self._factor(f_from, f_to)

    def sample_latency(self, f_from: float, f_to: float, rng) -> float:
        factor = self._factor(f_from, f_to)
        if self.ramp_samples > 0 and self._applies(f_from, f_to):
            self._drawn += 1
        return float(self.inner.sample_latency(f_from, f_to, rng) * factor)

    def trajectory(self, f_from: float, f_to: float, latency: float, rng):
        return self.inner.trajectory(f_from, f_to, latency, rng)

    def __getattr__(self, attr):
        # comm_delay_s, wakeup_s, unit_seed, ... — untouched passthrough
        return getattr(self.inner, attr)


_MODELS = {"a100": A100Like, "gh200": GH200Like, "rtx6000": RTXQuadro6000Like}

# frequency ranges per Table I (MHz): (min, max, step)
_FREQ_TABLES = {
    "a100": (210.0, 1410.0, 15.0),
    "gh200": (345.0, 1980.0, 15.0),
    "rtx6000": (300.0, 2100.0, 15.0),
}
_N_CORES = {"a100": 108, "gh200": 132, "rtx6000": 72}


def make_device(kind: str, *, seed: int = 0, unit_seed: int = 0,
                n_cores: int | None = None, cls=None, **overrides):
    """Factory for a paper-calibrated simulated accelerator.  ``cls`` picks
    the accelerator class (default SimulatedAccelerator; backends pass
    subclasses such as VmappedSimAccelerator)."""
    from repro.dvfs.device_model import DeviceConfig, SimulatedAccelerator
    model = _MODELS[kind](unit_seed=unit_seed)
    fmin, fmax, step = _FREQ_TABLES[kind]
    freqs = np.arange(fmin, fmax + 1e-9, step)
    cfg = DeviceConfig(
        n_cores=n_cores if n_cores is not None else _N_CORES[kind],
        frequencies=tuple(float(f) for f in freqs),
        **overrides,
    )
    return (cls or SimulatedAccelerator)(model, cfg, seed=seed)
