# Pallas TPU kernels for the perf-critical compute layers:
#   microbench       the paper's artificial iterative workload (per-core FMA
#                    chain) — the measurement instrument itself
#   flash_attention  blockwise causal attention (train/prefill hot spot)
#   ssd              mamba2 intra-chunk SSD kernel
# Each has kernel.py (pl.pallas_call + BlockSpec), ops.py (jit wrapper) and
# ref.py (pure-jnp oracle); tests sweep shapes/dtypes with interpret=True.
