"""Pure-jnp oracle for the intra-chunk SSD computation (mirrors the masked
einsum form in repro.models.ssm.ssd_ref's scan body)."""
from __future__ import annotations

import jax.numpy as jnp


def ssd_chunk_ref(x, B, C, cs, dt):
    """Same signature/layout as the kernel; returns (y_intra, S)."""
    b, nc, h, q, p = x.shape
    xf = x.astype(jnp.float32)
    Bf = B.astype(jnp.float32)
    Cf = C.astype(jnp.float32)
    csf = cs.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    idx = jnp.arange(q)
    causal = idx[:, None] >= idx[None, :]
    decay = jnp.where(causal[None, None, None],
                      csf[..., :, None] - csf[..., None, :], -jnp.inf)
    L = jnp.exp(decay)                                     # (b,nc,h,i,j)
    cb = jnp.einsum("bcin,bcjn->bcij", Cf, Bf)
    att = cb[:, :, None] * L * dtf[..., None, :]
    y = jnp.einsum("bchij,bchjp->bchip", att, xf)
    w = jnp.exp(csf[..., -1:] - csf) * dtf                 # (b,nc,h,q)
    S = jnp.einsum("bchj,bcjn,bchjp->bchpn", w, Bf, xf)
    return y, S
