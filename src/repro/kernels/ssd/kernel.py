"""Mamba-2 SSD intra-chunk kernel (Pallas TPU).

The chunked SSD computation splits into (a) a quadratic *intra-chunk* part
— attention-like (q x q) masked products, MXU-friendly — and (b) a tiny
sequential inter-chunk state recurrence.  The kernel computes (a) per
(batch, chunk, head) grid cell:

    L    = exp(cs_i - cs_j)  (causal-masked)        VPU
    cb   = C B^T                                    MXU
    y    = (cb * L * dt_j) x                        MXU
    S    = (B * exp(cs_last - cs) * dt)^T x         MXU  (chunk state)

The log-decay cumsum ``cs`` is precomputed in XLA (cheap, elementwise); the
inter-chunk recurrence stays a lax.scan in ops.py — the TPU-native split of
the paper's GPU algorithm (DESIGN.md: adapt, don't port).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _body(x_ref, b_ref, c_ref, cs_ref, dt_ref, y_ref, s_ref, *, chunk):
    x = x_ref[0, 0, 0].astype(jnp.float32)          # (q, p)
    B = b_ref[0, 0].astype(jnp.float32)             # (q, n)
    C = c_ref[0, 0].astype(jnp.float32)             # (q, n)
    cs = cs_ref[0, 0, 0].astype(jnp.float32)        # (q,)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)        # (q,)

    decay = cs[:, None] - cs[None, :]               # (q, q)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.exp(jnp.where(ii >= jj, decay, -jnp.inf))
    cb = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    att = cb * L * dt[None, :]
    y = jax.lax.dot_general(att, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    w = jnp.exp(cs[-1] - cs) * dt                   # (q,)
    s = jax.lax.dot_general(x, B * w[:, None], (((0,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (p, n)
    y_ref[0, 0, 0] = y.astype(y_ref.dtype)
    s_ref[0, 0, 0] = s.astype(s_ref.dtype)


def ssd_chunk_kernel(x, B, C, cs, dt, *, interpret=True):
    """x: (b, nc, h, q, p); B/C: (b, nc, q, n); cs/dt: (b, nc, h, q).
    Returns y_intra (b, nc, h, q, p) and chunk states S (b, nc, h, p, n)."""
    b, nc, h, q, p = x.shape
    n = B.shape[-1]
    return pl.pallas_call(
        functools.partial(_body, chunk=q),
        grid=(b, nc, h),
        in_specs=[
            pl.BlockSpec((1, 1, 1, q, p), lambda bi, ci, hi: (bi, ci, hi, 0, 0)),
            pl.BlockSpec((1, 1, q, n), lambda bi, ci, hi: (bi, ci, 0, 0)),
            pl.BlockSpec((1, 1, q, n), lambda bi, ci, hi: (bi, ci, 0, 0)),
            pl.BlockSpec((1, 1, 1, q), lambda bi, ci, hi: (bi, ci, hi, 0)),
            pl.BlockSpec((1, 1, 1, q), lambda bi, ci, hi: (bi, ci, hi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, q, p), lambda bi, ci, hi: (bi, ci, hi, 0, 0)),
            pl.BlockSpec((1, 1, 1, p, n), lambda bi, ci, hi: (bi, ci, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, nc, h, q, p), jnp.float32),
            jax.ShapeDtypeStruct((b, nc, h, p, n), jnp.float32),
        ],
        interpret=interpret,
    )(x, B, C, cs, dt)
