from repro.kernels.ssd.ops import ssd_chunk
from repro.kernels.ssd.ref import ssd_chunk_ref
