"""Full SSD via the Pallas intra-chunk kernel + XLA inter-chunk recurrence.

Drop-in equivalent of repro.models.ssm.ssd_ref (same (y, final_state)
contract) for seq lengths divisible by the chunk size.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd.kernel import ssd_chunk_kernel


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk(x, B, C, cs, dt, interpret: bool = True):
    return ssd_chunk_kernel(x, B, C, cs, dt, interpret=interpret)


def ssd_pallas(x, dt, A, B, C, chunk: int, *, interpret: bool = True):
    """x: (b, l, h, p); dt: (b, l, h); A: (h,); B/C: (b, l, n).
    Returns (y (b,l,h,p) fp32, final state (b,h,p,n) fp32)."""
    b, l, h, p = x.shape
    n = B.shape[-1]
    assert l % chunk == 0, "pallas path requires l % chunk == 0"
    nc = l // chunk
    xr = x.astype(jnp.float32).reshape(b, nc, chunk, h, p).transpose(0, 1, 3, 2, 4)
    Br = B.astype(jnp.float32).reshape(b, nc, chunk, n)
    Cr = C.astype(jnp.float32).reshape(b, nc, chunk, n)
    dtr = dt.astype(jnp.float32).reshape(b, nc, chunk, h).transpose(0, 1, 3, 2)
    dA = dtr * A[None, None, :, None]                    # (b,nc,h,q)
    cs = jnp.cumsum(dA, axis=-1)

    y_intra, S = ssd_chunk(xr, Br, Cr, cs, dtr, interpret=interpret)

    # inter-chunk recurrence (tiny sequential scan, stays in XLA)
    dA_chunk = jnp.exp(cs[..., -1])                      # (b,nc,h)

    def step(hstate, inp):
        S_c, dA_c = inp
        out = hstate
        return hstate * dA_c[..., None, None] + S_c, out

    h0 = jnp.zeros((b, h, p, n), jnp.float32)
    hfinal, h_in = jax.lax.scan(
        step, h0, (S.transpose(1, 0, 2, 3, 4), dA_chunk.transpose(1, 0, 2)))
    h_in = h_in.transpose(1, 0, 2, 3, 4)                 # (b,nc,h,p,n)

    y_inter = jnp.einsum("bcqn,bchpn->bchqp", Cr, h_in) * jnp.exp(cs)[..., None]
    y = (y_intra + y_inter).transpose(0, 1, 3, 2, 4).reshape(b, l, h, p)
    return y, hfinal
