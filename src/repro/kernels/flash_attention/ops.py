"""Jitted public wrapper for the Pallas flash-attention kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention.kernel import flash_attention_kernel


@functools.partial(jax.jit,
                   static_argnames=("causal", "blk_q", "blk_k", "interpret"))
def flash_attention(q, k, v, causal: bool = True, blk_q: int = 128,
                    blk_k: int = 128, interpret: bool = True):
    return flash_attention_kernel(q, k, v, causal=causal, blk_q=blk_q,
                                  blk_k=blk_k, interpret=interpret)
