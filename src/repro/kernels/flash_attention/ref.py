"""Oracle: the O(S^2) naive attention from the model zoo."""
from repro.models.layers import naive_attention


def flash_attention_ref(q, k, v, *, causal=True):
    return naive_attention(q, k, v, causal=causal)
