"""Blockwise (flash) causal attention as a Pallas TPU kernel.

Grid (B, KV, G, nQ, nK) — nK innermost so the (m, l, acc) online-softmax
state lives in VMEM scratch across the kv sweep for one q block:

  kj == 0      : init scratch
  every kj     : s = q k^T (MXU), online-softmax update (VPU)
  kj == nK - 1 : normalize and write the output block

Causal block skipping: kv blocks strictly above the diagonal contribute
nothing; @pl.when guards the compute so the MXU work matches the
triangular FLOP count (the XLA fallback in repro.models.layers pays the
same schedule via the triangular pair scan).  Block shapes default to
(128, 128) — MXU-aligned on the (sublane, lane) dims.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _body(q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *,
          scale, causal, blk_q, blk_k, n_k):
    qi = pl.program_id(3)
    kj = pl.program_id(4)

    @pl.when(kj == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    def _compute():
        q = q_ref[0, 0, 0].astype(jnp.float32)    # (blk_q, dh)
        k = k_ref[0, 0].astype(jnp.float32)       # (blk_k, dh)
        v = v_ref[0, 0].astype(jnp.float32)       # (blk_k, dv)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = qi * blk_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            kpos = kj * blk_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_sc[...] = l_sc[...] * corr + p.sum(axis=1)
        acc_sc[...] = acc_sc[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_sc[...] = m_new

    if causal:
        # skip kv blocks strictly above the causal diagonal
        pl.when(kj * blk_k <= qi * blk_q + blk_q - 1)(_compute)
    else:
        _compute()

    @pl.when(kj == n_k - 1)
    def _flush():
        l = jnp.maximum(l_sc[...], 1e-30)
        o_ref[0, 0, 0] = (acc_sc[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_kernel(q, k, v, *, causal=True, blk_q=128, blk_k=128,
                           interpret=True):
    """q: (B, Sq, H, dh); k/v: (B, Sk, KV, dh/dv); GQA via H = KV * G."""
    b, sq, h, dh = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    g = h // kvh
    blk_q = min(blk_q, sq)
    blk_k = min(blk_k, sk)
    assert sq % blk_q == 0 and sk % blk_k == 0
    n_q, n_k = sq // blk_q, sk // blk_k
    scale = 1.0 / math.sqrt(dh)

    # layout: (B, KV, G, S, d)
    qr = q.reshape(b, sq, kvh, g, dh).transpose(0, 2, 3, 1, 4)
    kr = k.transpose(0, 2, 1, 3)          # (b, kv, sk, dh)
    vr = v.transpose(0, 2, 1, 3)

    out = pl.pallas_call(
        functools.partial(_body, scale=scale, causal=causal, blk_q=blk_q,
                          blk_k=blk_k, n_k=n_k),
        grid=(b, kvh, g, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, 1, blk_q, dh),
                         lambda b, h, g, qi, kj: (b, h, g, qi, 0)),
            pl.BlockSpec((1, 1, blk_k, dh),
                         lambda b, h, g, qi, kj: (b, h, kj, 0)),
            pl.BlockSpec((1, 1, blk_k, dv),
                         lambda b, h, g, qi, kj: (b, h, kj, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, blk_q, dv),
                               lambda b, h, g, qi, kj: (b, h, g, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, n_q * blk_q, dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q,), jnp.float32),
            pltpu.VMEM((blk_q,), jnp.float32),
            pltpu.VMEM((blk_q, dv), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, dv)
