"""The paper's artificial iterative workload as a Pallas TPU kernel.

"The same arithmetic instruction repeated multiple times in each performed
iteration" (§V), adapted to the TPU: one grid program per core stand-in
(CUDA SM -> grid cell), each running `n_iters` iterations of an unrolled
FMA chain on a VPU-aligned (8, 128) VMEM tile.  The chain is sequentially
dependent (a = a*c1 + c2), so runtime tracks clock frequency rather than
memory bandwidth — the property the methodology needs from its workload.

On real hardware the per-iteration timestamps come from the host bracketing
kernel launches (TPU exposes no in-kernel global timer — DESIGN.md #2); in
this repo the simulator provides the timeline and this kernel is validated
for numerical equivalence against ref.py in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = (8, 128)          # float32 VPU tile


def _body(x_ref, o_ref, *, n_iters, unroll):
    a = x_ref[...]
    c1 = jnp.float32(1.000000119)          # keeps the chain bounded
    c2 = jnp.float32(1e-7)

    def iter_fn(_, a):
        for _ in range(unroll):            # unrolled FMA chain
            a = a * c1 + c2
        return a

    a = jax.lax.fori_loop(0, n_iters, iter_fn, a)
    o_ref[...] = a


def microbench_kernel(x: jax.Array, *, n_iters: int = 64, unroll: int = 32,
                      interpret: bool = True) -> jax.Array:
    """x: (cores * 8, 128) float32 — one (8,128) tile per core."""
    cores = x.shape[0] // TILE[0]
    return pl.pallas_call(
        functools.partial(_body, n_iters=n_iters, unroll=unroll),
        grid=(cores,),
        in_specs=[pl.BlockSpec(TILE, lambda i: (i, 0))],
        out_specs=pl.BlockSpec(TILE, lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x)
