"""Pure-jnp oracle for the microbench FMA chain."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def microbench_ref(x: jax.Array, *, n_iters: int = 64, unroll: int = 32) -> jax.Array:
    c1 = jnp.float32(1.000000119)
    c2 = jnp.float32(1e-7)

    def iter_fn(_, a):
        for _ in range(unroll):
            a = a * c1 + c2
        return a

    return jax.lax.fori_loop(0, n_iters, iter_fn, x)
