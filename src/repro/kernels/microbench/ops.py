"""Jitted public wrapper for the microbench workload."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.microbench.kernel import TILE, microbench_kernel


@functools.partial(jax.jit, static_argnames=("n_iters", "unroll", "interpret"))
def microbench(x: jax.Array, n_iters: int = 64, unroll: int = 32,
               interpret: bool = True) -> jax.Array:
    return microbench_kernel(x, n_iters=n_iters, unroll=unroll,
                             interpret=interpret)


def make_input(cores: int, seed: int = 0) -> jax.Array:
    k = jax.random.PRNGKey(seed)
    return jax.random.uniform(k, (cores * TILE[0], TILE[1]), jnp.float32)


def flops_per_core(n_iters: int, unroll: int) -> float:
    """2 flops (mul+add) per element per chain step."""
    return 2.0 * n_iters * unroll * TILE[0] * TILE[1]
