from repro.kernels.microbench.ops import microbench
from repro.kernels.microbench.ref import microbench_ref
