# The paper's primary contribution: the LATEST accelerator frequency-
# switching-latency measurement methodology (Velicka/Vysocky/Riha, CS.DC'25),
# implemented device-agnostically in numpy/JAX.
from repro.core.stats import (FreqStats, mean_std, diff_confidence_interval,
                              rse, two_sigma_band, two_se_band, welch_t_test,
                              ci_excludes_zero, null_hypothesis_holds)
from repro.core.workload import WorkloadSpec, size_workload
from repro.core.clock_sync import synchronize_timers
from repro.core.calibration import calibrate, valid_pairs
from repro.core.switching import measure_switch_once
from repro.core.evaluation import measure_pair
from repro.core.dbscan import dbscan, adaptive_dbscan
from repro.core.silhouette import silhouette_score
from repro.core.latency_table import LatencyTable, PairResult
from repro.core.executors import SerialExecutor, ThreadExecutor, get_executor
from repro.core.session import (LatestConfig, MeasurementSession,
                                SessionConfig, probe_latency)
from repro.core.latest import run_latest
from repro.core.paths import campaigns_dir, results_dir, results_root

__all__ = [
    "FreqStats", "mean_std", "diff_confidence_interval", "rse",
    "two_sigma_band", "two_se_band", "welch_t_test", "ci_excludes_zero",
    "null_hypothesis_holds", "WorkloadSpec", "size_workload",
    "synchronize_timers", "calibrate", "valid_pairs", "measure_switch_once",
    "measure_pair", "dbscan", "adaptive_dbscan", "silhouette_score",
    "LatencyTable", "PairResult", "SerialExecutor", "ThreadExecutor",
    "get_executor", "LatestConfig", "MeasurementSession", "SessionConfig",
    "probe_latency", "run_latest",
    "campaigns_dir", "results_dir", "results_root",
]
