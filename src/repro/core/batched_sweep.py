"""Accelerator-native batched sweep engine: the whole (f_init, f_target)
grid measured as a handful of vectorized dispatches.

After PR 5 the campaign layer is process-parallel, but the measurement
core still runs one ``measure_pair`` at a time: every pass pays ~200
Python/numpy dispatches (16 scalar sync exchanges, the segment-eval
rounds, detection, the confirm cumsums) on arrays whose math is over in
microseconds.  On a one-core host no executor can win that back — the
dispatch overhead IS the sweep.

This engine runs every pair as a *lane* of one lock-stepped program:

* each lane owns a freshly built, pair-seeded device
  (``pair_seed(base_seed, f_init, f_target)`` — the PR-5 determinism
  contract), so lanes never interact and lane order cannot matter;
* per round (= one Alg. 2 pass per still-active lane) the scalar device
  protocol — ``set_frequency``, ``launch_kernel``, ``usleep`` — runs
  per lane through the *unmodified* device methods, keeping wake-up,
  throttle and trajectory semantics identical by construction, while
  every array stage is fused across lanes: the 16-exchange timer sync
  becomes one (lanes, 16) program, the segment-wise cumsum wait
  evaluation runs all lanes' cores as rows of one
  :func:`repro.backends.vmapped_sim.eval_timestamps_lanes` call, and
  phase-2 detection + the reverse-cumsum suffix confirm run on the
  (lanes*cores, iters) stack without ever leaving numpy;
* the Alg. 2 retry/RSE loop is a masked still-active-pairs iteration:
  converged, power-throttled and retry-exhausted lanes drop out of the
  stack, so stragglers keep iterating on ever-smaller dispatches.

Bit-exactness contract: per lane, every RNG draw happens through that
lane's own generator in exactly the serial order (one vectorized
``uniform(0, j, 32)`` fills the same stream as 32 scalar sync draws),
and every fused array op reduces/scans only within rows, so each pair's
``PairMeasurement`` is bit-identical to ``run_pair_task`` on the same
seed — serial, threaded, process and batched schedules all agree.  The
per-pair path stays in the tree as the reference, exactly like
``wait_impl="loop"`` and the analysis engine's ``impl="matrix"``.
"""
from __future__ import annotations

import bisect

import numpy as np

from repro.core import stats as statsmod
from repro.core.evaluation import MeasureConfig, PairMeasurement
from repro.core.pairtask import PairTask, extract_ground_truth, pair_seed
from repro.core.switching import detect_switch
from repro.core.workload import WorkloadSpec

_SYNC_EXCHANGES = 16          # synchronize_timers default
_SYNC_PROC_S = 2e-6           # device-side turnaround (sync_exchange)
_Z = 1.96                     # measure_switch_once defaults
_TOL_FRAC = 0.02


class _Lane:
    """One pair's measurement state: its device plus the exact
    ``measure_pair`` bookkeeping (latencies, running RSE, retries)."""

    __slots__ = ("device", "f_init", "f_target", "target", "init_iter",
                 "lo", "hi", "tol", "lat", "running", "retries", "offset",
                 "t_s", "warm_h", "meas_h", "result")

    def __init__(self, device, f_init: float, f_target: float, cal,
                 k_sigma: float):
        self.device = device
        self.f_init = f_init
        self.f_target = f_target
        self.target = cal.baselines[f_target]
        self.init_iter = cal.baselines[f_init].mean
        self.lo, self.hi = statsmod.two_sigma_band(self.target, k_sigma)
        self.tol = _TOL_FRAC * self.target.mean
        self.lat: list[float] = []
        self.running = statsmod.RunningStats()
        self.retries = 0
        self.offset = 0.0             # clock-sync offset, current pass
        self.t_s = 0.0                # change-request time, current pass
        self.warm_h = None
        self.meas_h = None
        self.result: tuple[PairMeasurement, dict] | None = None

    def finish(self, status: str, rse: float) -> None:
        pm = PairMeasurement(self.f_init, self.f_target,
                             np.asarray(self.lat), status, self.retries,
                             rse)
        self.result = (pm, extract_ground_truth(self.device))


def _require_batchable(device):
    if not (hasattr(device, "_wait_draw") and hasattr(device, "_events")):
        raise ValueError(
            "the batched sweep engine drives SimulatedAccelerator-family "
            f"devices; {type(device).__name__} exposes no split wait "
            "protocol — use the serial engine for this backend")


def _event_pads(lanes, handles):
    """Per-lane frequency timelines, sliced to the events that can matter
    for kernels starting at ``handle.start_dev`` (every core starts at or
    after it) and right-padded with ``+inf``.  The slice keeps the padded
    table a few columns wide even though device timelines grow over the
    sweep — the serial path pays that growth on every lookup instead."""
    tails = []
    for lane, h in zip(lanes, handles):
        dev = lane.device
        i = max(bisect.bisect_right(dev._ev_t, h.start_dev) - 1, 0)
        tails.append((dev._ev_t[i:], dev._ev_f[i:]))
    width = max(len(tt) for tt, _ in tails) + 1
    ev_t = np.full((width, len(tails)), np.inf)      # (events, lanes)
    ev_f = np.ones((width, len(tails)))
    for i, (tt, tf) in enumerate(tails):
        ev_t[:len(tt), i] = tt
        ev_f[:len(tt), i] = tf
    return ev_t, ev_f


def _batched_wait(lanes, handles, n_iters, base_iter_s, f_max,
                  ends_only=False):
    """All active lanes' ``wait()`` as one fused evaluation.  Per lane the
    RNG draws come from the device's own :meth:`_wait_draw` (exact serial
    stream); the segment-wise bounds evaluation crosses lanes.  Returns
    the unquantized iteration-major (I + 1, L*C) boundary timestamps, or
    ``None`` for ``ends_only`` (warm-up) waits, which skip materializing
    boundaries nobody reads."""
    from repro.backends.vmapped_sim import eval_timestamps_lanes
    n_lanes = len(lanes)
    n_cores = lanes[0].device.cfg.n_cores
    t0 = np.empty(n_lanes * n_cores)
    noise_t = np.empty((n_iters, n_lanes * n_cores))  # iteration-major
    for i, (lane, h) in enumerate(zip(lanes, handles)):
        lt0, ln = lane.device._wait_draw(h)
        t0[i * n_cores:(i + 1) * n_cores] = lt0
        noise_t[:, i * n_cores:(i + 1) * n_cores] = ln.T
    ev_t, ev_f = _event_pads(lanes, handles)
    lane_of_row = np.repeat(np.arange(n_lanes), n_cores)
    out = eval_timestamps_lanes(
        base_iter_s, t0, noise_t, lane_of_row, ev_t, ev_f, f_max,
        ends_only=ends_only)
    if ends_only:
        bounds = None
        ends = out.reshape(n_lanes, n_cores).max(axis=1)
    else:
        bounds = out                                  # (iters + 1, L*C)
        ends = bounds[-1].reshape(n_lanes, n_cores).max(axis=1)
    # per-lane completion: busy/activity marks + host clock catch-up,
    # through the device's own finalize (max over one lane's cores only)
    for i, lane in enumerate(lanes):
        lane.device._wait_finalize(float(ends[i]))
    return bounds


def _batched_sync(lanes):
    """The 16-exchange IEEE-1588 sync for every active lane at once.  One
    ``uniform(0, j, 32)`` per lane fills the identical RNG stream as the
    serial path's 32 scalar draws; the exchange arithmetic is elementwise
    over lanes with the exact serial operation order, and best-of-n picks
    the first minimum-RTT exchange like ``sync_from_exchanges``."""
    dev0 = lanes[0].device
    jitter = dev0.cfg.link_jitter_s
    comm = dev0.model.comm_delay_s
    off = dev0.cfg.clock_offset_s
    drift = dev0.cfg.clock_drift
    n_lanes = len(lanes)
    jit = np.empty((n_lanes, 2 * _SYNC_EXCHANGES))
    host = np.empty(n_lanes)
    dev_t0 = np.empty(n_lanes)
    for i, lane in enumerate(lanes):
        jit[i] = lane.device.rng.uniform(0, jitter, 2 * _SYNC_EXCHANGES)
        host[i] = lane.device._host_t
        dev_t0[i] = lane.device._t0
    offs = np.empty((n_lanes, _SYNC_EXCHANGES))
    rtts = np.empty((n_lanes, _SYNC_EXCHANGES))
    for k in range(_SYNC_EXCHANGES):
        t1 = host
        x = t1 + (comm + jit[:, 2 * k])                 # t1 + d1
        t2 = x + off + drift * (x - dev_t0)
        t3 = t2 + _SYNC_PROC_S
        host = (x + _SYNC_PROC_S) + (comm + jit[:, 2 * k + 1])
        t4 = host
        rtts[:, k] = (t4 - t1) - (t3 - t2)
        offs[:, k] = ((t2 - t1) + (t3 - t4)) / 2.0
    best = np.argmin(rtts, axis=1)                      # first minimum
    offset = offs[np.arange(n_lanes), best]
    for i, lane in enumerate(lanes):
        lane.device._host_t = host[i]
        lane.offset = offset[i]


def _lane_rows(lanes, n_cores, cache):
    """Per-row detection constants (band edges, target stats, tolerance)
    replicated core-wise, memoized on the identity of the active lane
    list — in the steady state every round sees the same lanes, so the
    ``np.repeat`` stack is built once per active-set change."""
    key = tuple(map(id, lanes))
    hit = cache.get("key")
    if hit != key:
        cache["key"] = key
        cache["lo"] = np.repeat([lane.lo for lane in lanes], n_cores)
        cache["hi"] = np.repeat([lane.hi for lane in lanes], n_cores)
        cache["t_mean"] = np.repeat(
            [lane.target.mean for lane in lanes], n_cores)
        cache["t_se"] = np.repeat(
            [lane.target.se for lane in lanes], n_cores)
        cache["tol"] = np.repeat([lane.tol for lane in lanes], n_cores)
    return cache


def _pairwise_colsum(cols):
    """``np.add.reduce`` over axis 1 of ``cols.T`` — i.e. numpy's pairwise
    summation tree — computed column-wise on the iteration-major (n, R)
    stack, so every partial is one contiguous R-wide add instead of R
    short per-row loops.  Mirrors numpy's ``pairwise_sum``: sequential
    below 8 terms, an 8-accumulator unrolled block up to 128, halving
    recursion (rounded to a multiple of 8) above.  Bit-exactness against
    the serial confirm's ``mean(axis=1)`` hinges on reproducing that tree
    and is pinned by the batched-vs-serial identity tests."""
    n = cols.shape[0]
    if n < 8:
        res = np.zeros(cols.shape[1])
        for k in range(n):
            res += cols[k]
        return res
    if n <= 128:
        r8 = [cols[j].copy() for j in range(8)]
        k = 8
        while k + 8 <= n:
            for j in range(8):
                r8[j] += cols[k + j]
            k += 8
        res = ((r8[0] + r8[1]) + (r8[2] + r8[3])) \
            + ((r8[4] + r8[5]) + (r8[6] + r8[7]))
        while k < n:
            res += cols[k]
            k += 1
        return res
    n2 = (n // 2) - ((n // 2) % 8)
    return _pairwise_colsum(cols[:n2]) + _pairwise_colsum(cols[n2:])


def _batched_detect(lanes, bounds, t_s, mc: MeasureConfig, cache=None):
    """Alg. 2 detection + suffix confirm fused over every active lane:
    quantize once, band-match, then the reverse-cumsum suffix mean/std of
    ``_confirm_vectorized`` on the iteration-major (I + 1, lanes*cores)
    boundary stack.  All reductions/scans stay within columns (= one core
    of one lane), so each lane's outcome is bit-identical to
    ``detect_switch`` on its own pass.  Returns ``(viable, latency)``
    arrays over lanes (latency valid where viable)."""
    n_rows = bounds.shape[1]
    n_lanes = len(lanes)
    n_cores = n_rows // n_lanes
    q = lanes[0].device.cfg.timer_resolution_s
    qb = bounds
    qb /= q                                             # quantize in place
    np.floor(qb, out=qb)
    qb *= q
    starts, ends = qb[:-1], qb[1:]
    n_iters = starts.shape[0]
    if n_iters >= 128 and n_rows <= 512:
        # few lanes, long kernels: the fused column-major path would be
        # all dispatch (mirroring the eval fallback in vmapped_sim) — run
        # the serial detector per lane on its native row-major view
        viable = np.zeros(n_lanes, dtype=bool)
        latency = np.full(n_lanes, -np.inf)
        for i, lane in enumerate(lanes):
            sl = slice(i * n_cores, (i + 1) * n_cores)
            data = np.stack([starts[:, sl].T, ends[:, sl].T], axis=-1)
            res = detect_switch(data, float(t_s[i]), lane.target,
                                k_sigma=mc.k_sigma, z=_Z,
                                tol_frac=_TOL_FRAC,
                                min_confirm=mc.min_confirm)
            if res is not None:
                viable[i] = True
                latency[i] = res.latency
        return viable, latency
    durs = ends - starts                                # (I, R)
    t_s_row = np.repeat(t_s, n_cores)
    c = _lane_rows(lanes, n_cores, cache if cache is not None else {})
    in_band = durs >= c["lo"][None, :]
    in_band &= durs <= c["hi"][None, :]
    in_band &= starts >= t_s_row[None, :]
    # first in-band hit per column without a short-axis argmax: once any
    # iteration hits, `seen` stays True, so counting True rows gives
    # n_iters - first_hit (and 0 where there is no hit at all)
    seen = np.logical_or.accumulate(in_band, axis=0, out=in_band)
    has_hit = seen[-1]
    first_hit = n_iters - np.count_nonzero(seen, axis=0)

    core_lat = np.full(n_rows, np.nan)
    cand = has_hit & (n_iters - first_hit >= mc.min_confirm)
    rows = np.flatnonzero(cand)
    if rows.size:
        # durs is a throwaway temp: center it in place (skipping the
        # column gather entirely when every column is a candidate)
        d = durs if rows.size == n_rows else durs[:, rows]
        center = _pairwise_colsum(d) / n_iters          # mean(axis=1).T
        d -= center[None, :]                            # cd, in place
        i = first_hit[rows]
        ir = n_iters - 1 - i                            # reversed index
        # the reference reverse cumsums (cd[:, ::-1] scans), iteration-
        # major and truncated to the rows the suffix picks can reach — a
        # prefix scan never reads past its slice, so the kept entries are
        # bit-identical to the full scan
        mi = int(ir.max()) + 1
        rev = d[::-1][:mi]
        s1r = np.add.accumulate(rev, axis=0)
        sq = np.square(rev)                             # (cd*cd) reversed
        np.add.accumulate(sq, axis=0, out=sq)
        rr = np.arange(rows.size)
        n = (n_iters - i).astype(np.float64)
        m = s1r[ir, rr] / n
        mean = center + m
        var = np.where(n > 1, (sq[ir, rr] - n * m * m)
                       / np.maximum(n - 1, 1), 0.0)
        se = np.sqrt(np.maximum(var, 0.0) / n + c["t_se"][rows] ** 2)
        diff = mean - c["t_mean"][rows]
        ok = ((diff - _Z * se <= 0.0) & (diff + _Z * se >= 0.0)) \
            | (np.abs(diff) < c["tol"][rows])
        sel = rows[ok]
        core_lat[sel] = ends[i[ok], sel] - t_s_row[sel]

    cl = core_lat.reshape(n_lanes, n_cores)
    viable = ~np.isnan(cl).all(axis=1)
    latency = np.where(np.isnan(cl), -np.inf, cl).max(axis=1)
    return viable, latency


def _after_pass(lane: _Lane, viable: bool, latency: float,
                mc: MeasureConfig) -> None:
    """One lane's ``measure_pair`` bookkeeping after a pass: retry budget,
    throttle checks every 5 measurements (power -> skip pair; thermal ->
    drop the newest 5 + cool-down), RSE-driven stopping.  Statement-level
    mirror of the serial loop body."""
    if not viable:
        lane.retries += 1
        if lane.retries > mc.max_retries:
            lane.finish("undetectable", float("inf"))
        return
    lane.lat.append(latency)
    lane.running.add(latency)
    if len(lane.lat) % mc.throttle_check_every == 0:
        flags = lane.device.throttle_reasons()
        if "power" in flags:
            lane.finish("power_throttled", float("inf"))
            return
        if "thermal" in flags:
            for v in lane.lat[-mc.throttle_check_every:]:
                lane.running.remove(v)
            del lane.lat[-mc.throttle_check_every:]     # drop newest 5
            lane.device.usleep(mc.cooldown_s)
            return                                      # serial `continue`
    if (len(lane.lat) >= mc.min_measurements
            and len(lane.lat) % mc.rse_check_every == 0
            and lane.running.rse() < mc.rse_target):
        lane.finish("ok", lane.running.rse())
        return
    if len(lane.lat) >= mc.max_measurements:            # serial loop exit
        lane.finish("ok", lane.running.rse())


class BatchedSweepEngine:
    """Measure a pair grid in lock-stepped batched rounds (module
    docstring).  Construct once per sweep; :meth:`run` consumes a
    :class:`~repro.core.pairtask.PairTask` (the same picklable spec the
    serial/process executors use) plus the pair list."""

    def __init__(self, task: PairTask):
        self.task = task

    def _build_lane(self, pair) -> _Lane:
        from repro.backends import create_backend
        f_init, f_target = pair
        device = create_backend(
            self.task.backend, **dict(self.task.options),
            seed=pair_seed(self.task.base_seed, f_init, f_target))
        _require_batchable(device)
        return _Lane(device, f_init, f_target, self.task.cal,
                     self.task.measure.k_sigma)

    def run(self, pairs, on_result=None):
        """Measure every pair; returns ``{pair: (PairMeasurement,
        ground_truth)}``.  ``on_result(pair, (pm, gt))`` fires as each
        lane completes (the session's persistence hook), like the
        executors' completion callback."""
        task = self.task
        spec: WorkloadSpec = task.spec
        mc: MeasureConfig = task.measure
        results: dict = {}

        def _collect(lane: _Lane) -> None:
            pair = (lane.f_init, lane.f_target)
            results[pair] = lane.result
            if on_result is not None:
                on_result(pair, lane.result)

        lanes = [self._build_lane(p) for p in pairs]
        for lane in lanes:                  # degenerate max_measurements=0
            if len(lane.lat) >= mc.max_measurements:
                lane.finish("ok", lane.running.rse())
                _collect(lane)
        active = [lane for lane in lanes if lane.result is None]

        n_iters = spec.iters_per_kernel
        warm_iters = spec.iters_per_kernel // 2
        flops = spec.flops_per_iter
        # identical to max(cfg.frequencies) on every batchable backend
        # (single clock domain; multi-domain backends register
        # batchable=False and never reach this engine)
        f_max = lanes[0].device._f_max() if lanes else 0.0
        det_cache: dict = {}
        while active:
            # --- one Alg. 2 pass for every still-active lane ---------- #
            _batched_sync(active)
            for lane in active:
                lane.device.set_frequency(lane.f_init)
                lane.warm_h = lane.device.launch_kernel(warm_iters, flops)
            _batched_wait(active, [lane.warm_h for lane in active],
                          warm_iters, flops, f_max,
                          ends_only=True)               # warm-up: run only
            for lane in active:
                dev = lane.device
                lane.meas_h = dev.launch_kernel(n_iters, flops)
                dev.usleep(spec.delay_iters * lane.init_iter)
                lane.t_s = dev.host_now() + lane.offset  # Alg.2 line 6
                dev.set_frequency(lane.f_target)
            bounds = _batched_wait(active,
                                   [lane.meas_h for lane in active],
                                   n_iters, flops, f_max)
            viable, latency = _batched_detect(
                active, bounds, np.array([lane.t_s for lane in active]), mc,
                det_cache)
            for i, lane in enumerate(active):
                _after_pass(lane, bool(viable[i]), float(latency[i]), mc)
                if lane.result is not None:
                    _collect(lane)
            active = [lane for lane in active if lane.result is None]
        return results


def run_batched_sweep(task: PairTask, pairs, *, on_result=None):
    """Functional convenience over :class:`BatchedSweepEngine`."""
    return BatchedSweepEngine(task).run(pairs, on_result=on_result)
