"""Statistical machinery for the LATEST methodology (paper §IV-V).

The paper's central statistical point (§V-A): FTaLaT detects the transition
end with a +-2*SE(mean) confidence band.  On an accelerator, n = cores x
iterations ~ 1e7 samples drives SE = sigma/sqrt(n) below the device timer
resolution (~1 us on CUDA), so almost no single iteration ever lands inside
the band and detection starves.  LATEST replaces it with the +-2*sigma
POPULATION band: ~95% of iterations under a stable frequency fall inside,
so per-iteration detection works regardless of n.  Both bands are
implemented here; tests/test_stats.py reproduces the failure mode.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np


@dataclasses.dataclass(frozen=True)
class FreqStats:
    freq_mhz: float
    mean: float           # mean iteration time (s)
    std: float            # population std of iteration times
    n: int                # samples

    @property
    def se(self) -> float:
        return self.std / math.sqrt(max(1, self.n))


def mean_std(samples: np.ndarray, freq_mhz: float = 0.0) -> FreqStats:
    s = np.asarray(samples, dtype=np.float64).ravel()
    return FreqStats(freq_mhz, float(s.mean()), float(s.std(ddof=1) if s.size > 1 else 0.0),
                     int(s.size))


def rse(samples) -> float:
    """Relative standard error (paper §VI: stop when RSE < 5%)."""
    s = np.asarray(samples, dtype=np.float64).ravel()
    if s.size < 2 or s.mean() == 0:
        return float("inf")
    return float(s.std(ddof=1) / math.sqrt(s.size) / abs(s.mean()))


def two_sigma_band(st: FreqStats, k: float = 2.0) -> tuple[float, float]:
    """Population band (the paper's accelerator-adapted criterion)."""
    return st.mean - k * st.std, st.mean + k * st.std


def two_se_band(st: FreqStats, k: float = 2.0) -> tuple[float, float]:
    """FTaLaT's mean-precision band — collapses at accelerator sample
    counts; kept for the comparison experiment."""
    return st.mean - k * st.se, st.mean + k * st.se


def diff_confidence_interval(a: FreqStats, b: FreqStats,
                             z: float = 1.96) -> tuple[float, float]:
    """CI of mean(a) - mean(b) (Alg. 1 pair-validity test)."""
    se = math.sqrt(a.se ** 2 + b.se ** 2)
    d = a.mean - b.mean
    return d - z * se, d + z * se


def ci_excludes_zero(a: FreqStats, b: FreqStats, z: float = 1.96) -> bool:
    lo, hi = diff_confidence_interval(a, b, z)
    return lo > 0 or hi < 0


def welch_t_test(a: FreqStats, b: FreqStats) -> float:
    """Welch's t statistic for mean difference (alternative null-hypothesis
    test mentioned in §V-B phase 1: 't-test or z-test or CI test')."""
    se = math.sqrt(a.se ** 2 + b.se ** 2)
    if se == 0:
        return float("inf") if a.mean != b.mean else 0.0
    return (a.mean - b.mean) / se


def null_hypothesis_holds(a: FreqStats, b: FreqStats, *, z: float = 1.96,
                          tol: float = 0.0) -> bool:
    """Accept H0 (same mean) if the difference CI contains zero, OR the
    absolute difference is below tol (Alg. 2 line 20's `meanDiff < tol`)."""
    lo, hi = diff_confidence_interval(a, b, z)
    if lo <= 0.0 <= hi:
        return True
    return abs(a.mean - b.mean) < tol
