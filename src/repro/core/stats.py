"""Statistical machinery for the LATEST methodology (paper §IV-V).

The paper's central statistical point (§V-A): FTaLaT detects the transition
end with a +-2*SE(mean) confidence band.  On an accelerator, n = cores x
iterations ~ 1e7 samples drives SE = sigma/sqrt(n) below the device timer
resolution (~1 us on CUDA), so almost no single iteration ever lands inside
the band and detection starves.  LATEST replaces it with the +-2*sigma
POPULATION band: ~95% of iterations under a stable frequency fall inside,
so per-iteration detection works regardless of n.  Both bands are
implemented here; tests/test_stats.py reproduces the failure mode.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np


@dataclasses.dataclass(frozen=True)
class FreqStats:
    freq_mhz: float
    mean: float           # mean iteration time (s)
    std: float            # population std of iteration times
    n: int                # samples

    @property
    def se(self) -> float:
        return self.std / math.sqrt(max(1, self.n))


def mean_std(samples: np.ndarray, freq_mhz: float = 0.0) -> FreqStats:
    s = np.asarray(samples, dtype=np.float64).ravel()
    return FreqStats(freq_mhz, float(s.mean()), float(s.std(ddof=1) if s.size > 1 else 0.0),
                     int(s.size))


def rse(samples) -> float:
    """Relative standard error (paper §VI: stop when RSE < 5%)."""
    s = np.asarray(samples, dtype=np.float64).ravel()
    if s.size < 2 or s.mean() == 0:
        return float("inf")
    return float(s.std(ddof=1) / math.sqrt(s.size) / abs(s.mean()))


def two_sigma_band(st: FreqStats, k: float = 2.0) -> tuple[float, float]:
    """Population band (the paper's accelerator-adapted criterion)."""
    return st.mean - k * st.std, st.mean + k * st.std


def two_se_band(st: FreqStats, k: float = 2.0) -> tuple[float, float]:
    """FTaLaT's mean-precision band — collapses at accelerator sample
    counts; kept for the comparison experiment."""
    return st.mean - k * st.se, st.mean + k * st.se


def diff_confidence_interval(a: FreqStats, b: FreqStats,
                             z: float = 1.96) -> tuple[float, float]:
    """CI of mean(a) - mean(b) (Alg. 1 pair-validity test)."""
    se = math.sqrt(a.se ** 2 + b.se ** 2)
    d = a.mean - b.mean
    return d - z * se, d + z * se


def ci_excludes_zero(a: FreqStats, b: FreqStats, z: float = 1.96) -> bool:
    lo, hi = diff_confidence_interval(a, b, z)
    return lo > 0 or hi < 0


def welch_t_test(a: FreqStats, b: FreqStats) -> float:
    """Welch's t statistic for mean difference (alternative null-hypothesis
    test mentioned in §V-B phase 1: 't-test or z-test or CI test')."""
    se = math.sqrt(a.se ** 2 + b.se ** 2)
    if se == 0:
        return float("inf") if a.mean != b.mean else 0.0
    return (a.mean - b.mean) / se


def null_hypothesis_holds(a: FreqStats, b: FreqStats, *, z: float = 1.96,
                          tol: float = 0.0) -> bool:
    """Accept H0 (same mean) if the difference CI contains zero, OR the
    absolute difference is below tol (Alg. 2 line 20's `meanDiff < tol`)."""
    lo, hi = diff_confidence_interval(a, b, z)
    if lo <= 0.0 <= hi:
        return True
    return abs(a.mean - b.mean) < tol


class RunningStats:
    """O(1) streaming mean/std/RSE with element removal (the evaluation
    loop's thermal-throttle rollback drops the newest samples).

    Sums are kept shifted by the first accepted sample, so the
    sum-of-squares variance never cancels catastrophically on the tightly
    clustered latencies this accumulates (values ~mean >> spread)."""

    __slots__ = ("n", "_s1", "_s2", "_shift")

    def __init__(self) -> None:
        self.n = 0
        self._s1 = 0.0
        self._s2 = 0.0
        self._shift = 0.0

    def add(self, v: float) -> None:
        if self.n == 0:
            self._shift = float(v)
        d = float(v) - self._shift
        self.n += 1
        self._s1 += d
        self._s2 += d * d

    def remove(self, v: float) -> None:
        """Remove a previously added value (order-independent)."""
        d = float(v) - self._shift
        self.n -= 1
        self._s1 -= d
        self._s2 -= d * d
        if self.n == 0:
            self._s1 = self._s2 = self._shift = 0.0

    @property
    def mean(self) -> float:
        return self._shift + self._s1 / self.n if self.n else float("nan")

    @property
    def std(self) -> float:                    # sample std (ddof=1)
        if self.n < 2:
            return 0.0
        var = (self._s2 - self._s1 * self._s1 / self.n) / (self.n - 1)
        return math.sqrt(max(0.0, var))

    def rse(self) -> float:
        """Same semantics as :func:`rse`, without rescanning the samples."""
        if self.n < 2 or self.mean == 0:
            return float("inf")
        return self.std / math.sqrt(self.n) / abs(self.mean)


# ---------------------------------------------------------------------- #
# sequential change detection (the fleet monitor's per-pair drift tests)
# ---------------------------------------------------------------------- #
class Cusum:
    """Two-sided CUSUM over standardized residuals.

    Feed ``z = (x - mean0) / sigma0``; the statistic accumulates excess
    drift beyond the ``k`` allowance in either direction and trips once it
    exceeds ``h``.  With ``k = 0.5`` and ``h = 5`` the detector reacts to a
    sustained one-sigma shift within a handful of samples while a
    stationary stream's statistic keeps resetting toward zero."""

    __slots__ = ("k", "h", "pos", "neg")

    def __init__(self, k: float = 0.5, h: float = 5.0):
        self.k = float(k)
        self.h = float(h)
        self.pos = 0.0
        self.neg = 0.0

    def update(self, z: float) -> float:
        z = float(z)
        self.pos = max(0.0, self.pos + z - self.k)
        self.neg = max(0.0, self.neg - z - self.k)
        return self.score

    @property
    def score(self) -> float:
        return max(self.pos, self.neg)

    @property
    def tripped(self) -> bool:
        return self.score > self.h

    def reset(self) -> None:
        self.pos = self.neg = 0.0


class PageHinkley:
    """Two-sided Page-Hinkley test over standardized residuals.

    Tracks the cumulative deviation of the stream from its own running
    mean minus a ``delta`` allowance; the statistic is the distance from
    the cumulative sum to its running extremum, tripping at ``lam``.
    Complements :class:`Cusum`: PH's self-centering running mean catches
    slow ramps that stay inside CUSUM's per-sample allowance."""

    __slots__ = ("delta", "lam", "n", "_mean", "_up", "_up_min",
                 "_down", "_down_max")

    def __init__(self, delta: float = 0.05, lam: float = 5.0):
        self.delta = float(delta)
        self.lam = float(lam)
        self.n = 0
        self._mean = 0.0
        self._up = 0.0          # cumulative (z - mean - delta)
        self._up_min = 0.0
        self._down = 0.0        # cumulative (z - mean + delta)
        self._down_max = 0.0

    def update(self, z: float) -> float:
        z = float(z)
        self.n += 1
        self._mean += (z - self._mean) / self.n
        self._up += z - self._mean - self.delta
        self._up_min = min(self._up_min, self._up)
        self._down += z - self._mean + self.delta
        self._down_max = max(self._down_max, self._down)
        return self.score

    @property
    def score(self) -> float:
        if self.n == 0:
            return 0.0
        return max(self._up - self._up_min, self._down_max - self._down)

    @property
    def tripped(self) -> bool:
        return self.score > self.lam

    def reset(self) -> None:
        self.n = 0
        self._mean = self._up = self._up_min = 0.0
        self._down = self._down_max = 0.0


# ---------------------------------------------------------------------- #
# two-sample machinery for campaign regression detection
# ---------------------------------------------------------------------- #
def _ranks_and_tie_counts(x: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """One sort gives both the average ranks and the tie-run counts (the
    Mann-Whitney variance correction needs the latter; computing them here
    saves the extra full sort ``np.unique`` would spend)."""
    order = np.argsort(x, kind="mergesort")
    sx = x[order]
    run_start = np.r_[True, sx[1:] != sx[:-1]]
    edges = np.flatnonzero(run_start)
    counts = np.diff(np.r_[edges, x.size])
    # average 1-based rank of run r spanning [edges[r], edges[r]+counts[r])
    avg = edges + 0.5 * (counts - 1) + 1.0
    ranks = np.empty(x.size, dtype=np.float64)
    ranks[order] = avg[np.cumsum(run_start) - 1]
    return ranks, counts


def rankdata(x: np.ndarray) -> np.ndarray:
    """Average ranks (1-based) with ties sharing their mean rank,
    fully vectorized over the tie runs."""
    x = np.asarray(x, dtype=np.float64).ravel()
    return _ranks_and_tie_counts(x)[0]


def mann_whitney_u(x, y) -> tuple[float, float]:
    """Two-sided Mann-Whitney U test (normal approximation with tie
    correction and continuity correction).

    Latency distributions are multi-modal and heavy-tailed (Figs. 5-6), so
    campaign drift detection needs a *nonparametric* two-sample test — a
    t-test on cluster mixtures answers the wrong question.  Returns
    ``(U, p)`` where U is the statistic of the first sample; ``p = nan``
    when either sample is empty.
    """
    x = np.asarray(x, dtype=np.float64).ravel()
    y = np.asarray(y, dtype=np.float64).ravel()
    n1, n2 = x.size, y.size
    if n1 == 0 or n2 == 0:
        return float("nan"), float("nan")
    ranks, counts = _ranks_and_tie_counts(np.concatenate([x, y]))
    u1 = float(ranks[:n1].sum()) - n1 * (n1 + 1) / 2.0
    n = n1 + n2
    mu = n1 * n2 / 2.0
    # tie correction to the variance (counts = tie-run sizes, same sort)
    tie_term = float(((counts ** 3 - counts).sum())) / (n * (n - 1)) if n > 1 else 0.0
    var = n1 * n2 / 12.0 * ((n + 1) - tie_term)
    if var <= 0:                      # all values identical
        return u1, 1.0
    z = (abs(u1 - mu) - 0.5) / math.sqrt(var)
    p = 2.0 * 0.5 * math.erfc(max(0.0, z) / math.sqrt(2.0))
    return u1, float(min(1.0, p))
