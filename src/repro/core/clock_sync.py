"""CPU <-> accelerator timer synchronization (IEEE 1588 two-way exchange).

Alg. 2 line 1: ``cpu_sync, acc_sync = synchronizeTimers()``.  The offset is
estimated from n delay-request exchanges

    offset_i = ((t2 - t1) + (t3 - t4)) / 2

taking the exchange with the smallest round-trip delay (best-of-n filters
link jitter, the standard PTP trick).  Host timestamps then map to the
accelerator timeline as  t_acc = t_host + offset.

:func:`sync_from_exchanges` performs the estimation on raw ``(t1,t2,t3,t4)``
tuples without touching a device, so recorded telemetry traces
(:mod:`repro.trace`) recompute the exact same mapping offline; the
per-exchange offsets/RTTs ride along on :class:`ClockSync` for trace
recording and diagnostics.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class ClockSync:
    offset: float          # t_acc - t_host at sync time
    rtt: float             # best round-trip delay observed
    n_exchanges: int
    offsets: tuple[float, ...] = ()   # per-exchange offset estimates
    rtts: tuple[float, ...] = ()      # per-exchange round-trip delays

    def host_to_acc(self, t_host: float) -> float:
        return t_host + self.offset


def sync_from_exchanges(exchanges) -> ClockSync:
    """Best-of-n offset from raw exchange tuples ``(t1, t2, t3, t4)``.

    Picks the (first) exchange with the smallest round-trip delay — link
    jitter only ever *adds* to the RTT, so the min-RTT exchange carries the
    least-contaminated offset."""
    exchanges = list(exchanges)
    if not exchanges:
        raise ValueError(
            "clock sync needs at least one exchange (got 0); call "
            "synchronize_timers with n_exchanges >= 1")
    offsets, rtts = [], []
    for t1, t2, t3, t4 in exchanges:
        rtts.append((t4 - t1) - (t3 - t2))
        offsets.append(((t2 - t1) + (t3 - t4)) / 2.0)
    best = int(np.argmin(rtts))        # first minimum, like the seed loop
    return ClockSync(offset=offsets[best], rtt=rtts[best],
                     n_exchanges=len(exchanges),
                     offsets=tuple(offsets), rtts=tuple(rtts))


def synchronize_timers(device, n_exchanges: int = 16) -> ClockSync:
    if n_exchanges < 1:
        raise ValueError(
            f"n_exchanges must be >= 1, got {n_exchanges}: an offset "
            "cannot be estimated from zero exchanges")
    return sync_from_exchanges(
        device.sync_exchange() for _ in range(n_exchanges))
