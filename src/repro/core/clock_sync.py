"""CPU <-> accelerator timer synchronization (IEEE 1588 two-way exchange).

Alg. 2 line 1: ``cpu_sync, acc_sync = synchronizeTimers()``.  The offset is
estimated from n delay-request exchanges

    offset_i = ((t2 - t1) + (t3 - t4)) / 2

taking the exchange with the smallest round-trip delay (best-of-n filters
link jitter, the standard PTP trick).  Host timestamps then map to the
accelerator timeline as  t_acc = t_host + offset.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class ClockSync:
    offset: float          # t_acc - t_host at sync time
    rtt: float             # best round-trip delay observed
    n_exchanges: int

    def host_to_acc(self, t_host: float) -> float:
        return t_host + self.offset


def synchronize_timers(device, n_exchanges: int = 16) -> ClockSync:
    best = None
    for _ in range(n_exchanges):
        t1, t2, t3, t4 = device.sync_exchange()
        rtt = (t4 - t1) - (t3 - t2)
        offset = ((t2 - t1) + (t3 - t4)) / 2.0
        if best is None or rtt < best[0]:
            best = (rtt, offset)
    return ClockSync(offset=best[1], rtt=best[0], n_exchanges=n_exchanges)
