"""Artificial iterative workload specification + sizing rules (paper §V).

The workload is "the same arithmetic instruction repeated multiple times in
each performed iteration", launched on every accelerator core.  Its length
must cover four events (§V bullet list):

  wake-up      : sustained load until the device stabilizes at the set
                 frequency (estimated by comparing first-kernel iteration
                 times against the last kernel's average)
  delay        : several hundred iterations at the initial frequency before
                 the change call, so init/target regions are separable
  switching    : ~10x the longest observed switching latency among a probe
                 subset of pairs (low/mid/high); retried 10x longer if the
                 latency is not captured
  confirmation : several hundred .. a thousand iterations to confirm the
                 target frequency statistically

On real TPU/GPU hardware the workload is the Pallas microbench kernel
(repro.kernels.microbench) — an unrolled FMA chain per grid cell with
MXU/VPU-aligned tiles.  Against the simulator, the same spec drives
SimulatedAccelerator.launch_kernel.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    iters_per_kernel: int            # iterations per kernel launch
    flops_per_iter: float            # arithmetic work per iteration per core
    delay_iters: int                 # iterations before the switch call
    confirm_iters: int               # iterations for target confirmation
    wakeup_kernels: int = 3          # kernels to burn before measuring

    def delay_seconds(self, iter_time_s: float) -> float:
        return self.delay_iters * iter_time_s


def size_workload(*, probe_latency_s: float, iter_time_s: float,
                  delay_iters: int = 400, confirm_iters: int = 600,
                  safety: float = 10.0) -> WorkloadSpec:
    """Apply the paper's sizing rules given a probe of the switching latency
    (upper bound over a few low/mid/high pairs) and the iteration runtime."""
    switch_iters = int(safety * probe_latency_s / iter_time_s) + 1
    total = delay_iters + switch_iters + confirm_iters
    return WorkloadSpec(
        iters_per_kernel=total,
        flops_per_iter=iter_time_s,     # simulator: work expressed in seconds
        delay_iters=delay_iters,
        confirm_iters=confirm_iters,
    )
