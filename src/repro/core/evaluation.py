"""Phase 2-3 repetition loop with the LATEST tool's operational semantics
(paper §VI): RSE-driven stopping, min/max measurement counts, throttle
checks every 5 passes (thermal -> drop newest 5 + 10 s cool-down; power ->
skip the pair), RSE checked every 25 passes.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import stats as statsmod
from repro.core.switching import measure_switch_once
from repro.core.workload import WorkloadSpec


@dataclasses.dataclass(frozen=True)
class MeasureConfig:
    rse_target: float = 0.05
    min_measurements: int = 10
    max_measurements: int = 200
    rse_check_every: int = 25
    throttle_check_every: int = 5
    cooldown_s: float = 10.0
    max_retries: int = 50            # bound on Alg.2 GOTO loops per pass
    k_sigma: float = 2.0
    min_confirm: int = 64            # suffix length the confirm step needs


@dataclasses.dataclass
class PairMeasurement:
    f_init: float
    f_target: float
    latencies: np.ndarray            # one entry per successful pass (s)
    status: str                      # ok | power_throttled | undetectable
    retries: int
    rse: float

    # persistence hooks for resumable sweeps (repro.core.session)
    def to_dict(self) -> dict:
        return {"f_init": self.f_init, "f_target": self.f_target,
                "latencies": [float(v) for v in self.latencies],
                "status": self.status, "retries": self.retries,
                "rse": float(self.rse)}

    @classmethod
    def from_dict(cls, d: dict) -> "PairMeasurement":
        return cls(float(d["f_init"]), float(d["f_target"]),
                   np.asarray(d["latencies"], dtype=np.float64),
                   str(d["status"]), int(d["retries"]), float(d["rse"]))


def measure_pair(device, f_init: float, f_target: float, cal,
                 spec: WorkloadSpec, mc: MeasureConfig | None = None
                 ) -> PairMeasurement:
    if mc is None:
        mc = MeasureConfig()
    lat: list[float] = []
    # O(1) RSE checks: running sums track the growing list (and un-track
    # thermal rollbacks) instead of rescanning it every rse_check_every
    running = statsmod.RunningStats()
    retries = 0
    while len(lat) < mc.max_measurements:
        res = measure_switch_once(device, f_init, f_target, cal, spec,
                                  k_sigma=mc.k_sigma,
                                  min_confirm=mc.min_confirm)
        if res is None:
            retries += 1
            if retries > mc.max_retries:
                return PairMeasurement(f_init, f_target, np.asarray(lat),
                                       "undetectable", retries, float("inf"))
            continue
        lat.append(res.latency)
        running.add(res.latency)

        if len(lat) % mc.throttle_check_every == 0:
            flags = device.throttle_reasons()
            if "power" in flags:
                return PairMeasurement(f_init, f_target, np.asarray(lat),
                                       "power_throttled", retries,
                                       float("inf"))
            if "thermal" in flags:
                for v in lat[-mc.throttle_check_every:]:
                    running.remove(v)
                del lat[-mc.throttle_check_every:]          # drop newest 5
                device.usleep(mc.cooldown_s)
                continue

        if (len(lat) >= mc.min_measurements
                and len(lat) % mc.rse_check_every == 0
                and running.rse() < mc.rse_target):
            break
    return PairMeasurement(f_init, f_target, np.asarray(lat), "ok", retries,
                           running.rse())
