"""Phase 2 + per-core phase-3 evaluation (Alg. 2).

One measurement pass:
  1. synchronize timers (IEEE 1588)
  2. set initial frequency, run the warm-up workload
  3. launch the benchmark kernel; usleep(delay); record t_s (host clock,
     mapped to the accelerator timeline); issue the change to the target
  4. wait for the kernel; per core, find the first iteration at/after t_s
     whose runtime falls inside the +-2*sigma band of the target baseline
  5. confirm: the REMAINING iterations' mean must match the target baseline
     (difference CI contains zero, or |diff| < tol) — rejects "passing
     through" the target band while still adapting
  6. switching latency of the pass = max over cores of (t_e - t_s)

Returns None when no core yields a viable (detected + confirmed) result;
the caller (evaluation.measure_pair) repeats the pass — Alg. 2's GOTO.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import stats
from repro.core.clock_sync import synchronize_timers
from repro.core.workload import WorkloadSpec


@dataclasses.dataclass
class SwitchPass:
    latency: float                 # max over cores (s)
    t_s: float                     # change request, accelerator timeline
    core_latencies: np.ndarray     # per-core t_e - t_s (nan = not viable)
    n_viable: int
    transition_index: int          # iteration index of detection (max core)


def measure_switch_once(device, f_init: float, f_target: float,
                        cal, spec: WorkloadSpec, *, k_sigma: float = 2.0,
                        z: float = 1.96, tol_frac: float = 0.02,
                        min_confirm: int = 64) -> SwitchPass | None:
    target = cal.baselines[f_target]
    sync = synchronize_timers(device)

    device.set_frequency(f_init)
    device.run_kernel(spec.iters_per_kernel // 2, spec.flops_per_iter)  # warm up

    h = device.launch_kernel(spec.iters_per_kernel, spec.flops_per_iter)
    init_iter = cal.baselines[f_init].mean
    device.usleep(spec.delay_iters * init_iter)
    t_s = sync.host_to_acc(device.host_now())       # Alg.2 line 6
    device.set_frequency(f_target)
    data = device.wait(h)                           # (cores, iters, 2)

    starts, ends = data[..., 0], data[..., 1]
    durs = ends - starts
    lo, hi = stats.two_sigma_band(target, k_sigma)
    tol = tol_frac * target.mean

    n_cores, n_iters = durs.shape
    after = starts >= t_s                                    # Alg.2 line 12
    in_band = (durs >= lo) & (durs <= hi) & after
    has_hit = in_band.any(axis=1)
    first_hit = np.where(has_hit, in_band.argmax(axis=1), n_iters)

    core_lat = np.full(n_cores, np.nan)
    trans_idx = np.full(n_cores, -1, dtype=int)
    for c in np.nonzero(has_hit)[0]:
        i = int(first_hit[c])
        rest = durs[c, i:]
        if rest.size < min_confirm:
            continue
        rest_stats = stats.mean_std(rest)
        if stats.null_hypothesis_holds(rest_stats, target, z=z, tol=tol):
            core_lat[c] = ends[c, i] - t_s                   # t_e - t_s
            trans_idx[c] = i

    viable = ~np.isnan(core_lat)
    if not viable.any():
        return None                                          # Alg.2 GOTO
    return SwitchPass(
        latency=float(np.nanmax(core_lat)),
        t_s=float(t_s),
        core_latencies=core_lat,
        n_viable=int(viable.sum()),
        transition_index=int(trans_idx[np.nanargmax(core_lat)]),
    )
