"""Phase 2 + per-core phase-3 evaluation (Alg. 2).

One measurement pass:
  1. synchronize timers (IEEE 1588)
  2. set initial frequency, run the warm-up workload
  3. launch the benchmark kernel; usleep(delay); record t_s (host clock,
     mapped to the accelerator timeline); issue the change to the target
  4. wait for the kernel; per core, find the first iteration at/after t_s
     whose runtime falls inside the +-2*sigma band of the target baseline
  5. confirm: the REMAINING iterations' mean must match the target baseline
     (difference CI contains zero, or |diff| < tol) — rejects "passing
     through" the target band while still adapting
  6. switching latency of the pass = max over cores of (t_e - t_s)

Returns None when no core yields a viable (detected + confirmed) result;
the caller (evaluation.measure_pair) repeats the pass — Alg. 2's GOTO.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import stats
from repro.core.clock_sync import synchronize_timers
from repro.core.workload import WorkloadSpec


@dataclasses.dataclass
class SwitchPass:
    latency: float                 # max over cores (s)
    t_s: float                     # change request, accelerator timeline
    core_latencies: np.ndarray     # per-core t_e - t_s (nan = not viable)
    n_viable: int
    transition_index: int          # iteration index of detection (max core)


def _confirm_loop(durs, ends, t_s, target, first_hit, has_hit,
                  min_confirm, z, tol):
    """Reference per-core confirm loop (one mean_std per candidate core);
    kept for the equivalence test of the vectorized path."""
    n_cores, n_iters = durs.shape
    core_lat = np.full(n_cores, np.nan)
    trans_idx = np.full(n_cores, -1, dtype=int)
    for c in np.nonzero(has_hit)[0]:
        i = int(first_hit[c])
        rest = durs[c, i:]
        if rest.size < min_confirm:
            continue
        rest_stats = stats.mean_std(rest)
        if stats.null_hypothesis_holds(rest_stats, target, z=z, tol=tol):
            core_lat[c] = ends[c, i] - t_s                   # t_e - t_s
            trans_idx[c] = i
    return core_lat, trans_idx


def _confirm_vectorized(durs, ends, t_s, target, first_hit, has_hit,
                        min_confirm, z, tol):
    """Suffix statistics for every candidate core at once: reverse cumsums
    give mean/std of the remaining iterations without a Python-level loop.
    Rows are centered on their full-row mean first so the sum-of-squares
    variance keeps precision on tightly clustered iteration times."""
    n_cores, n_iters = durs.shape
    core_lat = np.full(n_cores, np.nan)
    trans_idx = np.full(n_cores, -1, dtype=int)
    cand = has_hit & (n_iters - first_hit >= min_confirm)
    cores = np.flatnonzero(cand)
    if not cores.size:
        return core_lat, trans_idx
    d = durs[cores]
    center = d.mean(axis=1, keepdims=True)
    cd = d - center
    s1 = np.cumsum(cd[:, ::-1], axis=1)[:, ::-1]     # s1[:, i] = sum cd[:, i:]
    s2 = np.cumsum((cd * cd)[:, ::-1], axis=1)[:, ::-1]
    rows = np.arange(cores.size)
    i = first_hit[cores]
    n = (n_iters - i).astype(np.float64)
    m = s1[rows, i] / n                              # centered suffix mean
    mean = center[:, 0] + m
    # ddof=1; a single-sample suffix has std 0 (the loop's mean_std), not 0/0
    var = np.where(n > 1, (s2[rows, i] - n * m * m) / np.maximum(n - 1, 1),
                   0.0)
    se = np.sqrt(np.maximum(var, 0.0) / n + target.se ** 2)
    diff = mean - target.mean
    # null_hypothesis_holds, vectorized: CI contains zero OR |diff| < tol
    ok = ((diff - z * se <= 0.0) & (diff + z * se >= 0.0)) \
        | (np.abs(diff) < tol)
    sel = cores[ok]
    core_lat[sel] = ends[sel, i[ok]] - t_s           # t_e - t_s
    trans_idx[sel] = i[ok]
    return core_lat, trans_idx


_CONFIRM_IMPLS = {"loop": _confirm_loop, "vectorized": _confirm_vectorized}


def measure_switch_once(device, f_init: float, f_target: float,
                        cal, spec: WorkloadSpec, *, k_sigma: float = 2.0,
                        z: float = 1.96, tol_frac: float = 0.02,
                        min_confirm: int = 64,
                        confirm_impl: str = "vectorized"
                        ) -> SwitchPass | None:
    if confirm_impl not in _CONFIRM_IMPLS:    # fail before touching the device
        raise ValueError(f"unknown confirm impl {confirm_impl!r}")
    target = cal.baselines[f_target]
    sync = synchronize_timers(device)

    device.set_frequency(f_init)
    # warm up, run-for-effect: backends exposing warm_kernel (e.g. the
    # telemetry recorder) may skip materializing timestamps nobody reads
    warm = getattr(device, "warm_kernel", None) or device.run_kernel
    warm(spec.iters_per_kernel // 2, spec.flops_per_iter)

    h = device.launch_kernel(spec.iters_per_kernel, spec.flops_per_iter)
    init_iter = cal.baselines[f_init].mean
    device.usleep(spec.delay_iters * init_iter)
    t_s = sync.host_to_acc(device.host_now())       # Alg.2 line 6
    device.set_frequency(f_target)
    data = device.wait(h)                           # (cores, iters, 2)

    return detect_switch(data, t_s, target, k_sigma=k_sigma, z=z,
                         tol_frac=tol_frac, min_confirm=min_confirm,
                         confirm_impl=confirm_impl)


def detect_switch(data: np.ndarray, t_s: float, target, *,
                  k_sigma: float = 2.0, z: float = 1.96,
                  tol_frac: float = 0.02, min_confirm: int = 64,
                  confirm_impl: str = "vectorized") -> SwitchPass | None:
    """Pure Alg.2 lines 12-21 on one pass's timestamps: detect + confirm
    the transition given the change-request time ``t_s`` (accelerator
    timeline) and the ``target`` frequency baseline.  Factored out of
    :func:`measure_switch_once` so recorded traces (and the streaming
    estimator in :mod:`repro.trace.online`) run the identical batch
    decision without a device."""
    if confirm_impl not in _CONFIRM_IMPLS:
        raise ValueError(f"unknown confirm impl {confirm_impl!r}")
    starts, ends = data[..., 0], data[..., 1]
    durs = ends - starts
    lo, hi = stats.two_sigma_band(target, k_sigma)
    tol = tol_frac * target.mean

    n_cores, n_iters = durs.shape
    after = starts >= t_s                                    # Alg.2 line 12
    in_band = (durs >= lo) & (durs <= hi) & after
    has_hit = in_band.any(axis=1)
    first_hit = np.where(has_hit, in_band.argmax(axis=1), n_iters)

    core_lat, trans_idx = _CONFIRM_IMPLS[confirm_impl](
        durs, ends, t_s, target, first_hit, has_hit, min_confirm, z, tol)

    viable = ~np.isnan(core_lat)
    if not viable.any():
        return None                                          # Alg.2 GOTO
    return SwitchPass(
        latency=float(np.nanmax(core_lat)),
        t_s=float(t_s),
        core_latencies=core_lat,
        n_viable=int(viable.sum()),
        transition_index=int(trans_idx[np.nanargmax(core_lat)]),
    )
