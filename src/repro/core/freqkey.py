"""Domain-aware frequency keys.

The paper's methodology only assumes "a settable frequency", but every
device measured through PR 9 had exactly one clock domain, so the whole
pipeline flows bare MHz floats: calibration baselines are ``dict[float,
FreqStats]``, pairs are ``(float, float)`` tuples, CSV names embed
``int(f)``, and :func:`repro.core.pairtask.pair_seed` hashes ``f"{f:.6g}"``.
Heterogeneous devices (core + uncore/memory clocks, e-/p-core pstate
clusters) need to say *which* domain a frequency belongs to — without
perturbing a single bit of the existing single-domain artifacts.

The canonical wire form therefore stays a ``float``:

* a **bare MHz value** is its own key (today's devices, unchanged);
* a **domain-qualified** frequency ``(domain, mhz)`` encodes as
  ``DOMAIN_STRIDE * index(domain) + mhz`` — e.g. ``("core", 1410)`` ->
  ``101410.0``, ``("uncore", 600)`` -> ``200600.0``.

Encoded keys ride through every float-shaped seam for free: dict keys,
``(f_init, f_target)`` pair tuples, numpy arrays, CSV names
(``201410_100600_node0_0.csv``), content digests, the trace event stream,
and the blake2s pair seed.  Domains come from the fixed table below (not a
runtime registry) so every process — thread workers, process pools, cluster
nodes — decodes identically without coordination.

An encoded key names an *operating point*: the given domain at the given
MHz with every other domain at its device-default value.  That keeps phase
1 well-posed (one operating point = one iteration-time baseline) and makes
cross-domain pairs ordinary ``(f_init, f_target)`` pairs: the transition
from ``("core", v)`` to ``("uncore", w)`` moves BOTH clocks, which is
exactly the interaction the multi-domain backends model.

Constraints enforced by :func:`canon_freq`:

* domain-qualified MHz must be whole numbers in ``(0, DOMAIN_STRIDE)``,
  so the encoded float renders exactly under the ``%.6g`` formatting
  :func:`repro.core.pairtask.pair_seed` applies (6 significant digits
  cover every integer below 1e6 — a rounding collision there would merge
  two pairs' RNG streams);
* bare floats below ``DOMAIN_STRIDE`` pass through untouched (bit-identity
  for single-domain backends is by construction, not by convention).
"""
from __future__ import annotations

# Fixed canonical domain table.  Index 0 is reserved for the implicit
# domain of single-domain devices (bare floats, never encoded); real
# domains start at 1.  Append-only: reordering would re-key every stored
# multi-domain artifact.
DOMAINS: tuple[str, ...] = ("core", "uncore", "mem", "ecore", "pcore")

DOMAIN_STRIDE = 100_000.0

_INDEX = {name: i + 1 for i, name in enumerate(DOMAINS)}


def domain_index(domain: str) -> int:
    """1-based index of ``domain`` in the canonical table."""
    try:
        return _INDEX[domain]
    except KeyError:
        raise KeyError(
            f"unknown frequency domain {domain!r}; canonical domains: "
            f"{list(DOMAINS)}") from None


def encode_freq(domain: str, mhz: float) -> float:
    """Encode one (domain, MHz) operating point as a canonical float."""
    idx = domain_index(domain)
    mhz = float(mhz)
    if not 0.0 < mhz < DOMAIN_STRIDE:
        raise ValueError(
            f"domain-qualified frequency {domain}:{mhz:g} out of range "
            f"(0, {DOMAIN_STRIDE:g}) MHz")
    if mhz != int(mhz):
        raise ValueError(
            f"domain-qualified frequency {domain}:{mhz} must be a whole "
            "number of MHz: the encoded key must survive the pair-seed's "
            "%.6g formatting bit-exactly")
    return DOMAIN_STRIDE * idx + mhz


def canon_freq(f) -> float:
    """Canonicalize any accepted spelling of a frequency key to its float
    wire form.

    Accepts a bare number (returned as ``float``, untouched), a
    ``(domain, mhz)`` tuple/list, a ``"domain:mhz"`` string, a numeric
    string ``"1410"``, or an already-encoded float (idempotent).
    """
    if isinstance(f, str):
        if ":" in f:
            domain, _, mhz = f.partition(":")
            return encode_freq(domain.strip(), float(mhz))
        return float(f)
    if isinstance(f, (tuple, list)):
        if len(f) != 2:
            raise ValueError(
                f"frequency key {f!r} must be (domain, mhz), got "
                f"{len(f)} elements")
        return encode_freq(str(f[0]), float(f[1]))
    return float(f)


def has_domain(f: float) -> bool:
    """True when ``f`` is a domain-encoded key (not a bare MHz value)."""
    return float(f) >= DOMAIN_STRIDE


def split_freq(f: float) -> tuple[str | None, float]:
    """Decode a canonical key to ``(domain, mhz)``; bare values decode to
    ``(None, mhz)``."""
    f = float(f)
    if f < DOMAIN_STRIDE:
        return None, f
    idx = int(f // DOMAIN_STRIDE)
    if idx > len(DOMAINS):
        raise ValueError(
            f"encoded frequency {f:g} names domain index {idx}, beyond "
            f"the canonical table {list(DOMAINS)}")
    return DOMAINS[idx - 1], f - DOMAIN_STRIDE * idx


def freq_domain(f: float, default: str = "core") -> str:
    """Domain name of a key; bare MHz values report ``default``."""
    domain, _ = split_freq(f)
    return default if domain is None else domain


def freq_mhz(f: float) -> float:
    """The physical MHz value of a key, domain stripped."""
    return split_freq(f)[1]


def format_freq(f: float) -> str:
    """Human form: ``"1410"`` for bare keys, ``"uncore:600"`` for
    domain-qualified ones."""
    domain, mhz = split_freq(f)
    text = f"{mhz:g}"
    return text if domain is None else f"{domain}:{text}"


def transition_class(f_init: float, f_target: float) -> str:
    """Label one pair by which domain(s) move: ``"core"`` (same-domain),
    or ``"core->uncore"`` for cross-domain transitions.  Bare keys count
    as the implicit ``"core"`` domain."""
    a, b = freq_domain(f_init), freq_domain(f_target)
    return a if a == b else f"{a}->{b}"


def spec_form(f: float):
    """The JSON-spec spelling of a key: bare floats stay numbers (so
    existing campaign specs keep byte-identical canonical JSON and ids);
    domain-qualified keys render as ``"domain:mhz"`` strings."""
    f = float(f)
    return format_freq(f) if has_domain(f) else f
