"""Silhouette score (paper §VII-B: all multi-cluster pairs score > 0.4,
mean 0.84 across the three GPUs)."""
from __future__ import annotations

import numpy as np


def silhouette_score(x: np.ndarray, labels: np.ndarray) -> float:
    """Mean silhouette over non-noise points; requires >= 2 clusters."""
    x = np.asarray(x, dtype=np.float64).ravel()
    labels = np.asarray(labels)
    keep = labels >= 0
    x, labels = x[keep], labels[keep]
    ids = np.unique(labels)
    if len(ids) < 2 or len(x) < 3:
        return float("nan")
    d = np.abs(x[:, None] - x[None, :])
    s = np.zeros(len(x))
    for i in range(len(x)):
        same = labels == labels[i]
        n_same = same.sum()
        a = d[i, same].sum() / max(1, n_same - 1)
        b = min(d[i, labels == c].mean() for c in ids if c != labels[i])
        s[i] = 0.0 if max(a, b) == 0 else (b - a) / max(a, b)
    return float(s.mean())
