"""Silhouette score (paper §VII-B: all multi-cluster pairs score > 0.4,
mean 0.84 across the three GPUs).

Latency samples are 1-D, so the mean absolute distance from a value ``v``
to a sorted cluster ``y_1 <= ... <= y_m`` needs no pairwise matrix: with
``k`` values at or below ``v`` and prefix sums ``P``,

    sum_j |v - y_j| = v*k - P[k] + (P[m] - P[k]) - v*(m - k)

so one sort per cluster plus one ``searchsorted`` per (point, cluster)
gives every a(i)/b(i) in O(n log n) time and O(n) memory — that is the
default ``impl="sorted"`` path.  ``impl="matrix"`` keeps the original
O(n²) formulation as the executable reference; the two agree to ~1e-15
(summation order differs, so bit-identity is not expected).
"""
from __future__ import annotations

import numpy as np


def _silhouette_matrix(x: np.ndarray, labels: np.ndarray,
                       ids: np.ndarray) -> float:
    """Reference O(n²) path (full |xi - xj| matrix)."""
    d = np.abs(x[:, None] - x[None, :])
    s = np.zeros(len(x))
    for i in range(len(x)):
        same = labels == labels[i]
        n_same = same.sum()
        a = d[i, same].sum() / max(1, n_same - 1)
        b = min(d[i, labels == c].mean() for c in ids if c != labels[i])
        s[i] = 0.0 if max(a, b) == 0 else (b - a) / max(a, b)
    return float(s.mean())


def _silhouette_sorted(x: np.ndarray, labels: np.ndarray,
                       ids: np.ndarray) -> float:
    n, k = len(x), len(ids)
    li = np.searchsorted(ids, labels)          # 0..k-1 cluster index
    dist_sum = np.empty((n, k))                # sum |x_i - y| per cluster
    sizes = np.empty(k)
    for j in range(k):
        vals = np.sort(x[li == j])
        m = vals.size
        sizes[j] = m
        # shift by the cluster's own minimum: a constant cluster then sums
        # to EXACTLY zero (as the matrix path's |v - v| terms do) — without
        # it, the ~1e-16 rounding residue of v*pos - pref[pos] gets
        # amplified to O(1) by (b - a)/max(a, b) when true a and b are 0
        base = vals[0]
        pref = np.concatenate([[0.0], np.cumsum(vals - base)])
        pos = np.searchsorted(vals, x, side="right")
        xs = x - base
        below = xs * pos - pref[pos]
        above = (pref[m] - pref[pos]) - xs * (m - pos)
        dist_sum[:, j] = below + above
    rows = np.arange(n)
    a = dist_sum[rows, li] / np.maximum(1, sizes[li] - 1)
    mean_other = dist_sum / sizes
    mean_other[rows, li] = np.inf
    b = mean_other.min(axis=1)
    denom = np.maximum(a, b)
    s = np.where(denom == 0, 0.0, (b - a) / np.where(denom == 0, 1.0, denom))
    return float(s.mean())


def silhouette_score(x: np.ndarray, labels: np.ndarray, *,
                     impl: str = "sorted") -> float:
    """Mean silhouette over non-noise points; requires >= 2 clusters."""
    if impl not in ("sorted", "matrix"):
        raise ValueError(f"unknown silhouette impl {impl!r}")
    x = np.asarray(x, dtype=np.float64).ravel()
    labels = np.asarray(labels)
    keep = labels >= 0
    x, labels = x[keep], labels[keep]
    ids = np.unique(labels)
    if len(ids) < 2 or len(x) < 3:
        return float("nan")
    if impl == "matrix":
        return _silhouette_matrix(x, labels, ids)
    return _silhouette_sorted(x, labels, ids)
