"""Per-pair results, CSV persistence (LATEST naming convention) and the
summary statistics of Table II / Figs. 3-4.
"""
from __future__ import annotations

import dataclasses
import os

import numpy as np

from repro.core.dbscan import NOISE, adaptive_dbscan, split_clusters
from repro.core.freqkey import freq_domain
from repro.core.paths import atomic_replace
from repro.core.silhouette import silhouette_score


@dataclasses.dataclass
class PairResult:
    f_init: float
    f_target: float
    latencies: np.ndarray          # raw passes (s)
    clean: np.ndarray              # after DBSCAN outlier removal
    outliers: np.ndarray
    n_clusters: int
    silhouette: float
    status: str = "ok"
    labels: np.ndarray | None = None   # per-sample DBSCAN labels (-1 = noise)

    @property
    def worst_case(self) -> float:     # max switching latency (clean)
        return float(self.clean.max()) if self.clean.size else float("nan")

    @property
    def best_case(self) -> float:
        return float(self.clean.min()) if self.clean.size else float("nan")

    @property
    def mean(self) -> float:
        return float(self.clean.mean()) if self.clean.size else float("nan")

    @property
    def outlier_mask(self) -> np.ndarray:
        """Per-sample outlier flags, aligned with ``latencies``.  Prefers
        the persisted DBSCAN labels; the value-membership fallback for
        label-less legacy results mislabels values duplicated across the
        clean and outlier sets, which is exactly why labels are stored."""
        if self.labels is not None:
            return np.asarray(self.labels) == NOISE
        return np.isin(np.round(self.latencies, 12),
                       np.round(self.outliers, 12))


def analyse_pair(f_init, f_target, latencies, status="ok", *,
                 impl: str = "sorted",
                 with_silhouette: bool = True) -> PairResult:
    """Cluster one pair's samples; ``with_silhouette=False`` skips the
    §VII-B validation score for consumers that only need the
    clean/outlier split (e.g. regression re-analysis)."""
    lat = np.asarray(latencies, dtype=np.float64).ravel()
    if lat.size < 5:
        return PairResult(f_init, f_target, lat, lat, np.empty(0), 1,
                          float("nan"), status,
                          labels=np.zeros(lat.size, dtype=int))
    res = adaptive_dbscan(lat, impl=impl)
    clean, outliers, clusters = split_clusters(lat, res)
    sil = (silhouette_score(lat, res.labels, impl=impl)
           if with_silhouette and res.n_clusters >= 2 else float("nan"))
    if clean.size == 0:
        clean = lat
    return PairResult(f_init, f_target, lat, clean, outliers,
                      max(1, res.n_clusters), sil, status, labels=res.labels)


class LatencyTable:
    """All measured pairs for one device; feeds the governor + benchmarks."""

    def __init__(self, device_name: str = "sim", device_index: int = 0,
                 hostname: str = "node0"):
        self.device_name = device_name
        self.device_index = device_index
        self.hostname = hostname
        self.pairs: dict[tuple[float, float], PairResult] = {}

    def add(self, pr: PairResult) -> None:
        self.pairs[(pr.f_init, pr.f_target)] = pr

    def lookup(self, f_init: float, f_target: float) -> PairResult | None:
        return self.pairs.get((f_init, f_target))

    # ------------------------------------------------------------------ #
    def csv_name(self, f_init: float, f_target: float) -> str:
        """LATEST convention: <init>_<target>_<hostname>_<gpuidx>.csv"""
        return f"{int(f_init)}_{int(f_target)}_{self.hostname}_{self.device_index}.csv"

    def save_csv(self, out_dir: str) -> list[str]:
        os.makedirs(out_dir, exist_ok=True)
        paths = []
        for (fi, ft), pr in self.pairs.items():
            p = os.path.join(out_dir, self.csv_name(fi, ft))
            rows = np.column_stack([pr.latencies,
                                    pr.outlier_mask.astype(np.float64)])
            # %.17g round-trips float64 exactly, so a store-loaded table is
            # bit-identical to the live one — the campaign determinism
            # contract reaches through the artifact layer
            with atomic_replace(p) as tmp:
                np.savetxt(tmp, rows, fmt=("%.17g", "%d"), delimiter=",",
                           header="latency_s,is_outlier", comments="")
            paths.append(p)
        return paths

    @staticmethod
    def load_csv(path: str) -> tuple[np.ndarray, np.ndarray]:
        with open(path) as f:
            body = f.readlines()[1:]       # header-only = failed pair
        if not body:
            return np.empty(0), np.empty(0, dtype=bool)
        rows = np.loadtxt(body, delimiter=",").reshape(-1, 2)
        return rows[:, 0], rows[:, 1].astype(bool)

    # ------------------------------------------------------------------ #
    def summary(self) -> dict:
        """Table II analogue: min/mean/max of the worst-case and best-case
        per-pair switching latencies, with the arg-pairs."""
        ok = [p for p in self.pairs.values() if p.status == "ok" and p.clean.size]
        if not ok:
            return {}
        worst = np.array([p.worst_case for p in ok])
        best = np.array([p.best_case for p in ok])
        pairs = [(p.f_init, p.f_target) for p in ok]

        def stats_of(v):
            return {"min_ms": float(v.min()) * 1e3,
                    "mean_ms": float(v.mean()) * 1e3,
                    "max_ms": float(v.max()) * 1e3,
                    "argmin": pairs[int(v.argmin())],
                    "argmax": pairs[int(v.argmax())]}

        return {"worst_case": stats_of(worst), "best_case": stats_of(best),
                "n_pairs": len(ok),
                "one_cluster_fraction": float(np.mean(
                    [p.n_clusters == 1 for p in ok])),
                "max_clusters": int(max(p.n_clusters for p in ok))}

    def heatmap(self, which: str = "worst") -> tuple[np.ndarray, list, list]:
        """(matrix, init_freqs, target_freqs) — Fig. 3 analogue; NaN where
        unmeasured.  Rows = initial, columns = target."""
        inits = sorted({fi for fi, _ in self.pairs})
        targets = sorted({ft for _, ft in self.pairs})
        m = np.full((len(inits), len(targets)), np.nan)
        for (fi, ft), p in self.pairs.items():
            if p.status != "ok" or not p.clean.size:
                continue
            v = p.worst_case if which == "worst" else p.best_case
            m[inits.index(fi), targets.index(ft)] = v
        return m, inits, targets

    def asymmetry(self) -> dict:
        """Fig. 4 analogue: worst-case latency distributions for increasing
        (init < target) vs decreasing (init > target) transitions.
        Cross-domain pairs are excluded — "up" vs "down" is only meaningful
        within one clock ladder (comparing a core MHz against an uncore MHz
        orders nothing physical); within a domain the encoded keys order
        exactly like the physical MHz, so single-domain tables are
        unaffected."""
        same = [p for p in self.pairs.values()
                if p.status == "ok" and p.clean.size
                and freq_domain(p.f_init) == freq_domain(p.f_target)]
        up = [p.worst_case for p in same if p.f_init < p.f_target]
        down = [p.worst_case for p in same if p.f_init > p.f_target]
        def dist(v):
            v = np.asarray(v)
            if not v.size:
                return {}
            return {"mean_ms": float(v.mean()) * 1e3,
                    "median_ms": float(np.median(v)) * 1e3,
                    "p95_ms": float(np.quantile(v, 0.95)) * 1e3,
                    "max_ms": float(v.max()) * 1e3, "n": int(v.size)}
        return {"increase": dist(up), "decrease": dist(down)}
