"""Picklable per-pair measurement tasks.

Process-parallel sweeps cannot ship a live device object to a worker (the
simulator holds numpy RNG state and an event timeline; real backends hold
driver handles).  What crosses the boundary instead is a :class:`PairTask`:
the backend *name* plus its constructor options, the calibration result,
and the workload/measurement configs — all plain data.  The worker rebuilds
the backend locally and measures.

The same task spec also gives every executor a *determinism* guarantee the
shared-device path never had: each pair is measured on a device seeded by
:func:`pair_seed`, a stable hash of ``(base_seed, f_init, f_target)``.
Pair results therefore depend only on the unit spec and the pair — never on
which worker ran them, in what order, or whether the sweep was interrupted
and resumed — so serial, thread, and process schedules (and crash-requeued
re-runs) produce bit-identical tables on simulated backends.
"""
from __future__ import annotations

import dataclasses
import hashlib

from repro.core.calibration import Calibration
from repro.core.evaluation import (MeasureConfig, PairMeasurement,
                                   measure_pair)
from repro.core.workload import WorkloadSpec


def pair_seed(base_seed: int, f_init: float, f_target: float) -> int:
    """Stable 64-bit seed for one (f_init, f_target) measurement device.

    Uses blake2s, not ``hash()``: Python string hashing is salted per
    process, and the whole point is that every process derives the same
    stream."""
    key = f"{int(base_seed)}|{f_init:.6g}|{f_target:.6g}".encode()
    return int.from_bytes(hashlib.blake2s(key, digest_size=8).digest(),
                          "big")


def extract_ground_truth(device) -> dict[tuple[float, float], float]:
    """Max true transition latency per (from, to) from a simulator's event
    log; empty for backends that keep no history (real hardware)."""
    gt: dict[tuple[float, float], float] = {}
    for h in getattr(device, "history", []):
        k = (float(h["from"]), float(h["to"]))
        gt[k] = max(gt.get(k, 0.0), float(h["true_latency"]))
    return gt


@dataclasses.dataclass(frozen=True)
class PairTask:
    """Everything a worker needs to measure one frequency pair, as plain
    picklable data.  ``options`` is the canonical sorted (name, value)
    tuple form (see :class:`repro.campaign.spec.DeviceSpec`)."""

    backend: str
    options: tuple                      # sorted (name, value) pairs, no seed
    base_seed: int
    cal: Calibration
    spec: WorkloadSpec
    measure: MeasureConfig
    # propagated span-profiler trace context (repro.obs): the session's
    # active span id, so pair spans recorded by thread/process workers
    # stitch under the session that dispatched them.  Never feeds seeds or
    # fingerprints — profiling must not perturb measurement bits.
    obs_ctx: str | None = None

    @staticmethod
    def make(backend: str, options: dict, cal: Calibration,
             spec: WorkloadSpec, measure: MeasureConfig,
             obs_ctx: str | None = None) -> "PairTask":
        opts = dict(options or {})
        base_seed = int(opts.pop("seed", 0))
        return PairTask(backend, tuple(sorted(opts.items())), base_seed,
                        cal, spec, measure, obs_ctx)


def run_pair_task(task: PairTask, pair, worker: int = 0
                  ) -> tuple[PairMeasurement, dict]:
    """Measure one pair on a freshly built, pair-seeded device.

    Returns ``(measurement, ground_truth)`` where ground truth is the
    simulator's true-latency log for this device (empty on hardware).
    Module-level on purpose: ``functools.partial(run_pair_task, task)`` is
    what sessions hand to executors, and it pickles by reference."""
    from repro import obs
    from repro.backends import create_backend
    f_init, f_target = pair
    with obs.span("pair", "pair", parent=task.obs_ctx or obs.AMBIENT,
                  f_init=f_init, f_target=f_target, worker=worker):
        device = create_backend(
            task.backend, **dict(task.options),
            seed=pair_seed(task.base_seed, f_init, f_target))
        pm = measure_pair(device, f_init, f_target, task.cal, task.spec,
                          task.measure)
        return pm, extract_ground_truth(device)
