"""Resumable, backend-agnostic measurement sessions.

The paper's pipeline (calibrate -> switch-detect -> filter) was a serial
loop over frequency pairs against one concrete simulator.  A
:class:`MeasurementSession` generalizes it into the shape fleet-scale DVFS
tooling needs:

* the target is any registered :mod:`repro.backends` backend (or an
  explicit device instance), never a concrete simulator class;
* phase-1 calibration state (baselines, workload sizing) is owned by the
  session and computed once;
* phase-2/3 pair measurements are scheduled through a pluggable executor —
  serial, thread-parallel, or process-parallel.  For *virtual* registry
  backends (the simulators) every pair is measured on a freshly built
  device seeded from ``(base_seed, f_init, f_target)``
  (:mod:`repro.core.pairtask`): the per-pair work is plain picklable data,
  so it can cross process boundaries, and the resulting tables are
  bit-identical across serial/thread/process schedules and across
  crash-resume boundaries.  Explicit device instances (hardware,
  trace-replay, traced runs) keep the shared-device path;
* with ``out_dir`` set, every finished pair is persisted to disk the moment
  it completes, so an interrupted sweep resumes where it stopped (already
  measured pairs are loaded, not re-measured) and calibration is reloaded
  instead of re-run.

``run_latest`` (repro.core.latest) is now a thin veneer over this class.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import os

import numpy as np

from repro import obs
from repro.core.calibration import Calibration, calibrate, valid_pairs
from repro.core.evaluation import (MeasureConfig, PairMeasurement,
                                   measure_pair)
from repro.core.executors import get_executor, map_pairs_with_callback
from repro.core.freqkey import format_freq
from repro.core.latency_table import LatencyTable, analyse_pair
from repro.core.pairtask import (PairTask, extract_ground_truth,
                                 run_pair_task)
from repro.core.paths import atomic_replace
from repro.core.stats import FreqStats
from repro.core.workload import WorkloadSpec, size_workload

_SESSION_FILE = "session.json"
_PAIR_DIR = "pairs"


@dataclasses.dataclass(frozen=True)
class LatestConfig:
    base_iter_s: float = 40e-6          # iteration time at f_max
    delay_iters: int = 300
    confirm_iters: int = 400
    probe_pairs: int = 3                # low/mid/high probe for sizing
    measure: MeasureConfig = dataclasses.field(default_factory=MeasureConfig)


def probe_latency(device, frequencies, spec, cal, mc) -> float:
    """Upper-bound probe over low/mid/high pairs (workload-sizing rule)."""
    fs = sorted(frequencies)
    probes = [(fs[0], fs[-1]), (fs[-1], fs[0]),
              (fs[len(fs) // 2], fs[-1])]
    worst = 1e-3
    for fi, ft in probes:
        if fi == ft:
            continue
        pm = measure_pair(device, fi, ft, cal, spec,
                          dataclasses.replace(mc, min_measurements=3,
                                              max_measurements=3))
        if pm.latencies.size:
            worst = max(worst, float(pm.latencies.max()))
    return worst


@dataclasses.dataclass(frozen=True)
class SessionConfig:
    latest: LatestConfig = dataclasses.field(default_factory=LatestConfig)
    executor: object = "serial"         # "serial" | "threads" | instance
    max_workers: int = 4
    out_dir: str | None = None          # persistence root; None = in-memory


class MeasurementSession:
    """Owns one measurement campaign against one device (or one fleet of
    independent identical devices when thread-parallel).

    ``engine`` picks how phase-2/3 pair measurements execute; how it
    combines with the other scheduling knobs:

    ================  ==========================  =======================
    combination       ``engine="serial"``         ``engine="batched"``
    ================  ==========================  =======================
    executor serial   per-pair loop (reference)   lock-stepped lane grid
    executor threads  per-pair, thread pool       ValueError (the engine
    executor procs    per-pair, process pool      is one fused program —
                                                  there is nothing left
                                                  to farm out)
    trace=...         shared-device path, traced  ValueError (a trace is
                                                  one device's stream;
                                                  lanes would interleave)
    explicit device   shared-device path          ValueError (lanes need
    / hw backend                                  the registry factory +
                                                  the simulator's split
                                                  wait protocol)
    ================  ==========================  =======================

    Every supported combination lands on bit-identical per-pair tables:
    pairs are measured on devices seeded by ``pair_seed(base_seed,
    f_init, f_target)`` regardless of schedule (PR-5 contract, extended
    to the batched engine by :mod:`repro.core.batched_sweep`)."""

    def __init__(self, device=None, frequencies=None,
                 cfg: SessionConfig | None = None, *,
                 backend: str | None = None, backend_options: dict | None = None,
                 device_factory=None, device_name: str | None = None,
                 device_index: int = 0, hostname: str = "node0",
                 trace=None, engine: str = "serial"):
        if device is None and backend is None:
            backend = "simulated"
        if engine not in ("serial", "batched"):
            raise ValueError(
                f"unknown engine {engine!r}: expected 'serial' or 'batched'")
        if engine == "batched" and trace is not None:
            raise ValueError(
                "trace= records ONE device's interaction stream; the "
                "batched engine interleaves every pair's device in one "
                "lock-stepped program, so the combination is unrecordable "
                "— use engine='serial' when tracing (see the class "
                "docstring's combination matrix)")
        if engine == "batched" and backend is None:
            raise ValueError(
                "engine='batched' measures each pair on a freshly built "
                "pair-seeded device, so it needs a registry backend "
                "(backend=...), not a bare device instance")
        self.engine = engine
        self.cfg = cfg if cfg is not None else SessionConfig()
        self._backend = backend
        self._backend_options = dict(backend_options or {})
        if device is None:
            from repro.backends import create_backend
            device = create_backend(backend, **self._backend_options)
        self._trace = trace
        if trace is not None:
            from repro.trace.recorder import TracedBackend
            device = TracedBackend(device, trace)
        self._devices = [device]
        self._device_factory = device_factory
        if self._device_factory is None and backend is not None:
            def _factory(worker: int):
                from repro.backends import create_backend
                opts = dict(self._backend_options)
                # same modeled unit, independent measurement noise
                opts["seed"] = int(opts.get("seed", 0)) + worker
                return create_backend(backend, **opts)
            self._device_factory = _factory
        if frequencies is None:
            frequencies = list(device.frequencies)
        self.frequencies = [float(f) for f in frequencies]
        self.device_name = (device_name if device_name is not None
                            else self._backend_options.get("kind", backend)
                            or "sim")
        self.device_index = device_index
        self.hostname = hostname
        self.cal: Calibration | None = None
        self.spec: WorkloadSpec | None = None
        self._cal_loaded = False
        # ground truth from pair-scoped devices (their histories never
        # attach to self._devices); merged with device histories by
        # ground_truth()
        self._pair_ground_truth: dict[tuple[float, float], float] = {}
        if trace is not None:
            # everything a replay needs to rebuild this session offline
            trace.update_meta(sweep={
                "frequencies": self.frequencies,
                "latest": dataclasses.asdict(self.cfg.latest),
                "device_name": self.device_name,
                "device_index": self.device_index,
                "hostname": self.hostname,
                "backend": self._backend,
            })

    @property
    def device(self):
        """The primary device (worker 0)."""
        return self._devices[0]

    @property
    def devices(self) -> list:
        """All devices the session has instantiated (one per worker)."""
        return list(self._devices)

    # ------------------------------------------------------------------ #
    # phase 1: calibration + workload sizing (persisted, reloadable)
    # ------------------------------------------------------------------ #
    def _sizing_spec(self) -> WorkloadSpec:
        lc = self.cfg.latest
        return WorkloadSpec(
            iters_per_kernel=lc.delay_iters + lc.confirm_iters + 512,
            flops_per_iter=lc.base_iter_s, delay_iters=lc.delay_iters,
            confirm_iters=lc.confirm_iters)

    def calibrate(self, force: bool = False) -> Calibration:
        if self.cal is not None and self.spec is not None and not force:
            return self.cal
        if not force and self._load_calibration():
            self._cal_loaded = True
            return self.cal
        lc = self.cfg.latest
        spec0 = self._sizing_spec()
        with obs.span("session.calibrate", "cal", device=self.device_name,
                      n_freqs=len(self.frequencies)):
            self.cal = calibrate(self.device, self.frequencies, spec0)
            worst = probe_latency(self.device, self.frequencies, spec0,
                                  self.cal, lc.measure)
        self.spec = size_workload(probe_latency_s=worst,
                                  iter_time_s=lc.base_iter_s,
                                  delay_iters=lc.delay_iters,
                                  confirm_iters=lc.confirm_iters)
        self._save_calibration()
        return self.cal

    # ------------------------------------------------------------------ #
    # phase 2/3: scheduled pair measurements
    # ------------------------------------------------------------------ #
    def valid_pairs(self) -> list[tuple[float, float]]:
        self.calibrate()
        return valid_pairs(self.cal)

    def pair_scoped(self) -> bool:
        """True when pairs are measured on per-pair deterministic devices
        (virtual registry backend, no trace recorder attached) — the mode
        that makes parallel and resumed sweeps bit-identical to serial."""
        if self._backend is None or self._trace is not None:
            return False
        from repro.backends import get_backend
        try:
            return get_backend(self._backend).virtual
        except KeyError:
            return False

    def run(self, pair_subset=None, verbose: bool = False) -> LatencyTable:
        """Measure (or resume) every valid pair; see ``_run``.  The span
        wrapper makes each session one ``exec``-category profiler span, so
        stragglers show up as self-time on the unit that ran long."""
        with obs.span("session.run", "exec", device=self.device_name,
                      engine=self.engine):
            return self._run(pair_subset, verbose)

    def _run(self, pair_subset=None, verbose: bool = False) -> LatencyTable:
        self.calibrate()
        pairs = valid_pairs(self.cal)
        if pair_subset is not None:
            pairs = [p for p in pairs if p in set(pair_subset)]
        # failed persisted pairs (power_throttled / undetectable) are NOT
        # treated as done: a resume retries them — the failure may have
        # been transient
        done = {p: pm for p, pm in self._load_pairs().items()
                if pm.status == "ok"}
        todo = [p for p in pairs if p not in done]
        if verbose and done:
            print(f"  resume: {len(done)} pair(s) loaded from "
                  f"{self.cfg.out_dir}, {len(todo)} to measure")
        executor = get_executor(self.cfg.executor, self.cfg.max_workers)
        pair_scoped = self.pair_scoped()
        if self.engine == "batched":
            if not pair_scoped:
                raise ValueError(
                    "engine='batched' needs a virtual registry backend "
                    "(e.g. 'simulated', 'vmapped-sim'); this session's "
                    "device cannot be rebuilt per pair")
            from repro.backends import get_backend
            if not get_backend(self._backend).batchable:
                raise ValueError(
                    f"backend {self._backend!r} does not expose the split "
                    "wait protocol the batched engine fuses over; use "
                    "engine='serial' (registry backends opt in with "
                    "batchable=True)")
            if self.cfg.executor != "serial":
                raise ValueError(
                    "engine='batched' is one fused lock-stepped program; "
                    f"executor={self.cfg.executor!r} has nothing to "
                    "schedule — drop the executor or use engine='serial'")
        if pair_scoped:
            # every pair measured on a freshly built, pair-seeded device;
            # the task is plain data, so any executor (including process
            # pools) can schedule it
            task = PairTask.make(self._backend, self._backend_options,
                                 self.cal, self.spec,
                                 self.cfg.latest.measure,
                                 obs_ctx=obs.ctx())
            fn = functools.partial(run_pair_task, task)
        else:
            if getattr(executor, "requires_picklable_fn", False):
                raise ValueError(
                    "process-parallel sweeps need a virtual registry "
                    "backend (e.g. 'simulated', 'vmapped-sim'): explicit "
                    "device instances and traced runs cannot cross process "
                    "boundaries — use backend=... or a serial/thread "
                    "executor")
            self._ensure_workers(executor.n_workers)
            session_ctx = obs.ctx()  # thread-pool workers lose the
            # ambient parent stack, so pair spans carry it explicitly

            def fn(pair, worker):
                with obs.span("pair", "pair",
                              parent=session_ctx or obs.AMBIENT,
                              f_init=pair[0], f_target=pair[1],
                              worker=worker):
                    pm = measure_pair(self._devices[worker], pair[0],
                                      pair[1], self.cal, self.spec,
                                      self.cfg.latest.measure)
                return pm, {}

        analysed: dict[tuple[float, float], object] = {}
        measured: dict[tuple[float, float], PairMeasurement] = {}

        def on_result(pair, result):
            # runs in the scheduling process as each pair completes: the
            # persistence (crash-resume) hook never crosses processes
            pm, gt = result
            measured[pair] = pm
            for k, v in gt.items():
                self._pair_ground_truth[k] = max(
                    self._pair_ground_truth.get(k, 0.0), v)
            self._save_pair(pm, gt)
            if verbose:
                pr = analyse_pair(pm.f_init, pm.f_target, pm.latencies,
                                  pm.status)
                analysed[pair] = pr
                print(f"  {format_freq(pm.f_init)}->"
                      f"{format_freq(pm.f_target)} MHz: "
                      f"n={pm.latencies.size} "
                      f"status={pm.status} worst={pr.worst_case*1e3:.2f}ms "
                      f"best={pr.best_case*1e3:.2f}ms "
                      f"clusters={pr.n_clusters}")

        if self.engine == "batched":
            # pair_scoped is guaranteed above, so `task` exists: the
            # batched engine consumes the same picklable spec the
            # executors do, with the same completion callback
            from repro.core.batched_sweep import run_batched_sweep
            with obs.span("engine.batched", "exec", pairs=len(todo)):
                run_batched_sweep(task, todo, on_result=on_result)
        else:
            map_pairs_with_callback(executor, fn, todo, on_result)
        table = LatencyTable(self.device_name, self.device_index,
                             self.hostname)
        for p in pairs:
            pm = done.get(p) or measured[p]
            pr = analysed.get(p)
            if pr is None:
                pr = analyse_pair(pm.f_init, pm.f_target, pm.latencies,
                                  pm.status)
            table.add(pr)
        if self._trace is not None:
            # The replay-determinism contract: a replayed sweep must land on
            # this exact digest (repro.trace.analyze / `trace replay`).  A
            # resumed run is NOT replayable from this trace alone — loaded
            # pairs / reloaded calibration were measured by an earlier
            # process the recorder never saw — so the digest is only
            # stamped when the trace covers the whole run.
            complete = not done and not self._cal_loaded
            self._trace.update_meta(trace_complete=complete)
            if complete:
                from repro.trace.analyze import table_digest
                self._trace.update_meta(live_table_digest=table_digest(table))
        return table

    def ground_truth(self) -> dict[tuple[float, float], float]:
        """Max true transition latency per (from, to) pair across every
        device this session touched: the primary (calibration) device, any
        per-worker devices, and the pair-scoped measurement devices whose
        histories were harvested as their results arrived.  Empty entries
        only for backends without an event log (real hardware)."""
        gt = dict(self._pair_ground_truth)
        for dev in self._devices:
            for k, v in extract_ground_truth(dev).items():
                gt[k] = max(gt.get(k, 0.0), v)
        return gt

    def _ensure_workers(self, n: int) -> None:
        if n <= len(self._devices):
            return
        if self._trace is not None:
            raise ValueError(
                "tracing records one device's interaction stream; "
                "thread-parallel sweeps would interleave it — use the "
                "serial executor when trace= is set")
        if self._device_factory is None:
            raise ValueError(
                "thread-parallel sweeps need independent devices: construct "
                "the session with backend=... (registry factory) or pass "
                "device_factory=")
        while len(self._devices) < n:
            self._devices.append(self._device_factory(len(self._devices)))

    # ------------------------------------------------------------------ #
    # persistence
    # ------------------------------------------------------------------ #
    def _config_fingerprint(self) -> dict:
        """Settings persisted pair results depend on; resuming under a
        different fingerprint would silently mix measurement regimes.
        Covers the measurement config AND the device identity (backend +
        options minus the measurement-noise seed, which is freely
        resumable across runs)."""
        lc = self.cfg.latest
        fp = {"measure": dataclasses.asdict(lc.measure),
              "base_iter_s": lc.base_iter_s,
              "delay_iters": lc.delay_iters,
              "confirm_iters": lc.confirm_iters,
              "device_name": self.device_name,
              "backend": self._backend,
              "backend_options": {k: v for k, v in
                                  sorted(self._backend_options.items())
                                  if k != "seed"}}
        # normalize through JSON so the comparison against a reloaded
        # session.json is type-stable (tuples become lists, etc.)
        return json.loads(json.dumps(fp, default=str))

    def _pair_path(self, f_init: float, f_target: float) -> str:
        return os.path.join(self.cfg.out_dir, _PAIR_DIR,
                            f"{f_init:g}_{f_target:g}.json")

    def _save_pair(self, pm: PairMeasurement,
                   ground_truth: dict | None = None) -> None:
        if self.cfg.out_dir is None:
            return
        path = self._pair_path(pm.f_init, pm.f_target)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        doc = pm.to_dict()
        if ground_truth:
            # the simulator's oracle for this pair rides WITH the pair: a
            # session that resumes these measurements (crash-requeue, a
            # speculative duplicate) recovers the truths it never measured
            # itself, so downstream gt consumers see no holes
            doc["ground_truth"] = [[fi, ft, float(v)] for (fi, ft), v in
                                   sorted(ground_truth.items())]
        with atomic_replace(path) as tmp:
            with open(tmp, "w") as f:
                json.dump(doc, f)

    def _load_pairs(self) -> dict[tuple[float, float], PairMeasurement]:
        out: dict[tuple[float, float], PairMeasurement] = {}
        if self.cfg.out_dir is None:
            return out
        pair_dir = os.path.join(self.cfg.out_dir, _PAIR_DIR)
        if not os.path.isdir(pair_dir):
            return out
        for name in sorted(os.listdir(pair_dir)):
            if not name.endswith(".json"):
                continue
            with open(os.path.join(pair_dir, name)) as f:
                doc = json.load(f)
            pm = PairMeasurement.from_dict(doc)
            # harvest the persisted oracle: this session never ran these
            # transitions, but ground_truth() must still cover them
            for fi, ft, v in doc.get("ground_truth", []):
                k = (float(fi), float(ft))
                self._pair_ground_truth[k] = max(
                    self._pair_ground_truth.get(k, 0.0), float(v))
            out[(pm.f_init, pm.f_target)] = pm
        return out

    def _save_calibration(self) -> None:
        if self.cfg.out_dir is None:
            return
        os.makedirs(self.cfg.out_dir, exist_ok=True)
        doc = {
            "device_name": self.device_name,
            "device_index": self.device_index,
            "hostname": self.hostname,
            "frequencies": self.frequencies,
            "config": self._config_fingerprint(),
            "wakeup_estimate_s": self.cal.wakeup_estimate_s,
            "baselines": [dataclasses.asdict(st)
                          for st in self.cal.baselines.values()],
            "spec": dataclasses.asdict(self.spec),
        }
        with atomic_replace(os.path.join(self.cfg.out_dir,
                                         _SESSION_FILE)) as tmp:
            with open(tmp, "w") as f:
                json.dump(doc, f, indent=1)

    def _load_calibration(self) -> bool:
        if self.cfg.out_dir is None:
            return False
        path = os.path.join(self.cfg.out_dir, _SESSION_FILE)
        if not os.path.exists(path):
            return False
        with open(path) as f:
            doc = json.load(f)
        if [float(v) for v in doc["frequencies"]] != self.frequencies:
            raise ValueError(
                f"session dir {self.cfg.out_dir} was recorded for "
                f"frequencies {doc['frequencies']}, not {self.frequencies}; "
                "use a fresh out_dir")
        if doc.get("config") != self._config_fingerprint():
            raise ValueError(
                f"session dir {self.cfg.out_dir} was recorded with "
                f"measurement config {doc.get('config')}, which differs "
                f"from the current {self._config_fingerprint()}; resuming "
                "would silently mix settings — use a fresh out_dir")
        baselines = {float(b["freq_mhz"]): FreqStats(**b)
                     for b in doc["baselines"]}
        # iteration samples are not persisted (only the fitted baselines
        # feed detection); an empty dict keeps the dataclass shape
        self.cal = Calibration(
            baselines=baselines,
            iter_samples={f: np.empty(0) for f in baselines},
            wakeup_estimate_s=float(doc["wakeup_estimate_s"]))
        self.spec = WorkloadSpec(**doc["spec"])
        return True
