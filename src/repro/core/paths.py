"""One switchboard for every default output location.

Examples, benchmarks and the campaign store all used to hard-code
``results/...`` relative to the current directory, so test runs and verify
drives littered the working tree with untracked state dirs.  Everything now
routes through :func:`results_dir`, which honors ``REPRO_RESULTS_DIR`` —
point it at a scratch directory (CI does, tests use ``tmp_path``) and the
tree stays clean; leave it unset and you get the familiar ``results/``.
"""
from __future__ import annotations

import contextlib
import os

_ENV = "REPRO_RESULTS_DIR"


@contextlib.contextmanager
def atomic_replace(path: str):
    """Write-then-rename: yields a tmp path; on clean exit renames it onto
    ``path`` atomically.  The tmp name is pid-unique so concurrent writers
    (campaign worker processes, speculative duplicate units) never race on
    the rename source, and a mid-write kill leaves only tmp debris."""
    tmp = f"{path}.tmp-{os.getpid()}"
    yield tmp
    os.replace(tmp, path)


def results_root() -> str:
    """The base results directory (``$REPRO_RESULTS_DIR`` or ``results``).

    Read at call time, not import time, so tests can monkeypatch the
    environment without re-importing consumers.
    """
    return os.environ.get(_ENV, "results")


def results_dir(*parts: str, create: bool = False) -> str:
    """Join ``parts`` under the results root; ``create=True`` mkdir -p's it."""
    path = os.path.join(results_root(), *parts)
    if create:
        os.makedirs(path, exist_ok=True)
    return path


def campaigns_dir() -> str:
    """Default root of the campaign artifact store."""
    return results_dir("campaigns")
