"""Phase 1 (Alg. 1): warm-up + per-frequency baselines + pair validity.

For each candidate frequency the workload runs in several kernels; the
FIRST kernels warm the device (thermal stabilization + wake-up), the LAST
kernel's iterations provide the (mean, std) baseline.  Frequency pairs
whose difference confidence interval contains zero are excluded — their
execution times cannot be told apart, so the transition end would be
undetectable (paper §V-B.1).
"""
from __future__ import annotations

import dataclasses
import itertools

import numpy as np

from repro.core import stats
from repro.core.workload import WorkloadSpec


@dataclasses.dataclass
class Calibration:
    baselines: dict             # freq -> FreqStats
    iter_samples: dict          # freq -> np.ndarray of iteration times
    wakeup_estimate_s: float


def calibrate(device, frequencies, spec: WorkloadSpec) -> Calibration:
    baselines, samples = {}, {}
    wakeup = 0.0
    for f in frequencies:
        device.set_frequency(f)
        n_kernels = max(1, spec.wakeup_kernels)
        if hasattr(device, "run_kernel_batch"):
            # vmapped backends evaluate the whole warm-up burst in one
            # vectorized pass; only the first and last kernels matter here
            batch = device.run_kernel_batch(
                n_kernels, spec.iters_per_kernel, spec.flops_per_iter)
            first_kernel, data = batch[0], batch[-1]
        else:
            first_kernel = None
            data = None
            for k in range(n_kernels):
                data = device.run_kernel(spec.iters_per_kernel,
                                         spec.flops_per_iter)
                if k == 0:
                    first_kernel = data
        iters = np.diff(data, axis=-1)[..., 0].ravel()  # (cores*iters,)
        # driver-spike guard: a handful of huge iterations (CUDA driver
        # management, host interference — paper §V-C) would inflate sigma
        # and collapse the 2-sigma detection band onto overlapping pairs;
        # trim the top 0.5% before fitting the baseline.
        cut = np.quantile(iters, 0.995)
        trimmed = iters[iters <= cut]
        st = stats.mean_std(trimmed, freq_mhz=f)
        baselines[f] = st
        samples[f] = trimmed
        # wake-up estimate (paper §V): first kernel's early iterations vs the
        # last kernel's average — time until they match
        fi = np.diff(first_kernel, axis=-1)[..., 0].mean(axis=0)
        stable = np.abs(fi - st.mean) <= 2 * st.std
        if not stable.all():
            first_stable = int(np.argmax(stable)) if stable.any() else len(fi)
            wakeup = max(wakeup, float(fi[:first_stable].sum()))
    return Calibration(baselines=baselines, iter_samples=samples,
                       wakeup_estimate_s=wakeup)


def valid_pairs(cal: Calibration, *, z: float = 1.96,
                use_population_band: bool = True) -> list[tuple[float, float]]:
    """Pairs whose baselines are statistically distinguishable (Alg. 1 lines
    8-11).  With use_population_band the 2-sigma bands must not fully
    overlap either — the accelerator-grade criterion (SE ~ 0 at n ~ 1e6
    makes the plain CI test accept pairs whose iteration populations are
    inseparable)."""
    out = []
    freqs = sorted(cal.baselines)
    for a, b in itertools.permutations(freqs, 2):
        sa, sb = cal.baselines[a], cal.baselines[b]
        if not stats.ci_excludes_zero(sa, sb, z):
            continue
        if use_population_band:
            lo_a, hi_a = stats.two_sigma_band(sa)
            lo_b, hi_b = stats.two_sigma_band(sb)
            if not (hi_a < lo_b or hi_b < lo_a):
                continue                   # bands overlap: detection unsafe
        out.append((a, b))
    return out
