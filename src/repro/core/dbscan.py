"""DBSCAN outlier detection with the paper's adaptive parameter selection
(Alg. 3, §V-C).

DBSCAN from scratch (no sklearn): core points have >= minPts neighbors
within eps; clusters grow from core points; everything else is noise.
Adaptive selection sweeps minPts from ceil(4% n) down to floor(2% n) in
steps of 2, eps = m * quantile_range(0.05, 0.95) (paper: m = 0.15 from the
k-NN-distance analysis), halting once the noise ratio drops below 10%.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

NOISE = -1


def dbscan(x: np.ndarray, eps: float, min_pts: int) -> np.ndarray:
    """Labels for 1-D (or (n,d)) data: cluster ids 0.. or NOISE (-1)."""
    x = np.asarray(x, dtype=np.float64)
    if x.ndim == 1:
        x = x[:, None]
    n = len(x)
    if n == 0:
        return np.empty(0, dtype=int)
    d = np.sqrt(((x[:, None, :] - x[None, :, :]) ** 2).sum(-1))
    neighbors = [np.nonzero(d[i] <= eps)[0] for i in range(n)]
    core = np.array([len(nb) >= min_pts for nb in neighbors])
    labels = np.full(n, NOISE, dtype=int)
    cid = 0
    for i in range(n):
        if labels[i] != NOISE or not core[i]:
            continue
        # expand a new cluster from core point i (BFS)
        labels[i] = cid
        stack = list(neighbors[i])
        while stack:
            j = stack.pop()
            if labels[j] == NOISE:
                labels[j] = cid
                if core[j]:
                    stack.extend(neighbors[j])
        cid += 1
    return labels


def knn_distance(x: np.ndarray, k: int) -> np.ndarray:
    """Distance to the k-th nearest neighbor (the eps-selection heuristic
    the paper refines into the quantile-range multiplier)."""
    x = np.asarray(x, dtype=np.float64)
    if x.ndim == 1:
        x = x[:, None]
    d = np.sqrt(((x[:, None, :] - x[None, :, :]) ** 2).sum(-1))
    d.sort(axis=1)
    k = min(k, d.shape[1] - 1)
    return d[:, k]


@dataclasses.dataclass
class DBSCANResult:
    labels: np.ndarray
    eps: float
    min_pts: int
    noise_ratio: float
    n_clusters: int
    converged: bool          # noise ratio < 10% reached within the sweep


def adaptive_dbscan(latencies: np.ndarray, *, mult: float = 0.15,
                    start_frac: float = 0.04, end_frac: float = 0.02,
                    step: int = 2, max_noise: float = 0.10) -> DBSCANResult:
    """Alg. 3: sweep minPts from ceil(4% n) down to floor(2% n) (step -2)
    with eps = mult * quantile_range(0.05, 0.95); stop when noise < 10%."""
    x = np.asarray(latencies, dtype=np.float64).ravel()
    n = len(x)
    q05, q95 = np.quantile(x, [0.05, 0.95])
    eps = max(mult * (q95 - q05), 1e-12)
    start = max(2, math.ceil(start_frac * n))
    end = max(2, math.floor(end_frac * n))
    best = None
    i = start
    while i >= end:
        labels = dbscan(x, eps, i)
        noise = float((labels == NOISE).mean())
        ncl = int(labels.max() + 1) if (labels >= 0).any() else 0
        best = DBSCANResult(labels, eps, i, noise, ncl, noise <= max_noise)
        if noise <= max_noise:
            return best
        i -= step
    return best


def split_clusters(latencies: np.ndarray, result: DBSCANResult):
    """(clean_values, outlier_values, list-of-cluster-arrays)."""
    x = np.asarray(latencies, dtype=np.float64).ravel()
    clean = x[result.labels != NOISE]
    outliers = x[result.labels == NOISE]
    clusters = [x[result.labels == c] for c in range(result.n_clusters)]
    return clean, outliers, clusters
