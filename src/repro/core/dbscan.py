"""DBSCAN outlier detection with the paper's adaptive parameter selection
(Alg. 3, §V-C).

DBSCAN from scratch (no sklearn): core points have >= minPts neighbors
within eps; clusters grow from core points; everything else is noise.
Adaptive selection sweeps minPts from ceil(4% n) down to floor(2% n) in
steps of 2, eps = m * quantile_range(0.05, 0.95) (paper: m = 0.15 from the
k-NN-distance analysis), halting once the noise ratio drops below 10%.

Two implementations, selectable via ``impl=``:

``"sorted"`` (default)
    Latency samples are 1-D, so every eps-neighborhood is a contiguous
    window of the sorted array: neighbor counts come from two
    ``searchsorted`` calls, core points are windowed counts, and cluster
    expansion reduces to merging gap-connected runs of core points —
    O(n log n) time, O(n) memory.  Labels are bit-identical to the matrix
    path: window boundaries are fixed up against the reference distance
    predicate, clusters are numbered by the smallest original index of
    each core component (the matrix BFS's discovery order), and border
    points reachable from two clusters go to the lower-numbered one (the
    cluster that expands first in the reference).

``"matrix"``
    The original O(n²) full-pairwise-distance formulation, kept as the
    executable reference (and the only path for d > 1 inputs).
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

NOISE = -1


def _ref_dist(a, b):
    """The matrix reference's exact distance arithmetic for 1-D points:
    sqrt((a-b)^2).  Window fix-ups must use THIS predicate, not |a-b|,
    so the sorted path agrees with the reference bit-for-bit."""
    return np.sqrt((a - b) ** 2)


def _sorted_windows(sx: np.ndarray, eps: float) -> tuple[np.ndarray, np.ndarray]:
    """Per sorted position i, the eps-neighborhood [lo[i], hi[i]) as
    indices into the sorted array ``sx``.

    ``searchsorted(sx, sx ± eps)`` evaluates the rounded bound
    ``fl(x ± eps)`` while the reference compares ``fl(|x - y|) <= eps``;
    the two can disagree for pairs within an ulp of the eps boundary, so
    the rare boundary indices are nudged until they satisfy the reference
    predicate exactly."""
    n = sx.size
    lo = np.searchsorted(sx, sx - eps, side="left")
    hi = np.searchsorted(sx, sx + eps, side="right")
    if n == 0:
        return lo, hi
    # Left boundary: extend while the element just outside is in range...
    cand = np.flatnonzero(lo > 0)
    cand = cand[_ref_dist(sx[cand], sx[lo[cand] - 1]) <= eps]
    for i in cand:
        j = lo[i] - 1
        while j >= 0 and _ref_dist(sx[i], sx[j]) <= eps:
            j -= 1
        lo[i] = j + 1
    # ...and shrink while the first element inside is out of range.
    # (lo[i] <= i always, since fl(x - eps) <= x for eps >= 0, so sx[lo[i]]
    # is a valid index and the walk terminates at j == i at the latest.)
    cand = np.flatnonzero(_ref_dist(sx, sx[lo]) > eps)
    for i in cand:
        j = lo[i]
        while _ref_dist(sx[i], sx[j]) > eps:
            j += 1
        lo[i] = j
    # Right boundary, symmetric (hi[i] >= i + 1 always).
    cand = np.flatnonzero(hi < n)
    cand = cand[_ref_dist(sx[cand], sx[hi[cand]]) <= eps]
    for i in cand:
        j = hi[i]
        while j < n and _ref_dist(sx[i], sx[j]) <= eps:
            j += 1
        hi[i] = j
    cand = np.flatnonzero(_ref_dist(sx, sx[hi - 1]) > eps)
    for i in cand:
        j = hi[i] - 1
        while _ref_dist(sx[i], sx[j]) > eps:
            j -= 1
        hi[i] = j + 1
    return lo, hi


def _labels_from_windows(order: np.ndarray, lo: np.ndarray, hi: np.ndarray,
                         min_pts: int) -> np.ndarray:
    """Cluster labels (original order) from precomputed sorted windows.

    Core points whose sorted positions chain within eps form one cluster
    each; in sorted order a component breaks exactly where consecutive
    core points are more than eps apart — i.e. where the right core's
    window no longer reaches the left core, so no distance is ever
    re-evaluated here.  Re-thresholding ``min_pts`` against the same
    windows is how :func:`adaptive_dbscan` sweeps minPts in O(n) per step.
    """
    n = order.size
    labels_sorted = np.full(n, NOISE, dtype=int)
    core_pos = np.flatnonzero(hi - lo >= min_pts)
    if core_pos.size:
        # component breaks where the gap between consecutive cores > eps
        new_comp = lo[core_pos[1:]] > core_pos[:-1]
        comp = np.concatenate([[0], np.cumsum(new_comp)])
        comp_starts = np.flatnonzero(np.r_[True, new_comp])
        # reference cluster ids follow BFS discovery order: the component
        # holding the smallest not-yet-labeled original index goes first
        min_orig = np.minimum.reduceat(order[core_pos], comp_starts)
        cid_of_comp = np.empty(min_orig.size, dtype=int)
        cid_of_comp[np.argsort(min_orig, kind="mergesort")] = \
            np.arange(min_orig.size)
        labels_sorted[core_pos] = cid_of_comp[comp]
        # border points: non-core with >= 1 core in their window; the
        # reference's first-expanding (lowest-cid) cluster claims the point
        border = np.flatnonzero(hi - lo < min_pts)
        cl = np.searchsorted(core_pos, lo[border], side="left")
        cr = np.searchsorted(core_pos, hi[border], side="left")
        reach = cr > cl
        b = border[reach]
        comp_l = comp[cl[reach]]
        comp_r = comp[cr[reach] - 1]
        best = np.minimum(cid_of_comp[comp_l], cid_of_comp[comp_r])
        # a 2*eps window straddles > 2 components only when eps sits within
        # a few ulps of the data spacing; take the exact range-min then
        for t in np.flatnonzero(comp_r - comp_l > 1):
            best[t] = cid_of_comp[comp_l[t]:comp_r[t] + 1].min()
        labels_sorted[b] = best
    labels = np.empty(n, dtype=int)
    labels[order] = labels_sorted
    return labels


def _dbscan_matrix(x: np.ndarray, eps: float, min_pts: int) -> np.ndarray:
    """Reference O(n²) path (full distance matrix + BFS expansion)."""
    n = len(x)
    d = np.sqrt(((x[:, None, :] - x[None, :, :]) ** 2).sum(-1))
    neighbors = [np.nonzero(d[i] <= eps)[0] for i in range(n)]
    core = np.array([len(nb) >= min_pts for nb in neighbors])
    labels = np.full(n, NOISE, dtype=int)
    cid = 0
    for i in range(n):
        if labels[i] != NOISE or not core[i]:
            continue
        # expand a new cluster from core point i (BFS)
        labels[i] = cid
        stack = list(neighbors[i])
        while stack:
            j = stack.pop()
            if labels[j] == NOISE:
                labels[j] = cid
                if core[j]:
                    stack.extend(neighbors[j])
        cid += 1
    return labels


def dbscan(x: np.ndarray, eps: float, min_pts: int, *,
           impl: str = "sorted") -> np.ndarray:
    """Labels for 1-D (or (n,d)) data: cluster ids 0.. or NOISE (-1)."""
    if impl not in ("sorted", "matrix"):
        raise ValueError(f"unknown dbscan impl {impl!r}")
    x = np.asarray(x, dtype=np.float64)
    if x.ndim == 1:
        x = x[:, None]
    n = len(x)
    if n == 0:
        return np.empty(0, dtype=int)
    if impl == "sorted" and x.shape[1] == 1:
        flat = x[:, 0]
        order = np.argsort(flat, kind="mergesort")
        lo, hi = _sorted_windows(flat[order], eps)
        return _labels_from_windows(order, lo, hi, min_pts)
    return _dbscan_matrix(x, eps, min_pts)


def knn_distance(x: np.ndarray, k: int) -> np.ndarray:
    """Distance to the k-th nearest neighbor (the eps-selection heuristic
    the paper refines into the quantile-range multiplier)."""
    x = np.asarray(x, dtype=np.float64)
    if x.ndim == 1:
        x = x[:, None]
    d = np.sqrt(((x[:, None, :] - x[None, :, :]) ** 2).sum(-1))
    d.sort(axis=1)
    k = min(k, d.shape[1] - 1)
    return d[:, k]


@dataclasses.dataclass
class DBSCANResult:
    labels: np.ndarray
    eps: float
    min_pts: int
    noise_ratio: float
    n_clusters: int
    converged: bool          # noise ratio < 10% reached within the sweep


def adaptive_dbscan(latencies: np.ndarray, *, mult: float = 0.15,
                    start_frac: float = 0.04, end_frac: float = 0.02,
                    step: int = 2, max_noise: float = 0.10,
                    impl: str = "sorted") -> DBSCANResult:
    """Alg. 3: sweep minPts from ceil(4% n) down to floor(2% n) (step -2)
    with eps = mult * quantile_range(0.05, 0.95); stop when noise < 10%.

    On the sorted path the eps-windows (and hence every point's neighbor
    count) are computed ONCE and re-thresholded per minPts step, so the
    whole sweep costs one sort plus O(n) per step instead of one full
    clustering per step."""
    if impl not in ("sorted", "matrix"):
        raise ValueError(f"unknown dbscan impl {impl!r}")
    x = np.asarray(latencies, dtype=np.float64).ravel()
    n = len(x)
    q05, q95 = np.quantile(x, [0.05, 0.95])
    eps = max(mult * (q95 - q05), 1e-12)
    if impl == "sorted":
        order = np.argsort(x, kind="mergesort")
        lo, hi = _sorted_windows(x[order], eps)
        def labels_for(min_pts: int) -> np.ndarray:
            return _labels_from_windows(order, lo, hi, min_pts)
    else:
        def labels_for(min_pts: int) -> np.ndarray:
            return dbscan(x, eps, min_pts, impl="matrix")
    start = max(2, math.ceil(start_frac * n))
    end = max(2, math.floor(end_frac * n))
    best = None
    i = start
    while i >= end:
        labels = labels_for(i)
        noise = float((labels == NOISE).mean())
        ncl = int(labels.max() + 1) if (labels >= 0).any() else 0
        best = DBSCANResult(labels, eps, i, noise, ncl, noise <= max_noise)
        if noise <= max_noise:
            return best
        i -= step
    return best


def split_clusters(latencies: np.ndarray, result: DBSCANResult):
    """(clean_values, outlier_values, list-of-cluster-arrays)."""
    x = np.asarray(latencies, dtype=np.float64).ravel()
    clean = x[result.labels != NOISE]
    outliers = x[result.labels == NOISE]
    clusters = [x[result.labels == c] for c in range(result.n_clusters)]
    return clean, outliers, clusters
