"""Pluggable schedulers for frequency-pair measurements.

A sweep is an embarrassingly parallel bag of (f_init, f_target) tasks —
*provided each worker owns an independent device* (two threads interleaving
set_frequency on one accelerator would corrupt each other's transitions).
The session therefore hands every worker its own backend instance; the
executor only decides how tasks are scheduled:

  SerialExecutor   one device, in-order — the paper's single-GPU campaign
  ThreadExecutor   N worker threads, one independent device each — the
                   fleet-measurement shape (multiple boards, or several
                   simulated units evaluated concurrently)

Results always come back in task order regardless of completion order.
"""
from __future__ import annotations

import concurrent.futures
import itertools
import threading


class SerialExecutor:
    """In-order execution on the session's primary device."""

    n_workers = 1

    def map_pairs(self, fn, pairs):
        return [fn(p, 0) for p in pairs]


class ThreadExecutor:
    """Thread pool; ``fn(pair, worker_index)`` runs with a stable worker
    index so the session can pin one device per worker."""

    def __init__(self, max_workers: int = 4):
        self.n_workers = max(1, int(max_workers))

    def map_pairs(self, fn, pairs):
        pairs = list(pairs)
        if not pairs:
            return []
        local = threading.local()
        counter = itertools.count()     # one id per pool thread, thread-safe
                                        # enough under the GIL for next()

        def worker_index() -> int:
            if not hasattr(local, "idx"):
                local.idx = next(counter) % self.n_workers
            return local.idx

        with concurrent.futures.ThreadPoolExecutor(self.n_workers) as pool:
            return list(pool.map(lambda p: fn(p, worker_index()), pairs))


def get_executor(spec, max_workers: int = 4):
    """Resolve an executor from a name ("serial" | "threads") or pass an
    instance through unchanged."""
    if isinstance(spec, str):
        if spec == "serial":
            return SerialExecutor()
        if spec == "threads":
            return ThreadExecutor(max_workers=max_workers)
        raise ValueError(f"unknown executor {spec!r} "
                         "(expected 'serial' or 'threads')")
    missing = [a for a in ("map_pairs", "n_workers") if not hasattr(spec, a)]
    if missing:
        raise TypeError(f"executor {spec!r} lacks {', '.join(missing)}")
    return spec
