"""Pluggable schedulers for frequency-pair measurements.

A sweep is an embarrassingly parallel bag of (f_init, f_target) tasks —
*provided each worker owns an independent device* (two threads interleaving
set_frequency on one accelerator would corrupt each other's transitions).
The session therefore isolates devices per task (or per worker, for
explicit-device sessions); the executor only decides how tasks are
scheduled:

  SerialExecutor    in-order — the paper's single-GPU campaign
  ThreadExecutor    N worker threads; concurrency for workloads that
                    release the GIL (numpy hot paths) or block on I/O
  ProcessExecutor   N worker processes; true CPU parallelism.  The task
                    callable must be PICKLABLE (a module-level function or
                    functools.partial over one — never a closure), which is
                    why the session hands process pools a
                    :mod:`repro.core.pairtask` spec instead of a device.

Results always come back in task order regardless of completion order.
Executors additionally accept an ``on_result(task, result)`` callback,
invoked in the scheduling process as each task finishes — the session's
per-pair persistence hook, which therefore never crosses a process
boundary.
"""
from __future__ import annotations

import concurrent.futures
import inspect
import itertools
import multiprocessing
import threading


class SerialExecutor:
    """In-order execution in the calling thread."""

    n_workers = 1

    def map_pairs(self, fn, pairs, on_result=None):
        out = []
        for p in pairs:
            r = fn(p, 0)
            if on_result is not None:
                on_result(p, r)
            out.append(r)
        return out


class ThreadExecutor:
    """Thread pool; ``fn(pair, worker_index)`` runs with a stable worker
    index so sessions without a backend factory can pin one device per
    worker."""

    def __init__(self, max_workers: int = 4):
        self.n_workers = max(1, int(max_workers))

    def map_pairs(self, fn, pairs, on_result=None):
        pairs = list(pairs)
        if not pairs:
            return []
        local = threading.local()
        counter = itertools.count()     # one id per pool thread, thread-safe
                                        # enough under the GIL for next()

        def worker_index() -> int:
            if not hasattr(local, "idx"):
                local.idx = next(counter) % self.n_workers
            return local.idx

        results: list = [None] * len(pairs)
        with concurrent.futures.ThreadPoolExecutor(self.n_workers) as pool:
            futs = {pool.submit(lambda p: fn(p, worker_index()), p): i
                    for i, p in enumerate(pairs)}
            for fut in concurrent.futures.as_completed(futs):
                i = futs[fut]
                results[i] = fut.result()
                if on_result is not None:
                    # callback runs in the scheduling thread, so result
                    # consumers (persistence, verbose printing) need no lock
                    on_result(pairs[i], results[i])
        return results


# ------------------------------------------------------------------ #
# process pool
# ------------------------------------------------------------------ #
# Module-level state set by the pool initializer: each worker process gets
# a stable index from a shared counter (mirroring ThreadExecutor's
# per-thread ids) and the task callable — shipped ONCE per worker, so a
# task closure embedding real payload (e.g. a PairTask's calibration
# arrays) is not re-pickled for every submitted pair.
_WORKER_INDEX = 0
_WORKER_FN = None


def _init_process_worker(counter, fn) -> None:
    global _WORKER_INDEX, _WORKER_FN
    with counter.get_lock():
        _WORKER_INDEX = counter.value
        counter.value += 1
    _WORKER_FN = fn


def _call_in_worker(pair):
    return _WORKER_FN(pair, _WORKER_INDEX)


class ProcessExecutor:
    """Process pool for CPU-bound sweeps.

    ``fn`` is pickled per task, so it must be a module-level callable (or a
    ``functools.partial`` over one) with picklable arguments; sessions
    satisfy this with :func:`repro.core.pairtask.run_pair_task`, which
    rebuilds the backend *inside* the worker from its ``(backend, options)``
    spec — device objects never cross the process boundary.

    Uses the ``spawn`` start method by default: workers import only the
    numpy measurement stack (fast), and no parent-process locks or JAX
    runtime state are inherited mid-flight.
    """

    requires_picklable_fn = True

    def __init__(self, max_workers: int = 4, mp_context: str = "spawn"):
        self.n_workers = max(1, int(max_workers))
        self._mp_context = mp_context

    def map_pairs(self, fn, pairs, on_result=None):
        pairs = list(pairs)
        if not pairs:
            return []
        ctx = multiprocessing.get_context(self._mp_context)
        counter = ctx.Value("i", 0)
        results: list = [None] * len(pairs)
        with concurrent.futures.ProcessPoolExecutor(
                min(self.n_workers, len(pairs)), mp_context=ctx,
                initializer=_init_process_worker,
                initargs=(counter, fn)) as pool:
            futs = {pool.submit(_call_in_worker, p): i
                    for i, p in enumerate(pairs)}
            for fut in concurrent.futures.as_completed(futs):
                i = futs[fut]
                results[i] = fut.result()
                if on_result is not None:
                    on_result(pairs[i], results[i])
        return results


def map_pairs_with_callback(executor, fn, pairs, on_result):
    """Invoke ``executor.map_pairs`` with the per-result callback when the
    executor supports it, degrading gracefully for third-party executors
    that predate ``on_result`` (the callback then runs after the batch)."""
    try:
        accepts = "on_result" in inspect.signature(
            executor.map_pairs).parameters
    except (TypeError, ValueError):     # builtins / C callables
        accepts = False
    if accepts:
        return executor.map_pairs(fn, pairs, on_result=on_result)
    results = executor.map_pairs(fn, pairs)
    for p, r in zip(pairs, results):
        on_result(p, r)
    return results


EXECUTOR_NAMES = ("serial", "threads", "processes")


def get_executor(spec, max_workers: int = 4):
    """Resolve an executor from a name ("serial" | "threads" | "processes")
    or pass an instance through unchanged."""
    if isinstance(spec, str):
        if spec == "serial":
            return SerialExecutor()
        if spec == "threads":
            return ThreadExecutor(max_workers=max_workers)
        if spec == "processes":
            return ProcessExecutor(max_workers=max_workers)
        raise ValueError(f"unknown executor {spec!r} "
                         f"(expected one of {EXECUTOR_NAMES})")
    missing = [a for a in ("map_pairs", "n_workers") if not hasattr(spec, a)]
    if missing:
        raise TypeError(f"executor {spec!r} lacks {', '.join(missing)}")
    return spec
