"""LATEST-style top-level driver (paper §VI): benchmark the switching
latency of a device over a frequency list, with RSE stopping, throttle
handling and DBSCAN analysis, producing a LatencyTable (+ CSVs).

Since the session refactor this module is a thin veneer:
:class:`~repro.core.session.MeasurementSession` owns calibration state,
executor scheduling and resume-from-disk; ``run_latest`` keeps the
historical one-call signature on top of it.
"""
from __future__ import annotations

from repro.core.latency_table import LatencyTable
from repro.core.session import (LatestConfig, MeasurementSession,
                                SessionConfig, probe_latency)

__all__ = ["LatestConfig", "probe_latency", "run_latest"]


def run_latest(device=None, frequencies=None,
               cfg: LatestConfig | None = None,
               device_name: str = "sim", device_index: int = 0,
               hostname: str = "node0", pair_subset=None,
               verbose: bool = False, *, backend: str | None = None,
               backend_options: dict | None = None,
               out_dir: str | None = None, executor="serial",
               max_workers: int = 4) -> LatencyTable:
    """One-call sweep.  Pass a live ``device`` (any AcceleratorBackend) or
    a registry ``backend`` name; with ``out_dir`` the sweep persists pair
    results as it goes and a re-run resumes instead of restarting."""
    session = MeasurementSession(
        device, frequencies,
        SessionConfig(latest=cfg if cfg is not None else LatestConfig(),
                      executor=executor, max_workers=max_workers,
                      out_dir=out_dir),
        backend=backend, backend_options=backend_options,
        device_name=device_name, device_index=device_index,
        hostname=hostname)
    return session.run(pair_subset=pair_subset, verbose=verbose)
