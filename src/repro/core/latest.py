"""LATEST-style top-level driver (paper §VI): benchmark the switching
latency of a device over a frequency list, with RSE stopping, throttle
handling and DBSCAN analysis, producing a LatencyTable (+ CSVs).
"""
from __future__ import annotations

import dataclasses
import itertools

import numpy as np

from repro.core.calibration import calibrate, valid_pairs
from repro.core.evaluation import MeasureConfig, measure_pair
from repro.core.latency_table import LatencyTable, analyse_pair
from repro.core.workload import WorkloadSpec, size_workload


@dataclasses.dataclass(frozen=True)
class LatestConfig:
    base_iter_s: float = 40e-6          # iteration time at f_max
    delay_iters: int = 300
    confirm_iters: int = 400
    probe_pairs: int = 3                # low/mid/high probe for sizing
    measure: MeasureConfig = MeasureConfig()


def probe_latency(device, frequencies, spec, cal, mc) -> float:
    """Upper-bound probe over low/mid/high pairs (workload-sizing rule)."""
    fs = sorted(frequencies)
    probes = [(fs[0], fs[-1]), (fs[-1], fs[0]),
              (fs[len(fs) // 2], fs[-1])]
    worst = 1e-3
    for fi, ft in probes:
        if fi == ft:
            continue
        pm = measure_pair(device, fi, ft, cal, spec,
                          dataclasses.replace(mc, min_measurements=3,
                                              max_measurements=3))
        if pm.latencies.size:
            worst = max(worst, float(pm.latencies.max()))
    return worst


def run_latest(device, frequencies, cfg: LatestConfig = LatestConfig(),
               device_name: str = "sim", device_index: int = 0,
               hostname: str = "node0", pair_subset=None,
               verbose: bool = False) -> LatencyTable:
    # initial sizing guess; refined after the probe
    spec0 = WorkloadSpec(
        iters_per_kernel=cfg.delay_iters + cfg.confirm_iters + 512,
        flops_per_iter=cfg.base_iter_s, delay_iters=cfg.delay_iters,
        confirm_iters=cfg.confirm_iters)
    cal = calibrate(device, frequencies, spec0)
    pairs = valid_pairs(cal)
    if pair_subset is not None:
        pairs = [p for p in pairs if p in set(pair_subset)]

    worst_probe = probe_latency(device, frequencies, spec0, cal, cfg.measure)
    spec = size_workload(probe_latency_s=worst_probe,
                         iter_time_s=cfg.base_iter_s,
                         delay_iters=cfg.delay_iters,
                         confirm_iters=cfg.confirm_iters)

    table = LatencyTable(device_name, device_index, hostname)
    for fi, ft in pairs:
        pm = measure_pair(device, fi, ft, cal, spec, cfg.measure)
        pr = analyse_pair(fi, ft, pm.latencies, pm.status)
        table.add(pr)
        if verbose:
            print(f"  {fi:.0f}->{ft:.0f} MHz: n={pm.latencies.size} "
                  f"status={pm.status} worst={pr.worst_case*1e3:.2f}ms "
                  f"best={pr.best_case*1e3:.2f}ms clusters={pr.n_clusters}")
    return table
