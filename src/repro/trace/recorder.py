"""Streaming telemetry recording: :class:`TraceRecorder` accumulates
events, :class:`TracedBackend` wraps ANY :class:`AcceleratorBackend` and
transparently records every interaction, :class:`Trace` is the loaded
(or finished) columnar record.

Recording is append-to-python-lists plus one extra ``host_now()`` read per
event — bounded overhead by construction (``benchmarks/trace_overhead.py``
holds it under 5% of an untraced simulated sweep).  Nothing is written
until :meth:`TraceRecorder.save`, which emits the columnar npz + JSONL
header described in :mod:`repro.trace.schema`.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
from typing import Any

import numpy as np

from repro.trace import schema

_NAN4 = (math.nan, math.nan, math.nan, math.nan)


class Trace:
    """One finished telemetry record: columnar event arrays + metadata.

    ``kinds``/``t_host``/``cols`` have one row per event (``cols`` is
    ``(n_events, 4)``); ``payload`` is the concatenated ``(rows, 2)``
    device-timestamp store that WAIT/BATCH events reference by offset;
    ``extras`` maps event index -> string-valued annotation dict.
    """

    def __init__(self, meta: dict, kinds: np.ndarray, t_host: np.ndarray,
                 cols: np.ndarray, payload: np.ndarray,
                 extras: dict[int, dict]):
        self.meta = meta
        self.kinds = np.asarray(kinds, dtype=np.int16)
        self.t_host = np.asarray(t_host, dtype=np.float64)
        self.cols = np.asarray(cols, dtype=np.float64).reshape(-1, 4)
        self.payload = np.asarray(payload, dtype=np.float64).reshape(-1, 2)
        self.extras = extras

    @property
    def n_events(self) -> int:
        return int(self.kinds.size)

    def kind_name(self, i: int) -> str:
        return schema.KIND_NAMES.get(int(self.kinds[i]), f"?{self.kinds[i]}")

    def wait_payload(self, i: int) -> np.ndarray:
        """The (n_cores, n_iters, 2) timestamps of WAIT event ``i`` (a
        view into the shared payload store)."""
        if int(self.kinds[i]) != schema.WAIT:
            raise ValueError(f"event {i} is {self.kind_name(i)}, not wait")
        _, n_cores, n_iters, off = self.cols[i]
        n_cores, n_iters, off = int(n_cores), int(n_iters), int(off)
        return self.payload[off:off + n_cores * n_iters].reshape(
            n_cores, n_iters, 2)

    def batch_payload(self, i: int) -> np.ndarray:
        """The (n_kernels, n_cores, n_iters, 2) timestamps of BATCH event
        ``i`` (n_cores comes from the device metadata)."""
        if int(self.kinds[i]) != schema.BATCH:
            raise ValueError(f"event {i} is {self.kind_name(i)}, not batch")
        n_kernels, n_iters, _, off = self.cols[i]
        n_kernels, n_iters, off = int(n_kernels), int(n_iters), int(off)
        n_cores = int(self.meta["device"]["n_cores"])
        return self.payload[off:off + n_kernels * n_cores * n_iters].reshape(
            n_kernels, n_cores, n_iters, 2)

    # -------------------------------------------------------------- #
    # persistence
    # -------------------------------------------------------------- #
    def save(self, path: str) -> str:
        """Write the trace as a directory (``header.jsonl`` + ``events.npz``)
        with atomic per-file replace; returns ``path``."""
        os.makedirs(path, exist_ok=True)
        header = os.path.join(path, schema.HEADER_FILE)
        tmp = header + ".tmp"
        with open(tmp, "w") as f:
            f.write(json.dumps({"schema_version": schema.SCHEMA_VERSION,
                                "n_events": self.n_events,
                                "meta": self.meta}) + "\n")
            for i in sorted(self.extras):
                f.write(json.dumps({"i": i, **self.extras[i]}) + "\n")
        os.replace(tmp, header)
        events = os.path.join(path, schema.EVENTS_FILE)
        tmp = events + ".tmp"
        with open(tmp, "wb") as f:
            np.savez_compressed(f, kind=self.kinds, t_host=self.t_host,
                                cols=self.cols, payload=self.payload)
        os.replace(tmp, events)
        return path

    @classmethod
    def load(cls, path: str) -> "Trace":
        header = os.path.join(path, schema.HEADER_FILE)
        if not os.path.exists(header):
            raise FileNotFoundError(
                f"{path} is not a trace directory (no {schema.HEADER_FILE})")
        with open(header) as f:
            head = json.loads(f.readline())
            schema.check_schema_version(head.get("schema_version", -1), path)
            extras = {}
            for line in f:
                line = line.strip()
                if not line:
                    continue
                d = json.loads(line)
                extras[int(d.pop("i"))] = d
        with np.load(os.path.join(path, schema.EVENTS_FILE)) as z:
            trace = cls(head.get("meta", {}), z["kind"], z["t_host"],
                        z["cols"], z["payload"], extras)
        if trace.n_events != int(head.get("n_events", trace.n_events)):
            raise schema.TraceSchemaError(
                f"{path}: header says {head['n_events']} events, npz holds "
                f"{trace.n_events} — truncated or mismatched files")
        return trace


class _Arena:
    """Chunked append-only store.  Chunks are ``np.empty`` (never touched
    until the copy itself lands), so each retained byte costs exactly one
    cold write and the source array can be freed immediately — holding
    views of the device's output buffers instead would force the allocator
    onto fresh pages for every subsequent kernel evaluation (measured 2x
    slowdown of the whole simulator)."""

    __slots__ = ("dtype", "chunk", "_chunks", "_pos")

    def __init__(self, dtype, chunk_elems: int = 1 << 21):
        self.dtype = np.dtype(dtype)
        self.chunk = int(chunk_elems)
        self._chunks: list[np.ndarray] = []
        self._pos = 0

    def reserve(self, n: int) -> np.ndarray:
        """A writable 1-D view of ``n`` fresh elements."""
        if not self._chunks or self._pos + n > self._chunks[-1].size:
            self._chunks.append(np.empty(max(self.chunk, n), self.dtype))
            self._pos = 0
        view = self._chunks[-1][self._pos:self._pos + n]
        self._pos += n
        return view

    def unreserve(self, n: int) -> None:
        """Give back the most recent reservation (validation failed)."""
        self._pos -= n

    def prefault(self, n: int) -> None:
        """Pre-touch capacity for ``n`` more elements so the recording hot
        path writes into already-faulted pages (flight-recorder style: on
        boxes without transparent huge pages, first-touch page faults are
        the recorder's dominant cost)."""
        free = self._chunks[-1].size - self._pos if self._chunks else 0
        if n <= free:
            return
        chunk = np.empty(max(self.chunk, n - free), self.dtype)
        chunk.fill(0)                   # dirty every page now, not mid-sweep
        self._chunks.append(chunk)
        self._pos = 0


@dataclasses.dataclass
class _PayloadDesc:
    """One recorded timestamp array, in whichever in-memory encoding the
    hot path chose; decodes back to the original float64 bits.

    Modes (by field population):
      raw   float64 copy — anything that fails the structure checks
      b32   int32 boundary ticks relative to a scalar base (rel (c, i+1))
      b16   uint16 per-iteration duration ticks (rel (c, i)) + per-core
            int64 start ticks — the common case, 8x smaller than raw
    """
    rows: int                       # flat (rows, 2) rows when decoded
    shape: tuple                    # original array shape
    raw: np.ndarray | None = None   # float64 arena view ("raw" mode)
    rel: np.ndarray | None = None   # tick array ("b32" / "b16")
    base: int = 0                   # scalar base tick ("b32")
    bases: np.ndarray | None = None  # per-core start ticks ("b16")
    q: float = 0.0                  # timer resolution the ticks count

    def decode_into(self, out: np.ndarray) -> None:
        """Write the original (rows, 2) float64 data into ``out``."""
        if self.raw is not None:
            out[:] = self.raw.reshape(-1, 2)
            return
        if self.bases is not None:   # b16: boundary = start + running sum
            acc = np.cumsum(self.rel, axis=1, dtype=np.int64)
            acc += self.bases[:, None]
            bounds = np.concatenate([self.bases[:, None], acc], axis=1) \
                * self.q
        else:                        # b32: boundaries relative to one base
            bounds = (np.int64(self.base) + self.rel) * self.q
        # float64(tick) * q reproduces the device's own quantization
        # arithmetic bit for bit
        view = out.reshape(self.shape)
        view[..., 0] = bounds[:, :-1]
        view[..., 1] = bounds[:, 1:]


class TraceRecorder:
    """Append-only event sink shared by one or more :class:`TracedBackend`
    wrappers (and the annotation hooks: governor plans, online estimates).

    Timestamp payloads are retained compactly: device timestamps are timer
    ticks under the hood (``floor(t / q) * q``), and kernel iterations are
    gapless (iteration i's end IS iteration i+1's start), so one wait's
    (n_cores, n_iters, 2) float64 array collapses to (n_cores, n_iters+1)
    int32 boundary ticks — 4x fewer retained bytes, decoded back to the
    identical float64 bits at :meth:`finish`.  Arrays that don't fit the
    pattern (non-quantized device, gapped iterations) fall back to a raw
    float64 copy; either way the device's buffer is released immediately.
    """

    def __init__(self, meta: dict | None = None):
        self.meta: dict = dict(meta or {})
        self._kinds: list[int] = []
        self._t_host: list[float] = []
        self._cols: list[tuple] = []
        self._extras: dict[int, dict] = {}
        self._payloads: list[_PayloadDesc] = []
        self._payload_rows = 0
        self._f64 = _Arena(np.float64)
        self._i32 = _Arena(np.int32)
        self._u16 = _Arena(np.uint16)
        self._tick_buf: np.ndarray | None = None   # reused encode scratch
        self._dur_buf: np.ndarray | None = None    # reused duration scratch
        self._pending_sync: list[tuple] = []       # current sync round
        self._taps: list = []                      # live event subscribers

    # stream taps ---------------------------------------------------- #
    def add_tap(self, fn) -> None:
        """Subscribe ``fn(kind, t_host, cols, data, extra)`` to every event
        as it is recorded — the live-streaming hook the fleet monitor
        attaches to.  ``cols`` is the event's c0..c3 tuple; ``data`` is the
        timestamp payload for WAIT/BATCH events and the ``(n, 4)`` exchange
        array for SYNC_BATCH, else None.  Taps see exactly the event stream
        a saved trace would replay (sync rounds arrive folded, on flush).
        Taps must not mutate ``data``: it may be the device's live buffer."""
        self._taps.append(fn)

    def remove_tap(self, fn) -> None:
        self._taps.remove(fn)

    def _emit_tap(self, kind: int, t_host: float, c: tuple,
                  data=None, extra: dict | None = None) -> None:
        for fn in self._taps:
            fn(kind, t_host, c, data, extra)

    @property
    def n_events(self) -> int:
        return len(self._kinds) + bool(self._pending_sync)

    def update_meta(self, **kw) -> None:
        self.meta.update(kw)

    def prefault(self, *, wait_samples: int = 0, raw_samples: int = 0,
                 sync_exchanges: int = 0) -> None:
        """Pre-touch arena capacity (flight-recorder style) so recording
        never stalls on first-touch page faults mid-measurement:
        ``wait_samples`` = expected total core x iteration samples across
        all kernels, ``raw_samples`` = samples expected to fall back to the
        raw float64 path, ``sync_exchanges`` = total clock-sync exchanges.
        Purely optional — unreserved growth just faults lazily."""
        if wait_samples:
            self._u16.prefault(wait_samples)
        if raw_samples or sync_exchanges:
            self._f64.prefault(2 * raw_samples + 4 * sync_exchanges)

    def record(self, kind: int, t_host: float, c: tuple = _NAN4,
               extra: dict | None = None, tap_data=None) -> int:
        """Append one event; returns its index.  ``tap_data`` is forwarded
        to stream taps (payload carriers pass their timestamp array)."""
        if self._pending_sync:
            self._flush_sync()
        i = len(self._kinds)
        self._kinds.append(kind)
        self._t_host.append(t_host)
        self._cols.append(c)
        if extra:
            self._extras[i] = extra
        if self._taps:
            self._emit_tap(kind, t_host, c, tap_data, extra)
        return i

    # sync rounds -------------------------------------------------- #
    def record_sync(self, exchange: tuple) -> None:
        """Buffer one (t1, t2, t3, t4) exchange; consecutive exchanges
        become ONE sync-round event, flushed when any other event arrives.
        A sync round is 16+ back-to-back exchanges (best-of-n), so folding
        it keeps the per-exchange recording cost at a list append."""
        self._pending_sync.append(exchange)

    def _flush_sync(self) -> None:
        pend = self._pending_sync
        self._pending_sync = []
        arr = np.asarray(pend, dtype=np.float64)        # (n, 4)
        raw = self._f64.reserve(arr.size)
        np.copyto(raw.reshape(arr.shape), arr)
        desc = _PayloadDesc(rows=arr.size // 2, shape=arr.shape, raw=raw)
        off = self._payload_rows
        self._payloads.append(desc)
        self._payload_rows += desc.rows
        self._kinds.append(schema.SYNC_BATCH)
        self._t_host.append(float(pend[-1][3]))         # t4 of the last one
        self._cols.append((float(len(pend)), math.nan, math.nan, float(off)))
        if self._taps:
            self._emit_tap(schema.SYNC_BATCH, self._t_host[-1],
                           self._cols[-1], arr)

    def _encode_compact(self, data: np.ndarray) -> _PayloadDesc | None:
        """Compact tick encoding, or None when ``data`` doesn't prove (on a
        sampled row prefix, cheap) to be quantized and gapless.  The
        sampling is backed end to end by the replay-determinism digest: a
        device that quantizes row 0 but not row 5 would fail the
        bit-for-bit table check immediately.

        Preferred mode is b16 — per-iteration duration ticks in uint16
        (durations are exact integer differences, so per-core running sums
        rebuild every boundary exactly); kernels with >65535-tick
        iterations fall back to b32 boundary ticks."""
        q = self.meta.get("device", {}).get("timer_resolution_s") or 0.0
        if q <= 0.0 or data.ndim != 3 or data.shape[-1] != 2 \
                or data.shape[1] < 1:
            return None
        n_cores, n_iters = data.shape[:2]
        k = min(64, n_iters - 1)
        # sampled structure check: row 0 gapless (ends == next starts)
        if (data[0, 1:1 + k, 0] != data[0, :k, 1]).any():
            return None
        inv_q = 1.0 / q
        dbuf = self._dur_buf
        if dbuf is None or dbuf.shape != (n_cores, n_iters):
            dbuf = self._dur_buf = np.empty((n_cores, n_iters))
        np.subtract(data[..., 1], data[..., 0], out=dbuf)
        np.multiply(dbuf, inv_q, out=dbuf)        # duration ticks +- eps
        if not -0.5 < float(dbuf.max()) < 65535.0:
            return self._encode_b32(data, q, k)   # wide/degenerate kernel
        bases = np.rint(data[:, 0, 0] * inv_q).astype(np.int64)
        # +0.5 then truncate == rint for the non-negative tick counts
        np.add(dbuf, 0.5, out=dbuf)
        rel = self._u16.reserve(dbuf.size).reshape(dbuf.shape)
        np.copyto(rel, dbuf, casting="unsafe")         # the one cold write
        # telescoped validity: every core's last boundary rebuilt from the
        # running duration sum must equal its recorded last end tick — one
        # cheap pass that catches gapped rows, negative durations and
        # non-quantized data anywhere in the array, not just in row 0
        ends = (bases + rel.sum(axis=1, dtype=np.int64)) * q
        # sampled exactness: row 0's decoded prefix must give the input
        # bits (same float64(tick) * q arithmetic as decode_into)
        t0 = np.int64(bases[0])
        ends0 = (t0 + np.cumsum(rel[0, :k + 1], dtype=np.int64)) * q
        if (ends != data[:, -1, 1]).any() or float(t0 * q) != data[0, 0, 0] \
                or (ends0 != data[0, :k + 1, 1]).any():
            self._u16.unreserve(dbuf.size)
            return self._encode_b32(data, q, k)
        return _PayloadDesc(rows=n_cores * n_iters, shape=data.shape,
                            rel=rel, bases=bases, q=q)

    def _encode_b32(self, data: np.ndarray, q: float,
                    k: int) -> _PayloadDesc | None:
        """Boundary ticks relative to one scalar base, in int32 — the wide
        fallback when a single iteration exceeds 65535 ticks."""
        n_cores, n_iters = data.shape[:2]
        buf = self._tick_buf
        if buf is None or buf.shape != (n_cores, n_iters + 1):
            buf = self._tick_buf = np.empty((n_cores, n_iters + 1))
        inv_q = 1.0 / q
        np.multiply(data[..., 0], inv_q, out=buf[:, :-1])
        np.multiply(data[:, -1, 1], inv_q, out=buf[:, -1])
        # buf now holds tick values k +- eps.  base = the smallest tick
        # (boundaries are monotone per core, so column 0 has the minimum);
        # shifting by base - 0.5 makes every value (k - base) + 0.5 +- eps,
        # strictly positive, so the int32 cast *truncates* to exactly
        # k - base — the rint pass is folded into the cast.
        m = float(buf[:, 0].min())
        if m != m:                                 # NaN timestamps: raw copy
            return None
        base = int(m + 0.5)
        np.subtract(buf, base - 0.5, out=buf)
        if float(buf[:, -1].max()) >= 2 ** 31:     # only the last column
            return None                            # can overflow (monotone)
        rel = self._i32.reserve(buf.size).reshape(buf.shape)
        np.copyto(rel, buf, casting="unsafe")      # the one cold write
        # sampled exactness check: decoding row 0's prefix must reproduce
        # the input bits (same float64(k) * q arithmetic as decode_into)
        if (((np.int64(base) + rel[0, :k + 1]) * q)
                != data[0, :k + 1, 0]).any():
            self._i32.unreserve(buf.size)
            return None
        return _PayloadDesc(rows=n_cores * n_iters, shape=data.shape,
                            rel=rel, base=base, q=q)

    def record_payload(self, kind: int, t_host: float, data: np.ndarray,
                       c_prefix: tuple) -> int:
        """Append one event carrying a timestamp array: ``c_prefix`` fills
        c0..c2, c3 becomes the payload row offset."""
        if self._pending_sync:
            self._flush_sync()     # before claiming this event's row offset
        desc = self._encode_compact(data) if kind == schema.WAIT else None
        if desc is None:
            raw = self._f64.reserve(data.size)
            np.copyto(raw.reshape(data.shape), data)
            desc = _PayloadDesc(rows=data.size // 2, shape=data.shape,
                                raw=raw)
        off = self._payload_rows
        self._payloads.append(desc)
        self._payload_rows += desc.rows
        return self.record(kind, t_host, (*c_prefix, float(off)),
                           tap_data=data)

    # annotation hooks ---------------------------------------------- #
    def record_plan(self, t_host: float, f_from: float, f_to: float,
                    reason: str, region_kind: str, duration_s: float) -> int:
        return self.record(schema.PLAN, t_host,
                           (float(f_from), float(f_to), float(duration_s),
                            math.nan),
                           {"reason": reason, "region": region_kind})

    def record_estimate(self, t_host: float, latency_s: float, t_s: float,
                        core: int, final: bool) -> int:
        return self.record(schema.ESTIMATE, t_host,
                           (float(latency_s), float(t_s), float(core),
                            1.0 if final else 0.0))

    # -------------------------------------------------------------- #
    def finish(self) -> Trace:
        """Freeze the buffered events into an immutable :class:`Trace`
        (payloads decode back to their original float64 bits here, off the
        recording hot path)."""
        if self._pending_sync:
            self._flush_sync()
        payload = np.empty((self._payload_rows, 2))
        off = 0
        for desc in self._payloads:
            desc.decode_into(payload[off:off + desc.rows])
            off += desc.rows
        return Trace(dict(self.meta),
                     np.asarray(self._kinds, dtype=np.int16),
                     np.asarray(self._t_host, dtype=np.float64),
                     np.asarray(self._cols, dtype=np.float64).reshape(-1, 4),
                     payload, dict(self._extras))

    def save(self, path: str) -> Trace:
        trace = self.finish()
        trace.save(path)
        return trace


@dataclasses.dataclass
class _TracedHandle:
    inner: Any
    seq: int
    n_iters: int


def device_meta(device) -> dict:
    """Best-effort device identity for the trace header."""
    meta = {"class": type(device).__name__,
            "frequencies": [float(f) for f in device.frequencies]}
    cfg = getattr(device, "cfg", None)
    if cfg is not None:
        meta["n_cores"] = int(getattr(cfg, "n_cores", 0))
        meta["timer_resolution_s"] = float(
            getattr(cfg, "timer_resolution_s", 0.0))
    model = getattr(device, "model", None)
    if model is not None:
        meta["model"] = getattr(model, "name", type(model).__name__)
    return meta


class TracedBackend:
    """Transparent recording wrapper around any AcceleratorBackend.

    Every protocol call is delegated to the wrapped device and appended to
    the recorder; results (wait timestamps, sync tuples, throttle flags)
    are recorded verbatim so a :class:`repro.trace.replay.TraceReplayBackend`
    can re-serve them bit for bit.  Non-protocol attributes (``cfg``,
    ``history``, ``dev_now``...) delegate untouched; ``run_kernel_batch``
    is intercepted per-instance only when the wrapped device has it, so
    ``hasattr`` probes (e.g. the calibration fast path) see the same
    surface as the bare device.
    """

    def __init__(self, device, recorder: TraceRecorder):
        self._device = device
        self._recorder = recorder
        self._seq = 0
        recorder.meta.setdefault("device", device_meta(device))
        if hasattr(device, "run_kernel_batch"):
            recorder.meta["device"]["batch_capable"] = True
            self.run_kernel_batch = self._run_kernel_batch

    def __getattr__(self, name):
        # only reached when normal lookup fails: pass-through for the
        # wrapped device's extra surface (history, cfg, rng, ...)
        return getattr(self._device, name)

    @property
    def device(self):
        """The wrapped (inner) backend."""
        return self._device

    @property
    def recorder(self) -> TraceRecorder:
        return self._recorder

    # protocol ------------------------------------------------------ #
    @property
    def frequencies(self):
        return self._device.frequencies

    def host_now(self) -> float:
        v = self._device.host_now()
        self._recorder.record(schema.HOST_NOW, v,
                              (v, math.nan, math.nan, math.nan))
        return v

    def usleep(self, dt: float) -> None:
        self._device.usleep(dt)
        self._recorder.record(schema.USLEEP, self._device.host_now(),
                              (float(dt), math.nan, math.nan, math.nan))

    def set_frequency(self, mhz: float) -> None:
        self._device.set_frequency(mhz)
        self._recorder.record(schema.SET_FREQUENCY, self._device.host_now(),
                              (float(mhz), math.nan, math.nan, math.nan))

    def sync_exchange(self):
        t = self._device.sync_exchange()
        # buffered: the whole best-of-n round becomes one SYNC_BATCH event
        self._recorder.record_sync(t)
        return t

    def throttle_reasons(self) -> set:
        flags = self._device.throttle_reasons()
        self._recorder.record(schema.THROTTLE, self._device.host_now(),
                              extra={"flags": sorted(flags)})
        return flags

    def launch_kernel(self, n_iters: int, base_iter_s: float) -> _TracedHandle:
        h = self._device.launch_kernel(n_iters, base_iter_s)
        seq = self._seq
        self._seq += 1
        self._recorder.record(schema.LAUNCH, self._device.host_now(),
                              (float(n_iters), float(base_iter_s),
                               float(seq), math.nan))
        return _TracedHandle(h, seq, int(n_iters))

    def wait(self, h: _TracedHandle) -> np.ndarray:
        data = self._device.wait(h.inner)
        self._recorder.record_payload(
            schema.WAIT, self._device.host_now(), data,
            (float(h.seq), float(data.shape[0]), float(data.shape[1])))
        return data

    def run_kernel(self, n_iters: int, base_iter_s: float) -> np.ndarray:
        return self.wait(self.launch_kernel(n_iters, base_iter_s))

    def warm_kernel(self, n_iters: int, base_iter_s: float) -> None:
        """Run-for-effect kernel (warm-up): the caller declares it will
        never read the timestamps, so none are retained — the single
        biggest recording saving on the measurement hot path."""
        warm = getattr(self._device, "warm_kernel", None)
        if warm is not None:
            warm(n_iters, base_iter_s)
        else:
            self._device.run_kernel(n_iters, base_iter_s)
        self._recorder.record(schema.WARM_KERNEL, self._device.host_now(),
                              (float(n_iters), float(base_iter_s),
                               math.nan, math.nan))

    def _run_kernel_batch(self, n_kernels: int, n_iters: int,
                          base_iter_s: float) -> np.ndarray:
        data = self._device.run_kernel_batch(n_kernels, n_iters, base_iter_s)
        self._recorder.record_payload(
            schema.BATCH, self._device.host_now(), data,
            (float(n_kernels), float(n_iters), float(base_iter_s)))
        return data

    # annotation ---------------------------------------------------- #
    def record_plan(self, *, f_from: float, f_to: float, reason: str,
                    region_kind: str, duration_s: float) -> int:
        """Governor audit hook (called by :meth:`Governor.plan`).  Returns
        the recorded event's index — the audit id span profiles link to."""
        return self._recorder.record_plan(
            self._device.host_now(), f_from, f_to, reason, region_kind,
            duration_s)
