"""Trace schema: event kinds, columnar layout, and the version policy.

A trace is a compact columnar record of every backend interaction:

* ``events.npz`` — numeric columns, one row per event in wall order:
  ``kind`` (int16 code), ``t_host`` (float64, host clock after the call)
  and four generic float64 payload slots ``c0..c3`` (NaN when unused),
  plus one concatenated ``payload`` array holding every kernel's
  ``(start, end)`` device timestamps back to back (events reference it by
  row offset, so the big arrays are stored exactly once, contiguously);
* ``header.jsonl`` — line 1 is the header (``schema_version``, free-form
  ``meta`` with device metadata / sweep config / live-table digest);
  every following line annotates one event with the string-valued payload
  the numeric columns cannot carry (throttle flags, governor reasons).

Version policy: ``SCHEMA_VERSION`` is a single integer bumped on ANY
incompatible change to the column layout or event semantics.  Readers
refuse traces written under a different version instead of guessing —
a replayed measurement that silently mis-decodes would defeat the whole
point of bit-for-bit replay.
"""
from __future__ import annotations

SCHEMA_VERSION = 1

HEADER_FILE = "header.jsonl"
EVENTS_FILE = "events.npz"

# ---------------------------------------------------------------------- #
# event kinds.  Codes are part of the on-disk format: append only, never
# renumber (renumbering is a SCHEMA_VERSION bump).
# ---------------------------------------------------------------------- #
SET_FREQUENCY = 1      # c0 = mhz
LAUNCH = 2             # c0 = n_iters, c1 = base_iter_s, c2 = seq
WAIT = 3               # c0 = seq, c1 = n_cores, c2 = n_iters, c3 = payload row offset
SYNC_EXCHANGE = 4      # c0..c3 = t1, t2, t3, t4
HOST_NOW = 5           # c0 = returned host time
USLEEP = 6             # c0 = dt
THROTTLE = 7           # extra: {"flags": [...]}
BATCH = 8              # c0 = n_kernels, c1 = n_iters, c2 = base_iter_s,
                       # c3 = payload row offset
PLAN = 9               # c0 = f_from, c1 = f_to, c2 = region duration_s;
                       # extra: {"reason": ..., "region": ...}
ESTIMATE = 10          # c0 = latency_s, c1 = t_s, c2 = core, c3 = final(0/1)
WARM_KERNEL = 11       # c0 = n_iters, c1 = base_iter_s — run-for-effect
                       # kernel whose timestamps nobody reads; no payload
SYNC_BATCH = 12        # c0 = n_exchanges, c3 = payload row offset; one
                       # event per sync ROUND (consecutive exchanges),
                       # payload holds the (t1..t4) tuples back to back

KIND_NAMES = {
    SET_FREQUENCY: "set_frequency",
    LAUNCH: "launch",
    WAIT: "wait",
    SYNC_EXCHANGE: "sync_exchange",
    HOST_NOW: "host_now",
    USLEEP: "usleep",
    THROTTLE: "throttle",
    BATCH: "batch",
    PLAN: "plan",
    ESTIMATE: "estimate",
    WARM_KERNEL: "warm_kernel",
    SYNC_BATCH: "sync_batch",
}
KIND_CODES = {v: k for k, v in KIND_NAMES.items()}

# kinds that are part of the AcceleratorBackend protocol (replay must see
# them in call order); PLAN / ESTIMATE are annotations layered on top and
# are skipped by the replay cursor.
PROTOCOL_KINDS = frozenset({SET_FREQUENCY, LAUNCH, WAIT, SYNC_EXCHANGE,
                            HOST_NOW, USLEEP, THROTTLE, BATCH, WARM_KERNEL,
                            SYNC_BATCH})
ANNOTATION_KINDS = frozenset({PLAN, ESTIMATE})


class TraceSchemaError(ValueError):
    """Raised when a trace file cannot be decoded under this schema."""


def check_schema_version(version: int, path: str = "<trace>") -> None:
    if int(version) != SCHEMA_VERSION:
        raise TraceSchemaError(
            f"{path}: trace schema version {version} != supported "
            f"{SCHEMA_VERSION}; re-record the trace (or run a matching "
            "repro version) — the format is refused, never guessed")
