"""`python -m repro.trace` — record / replay / analyze / export telemetry.

    record   run a (simulated) sweep with recording on, save the trace
    replay   re-execute a trace offline; exit 1 if the replayed latency
             table is not bit-for-bit identical to the live run
    analyze  replay + reconstruct switch passes + online-vs-batch report
    export   dump the event stream as JSONL or CSV for external tools
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.cliutil import emit as _emit
from repro.trace import schema
from repro.trace.recorder import Trace, TraceRecorder


def cmd_record(args) -> int:
    from repro.core.evaluation import MeasureConfig
    from repro.core.session import (LatestConfig, MeasurementSession,
                                    SessionConfig)
    recorder = TraceRecorder()
    lc = LatestConfig(measure=MeasureConfig(
        min_measurements=args.min_measurements,
        max_measurements=args.max_measurements,
        rse_check_every=args.min_measurements))
    session = MeasurementSession(
        cfg=SessionConfig(latest=lc),
        backend=args.backend,
        backend_options={"kind": args.kind, "n_cores": args.n_cores,
                         "seed": args.seed},
        frequencies=args.frequencies or None,
        trace=recorder)
    table = session.run(verbose=not args.quiet)
    trace = recorder.save(args.out)
    summary = table.summary()
    print(f"recorded {trace.n_events} events "
          f"({summary.get('n_pairs', 0)} pairs) -> {args.out}")
    print(f"live table digest {trace.meta['live_table_digest'][:16]}…")
    return 0


def cmd_replay(args) -> int:
    from repro.trace.analyze import replay_session, table_digest
    trace = Trace.load(args.trace)
    session = replay_session(trace, strict=not args.lenient)
    table = session.run(verbose=not args.quiet)
    digest = table_digest(table)
    live = trace.meta.get("live_table_digest")
    leftover = session.device.remaining_events
    if leftover:
        print(f"WARNING: {leftover} recorded protocol event(s) were never "
              "replayed", file=sys.stderr)
    if live is None:
        print(f"replayed {len(table.pairs)} pairs; no live digest recorded, "
              f"replay digest {digest[:16]}…")
        return 0
    if digest == live:
        print(f"replay DETERMINISTIC: digest {digest[:16]}… matches the "
              "live run bit for bit")
        return 0
    print(f"replay DIVERGED: live {live[:16]}… != replayed {digest[:16]}…",
          file=sys.stderr)
    return 1


def cmd_analyze(args) -> int:
    from repro.trace.analyze import analyze_trace, report_markdown
    report = analyze_trace(Trace.load(args.trace))
    _emit(report_markdown(report), args.out)
    return 0 if report.ok else 1


def cmd_export(args) -> int:
    trace = Trace.load(args.trace)
    lines = []
    if args.format == "csv":
        lines.append("index,kind,t_host,c0,c1,c2,c3")
        for i in range(trace.n_events):
            c = ",".join(f"{v:.9g}" for v in trace.cols[i])
            lines.append(f"{i},{trace.kind_name(i)},{trace.t_host[i]:.9f},{c}")
    else:
        for i in range(trace.n_events):
            doc = {"i": i, "kind": trace.kind_name(i),
                   "t_host": float(trace.t_host[i]),
                   "c": [None if v != v else float(v)
                         for v in trace.cols[i]]}
            doc.update(trace.extras.get(i, {}))
            if int(trace.kinds[i]) == schema.WAIT:
                doc["payload_shape"] = list(trace.wait_payload(i).shape)
            lines.append(json.dumps(doc))
    _emit("\n".join(lines), args.out)
    return 0


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.trace",
        description="Streaming telemetry traces: record, replay, analyze")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("record", help="run a traced sweep, save the trace")
    p.add_argument("--out", required=True, help="trace output directory")
    p.add_argument("--backend", default="vmapped-sim")
    p.add_argument("--kind", default="a100")
    p.add_argument("--n-cores", type=int, default=4)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--frequencies", type=float, nargs="*", default=None,
                   help="MHz subset (default: all device frequencies)")
    p.add_argument("--min-measurements", type=int, default=3)
    p.add_argument("--max-measurements", type=int, default=6)
    p.add_argument("--quiet", action="store_true")
    p.set_defaults(fn=cmd_record)

    p = sub.add_parser("replay",
                       help="re-execute a trace; exit 1 unless bit-for-bit")
    p.add_argument("trace", help="trace directory")
    p.add_argument("--lenient", action="store_true",
                   help="serve recorded data without strict call checking")
    p.add_argument("--quiet", action="store_true")
    p.set_defaults(fn=cmd_replay)

    p = sub.add_parser("analyze",
                       help="replay + online-vs-batch estimator report")
    p.add_argument("trace", help="trace directory")
    p.add_argument("--out", default=None, help="write markdown to file")
    p.set_defaults(fn=cmd_analyze)

    p = sub.add_parser("export", help="dump the event stream")
    p.add_argument("trace", help="trace directory")
    p.add_argument("--format", choices=("jsonl", "csv"), default="jsonl")
    p.add_argument("--out", default=None, help="write to file")
    p.set_defaults(fn=cmd_export)
    return ap


def main(argv: list[str] | None = None) -> int:
    args = build_parser().parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main())
