"""`trace-replay` backend: re-execute a recorded timeline with no device.

The replay backend is a strict log-structured double: each protocol call
consumes the next recorded protocol event (annotations — governor plans,
online estimates — are skipped) and returns the recorded result verbatim.
Because the measurement pipeline is deterministic given the device's
responses, driving a :class:`MeasurementSession` with the same config
against the replay backend reproduces the live run bit for bit — phase-1
calibration, phase-2/3 detection, DBSCAN labels, the whole latency table
(``repro.trace.analyze.replay_table`` / ``tests/test_trace.py``).

In strict mode (default) any divergence — wrong call kind, different
frequency, different kernel shape — raises :class:`TraceReplayError`
with the event position, instead of silently serving mismatched data.
"""
from __future__ import annotations

import collections
import dataclasses
import math
from typing import Any

import numpy as np

from repro.backends.registry import register_backend
from repro.trace import schema
from repro.trace.recorder import Trace


class TraceReplayError(RuntimeError):
    """The caller's call sequence diverged from the recorded timeline."""


@dataclasses.dataclass
class _ReplayHandle:
    seq: int
    n_iters: int
    base_iter_s: float


def _close(a: float, b: float) -> bool:
    return a == b or math.isclose(a, b, rel_tol=1e-9, abs_tol=1e-15)


class TraceReplayBackend:
    """AcceleratorBackend serving a recorded :class:`Trace`."""

    def __init__(self, trace: Trace, strict: bool = True):
        self.trace = trace
        self.strict = strict
        # cursor over protocol events only (annotations interleave freely)
        self._protocol = np.flatnonzero(
            np.isin(trace.kinds, list(schema.PROTOCOL_KINDS)))
        self._pos = 0
        self._sync_queue: collections.deque = collections.deque()
        dev_meta = trace.meta.get("device", {})
        self._frequencies = tuple(float(f)
                                  for f in dev_meta.get("frequencies", ()))
        if dev_meta.get("batch_capable"):
            self.run_kernel_batch = self._run_kernel_batch

    # -------------------------------------------------------------- #
    @property
    def frequencies(self) -> tuple[float, ...]:
        return self._frequencies

    @property
    def remaining_events(self) -> int:
        """Protocol events not yet consumed (0 after a complete replay)."""
        return int(self._protocol.size - self._pos)

    def _next(self, kind: int, call: str) -> int:
        if self._pos >= self._protocol.size:
            raise TraceReplayError(
                f"replay exhausted: {call}() called after all "
                f"{self._protocol.size} recorded protocol events were "
                "consumed — the driving code ran longer than the recording")
        i = int(self._protocol[self._pos])
        got = int(self.trace.kinds[i])
        if got != kind:
            raise TraceReplayError(
                f"replay diverged at event {i}: caller issued {call}() but "
                f"the recording holds {self.trace.kind_name(i)!r} — drive "
                "the replay with the same configuration that recorded it")
        self._pos += 1
        return i

    def _check(self, i: int, what: str, want: float, got: float) -> None:
        if self.strict and not _close(want, got):
            raise TraceReplayError(
                f"replay diverged at event {i} ({self.trace.kind_name(i)}): "
                f"{what} was {got!r} when recorded, caller passed {want!r}")

    # protocol ------------------------------------------------------ #
    def host_now(self) -> float:
        i = self._next(schema.HOST_NOW, "host_now")
        return float(self.trace.cols[i, 0])

    def usleep(self, dt: float) -> None:
        i = self._next(schema.USLEEP, "usleep")
        self._check(i, "dt", float(dt), float(self.trace.cols[i, 0]))

    def set_frequency(self, mhz: float) -> None:
        i = self._next(schema.SET_FREQUENCY, "set_frequency")
        self._check(i, "mhz", float(mhz), float(self.trace.cols[i, 0]))

    def sync_exchange(self) -> tuple[float, float, float, float]:
        if self._sync_queue:
            return self._sync_queue.popleft()
        # a recorded sync ROUND (SYNC_BATCH) serves the whole best-of-n
        # loop; bare SYNC_EXCHANGE events are accepted one-for-one
        if self._pos < self._protocol.size and \
                int(self.trace.kinds[int(self._protocol[self._pos])]) \
                == schema.SYNC_EXCHANGE:
            i = self._next(schema.SYNC_EXCHANGE, "sync_exchange")
            t1, t2, t3, t4 = self.trace.cols[i]
            return float(t1), float(t2), float(t3), float(t4)
        i = self._next(schema.SYNC_BATCH, "sync_exchange")
        n, _, _, off = self.trace.cols[i]
        rows = self.trace.payload[int(off):int(off) + 2 * int(n)]
        self._sync_queue.extend(
            tuple(float(v) for v in rows[2 * j:2 * j + 2].ravel())
            for j in range(int(n)))
        return self._sync_queue.popleft()

    def warm_kernel(self, n_iters: int, base_iter_s: float) -> None:
        i = self._next(schema.WARM_KERNEL, "warm_kernel")
        self._check(i, "n_iters", float(n_iters),
                    float(self.trace.cols[i, 0]))
        self._check(i, "base_iter_s", float(base_iter_s),
                    float(self.trace.cols[i, 1]))

    def throttle_reasons(self) -> set:
        i = self._next(schema.THROTTLE, "throttle_reasons")
        return set(self.trace.extras.get(i, {}).get("flags", ()))

    def launch_kernel(self, n_iters: int, base_iter_s: float) -> _ReplayHandle:
        i = self._next(schema.LAUNCH, "launch_kernel")
        rec_iters, rec_base, seq, _ = self.trace.cols[i]
        self._check(i, "n_iters", float(n_iters), float(rec_iters))
        self._check(i, "base_iter_s", float(base_iter_s), float(rec_base))
        return _ReplayHandle(int(seq), int(n_iters), float(base_iter_s))

    def wait(self, h: Any) -> np.ndarray:
        i = self._next(schema.WAIT, "wait")
        seq = float(self.trace.cols[i, 0])
        if isinstance(h, _ReplayHandle):
            self._check(i, "kernel seq", float(h.seq), seq)
        return self.trace.wait_payload(i).copy()

    def run_kernel(self, n_iters: int, base_iter_s: float) -> np.ndarray:
        return self.wait(self.launch_kernel(n_iters, base_iter_s))

    def _run_kernel_batch(self, n_kernels: int, n_iters: int,
                          base_iter_s: float) -> np.ndarray:
        i = self._next(schema.BATCH, "run_kernel_batch")
        rec_k, rec_iters, rec_base, _ = self.trace.cols[i]
        self._check(i, "n_kernels", float(n_kernels), float(rec_k))
        self._check(i, "n_iters", float(n_iters), float(rec_iters))
        self._check(i, "base_iter_s", float(base_iter_s), float(rec_base))
        return self.trace.batch_payload(i).copy()


@register_backend(
    "trace-replay",
    description="re-execute a recorded telemetry trace offline, bit for bit")
def make_trace_replay(path: str | None = None, trace: Trace | None = None,
                      strict: bool = True) -> TraceReplayBackend:
    if trace is None:
        if path is None:
            raise ValueError("trace-replay needs path= (a saved trace "
                             "directory) or trace= (a loaded Trace)")
        trace = Trace.load(path)
    return TraceReplayBackend(trace, strict=strict)
