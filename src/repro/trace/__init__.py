"""Streaming telemetry: record every backend interaction, replay it
offline bit for bit, estimate switching latency online as events arrive.

    TraceRecorder / TracedBackend    record   (repro.trace.recorder)
    Trace                            the columnar artifact
    TraceReplayBackend               replay   (registered as `trace-replay`)
    OnlineSwitchEstimator            online estimation (repro.trace.online)
    analyze_trace / replay_table     offline analysis (repro.trace.analyze)

CLI: ``python -m repro.trace {record,replay,analyze,export}``.
"""
from repro.trace.schema import SCHEMA_VERSION, TraceSchemaError
from repro.trace.recorder import Trace, TracedBackend, TraceRecorder
from repro.trace.replay import TraceReplayBackend, TraceReplayError
from repro.trace.online import OnlineEstimate, OnlineSwitchEstimator, stream_pass

__all__ = [
    "SCHEMA_VERSION", "TraceSchemaError", "Trace", "TraceRecorder",
    "TracedBackend", "TraceReplayBackend", "TraceReplayError",
    "OnlineEstimate", "OnlineSwitchEstimator", "stream_pass",
]
