"""Online switching-latency estimation: a streaming change-point detector
over per-iteration kernel runtimes.

The batch path (:func:`repro.core.switching.detect_switch`) sees the whole
pass at once; a runtime system sees iterations as they complete.  The
estimator mirrors Alg. 2's per-core decision as a state machine:

  SEARCH   until an iteration starting at/after ``t_s`` lands inside the
           target baseline's +-k*sigma population band — that iteration is
           the core's (only) transition candidate, exactly like the batch
           path's first-hit rule;
  CONFIRM  from the candidate on, suffix statistics accumulate in O(1)
           (:class:`repro.core.stats.RunningStats`); once ``min_confirm``
           iterations are in and the null hypothesis (suffix mean ==
           target mean) holds, a *provisional* estimate is emitted — the
           latency a runtime could act on immediately;
  FINAL    at end of kernel, :meth:`finalize` applies the batch confirm
           rule over the full suffix and returns the pass estimate
           (max over viable cores), agreeing with ``detect_switch`` to
           within the device timer resolution (tests/test_trace_online.py
           cross-validates every pair).

The estimator never holds the sample arrays — per-core state is a handful
of scalars, so it runs happily inside a serving loop or over a trace
replayed event by event.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import stats


@dataclasses.dataclass(frozen=True)
class OnlineEstimate:
    """One emitted latency estimate (provisional while the kernel still
    runs; final after :meth:`OnlineSwitchEstimator.finalize`)."""
    latency: float              # t_e - t_s (s)
    t_s: float                  # change request, accelerator timeline
    core: int
    transition_index: int       # iteration index of the candidate
    n_confirm: int              # suffix samples backing the estimate
    final: bool


@dataclasses.dataclass
class _CoreState:
    index: int = 0                      # iterations observed so far
    candidate_index: int = -1           # -1: still searching
    candidate_end: float = 0.0          # t_e of the candidate iteration
    suffix: stats.RunningStats = dataclasses.field(
        default_factory=stats.RunningStats)
    announced: bool = False             # provisional estimate emitted


class OnlineSwitchEstimator:
    """Streaming Alg. 2 for ONE switch pass.

    Feed iterations in completion order via :meth:`observe`; call
    :meth:`finalize` when the kernel ends.  ``target`` is the target
    frequency's calibration baseline (:class:`repro.core.stats.FreqStats`);
    the detection/confirm thresholds default to the batch path's.
    """

    def __init__(self, target: stats.FreqStats, t_s: float, *,
                 k_sigma: float = 2.0, z: float = 1.96,
                 tol_frac: float = 0.02, min_confirm: int = 64):
        self.target = target
        self.t_s = float(t_s)
        self.z = float(z)
        self.min_confirm = int(min_confirm)
        self._lo, self._hi = stats.two_sigma_band(target, k_sigma)
        self._tol = tol_frac * target.mean
        self._cores: dict[int, _CoreState] = {}

    def _confirmed(self, st: _CoreState) -> bool:
        if st.candidate_index < 0 or st.suffix.n < self.min_confirm:
            return False
        suffix = stats.FreqStats(self.target.freq_mhz, st.suffix.mean,
                                 st.suffix.std, st.suffix.n)
        return stats.null_hypothesis_holds(suffix, self.target, z=self.z,
                                           tol=self._tol)

    def observe(self, core: int, start: float, end: float
                ) -> OnlineEstimate | None:
        """One finished iteration of ``core``; returns a provisional
        estimate the first time that core's candidate confirms, else None."""
        st = self._cores.setdefault(int(core), _CoreState())
        dur = end - start
        if st.candidate_index < 0:
            # first-hit rule: the FIRST in-band iteration at/after t_s is
            # the core's only candidate (Alg.2 line 12)
            if start >= self.t_s and self._lo <= dur <= self._hi:
                st.candidate_index = st.index
                st.candidate_end = end
                st.suffix.add(dur)
        else:
            st.suffix.add(dur)
        st.index += 1
        if not st.announced and self._confirmed(st):
            st.announced = True
            return OnlineEstimate(st.candidate_end - self.t_s, self.t_s,
                                  int(core), st.candidate_index,
                                  st.suffix.n, final=False)
        return None

    def finalize(self) -> OnlineEstimate | None:
        """End of kernel: apply the full-suffix confirm rule per core and
        return the pass estimate (max latency over viable cores), or None
        when no core is viable — the batch path's GOTO."""
        best: OnlineEstimate | None = None
        for core, st in self._cores.items():
            if not self._confirmed(st):
                continue
            lat = st.candidate_end - self.t_s
            if best is None or lat > best.latency:
                best = OnlineEstimate(lat, self.t_s, core, st.candidate_index,
                                      st.suffix.n, final=True)
        return best


def stream_pass(data: np.ndarray, t_s: float, target: stats.FreqStats, *,
                recorder=None, **kw
                ) -> tuple[OnlineEstimate | None, list[OnlineEstimate]]:
    """Stream one pass's (n_cores, n_iters, 2) timestamps through the
    estimator in global completion order (the order a runtime would see
    them).  Returns ``(final_estimate, provisional_estimates)``; when a
    :class:`repro.trace.recorder.TraceRecorder` is given, every emission
    is appended to the trace as an ESTIMATE annotation."""
    starts = data[..., 0]
    ends = data[..., 1]
    n_cores, n_iters = starts.shape
    est = OnlineSwitchEstimator(target, t_s, **kw)
    provisional: list[OnlineEstimate] = []
    order = np.argsort(ends, axis=None, kind="stable")
    for flat in order:
        core, i = divmod(int(flat), n_iters)
        e = est.observe(core, float(starts[core, i]), float(ends[core, i]))
        if e is not None:
            provisional.append(e)
            if recorder is not None:
                recorder.record_estimate(float(ends[core, i]), e.latency,
                                         e.t_s, e.core, final=False)
    final = est.finalize()
    if final is not None and recorder is not None:
        recorder.record_estimate(float(ends.max()), final.latency,
                                 final.t_s, final.core, final=True)
    return final, provisional
