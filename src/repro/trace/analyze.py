"""Offline trace analysis: batch replay, pass reconstruction, and the
online-vs-batch cross-validation report.

Three consumers share this module:

* ``python -m repro.trace replay`` — rebuild the recording session from the
  trace header, drive it against the :class:`TraceReplayBackend`, and check
  the resulting latency table against the live run's digest (bit-for-bit
  determinism gate, also the CI ``trace-smoke`` job);
* ``python -m repro.trace analyze`` — additionally reconstruct every
  mid-kernel switch pass from the raw event stream, run the streaming
  estimator over it, and compare against the batch ``detect_switch``
  decision on identical inputs;
* tests, which assert both properties pair by pair.
"""
from __future__ import annotations

import dataclasses
import hashlib

import numpy as np

from repro.core.clock_sync import sync_from_exchanges
from repro.core.switching import detect_switch
from repro.trace import schema
from repro.trace.online import stream_pass
from repro.trace.recorder import Trace
from repro.trace.replay import TraceReplayBackend


# ---------------------------------------------------------------------- #
# table digest: canonical fingerprint of a LatencyTable's measured content
# ---------------------------------------------------------------------- #
def table_digest(table) -> str:
    """sha256 over every pair's raw samples, labels and analysis outputs —
    two tables share a digest iff the measurement AND the analysis are
    bit-identical, which is exactly the replay-determinism contract."""
    h = hashlib.sha256()
    for (fi, ft) in sorted(table.pairs):
        pr = table.pairs[(fi, ft)]
        h.update(f"{fi!r}|{ft!r}|{pr.status}|{pr.n_clusters}|".encode())
        h.update(np.asarray(pr.latencies, dtype=np.float64).tobytes())
        labels = (pr.labels if pr.labels is not None
                  else np.zeros(0, dtype=np.int64))
        h.update(np.asarray(labels, dtype=np.int64).tobytes())
        h.update(np.float64(pr.silhouette).tobytes())
    return h.hexdigest()


# ---------------------------------------------------------------------- #
# session reconstruction
# ---------------------------------------------------------------------- #
def latest_config_from_meta(meta: dict):
    """Rebuild the recording session's LatestConfig from the trace header."""
    from repro.core.evaluation import MeasureConfig
    from repro.core.session import LatestConfig
    sweep = meta.get("sweep")
    if sweep is None:
        raise ValueError(
            "trace has no 'sweep' metadata: it was not recorded through "
            "MeasurementSession(trace=...), so the session config is "
            "unknown — replay it by driving the same code manually")
    lc = dict(sweep["latest"])
    lc["measure"] = MeasureConfig(**lc["measure"])
    return LatestConfig(**lc)


def replay_session(trace: Trace, strict: bool = True):
    """A MeasurementSession wired to the replay backend, configured exactly
    as the session that recorded ``trace``."""
    from repro.core.session import MeasurementSession, SessionConfig
    latest = latest_config_from_meta(trace.meta)   # raises if no sweep meta
    if trace.meta.get("trace_complete") is False:
        raise ValueError(
            "trace records a RESUMED sweep: pairs measured by an earlier "
            "process are not in this event stream, so the session cannot "
            "be re-driven offline — record with a fresh out_dir (or none) "
            "for a replayable trace")
    sweep = trace.meta["sweep"]
    dev = TraceReplayBackend(trace, strict=strict)
    return MeasurementSession(
        dev, [float(f) for f in sweep["frequencies"]],
        SessionConfig(latest=latest),
        device_name=sweep.get("device_name", "trace"),
        device_index=int(sweep.get("device_index", 0)),
        hostname=sweep.get("hostname", "node0"))


def replay_table(trace: Trace, strict: bool = True):
    """Re-run the recorded sweep offline; returns the LatencyTable."""
    return replay_session(trace, strict=strict).run()


# ---------------------------------------------------------------------- #
# switch-pass reconstruction from the raw event stream
# ---------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class SwitchPassTrace:
    """One reconstructed mid-kernel frequency switch."""
    f_init: float
    f_target: float
    t_s: float                  # change request mapped to the acc timeline
    data: np.ndarray            # (n_cores, n_iters, 2) of the crossed kernel
    wait_event: int             # index of the WAIT event in the trace


class SwitchPassAssembler:
    """Push-based switch-pass reconstruction: feed events one at a time
    (live, from a :meth:`TraceRecorder.add_tap` subscription, or offline
    from a stored trace) and get a :class:`SwitchPassTrace` back whenever
    one completes.

    A switch pass is a ``set_frequency`` issued between a kernel's launch
    and its wait, preceded by a ``host_now`` read (Alg. 2's t_s); the
    accelerator-timeline mapping comes from the most recent run of
    ``sync_exchange`` events, re-estimated with the identical best-of-n
    rule the live run used."""

    def __init__(self):
        self._sync_group: list[tuple] = []
        self._sync = None
        self.current_freq: float | None = None   # last committed frequency
        self._last_host_now: float | None = None
        self._open_seq: int | None = None        # most recent un-waited launch
        self._armed: tuple[float, float, float, int] | None = None

    def feed(self, kind: int, cols, data=None,
             index: int = -1) -> SwitchPassTrace | None:
        """One event: ``cols`` is the c0..c3 row; ``data`` is the WAIT
        timestamp payload / SYNC_BATCH ``(n, 4)`` exchange array when the
        event carries one.  Returns the completed pass, if any."""
        if kind == schema.SYNC_EXCHANGE:
            self._sync_group.append(tuple(float(v) for v in cols[:4]))
            return None
        if kind == schema.SYNC_BATCH:
            rows = np.asarray(data, dtype=np.float64).reshape(-1, 4)
            self._sync_group.extend(tuple(float(v) for v in row)
                                    for row in rows)
            return None
        if self._sync_group:
            self._sync = sync_from_exchanges(self._sync_group)
            self._sync_group = []
        if kind == schema.HOST_NOW:
            self._last_host_now = float(cols[0])
        elif kind == schema.SET_FREQUENCY:
            mhz = float(cols[0])
            if (self._open_seq is not None and self.current_freq is not None
                    and self._last_host_now is not None
                    and self._sync is not None):
                self._armed = (self.current_freq, mhz,
                               self._sync.host_to_acc(self._last_host_now),
                               self._open_seq)
            self.current_freq = mhz
        elif kind == schema.LAUNCH:
            self._open_seq = int(cols[2])
            self._armed = None           # a new launch invalidates any arm
        elif kind == schema.WAIT:
            seq = int(cols[0])
            armed = self._armed
            if self._open_seq == seq:
                self._open_seq = None
            self._armed = None
            if armed is not None and armed[3] == seq:
                f_init, f_target, t_s, _ = armed
                return SwitchPassTrace(f_init, f_target, t_s,
                                       np.asarray(data), index)
        return None


def trace_event_data(trace: Trace, i: int):
    """The payload array event ``i`` carries (what a live tap would have
    seen as ``data``), or None for payload-less kinds."""
    kind = int(trace.kinds[i])
    if kind == schema.WAIT:
        return trace.wait_payload(i)
    if kind == schema.BATCH:
        return trace.batch_payload(i)
    if kind == schema.SYNC_BATCH:
        n, off = int(trace.cols[i, 0]), int(trace.cols[i, 3])
        return trace.payload[off:off + 2 * n].reshape(n, 4)
    return None


def iter_switch_passes(trace: Trace):
    """Yield every :class:`SwitchPassTrace` in stream order (the offline
    driver over :class:`SwitchPassAssembler`)."""
    asm = SwitchPassAssembler()
    for i in range(trace.n_events):
        sp = asm.feed(int(trace.kinds[i]), trace.cols[i],
                      trace_event_data(trace, i), index=i)
        if sp is not None:
            yield sp


# ---------------------------------------------------------------------- #
# online vs batch cross-validation
# ---------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class PassComparison:
    f_init: float
    f_target: float
    batch_latency: float | None      # None: pass rejected (Alg.2 GOTO)
    online_latency: float | None
    n_provisional: int

    @property
    def delta(self) -> float:
        if self.batch_latency is None and self.online_latency is None:
            return 0.0
        if self.batch_latency is None or self.online_latency is None:
            return float("inf")
        return abs(self.batch_latency - self.online_latency)


@dataclasses.dataclass
class TraceReport:
    table: object                     # replayed LatencyTable
    digest: str
    live_digest: str | None           # from the trace header (None if absent)
    passes: list[PassComparison]
    timer_resolution_s: float

    @property
    def deterministic(self) -> bool:
        return self.live_digest is None or self.digest == self.live_digest

    @property
    def max_delta(self) -> float:
        return max((p.delta for p in self.passes), default=0.0)

    @property
    def online_agrees(self) -> bool:
        return self.max_delta <= self.timer_resolution_s

    @property
    def ok(self) -> bool:
        return self.deterministic and self.online_agrees


def analyze_trace(trace: Trace, *, k_sigma: float | None = None
                  ) -> TraceReport:
    """Full offline analysis of one recorded sweep."""
    session = replay_session(trace)
    table = session.run()
    cal = session.cal
    if k_sigma is None:
        k_sigma = float(session.cfg.latest.measure.k_sigma)
    comparisons: list[PassComparison] = []
    for sp in iter_switch_passes(trace):
        target = cal.baselines.get(sp.f_target)
        if target is None:
            continue                     # switch outside the calibrated set
        batch = detect_switch(sp.data, sp.t_s, target, k_sigma=k_sigma)
        final, provisional = stream_pass(sp.data, sp.t_s, target,
                                         k_sigma=k_sigma)
        comparisons.append(PassComparison(
            sp.f_init, sp.f_target,
            None if batch is None else float(batch.latency),
            None if final is None else float(final.latency),
            len(provisional)))
    timer = float(trace.meta.get("device", {}).get("timer_resolution_s", 0.0))
    return TraceReport(table=table, digest=table_digest(table),
                       live_digest=trace.meta.get("live_table_digest"),
                       passes=comparisons, timer_resolution_s=timer)


def report_markdown(report: TraceReport) -> str:
    """Human-readable summary for `python -m repro.trace analyze`."""
    lines = ["# Trace analysis", ""]
    det = ("bit-for-bit MATCH" if report.live_digest and report.deterministic
           else "no live digest recorded" if report.live_digest is None
           else "MISMATCH")
    lines += [f"- replay determinism: {det} (`{report.digest[:16]}…`)",
              f"- switch passes reconstructed: {len(report.passes)}",
              f"- online vs batch max |delta|: {report.max_delta:.3e} s "
              f"(timer resolution {report.timer_resolution_s:.1e} s) — "
              f"{'AGREE' if report.online_agrees else 'DISAGREE'}", ""]
    lines += ["| pair (MHz) | batch (ms) | online (ms) | delta (s) "
              "| provisional |",
              "|---|---|---|---|---|"]

    def fmt(v):
        return "rejected" if v is None else f"{v * 1e3:.3f}"

    for p in report.passes:
        lines.append(f"| {p.f_init:.0f}→{p.f_target:.0f} "
                     f"| {fmt(p.batch_latency)} | {fmt(p.online_latency)} "
                     f"| {p.delta:.2e} | {p.n_provisional} |")
    summary = report.table.summary()
    if summary:
        wc = summary["worst_case"]
        lines += ["", f"Replayed table: {summary['n_pairs']} pairs, "
                      f"worst-case {wc['min_ms']:.2f}–{wc['max_ms']:.2f} ms "
                      f"(mean {wc['mean_ms']:.2f} ms)."]
    return "\n".join(lines)
