"""Continuous batching for serving: a slot-based scheduler that admits new
requests into finished slots between decode steps (vLLM-style iteration-
level scheduling), with per-slot position tracking and a governor hook —
decode is the memory-bound region the paper's §III downclocking targets.

One fixed-shape decode step serves all active slots; finished/empty slots
carry a pad token and are masked out of the accounting.  This keeps a
single compiled decode_step regardless of arrival pattern.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.registry import decode_module


@dataclasses.dataclass
class Request:
    rid: int
    prompt: jnp.ndarray            # (ctx,) int32
    max_new: int
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class SchedulerStats:
    steps: int = 0
    completed: int = 0
    admitted: int = 0
    slot_busy_fraction: float = 0.0


class ContinuousBatcher:
    """Fixed slot count; one shared fixed-shape KV cache."""

    def __init__(self, cfg, env, params, *, slots: int, max_len: int,
                 ctx_len: int):
        self.cfg, self.env, self.params = cfg, env, params
        self.slots = slots
        self.max_len = max_len
        self.ctx = ctx_len
        dec = decode_module(cfg)
        self._dec = dec
        self._prefill = jax.jit(
            lambda p, b: dec.prefill(p, b, cfg, env, max_len))
        self._step = jax.jit(
            lambda p, c, t, i: dec.decode_step(p, c, t, i, cfg, env),
            donate_argnums=(1,))
        self.cache = None
        self.slot_req: list = [None] * slots
        self.pos = ctx_len                     # shared position cursor
        self.stats = SchedulerStats()

    # ------------------------------------------------------------------ #
    def _admit(self, queue: list) -> None:
        fresh = []
        for s in range(self.slots):
            if self.slot_req[s] is None and queue:
                self.slot_req[s] = queue.pop(0)
                self.stats.admitted += 1
                fresh.append(s)
        # (re)prefill when slots changed; a production engine would do
        # per-slot prefill — with one shared fixed-shape cache we batch all
        # current prompts together, which keeps ONE compiled prefill
        if fresh:
            prompts = []
            for s in range(self.slots):
                r = self.slot_req[s]
                prompts.append(r.prompt if r is not None
                               else jnp.zeros((self.ctx,), jnp.int32))
            batch = {"tokens": jnp.stack(prompts)}
            if self.cfg.family == "vlm":
                batch["img_embeds"] = jnp.zeros(
                    (self.slots, self.cfg.vlm.n_patches, self.cfg.d_model),
                    self.cfg.compute_dtype)
            if self.cfg.family == "encdec":
                batch["enc_frames"] = jnp.zeros(
                    (self.slots, self.cfg.encdec.n_frames, self.cfg.d_model),
                    self.cfg.compute_dtype)
            logits, self.cache = self._prefill(self.params, batch)
            self._next = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            self.pos = self.ctx

    def run(self, requests: list[Request], max_steps: int = 10_000,
            governor=None, device=None) -> SchedulerStats:
        queue = list(requests)
        busy_acc = 0.0
        while (queue or any(r is not None for r in self.slot_req)) \
                and self.stats.steps < max_steps and self.pos < self.max_len - 1:
            self._admit(queue)
            tok = self._next
            logits, self.cache = self._step(self.params, self.cache, tok,
                                            jnp.int32(self.pos))
            self._next = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            self.pos += 1
            self.stats.steps += 1
            busy = 0
            for s in range(self.slots):
                r = self.slot_req[s]
                if r is None:
                    continue
                busy += 1
                r.generated.append(int(tok[s, 0]))
                if len(r.generated) >= r.max_new:
                    r.done = True
                    self.stats.completed += 1
                    self.slot_req[s] = None
            busy_acc += busy / self.slots
            if governor is not None and device is not None:
                from repro.dvfs.planner import Region
                governor.plan(Region("memory", 0.01), device)
        self.stats.slot_busy_fraction = busy_acc / max(1, self.stats.steps)
        return self.stats
