"""Fault tolerance: heartbeats, straggler detection/mitigation, elastic
re-mesh, and a retrying step executor.

On a real multi-pod job these hooks bind to the cluster control plane; here
they are exercised against simulated failure injectors (tests) and drive
the campaign work-queue scheduler (:mod:`repro.campaign.workqueue`) with
the same interfaces:

  HeartbeatMonitor   per-worker liveness from step-completion stamps;
                     a worker silent for > timeout is declared dead ->
                     the driver requeues its in-flight work (campaign
                     scheduler) or triggers elastic_remesh + checkpoint
                     restore (training loops)
  StragglerPolicy    EWMA of per-step durations; a step slower than
                     ratio x EWMA marks the step degraded; after `budget`
                     consecutive degraded steps the driver requests the
                     slow worker's eviction.  Also tracks *in-flight* task
                     elapsed time so schedulers can speculatively
                     re-dispatch a straggling task before it finishes
  retry_step         transient-failure wrapper (preemption, ICI hiccup):
                     re-executes a pure step function; correctness is free
                     because steps are pure (params, opt, batch) -> ...
  elastic_remesh     rebuild the mesh from the surviving device list and
                     recompute shardings (restore re-shards the state)

All timeout logic runs on an injected clock, ``time.monotonic`` by
default — never wall-clock time, which steps under NTP adjustments and
would spuriously kill (or revive) workers.  Tests inject a fake clock.
"""
from __future__ import annotations

import dataclasses
import time


class HeartbeatMonitor:
    """Liveness from step-completion stamps on an injected monotonic clock.

    Workers are registered up front (``workers`` may be a count or an
    iterable of ids) or dynamically via :meth:`register` — the campaign
    scheduler registers replacements as it respawns crashed processes.
    A worker reaped with :meth:`remove` stays gone: a late ``beat`` from a
    process that was already declared dead is dropped, not resurrected
    (the driver already requeued its work; letting the zombie re-register
    would double-account it).
    """

    def __init__(self, workers=0, timeout_s: float = 60.0,
                 clock=time.monotonic):
        if timeout_s <= 0:
            raise ValueError(f"timeout_s must be positive, got {timeout_s}")
        self.timeout = timeout_s
        self.clock = clock
        ids = range(workers) if isinstance(workers, int) else workers
        now = clock()
        self.last = {w: now for w in ids}

    def register(self, worker) -> None:
        """Start (or restart) tracking ``worker`` from now."""
        self.last[worker] = self.clock()

    def remove(self, worker) -> None:
        """Stop tracking ``worker`` (reaped or evicted); idempotent."""
        self.last.pop(worker, None)

    def beat(self, worker, t: float | None = None) -> None:
        """Record a liveness stamp.  Beats from unknown (never-registered
        or already-removed) workers are ignored — see class docstring."""
        if worker not in self.last:
            return
        self.last[worker] = self.clock() if t is None else t

    def dead(self, now: float | None = None) -> list:
        """Workers silent for longer than the timeout ([] when none are
        tracked)."""
        now = self.clock() if now is None else now
        return [w for w, t in self.last.items() if now - t > self.timeout]


@dataclasses.dataclass
class StragglerPolicy:
    """EWMA straggler detection over an injected monotonic clock.

    Two usage shapes, sharing one EWMA:

    * post-hoc: :meth:`observe` a completed step duration -> ok | degraded
      | evict (consecutive-degraded budget);
    * in-flight: :meth:`start`/:meth:`finish` bracket a task; while it
      runs, :meth:`straggling` compares its elapsed time against
      ratio x EWMA so a scheduler can speculatively re-dispatch it.
    """

    ratio: float = 1.8          # step slower than ratio x EWMA = degraded
    alpha: float = 0.2
    budget: int = 5             # consecutive degraded steps before eviction
    clock: object = time.monotonic
    _ewma: float = 0.0
    _degraded: int = 0
    _started: dict = dataclasses.field(default_factory=dict)

    @property
    def ewma(self) -> float:
        """Current healthy-step EWMA (0 until the first observation)."""
        return self._ewma

    def observe(self, step_time_s: float) -> str:
        """Returns ok | degraded | evict."""
        if self._ewma == 0.0:
            self._ewma = step_time_s
            return "ok"
        verdict = "ok"
        if step_time_s > self.ratio * self._ewma:
            self._degraded += 1
            verdict = "evict" if self._degraded >= self.budget else "degraded"
        else:
            self._degraded = 0
            # only fold healthy steps into the EWMA (stragglers would poison it)
            self._ewma = (1 - self.alpha) * self._ewma + self.alpha * step_time_s
        return verdict

    # ---------------- in-flight tracking ---------------- #
    def start(self, task) -> None:
        """Stamp ``task`` as started now (idempotent per task: a
        speculative duplicate does not reset the original's clock)."""
        self._started.setdefault(task, self.clock())

    def elapsed(self, task) -> float:
        """Seconds since :meth:`start` (0.0 for unknown tasks)."""
        t0 = self._started.get(task)
        return 0.0 if t0 is None else self.clock() - t0

    def straggling(self, task) -> bool:
        """True when ``task`` has been in flight longer than
        ratio x EWMA (never before the first completed observation —
        with no baseline there is nothing to call slow)."""
        return self._ewma > 0.0 and self.elapsed(task) > self.ratio * self._ewma

    def finish(self, task) -> str:
        """Complete ``task``: fold its duration into :meth:`observe` and
        stop tracking it.  Unknown tasks return "ok" untracked."""
        t0 = self._started.pop(task, None)
        if t0 is None:
            return "ok"
        return self.observe(self.clock() - t0)

    def abandon(self, task) -> None:
        """Drop an in-flight task without observing it (its host died —
        the wall time says nothing about step cost); idempotent."""
        self._started.pop(task, None)


def retry_step(fn, *args, retries: int = 3, on_error=None):
    last = None
    for i in range(retries):
        try:
            return fn(*args)
        except Exception as e:      # noqa: BLE001 — deliberate catch-all boundary
            last = e
            if on_error is not None:
                on_error(i, e)
    raise last


def elastic_remesh(devices=None, *, axis_names=("data", "model")):
    """Rebuild the largest usable mesh from the surviving devices.

    Keeps the model axis as large as possible (TP degree preserved) and
    shrinks the data axis; returns (mesh, dropped_devices).

    JAX is imported lazily: everything else in this module is pure-Python
    bookkeeping that campaign worker processes import on spawn, and they
    must not pay (or depend on) the JAX runtime.
    """
    import jax
    import numpy as np
    devices = list(devices if devices is not None else jax.devices())
    n = len(devices)
    tp = 1
    # largest power-of-two TP that divides the survivor count
    for cand in (16, 8, 4, 2, 1):
        if n % cand == 0:
            tp = cand
            break
    dp = n // tp
    used = devices[: dp * tp]
    mesh = jax.sharding.Mesh(
        np.array(used).reshape(dp, tp), axis_names)
    return mesh, devices[dp * tp:]
