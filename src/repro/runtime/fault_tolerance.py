"""Fault tolerance: heartbeats, straggler detection/mitigation, elastic
re-mesh, and a retrying step executor.

On a real multi-pod job these hooks bind to the cluster control plane; here
they are exercised against simulated failure injectors (tests) with the
same interfaces:

  HeartbeatMonitor   per-worker liveness from step-completion stamps;
                     a worker silent for > timeout is declared dead ->
                     the driver triggers elastic_remesh + checkpoint restore
  StragglerPolicy    EWMA of per-step durations; a step slower than
                     ratio x EWMA marks the step degraded; after `budget`
                     consecutive degraded steps the driver requests the
                     slow worker's eviction (descheduling beats waiting —
                     the standard large-fleet mitigation)
  retry_step         transient-failure wrapper (preemption, ICI hiccup):
                     re-executes a pure step function; correctness is free
                     because steps are pure (params, opt, batch) -> ...
  elastic_remesh     rebuild the mesh from the surviving device list and
                     recompute shardings (restore re-shards the state)
"""
from __future__ import annotations

import dataclasses
import time

import jax


class HeartbeatMonitor:
    def __init__(self, workers: int, timeout_s: float = 60.0,
                 clock=time.monotonic):
        self.timeout = timeout_s
        self.clock = clock
        now = clock()
        self.last = {w: now for w in range(workers)}

    def beat(self, worker: int, t: float | None = None) -> None:
        self.last[worker] = self.clock() if t is None else t

    def dead(self, now: float | None = None) -> list[int]:
        now = self.clock() if now is None else now
        return [w for w, t in self.last.items() if now - t > self.timeout]


@dataclasses.dataclass
class StragglerPolicy:
    ratio: float = 1.8          # step slower than ratio x EWMA = degraded
    alpha: float = 0.2
    budget: int = 5             # consecutive degraded steps before eviction
    _ewma: float = 0.0
    _degraded: int = 0

    def observe(self, step_time_s: float) -> str:
        """Returns ok | degraded | evict."""
        if self._ewma == 0.0:
            self._ewma = step_time_s
            return "ok"
        verdict = "ok"
        if step_time_s > self.ratio * self._ewma:
            self._degraded += 1
            verdict = "evict" if self._degraded >= self.budget else "degraded"
        else:
            self._degraded = 0
            # only fold healthy steps into the EWMA (stragglers would poison it)
            self._ewma = (1 - self.alpha) * self._ewma + self.alpha * step_time_s
        return verdict


def retry_step(fn, *args, retries: int = 3, on_error=None):
    last = None
    for i in range(retries):
        try:
            return fn(*args)
        except Exception as e:      # noqa: BLE001 — deliberate catch-all boundary
            last = e
            if on_error is not None:
                on_error(i, e)
    raise last


def elastic_remesh(devices=None, *, axis_names=("data", "model")):
    """Rebuild the largest usable mesh from the surviving devices.

    Keeps the model axis as large as possible (TP degree preserved) and
    shrinks the data axis; returns (mesh, dropped_devices)."""
    devices = list(devices if devices is not None else jax.devices())
    n = len(devices)
    tp = 1
    # largest power-of-two TP that divides the survivor count
    for cand in (16, 8, 4, 2, 1):
        if n % cand == 0:
            tp = cand
            break
    dp = n // tp
    used = devices[: dp * tp]
    import numpy as np
    mesh = jax.sharding.Mesh(
        np.array(used).reshape(dp, tp), axis_names)
    return mesh, devices[dp * tp:]
