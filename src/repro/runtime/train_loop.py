"""Training driver: jit'd step + checkpointing + fault-tolerance hooks +
the energy-aware DVFS governor (the paper's runtime integrated first-class).

Per step the governor is consulted at each region boundary (regions from
the dry-run roofline cell when available, else measured step fractions);
its decisions are logged into the metrics stream.  Because the container
has no DVFS control surface, "applying" a frequency is a simulator call —
on real hardware the same hook issues the platform command (DESIGN.md #2).
"""
from __future__ import annotations

import dataclasses
import time

import jax

from repro.checkpoint import Checkpointer
from repro.configs.registry import model_module
from repro.data.synthetic import make_batch
from repro.launch.specs import abstract_init, make_train_step
from repro.optim import adamw, schedules
from repro.parallel.sharding import param_shardings
from repro.runtime.fault_tolerance import StragglerPolicy, retry_step


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    steps: int = 100
    log_every: int = 10
    checkpoint_every: int = 50
    checkpoint_dir: str | None = None
    seed: int = 0
    lr: float = 3e-4
    warmup: int = 20
    microbatches: int = 1
    grad_compression: bool = False   # bf16 grads + error feedback
    resume: bool = True


def train(cfg, shape, env, tc: TrainConfig | None = None, *,
          governor=None, device=None, regions=None, verbose=True) -> dict:
    """Returns metrics dict (losses, step times, governor stats)."""
    if tc is None:
        tc = TrainConfig()
    mod = model_module(cfg)
    key = jax.random.PRNGKey(tc.seed)
    params, axes = mod.init(key, cfg)
    opt_state = adamw.init(params)
    if env.mesh is not None:
        p_sds, _ = abstract_init(cfg)
        p_sh = param_shardings(env, axes, p_sds)
        params = jax.tree.map(
            lambda x, s: jax.device_put(x, s) if s is not None else x,
            params, p_sh)

    if tc.grad_compression:
        from repro.optim import compression
        opt_state["err"] = compression.init_error(params)
    opt_cfg = adamw.AdamWConfig(lr=tc.lr)
    step_fn = jax.jit(make_train_step(cfg, env, opt_cfg,
                                      microbatches=tc.microbatches,
                                      grad_compression=tc.grad_compression),
                      donate_argnums=(0, 1))

    ckpt = Checkpointer(tc.checkpoint_dir) if tc.checkpoint_dir else None
    start = 0
    if ckpt and tc.resume:
        latest = ckpt.latest_step()
        if latest is not None:
            state = ckpt.restore(latest, {"params": params, "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            start = latest + 1
            if verbose:
                print(f"[train] resumed from step {latest}")

    straggler = StragglerPolicy()
    metrics = {"loss": [], "step_time": [], "lr": [], "straggler": [],
               "governor": None, "resumed_at": start}

    for step in range(start, tc.steps):
        lr_scale = schedules.cosine_with_warmup(
            step, warmup=tc.warmup, total=tc.steps)
        batch = make_batch(cfg, shape, step=step, seed=tc.seed)
        t0 = time.perf_counter()
        loss, params, opt_state = retry_step(step_fn, params, opt_state, batch)
        loss = float(loss)
        dt = time.perf_counter() - t0
        metrics["loss"].append(loss)
        metrics["step_time"].append(dt)
        metrics["lr"].append(lr_scale * tc.lr)
        metrics["straggler"].append(straggler.observe(dt))

        if governor is not None and regions is not None:
            # region-boundary frequency planning for the *next* step
            for r in regions:
                governor.plan(r, device)

        if ckpt and tc.checkpoint_every and (step + 1) % tc.checkpoint_every == 0:
            ckpt.save_async(step, {"params": params, "opt": opt_state})
        if verbose and (step % tc.log_every == 0 or step == tc.steps - 1):
            print(f"[train] step {step:5d} loss {loss:.4f} ({dt*1e3:.0f} ms)")

    if ckpt:
        ckpt.wait()
        ckpt.save(tc.steps - 1, {"params": params, "opt": opt_state})
    if governor is not None and regions is not None:
        metrics["governor"] = governor.simulate(regions * tc.steps)
    metrics["params"] = params
    metrics["opt_state"] = opt_state
    return metrics
