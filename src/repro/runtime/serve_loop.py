"""Batched serving driver: prefill + greedy decode with a jit'd step.

The governor hook mirrors train_loop: decode is memory-bound (roofline
#Dry-run), so the governor steers toward lower frequencies between prefill
bursts — the paper's §III memory-bound downclocking opportunity.  Pass a
``governor`` (e.g. ``Governor.from_session(...)``, built on a MEASURED
latency table) plus the backend ``device`` it plans for; the hook consults
it at the prefill->decode region boundary and again after decode.  Wrap
``device`` in :class:`repro.trace.TracedBackend` and every plan decision
(with its reason) plus the issued frequency commands land in a replayable
telemetry trace.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import decode_module


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_new_tokens: int = 32
    greedy: bool = True
    seed: int = 0


def serve(cfg, env, params, batch, sc: ServeConfig | None = None,
          max_len: int | None = None, verbose=False,
          governor=None, device=None) -> dict:
    if sc is None:
        sc = ServeConfig()
    dec = decode_module(cfg)
    b, s = batch["tokens"].shape
    max_len = max_len or (s + sc.max_new_tokens)

    prefill = jax.jit(lambda p, bt: dec.prefill(p, bt, cfg, env, max_len))
    step = jax.jit(lambda p, c, t, i: dec.decode_step(p, c, t, i, cfg, env),
                   donate_argnums=(1,))

    t0 = time.perf_counter()
    logits, cache = prefill(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    if governor is not None:
        from repro.dvfs.planner import Region
        # decode is memory-bound; one step costs roughly a prefill over a
        # single token, so the burst lasts ~(t_prefill / prompt_len) per
        # generated token
        per_step = max(t_prefill / max(s, 1), 1e-5)
        governor.plan(Region("memory", per_step * sc.max_new_tokens),
                      device)

    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out = [tok]
    t0 = time.perf_counter()
    for i in range(sc.max_new_tokens - 1):
        logits, cache = step(params, cache, tok, jnp.int32(s + i))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0

    if governor is not None:
        from repro.dvfs.planner import Region
        # next prefill burst is compute-bound: plan back up
        governor.plan(Region("compute", max(t_prefill, 1e-3)), device)

    tokens = jnp.concatenate(out, axis=1)
    return {
        "tokens": tokens,
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        "tokens_per_s": (b * (sc.max_new_tokens - 1)) / max(t_decode, 1e-9),
    }
