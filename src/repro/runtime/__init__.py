from repro.runtime.train_loop import TrainConfig, train
from repro.runtime.serve_loop import ServeConfig, serve
from repro.runtime.fault_tolerance import HeartbeatMonitor, StragglerPolicy

__all__ = ["TrainConfig", "train", "ServeConfig", "serve",
           "HeartbeatMonitor", "StragglerPolicy"]
