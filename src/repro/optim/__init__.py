from repro.optim import adamw, schedules

__all__ = ["adamw", "schedules"]
