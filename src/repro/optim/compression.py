"""Gradient compression with error feedback (distributed-optimization
trick for cross-pod traffic).

Gradients are quantized to bf16 before the (cross-pod) reduction; the
quantization residual is accumulated locally in fp32 and added back the
next step (error feedback), which keeps the long-run bias at zero — the
standard guarantee that makes compressed SGD/Adam converge like the
uncompressed baseline.  Halves the "pod"-axis all-reduce bytes in the
multi-pod mesh (the slowest link in a 2x16x16 deployment).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_error(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress(grads, err, dtype=jnp.bfloat16):
    """(compressed grads in `dtype`, new error state).

    compressed = cast(g + err); err' = (g + err) - compressed."""
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q = corrected.astype(dtype)
        return q, corrected - q.astype(jnp.float32)

    out = jax.tree.map(one, grads, err)
    flat, treedef = jax.tree.flatten(out, is_leaf=lambda x: isinstance(x, tuple))
    q = jax.tree.unflatten(treedef, [t[0] for t in flat])
    new_err = jax.tree.unflatten(treedef, [t[1] for t in flat])
    return q, new_err
