"""AdamW with fp32 moments over (possibly bf16) params.

Moments inherit each parameter's sharding (2-D FSDP+TP via the logical-axes
tree), so optimizer state scales with the full chip count — the ZeRO-style
partitioning falls out of the sharding annotations rather than a separate
code path.  Production note (DESIGN.md): bf16 params + fp32 moments; master
fp32 copies are intentionally omitted to fit the 16 GB/chip envelope at
236 B params — on real hardware pair this with stochastic rounding.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def opt_axes(param_axes):
    """Logical axes for the optimizer state (moments mirror params)."""
    return {"m": param_axes, "v": param_axes, "step": ()}


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def update(params, grads, state, cfg: AdamWConfig, lr_scale=1.0):
    step = state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32) * clip
        m_new = b1 * m + (1 - b1) * gf
        v_new = b2 * v + (1 - b2) * gf * gf
        mh = m_new / bc1
        vh = v_new / bc2
        step_ = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * step_).astype(p.dtype)
        return p_new, m_new, v_new

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    flat, treedef = jax.tree.flatten(out, is_leaf=lambda x: isinstance(x, tuple))
    p_new = jax.tree.unflatten(treedef, [t[0] for t in flat])
    m_new = jax.tree.unflatten(treedef, [t[1] for t in flat])
    v_new = jax.tree.unflatten(treedef, [t[2] for t in flat])
    return p_new, {"m": m_new, "v": v_new, "step": step}, gnorm
