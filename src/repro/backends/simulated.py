"""`simulated` backend: the paper-calibrated SimulatedAccelerator.

Thin registry adapter over :func:`repro.dvfs.transition_models.make_device`;
``kind`` selects the architecture model (a100 | gh200 | rtx6000), remaining
options forward to DeviceConfig (n_cores, iter_noise_sigma, wait_impl, ...).
"""
from __future__ import annotations

from repro.backends.registry import register_backend
from repro.dvfs.transition_models import make_device


@register_backend(
    "simulated",
    description="SimulatedAccelerator calibrated to the paper's three GPUs",
    virtual=True, batchable=True)
def make_simulated(kind: str = "a100", *, seed: int = 0, unit_seed: int = 0,
                   n_cores: int | None = None, **overrides):
    return make_device(kind, seed=seed, unit_seed=unit_seed,
                       n_cores=n_cores, **overrides)
