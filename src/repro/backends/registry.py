"""Backend registry: name -> factory, with availability gating.

Factories register themselves at import time::

    @register_backend("simulated", description="...")
    def _make(**options) -> AcceleratorBackend: ...

Consumers create instances by name::

    dev = create_backend("vmapped-sim", kind="a100", n_cores=8)

``requires`` lists import names that must be present for the backend to be
usable; :func:`create_backend` raises :class:`BackendUnavailableError` with
an actionable message when they are missing, so unavailable backends (e.g.
``cuda-nvml`` without a GPU) stay *listed* but fail loudly only on use.
"""
from __future__ import annotations

import dataclasses
import importlib.util
from typing import Callable

from repro.backends.base import AcceleratorBackend, BackendUnavailableError


@dataclasses.dataclass(frozen=True)
class BackendEntry:
    name: str
    factory: Callable[..., AcceleratorBackend]
    description: str = ""
    requires: tuple[str, ...] = ()
    # virtual backends model a device entirely in software: any number of
    # independent instances may be constructed (one per worker, or one per
    # measured pair for deterministic parallel sweeps).  Hardware-bound or
    # stream-bound backends (cuda-nvml, trace-replay) keep the default
    # False and are measured on their single explicit instance.
    virtual: bool = False
    # batchable backends expose the simulator's split wait protocol
    # (_wait_draw / event timeline), which the batched sweep engine
    # (repro.core.batched_sweep) fuses across pair lanes.  Lets sessions
    # reject engine="batched" on unsuitable backends before building a
    # single device.
    batchable: bool = False
    # frequency domains the backend's operating points span, in
    # repro.core.freqkey's canonical names.  Empty = one implicit domain
    # (bare-MHz keys, every backend before the heterogeneous families).
    # Informational: error messages and the docs-check completeness gate
    # read it; the measurement pipeline itself is domain-agnostic.
    domains: tuple[str, ...] = ()

    def missing_requirements(self) -> list[str]:
        return [m for m in self.requires
                if importlib.util.find_spec(m) is None]

    @property
    def available(self) -> bool:
        return not self.missing_requirements()


_REGISTRY: dict[str, BackendEntry] = {}


def register_backend(name: str, *, description: str = "",
                     requires: tuple[str, ...] = (), virtual: bool = False,
                     batchable: bool = False,
                     domains: tuple[str, ...] = ()):
    """Decorator registering ``factory`` under ``name`` (idempotent per
    name: re-registration overwrites, so module reloads are harmless)."""
    def deco(factory: Callable[..., AcceleratorBackend]):
        _REGISTRY[name] = BackendEntry(name, factory, description, requires,
                                       virtual, batchable, domains)
        return factory
    return deco


def get_backend(name: str) -> BackendEntry:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown backend {name!r}; registered: "
            f"{sorted(_REGISTRY)}") from None


def create_backend(name: str, **options) -> AcceleratorBackend:
    entry = get_backend(name)
    missing = entry.missing_requirements()
    if missing:
        raise BackendUnavailableError(
            f"backend {name!r} needs missing module(s) {missing}; "
            f"install them or pick one of "
            f"{[n for n in sorted(_REGISTRY) if _REGISTRY[n].available]}")
    return entry.factory(**options)


def list_backends(*, available_only: bool = False) -> list[str]:
    return sorted(n for n, e in _REGISTRY.items()
                  if e.available or not available_only)
