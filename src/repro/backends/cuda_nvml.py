"""`cuda-nvml` backend stub: the real-hardware contract, documented.

This module records how each :class:`AcceleratorBackend` method maps onto
CUDA + NVML so a hardware port is mechanical.  It registers with
``requires=("pynvml",)`` — in environments without the NVIDIA bindings the
registry lists it but :func:`create_backend` raises
:class:`BackendUnavailableError` instead of constructing it.

Method contract on real hardware (paper §VI, the LATEST tool):

  frequencies        nvmlDeviceGetSupportedGraphicsClocks(mem_clock)
  set_frequency      nvmlDeviceSetGpuLockedClocks(mhz, mhz); asynchronous —
                     returns before the clock settles, which is precisely
                     the latency this repo measures
  launch_kernel      launch the iterative workload (repro.kernels.microbench
                     on TPU/Pallas; an unrolled FMA chain per SM on CUDA)
                     with one block per SM; each iteration stores
                     %%globaltimer before/after into a device buffer
  wait               cudaStreamSynchronize + D2H copy of the per-core
                     (n_iters, 2) globaltimer stamps (1 us resolution)
  sync_exchange      IEEE-1588 two-way exchange: host clock_gettime vs a
                     single-thread kernel reading %%globaltimer, repeated;
                     best-of-n by round-trip time (repro.core.clock_sync)
  throttle_reasons   nvmlDeviceGetCurrentClocksEventReasons, mapped to
                     {"thermal", "power"} like the simulator
  usleep / host_now  time.sleep / time.monotonic
"""
from __future__ import annotations

from repro.backends.base import BackendUnavailableError
from repro.backends.registry import register_backend


class CudaNvmlBackend:
    """Skeleton for the CUDA/NVML implementation.

    Construction requires working NVIDIA bindings; every device method is
    a placeholder raising NotImplementedError until the hardware port
    lands.  Kept importable so the registry, docs and tests can reference
    the contract without a GPU.
    """

    def __init__(self, device_index: int = 0):
        try:
            import pynvml  # noqa: F401
        except ImportError as e:  # pragma: no cover - exercised via registry
            raise BackendUnavailableError(
                "cuda-nvml backend needs the 'pynvml' package and an "
                "NVIDIA driver") from e
        self.device_index = device_index
        raise NotImplementedError(
            "cuda-nvml backend is a documented stub; see module docstring "
            "for the method-by-method hardware mapping")

    @property
    def frequencies(self) -> tuple[float, ...]:
        raise NotImplementedError

    def host_now(self) -> float:
        raise NotImplementedError

    def usleep(self, dt: float) -> None:
        raise NotImplementedError

    def set_frequency(self, mhz: float) -> None:
        raise NotImplementedError

    def launch_kernel(self, n_iters: int, base_iter_s: float):
        raise NotImplementedError

    def wait(self, handle):
        raise NotImplementedError

    def run_kernel(self, n_iters: int, base_iter_s: float):
        raise NotImplementedError

    def sync_exchange(self) -> tuple[float, float, float, float]:
        raise NotImplementedError

    def throttle_reasons(self) -> set:
        raise NotImplementedError


@register_backend(
    "cuda-nvml",
    description="CUDA + NVML hardware backend (stub: documents the "
                "real-HW contract)",
    requires=("pynvml",))
def make_cuda_nvml(device_index: int = 0, **_ignored):
    return CudaNvmlBackend(device_index=device_index)
