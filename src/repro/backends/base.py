"""The formal host-side contract every measurement backend implements.

`repro.core` (calibration, switching, evaluation, session) is written
against this protocol only — it never sees simulation internals or NVML
handles.  The contract mirrors what a CUDA/NVML implementation exposes
(paper §VI) and what the simulator provides today:

  host_now() / usleep(dt)      host clock, seconds
  set_frequency(mhz)           asynchronous frequency-change command
  launch_kernel(n, iter_s)     non-blocking launch of the iterative workload
  wait(handle)                 -> (n_cores, n_iters, 2) device timestamps
  run_kernel(n, iter_s)        blocking convenience wrapper
  sync_exchange()              one IEEE-1588 two-way message exchange
  throttle_reasons()           throttle flags raised since the last call
  frequencies                  supported core frequencies, MHz
"""
from __future__ import annotations

from typing import Any, Protocol, runtime_checkable


class BackendUnavailableError(RuntimeError):
    """Raised when a registered backend cannot run in this environment
    (missing driver bindings, no hardware, ...)."""


@runtime_checkable
class AcceleratorBackend(Protocol):
    """Structural protocol for measurement targets.

    Timestamps returned by :meth:`wait` live on the *device* timeline and
    are quantized to the device timer resolution; :meth:`sync_exchange`
    provides the raw material for mapping host time onto that timeline
    (``repro.core.clock_sync``).
    """

    @property
    def frequencies(self) -> tuple[float, ...]:
        """Supported core frequencies in MHz, ascending."""
        ...

    def host_now(self) -> float:
        ...

    def usleep(self, dt: float) -> None:
        ...

    def set_frequency(self, mhz: float) -> None:
        ...

    def launch_kernel(self, n_iters: int, base_iter_s: float) -> Any:
        ...

    def wait(self, handle: Any):
        ...

    def run_kernel(self, n_iters: int, base_iter_s: float):
        ...

    def sync_exchange(self) -> tuple[float, float, float, float]:
        ...

    def throttle_reasons(self) -> set:
        ...
