"""`multi-domain-sim` backend: independent core and uncore/memory clocks.

Everything measured through PR 9 had one clock domain; this device has two
ladders whose operating points are domain-encoded frequency keys
(:mod:`repro.core.freqkey`): ``"core:1200"`` runs the core ladder with the
uncore at its default, ``"uncore:450"`` drops the fabric/memory clock with
the core at its default.  Switching latency depends on which domain moves
— core relocks are fast, uncore retrains are ~4-6x slower, and a
cross-domain transition pays both legs plus a coupling penalty
(:class:`repro.dvfs.domain_models.MultiDomainModel`).

The measurement pipeline needs no special casing: ``device.frequencies``
is the encoded union of both ladders, phase 1 calibrates one iteration-time
baseline per operating point (uncore settings shave effective throughput
via the model's ``effective_frequency``), and phase 2/3 measure encoded
``(f_init, f_target)`` pairs exactly like bare-MHz ones.  The backend is
``virtual`` (pair-seeded deterministic sweeps) but NOT ``batchable``: the
batched engine's fused lane evaluator assumes one shared ``f_max``
normalization per backend kind, which a per-domain effective-rate map
breaks — sessions reject ``engine="batched"`` with a clear error instead.
"""
from __future__ import annotations

from repro.backends.registry import register_backend
from repro.core.freqkey import (canon_freq, domain_index, encode_freq,
                                format_freq, freq_domain, freq_mhz,
                                split_freq)
from repro.dvfs.device_model import DeviceConfig, SimulatedAccelerator
from repro.dvfs.domain_models import MultiDomainModel, _encode_raw


class MultiDomainAccelerator(SimulatedAccelerator):
    """SimulatedAccelerator over domain-encoded operating points.

    The committed frequency timeline holds *effective* clock rates (what
    iteration durations scale by), so the unmodified wait evaluators, the
    trace recorder and clock sync all work untouched; setpoints, history
    entries and throttle bookkeeping stay in encoded operating-point keys,
    so ground truth and pair artifacts are keyed exactly like the
    session's pairs."""

    def __init__(self, model, cfg: DeviceConfig, seed: int = 0):
        # super().__init__ commits the idle operating point through
        # _timeline_freq, so the effective-rate map must exist first
        self._eff = model.effective_frequency
        super().__init__(model, cfg, seed=seed)
        self._f_max_eff = max(self._eff(f) for f in cfg.frequencies)

    # -------------------------------------------------------------- #
    # the domain-aware seams (see SimulatedAccelerator hook docstrings)
    # -------------------------------------------------------------- #
    def _timeline_freq(self, f: float) -> float:
        return self._eff(f)

    def _f_max(self) -> float:
        return self._f_max_eff

    def _thermal_cap(self) -> float:
        domain, mhz = split_freq(self._set_freq)
        if domain is None:
            return super()._thermal_cap()
        top = max(v for v in self.domain_frequencies()[domain])
        return _encode_raw(domain, min(mhz, 0.8 * top))

    def set_frequency(self, mhz) -> None:
        """Accepts any :func:`repro.core.freqkey.canon_freq` spelling —
        encoded float, ``(domain, mhz)`` tuple, or ``"domain:mhz"``."""
        key = canon_freq(mhz)
        if key not in self._freq_set:
            raise ValueError(
                f"unsupported operating point {format_freq(key)}; this "
                f"device offers "
                f"{[format_freq(f) for f in self.cfg.frequencies]}")
        super().set_frequency(key)

    # -------------------------------------------------------------- #
    # introspection (docs, reports, error messages)
    # -------------------------------------------------------------- #
    @property
    def domains(self) -> tuple[str, ...]:
        """Domain names present on this device, ladder order."""
        seen: list[str] = []
        for f in self.cfg.frequencies:
            d = freq_domain(f)
            if d not in seen:
                seen.append(d)
        return tuple(seen)

    def domain_frequencies(self) -> dict[str, tuple[float, ...]]:
        """domain -> its ladder in physical MHz, ascending."""
        out: dict[str, list[float]] = {}
        for f in self.cfg.frequencies:
            out.setdefault(freq_domain(f), []).append(freq_mhz(f))
        return {d: tuple(sorted(v)) for d, v in out.items()}


def _canon_ladder(domain: str, freqs) -> list[float]:
    keys = sorted(encode_freq(domain, float(f)) for f in freqs)
    if not keys:
        raise ValueError(f"{domain} ladder must be non-empty")
    return keys


@register_backend(
    "multi-domain-sim",
    description="simulated device with independent core and uncore/memory "
                "clock ladders; switching latency depends on which domain "
                "moves and cross-domain transitions interact",
    virtual=True, batchable=False, domains=("core", "uncore"))
def make_multi_domain(*, seed: int = 0, unit_seed: int = 0,
                      n_cores: int = 24,
                      core_freqs=(600.0, 900.0, 1200.0, 1500.0),
                      uncore_freqs=(300.0, 450.0, 600.0),
                      uncore_default: float = 750.0,
                      uncore_floor: float = 0.45,
                      **overrides):
    """Build a two-domain device.  ``core_freqs`` / ``uncore_freqs`` are
    physical MHz ladders (whole numbers — the operating-point encoding
    requires it); the device's ``frequencies`` tuple is their encoded
    union, core entries first."""
    model = MultiDomainModel(unit_seed=unit_seed,
                             core_default=float(max(core_freqs)),
                             uncore_default=float(uncore_default),
                             uncore_floor=float(uncore_floor))
    keys = _canon_ladder("core", core_freqs) \
        + _canon_ladder("uncore", uncore_freqs)
    assert keys == sorted(keys), "core domain index precedes uncore"
    if "power_throttle_freqs" in overrides:
        overrides["power_throttle_freqs"] = tuple(
            canon_freq(f) for f in overrides["power_throttle_freqs"])
    cfg = DeviceConfig(n_cores=int(n_cores), frequencies=tuple(keys),
                       **overrides)
    return MultiDomainAccelerator(model, cfg, seed=seed)


# re-exported for backends that share the encoding helpers
__all__ = ["MultiDomainAccelerator", "make_multi_domain", "domain_index"]
