"""`pstate-sim` backend: per-cluster pstate registers, m1n1-style.

Modeled on AsahiLinux m1n1's ``cpu_pstate_latencies.py`` experiment
(SNIPPETS.md): an e-core and a p-core cluster, each with its own pstate
ladder behind a per-cluster register, and transition latency observed by
sampling a high-rate *timelog* — (timestamp, frequency) pairs polled from
a cycle counter — rather than inferring it from kernel-iteration timing.

Operating points are domain-encoded keys (:mod:`repro.core.freqkey`):
``"ecore:1332"`` runs the workload on the e-cluster at 1332 MHz,
``"pcore:2988"`` on the p-cluster.  The default ladders are the M1's
published pstate tables.  Two measurement paths coexist:

* the standard phases 1-3 pipeline works unmodified (the device is a full
  :class:`AcceleratorBackend`; iteration durations scale with the active
  cluster's IPC-adjusted clock), and
* :meth:`PStateAccelerator.measure_pstate_latency` reproduces the m1n1
  experiment natively: issue the register write, poll the timelog at
  ``rate_hz``, report when the observed clock settles on the target —
  resolution is one sample period instead of one kernel iteration.  Tests
  cross-check both paths against the simulator's ground truth.

Like ``multi-domain-sim`` this backend is ``virtual`` (pair-seeded
deterministic parallel sweeps) but not ``batchable``.
"""
from __future__ import annotations

import numpy as np

from repro.backends.registry import register_backend
from repro.core.freqkey import canon_freq, encode_freq, format_freq
from repro.dvfs.device_model import DeviceConfig
from repro.backends.multi_domain import MultiDomainAccelerator
from repro.dvfs.domain_models import PStateClusterModel

# the M1 pstate tables from m1n1's experiment (MHz)
E_CORE_PSTATES = (600.0, 972.0, 1332.0, 1704.0, 2064.0)
P_CORE_PSTATES = (600.0, 828.0, 1056.0, 1284.0, 1500.0, 1728.0, 1956.0,
                  2184.0, 2388.0, 2592.0, 2772.0, 2988.0, 3096.0, 3144.0,
                  3204.0)

_TIMEBASE_HZ = 24e6            # ARM generic timer (CNTFRQ) on the M1


class PStateAccelerator(MultiDomainAccelerator):
    """Two pstate clusters behind the multi-domain operating-point seams,
    plus the m1n1 timelog measurement surface."""

    # -------------------------------------------------------------- #
    # high-rate timelog sampling
    # -------------------------------------------------------------- #
    def read_timelog(self, t_start_dev: float, duration_s: float,
                     rate_hz: float = 200e3) -> np.ndarray:
        """Sample the committed frequency timeline like m1n1's ``timelog``
        loop polls (CNTPCT, cycle counter) pairs: returns ``(n, 2)`` rows
        of ``[t_dev, effective_mhz]`` on a uniform ``1/rate_hz`` grid.
        The simulator's timeline is committed eagerly at command time, so
        the log can cover a transition that is still "in flight" on the
        host clock."""
        n = max(2, int(round(duration_s * rate_hz)))
        ts = t_start_dev + np.arange(n) / rate_hz
        freqs = np.array([self._freq_at(float(t)) for t in ts])
        return np.column_stack([ts, freqs])

    def measure_pstate_latency(self, f_from, f_to, *, window_s: float = 0.02,
                               rate_hz: float = 200e3
                               ) -> tuple[float, np.ndarray]:
        """The m1n1 ``bench_latency`` shape: settle at ``f_from``, write
        the target pstate, poll the timelog, and report when the observed
        clock first settles on (and stays at) the target.  Returns
        ``(latency_estimate_s, samples)``; the estimate resolves to one
        sample period (``1/rate_hz``), NOT one kernel iteration — the
        point of the timelog path.  Ground truth for the same transition
        lands in ``self.history[-1]["true_latency"]``."""
        f_from, f_to = canon_freq(f_from), canon_freq(f_to)
        self.set_frequency(f_from)
        # let the first transition land before the measured one is issued
        self.usleep(max(window_s, 0.05))
        self.set_frequency(f_to)
        arrive = self.history[-1]["arrive_dev"]
        samples = self.read_timelog(arrive, window_s, rate_hz)
        target_eff = self._timeline_freq(f_to)
        at_target = samples[:, 1] == target_eff
        # first index from which the clock never leaves the target again
        # (cross-cluster trajectories pass through the default point, which
        # can momentarily equal the target's effective rate)
        settled = np.flatnonzero(~at_target)
        first = 0 if not settled.size else int(settled[-1]) + 1
        if first >= len(samples):
            raise RuntimeError(
                f"clock never settled on {format_freq(f_to)} within "
                f"{window_s * 1e3:.1f} ms; widen window_s")
        return float(samples[first, 0] - arrive), samples

    # -------------------------------------------------------------- #
    # introspection, cluster vocabulary
    # -------------------------------------------------------------- #
    @property
    def clusters(self) -> tuple[str, ...]:
        return self.domains

    def cluster_frequencies(self) -> dict[str, tuple[float, ...]]:
        return self.domain_frequencies()


@register_backend(
    "pstate-sim",
    description="m1n1-style per-cluster pstate device: e-/p-core clusters "
                "on different frequency ladders, timelog-resolution "
                "latency sampling",
    virtual=True, batchable=False, domains=("ecore", "pcore"))
def make_pstate(*, seed: int = 0, unit_seed: int = 0, n_cores: int = 8,
                ecore_freqs=E_CORE_PSTATES, pcore_freqs=P_CORE_PSTATES,
                e_ipc: float = 0.55, p_ipc: float = 1.0, **overrides):
    model = PStateClusterModel(unit_seed=unit_seed, e_ipc=float(e_ipc),
                               p_ipc=float(p_ipc),
                               e_default=float(max(ecore_freqs)),
                               p_default=float(max(pcore_freqs)))
    keys = sorted(encode_freq("ecore", f) for f in ecore_freqs) \
        + sorted(encode_freq("pcore", f) for f in pcore_freqs)
    if "power_throttle_freqs" in overrides:
        overrides["power_throttle_freqs"] = tuple(
            canon_freq(f) for f in overrides["power_throttle_freqs"])
    overrides.setdefault("timer_resolution_s", 1.0 / _TIMEBASE_HZ)
    cfg = DeviceConfig(n_cores=int(n_cores), frequencies=tuple(keys),
                       **overrides)
    return PStateAccelerator(model, cfg, seed=seed)
