"""`vmapped-sim` backend: batched, always-vectorized simulator.

Same device model and statistics as `simulated`, with two differences:

* the segment-wise cumulative-sum timestamp evaluation is mandatory (the
  per-iteration reference loop is rejected), and
* :meth:`run_kernel_batch` evaluates a back-to-back train of identical
  kernels — all cores x all passes — in ONE vectorized numpy pass over the
  frequency-event timeline, instead of one `launch/wait` round-trip per
  kernel.  The train is gapless: no per-kernel launch overhead or start
  skew re-roll, which is exactly the calibration warm-up burst shape
  (paper Alg. 1) where only the last kernel's statistics matter.
"""
from __future__ import annotations

import numpy as np

from repro.backends.registry import register_backend
from repro.dvfs.device_model import SimulatedAccelerator
from repro.dvfs.transition_models import make_device


class VmappedSimAccelerator(SimulatedAccelerator):
    def __init__(self, model, cfg, seed: int = 0):
        if cfg.wait_impl != "vectorized":
            raise ValueError(
                "vmapped-sim requires wait_impl='vectorized'; use the "
                "'simulated' backend for the reference loop")
        super().__init__(model, cfg, seed=seed)

    def run_kernel_batch(self, n_kernels: int, n_iters: int,
                         base_iter_s: float) -> np.ndarray:
        """Run ``n_kernels`` identical kernels back-to-back and return
        (n_kernels, n_cores, n_iters, 2) timestamps from one evaluation."""
        h = self.launch_kernel(n_kernels * n_iters, base_iter_s)
        data = self.wait(h)                      # (cores, k*iters, 2)
        n = self.cfg.n_cores
        return np.ascontiguousarray(
            data.reshape(n, n_kernels, n_iters, 2).swapaxes(0, 1))


@register_backend(
    "vmapped-sim",
    description="SimulatedAccelerator with mandatory vectorized evaluation "
                "and batched multi-kernel passes",
    virtual=True)
def make_vmapped_sim(kind: str = "a100", *, seed: int = 0, unit_seed: int = 0,
                     n_cores: int | None = None, **overrides):
    overrides.setdefault("wait_impl", "vectorized")
    return make_device(kind, seed=seed, unit_seed=unit_seed, n_cores=n_cores,
                       cls=VmappedSimAccelerator, **overrides)
