"""`vmapped-sim` backend: batched, always-vectorized simulator.

Same device model and statistics as `simulated`, with three differences:

* the segment-wise cumulative-sum timestamp evaluation is mandatory (the
  per-iteration reference loop is rejected),
* :meth:`run_kernel_batch` evaluates a back-to-back train of identical
  kernels — all cores x all passes — in ONE vectorized numpy pass over the
  frequency-event timeline, instead of one `launch/wait` round-trip per
  kernel.  The train is gapless: no per-kernel launch overhead or start
  skew re-roll, which is exactly the calibration warm-up burst shape
  (paper Alg. 1) where only the last kernel's statistics matter, and
* :func:`eval_timestamps_lanes` extends the same segment-wise evaluation
  from one device to a whole GRID of independent pair-seeded devices
  ("lanes"): every lane's cores become rows of one (lanes*cores, iters)
  program evaluated against per-lane frequency timelines.  This is the
  switch-pass analogue of :meth:`run_kernel_batch` and the numeric core of
  the batched sweep engine (:mod:`repro.core.batched_sweep`).
"""
from __future__ import annotations

import numpy as np

from repro.backends.registry import register_backend
from repro.dvfs.device_model import SimulatedAccelerator
from repro.dvfs.transition_models import make_device


def eval_timestamps_lanes(base_iter_s: float, t0: np.ndarray,
                          noise_t: np.ndarray, lane_of_row: np.ndarray,
                          ev_t_pad: np.ndarray, ev_f_pad: np.ndarray,
                          f_max: float, *, ends_only: bool = False
                          ) -> np.ndarray:
    """Segment-wise cumsum evaluation of MANY lanes' kernels at once.

    Everything is laid out iteration-major ("transposed"): ``noise_t`` is
    (iters, R) with R = lanes*cores columns, and the result is the
    (iters + 1, R) iteration-boundary timestamp stack — or just the (R,)
    final boundaries when ``ends_only`` is set (warm-up kernels: the
    timestamps are never read, only the completion time and the RNG
    stream matter).  ``lane_of_row`` maps each column to its lane;
    ``ev_t_pad`` / ``ev_f_pad`` are (events, lanes) frequency timelines
    right-padded with ``+inf`` times (at least one pad row, so
    ``seg + 1`` always gathers).

    Iteration-major matters on this hot path: the loop advances ALL
    columns one iteration per step with two contiguous R-wide ops (one
    multiply, one add), instead of R tiny per-row inner loops or the
    windowed scatter/gather rounds of the single-device evaluator —
    both of which dominate wall time for the 8-24-iteration kernels
    sweeps actually use, where nearly every column crosses a frequency
    event (the warm-up kernel brackets the f_init arrival, the measured
    kernel brackets the switch) and windowing degenerates.

    Bit-identical per column to
    :meth:`SimulatedAccelerator._eval_timestamps_vectorized` on the
    corresponding single device: the frequency is still sampled at each
    iteration's start (``searchsorted side='right'`` semantics, computed
    here as a padded comparison count), each duration is the same single
    ``noise * (base * (f_max / f))`` multiply, and each boundary is one
    ``t + dur`` add.  The windowed evaluator's ``np.add.accumulate``
    IS that same sequential add chain — it restarts each round from the
    last committed boundary and discards (then recomputes) everything
    past the segment end — so both schemes perform the identical
    additions in the identical order, just one column per device core.
    Segment state (``seg``/``seg_end``/``scale``) advances incrementally
    for the few columns that cross an event each step, which is where
    the per-column "recompute the window with the new scale" of the
    windowed scheme collapses to a small fancy-indexed update.
    """
    it, r_total = noise_t.shape
    if it >= 128 and r_total <= 512:
        # few columns, long kernels: the iteration loop would be all numpy
        # dispatch.  The windowed scheme (bit-identical, see its docstring)
        # covers a whole segment per round instead.
        return _eval_lanes_windowed(base_iter_s, t0, noise_t, lane_of_row,
                                    ev_t_pad, ev_f_pad, f_max,
                                    ends_only=ends_only)
    # f_max / f per (event, lane) once; `base * pre[...]` below keeps the
    # serial `base * (f_max / f)` operation order exactly
    pre_scale = f_max / ev_f_pad
    # segment of each column at its start time: count events <= t, like
    # searchsorted(side="right") against that column's lane timeline
    ev_t = ev_t_pad[:, lane_of_row]                      # (E, R) gather
    seg = np.maximum((ev_t <= t0[None, :]).sum(axis=0) - 1, 0)
    scale = base_iter_s * pre_scale[seg, lane_of_row]
    seg_end = ev_t_pad[seg + 1, lane_of_row]
    bounds = None
    if ends_only:
        t = t0.astype(np.float64, copy=True)
    else:
        bounds = np.empty((it + 1, r_total))
        bounds[0] = t0
    dur = np.empty(r_total)
    cross = np.empty(r_total, dtype=bool)
    for k in range(it):
        np.multiply(noise_t[k], scale, out=dur)
        if bounds is None:
            np.add(t, dur, out=t)
        else:
            t = bounds[k + 1]
            np.add(bounds[k], dur, out=t)
        if k == it - 1:                  # last boundary: freq never read
            break
        # an iteration starting exactly at seg_end belongs to the next
        # segment (events at time t count as <= t), hence >=; columns in
        # the final segment (seg_end = inf) never cross.  A column can
        # skip several closely-spaced events in one iteration, so re-test
        # the shrinking crossed set until every column's boundary holds.
        np.greater_equal(t, seg_end, out=cross)
        if cross.any():
            idx = np.flatnonzero(cross)
            while idx.size:
                seg[idx] += 1
                ln = lane_of_row[idx]
                s = seg[idx]
                seg_end[idx] = ev_t_pad[s + 1, ln]
                scale[idx] = base_iter_s * pre_scale[s, ln]
                idx = idx[seg_end[idx] <= t[idx]]
    return t if ends_only else bounds


def _eval_lanes_windowed(base_iter_s, t0, noise_t, lane_of_row,
                         ev_t_pad, ev_f_pad, f_max, *, ends_only=False):
    """Few-columns / many-iterations fallback: the per-iteration loop
    above would be all numpy dispatch, so delegate each lane to the
    single-device segment-windowed evaluator in its native row-major
    layout — bitwise identical by construction, since that IS the serial
    code path.  The transposes in and out are a few MB per lane, noise
    in the bandwidth the evaluation itself touches anyway."""
    it, r_total = noise_t.shape
    n_lanes = ev_t_pad.shape[1]
    out = (np.empty(r_total) if ends_only
           else np.empty((it + 1, r_total)))
    for i in range(n_lanes):
        cols = np.flatnonzero(lane_of_row == i)
        if not cols.size:
            continue
        keep = np.isfinite(ev_t_pad[:, i])               # drop inf padding
        ev_t = ev_t_pad[keep, i]
        ev_f = ev_f_pad[keep, i]
        noise = np.ascontiguousarray(noise_t[:, cols].T)
        b = SimulatedAccelerator._eval_timestamps_vectorized(
            base_iter_s, t0[cols], noise, ev_t, ev_f, f_max)
        if ends_only:
            out[cols] = b[:, -1]
        else:
            out[:, cols] = b.T
    return out


class VmappedSimAccelerator(SimulatedAccelerator):
    def __init__(self, model, cfg, seed: int = 0):
        if cfg.wait_impl != "vectorized":
            raise ValueError(
                "vmapped-sim requires wait_impl='vectorized'; use the "
                "'simulated' backend for the reference loop")
        super().__init__(model, cfg, seed=seed)

    def run_kernel_batch(self, n_kernels: int, n_iters: int,
                         base_iter_s: float) -> np.ndarray:
        """Run ``n_kernels`` identical kernels back-to-back and return
        (n_kernels, n_cores, n_iters, 2) timestamps from one evaluation."""
        h = self.launch_kernel(n_kernels * n_iters, base_iter_s)
        data = self.wait(h)                      # (cores, k*iters, 2)
        n = self.cfg.n_cores
        return np.ascontiguousarray(
            data.reshape(n, n_kernels, n_iters, 2).swapaxes(0, 1))


@register_backend(
    "vmapped-sim",
    description="SimulatedAccelerator with mandatory vectorized evaluation "
                "and batched multi-kernel passes",
    virtual=True, batchable=True)
def make_vmapped_sim(kind: str = "a100", *, seed: int = 0, unit_seed: int = 0,
                     n_cores: int | None = None, **overrides):
    overrides.setdefault("wait_impl", "vectorized")
    return make_device(kind, seed=seed, unit_seed=unit_seed, n_cores=n_cores,
                       cls=VmappedSimAccelerator, **overrides)
