"""Pluggable accelerator backends for the measurement pipeline.

Importing this package populates the registry with the built-in backends:

  simulated     SimulatedAccelerator calibrated to the paper's three GPUs
  vmapped-sim   same model, mandatory vectorized evaluation + batched
                multi-kernel passes
  cuda-nvml     real-hardware contract stub (needs pynvml + a GPU)
  trace-replay  re-execute a recorded telemetry trace offline (repro.trace)
  multi-domain-sim  independent core + uncore/memory clock ladders with
                domain-dependent and cross-domain switching latency
  pstate-sim    m1n1-style per-cluster pstate device (e-/p-core ladders,
                timelog-resolution latency sampling)
"""
from repro.backends.base import AcceleratorBackend, BackendUnavailableError
from repro.backends.registry import (BackendEntry, create_backend,
                                     get_backend, list_backends,
                                     register_backend)

# built-ins register themselves on import
from repro.backends import simulated as _simulated            # noqa: F401
from repro.backends import vmapped_sim as _vmapped_sim        # noqa: F401
from repro.backends import cuda_nvml as _cuda_nvml            # noqa: F401
from repro.trace import replay as _trace_replay               # noqa: F401
from repro.backends import multi_domain as _multi_domain      # noqa: F401
from repro.backends import pstate as _pstate                  # noqa: F401
from repro.backends.vmapped_sim import VmappedSimAccelerator
from repro.backends.cuda_nvml import CudaNvmlBackend
from repro.backends.multi_domain import MultiDomainAccelerator
from repro.backends.pstate import PStateAccelerator

__all__ = [
    "AcceleratorBackend", "BackendUnavailableError", "BackendEntry",
    "register_backend", "create_backend", "get_backend", "list_backends",
    "VmappedSimAccelerator", "CudaNvmlBackend",
    "MultiDomainAccelerator", "PStateAccelerator",
]
