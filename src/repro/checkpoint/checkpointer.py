"""Sharded checkpointing with elastic restore.

Format: one .npz per host (here: one) holding flattened leaves + a JSON
manifest (step, tree structure, shapes, dtypes).  Restore re-shards onto
whatever mesh the restoring job runs — a 512-chip checkpoint restores onto
256 chips (elastic downscale after pod loss) because leaves are saved as
full logical arrays and re-placed via NamedSharding at load.  Writes are
atomic (tmp + rename) and keep the last `keep` steps; `save_async` overlaps
serialization with the next step (thread), matching production behavior.
"""
from __future__ import annotations

import json
import os
import threading

import jax
import jax.numpy as jnp
import numpy as np


def _np_safe(x) -> np.ndarray:
    """numpy array with an npz-safe dtype (bf16 etc. widen to float32; the
    manifest + like_tree restore the true dtype)."""
    a = np.asarray(x)
    if a.dtype.kind not in "fiub" or a.dtype.name == "bfloat16":
        return a.astype(np.float32)
    return a


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------------ #
    def _paths(self, step: int) -> tuple[str, str]:
        return (os.path.join(self.dir, f"step_{step:08d}.npz"),
                os.path.join(self.dir, f"step_{step:08d}.json"))

    def save(self, step: int, tree) -> None:
        leaves, treedef = jax.tree.flatten(tree)
        arrays = [_np_safe(x) for x in leaves]
        npz, manifest = self._paths(step)
        tmp = npz + ".tmp.npz"
        np.savez(tmp, *arrays)
        os.replace(tmp, npz)
        meta = {
            "step": step,
            "treedef": str(treedef),
            "shapes": [list(a.shape) for a in arrays],
            "dtypes": [str(a.dtype) for a in arrays],
        }
        with open(manifest + ".tmp", "w") as f:
            json.dump(meta, f)
        os.replace(manifest + ".tmp", manifest)
        self._gc()

    def save_async(self, step: int, tree) -> None:
        self.wait()
        # device->host copy happens here; serialization overlaps training
        leaves, treedef = jax.tree.flatten(tree)
        arrays = [_np_safe(x) for x in leaves]

        def work():
            npz, manifest = self._paths(step)
            tmp = npz + ".tmp.npz"
            np.savez(tmp, *arrays)
            os.replace(tmp, npz)
            meta = {"step": step, "treedef": str(treedef),
                    "shapes": [list(a.shape) for a in arrays],
                    "dtypes": [str(a.dtype) for a in arrays]}
            with open(manifest + ".tmp", "w") as f:
                json.dump(meta, f)
            os.replace(manifest + ".tmp", manifest)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # ------------------------------------------------------------------ #
    def latest_step(self) -> int | None:
        steps = sorted(int(f[5:13]) for f in os.listdir(self.dir)
                       if f.startswith("step_") and f.endswith(".npz"))
        return steps[-1] if steps else None

    def restore(self, step: int, like_tree, shardings=None):
        """Restore into the structure of like_tree; if shardings (a matching
        pytree of NamedSharding) is given, leaves are placed/re-sharded onto
        the current mesh — elastic restore across mesh sizes."""
        npz, _ = self._paths(step)
        with np.load(npz) as data:
            arrays = [data[k] for k in data.files]
        leaves, treedef = jax.tree.flatten(like_tree)
        assert len(arrays) == len(leaves), "checkpoint/tree mismatch"
        out = [jnp.asarray(a).astype(ref.dtype)
               for a, ref in zip(arrays, leaves)]
        tree = jax.tree.unflatten(treedef, out)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s) if s is not None else x,
                tree, shardings)
        return tree

    def _gc(self) -> None:
        steps = sorted(int(f[5:13]) for f in os.listdir(self.dir)
                       if f.startswith("step_") and f.endswith(".npz"))
        for s in steps[: -self.keep]:
            for p in self._paths(s):
                if os.path.exists(p):
                    os.remove(p)
