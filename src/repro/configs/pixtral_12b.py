"""pixtral-12b [vlm] — mistral-nemo decoder backbone; pixtral-ViT frontend
stubbed to precomputed patch embeddings.  [hf:mistralai/Pixtral-12B-2409;
unverified]"""
from repro.configs.base import ModelConfig, VLMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="pixtral-12b", family="vlm",
        n_layers=40, d_model=5120, n_heads=32, n_kv=8, head_dim=128,
        d_ff=14336, vocab=131072, mlp="swiglu", rope_theta=1000000.0,
        vlm=VLMConfig(n_patches=256),
        source="[hf:mistralai/Pixtral-12B-2409; unverified]",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="pixtral-12b-smoke", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv=2, head_dim=16,
        d_ff=128, vocab=256, mlp="swiglu", rope_theta=1000000.0,
        vlm=VLMConfig(n_patches=8),
        attn_kv_chunk=16, attn_q_chunk=16,
    )
