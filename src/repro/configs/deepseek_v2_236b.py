"""deepseek-v2-236b [moe] — MLA (kv_lora=512) + 2 shared + 160 routed top-6.
[arXiv:2405.04434; hf]"""
from repro.configs.base import ModelConfig, MoEConfig, MLAConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b", family="mla_moe",
        n_layers=60, d_model=5120, n_heads=128, n_kv=128, head_dim=128,
        d_ff=1536, vocab=102400, mlp="swiglu", rope_theta=10000.0,
        moe=MoEConfig(n_routed=160, n_shared=2, top_k=6, d_expert=1536),
        mla=MLAConfig(kv_lora=512, q_lora=1536, dh_nope=128, dh_rope=64,
                      dh_v=128),
        source="[arXiv:2405.04434; hf]",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b-smoke", family="mla_moe",
        n_layers=3, d_model=64, n_heads=4, n_kv=4, head_dim=16,
        d_ff=48, vocab=256, mlp="swiglu", rope_theta=10000.0,
        moe=MoEConfig(n_routed=8, n_shared=2, top_k=2, d_expert=48),
        mla=MLAConfig(kv_lora=32, q_lora=48, dh_nope=16, dh_rope=8, dh_v=16),
        attn_kv_chunk=16, attn_q_chunk=16,
    )
