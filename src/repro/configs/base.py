"""Config dataclasses for the model zoo."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_routed: int
    n_shared: int
    top_k: int
    d_expert: int
    capacity_factor: float = 1.25
    norm_topk: bool = True
    first_dense: bool = True


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora: int = 512
    q_lora: int = 1536        # 0 => full-rank queries
    dh_nope: int = 128
    dh_rope: int = 64
    dh_v: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_inner: int
    headdim: int = 64
    n_state: int = 128
    chunk: int = 256
    conv_width: int = 4


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    window: int = 1024
    n_meta: int = 128


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    n_enc_layers: int
    n_frames: int = 1500
    max_dec_len: int = 32768


@dataclasses.dataclass(frozen=True)
class VLMConfig:
    n_patches: int = 256


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | mla_moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    vocab: int
    n_heads: int = 0
    n_kv: int = 0
    head_dim: int = 0
    d_ff: int = 0
    mlp: str = "swiglu"              # swiglu | relu2 | gelu
    rope_theta: float = 500000.0
    rope_fraction: float = 1.0
    qk_norm: bool = False
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    encdec: Optional[EncDecConfig] = None
    vlm: Optional[VLMConfig] = None
    aux_loss_weight: float = 0.01
    attn_kv_chunk: int = 512
    attn_q_chunk: int = 512
    param_dtype: jnp.dtype = jnp.bfloat16
    compute_dtype: jnp.dtype = jnp.bfloat16
    sub_quadratic: bool = False      # can run long_500k decode
    vocab_pad_to: int = 256          # embedding table padded for TP sharding
    source: str = ""                 # provenance note [paper; tier]

    @property
    def padded_vocab(self) -> int:
        p = self.vocab_pad_to
        return (self.vocab + p - 1) // p * p

    # ----------------------------------------------------------------- #
    def param_count(self) -> int:
        """Analytic parameter count (embedding tied)."""
        d, L = self.d_model, self.n_layers
        n = self.vocab * d                         # embed (tied unembed)
        fam = self.family

        def attn_params():
            return d * (self.n_heads + 2 * self.n_kv) * self.head_dim \
                + self.n_heads * self.head_dim * d

        def mla_params():
            a = self.mla
            q = (d * a.q_lora + a.q_lora * self.n_heads * (a.dh_nope + a.dh_rope)
                 if a.q_lora else d * self.n_heads * (a.dh_nope + a.dh_rope))
            kv = d * (a.kv_lora + a.dh_rope) \
                + a.kv_lora * self.n_heads * (a.dh_nope + a.dh_v)
            o = self.n_heads * a.dh_v * d
            return q + kv + o

        def mlp_params(ff):
            mult = 3 if self.mlp == "swiglu" else 2
            return mult * d * ff

        def moe_params():
            m = self.moe
            routed = m.n_routed * 3 * d * m.d_expert + d * m.n_routed
            shared = mlp_params(m.d_expert * m.n_shared) if m.n_shared else 0
            return routed + shared

        def ssm_params():
            s = self.ssm
            di = s.d_inner
            h = di // s.headdim
            proj = d * (2 * di + 2 * s.n_state + h)
            return proj + di * d + s.conv_width * (di + 2 * s.n_state)

        if fam in ("dense", "vlm"):
            n += L * (attn_params() + mlp_params(self.d_ff))
        elif fam == "moe":
            n += attn_params() * L + mlp_params(self.dense_ff()) \
                + (L - 1) * moe_params()
        elif fam == "mla_moe":
            n += mla_params() * L + mlp_params(self.dense_ff()) \
                + (L - 1) * moe_params()
        elif fam == "ssm":
            n += L * ssm_params()
        elif fam == "hybrid":
            n += L * (attn_params() + ssm_params() + mlp_params(self.d_ff))
            n += self.hybrid.n_meta * d
        elif fam == "encdec":
            e = self.encdec
            n += e.n_enc_layers * (attn_params() + mlp_params(self.d_ff))
            n += L * (2 * attn_params() + mlp_params(self.d_ff))
            n += e.max_dec_len * d                 # learned decoder positions
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: shared + top_k experts only)."""
        if self.family not in ("moe", "mla_moe"):
            return self.param_count()
        m = self.moe
        d, L = self.d_model, self.n_layers
        full = self.param_count()
        routed_all = (L - 1) * m.n_routed * 3 * d * m.d_expert
        routed_active = (L - 1) * m.top_k * 3 * d * m.d_expert
        return full - routed_all + routed_active

    def dense_ff(self) -> int:
        """FFN width of the dense first layer in MoE archs."""
        m = self.moe
        return m.d_expert * (m.n_shared + m.top_k)
