from repro.configs.base import (
    ModelConfig, MoEConfig, MLAConfig, SSMConfig, HybridConfig, EncDecConfig,
    VLMConfig,
)
from repro.configs.registry import ARCH_IDS, get_config, model_module, decode_module
from repro.configs.shapes import SHAPES, ShapeSpec, applicable

__all__ = [
    "ModelConfig", "MoEConfig", "MLAConfig", "SSMConfig", "HybridConfig",
    "EncDecConfig", "VLMConfig", "ARCH_IDS", "get_config", "model_module",
    "decode_module", "SHAPES", "ShapeSpec", "applicable",
]
