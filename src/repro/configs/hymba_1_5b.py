"""hymba-1.5b [hybrid] — parallel attention + mamba heads, sliding-window
attention with 3 global layers + 128 meta tokens.  [arXiv:2411.13676; hf]"""
from repro.configs.base import ModelConfig, SSMConfig, HybridConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b", family="hybrid",
        n_layers=32, d_model=1600, n_heads=25, n_kv=5, head_dim=64,
        d_ff=5504, vocab=32001, mlp="swiglu", rope_theta=10000.0,
        ssm=SSMConfig(d_inner=3200, headdim=64, n_state=16, chunk=256),
        hybrid=HybridConfig(window=1024, n_meta=128),
        sub_quadratic=True,
        source="[arXiv:2411.13676; hf]",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b-smoke", family="hybrid",
        n_layers=6, d_model=64, n_heads=4, n_kv=2, head_dim=16,
        d_ff=128, vocab=256, mlp="swiglu", rope_theta=10000.0,
        ssm=SSMConfig(d_inner=128, headdim=16, n_state=8, chunk=16),
        hybrid=HybridConfig(window=16, n_meta=8),
        sub_quadratic=True,
        attn_kv_chunk=16, attn_q_chunk=16,
    )
