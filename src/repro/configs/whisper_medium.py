"""whisper-medium [audio] — enc-dec, conv frontend stubbed to precomputed
frame embeddings.  [arXiv:2212.04356; unverified]"""
from repro.configs.base import ModelConfig, EncDecConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium", family="encdec",
        n_layers=24, d_model=1024, n_heads=16, n_kv=16, head_dim=64,
        d_ff=4096, vocab=51865, mlp="gelu",
        rope_fraction=0.0,                     # learned/sinusoidal positions
        encdec=EncDecConfig(n_enc_layers=24, n_frames=1500, max_dec_len=32768),
        source="[arXiv:2212.04356; unverified]",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium-smoke", family="encdec",
        n_layers=2, d_model=64, n_heads=4, n_kv=4, head_dim=16,
        d_ff=128, vocab=256, mlp="gelu", rope_fraction=0.0,
        encdec=EncDecConfig(n_enc_layers=2, n_frames=24, max_dec_len=64),
        attn_kv_chunk=16, attn_q_chunk=16,
    )
