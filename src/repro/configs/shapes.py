"""Assigned input-shape set (applies to every architecture).

  train_4k     seq 4096,    global_batch 256   -> lowers train_step
  prefill_32k  seq 32768,   global_batch 32    -> lowers prefill
  decode_32k   seq 32768,   global_batch 128   -> lowers decode_step (1 token,
                                                  KV cache of seq_len)
  long_500k    seq 524288,  global_batch 1     -> decode_step; requires a
                                                  sub-quadratic arch (SSM /
                                                  hybrid); skipped + documented
                                                  for full-attention archs
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def applicable(cfg, shape: ShapeSpec) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) for an (arch, shape) cell."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("full quadratic attention: O(L^2) at 524288 ctx is "
                       "infeasible by design (DESIGN.md #4); runs only for "
                       "SSM/hybrid archs")
    return True, ""
