"""Architecture registry: ``--arch <id>`` selection surface."""
from __future__ import annotations

from repro.configs import (
    whisper_medium, deepseek_moe_16b, deepseek_v2_236b, llama3_8b,
    nemotron_4_15b, chatglm3_6b, qwen3_32b, mamba2_130m, hymba_1_5b,
    pixtral_12b,
)

_MODULES = {
    "whisper-medium": whisper_medium,
    "deepseek-moe-16b": deepseek_moe_16b,
    "deepseek-v2-236b": deepseek_v2_236b,
    "llama3-8b": llama3_8b,
    "nemotron-4-15b": nemotron_4_15b,
    "chatglm3-6b": chatglm3_6b,
    "qwen3-32b": qwen3_32b,
    "mamba2-130m": mamba2_130m,
    "hymba-1.5b": hymba_1_5b,
    "pixtral-12b": pixtral_12b,
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str, smoke: bool = False):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {', '.join(ARCH_IDS)}")
    mod = _MODULES[arch]
    return mod.smoke_config() if smoke else mod.config()


def model_module(cfg):
    """Return the (init/forward/loss/prefill/decode) module for a config."""
    from repro.models import lm, encdec, decode
    if cfg.family == "encdec":
        return encdec
    return lm


def decode_module(cfg):
    from repro.models import encdec, decode
    if cfg.family == "encdec":
        return encdec
    return decode
