"""deepseek-moe-16b [moe] — 2 shared + 64 routed top-6, fine-grained experts.
[arXiv:2401.06066; hf]"""
from repro.configs.base import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b", family="moe",
        n_layers=28, d_model=2048, n_heads=16, n_kv=16, head_dim=128,
        d_ff=1408, vocab=102400, mlp="swiglu", rope_theta=10000.0,
        moe=MoEConfig(n_routed=64, n_shared=2, top_k=6, d_expert=1408),
        source="[arXiv:2401.06066; hf]",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b-smoke", family="moe",
        n_layers=3, d_model=64, n_heads=4, n_kv=4, head_dim=16,
        d_ff=48, vocab=256, mlp="swiglu", rope_theta=10000.0,
        moe=MoEConfig(n_routed=8, n_shared=2, top_k=2, d_expert=48),
        attn_kv_chunk=16, attn_q_chunk=16,
    )
