"""qwen3-32b [dense] — GQA kv=8 with per-head qk RMS-norm.
[hf:Qwen/Qwen3-8B; hf]"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-32b", family="dense",
        n_layers=64, d_model=5120, n_heads=64, n_kv=8, head_dim=128,
        d_ff=25600, vocab=151936, mlp="swiglu", rope_theta=1000000.0,
        qk_norm=True,
        source="[hf:Qwen/Qwen3-8B; hf]",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-32b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv=2, head_dim=16,
        d_ff=128, vocab=256, mlp="swiglu", rope_theta=1000000.0,
        qk_norm=True, attn_kv_chunk=16, attn_q_chunk=16,
    )
