"""nemotron-4-15b [dense] — GQA kv=8, squared-ReLU MLP.
[arXiv:2402.16819; unverified]"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-15b", family="dense",
        n_layers=32, d_model=6144, n_heads=48, n_kv=8, head_dim=128,
        d_ff=24576, vocab=256000, mlp="relu2", rope_theta=10000.0,
        source="[arXiv:2402.16819; unverified]",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-15b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv=2, head_dim=16,
        d_ff=192, vocab=256, mlp="relu2", rope_theta=10000.0,
        attn_kv_chunk=16, attn_q_chunk=16,
    )
