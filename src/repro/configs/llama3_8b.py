"""llama3-8b [dense] — GQA kv=8, 128k vocab.  [arXiv:2407.21783; unverified]"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama3-8b", family="dense",
        n_layers=32, d_model=4096, n_heads=32, n_kv=8, head_dim=128,
        d_ff=14336, vocab=128256, mlp="swiglu", rope_theta=500000.0,
        source="[arXiv:2407.21783; unverified]",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama3-8b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv=2, head_dim=16,
        d_ff=128, vocab=256, mlp="swiglu", rope_theta=500000.0,
        attn_kv_chunk=16, attn_q_chunk=16,
    )
