"""chatglm3-6b [dense] — GQA kv=2, 2d (half-dim) RoPE.  [arXiv:2406.12793; hf]"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="chatglm3-6b", family="dense",
        n_layers=28, d_model=4096, n_heads=32, n_kv=2, head_dim=128,
        d_ff=13696, vocab=65024, mlp="swiglu",
        rope_theta=10000.0, rope_fraction=0.5,
        source="[arXiv:2406.12793; hf]",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="chatglm3-6b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv=2, head_dim=16,
        d_ff=128, vocab=256, mlp="swiglu",
        rope_theta=10000.0, rope_fraction=0.5,
        attn_kv_chunk=16, attn_q_chunk=16,
    )
