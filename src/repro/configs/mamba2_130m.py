"""mamba2-130m [ssm] — SSD (state-space duality), attention-free.
[arXiv:2405.21060; unverified]"""
from repro.configs.base import ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-130m", family="ssm",
        n_layers=24, d_model=768, vocab=50280,
        ssm=SSMConfig(d_inner=1536, headdim=64, n_state=128, chunk=256),
        sub_quadratic=True,
        source="[arXiv:2405.21060; unverified]",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-130m-smoke", family="ssm",
        n_layers=2, d_model=64, vocab=256,
        ssm=SSMConfig(d_inner=128, headdim=16, n_state=16, chunk=16),
        sub_quadratic=True,
    )
