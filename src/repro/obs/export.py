"""Chrome ``trace_event`` / Perfetto export for span rows.

Produces the JSON object format (``{"traceEvents": [...]}``) that both
``chrome://tracing`` and https://ui.perfetto.dev load directly: complete
("X") events for spans, instant ("i") events for point events, and "M"
metadata events naming one process track per actor.  Timestamps are
microseconds relative to the earliest recorded instant so traces open
zoomed to the campaign rather than to the Unix epoch.

``validate_trace_events`` is the schema gate CI runs against the exported
file; it returns a list of violations (empty = valid).
"""
from __future__ import annotations

import json

_PHASES = {"X", "i", "M"}


def to_trace_events(rows: list[dict]) -> dict:
    """Span rows (as written by ``SpanRecorder``) -> trace_event document."""
    actors: list[str] = sorted({r.get("actor", "?") for r in rows})
    pid_of = {a: i + 1 for i, a in enumerate(actors)}
    t_min = min((float(r["t0"]) for r in rows), default=0.0)

    events: list[dict] = []
    for actor in actors:
        events.append({"name": "process_name", "ph": "M", "pid": pid_of[actor],
                       "tid": 0, "ts": 0,
                       "args": {"name": f"repro/{actor}"}})
    for r in rows:
        pid = pid_of[r.get("actor", "?")]
        tid = int(r.get("tid", 0))
        ts = (float(r["t0"]) - t_min) * 1e6
        args = dict(r.get("attrs") or {})
        args["sid"] = r["sid"]
        if r.get("parent"):
            args["parent"] = r["parent"]
        ev = {"name": r["name"], "cat": r.get("cat", "?"), "pid": pid,
              "tid": tid, "ts": ts, "args": args}
        if r.get("ph", "X") == "X":
            ev["ph"] = "X"
            ev["dur"] = max(0.0, (float(r["t1"]) - float(r["t0"])) * 1e6)
        else:
            ev["ph"] = "i"
            ev["s"] = "t"  # thread-scoped instant
        events.append(ev)
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def validate_trace_events(doc: dict) -> list[str]:
    """Schema check for a trace_event document; returns violations."""
    errors: list[str] = []
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        return ["document must be an object with a 'traceEvents' array"]
    events = doc["traceEvents"]
    if not isinstance(events, list):
        return ["'traceEvents' must be an array"]
    if not events:
        errors.append("'traceEvents' is empty")
    for i, ev in enumerate(events):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            errors.append(f"{where}: not an object")
            continue
        for key in ("name", "ph", "pid", "tid", "ts"):
            if key not in ev:
                errors.append(f"{where}: missing '{key}'")
        ph = ev.get("ph")
        if ph not in _PHASES:
            errors.append(f"{where}: unsupported phase {ph!r}")
        if ph == "X":
            if not isinstance(ev.get("dur"), (int, float)) or ev["dur"] < 0:
                errors.append(f"{where}: 'X' event needs dur >= 0")
        if ph == "i" and ev.get("s") not in ("g", "p", "t"):
            errors.append(f"{where}: instant event needs scope s in g/p/t")
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            errors.append(f"{where}: ts must be a non-negative number")
        if len(errors) > 20:
            errors.append("... (truncated)")
            break
    return errors


def write_trace_events(path: str, rows: list[dict]) -> dict:
    """Export rows to ``path``; raises ``ValueError`` if the produced
    document fails its own schema check (the export is a contract)."""
    doc = to_trace_events(rows)
    errors = validate_trace_events(doc)
    if errors:
        raise ValueError("invalid trace_event export: " + "; ".join(errors))
    with open(path, "w") as f:
        json.dump(doc, f, separators=(",", ":"))
    return doc
