"""``repro.obs`` — low-overhead structured span profiling for campaigns.

The package has two faces:

* an **ambient recording API** (this module): instrumentation sites call
  ``obs.span(...)`` / ``obs.event(...)`` / ``obs.ctx()`` unconditionally;
  when no recorder is installed these are near-free no-ops (one
  thread-local read), so profiling is off by default and the measurement
  hot paths are not perturbed.  ``install()`` activates a
  :class:`~repro.obs.spans.SpanRecorder` process-wide or — for the
  simulated cluster, whose "nodes" are threads of the driver process —
  thread-locally, where the thread-local recorder shadows the process
  default.
* an **analysis toolchain** (``tree``/``export``/``bridge``/``profile``):
  merge per-actor JSONL span files into one tree, walk the critical path,
  export Chrome ``trace_event`` JSON for Perfetto, and feed span-derived
  counters into the monitor's ``MetricsRegistry``.

``suppressed()`` masks recording on the current thread; the cluster node
uses it while uploading its own span file through the (instrumented)
store client, which would otherwise trace its own flushes forever.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager

from repro.obs.spans import _AMBIENT, SpanRecorder, load_span_rows

#: public alias for the "inherit the ambient parent" sentinel — pass as
#: ``parent`` when a propagated context may be absent:
#: ``obs.span(..., parent=ctx or obs.AMBIENT)``
AMBIENT = _AMBIENT
from repro.obs.tree import (SpanNode, analyze, build_forest, critical_path,
                            self_time, walk)
from repro.obs.export import (to_trace_events, validate_trace_events,
                              write_trace_events)
from repro.obs.bridge import export_to_registry

__all__ = [
    "AMBIENT", "SpanRecorder", "SpanNode", "install", "uninstall", "current",
    "enabled", "span", "event", "ctx", "suppressed", "load_span_rows",
    "build_forest", "critical_path", "self_time", "walk", "analyze",
    "to_trace_events", "validate_trace_events", "write_trace_events",
    "export_to_registry",
]

_default: SpanRecorder | None = None
_tls = threading.local()


class _Noop:
    """Reusable no-op context manager: ``with obs.span(...)`` when
    profiling is off costs two attribute lookups and no allocation."""

    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, exc_type, exc, tb):
        return False


_NOOP = _Noop()


def install(rec: SpanRecorder, *, thread_only: bool = False) -> SpanRecorder:
    """Make ``rec`` the ambient recorder — process-wide, or for this
    thread only (shadowing the process default)."""
    global _default
    if thread_only:
        _tls.rec = rec
    else:
        _default = rec
    return rec


def uninstall(*, thread_only: bool = False) -> None:
    global _default
    if thread_only:
        _tls.rec = None
    else:
        _default = None


def current() -> SpanRecorder | None:
    """The ambient recorder, or ``None`` when profiling is off or
    suppressed on this thread."""
    if getattr(_tls, "suppress", 0):
        return None
    rec = getattr(_tls, "rec", None)
    return rec if rec is not None else _default


def enabled() -> bool:
    return current() is not None


def span(name: str, cat: str, parent=_AMBIENT, **attrs):
    """Ambient lexical span; a shared no-op context manager when off."""
    rec = current()
    if rec is None:
        return _NOOP
    return rec.span(name, cat, parent, **attrs)


def event(name: str, cat: str, parent=_AMBIENT, **attrs) -> str | None:
    rec = current()
    if rec is None:
        return None
    return rec.event(name, cat, parent, **attrs)


def ctx() -> str | None:
    """Trace context (current span id) to propagate across task messages
    and node envelopes; ``None`` when profiling is off."""
    rec = current()
    return rec.ctx() if rec is not None else None


@contextmanager
def suppressed():
    """Mask recording on this thread (anti-self-tracing guard)."""
    _tls.suppress = getattr(_tls, "suppress", 0) + 1
    try:
        yield
    finally:
        _tls.suppress -= 1
