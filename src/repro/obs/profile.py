"""Campaign-level profile assembly: span files -> merged tree -> report.

``profile_campaign`` is the engine behind ``campaign profile <id>``: it
merges every per-actor span file recorded under the campaign directory,
runs the critical-path analyzer, cross-references dead-letter entries
(which carry the span id active when the op exhausted its retries), and
returns one JSON-ready document.  ``profile_markdown`` renders it for
humans.
"""
from __future__ import annotations

import json
import os

from repro.obs.spans import load_span_rows
from repro.obs.tree import analyze, build_forest


def collect_span_rows(campaign) -> list[dict]:
    rows: list[dict] = []
    for path in campaign.list_span_files():
        rows.extend(load_span_rows(path))
    return rows


def collect_dead_letters(campaign) -> list[dict]:
    """Every dead-letter doc recorded for this campaign (driver + nodes),
    tagged with the file it came from."""
    docs: list[dict] = []
    d = campaign.deadletter_dir()
    if not os.path.isdir(d):
        return docs
    for name in sorted(os.listdir(d)):
        if not name.endswith(".jsonl"):
            continue
        with open(os.path.join(d, name)) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    doc = json.loads(line)
                except json.JSONDecodeError:
                    continue
                doc["source"] = name[: -len(".jsonl")]
                docs.append(doc)
    return docs


def profile_campaign(campaign) -> dict:
    """Merged span tree + critical path + dead-letter cross-references."""
    rows = collect_span_rows(campaign)
    doc = analyze(build_forest(rows))
    doc["campaign_id"] = campaign.campaign_id
    doc["name"] = campaign.spec.name
    doc["span_files"] = [os.path.basename(p)
                         for p in campaign.list_span_files()]

    crit_sids = ({seg["sid"] for seg in doc["critical_path"]["segments"]}
                 if "critical_path" in doc else set())
    letters = []
    for dl in collect_dead_letters(campaign):
        sid = dl.get("span")
        letters.append({
            "op": dl.get("op"), "key": dl.get("key"),
            "attempts": dl.get("attempts"), "error": dl.get("error"),
            "source": dl.get("source"), "span": sid,
            "elapsed_s": dl.get("elapsed_s"),
            "on_critical_path": bool(sid and sid in crit_sids),
        })
    doc["dead_letters"] = letters
    return doc


def _fmt_s(seconds) -> str:
    return "-" if seconds is None else f"{float(seconds):.3f}s"


def profile_markdown(doc: dict) -> str:
    """Human-readable cost breakdown for ``campaign profile``."""
    lines = [f"# Campaign profile — {doc.get('name', '?')} "
             f"(`{doc.get('campaign_id', '?')}`)", ""]
    if doc.get("empty") or "root" not in doc:
        lines.append("No spans recorded. Re-run with `campaign run --spans`.")
        return "\n".join(lines) + "\n"

    root = doc["root"]
    lines += [
        f"- wall time: **{_fmt_s(root['wall_s'])}** "
        f"(root span `{root['name']}`)",
        f"- spans: {doc['spans']}  ·  events: {doc['events']}  ·  "
        f"actors: {', '.join(doc['actors'])}",
        "",
    ]

    dom = doc.get("dominant")
    if dom:
        lines += [
            "## Dominant cost",
            "",
            f"**{dom['label']}** — {_fmt_s(dom['seconds'])} "
            f"({dom['frac'] * 100.0:.1f}% of the critical path), "
            f"led by span `{dom['span']['name']}` "
            f"[`{dom['span']['sid']}`]"
            + (f" on unit `{dom['span']['unit']}`"
               if dom["span"].get("unit") else ""),
            "",
        ]

    crit = doc["critical_path"]
    lines += ["## Critical path by category", "",
              "| category | seconds | share |", "| --- | ---: | ---: |"]
    total = crit["total_s"] or 1.0
    for cat, sec in crit["by_category"].items():
        lines.append(f"| {cat} | {sec:.3f} | {sec / total * 100.0:.1f}% |")
    lines.append("")

    if doc.get("self_time_top"):
        lines += ["## Top spans by self time", "",
                  "| span | cat | actor | unit | self time |",
                  "| --- | --- | --- | --- | ---: |"]
        for row in doc["self_time_top"]:
            lines.append(f"| `{row['name']}` | {row['cat']} | {row['actor']} "
                         f"| {row.get('unit') or '-'} "
                         f"| {_fmt_s(row['seconds'])} |")
        lines.append("")

    if doc.get("event_counts"):
        lines += ["## Event counters", "",
                  "| event | count |", "| --- | ---: |"]
        for name in sorted(doc["event_counts"]):
            lines.append(f"| `{name}` | {doc['event_counts'][name]} |")
        lines.append("")

    if doc.get("dead_letters"):
        lines += ["## Dead letters (cross-referenced to spans)", "",
                  "| op | key | attempts | elapsed | span | on critical path |",
                  "| --- | --- | ---: | ---: | --- | --- |"]
        for dl in doc["dead_letters"]:
            lines.append(
                f"| `{dl['op']}` | {dl['key'] or '-'} | {dl['attempts']} "
                f"| {_fmt_s(dl.get('elapsed_s'))} "
                f"| `{dl['span'] or '-'}` "
                f"| {'yes' if dl['on_critical_path'] else 'no'} |")
        lines.append("")

    return "\n".join(lines) + "\n"
