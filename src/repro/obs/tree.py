"""Span-tree assembly and critical-path / self-time analysis.

``build_forest`` stitches rows from any number of per-actor span files into
trees via the propagated parent ids.  On top of the tree:

* ``self_time``   — span duration minus the union of its children's
  intervals (overlapping children, e.g. concurrent unit attempts under the
  campaign root, are interval-merged, not double-counted).
* ``critical_path`` — Jaeger-style backward walk from the root's end: at
  any instant the walk attributes time to the deepest span that was
  actually running, producing segments that tile the root interval exactly
  (their durations sum to the root's wall time by construction).
* ``analyze``     — aggregates critical-path time per category and names
  the dominant cost in operator terms ("straggler unit …", "remote-store
  retries …", "scheduler idle").
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass
class SpanNode:
    sid: str
    parent: str | None
    actor: str
    name: str
    cat: str
    t0: float
    t1: float
    tid: int = 0
    ph: str = "X"
    attrs: dict = field(default_factory=dict)
    children: list["SpanNode"] = field(default_factory=list)
    up: "SpanNode | None" = None  # parent backlink (None for roots)

    @property
    def duration(self) -> float:
        return self.t1 - self.t0


@dataclass
class Segment:
    """One critical-path slice: ``node`` was the deepest running span over
    ``[t0, t1]``."""
    node: SpanNode
    t0: float
    t1: float

    @property
    def duration(self) -> float:
        return self.t1 - self.t0


def build_forest(rows: list[dict]) -> list[SpanNode]:
    """Rows (dicts as written by ``SpanRecorder``) -> list of root nodes,
    sorted by start time.  Rows whose parent id is unknown (its actor's
    file was lost) become roots; children are clamped into their parent's
    interval so cross-process clock skew cannot break nesting."""
    nodes: dict[str, SpanNode] = {}
    for r in rows:
        node = SpanNode(sid=r["sid"], parent=r.get("parent"),
                        actor=r.get("actor", "?"), name=r["name"],
                        cat=r.get("cat", "?"), t0=float(r["t0"]),
                        t1=float(r["t1"]), tid=int(r.get("tid", 0)),
                        ph=r.get("ph", "X"), attrs=r.get("attrs") or {})
        if node.t1 < node.t0:
            node.t1 = node.t0
        nodes[node.sid] = node
    roots: list[SpanNode] = []
    for node in nodes.values():
        parent = nodes.get(node.parent) if node.parent else None
        if parent is None or parent is node:
            roots.append(node)
        else:
            parent.children.append(node)
            node.up = parent
    # clamp children into parents top-down so nesting is exact
    def _clamp(n: SpanNode) -> None:
        for c in n.children:
            c.t0 = min(max(c.t0, n.t0), n.t1)
            c.t1 = max(min(c.t1, n.t1), c.t0)
            _clamp(c)
    for root in roots:
        root.children.sort(key=lambda c: (c.t0, c.sid))
        _clamp(root)
    for node in nodes.values():
        node.children.sort(key=lambda c: (c.t0, c.sid))
    roots.sort(key=lambda n: (n.t0, n.sid))
    return roots


def walk(node: SpanNode):
    yield node
    for c in node.children:
        yield from walk(c)


def _interval_union(intervals: list[tuple[float, float]]) -> float:
    if not intervals:
        return 0.0
    intervals = sorted(intervals)
    total = 0.0
    cur0, cur1 = intervals[0]
    for a, b in intervals[1:]:
        if a > cur1:
            total += cur1 - cur0
            cur0, cur1 = a, b
        else:
            cur1 = max(cur1, b)
    return total + (cur1 - cur0)


def self_time(node: SpanNode) -> float:
    """Span duration not covered by any child interval."""
    kids = [(c.t0, c.t1) for c in node.children if c.ph == "X" and c.t1 > c.t0]
    return max(0.0, node.duration - _interval_union(kids))


def critical_path(root: SpanNode) -> list[Segment]:
    """Backward walk from ``root.t1``: repeatedly find the child that was
    running latest before the cursor, attribute the gap to the current
    span, recurse into that child, and continue from the child's start.
    The returned segments tile ``[root.t0, root.t1]``."""
    segments: list[Segment] = []

    def _walk(node: SpanNode, t_end: float) -> None:
        cursor = t_end
        # children that could contribute, latest-ending first
        kids = sorted((c for c in node.children if c.ph == "X"),
                      key=lambda c: (c.t1, c.t0))
        while cursor > node.t0:
            running = None
            while kids:
                c = kids[-1]
                if c.t0 >= cursor:
                    kids.pop()
                    continue
                running = c
                break
            if running is None:
                segments.append(Segment(node, node.t0, cursor))
                return
            kids.pop()
            child_end = min(running.t1, cursor)
            if child_end < cursor:
                segments.append(Segment(node, child_end, cursor))
            _walk(running, child_end)
            cursor = min(cursor, running.t0)
        # nothing left of this span

    _walk(root, root.t1)
    segments.reverse()
    return segments


_CAT_LABELS = {
    "campaign": "scheduler idle / orchestration",
    "unit": "unit orchestration",
    "sched": "dispatch & queueing",
    "exec": "unit execution",
    "pair": "pair measurement",
    "cal": "calibration",
    "store": "remote-store ops (retries / partition healing)",
    "msg": "transport messaging",
    "gov": "governor planning",
}


def unit_of(node: SpanNode) -> str | None:
    """Nearest ``unit`` attribute on the node or its ancestors."""
    cur: SpanNode | None = node
    while cur is not None:
        unit = cur.attrs.get("unit")
        if unit:
            return str(unit)
        cur = cur.up
    return None


def _dominant_label(cat: str, top: SpanNode | None) -> str:
    unit = unit_of(top) if top is not None else None
    if cat in ("exec", "pair", "cal"):
        base = _CAT_LABELS.get(cat, cat)
        return f"straggler unit {unit} ({base})" if unit else base
    if cat == "store":
        op = top.name if top is not None else "store op"
        suffix = f" on unit {unit}" if unit else ""
        return f"remote-store retries / partition healing ({op}{suffix})"
    if cat in ("campaign", "sched"):
        return "scheduler idle / dispatch overhead"
    return _CAT_LABELS.get(cat, cat)


def analyze(roots: list[SpanNode]) -> dict:
    """Full profile document for a span forest.

    The campaign root is the longest-duration root (campaign runs have
    exactly one; orphaned subtrees from lost files rank behind it)."""
    if not roots:
        return {"empty": True, "spans": 0}
    root = max(roots, key=lambda n: n.duration)
    segments = critical_path(root)

    by_cat: dict[str, float] = {}
    top_by_cat: dict[str, tuple[float, SpanNode]] = {}
    span_crit: dict[str, float] = {}
    for seg in segments:
        cat = seg.node.cat
        by_cat[cat] = by_cat.get(cat, 0.0) + seg.duration
        span_crit[seg.node.sid] = span_crit.get(seg.node.sid, 0.0) + seg.duration
        best = top_by_cat.get(cat)
        if best is None or span_crit[seg.node.sid] > best[0]:
            top_by_cat[cat] = (span_crit[seg.node.sid], seg.node)

    wall = root.duration
    dom_cat = max(by_cat, key=lambda c: by_cat[c]) if by_cat else None
    dom_top = top_by_cat[dom_cat][1] if dom_cat else None

    all_nodes = [n for r in roots for n in walk(r)]
    spans = [n for n in all_nodes if n.ph == "X"]
    events = [n for n in all_nodes if n.ph != "X"]
    self_top = sorted(((self_time(n), n) for n in spans),
                      key=lambda p: -p[0])[:10]

    counters: dict[str, int] = {}
    for ev in events:
        counters[ev.name] = counters.get(ev.name, 0) + 1

    def _node_doc(n: SpanNode, seconds: float) -> dict:
        return {"sid": n.sid, "name": n.name, "cat": n.cat, "actor": n.actor,
                "seconds": seconds, "unit": unit_of(n), "attrs": n.attrs}

    return {
        "root": {"sid": root.sid, "name": root.name, "wall_s": wall,
                 "attrs": root.attrs},
        "spans": len(spans),
        "events": len(events),
        "actors": sorted({n.actor for n in all_nodes}),
        "critical_path": {
            "total_s": sum(s.duration for s in segments),
            "by_category": {c: by_cat[c]
                            for c in sorted(by_cat, key=lambda c: -by_cat[c])},
            "segments": [{"sid": s.node.sid, "name": s.node.name,
                          "cat": s.node.cat, "t0": s.t0, "t1": s.t1,
                          "seconds": s.duration} for s in segments],
        },
        "dominant": None if dom_cat is None else {
            "cat": dom_cat,
            "seconds": by_cat[dom_cat],
            "frac": (by_cat[dom_cat] / wall) if wall > 0 else 1.0,
            "span": _node_doc(dom_top, span_crit.get(dom_top.sid, 0.0)),
            "label": _dominant_label(dom_cat, dom_top),
        },
        "self_time_top": [_node_doc(n, s) for s, n in self_top if s > 0],
        "event_counts": counters,
    }
