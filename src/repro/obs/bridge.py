"""Bridge from span rows into ``monitor.metrics.MetricsRegistry``.

Campaign profiles and fleet dashboards share one exporter: the spans
recorded by ``repro.obs`` are folded into the same Prometheus/JSON
registry the drift monitor already serves, so scheduler health (queue
depth, requeues, store retry totals, per-stage time) shows up next to
drift alerts without a second metrics stack.
"""
from __future__ import annotations

from typing import TYPE_CHECKING

if TYPE_CHECKING:  # import at use time: obs is imported by core/session,
    # and pulling the monitor package (-> campaign.regression) in at
    # module load would cycle back through the core layers
    from repro.monitor.metrics import MetricsRegistry

# per-stage wall-time buckets: orchestration spans span ~100us .. minutes
_STAGE_BUCKETS = (0.001, 0.005, 0.02, 0.1, 0.5, 2.0, 10.0, 60.0)

# instant-event name -> counter it feeds
_EVENT_COUNTERS = {
    "sched.requeue": ("obs_requeued_units_total",
                      "unit attempts requeued after worker loss/timeout"),
    "sched.speculate": ("obs_speculative_dispatches_total",
                        "speculative (straggler-hedge) dispatches"),
    "sched.worker_lost": ("obs_workers_lost_total",
                          "workers/nodes declared dead by the heartbeat reaper"),
    "store.retry": ("obs_store_retries_total",
                    "remote-store op retries (transient failures + partitions)"),
    "gov.plan": ("obs_governor_plans_total",
                 "governor frequency-plan decisions"),
}


def export_to_registry(rows: list[dict],
                       registry: "MetricsRegistry | None" = None
                       ) -> "MetricsRegistry":
    """Fold span rows into a metrics registry and return it."""
    from repro.monitor.metrics import MetricsRegistry
    reg = registry if registry is not None else MetricsRegistry()

    stage = reg.histogram(
        "obs_stage_seconds",
        "wall seconds per orchestration span, labelled by category",
        buckets=_STAGE_BUCKETS)
    spans_total = reg.counter("obs_spans_total",
                              "spans recorded, labelled by category")
    events_total = reg.counter("obs_events_total",
                               "instant events recorded, labelled by name")
    msgs = reg.counter("obs_msgs_total",
                       "transport messages, labelled by direction")
    queue_depth = reg.gauge("obs_queue_depth",
                            "pending work-queue depth at last dispatch")
    queue_peak = reg.gauge("obs_queue_depth_peak",
                           "maximum observed pending work-queue depth")

    peak = 0.0
    for r in rows:
        cat = r.get("cat", "?")
        attrs = r.get("attrs") or {}
        if r.get("ph", "X") == "X":
            spans_total.inc(cat=cat)
            stage.observe(max(0.0, float(r["t1"]) - float(r["t0"])), cat=cat)
        else:
            name = r["name"]
            events_total.inc(name=name)
            hit = _EVENT_COUNTERS.get(name)
            if hit is not None:
                reg.counter(*hit).inc()
            if name in ("msg.send", "msg.recv"):
                msgs.inc(direction=name.split(".", 1)[1])
        if "queue" in attrs:
            depth = float(attrs["queue"])
            queue_depth.set(depth)
            peak = max(peak, depth)
    queue_peak.set(peak)
    return reg
