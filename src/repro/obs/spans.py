"""Structured span recorder for orchestration profiling.

A :class:`SpanRecorder` captures *spans* (named wall-clock intervals with a
parent link and a small attribute dict) and *instant events* into a chunked
append-only arena — the same growth discipline as ``trace.recorder``'s
columnar ``_Arena``, scaled down to orchestration rates (hundreds of spans
per campaign, not millions of samples).  Rows drain to an append-only JSONL
file per process/actor so a crash loses at most one unflushed chunk and
files from different actors merge by concatenation.

Span ids are ``"<actor>:<seq>"`` and are globally unique as long as actor
names are (the campaign layer names actors ``driver``, ``worker<N>``,
``node-<id>``).  Parent links may cross actors: the driver propagates its
active span id ("trace context") inside task messages and node envelopes,
and the receiving side opens its spans with ``parent=ctx`` so the merged
rows stitch into one tree.

Clocks: all timestamps are absolute wall seconds from a shared epoch
(``time.time() - time.perf_counter()`` captured once per recorder), so rows
recorded by different processes on one host line up to clock-sync error.
Tests inject a deterministic ``clock`` callable instead.

Recording is allocation-light but not free; the ambient helpers in
``repro.obs`` are the zero-cost path when profiling is off.
"""
from __future__ import annotations

import json
import os
import threading
import time

_CHUNK = 512

_AMBIENT = object()  # sentinel: "parent = whatever span is on this thread"


class _Arena:
    """Fixed-size-chunk append arena.  Rows land in a preallocated chunk;
    full chunks are sealed and new ones opened, so steady-state appends
    never resize a list the interpreter has to copy."""

    __slots__ = ("_sealed", "_chunk", "_fill")

    def __init__(self):
        self._sealed: list[list] = []
        self._chunk: list = [None] * _CHUNK
        self._fill = 0

    def append(self, row) -> None:
        self._chunk[self._fill] = row
        self._fill += 1
        if self._fill == _CHUNK:
            self._sealed.append(self._chunk)
            self._chunk = [None] * _CHUNK
            self._fill = 0

    def __len__(self) -> int:
        return len(self._sealed) * _CHUNK + self._fill

    def drain(self) -> list:
        out: list = []
        for chunk in self._sealed:
            out.extend(chunk)
        out.extend(self._chunk[: self._fill])
        self._sealed = []
        self._fill = 0
        return out


class _LiveSpan:
    """Handle for an open span.  ``attrs`` may be mutated while the span is
    open (e.g. a store op sets its final ``attempts`` count just before the
    span closes); the dict is serialized at ``end`` time."""

    __slots__ = ("sid", "parent", "name", "cat", "t0", "tid", "attrs")

    def __init__(self, sid, parent, name, cat, t0, tid, attrs):
        self.sid = sid
        self.parent = parent
        self.name = name
        self.cat = cat
        self.t0 = t0
        self.tid = tid
        self.attrs = attrs


class _SpanCtx:
    """Lexical ``with`` wrapper around begin/end that maintains the
    per-thread ambient parent stack."""

    __slots__ = ("_rec", "_live")

    def __init__(self, rec, live):
        self._rec = rec
        self._live = live

    def __enter__(self) -> _LiveSpan:
        self._rec._stack().append(self._live.sid)
        return self._live

    def __exit__(self, exc_type, exc, tb) -> bool:
        stack = self._rec._stack()
        if stack and stack[-1] == self._live.sid:
            stack.pop()
        if exc_type is not None:
            self._live.attrs["error"] = exc_type.__name__
        self._rec.end(self._live)
        return False


class SpanRecorder:
    """Append-only span/event recorder for one actor (process or thread).

    Thread-safe: node threads in the simulated cluster share the driver
    process, so each installs its own recorder thread-locally, but a single
    recorder also tolerates concurrent use (the arena and seq counter are
    lock-protected; parent stacks are per-thread).
    """

    def __init__(self, actor: str, path: str | None = None, *,
                 clock=None, flush_every: int = _CHUNK):
        self.actor = str(actor)
        self.path = path
        if clock is None:
            epoch = time.time() - time.perf_counter()
            clock = lambda: epoch + time.perf_counter()  # noqa: E731
        self._clock = clock
        self._arena = _Arena()
        self._flushed: list[dict] = []
        self._lock = threading.Lock()
        self._seq = 0
        self._flush_every = int(flush_every)
        self._tids: dict[int, int] = {}
        self._local = threading.local()
        if path:
            os.makedirs(os.path.dirname(path) or ".", exist_ok=True)

    # -- internals ---------------------------------------------------------

    def _stack(self) -> list:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    def _next_sid(self) -> str:
        with self._lock:
            self._seq += 1
            return f"{self.actor}:{self._seq}"

    def _tid(self) -> int:
        ident = threading.get_ident()
        tid = self._tids.get(ident)
        if tid is None:
            with self._lock:
                tid = self._tids.setdefault(ident, len(self._tids) + 1)
        return tid

    def _resolve_parent(self, parent):
        if parent is _AMBIENT:
            stack = self._stack()
            return stack[-1] if stack else None
        return parent

    def _append(self, row: dict) -> None:
        with self._lock:
            self._arena.append(row)
            full = len(self._arena) >= self._flush_every
        if full:
            self.flush()

    # -- recording API -----------------------------------------------------

    def now(self) -> float:
        return float(self._clock())

    def span(self, name: str, cat: str, parent=_AMBIENT, **attrs) -> _SpanCtx:
        """Lexical span: ``with rec.span("unit.exec", "exec", unit=key):``."""
        return _SpanCtx(self, self.begin(name, cat, parent, **attrs))

    def begin(self, name: str, cat: str, parent=_AMBIENT, **attrs) -> _LiveSpan:
        """Open a non-lexical span (e.g. a unit attempt that outlives the
        scheduler loop iteration that dispatched it).  Does NOT touch the
        ambient parent stack; pair with :meth:`end`."""
        return _LiveSpan(self._next_sid(), self._resolve_parent(parent),
                         name, cat, self.now(), self._tid(), dict(attrs))

    def end(self, live: _LiveSpan, **attrs) -> str:
        if attrs:
            live.attrs.update(attrs)
        row = {"sid": live.sid, "parent": live.parent, "actor": self.actor,
               "name": live.name, "cat": live.cat, "ph": "X",
               "tid": live.tid, "t0": live.t0, "t1": self.now()}
        if live.attrs:
            row["attrs"] = live.attrs
        self._append(row)
        return live.sid

    def event(self, name: str, cat: str, parent=_AMBIENT, **attrs) -> str:
        """Instant event (zero-duration point on the timeline)."""
        sid = self._next_sid()
        t = self.now()
        row = {"sid": sid, "parent": self._resolve_parent(parent),
               "actor": self.actor, "name": name, "cat": cat, "ph": "i",
               "tid": self._tid(), "t0": t, "t1": t}
        if attrs:
            row["attrs"] = attrs
        self._append(row)
        return sid

    def ctx(self) -> str | None:
        """Current span id on this thread — the trace context to propagate
        into task messages / node envelopes."""
        stack = self._stack()
        return stack[-1] if stack else None

    # -- draining ----------------------------------------------------------

    def flush(self) -> None:
        """Drain the arena: append to the JSONL file (if any) and keep an
        in-memory copy for same-process analysis."""
        with self._lock:
            rows = self._arena.drain()
            if not rows:
                return
            self._flushed.extend(rows)
            if self.path:
                with open(self.path, "a") as f:
                    for row in rows:
                        f.write(json.dumps(row, separators=(",", ":")))
                        f.write("\n")

    def rows(self) -> list[dict]:
        """All recorded rows (flushes first)."""
        self.flush()
        with self._lock:
            return list(self._flushed)

    def close(self) -> None:
        self.flush()


def load_span_rows(path: str) -> list[dict]:
    """Read one actor's JSONL span file; tolerates a torn final line (the
    actor may have crashed mid-append — that is exactly when profiles are
    most interesting)."""
    rows: list[dict] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                rows.append(json.loads(line))
            except json.JSONDecodeError:
                continue
    return rows
