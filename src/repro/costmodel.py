"""Jaxpr-level cost model.

XLA's ``compiled.cost_analysis()`` counts while/scan bodies ONCE (verified in
tests/test_costmodel.py), which silently undercounts scan-over-layers models
by ~n_layers.  This walker multiplies through ``lax.scan`` trip counts
exactly, giving the FLOP/byte numbers the roofline terms use.

Conventions:
  * FLOPs: 2*B*M*N*K per dot_general; elementwise ops counted at 1 flop per
    output element (they are VPU work, not MXU, but contribute to the
    compute term at the same peak for bf16 on v5e-class chips only via the
    vector unit — we keep them so fp32 SSD scans are visible).
  * Bytes: HBM-traffic proxy = operand + result bytes of data-moving ops
    (dot_general, gather/scatter, dynamic slices, conv, reduce, carried scan
    state) — elementwise ops are assumed fused (free).  This is a *model*,
    not a measurement; EXPERIMENTS.md reports it alongside XLA's
    fusion-aware-but-loop-blind "bytes accessed".
  * while loops count their body once (documented limitation; the code base
    avoids while for hot loops — triangular prefill uses a static-length
    pair scan precisely so it is countable).
  * Numbers are GLOBAL (pre-SPMD); callers divide by mesh size.  TP-
    replicated small projections are therefore slightly undercounted
    per-chip (documented in EXPERIMENTS.md).
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

_ELEMENTWISE = {
    "add", "sub", "mul", "div", "max", "min", "exp", "log", "tanh", "logistic",
    "rsqrt", "sqrt", "neg", "abs", "floor", "ceil", "round", "sign", "pow",
    "integer_pow", "select_n", "compare", "and", "or", "not", "xor",
    "convert_element_type", "erf", "cos", "sin",
}
_DATA_MOVERS = {
    "gather", "scatter", "scatter-add", "scatter_add", "dynamic_slice",
    "dynamic_update_slice", "concatenate", "pad", "reshape", "transpose",
    "broadcast_in_dim", "reduce_sum", "reduce_max", "reduce_min", "argmax",
    "argmin", "sort", "iota", "rev", "cumsum", "cumlogsumexp", "cummax",
    "take", "conv_general_dilated", "reduce_and", "reduce_or", "top_k",
    "select_and_scatter_add", "slice", "squeeze",
}
_CHEAP_MOVERS = {"reshape", "transpose", "broadcast_in_dim", "iota", "slice",
                 "squeeze"}  # usually layout no-ops / fused

_CALL_PRIMS = {"pjit", "closed_call", "core_call", "remat_call", "remat",
               "remat2", "custom_jvp_call", "custom_vjp_call",
               "custom_vjp_call_jaxpr", "checkpoint", "named_call",
               "shard_map", "smap"}


def _nbytes(aval) -> int:
    try:
        return int(np.prod(aval.shape, dtype=np.int64)) * aval.dtype.itemsize
    except Exception:
        return 0


def _size(aval) -> int:
    try:
        return int(np.prod(aval.shape, dtype=np.int64))
    except Exception:
        return 0


@dataclasses.dataclass
class CostStats:
    flops: float = 0.0            # MXU (dot) flops
    vector_flops: float = 0.0     # elementwise flops
    bytes: float = 0.0            # no-fusion HBM traffic (upper bound)
    bytes_fused: float = 0.0      # fusion-aware HBM traffic (roofline input)
    dot_bytes: float = 0.0
    while_bodies: int = 0         # loops counted once (should stay tiny)

    @property
    def total_flops(self) -> float:
        return self.flops + self.vector_flops

    def as_dict(self) -> dict:
        return {"flops": self.flops, "vector_flops": self.vector_flops,
                "bytes": self.bytes, "bytes_fused": self.bytes_fused,
                "dot_bytes": self.dot_bytes,
                "while_bodies": self.while_bodies}


def _dot_flops(eqn) -> float:
    (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    b = 1
    for d in lb:
        b *= lhs.shape[d]
    k = 1
    for d in lc:
        k *= lhs.shape[d]
    m = 1
    for i, d in enumerate(lhs.shape):
        if i not in lc and i not in lb:
            m *= d
    n = 1
    for i, d in enumerate(rhs.shape):
        if i not in rc and i not in rb:
            n *= d
    return 2.0 * b * m * n * k


def _walk(jaxpr, scale: float, st: CostStats):
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "scan":
            ln = eqn.params["length"]
            inner = eqn.params["jaxpr"].jaxpr
            # fusion-aware HBM model: one scan execution reads its stacked xs
            # once (e.g. per-layer weights), reads+writes the carry at the
            # boundary, and writes its stacked ys once.  Intermediates inside
            # a step are VMEM-resident (this is precisely the schedule the
            # Pallas kernels implement); gather/scatter/DUS inside still add
            # their slice traffic per trip below.
            nc = eqn.params.get("num_consts", 0)
            ncar = eqn.params.get("num_carry", 0)
            consts = eqn.invars[:nc]
            carry = eqn.invars[nc: nc + ncar]
            xs = eqn.invars[nc + ncar:]
            ys = eqn.outvars[ncar:]
            st.bytes_fused += scale * (
                sum(_nbytes(v.aval) for v in consts)
                + 2 * sum(_nbytes(v.aval) for v in carry)
                + sum(_nbytes(v.aval) for v in xs)
                + sum(_nbytes(v.aval) for v in ys))
            _walk(inner, scale * ln, st)
        elif name == "while":
            st.while_bodies += 1
            _walk(eqn.params["body_jaxpr"].jaxpr, scale, st)
        elif name == "cond":
            for br in eqn.params["branches"]:
                _walk(br.jaxpr, scale, st)
        elif name in _CALL_PRIMS:
            sub = (eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
                   or eqn.params.get("fun_jaxpr"))
            if sub is not None:
                _walk(sub.jaxpr if hasattr(sub, "jaxpr") else sub, scale, st)
        elif name == "dot_general":
            f = _dot_flops(eqn)
            st.flops += scale * f
            io = sum(_nbytes(v.aval) for v in eqn.invars) \
                + sum(_nbytes(v.aval) for v in eqn.outvars)
            st.bytes += scale * io
            st.dot_bytes += scale * io
        elif name in _ELEMENTWISE or name.startswith("reduce_precision"):
            st.vector_flops += scale * max(
                (_size(v.aval) for v in eqn.outvars), default=0)
        elif name in _DATA_MOVERS:
            if name in _CHEAP_MOVERS:
                continue
            if name == "dynamic_slice":
                # reads only the slice, not the whole operand
                io = sum(_nbytes(v.aval) for v in eqn.outvars)
            elif name == "dynamic_update_slice":
                # read+write of the updated region (in-place on TPU/XLA)
                io = 2 * _nbytes(eqn.invars[1].aval)
            elif name in ("gather", "take"):
                io = 2 * sum(_nbytes(v.aval) for v in eqn.outvars)
            elif name.startswith("scatter"):
                upd = eqn.invars[2].aval if len(eqn.invars) > 2 else eqn.invars[-1].aval
                io = 3 * _nbytes(upd)        # read dst, read upd, write dst
            else:
                io = sum(_nbytes(v.aval) for v in eqn.invars) \
                    + sum(_nbytes(v.aval) for v in eqn.outvars)
            st.bytes += scale * io
            if name in ("gather", "take", "dynamic_slice",
                        "dynamic_update_slice") or name.startswith("scatter"):
                st.bytes_fused += scale * io
            if name in ("reduce_sum", "reduce_max", "reduce_min", "cumsum"):
                st.vector_flops += scale * max(
                    (_size(v.aval) for v in eqn.invars), default=0)
        else:
            # unknown primitive: count result bytes conservatively
            st.bytes += scale * sum(_nbytes(v.aval) for v in eqn.outvars)


def cost_of(fn, *args) -> CostStats:
    """Trace fn abstractly and return scan-exact global cost stats."""
    closed = jax.make_jaxpr(fn)(*args)
    st = CostStats()
    _walk(closed.jaxpr, 1.0, st)
    # program inputs/outputs touch HBM once
    io = sum(_nbytes(v.aval) for v in closed.jaxpr.invars) \
        + sum(_nbytes(v.aval) for v in closed.jaxpr.outvars)
    st.bytes += io
    st.bytes_fused += io
    return st


def xla_cost_analysis(compiled) -> dict:
    """Normalize ``compiled.cost_analysis()`` across jax versions: older
    releases return a one-element list of dicts, newer ones the dict
    itself (or None when the backend provides nothing)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}
