"""Training launcher:  PYTHONPATH=src python -m repro.launch.train \
    --arch llama3-8b --smoke --steps 50 [--governor a100]"""
from __future__ import annotations

import argparse

from repro.configs import ARCH_IDS, get_config
from repro.configs.shapes import ShapeSpec
from repro.launch.mesh import make_smoke_mesh
from repro.parallel.sharding import make_env
from repro.runtime.train_loop import TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="llama3-8b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--governor", choices=("a100", "gh200", "rtx6000"),
                    default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    shape = ShapeSpec("cli", args.seq, args.batch, "train")
    mesh = make_smoke_mesh() if args.smoke else None
    env = make_env(cfg, mesh)

    governor = device = regions = None
    if args.governor:
        from repro.core.latest import run_latest, LatestConfig
        from repro.core.evaluation import MeasureConfig
        from repro.dvfs import make_device, PowerModel
        from repro.dvfs.governor import Governor
        from repro.dvfs.planner import Region
        device = make_device(args.governor, seed=0, n_cores=8)
        freqs = list(device.cfg.frequencies[:: max(1, len(device.cfg.frequencies) // 4)])[:4]
        table = run_latest(device, freqs, LatestConfig(
            measure=MeasureConfig(min_measurements=5, max_measurements=5)))
        governor = Governor(table, PowerModel(f_max_mhz=max(freqs)), freqs)
        regions = [Region("compute", 0.5), Region("collective", 0.2),
                   Region("host", 0.05)]

    tc = TrainConfig(steps=args.steps, lr=args.lr,
                     microbatches=args.microbatches,
                     checkpoint_dir=args.ckpt_dir)
    m = train(cfg, shape, env, tc, governor=governor, device=device,
              regions=regions)
    print(f"final loss: {m['loss'][-1]:.4f}  "
          f"mean step: {sum(m['step_time'])/len(m['step_time'])*1e3:.0f} ms")
    if m["governor"]:
        print("governor:", m["governor"])


if __name__ == "__main__":
    main()
