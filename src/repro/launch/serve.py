"""Serving launcher:  PYTHONPATH=src python -m repro.launch.serve \
    --arch qwen3-32b --smoke --batch 4 --new-tokens 16"""
from __future__ import annotations

import argparse

import jax

from repro.configs import ARCH_IDS, get_config
from repro.configs.registry import model_module
from repro.configs.shapes import ShapeSpec
from repro.data.synthetic import make_batch
from repro.launch.mesh import make_smoke_mesh
from repro.parallel.sharding import make_env
from repro.runtime.serve_loop import ServeConfig, serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3-32b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    env = make_env(cfg, make_smoke_mesh() if args.smoke else None)
    mod = model_module(cfg)
    params, _ = mod.init(jax.random.PRNGKey(0), cfg)
    shape = ShapeSpec("cli", args.seq, args.batch, "prefill")
    batch = make_batch(cfg, shape)
    res = serve(cfg, env, params, batch,
                ServeConfig(max_new_tokens=args.new_tokens))
    print(f"prefill {res['prefill_s']*1e3:.0f} ms, "
          f"decode {res['tokens_per_s']:.1f} tok/s, "
          f"first row: {res['tokens'][0][:8].tolist()}")


if __name__ == "__main__":
    main()
