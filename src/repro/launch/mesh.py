"""Production mesh construction.

A FUNCTION (not a module constant) so importing this module never touches
jax device state.  Single pod: 16x16 = 256 chips ("data","model").
Multi-pod: 2x16x16 = 512 chips ("pod","data","model") — "pod" extends the
data-parallel/FSDP group across the inter-pod (DCN/ICI) boundary.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh(devices=None):
    """1x1 mesh with the production axis names — lets shard_map code paths
    run unmodified in single-device tests."""
    return jax.make_mesh((1, 1), ("data", "model"),
                         devices=devices or jax.devices()[:1])
