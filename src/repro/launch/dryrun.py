import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell on
512 placeholder host devices, record memory/cost/collective analysis.

  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
      --shape train_4k --mesh single --out results/dryrun
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Skipped cells (long_500k on full-attention archs) are recorded with their
reason so the 40-cell table in EXPERIMENTS.md is complete.
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import ARCH_IDS, SHAPES, applicable, get_config
from repro.core.paths import results_dir
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_case
from repro.parallel.collectives import parse_collective_bytes
from repro import costmodel, roofline


def _mem_analysis_dict(mem) -> dict:
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        try:
            out[k] = int(getattr(mem, k))
        except Exception:
            pass
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool, *,
             microbatches: int = 1, fsdp: bool = True, dp_only: bool = False,
             param_dtype: str | None = None,
             verbose: bool = True) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "multi" if multi_pod else "single"
    cell = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
            "microbatches": microbatches, "fsdp": fsdp, "dp_only": dp_only}
    ok, reason = applicable(cfg, shape)
    if not ok:
        cell.update(status="skipped", reason=reason)
        return cell

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    case = build_case(arch, shape_name, mesh, multi_pod=multi_pod,
                      microbatches=microbatches, fsdp=fsdp, dp_only=dp_only,
                      param_dtype=param_dtype)
    try:
        jitted = jax.jit(case["fn"], in_shardings=case["in_shardings"],
                         donate_argnums=case["donate"])
        lowered = jitted.lower(*case["args"])
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    except Exception as e:  # a failure here is a bug in the system
        cell.update(status="error", error=f"{type(e).__name__}: {e}",
                    traceback=traceback.format_exc()[-4000:])
        return cell

    mem = compiled.memory_analysis()
    xla_cost = costmodel.xla_cost_analysis(compiled)
    hlo = compiled.as_text()
    coll = parse_collective_bytes(hlo, mesh.size)
    mflops = roofline.model_flops(cfg, shape)
    # scan-exact jaxpr cost (XLA's cost_analysis counts loop bodies once —
    # see DESIGN.md / tests/test_costmodel.py); global -> per chip
    cm = costmodel.cost_of(case["fn"], *case["args"])
    cost = {"flops": cm.total_flops / mesh.size,
            # fusion-aware traffic (scan boundaries = kernel boundaries;
            # VMEM-resident intermediates excluded — the schedule the Pallas
            # kernels implement). cm.bytes (no-fusion upper bound) is kept
            # in cost_detail for comparison.
            "bytes accessed": cm.bytes_fused / mesh.size}
    terms = roofline.terms_from_analysis(cost, coll.per_chip_link_bytes,
                                         mesh.size, mflops)
    cell.update(
        status="ok",
        lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
        memory=_mem_analysis_dict(mem),
        cost=cost,
        cost_detail=cm.as_dict(),
        xla_cost={k: xla_cost[k] for k in ("flops", "bytes accessed")
                  if k in xla_cost},
        collectives=coll.as_dict(),
        roofline=terms.as_dict(),
        params=cfg.param_count(),
        active_params=cfg.active_param_count(),
        hlo_bytes=len(hlo),
    )
    if verbose:
        print(f"[{arch} x {shape_name} x {mesh_name}] OK "
              f"compile={t_compile:.1f}s dominant={terms.dominant} "
              f"mfu~{terms.mfu:.3f}")
        print("  memory_analysis:", cell["memory"])
        print("  cost_analysis: flops/chip=%.3e bytes/chip=%.3e"
              % (terms.flops_per_chip, terms.bytes_per_chip))
        print("  collectives:", {k: v["count"]
                                 for k, v in coll.by_kind.items()})
    return cell


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None,
                    help="output dir (default: $REPRO_RESULTS_DIR/dryrun)")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--dp-only", action="store_true")
    ap.add_argument("--param-dtype", choices=("fp8", "bf16", "f32"),
                    default=None)
    args = ap.parse_args()

    if args.out is None:
        args.out = results_dir("dryrun")
    os.makedirs(args.out, exist_ok=True)
    arches = ARCH_IDS if args.all or not args.arch else (args.arch,)
    shapes = tuple(SHAPES) if args.all or not args.shape else (args.shape,)
    meshes = {"single": (False,), "multi": (True,),
              "both": (False, True)}[args.mesh]

    n_err = 0
    for arch in arches:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'multi' if mp else 'single'}"
                if args.microbatches != 1:
                    tag += f"__mb{args.microbatches}"
                if args.no_fsdp:
                    tag += "__nofsdp"
                if args.dp_only:
                    tag += "__dponly"
                if args.param_dtype:
                    tag += f"__{args.param_dtype}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    print(f"[{tag}] cached")
                    continue
                cell = run_cell(arch, shape, mp,
                                microbatches=args.microbatches,
                                fsdp=not args.no_fsdp,
                                dp_only=args.dp_only,
                                param_dtype=args.param_dtype)
                if cell["status"] == "error":
                    n_err += 1
                    print(f"[{tag}] ERROR: {cell['error']}")
                elif cell["status"] == "skipped":
                    print(f"[{tag}] SKIPPED: {cell['reason'][:80]}")
                with open(path, "w") as f:
                    json.dump(cell, f, indent=1)
    raise SystemExit(1 if n_err else 0)


if __name__ == "__main__":
    main()
