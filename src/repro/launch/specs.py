"""Build (fn, abstract args, in_shardings) for every (arch x shape x mesh)
dry-run cell — ShapeDtypeStruct stand-ins only, no device allocation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.configs.registry import model_module, decode_module
from repro.optim import adamw
from repro.parallel.sharding import make_env, param_shardings


def batch_spec(env, b, *extra):
    """Shard batch dim over the data axes when divisible, else replicate."""
    if env.mesh is None:
        return None
    if b % env.dp == 0 and env.dp > 1:
        d = env.data_axes if len(env.data_axes) > 1 else env.data_axes[0]
        return NamedSharding(env.mesh, P(d, *extra))
    return NamedSharding(env.mesh, P(None, *extra))


def _rep(env):
    return None if env.mesh is None else NamedSharding(env.mesh, P())


@functools.lru_cache(maxsize=64)
def _abstract_init_cached(cfg):
    """(param ShapeDtypeStructs, logical axes) without allocating anything.

    init runs under eval_shape; the axes tree (static python tuples) escapes
    via closure side effect since tracers never touch it."""
    mod = model_module(cfg)
    box = {}

    def f(k):
        p, a = mod.init(k, cfg)
        box["axes"] = a
        return p

    sds = jax.eval_shape(f, jax.random.PRNGKey(0))
    return sds, box["axes"]


def abstract_init(cfg):
    return _abstract_init_cached(cfg)


def mod_axes(cfg):
    return _abstract_init_cached(cfg)[1]


def make_train_step(cfg, env, opt_cfg=adamw.AdamWConfig(), microbatches: int = 1,
                    grad_compression: bool = False):
    """grad_compression: bf16 gradients + error feedback before the
    (cross-pod) reduction — opt_state must carry an "err" tree
    (repro.optim.compression.init_error)."""
    mod = model_module(cfg)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(
                lambda p: mod.loss_fn(p, batch, cfg, env))(params)
        else:
            def split(x):
                return x.reshape(microbatches, x.shape[0] // microbatches,
                                 *x.shape[1:])
            mb = jax.tree.map(split, batch)

            def acc_step(carry, mbatch):
                loss_acc, grad_acc = carry
                l, g = jax.value_and_grad(
                    lambda p: mod.loss_fn(p, mbatch, cfg, env))(params)
                return (loss_acc + l,
                        jax.tree.map(jnp.add, grad_acc, g)), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params)
            (loss, grads), _ = jax.lax.scan(acc_step, (jnp.float32(0), zeros), mb)
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
        err = None
        if grad_compression:
            from repro.optim import compression
            grads, err = compression.compress(grads, opt_state["err"])
        opt_core = {k: v for k, v in opt_state.items() if k != "err"}
        new_params, new_opt, gnorm = adamw.update(params, grads, opt_core,
                                                  opt_cfg)
        if err is not None:
            new_opt["err"] = err
        return loss, new_params, new_opt

    return train_step


def batch_struct(cfg, shape, for_train=True):
    b, s = shape.global_batch, shape.seq_len
    batch = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if cfg.family == "vlm":
        batch["img_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.vlm.n_patches, cfg.d_model), cfg.compute_dtype)
    if cfg.family == "encdec":
        batch["enc_frames"] = jax.ShapeDtypeStruct(
            (b, cfg.encdec.n_frames, cfg.d_model), cfg.compute_dtype)
    return batch


def batch_shardings(cfg, shape, env):
    b = shape.global_batch
    sh = {"tokens": batch_spec(env, b, None)}
    if cfg.family == "vlm":
        sh["img_embeds"] = batch_spec(env, b, None, None)
    if cfg.family == "encdec":
        sh["enc_frames"] = batch_spec(env, b, None, None)
    return sh


def build_case(arch: str, shape_name: str, mesh, *, multi_pod=False,
               microbatches: int = 1, fsdp: bool = True, smoke=False,
               dp_only: bool = False, param_dtype: str | None = None):
    """Returns dict(fn, args, in_shardings, donate, cfg, env, kind)."""
    import dataclasses
    cfg = get_config(arch, smoke=smoke)
    if param_dtype is not None:
        dt = {"fp8": jnp.float8_e4m3fn, "bf16": jnp.bfloat16,
              "f32": jnp.float32}[param_dtype]
        cfg = dataclasses.replace(cfg, param_dtype=dt)
    shape = SHAPES[shape_name]
    env = make_env(cfg, mesh, fsdp=fsdp, dp_only=dp_only)
    mod = model_module(cfg)
    dec = decode_module(cfg)

    p_sds, axes = abstract_init(cfg)
    p_sh = param_shardings(env, axes, p_sds)

    if shape.kind == "train":
        fn = make_train_step(cfg, env, microbatches=microbatches)
        o_sds = jax.eval_shape(adamw.init, p_sds)
        o_sh = {"m": p_sh, "v": p_sh, "step": _rep(env)}
        args = (p_sds, o_sds, batch_struct(cfg, shape))
        in_sh = (p_sh, o_sh, batch_shardings(cfg, shape, env))
        donate = (0, 1)
    elif shape.kind == "prefill":
        fn = lambda params, batch: dec.prefill(params, batch, cfg, env,
                                               shape.seq_len)
        args = (p_sds, batch_struct(cfg, shape, for_train=False))
        in_sh = (p_sh, batch_shardings(cfg, shape, env))
        donate = ()
    else:  # decode
        b = shape.global_batch
        c_sds, c_axes = dec.cache_spec(cfg, b, shape.seq_len, env)

        def cache_sharding(k):
            ax = c_axes[k]
            if b % env.dp != 0 or env.dp == 1:   # replicate non-divisible batch
                ax = tuple(None if a == "batch" else a for a in ax)
            return NamedSharding(env.mesh, env.spec_sized(ax, c_sds[k].shape))

        c_sh = {k: (None if env.mesh is None else cache_sharding(k))
                for k in c_sds}
        fn = lambda params, cache, token, pos: dec.decode_step(
            params, cache, token, pos, cfg, env)
        args = (p_sds, c_sds,
                jax.ShapeDtypeStruct((b, 1), jnp.int32),
                jax.ShapeDtypeStruct((), jnp.int32))
        in_sh = (p_sh, c_sh, batch_spec(env, b, None), _rep(env))
        donate = (1,)

    return {"fn": fn, "args": args, "in_shardings": in_sh, "donate": donate,
            "cfg": cfg, "env": env, "shape": shape}
