"""Regression detection between two campaigns of the same fleet.

Cross-architecture DVFS studies show switching latencies must be
re-measured per device generation — and once campaigns run continuously,
the question becomes "did any pair's latency *drift* since the table the
governor is using was measured?".  The detector diffs two campaigns
pair-by-pair on their DBSCAN-cleaned sample distributions and flags a pair
when BOTH hold:

* the worst-case (max clean) latency moved by more than
  ``worst_delta_threshold`` relative — the quantity the governor's
  hysteresis rule actually consumes; and
* a nonparametric two-sample test (Mann-Whitney U,
  :func:`repro.core.stats.mann_whitney_u`) rejects "same distribution" at
  ``alpha`` — so a single outlier pass that survived DBSCAN cannot flag a
  pair on its own.  With fewer than ``min_samples`` clean samples on
  either side the test is underpowered and the delta rule decides alone.

With ``reanalyse=True`` the detector ignores the clean/outlier split
stored at measurement time and re-runs the sorted-window analysis engine
(:func:`repro.core.latency_table.analyse_pair`) on each pair's raw
samples — useful when the outlier-filter parameters changed since the
reference campaign was measured, and cheap enough to do on every diff now
that the engine is O(n log n).
"""
from __future__ import annotations

import dataclasses
import math

from repro.campaign.store import Campaign
from repro.core.latency_table import analyse_pair
from repro.core.stats import mann_whitney_u


@dataclasses.dataclass(frozen=True)
class DiffConfig:
    worst_delta_threshold: float = 0.2     # |relative worst-case change|
    alpha: float = 0.05                    # Mann-Whitney significance
    min_samples: int = 4                   # below this, delta decides alone
    reanalyse: bool = False                # re-cluster raw samples on diff


@dataclasses.dataclass
class PairDrift:
    unit_key: str
    f_init: float
    f_target: float
    worst_a: float
    worst_b: float
    rel_delta: float                       # (worst_b - worst_a) / worst_a
    p_value: float                         # nan when underpowered
    flagged: bool


@dataclasses.dataclass
class CampaignDiff:
    campaign_a: str
    campaign_b: str
    drifts: list[PairDrift]
    only_in_a: list[tuple[str, float, float]]
    only_in_b: list[tuple[str, float, float]]

    def flagged(self) -> list[PairDrift]:
        return [d for d in self.drifts if d.flagged]

    @property
    def clean(self) -> bool:
        return not self.flagged()


def _comparable_pairs(table, reanalyse: bool = False) -> dict:
    # reanalysis can't change the key set: analyse_pair falls back to
    # clean = latencies when DBSCAN marks everything noise, so any pair
    # that passed the stored clean.size check stays comparable
    pairs = {}
    for (fi, ft), pr in table.pairs.items():
        if pr.status != "ok" or not pr.clean.size:
            continue
        if reanalyse:
            pr = analyse_pair(fi, ft, pr.latencies, pr.status,
                              with_silhouette=False)   # diff never reads it
        pairs[(fi, ft)] = pr
    return pairs


def pair_drift(unit_key: str, f_init: float, f_target: float,
               ra, rb, cfg: DiffConfig | None = None) -> PairDrift:
    """The single drift verdict shared by the batch differ and the fleet
    monitor's streaming confirm gate: compare candidate :class:`PairResult`
    ``rb`` against reference ``ra`` with the worst-delta AND Mann-Whitney
    rule.  Keeping one implementation is what guarantees that a streaming
    alert and ``diff_campaigns`` agree on the same data by construction."""
    if cfg is None:
        cfg = DiffConfig()
    if ra.worst_case > 0:
        rel = (rb.worst_case - ra.worst_case) / ra.worst_case
    else:                     # sub-timer-resolution reference samples
        rel = float("inf") if rb.worst_case > 0 else 0.0
    underpowered = (ra.clean.size < cfg.min_samples
                    or rb.clean.size < cfg.min_samples)
    if underpowered:
        p = float("nan")
        shifted = True
    else:
        _, p = mann_whitney_u(ra.clean, rb.clean)
        shifted = p < cfg.alpha
    flagged = abs(rel) > cfg.worst_delta_threshold and shifted
    return PairDrift(unit_key, f_init, f_target, ra.worst_case,
                     rb.worst_case, rel, p, flagged)


def diff_campaigns(a: Campaign, b: Campaign,
                   cfg: DiffConfig | None = None) -> CampaignDiff:
    """Diff ``b`` (candidate) against ``a`` (reference)."""
    if cfg is None:
        cfg = DiffConfig()
    drifts: list[PairDrift] = []
    only_a: list[tuple[str, float, float]] = []
    only_b: list[tuple[str, float, float]] = []
    tables_a = a.tables()
    tables_b = b.tables()
    for key in sorted(set(tables_a) | set(tables_b)):
        # key-only enumeration: reanalysis can't change which pairs are
        # comparable, so skip the re-clustering for one-sided units
        if key not in tables_b:
            only_a.extend((key, fi, ft)
                          for fi, ft in _comparable_pairs(tables_a[key]))
            continue
        if key not in tables_a:
            only_b.extend((key, fi, ft)
                          for fi, ft in _comparable_pairs(tables_b[key]))
            continue
        pa = _comparable_pairs(tables_a[key], cfg.reanalyse)
        pb = _comparable_pairs(tables_b[key], cfg.reanalyse)
        only_a.extend((key, fi, ft) for fi, ft in sorted(set(pa) - set(pb)))
        only_b.extend((key, fi, ft) for fi, ft in sorted(set(pb) - set(pa)))
        for (fi, ft) in sorted(set(pa) & set(pb)):
            drifts.append(pair_drift(key, fi, ft, pa[(fi, ft)],
                                     pb[(fi, ft)], cfg))
    return CampaignDiff(a.campaign_id, b.campaign_id, drifts, only_a, only_b)


def diff_to_dict(diff: CampaignDiff) -> dict:
    """Machine-readable CampaignDiff (``campaign diff --json``): per-pair
    deltas, U-test p-values and verdicts, so tooling can assert on drift
    results without scraping the markdown table.  NaN p-values (the
    underpowered delta-decides-alone rule) serialize as None."""
    return {
        "campaign_a": diff.campaign_a,
        "campaign_b": diff.campaign_b,
        "clean": diff.clean,
        "n_pairs": len(diff.drifts),
        "n_flagged": len(diff.flagged()),
        "drifts": [
            {"unit_key": d.unit_key, "f_init": d.f_init,
             "f_target": d.f_target, "worst_a_s": d.worst_a,
             "worst_b_s": d.worst_b,
             # non-finite floats have no strict-JSON encoding: null them
             "rel_delta": (d.rel_delta if math.isfinite(d.rel_delta)
                           else None),
             "p_value": None if d.p_value != d.p_value else d.p_value,
             "flagged": d.flagged}
            for d in diff.drifts],
        "only_in_a": [list(t) for t in diff.only_in_a],
        "only_in_b": [list(t) for t in diff.only_in_b],
    }


def diff_markdown(diff: CampaignDiff) -> str:
    flagged = diff.flagged()
    lines = [
        f"# Campaign diff: `{diff.campaign_a}` (reference) vs "
        f"`{diff.campaign_b}` (candidate)",
        "",
        f"{len(diff.drifts)} comparable pairs, "
        f"**{len(flagged)} flagged** as drifted.",
        "",
    ]
    if diff.only_in_a or diff.only_in_b:
        lines += [f"Coverage changed: {len(diff.only_in_a)} pair(s) only in "
                  f"reference, {len(diff.only_in_b)} only in candidate.", ""]
    lines += ["| unit | pair (MHz) | worst A (ms) | worst B (ms) | Δ | "
              "MW p | drift |",
              "|---|---|---:|---:|---:|---:|---|"]
    # flagged rows first, then the largest absolute movements for context
    shown = flagged + sorted((d for d in diff.drifts if not d.flagged),
                             key=lambda d: -abs(d.rel_delta))[:10]
    for d in shown:
        p = "–" if d.p_value != d.p_value else f"{d.p_value:.3g}"
        lines.append(
            f"| {d.unit_key} | {d.f_init:.0f}→{d.f_target:.0f} "
            f"| {d.worst_a * 1e3:.2f} | {d.worst_b * 1e3:.2f} "
            f"| {d.rel_delta:+.1%} | {p} "
            f"| {'**DRIFT**' if d.flagged else ''} |")
    return "\n".join(lines)
