"""Retry/backoff policy for flaky remote operations, plus dead letters.

Every remote interaction in the cluster layer — store reads/writes over
the node transport, webhook alert delivery (:mod:`repro.monitor.sinks`)
— goes through :func:`call_with_retry` wrapping a :class:`RetryPolicy`:

* **capped exponential backoff**: attempt ``k`` waits
  ``min(cap_s, base_s * 2**k)`` seconds — the un-jittered schedule is
  monotone non-decreasing and its total is bounded by
  ``max_attempts * cap_s`` (the property tests pin both);
* **deterministic seeded jitter**: the wait is scaled into
  ``[raw * (1 - jitter), raw]`` by a ``blake2s(seed, op_key, attempt)``
  hash — decorrelated across operations (no thundering-herd retry
  convoys) yet bit-reproducible under a fixed seed, like every other
  source of randomness in this repo (``pair_seed`` uses the same
  construction);
* **per-operation timeout**: handed to the transport, which raises
  :class:`TransportTimeout` instead of blocking the driver loop;
* **dead letter after exhaustion**: the terminal failure is appended to
  a JSONL dead-letter file (operation, key, attempts, last error) so an
  operator can replay what the fleet could not deliver, then
  :class:`RetriesExhausted` is raised — a *non*-retryable error, so an
  outer retry loop never spins on a poisoned operation.

Only :class:`RetryableError` subclasses are retried.  Anything else
(a programming error, a validation failure) propagates immediately:
retrying it would just burn the budget hiding a bug.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import threading
import time


class RetryableError(Exception):
    """Base for failures that a retry may cure (flaky link, busy store)."""


class TransportError(RetryableError):
    """A message or RPC was lost, rejected, or hit a partition."""


class TransportTimeout(TransportError):
    """The operation exceeded its per-op timeout in flight."""


class StoreWriteError(RetryableError):
    """The artifact store rejected a write (transient or injected)."""


class RetriesExhausted(Exception):
    """The retry budget is spent; the failure is in the dead-letter file.

    Deliberately NOT a :class:`RetryableError`: once a policy has given
    up, an enclosing retry loop must not resurrect the operation."""

    def __init__(self, op: str, attempts: int, last: Exception):
        super().__init__(
            f"{op}: {attempts} attempt(s) exhausted; last error: "
            f"{type(last).__name__}: {last}")
        self.op = op
        self.attempts = attempts
        self.last = last


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Capped exponential backoff with deterministic seeded jitter."""

    max_attempts: int = 6
    base_s: float = 0.05
    cap_s: float = 2.0
    jitter: float = 0.25        # fraction of the raw backoff shaved off
    timeout_s: float = 10.0     # per-operation transport timeout
    seed: int = 0

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError(f"max_attempts must be >= 1, "
                             f"got {self.max_attempts}")
        if not 0.0 <= self.jitter <= 1.0:
            raise ValueError(f"jitter must be in [0, 1], got {self.jitter}")
        if self.base_s < 0 or self.cap_s < 0:
            raise ValueError("backoff times must be non-negative")

    def raw_backoff_s(self, attempt: int) -> float:
        """Un-jittered wait after failed attempt ``attempt`` (0-based):
        ``min(cap_s, base_s * 2**attempt)`` — monotone non-decreasing."""
        if attempt < 0:
            raise ValueError(f"attempt must be >= 0, got {attempt}")
        # 2.0** instead of <<: attempt can legitimately exceed float
        # exponent range under a pathological max_attempts; inf caps fine
        try:
            raw = self.base_s * (2.0 ** attempt)
        except OverflowError:
            raw = float("inf")
        return min(self.cap_s, raw)

    def backoff_s(self, attempt: int, op_key: str = "") -> float:
        """Jittered wait: ``raw * (1 - jitter * u)`` with ``u`` drawn
        deterministically from ``blake2s(seed, op_key, attempt)`` — always
        within ``[raw * (1 - jitter), raw]``."""
        raw = self.raw_backoff_s(attempt)
        if self.jitter == 0.0 or raw == 0.0:
            return raw
        h = hashlib.blake2s(
            f"{self.seed}:{op_key}:{attempt}".encode(), digest_size=8)
        u = int.from_bytes(h.digest(), "big") / 2.0 ** 64
        return raw * (1.0 - self.jitter * u)

    def total_backoff_bound_s(self) -> float:
        """Upper bound on the summed waits of one full retry cycle."""
        return sum(self.raw_backoff_s(k)
                   for k in range(self.max_attempts - 1))


class DeadLetterFile:
    """Append-only JSONL record of operations the fleet gave up on.

    One line per dead letter: ``{"op", "key", "attempts", "error",
    "t"}``.  Appends are serialized by a process-local lock and flushed
    line-at-a-time; concurrent processes interleave whole lines (POSIX
    O_APPEND), never tear them."""

    def __init__(self, path: str, clock=time.time):
        self.path = path
        self.clock = clock
        self._lock = threading.Lock()

    def record(self, op: str, key: str, attempts: int, error: str,
               **extra) -> dict:
        """``extra`` fields (e.g. the active span id and elapsed time the
        retry loop burned) merge into the record so ``campaign profile``
        can cross-reference dead letters against the span timeline."""
        doc = {"op": op, "key": key, "attempts": int(attempts),
               "error": str(error), "t": float(self.clock()),
               **{k: v for k, v in extra.items() if v is not None}}
        line = json.dumps(doc, sort_keys=True)
        with self._lock:
            os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
            with open(self.path, "a") as f:
                f.write(line + "\n")
        return doc

    def records(self) -> list[dict]:
        if not os.path.exists(self.path):
            return []
        with open(self.path) as f:
            return [json.loads(line) for line in f if line.strip()]

    def __len__(self) -> int:
        return len(self.records())


def call_with_retry(fn, policy: RetryPolicy, *, op: str = "op",
                    op_key: str = "", dead_letters: DeadLetterFile | None
                    = None, sleep=time.sleep, on_retry=None):
    """Run ``fn()`` under ``policy``; retries :class:`RetryableError` with
    backoff, anything else propagates immediately.  After the budget is
    spent the failure is dead-lettered (when a file is attached) and
    :class:`RetriesExhausted` raised."""
    from repro import obs
    span_ctx = obs.ctx()            # active span at entry (None when off)
    t0 = time.perf_counter()
    last: Exception | None = None
    for attempt in range(policy.max_attempts):
        try:
            return fn()
        except RetryableError as exc:
            last = exc
            if on_retry is not None:
                on_retry(attempt, exc)
            if attempt + 1 < policy.max_attempts:
                wait = policy.backoff_s(attempt, op_key or op)
                if wait > 0:
                    sleep(wait)
    assert last is not None
    if dead_letters is not None:
        dead_letters.record(op, op_key, policy.max_attempts, repr(last),
                            span=span_ctx,
                            elapsed_s=time.perf_counter() - t0)
    raise RetriesExhausted(op, policy.max_attempts, last)
