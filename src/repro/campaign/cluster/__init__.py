"""Multi-node campaign dispatch that survives a hostile fleet.

The cluster layer spans a campaign across N worker nodes behind the
same executor protocol as the process work queue, and makes the
artifact store pluggable behind a transport:

* :mod:`~repro.campaign.cluster.transport` — the :class:`NodeTransport`
  protocol, the chaos-injected in-process :class:`SimTransport`, and
  the per-link deterministic fault model;
* :mod:`~repro.campaign.cluster.remote_store` — the store-host request
  handler plus :class:`LocalStore` / :class:`RemoteStoreClient`
  (content-addressed, idempotent, retry-wrapped);
* :mod:`~repro.campaign.cluster.retry` — capped-exponential backoff
  with deterministic seeded jitter, per-op timeouts, dead letters;
* :mod:`~repro.campaign.cluster.node` — the simulated worker node
  (thread + scratch disk + transport-only store access);
* :mod:`~repro.campaign.cluster.dispatch` — the driver, built on the
  shared :class:`~repro.campaign.workqueue.DispatchCore`;
* :mod:`~repro.campaign.cluster.ssh` — the real-transport contract
  stub.

Entry point: ``CampaignRunner(..., executor="cluster")`` or
``python -m repro.campaign run spec.json --executor cluster --nodes 3``.
"""
from repro.campaign.cluster.dispatch import ClusterCampaignScheduler
from repro.campaign.cluster.node import NodeWorker
from repro.campaign.cluster.remote_store import (LocalStore,
                                                 RemoteStoreClient,
                                                 StoreServer, blob_digest,
                                                 file_digest)
from repro.campaign.cluster.retry import (DeadLetterFile, RetriesExhausted,
                                          RetryableError, RetryPolicy,
                                          StoreWriteError, TransportError,
                                          TransportTimeout, call_with_retry)
from repro.campaign.cluster.transport import (Channel, NodeTransport,
                                              SimTransport, TransportFaults)

__all__ = [
    "Channel",
    "ClusterCampaignScheduler",
    "DeadLetterFile",
    "LocalStore",
    "NodeTransport",
    "NodeWorker",
    "RemoteStoreClient",
    "RetriesExhausted",
    "RetryPolicy",
    "RetryableError",
    "SimTransport",
    "StoreServer",
    "StoreWriteError",
    "TransportError",
    "TransportFaults",
    "TransportTimeout",
    "blob_digest",
    "call_with_retry",
    "file_digest",
]
