"""Simulated worker node: one thread, one scratch disk, one transport.

A :class:`NodeWorker` models a remote measurement host faithfully enough
to chaos-test the dispatch layer: it owns a *node-local* campaign
directory (its scratch disk) and can only reach the real artifact store
through its store client — every byte that survives the node does so by
crossing the (faulty) transport.  The lifecycle per dispatched unit:

1. **download** the unit's artifact subtree from the store into local
   scratch (session state, tables, result) — this is what makes requeue
   resume at *pair* granularity: a dead node's uploaded pairs are right
   there for the survivor;
2. **measure** through the shared :class:`_BeatingSerial` executor —
   the same beating/crash/slowdown hooks the process workers use, with
   the crash action swapped from ``os._exit`` to :class:`_NodeCrash`
   (a thread cannot hard-exit the interpreter; dying silently is the
   simulated equivalent).  Every beat sends a heartbeat message and
   best-effort-syncs freshly persisted session pairs up to the store;
3. **upload** the full unit subtree (now including the final table and
   result), *then* ack ``done`` — the ordering matters: a ``done``
   whose artifacts had not landed would let the driver read a torn
   unit.  If the ack is dropped by the transport, the driver's
   heartbeat timeout requeues the unit and the next attempt finds
   everything already uploaded — it resumes instantly and re-acks.

A reaped node (the driver gave up on it) has its stop event set; the
zombie notices at its next beat and dies.  Anything it managed to
upload before that is bit-identical to what the replacement produces
(pair-seeded determinism), so zombie writes are dedups, never
corruption.
"""
from __future__ import annotations

import os
import threading
import time

from repro import obs
from repro.campaign.cluster.remote_store import blob_digest, file_digest
from repro.campaign.cluster.retry import RetriesExhausted
from repro.campaign.cluster.transport import POISON
from repro.campaign.store import Campaign
from repro.campaign.workqueue import _BeatingSerial
from repro.core.paths import atomic_replace


class _NodeCrash(Exception):
    """Injected node death: unwinds the node thread without a message."""


def _syncable(relpath: str) -> bool:
    """Artifact files that cross the transport.  Traces stay host-local
    (cluster runs are untraced), span files sync through their own
    dedicated path (:meth:`NodeWorker._sync_spans`, suppressed so the
    upload does not trace itself), fault markers and dead letters are
    harness bookkeeping, never payload."""
    parts = relpath.split("/")
    if "traces" in parts or "deadletter" in parts or "spans" in parts:
        return False
    name = parts[-1]
    return not name.endswith(".injected")


class NodeWorker:
    """One simulated node: consumes unit keys from its inbox, reports
    ``ready``/``start``/``beat``/``done``/``failed`` on its outbox —
    the same message grammar as the process workers, carried over a
    chaos-injected channel instead of a multiprocessing queue."""

    def __init__(self, node_id: str, spec, store, scratch_root: str,
                 inbox, outbox, *, campaign_id: str,
                 fault_plan=None, claim_fault=None, poll_s: float = 0.01,
                 spans: bool = False):
        from repro.campaign.workqueue import FaultPlan
        self.node_id = node_id
        self.spec = spec
        self.store = store                  # LocalStore | RemoteStoreClient
        self.inbox = inbox
        self.outbox = outbox
        self.spans = bool(spans)
        self._rec = None                    # node-thread SpanRecorder
        self.plan = fault_plan or FaultPlan()
        # fault claims are once-per-unit ACROSS attempts and nodes, so
        # they live driver-side; the dispatcher injects the claimer
        self.claim_fault = claim_fault or (lambda key, kind: False)
        self.poll_s = poll_s
        self.local = Campaign(os.path.join(scratch_root, node_id), spec,
                              campaign_id=campaign_id)
        self._units = {u.key: u for u in spec.units()}
        self._stop = threading.Event()
        self._thread = threading.Thread(
            target=self._main, name=f"node-{node_id}", daemon=True)
        self.sync_failures = 0              # best-effort beat syncs lost

    # ---------------- lifecycle ---------------- #
    def start(self) -> None:
        self.local.init()
        self._thread.start()

    @property
    def alive(self) -> bool:
        return self._thread.is_alive()

    def stop(self) -> None:
        """Reap: the zombie dies at its next beat or poll."""
        self._stop.set()

    def join(self, timeout: float | None = None) -> None:
        self._thread.join(timeout)

    # ---------------- main loop ---------------- #
    def _main(self) -> None:
        if self.spans:
            # nodes are threads of the driver process: a thread-local
            # recorder shadows the driver's so node spans carry their own
            # actor id and land on the node's scratch disk first
            actor = f"node-{self.node_id}"
            self._rec = obs.SpanRecorder(actor,
                                         path=self.local.span_path(actor))
            obs.install(self._rec, thread_only=True)
        try:
            self._main_loop()
        finally:
            self._sync_spans()
            if self._rec is not None:
                self._rec.close()
                obs.uninstall(thread_only=True)

    def _main_loop(self) -> None:
        self.outbox.send(("ready", self.node_id))
        while not self._stop.is_set():
            msgs = self.inbox.recv_ready()
            if not msgs:
                time.sleep(self.poll_s)
                continue
            for msg in msgs:
                if msg == POISON:
                    return
                _, key, *rest = msg      # ("unit", key[, trace_ctx])
                ctx = rest[0] if rest else None
                try:
                    self._run_unit(key, ctx)
                except _NodeCrash:
                    return                  # silent death — the driver's
                                            # liveness check finds the body
                except Exception as exc:  # noqa: BLE001 — unit isolation
                    self.outbox.send(
                        ("failed", self.node_id, key,
                         f"{type(exc).__name__}: {exc}"))
                finally:
                    self._sync_spans()      # incremental, best-effort

    # ---------------- one unit ---------------- #
    def _run_unit(self, key: str, ctx: str | None = None) -> None:
        with obs.span("unit.exec", "exec", parent=ctx or obs.AMBIENT,
                      unit=key, node=self.node_id):
            self._run_unit_inner(key)

    def _run_unit_inner(self, key: str) -> None:
        self.outbox.send(("start", self.node_id, key))
        t0 = time.perf_counter()
        synced = self._download(key)

        if self.plan.drift_for(key) is not None:
            raise ValueError(
                "FaultPlan drift injection needs the traced process "
                "scheduler (trace=True); cluster runs are untraced")
        stall = self.plan.stall_for(key)
        if stall is not None and self.claim_fault(key, "stall"):
            time.sleep(stall)               # silent: no beats, no syncs
        slow = self.plan.slow_for(key)
        if slow is not None and not self.claim_fault(key, "slow"):
            slow = None
        crash_after = self.plan.node_crash_for(key)

        def crash() -> None:
            raise _NodeCrash(f"injected crash of node {self.node_id}")

        def beat() -> None:
            if self._stop.is_set():         # reaped while measuring:
                raise _NodeCrash("node reaped by driver")   # die quietly
            self.outbox.send(("beat", self.node_id))
            self._upload(key, synced, session_only=True, best_effort=True)

        executor = _BeatingSerial(
            beat, crash_after=crash_after,
            on_crash=(lambda: self.claim_fault(key, "node_crash"))
            if crash_after is not None else None,
            sleep_between_s=slow, crash_action=crash)
        session = self._units[key].build_session(
            out_dir=self.local.session_dir(key), executor=executor)
        table = session.run(verbose=False)
        gt = (session.ground_truth()
              if hasattr(session, "ground_truth") else {})
        self.local.save_unit_result(key, table, gt)
        # full upload BEFORE the ack: a "done" must never race its bytes
        self._upload(key, synced, session_only=False, best_effort=False)
        self.outbox.send(("done", self.node_id, key,
                          time.perf_counter() - t0, len(table.pairs)))

    # ---------------- store sync ---------------- #
    def _download(self, key: str) -> dict[str, str]:
        """Pull the unit's store subtree into local scratch; returns the
        relpath -> digest map of what is now known-synced."""
        synced: dict[str, str] = {}
        listing = self.store.list_files(f"units/{key}")
        for rel, digest in sorted(listing.items()):
            if not _syncable(rel):
                continue
            local_path = os.path.join(self.local.dir, rel)
            if os.path.isfile(local_path) \
                    and file_digest(local_path) == digest:
                synced[rel] = digest        # same node re-running the
                continue                    # unit: scratch already matches
            data = self.store.get_file(rel)
            if data is None:
                continue
            os.makedirs(os.path.dirname(local_path), exist_ok=True)
            with atomic_replace(local_path) as tmp:
                with open(tmp, "wb") as f:
                    f.write(data)
            synced[rel] = digest
        return synced

    def _upload(self, key: str, synced: dict[str, str], *,
                session_only: bool, best_effort: bool) -> None:
        """Push changed unit files to the store.  Beat-time syncs are
        best-effort (a failure now is retried wholesale by the final
        upload); the final upload lets :class:`RetriesExhausted`
        propagate — an unreachable store is a failed attempt."""
        root = (self.local.session_dir(key) if session_only
                else self.local.unit_dir(key))
        if not os.path.isdir(root):
            return
        for dirpath, _, names in os.walk(root):
            for name in sorted(names):
                full = os.path.join(dirpath, name)
                rel = os.path.relpath(full, self.local.dir)
                rel = rel.replace(os.sep, "/")
                if not _syncable(rel):
                    continue
                with open(full, "rb") as f:
                    data = f.read()
                digest = blob_digest(data)
                if synced.get(rel) == digest:
                    continue
                try:
                    self.store.put_file(rel, data, digest)
                except RetriesExhausted:
                    if not best_effort:
                        raise
                    self.sync_failures += 1
                    continue                # the final sync will retry
                synced[rel] = digest

    def _sync_spans(self) -> None:
        """Best-effort upload of this node's span file to the store's
        ``spans/`` dir — under :func:`repro.obs.suppressed` so the
        (instrumented) store client does not trace its own flushes.
        Profiling must never fail a unit, so exhausted retries are
        swallowed; the content-addressed store makes re-uploads dedups."""
        if self._rec is None:
            return
        self._rec.flush()
        path = self._rec.path
        if not path or not os.path.isfile(path):
            return
        with open(path, "rb") as f:
            data = f.read()
        if not data:
            return
        with obs.suppressed():
            try:
                self.store.put_file(f"spans/{self._rec.actor}.jsonl",
                                    data, blob_digest(data))
            except RetriesExhausted:
                self.sync_failures += 1
