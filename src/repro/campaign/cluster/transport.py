"""Node transport: the wire between the campaign driver and its nodes.

The :class:`NodeTransport` protocol is deliberately tiny — a message
channel per direction per node, plus a synchronous RPC path for store
operations — because that is all the dispatch layer needs: unit
dispatch and heartbeats ride the channels, artifact bytes ride the
RPCs.  Two implementations ship:

* :class:`SimTransport` — in-process simulation used by CI and every
  chaos test.  Nodes are threads, channels are queues, and the chaos
  knobs (message drop, duplication, bounded delay) are applied at the
  *sending* edge by a per-link deterministic RNG: each link has exactly
  one producer, so the fault sequence a link experiences is a pure
  function of ``(seed, link_id, message index)`` regardless of how the
  threads interleave;
* :class:`~repro.campaign.cluster.ssh.SSHTransport` — the real-cluster
  contract stub (mirrors how :mod:`repro.backends.cuda_nvml` stubs the
  NVML backend): documents the wire protocol and fails loudly, so the
  sim and the eventual real transport share one call surface.

Dropped messages are not errors at this layer — they are *silence*, and
the driver's heartbeat machinery is the recovery path: a node that never
received its unit (dropped dispatch) or whose completion ack vanished
(dropped ``done``) simply stops making progress, times out, and has the
unit requeued.  Dropped or duplicated RPCs surface as
:class:`~repro.campaign.cluster.retry.TransportError` /double delivery,
which the retry layer and the store's idempotent writes absorb.
"""
from __future__ import annotations

import dataclasses
import hashlib
import random
import threading
import time
from collections import Counter
from typing import Protocol

from repro import obs
from repro.campaign.cluster.retry import TransportTimeout

POISON = ("__poison__",)        # raw shutdown sentinel (never chaos-mangled)


@dataclasses.dataclass(frozen=True)
class TransportFaults:
    """Chaos knobs for :class:`SimTransport` (all off by default)."""

    drop_rate: float = 0.0      # P(message or RPC request is lost)
    dup_rate: float = 0.0       # P(message/RPC is delivered twice)
    delay_s: float = 0.0        # max uniform delivery delay, seconds
    seed: int = 0               # per-link RNG seed material

    def __post_init__(self):
        for name in ("drop_rate", "dup_rate"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {v}")
        if self.delay_s < 0:
            raise ValueError(f"delay_s must be >= 0, got {self.delay_s}")

    @staticmethod
    def from_plan(plan) -> "TransportFaults":
        """Build from a :class:`~repro.campaign.workqueue.FaultPlan`'s
        ``transport`` knobs (empty plan -> a clean network)."""
        return TransportFaults(**plan.transport_dict())

    @property
    def clean(self) -> bool:
        return (self.drop_rate == 0.0 and self.dup_rate == 0.0
                and self.delay_s == 0.0)


class _LinkChaos:
    """Deterministic per-link fault source.  One producer per link is
    the invariant that makes this reproducible: the n-th send on a link
    sees the n-th draw of ``Random(blake2s(seed:link_id))`` no matter
    how the rest of the fleet interleaves."""

    def __init__(self, faults: TransportFaults, link_id: str):
        self.faults = faults
        h = hashlib.blake2s(f"{faults.seed}:{link_id}".encode(),
                            digest_size=8)
        self._rng = random.Random(int.from_bytes(h.digest(), "big"))

    def roll(self) -> tuple[bool, bool, float]:
        """(dropped, duplicated, delay_s) for one send.  All three are
        always drawn so the RNG stream stays aligned across fault
        configurations that share a seed."""
        f = self.faults
        u_drop, u_dup, u_del = (self._rng.random(), self._rng.random(),
                                self._rng.random())
        return (u_drop < f.drop_rate, u_dup < f.dup_rate,
                u_del * f.delay_s)


class Channel:
    """One-directional, single-producer message channel with injected
    chaos at the sending edge.  ``recv_ready`` returns every message
    whose (possibly delayed) delivery time has arrived — delayed
    messages can overtake each other, like a real datagram link."""

    def __init__(self, link_id: str, faults: TransportFaults,
                 clock=time.monotonic, counters: Counter | None = None):
        self.link_id = link_id
        self.clock = clock
        self.counters = counters if counters is not None else Counter()
        self._chaos = _LinkChaos(faults, link_id)
        self._lock = threading.Lock()
        self._inflight: list[tuple[float, object]] = []

    def send(self, msg) -> None:
        dropped, dup, delay = self._chaos.roll()
        if obs.enabled():
            obs.event("msg.send", "msg", link=self.link_id,
                      kind=(msg[0] if isinstance(msg, tuple) and msg
                            else str(msg)),
                      dropped=dropped, dup=dup, delay_s=delay)
        if dropped:
            self.counters["msg_dropped"] += 1
            return
        ready = self.clock() + delay
        if delay > 0:
            self.counters["msg_delayed"] += 1
        with self._lock:
            self._inflight.append((ready, msg))
            if dup:
                self.counters["msg_duplicated"] += 1
                self._inflight.append((ready, msg))

    def send_raw(self, msg) -> None:
        """Chaos-exempt send — control-plane shutdown only."""
        with self._lock:
            self._inflight.append((self.clock(), msg))

    def recv_ready(self) -> list:
        """Pop (in send order) every message whose delivery time has
        arrived."""
        now = self.clock()
        with self._lock:
            out = [m for t, m in self._inflight if t <= now]
            self._inflight = [(t, m) for t, m in self._inflight if t > now]
        if out and obs.enabled():
            obs.event("msg.recv", "msg", link=self.link_id, n=len(out))
        return out


class NodeTransport(Protocol):
    """What the cluster dispatcher needs from a transport.

    ``channel(link_id)`` returns the (created-on-first-use) message
    channel for one direction of one node link; ``rpc(link_id, fn,
    *args, timeout_s=...)`` performs one synchronous store operation
    over that node's control link, raising
    :class:`~repro.campaign.cluster.retry.TransportError` on loss and
    :class:`~repro.campaign.cluster.retry.TransportTimeout` when the
    operation cannot complete inside ``timeout_s``."""

    def channel(self, link_id: str) -> Channel: ...     # pragma: no cover

    def rpc(self, link_id: str, fn, *args, timeout_s: float | None = None): ...
    # pragma: no cover


class SimTransport:
    """In-process :class:`NodeTransport`: queues for channels, direct
    calls for RPCs, chaos injected deterministically per link."""

    def __init__(self, faults: TransportFaults | None = None,
                 clock=time.monotonic):
        self.faults = faults or TransportFaults()
        self.clock = clock
        self.counters: Counter = Counter()
        self._channels: dict[str, Channel] = {}
        self._rpc_chaos: dict[str, _LinkChaos] = {}
        self._lock = threading.Lock()

    def channel(self, link_id: str) -> Channel:
        with self._lock:
            ch = self._channels.get(link_id)
            if ch is None:
                ch = Channel(link_id, self.faults, clock=self.clock,
                             counters=self.counters)
                self._channels[link_id] = ch
            return ch

    def rpc(self, link_id: str, fn, *args,
            timeout_s: float | None = None):
        """One synchronous operation against the store host.  A dropped
        request surfaces as :class:`TransportTimeout` (the caller's
        retry layer owns recovery); a duplicated request really invokes
        ``fn`` twice — the store's writes must be idempotent, and the
        chaos tests prove they are."""
        with self._lock:
            chaos = self._rpc_chaos.get(link_id)
            if chaos is None:
                chaos = _LinkChaos(self.faults, f"rpc:{link_id}")
                self._rpc_chaos[link_id] = chaos
        dropped, dup, delay = chaos.roll()
        if dropped:
            self.counters["rpc_dropped"] += 1
            raise TransportTimeout(
                f"rpc on {link_id} lost in transit (no reply before "
                f"timeout {timeout_s})")
        if delay > 0:
            if timeout_s is not None and delay > timeout_s:
                self.counters["rpc_timeout"] += 1
                raise TransportTimeout(
                    f"rpc on {link_id} exceeded timeout "
                    f"({delay:.3f}s > {timeout_s}s)")
            self.counters["rpc_delayed"] += 1
            time.sleep(min(delay, 0.05))    # bounded: sim time, not wall
        result = fn(*args)
        if dup:
            self.counters["rpc_duplicated"] += 1
            fn(*args)                       # double delivery, result of
        return result                       # the first wins (idempotent)
