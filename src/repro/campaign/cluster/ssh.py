"""SSH/k8s-shaped transport contract stub (no real cluster in CI).

This mirrors how :mod:`repro.backends.cuda_nvml` stubs the NVML
backend: the class documents the exact contract a real implementation
must honor and fails loudly at construction, so code written against
:class:`SSHTransport` today runs unchanged against a real transport
later — and so the simulated transport cannot silently drift away from
the real one's surface.

Contract (shared with :class:`~repro.campaign.cluster.transport
.SimTransport`, enforced by the :class:`~repro.campaign.cluster
.transport.NodeTransport` protocol):

* ``channel(link_id)`` — a one-directional message channel.  Link ids
  are ``"driver-><node>"`` and ``"<node>->driver"``; messages are the
  worker grammar tuples (``ready``/``start``/``beat``/``done``/
  ``failed`` and ``("unit", key)`` dispatches).  A real implementation
  maps these onto a persistent SSH session's stdin/stdout framing or a
  k8s pod's exec stream.  Delivery MAY drop, duplicate, delay, or
  reorder — the dispatch layer is built for that and nothing may rely
  on reliable delivery;
* ``rpc(link_id, fn, *args, timeout_s=...)`` — one synchronous store
  operation.  A real implementation serializes the operation name +
  arguments (the :class:`~repro.campaign.cluster.remote_store
  .StoreServer` handler surface: ``put_file``/``get_file``/
  ``list_files``/``mark_unit``) instead of shipping callables.  It MUST
  raise :class:`~repro.campaign.cluster.retry.TransportTimeout` when no
  reply arrives within ``timeout_s`` and
  :class:`~repro.campaign.cluster.retry.TransportError` for link
  failures, because those are the only exception types the retry layer
  treats as retryable.  Operations MUST be safe to deliver twice
  (clients retry on timeout without knowing whether the op landed);
  the store side already guarantees idempotency.

Node provisioning (starting the worker process on the remote host,
shipping the spec, choosing a scratch directory) is out of transport
scope — a real deployment drives it with its orchestrator of choice and
hands this class an already-reachable endpoint per node.
"""
from __future__ import annotations


def is_available() -> bool:
    """True when a real remote transport could run here (it never can in
    this repo: no SSH fleet, no cluster API — CI uses SimTransport)."""
    return False


class SSHTransport:
    """Contract stub: construction always fails with the full story."""

    def __init__(self, hosts=None, **_kw):
        raise NotImplementedError(
            "SSHTransport is a contract stub: this environment has no "
            "reachable worker fleet. The wire contract a real transport "
            "must implement is documented in repro.campaign.cluster.ssh; "
            "use executor='cluster' with the default SimTransport for "
            f"simulated multi-node runs (requested hosts: {hosts!r})")
