"""Pluggable artifact-store access for cluster campaigns.

The campaign store (:class:`repro.campaign.store.Campaign`) stays the
single source of truth on the driver host; what becomes pluggable is how
a *writer* reaches it.  Three shapes share one call surface (``put_file``
/ ``get_file`` / ``list_files`` / ``mark_unit``):

* :class:`StoreServer` — the store-host side: resolves relpaths inside
  the campaign directory, validates content digests, dedups
  content-addressed writes, applies injected store faults, and merges
  manifest marks idempotently.  Everything it does is safe under
  duplicate delivery and concurrent writers: a put is
  ``atomic_replace`` of validated bytes, so two racing writers of the
  same content land on one artifact with no torn state;
* :class:`LocalStore` — a client that calls the server directly
  (single-host campaigns, tests, and the protocol's reference
  implementation);
* :class:`RemoteStoreClient` — a client whose every operation crosses a
  :class:`~repro.campaign.cluster.transport.NodeTransport` RPC wrapped
  in the retry/backoff policy: transient store failures and flaky links
  are retried with capped-exponential seeded-jitter backoff, a
  driver<->store partition is ridden out (each retry advances the
  partition's op-count window), and exhausted operations land in a
  dead-letter file instead of crashing the fleet.

Content addressing does the heavy lifting for multi-writer safety: the
client sends ``(relpath, bytes, sha256)``, the server verifies the
digest before touching disk (a corrupted transfer is a *non*-retryable
error — re-sending garbage would not cure it, the client must re-read
and re-digest), and a write whose target already holds those exact
bytes is acknowledged as a dedup instead of re-written.
"""
from __future__ import annotations

import hashlib
import os
import threading
from collections import Counter

from repro import obs
from repro.campaign.cluster.retry import (DeadLetterFile, RetryPolicy,
                                          StoreWriteError, call_with_retry)
from repro.core.paths import atomic_replace


def blob_digest(data: bytes) -> str:
    return hashlib.sha256(data).hexdigest()


def file_digest(path: str) -> str:
    with open(path, "rb") as f:
        return blob_digest(f.read())


def _unit_of(relpath: str) -> str | None:
    """Unit key of a ``units/<key>/...`` relpath (None otherwise)."""
    parts = relpath.split("/")
    if len(parts) >= 3 and parts[0] == "units":
        return parts[1]
    return None


class StoreServer:
    """Store-host request handler over one campaign's directory.

    All paths are relpaths under the campaign dir; anything escaping it
    (absolute, ``..``) is rejected outright.  Injected faults come from
    the campaign's :class:`~repro.campaign.workqueue.FaultPlan`:
    ``store_transient`` fails the first N writes touching a unit with a
    retryable :class:`StoreWriteError`, ``store_permanent`` fails every
    write for that unit forever (the retry layer must exhaust and
    dead-letter).  ``stats`` counts puts/gets/dedups/injected failures —
    the chaos tests' evidence that the faults actually fired."""

    def __init__(self, campaign, fault_plan=None):
        self.campaign = campaign
        self.plan = fault_plan
        self.stats: Counter = Counter()
        self._lock = threading.Lock()
        self._transient_left: dict[str, int] = {}

    # ---------------- fault injection ---------------- #
    def _maybe_fail_write(self, relpath: str) -> None:
        if self.plan is None:
            return
        key = _unit_of(relpath)
        if key is None:
            return
        if self.plan.store_permanent_for(key):
            self.stats["injected_permanent"] += 1
            raise StoreWriteError(
                f"injected permanent store failure for unit {key}")
        with self._lock:
            left = self._transient_left.get(key)
            if left is None:
                left = self.plan.store_transient_for(key)
            if left > 0:
                self._transient_left[key] = left - 1
                self.stats["injected_transient"] += 1
                raise StoreWriteError(
                    f"injected transient store failure for unit {key} "
                    f"({left - 1} left)")
            self._transient_left[key] = 0

    # ---------------- request handlers ---------------- #
    def _resolve(self, relpath: str) -> str:
        if os.path.isabs(relpath) or ".." in relpath.split("/"):
            raise ValueError(f"unsafe store path {relpath!r}")
        return os.path.join(self.campaign.dir, relpath)

    def put_file(self, relpath: str, data: bytes, digest: str) -> str:
        """Store one blob; returns ``"stored"`` or ``"deduped"``.

        Digest validation happens before the fault check: corruption is
        a protocol error, never retried."""
        if blob_digest(data) != digest:
            raise ValueError(
                f"digest mismatch for {relpath!r}: transfer corrupted")
        self._maybe_fail_write(relpath)
        path = self._resolve(relpath)
        # one write at a time: atomic_replace's tmp name is pid-unique,
        # but node workers are threads of THIS process, so duplicate
        # uploads of the same relpath (speculation, re-delivered RPCs)
        # would race on the same tmp file without the lock
        with self._lock:
            if os.path.exists(path) and file_digest(path) == digest:
                self.stats["deduped_puts"] += 1
                return "deduped"
            os.makedirs(os.path.dirname(path), exist_ok=True)
            with atomic_replace(path) as tmp:
                with open(tmp, "wb") as f:
                    f.write(data)
        # a freshly uploaded table must not be shadowed by a table the
        # driver cached from an earlier (partial) attempt
        key = _unit_of(relpath)
        if key is not None:
            self.campaign._table_cache.pop(key, None)
        self.stats["puts"] += 1
        return "stored"

    def get_file(self, relpath: str) -> bytes | None:
        self.stats["gets"] += 1
        path = self._resolve(relpath)
        if not os.path.isfile(path):
            return None
        with open(path, "rb") as f:
            return f.read()

    def list_files(self, prefix: str) -> dict[str, str]:
        """relpath -> sha256 for every file under ``prefix``."""
        self.stats["lists"] += 1
        root = self._resolve(prefix)
        out: dict[str, str] = {}
        if not os.path.isdir(root):
            return out
        for dirpath, _, names in os.walk(root):
            for name in names:
                full = os.path.join(dirpath, name)
                rel = os.path.relpath(full, self.campaign.dir)
                out[rel.replace(os.sep, "/")] = file_digest(full)
        return out

    def mark_unit(self, unit_key: str, fields: dict) -> None:
        """Manifest merge — naturally idempotent (same fields twice is
        one state), which is what makes duplicated RPCs harmless."""
        self._maybe_fail_write(f"units/{unit_key}/__manifest__")
        self.stats["marks"] += 1
        self.campaign.mark_unit(unit_key, **fields)


class LocalStore:
    """Direct (in-process, no transport) client — the reference shape of
    the store protocol, and what single-host campaigns use."""

    def __init__(self, server: StoreServer):
        self.server = server

    def put_file(self, relpath: str, data: bytes, digest: str) -> str:
        return self.server.put_file(relpath, data, digest)

    def get_file(self, relpath: str) -> bytes | None:
        return self.server.get_file(relpath)

    def list_files(self, prefix: str) -> dict[str, str]:
        return self.server.list_files(prefix)

    def mark_unit(self, unit_key: str, fields: dict) -> None:
        self.server.mark_unit(unit_key, fields)


class RemoteStoreClient:
    """Store client whose every call crosses the transport under the
    retry policy.

    ``partition_window=(after, n)`` models a driver<->store partition
    that heals: this client's ops ``after .. after+n-1`` (0-based,
    counting every attempt) fail with a retryable transport error.
    Counting *attempts* makes healing deterministic — a retried
    operation advances the window on its own, so a policy with
    ``max_attempts > n`` always rides the partition out without any
    wall-clock coupling."""

    def __init__(self, server: StoreServer, transport, link_id: str, *,
                 policy: RetryPolicy | None = None,
                 dead_letters: DeadLetterFile | None = None,
                 partition_window: tuple[int, int] | None = None,
                 sleep=None):
        self.server = server
        self.transport = transport
        self.link_id = link_id
        self.policy = policy or RetryPolicy()
        self.dead_letters = dead_letters
        self.partition_window = partition_window
        self.sleep = sleep      # None -> real time.sleep in call_with_retry
        self.stats: Counter = Counter()
        self._ops = 0
        self._lock = threading.Lock()

    def _attempt(self, fn, *args):
        with self._lock:
            op_index = self._ops
            self._ops += 1
        if self.partition_window is not None:
            after, n = self.partition_window
            if after <= op_index < after + n:
                self.stats["partitioned_ops"] += 1
                from repro.campaign.cluster.retry import TransportError
                raise TransportError(
                    f"store unreachable: driver<->store partition "
                    f"(op {op_index} in window [{after}, {after + n}))")
        return self.transport.rpc(self.link_id, fn, *args,
                                  timeout_s=self.policy.timeout_s)

    def _call(self, op: str, op_key: str, fn, *args):
        kw = {} if self.sleep is None else {"sleep": self.sleep}
        with obs.span(op, "store", op=op, key=op_key,
                      client=self.link_id) as live:

            def on_retry(attempt, exc):
                self.stats["retries"] += 1
                if live is not None:
                    live.attrs["attempts"] = attempt + 2
                    obs.event("store.retry", "store", op=op, key=op_key,
                              attempt=attempt + 1,
                              error=type(exc).__name__,
                              client=self.link_id)

            out = call_with_retry(
                lambda: self._attempt(fn, *args), self.policy, op=op,
                op_key=op_key, dead_letters=self.dead_letters,
                on_retry=on_retry, **kw)
        self.stats["ops"] += 1
        return out

    def put_file(self, relpath: str, data: bytes, digest: str) -> str:
        return self._call("store.put", relpath, self.server.put_file,
                          relpath, data, digest)

    def get_file(self, relpath: str) -> bytes | None:
        return self._call("store.get", relpath, self.server.get_file,
                          relpath)

    def list_files(self, prefix: str) -> dict[str, str]:
        return self._call("store.list", prefix, self.server.list_files,
                          prefix)

    def mark_unit(self, unit_key: str, fields: dict) -> None:
        self._call("store.mark", unit_key, self.server.mark_unit,
                   unit_key, fields)
