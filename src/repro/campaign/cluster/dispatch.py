"""Multi-node campaign dispatch: the driver side of the cluster.

:class:`ClusterCampaignScheduler` is the node-spanning sibling of
:class:`~repro.campaign.workqueue.ProcessCampaignScheduler` — same
:class:`~repro.campaign.workqueue.DispatchCore` (attempt budgets,
requeue on worker loss, straggler speculation, first-result-wins), same
:class:`~repro.runtime.fault_tolerance.HeartbeatMonitor` /
:class:`~repro.runtime.fault_tolerance.StragglerPolicy`, with "worker"
instantiated as a node handle instead of a process: ``send_unit`` pushes
a dispatch message over the (possibly lossy) transport, liveness is the
node thread, and a reap sets the node's stop event instead of
``terminate()``.

What the cluster adds on top of the process scheduler:

* the driver's own store access (manifest marks) crosses the transport
  through a retry-wrapped :class:`RemoteStoreClient` — a driver<->store
  partition stalls marks, the retry layer rides out windows shorter
  than its budget, and marks that still exhaust are *deferred*, not
  fatal: the driver re-flushes every deferred mark after the dispatch
  loop (the partition has healed by then — its op-count window was
  spent during the retries), so the manifest converges even when the
  partition outlives a single retry cycle;
* silence is a first-class failure: a dropped dispatch message leaves a
  node idle while the driver believes it busy — no process analogue
  exists, but no new machinery is needed either, because the heartbeat
  timeout already treats "no progress" and "hung" identically and the
  requeue path recovers both;
* completion acks may be dropped *after* the artifacts landed; the
  requeued attempt finds every pair already uploaded, resumes in one
  beat, and re-acks — which is why ``done`` is only sent after the full
  upload.

Bit-identity across all of this is inherited, not re-proven: nodes
measure each pair on a pair-seeded device, so whichever node (or
however many nodes, speculatively) measures a pair produces the same
bytes, and the store's content-addressed dedup makes every duplicate
write a no-op.
"""
from __future__ import annotations

import os
import time

from repro.campaign.cluster.node import NodeWorker
from repro.campaign.cluster.remote_store import (RemoteStoreClient,
                                                 StoreServer)
from repro.campaign.cluster.retry import (DeadLetterFile, RetriesExhausted,
                                          RetryPolicy)
from repro.campaign.cluster.transport import (POISON, SimTransport,
                                              TransportFaults)
from repro.campaign.spec import CampaignSpec, UnitSpec
from repro.campaign.store import Campaign
from repro.campaign.workqueue import DispatchCore, FaultPlan, _trip_once


class _NodeHandle:
    """DispatchCore's worker protocol over one node's links."""

    def __init__(self, node: NodeWorker, inbox, outbox):
        self.node = node
        self.inbox = inbox          # driver -> node (dispatch)
        self.outbox = outbox        # node -> driver (acks + heartbeats)
        self.inflight: str | None = None

    def send_unit(self, key: str, ctx: str | None = None) -> None:
        # ctx is the driver's attempt-span id: the node opens its
        # unit.exec span under it so per-process span files stitch into
        # one driver->node tree
        self.inbox.send(("unit", key, ctx))

    @property
    def alive(self) -> bool:
        return self.node.alive


class ClusterCampaignScheduler:
    """Drive a campaign's pending units across N (simulated) nodes.

    The driver owns all bookkeeping and is the only manifest writer;
    nodes only ever touch their own unit's artifact files, through the
    store server's idempotent content-addressed writes.  ``retry_policy``
    governs every transport-crossing store operation (driver marks and
    node uploads alike); the sim default trades the production-shaped
    waits of :class:`RetryPolicy` for millisecond backoffs so chaos
    tests stay fast."""

    #: sim-scaled retry policy: same shape, millisecond waits
    SIM_POLICY = RetryPolicy(max_attempts=8, base_s=0.005, cap_s=0.05,
                             timeout_s=5.0)

    def __init__(self, spec: CampaignSpec, campaign: Campaign, *,
                 n_nodes: int = 3,
                 heartbeat_timeout_s: float = 60.0,
                 straggler_ratio: float = 3.0,
                 speculate: bool = True,
                 fault_plan: FaultPlan | None = None,
                 retry_policy: RetryPolicy | None = None,
                 scratch_root: str | None = None,
                 poll_s: float = 0.02,
                 clock=time.monotonic,
                 verbose: bool = False):
        self.spec = spec
        self.campaign = campaign
        self.n_nodes = max(1, int(n_nodes))
        self.heartbeat_timeout_s = heartbeat_timeout_s
        self.straggler_ratio = straggler_ratio
        self.speculate = speculate
        self.fault_plan = fault_plan or FaultPlan()
        self.retry_policy = retry_policy or self.SIM_POLICY
        # node scratch disks default to a sibling of the campaign dir:
        # inside the store root but outside any campaign, so store
        # listings and digests never see them
        self.scratch_root = scratch_root or os.path.join(
            os.path.dirname(campaign.dir),
            f"_node_scratch_{campaign.campaign_id}")
        self.poll_s = poll_s
        self.clock = clock
        self.verbose = verbose
        self.trace = False          # protocol parity with the process
                                    # scheduler; cluster runs refuse trace
        self.spans = False          # span profiling (set by CampaignRunner)
        self.stats = {"crashed_nodes": 0, "hung_nodes": 0,
                      "respawned_nodes": 0, "deferred_marks": 0}

    # -------------------------------------------------------------- #
    def run(self, todo: list[UnitSpec]) -> dict:
        from repro.runtime.fault_tolerance import (HeartbeatMonitor,
                                                   StragglerPolicy)
        if self.trace:
            raise ValueError(
                "executor='cluster' cannot record traces: a trace is a "
                "host-local event stream, and requeued/speculated node "
                "attempts would each hold fragments — run trace "
                "campaigns with executor='processes'")
        if not todo:
            return {}
        plan = self.fault_plan
        self.transport = SimTransport(TransportFaults.from_plan(plan),
                                      clock=self.clock)
        self.server = StoreServer(self.campaign, fault_plan=plan)
        dl_dir = os.path.join(self.campaign.dir, "deadletter")
        self.driver_store = RemoteStoreClient(
            self.server, self.transport, "driver",
            policy=self.retry_policy,
            dead_letters=DeadLetterFile(os.path.join(dl_dir,
                                                     "driver.jsonl")),
            partition_window=plan.partition_window())
        self._dirty_marks: dict[str, dict] = {}
        self._next_nid = 0
        self._nodes: dict[str, _NodeHandle] = {}

        hb = HeartbeatMonitor(0, timeout_s=self.heartbeat_timeout_s,
                              clock=self.clock)
        sp = StragglerPolicy(ratio=self.straggler_ratio, clock=self.clock)
        core = DispatchCore(self.campaign, [u.key for u in todo],
                            retries=self.spec.retries, heartbeat=hb,
                            straggler=sp, stats=self.stats,
                            mark_unit=self._mark_unit,
                            clock=self.clock, verbose=self.verbose)

        def reap(nid: str, reason: str) -> None:
            h = self._nodes.pop(nid, None)
            if h is None:
                return
            hb.remove(nid)
            h.node.stop()
            key = h.inflight
            if self.verbose:
                print(f"  node {nid} {reason}"
                      + (f" while running [{key}]" if key else ""))
            if key is not None:
                core.worker_lost(key, f"node {reason}", worker=h)

        def drain() -> int:
            n = 0
            for nid, h in list(self._nodes.items()):
                for msg in h.outbox.recv_ready():
                    n += 1
                    hb.beat(nid)
                    kind = msg[0]
                    if kind == "done":
                        _, _, key, wall, n_pairs = msg
                        core.finish_done(self._nodes.get(nid), key,
                                         wall, n_pairs)
                    elif kind == "failed":
                        _, _, key, error = msg
                        core.release(self._nodes.get(nid), key,
                                     status="failed")
                        core.record_failure(key, error)
                    # "ready"/"start"/"beat" only feed the monitor
            if n == 0 and self.poll_s:
                time.sleep(self.poll_s)
            return n

        for _ in range(min(self.n_nodes, len(core.pending))):
            self._spawn_node(hb)

        try:
            while not core.all_resolved:
                idle = [h for h in self._nodes.values()
                        if h.inflight is None]
                while idle and core.pending:
                    key = core.next_pending()
                    if key is None:
                        break
                    core.dispatch(idle.pop(), key)
                while (core.pending
                       and len(self._nodes) < min(self.n_nodes,
                                                  len(core.pending))):
                    self._spawn_node(hb)
                    self.stats["respawned_nodes"] += 1
                if self.speculate and not core.pending:
                    idle = [h for h in self._nodes.values()
                            if h.inflight is None]
                    cand = core.speculation_candidate()
                    if idle and cand is not None:
                        core.dispatch(idle[0], cand, speculative=True)
                drain()
                for nid, h in self._nodes.items():
                    if h.inflight is None:
                        hb.beat(nid)
                for nid in [n for n, h in list(self._nodes.items())
                            if not h.alive]:
                    self.stats["crashed_nodes"] += 1
                    reap(nid, "crashed")
                for nid in hb.dead():
                    if self._nodes.get(nid) is not None:
                        self.stats["hung_nodes"] += 1
                        reap(nid, "hung (heartbeat timeout)")
                core.finalize_exhausted()
        finally:
            self._shutdown()
            core.obs_close()
        self._flush_marks()
        # fold the data plane's evidence into the campaign stats
        for k, v in self.server.stats.items():
            self.stats[f"store_{k}"] = v
        for k, v in self.transport.counters.items():
            self.stats[f"transport_{k}"] = v
        for k, v in self.driver_store.stats.items():
            self.stats[f"driver_{k}"] = v
        return core.ordered_outcomes()

    # -------------------------------------------------------------- #
    # driver-side store writes: retried, partition-aware, never fatal
    # -------------------------------------------------------------- #
    def _mark_unit(self, key: str, **fields) -> None:
        self._dirty_marks[key] = {**self._dirty_marks.get(key, {}),
                                  **fields}
        try:
            self.driver_store.mark_unit(key, self._dirty_marks[key])
        except RetriesExhausted:
            # the partition outlived one retry cycle: keep the fields,
            # keep dispatching, re-deliver once the loop is done
            self.stats["deferred_marks"] += 1
        else:
            self._dirty_marks.pop(key, None)

    def _flush_marks(self) -> None:
        for key, fields in list(self._dirty_marks.items()):
            try:
                self.driver_store.mark_unit(key, fields)
            except RetriesExhausted:
                self.stats["deferred_marks"] += 1   # dead-lettered; the
            else:                                   # manifest is stale
                self._dirty_marks.pop(key, None)    # for this key

    # -------------------------------------------------------------- #
    def _spawn_node(self, hb) -> None:
        nid = f"n{self._next_nid}"
        self._next_nid += 1
        inbox = self.transport.channel(f"driver->{nid}")
        outbox = self.transport.channel(f"{nid}->driver")
        store = RemoteStoreClient(
            self.server, self.transport, nid, policy=self.retry_policy,
            dead_letters=DeadLetterFile(
                os.path.join(self.campaign.dir, "deadletter",
                             f"{nid}.jsonl")))
        node = NodeWorker(
            nid, self.spec, store, self.scratch_root, inbox, outbox,
            campaign_id=self.campaign.campaign_id,
            fault_plan=self.fault_plan, spans=self.spans,
            claim_fault=lambda key, kind: _trip_once(self.campaign, key,
                                                     kind))
        node.start()
        self._nodes[nid] = _NodeHandle(node, inbox, outbox)
        hb.register(nid)

    def _shutdown(self) -> None:
        for h in self._nodes.values():
            h.inbox.send_raw(POISON)        # control plane: chaos-exempt
            h.node.stop()
        deadline = time.monotonic() + 5.0
        for h in self._nodes.values():
            h.node.join(timeout=max(0.1, deadline - time.monotonic()))
        self._nodes.clear()
