"""Cross-device aggregation: merge a campaign's per-unit latency tables
into the paper's comparison artifacts.

The headline result (Table II) is exactly this shape — one row per GPU,
min/mean/max of the per-pair worst- and best-case switching latencies —
except the paper built it by hand from three separate tool runs.  Here it
falls out of any campaign: every ``done`` unit contributes a row, and the
markdown renderer produces the cross-device table for reports/CI artifacts.
"""
from __future__ import annotations

import numpy as np

from repro.campaign.store import Campaign
from repro.core.freqkey import has_domain, transition_class


def unit_summaries(campaign: Campaign) -> dict[str, dict]:
    """`LatencyTable.summary()` (Table II analogue) per finished unit."""
    return {key: table.summary()
            for key, table in sorted(campaign.tables().items())}


def comparison_rows(campaign: Campaign) -> list[dict]:
    """Flat cross-device rows ready for tabulation or JSON export."""
    rows = []
    for key, s in unit_summaries(campaign).items():
        if not s:
            rows.append({"unit": key, "n_pairs": 0})
            continue
        w, b = s["worst_case"], s["best_case"]
        rows.append({
            "unit": key, "n_pairs": s["n_pairs"],
            "worst_min_ms": w["min_ms"], "worst_mean_ms": w["mean_ms"],
            "worst_max_ms": w["max_ms"],
            "best_min_ms": b["min_ms"], "best_mean_ms": b["mean_ms"],
            "best_max_ms": b["max_ms"],
            "one_cluster_fraction": s["one_cluster_fraction"],
            "max_clusters": s["max_clusters"],
        })
    return rows


def comparison_markdown(campaign: Campaign) -> str:
    """Table II across the campaign's devices, as markdown."""
    rows = comparison_rows(campaign)
    lines = [
        "| device unit | pairs | worst min/mean/max (ms) | "
        "best min/mean/max (ms) | 1-cluster | max clusters |",
        "|---|---:|---|---|---:|---:|",
    ]
    for r in rows:
        if r.get("n_pairs", 0) == 0:
            lines.append(f"| {r['unit']} | 0 | – | – | – | – |")
            continue
        lines.append(
            f"| {r['unit']} | {r['n_pairs']} "
            f"| {r['worst_min_ms']:.1f} / {r['worst_mean_ms']:.1f} / "
            f"{r['worst_max_ms']:.1f} "
            f"| {r['best_min_ms']:.1f} / {r['best_mean_ms']:.1f} / "
            f"{r['best_max_ms']:.1f} "
            f"| {r['one_cluster_fraction']:.0%} | {r['max_clusters']} |")
    return "\n".join(lines)


def campaign_has_domains(campaign: Campaign) -> bool:
    """True iff any finished unit measured domain-encoded operating points.
    The gate for every domain-aware report section: campaigns of purely
    single-domain devices keep byte-identical report output."""
    return any(has_domain(fi) or has_domain(ft)
               for table in campaign.tables().values()
               for fi, ft in table.pairs)


def domain_rows(campaign: Campaign) -> list[dict]:
    """Per-unit latency breakdown by transition class — the
    cross-architecture extension of Table II.  One row per (unit,
    class), where a class is a domain ("core", "uncore", "ecore", ...)
    for same-domain moves or "a->b" for cross-domain ones; bare-MHz pairs
    land in the implicit "core" class."""
    rows = []
    for key, table in sorted(campaign.tables().items()):
        groups: dict[str, list] = {}
        for (fi, ft), p in table.pairs.items():
            if p.status != "ok" or not p.clean.size:
                continue
            groups.setdefault(transition_class(fi, ft), []).append(p)
        for cls in sorted(groups):
            worst = np.array([p.worst_case for p in groups[cls]])
            best = np.array([p.best_case for p in groups[cls]])
            rows.append({
                "unit": key, "transition": cls, "n_pairs": int(worst.size),
                "worst_mean_ms": float(worst.mean()) * 1e3,
                "worst_max_ms": float(worst.max()) * 1e3,
                "best_mean_ms": float(best.mean()) * 1e3,
            })
    return rows


def domain_markdown(campaign: Campaign) -> str:
    """Markdown twin of :func:`domain_rows`."""
    lines = [
        "| device unit | transition | pairs | worst mean/max (ms) | "
        "best mean (ms) |",
        "|---|---|---:|---|---:|",
    ]
    for r in domain_rows(campaign):
        lines.append(
            f"| {r['unit']} | {r['transition']} | {r['n_pairs']} "
            f"| {r['worst_mean_ms']:.1f} / {r['worst_max_ms']:.1f} "
            f"| {r['best_mean_ms']:.1f} |")
    return "\n".join(lines)


def asymmetry_rows(campaign: Campaign) -> list[dict]:
    """Fig. 4 analogue per unit, as flat rows (None = no data)."""
    rows = []
    for key, table in sorted(campaign.tables().items()):
        a = table.asymmetry()
        up, dn = a.get("increase", {}), a.get("decrease", {})
        if not up or not dn:
            rows.append({"unit": key, "up_mean_ms": None,
                         "down_mean_ms": None, "ratio": None})
            continue
        rows.append({"unit": key, "up_mean_ms": up["mean_ms"],
                     "down_mean_ms": dn["mean_ms"],
                     "ratio": up["mean_ms"] / max(dn["mean_ms"], 1e-9)})
    return rows


def asymmetry_markdown(campaign: Campaign) -> str:
    """Fig. 4 analogue per unit: increase- vs decrease-transition means."""
    lines = ["| device unit | up mean (ms) | down mean (ms) | up/down |",
             "|---|---:|---:|---:|"]
    for r in asymmetry_rows(campaign):
        if r["ratio"] is None:
            lines.append(f"| {r['unit']} | – | – | – |")
            continue
        lines.append(f"| {r['unit']} | {r['up_mean_ms']:.1f} "
                     f"| {r['down_mean_ms']:.1f} | {r['ratio']:.2f} |")
    return "\n".join(lines)


def merged_pair_distribution(campaign: Campaign, unit_key: str,
                             f_init: float, f_target: float) -> np.ndarray:
    """DBSCAN-cleaned samples for one (unit, pair) — the regression layer's
    input distribution."""
    table = campaign.load_table(unit_key)
    pr = table.lookup(f_init, f_target)
    if pr is None:
        return np.empty(0)
    return pr.clean


def report_dict(campaign: Campaign) -> dict:
    """The full campaign report as one JSON-ready document — the
    machine-readable twin of :func:`report_markdown` (``campaign report
    --json``), mirroring the ``diff --json`` precedent."""
    states = campaign.unit_states()
    doc = {
        "campaign_id": campaign.campaign_id,
        "name": campaign.spec.name,
        "units_total": len(states),
        "units_done": sum(1 for st in states.values()
                          if st.get("status") == "done"),
        "units": {key: states[key] for key in sorted(states)},
        "comparison": comparison_rows(campaign),
        "asymmetry": asymmetry_rows(campaign),
    }
    if campaign_has_domains(campaign):
        doc["domains"] = domain_rows(campaign)
    return doc


def report_markdown(campaign: Campaign) -> str:
    """Full campaign report: status, cross-device Table II, asymmetry."""
    states = campaign.unit_states()
    n_done = sum(1 for st in states.values() if st.get("status") == "done")
    lines = [
        f"# Campaign `{campaign.campaign_id}` — {campaign.spec.name}",
        "",
        f"{n_done}/{len(states)} units done.",
        "",
        "## Unit status",
        "",
        "| unit | status | attempts | pairs | wall (s) |",
        "|---|---|---:|---:|---:|",
    ]
    for key, st in sorted(states.items()):
        wall = st.get("wall_s")
        lines.append(
            f"| {key} | {st.get('status', '?')} | {st.get('attempts', 0)} "
            f"| {st.get('n_pairs', '–')} "
            f"| {f'{wall:.1f}' if wall is not None else '–'} |")
        if st.get("error"):
            lines.append(f"| | `{st['error']}` | | | |")
    lines += ["", "## Cross-device switching latency (Table II analogue)",
              "", comparison_markdown(campaign)]
    if campaign_has_domains(campaign):
        lines += ["", "## Latency by transition class (domain breakdown)",
                  "", domain_markdown(campaign)]
    lines += ["", "## Transition asymmetry (Fig. 4 analogue)",
              "", asymmetry_markdown(campaign), ""]
    return "\n".join(lines)
