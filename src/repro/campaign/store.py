"""Content-addressed on-disk artifact store for measurement campaigns.

Layout (everything human-readable, everything atomic-replace written)::

    <root>/<campaign_id>/
        spec.json                  # canonical CampaignSpec
        manifest.json              # per-unit status / attempts / wall time
        units/<unit_key>/
            session/               # MeasurementSession state (resumable:
                                   #   session.json + pairs/*.json)
            table/                 # per-pair CSVs, LATEST naming convention
            result.json            # pair index + simulator ground truth
            traces/<name>/         # telemetry traces (repro.trace:
                                   #   header.jsonl + events.npz)

The campaign id is the hash of the spec (:meth:`CampaignSpec.campaign_id`),
so re-running an identical spec lands in the same directory and *resumes*:
finished units are skipped via the manifest, half-finished units resume at
pair granularity via the embedded session state.  Raw samples live in the
``table/`` CSVs (``latency_s,is_outlier`` — :class:`LatencyTable`'s format),
which is what the aggregation and regression layers read back.
"""
from __future__ import annotations

import json
import os
import threading
import time

import numpy as np

from repro.campaign.spec import CampaignSpec
from repro.core.latency_table import LatencyTable, PairResult
from repro.core.paths import atomic_replace, campaigns_dir

_SPEC = "spec.json"
_MANIFEST = "manifest.json"
_RESULT = "result.json"
_UNITS = "units"

UNIT_PENDING = "pending"
UNIT_RUNNING = "running"
UNIT_DONE = "done"
UNIT_FAILED = "failed"


def _atomic_write_json(path: str, doc: dict) -> None:
    with atomic_replace(path) as tmp:
        with open(tmp, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)


class Campaign:
    """Handle to one campaign's artifacts (spec + manifest + unit dirs)."""

    def __init__(self, root: str, spec: CampaignSpec,
                 campaign_id: str | None = None):
        self.spec = spec
        self.campaign_id = campaign_id or spec.campaign_id()
        self.dir = os.path.join(root, self.campaign_id)
        self._lock = threading.Lock()
        # unit results are write-once (save invalidates), so reloading the
        # CSVs for every report section / benchmark row would be pure waste
        self._table_cache: dict[str, LatencyTable] = {}

    # -------------------------------------------------------------- #
    # paths
    # -------------------------------------------------------------- #
    def unit_dir(self, unit_key: str) -> str:
        return os.path.join(self.dir, _UNITS, unit_key)

    def session_dir(self, unit_key: str) -> str:
        return os.path.join(self.unit_dir(unit_key), "session")

    def table_dir(self, unit_key: str) -> str:
        return os.path.join(self.unit_dir(unit_key), "table")

    def _result_path(self, unit_key: str) -> str:
        return os.path.join(self.unit_dir(unit_key), _RESULT)

    # -------------------------------------------------------------- #
    # manifest
    # -------------------------------------------------------------- #
    def _manifest_path(self) -> str:
        return os.path.join(self.dir, _MANIFEST)

    def init(self) -> None:
        """Create the on-disk skeleton (idempotent; resumes keep state)."""
        os.makedirs(os.path.join(self.dir, _UNITS), exist_ok=True)
        spec_path = os.path.join(self.dir, _SPEC)
        if not os.path.exists(spec_path):
            _atomic_write_json(spec_path, self.spec.to_dict())
        if not os.path.exists(self._manifest_path()):
            _atomic_write_json(self._manifest_path(), {
                "campaign_id": self.campaign_id,
                "name": self.spec.name,
                "created_at": time.time(),
                "units": {u.key: {"status": UNIT_PENDING, "attempts": 0}
                          for u in self.spec.units()},
            })

    def manifest(self) -> dict:
        with open(self._manifest_path()) as f:
            return json.load(f)

    def unit_states(self) -> dict[str, dict]:
        return self.manifest()["units"]

    def mark_unit(self, unit_key: str, **fields) -> None:
        """Merge ``fields`` into one unit's manifest entry (thread-safe
        within this process; writes are atomic against crashes)."""
        with self._lock:
            doc = self.manifest()
            doc["units"].setdefault(unit_key, {"attempts": 0}).update(fields)
            _atomic_write_json(self._manifest_path(), doc)

    def done_units(self) -> list[str]:
        return sorted(k for k, st in self.unit_states().items()
                      if st.get("status") == UNIT_DONE)

    # -------------------------------------------------------------- #
    # unit results
    # -------------------------------------------------------------- #
    def save_unit_result(self, unit_key: str, table: LatencyTable,
                         ground_truth: dict | None = None) -> None:
        """Persist one finished unit: per-pair CSVs + the metadata the CSVs
        cannot carry (status, cluster structure, simulator ground truth).

        Ground truth is MERGED with any previously stored values: a
        re-measured unit's new session never re-visits already-persisted
        pairs, so its device history covers only the remainder — truths
        stored by an earlier save must survive.  (A unit interrupted
        before its FIRST save has no stored truths to merge; the oracle
        for its pre-crash pairs lived only in the dead process, so gt
        consumers must treat missing pairs as unknown, not zero.)"""
        if os.path.exists(self._result_path(unit_key)):
            ground_truth = {**self.ground_truth(unit_key),
                            **(ground_truth or {})}
        self._table_cache.pop(unit_key, None)
        tdir = self.table_dir(unit_key)
        os.makedirs(tdir, exist_ok=True)
        table.save_csv(tdir)
        doc = {
            "unit_key": unit_key,
            "device_name": table.device_name,
            "device_index": table.device_index,
            "hostname": table.hostname,
            "pairs": [
                {"f_init": fi, "f_target": ft, "status": pr.status,
                 "n_clusters": pr.n_clusters,
                 "silhouette": (None if not np.isfinite(pr.silhouette)
                                else float(pr.silhouette)),
                 "csv": table.csv_name(fi, ft)}
                for (fi, ft), pr in sorted(table.pairs.items())],
            "ground_truth": [[fi, ft, float(v)] for (fi, ft), v in
                             sorted((ground_truth or {}).items())],
        }
        _atomic_write_json(self._result_path(unit_key), doc)

    def has_unit_result(self, unit_key: str) -> bool:
        return os.path.exists(self._result_path(unit_key))

    def load_table(self, unit_key: str) -> LatencyTable:
        """Rebuild the unit's :class:`LatencyTable` from CSVs + result.json
        (same clean/outlier split the analysis originally produced)."""
        cached = self._table_cache.get(unit_key)
        if cached is not None:
            return cached
        with open(self._result_path(unit_key)) as f:
            doc = json.load(f)
        table = LatencyTable(doc["device_name"], doc["device_index"],
                             doc["hostname"])
        for entry in doc["pairs"]:
            lat, is_out = LatencyTable.load_csv(
                os.path.join(self.table_dir(unit_key), entry["csv"]))
            clean = lat[~is_out]
            if clean.size == 0:            # analyse_pair's fallback
                clean = lat
            sil = entry.get("silhouette")
            table.add(PairResult(
                float(entry["f_init"]), float(entry["f_target"]),
                lat, clean, lat[is_out], int(entry["n_clusters"]),
                float("nan") if sil is None else float(sil),
                entry["status"],
                # cluster ids don't survive the CSV, but the per-sample
                # outlier split (what save_csv re-emits) does
                labels=np.where(is_out, -1, 0)))
        self._table_cache[unit_key] = table
        return table

    def ground_truth(self, unit_key: str) -> dict[tuple[float, float], float]:
        """Per-pair max true latency the simulator logged (empty for real
        hardware backends, which have no oracle)."""
        with open(self._result_path(unit_key)) as f:
            doc = json.load(f)
        return {(float(fi), float(ft)): float(v)
                for fi, ft, v in doc.get("ground_truth", [])}

    def tables(self) -> dict[str, LatencyTable]:
        return {k: self.load_table(k) for k in self.done_units()
                if self.has_unit_result(k)}

    # -------------------------------------------------------------- #
    # bit-identity witnesses: two campaigns measured the same thing iff
    # these digests match, regardless of which schedule (serial, process
    # fleet, node cluster) or how many recovered attempts produced them
    # -------------------------------------------------------------- #
    def unit_content_digests(self) -> dict[str, str]:
        """Per-unit sha256 over the unit's *measurement* artifacts: the
        result's pair index (status, cluster structure, silhouette, CSV
        names — byte-stable: sorted keys, no wall times) plus every
        table CSV in sorted name order.  The simulator ground-truth
        section is deliberately excluded: the oracle for a crashed
        attempt's calibration probes dies with the attempt (see
        :meth:`save_unit_result`), so gt is attempt-path metadata, not
        measurement content — the bit-identity contract covers what the
        paper's analysis consumes, the latency samples and their
        clustering."""
        import hashlib
        out: dict[str, str] = {}
        for key in self.done_units():
            if not self.has_unit_result(key):
                continue
            with open(self._result_path(key)) as f:
                doc = json.load(f)
            h = hashlib.sha256()
            h.update(json.dumps(
                {k: doc.get(k) for k in ("unit_key", "device_name",
                                         "device_index", "hostname",
                                         "pairs")},
                sort_keys=True).encode())
            tdir = self.table_dir(key)
            if os.path.isdir(tdir):
                for name in sorted(os.listdir(tdir)):
                    path = os.path.join(tdir, name)
                    if name.endswith(".csv") and os.path.isfile(path):
                        h.update(name.encode())
                        with open(path, "rb") as f:
                            h.update(f.read())
            out[key] = h.hexdigest()
        return out

    def content_digest(self) -> str:
        """Whole-campaign digest over the sorted per-unit digests — the
        chaos matrix's acceptance gate compares this between a faulted
        cluster run and the serial single-host reference."""
        import hashlib
        h = hashlib.sha256()
        for key, digest in sorted(self.unit_content_digests().items()):
            h.update(f"{key}:{digest}\n".encode())
        return h.hexdigest()

    def reset_unit(self, unit_key: str) -> None:
        """Forget a unit's measurement so the next run re-measures it
        from scratch (the monitor->scheduler requeue loop: a confirmed
        drift alert invalidates the data, not just flags it).  Alerts
        and traces survive as the evidence trail; session state, tables
        and the result are removed so the fresh attempt cannot resume
        into the suspect pairs."""
        import shutil
        self._table_cache.pop(unit_key, None)
        for path in (self.session_dir(unit_key), self.table_dir(unit_key)):
            shutil.rmtree(path, ignore_errors=True)
        result = self._result_path(unit_key)
        if os.path.exists(result):
            os.remove(result)
        self.mark_unit(unit_key, status=UNIT_PENDING, attempts=0,
                       error=None)

    # -------------------------------------------------------------- #
    # requeue manifest: the monitor writes re-measurement requests here
    # (`monitor watch --requeue`), the scheduler consumes them
    # (`campaign run --requeue-from-alerts`)
    # -------------------------------------------------------------- #
    def _requeue_path(self) -> str:
        return os.path.join(self.dir, "requeue.json")

    def save_requeue(self, units: dict[str, dict]) -> str:
        """Merge re-measurement requests (unit_key -> {"reason",
        "alert_ids"}) into the pending requeue manifest; returns its
        path.  Per-unit ``alert_ids`` accumulate across calls, so every
        alert that contributed to a requeue stays on the record."""
        with self._lock:
            doc = self.load_requeue()
            pending = doc.setdefault("units", {})
            for key, entry in units.items():
                prev = pending.get(key, {})
                ids = sorted(set(prev.get("alert_ids", []))
                             | set(entry.get("alert_ids", [])))
                pending[key] = {**prev, **entry, "alert_ids": ids}
            doc["updated_at"] = time.time()
            _atomic_write_json(self._requeue_path(), doc)
        return self._requeue_path()

    def load_requeue(self) -> dict:
        if not os.path.exists(self._requeue_path()):
            return {"units": {}}
        with open(self._requeue_path()) as f:
            return json.load(f)

    def clear_requeue(self) -> None:
        if os.path.exists(self._requeue_path()):
            os.remove(self._requeue_path())

    # -------------------------------------------------------------- #
    # orchestration spans (repro.obs): per-actor JSONL files, excluded
    # from the content digest by construction (digests cover only
    # result.json's pair index + table CSVs)
    # -------------------------------------------------------------- #
    def spans_dir(self) -> str:
        return os.path.join(self.dir, "spans")

    def span_path(self, actor: str) -> str:
        return os.path.join(self.spans_dir(), f"{actor}.jsonl")

    def list_span_files(self) -> list[str]:
        """Sorted paths of every recorded span file for this campaign."""
        d = self.spans_dir()
        if not os.path.isdir(d):
            return []
        return [os.path.join(d, n) for n in sorted(os.listdir(d))
                if n.endswith(".jsonl")]

    def deadletter_dir(self) -> str:
        return os.path.join(self.dir, "deadletter")

    # -------------------------------------------------------------- #
    # telemetry traces (repro.trace): measurement artifacts that outlive
    # the run — replayable offline through the `trace-replay` backend
    # -------------------------------------------------------------- #
    def traces_dir(self, unit_key: str) -> str:
        return os.path.join(self.unit_dir(unit_key), "traces")

    def trace_path(self, unit_key: str, name: str = "session") -> str:
        return os.path.join(self.traces_dir(unit_key), name)

    def save_trace(self, unit_key: str, trace, name: str = "session") -> str:
        """Persist one unit's telemetry trace (a loaded
        :class:`repro.trace.recorder.Trace` or a live ``TraceRecorder``)."""
        if hasattr(trace, "finish"):          # a recorder: freeze it first
            trace = trace.finish()
        return trace.save(self.trace_path(unit_key, name))

    def _scan_units(self, subdir: str, valid,
                    unit_key: str | None = None) -> dict[str, list[str]]:
        """unit_key -> sorted entry names under ``units/<key>/<subdir>``
        passing ``valid(dir, name)`` — the one directory walk behind both
        :meth:`list_traces` and :meth:`list_alerts`."""
        units_root = os.path.join(self.dir, _UNITS)
        units = ([unit_key] if unit_key is not None else
                 sorted(os.listdir(units_root))
                 if os.path.isdir(units_root) else [])
        out: dict[str, list[str]] = {}
        for key in units:
            d = os.path.join(self.unit_dir(key), subdir)
            if not os.path.isdir(d):
                continue
            names = sorted(n for n in os.listdir(d) if valid(d, n))
            if names:
                out[key] = names
        return out

    def list_traces(self, unit_key: str | None = None) -> dict[str, list[str]]:
        """unit_key -> sorted trace names (all units when key is None)."""
        from repro.trace.schema import HEADER_FILE
        return self._scan_units(
            "traces",
            lambda d, n: os.path.exists(os.path.join(d, n, HEADER_FILE)),
            unit_key)

    def load_trace(self, unit_key: str, name: str = "session"):
        from repro.trace.recorder import Trace
        return Trace.load(self.trace_path(unit_key, name))

    # -------------------------------------------------------------- #
    # drift alerts (repro.monitor): content-addressed JSON artifacts —
    # the id is the hash of the canonical document bytes, so a replayed
    # detection scenario reproduces identical files, and re-saving an
    # alert is a no-op rather than a duplicate
    # -------------------------------------------------------------- #
    def alerts_dir(self, unit_key: str) -> str:
        return os.path.join(self.unit_dir(unit_key), "alerts")

    def alert_path(self, unit_key: str, alert_id: str) -> str:
        return os.path.join(self.alerts_dir(unit_key), f"{alert_id}.json")

    def save_alert(self, unit_key: str, doc: dict) -> str:
        """Persist one alert document; returns its content-addressed id.
        ``doc`` must be JSON-serializable with only finite floats (alert
        builders own that invariant — determinism is the point)."""
        import hashlib
        body = json.dumps(doc, indent=1, sort_keys=True,
                          allow_nan=False) + "\n"
        alert_id = hashlib.sha256(body.encode()).hexdigest()[:24]
        path = self.alert_path(unit_key, alert_id)
        if not os.path.exists(path):
            os.makedirs(os.path.dirname(path), exist_ok=True)
            with atomic_replace(path) as tmp:
                with open(tmp, "w") as f:
                    f.write(body)
        return alert_id

    def list_alerts(self, unit_key: str | None = None) -> dict[str, list[str]]:
        """unit_key -> sorted alert ids (all units when key is None)."""
        return {k: [n[:-len(".json")] for n in names]
                for k, names in self._scan_units(
                    "alerts",
                    lambda d, n: (n.endswith(".json")
                                  and os.path.isfile(os.path.join(d, n))),
                    unit_key).items()}

    def load_alert(self, unit_key: str, alert_id: str) -> dict:
        with open(self.alert_path(unit_key, alert_id)) as f:
            return json.load(f)


class ArtifactStore:
    """Root directory holding many campaigns, addressed by content hash."""

    def __init__(self, root: str | None = None):
        self.root = root if root is not None else campaigns_dir()

    def open(self, spec: CampaignSpec) -> Campaign:
        """Create-or-attach the campaign for ``spec`` (content-addressed:
        the same spec always opens the same directory)."""
        c = Campaign(self.root, spec)
        c.init()
        return c

    def load(self, campaign_id: str) -> Campaign:
        """Load by id or unique id prefix."""
        cid = self._resolve(campaign_id)
        with open(os.path.join(self.root, cid, _SPEC)) as f:
            spec = CampaignSpec.from_dict(json.load(f))
        return Campaign(self.root, spec, campaign_id=cid)

    def _resolve(self, prefix: str) -> str:
        if os.path.isdir(os.path.join(self.root, prefix)):
            return prefix
        matches = [c for c in self.list_ids() if c.startswith(prefix)]
        if len(matches) == 1:
            return matches[0]
        if not matches:
            raise KeyError(f"no campaign matching {prefix!r} in {self.root} "
                           f"(have: {self.list_ids()})")
        raise KeyError(f"ambiguous campaign prefix {prefix!r}: {matches}")

    def list_ids(self) -> list[str]:
        if not os.path.isdir(self.root):
            return []
        return sorted(d for d in os.listdir(self.root)
                      if os.path.exists(os.path.join(self.root, d, _SPEC)))

    def list_campaigns(self) -> list[dict]:
        """Summaries for `campaign ls`: id, name, unit progress."""
        out = []
        for cid in self.list_ids():
            c = self.load(cid)
            states = c.unit_states()
            n_done = sum(1 for st in states.values()
                         if st.get("status") == UNIT_DONE)
            out.append({"campaign_id": cid, "name": c.spec.name,
                        "units_done": n_done, "units_total": len(states),
                        "created_at": c.manifest().get("created_at")})
        return out

    def latest_campaign_id(self) -> str | None:
        """Id of the most recently created campaign (manifest timestamp;
        id as a deterministic tiebreak), or None for an empty store.
        Powers ``campaign ls --latest`` so CI scripts get exactly one id
        instead of scraping the human listing."""
        rows = self.list_campaigns()
        if not rows:
            return None
        return max(rows, key=lambda r: (r.get("created_at") or 0.0,
                                        r["campaign_id"]))["campaign_id"]
