"""Expand a CampaignSpec into sessions and run them to completion.

The runner is the glue between three resume layers:

* **campaign level** — units already ``done`` in the manifest are loaded
  from the store, never re-measured;
* **unit level** — each unit's :class:`MeasurementSession` persists into
  the campaign's ``units/<key>/session`` directory, so a unit interrupted
  mid-sweep resumes at *pair* granularity;
* **per-unit retry** — a unit that raises (or whose worker process dies)
  gets up to ``spec.retries`` TOTAL attempts before being marked
  ``failed`` (the failure may be transient: a flaky board, a throttling
  burst); failed units never poison the rest of the campaign.

Scheduling is selected by ``executor``:

  serial | threads   in-process, through :mod:`repro.core.executors`;
                     a campaign is an embarrassingly parallel bag of
                     units, each owning its own device
  processes          the fault-tolerant work queue
                     (:mod:`repro.campaign.workqueue`): true CPU
                     parallelism plus heartbeat-based crash/hang recovery
                     and speculative straggler re-dispatch.  Unit tables
                     stay bit-identical to the serial schedule because
                     sessions measure every pair on a pair-seeded device
  cluster            the node-spanning dispatcher
                     (:mod:`repro.campaign.cluster`): the same recovery
                     core driving simulated worker nodes over a chaos-
                     injectable transport, with all store traffic going
                     through the retry-wrapped remote store client.
                     ``max_workers`` becomes the node count.

Orthogonally, ``engine`` selects how each unit measures its own pair
grid: ``serial`` (the per-pair reference loop) or ``batched`` (the
lock-stepped lane engine, :mod:`repro.core.batched_sweep`).  Both land
on identical tables; ``processes`` + ``batched`` is rejected — one
fuses units across workers, the other fuses pairs within a unit, and
nesting them schedules nothing.
"""
from __future__ import annotations

import dataclasses
import time
import traceback

from repro import obs
from repro.campaign.spec import CampaignSpec, UnitSpec
from repro.campaign.store import (UNIT_DONE, UNIT_FAILED, UNIT_RUNNING,
                                  ArtifactStore, Campaign)
from repro.core.executors import get_executor
from repro.core.latency_table import LatencyTable


@dataclasses.dataclass
class UnitOutcome:
    key: str
    status: str                        # done | failed | loaded
    attempts: int = 0
    wall_s: float = 0.0
    error: str | None = None
    table: LatencyTable | None = None
    session: object | None = None      # live session (in-process runs only)


@dataclasses.dataclass
class CampaignResult:
    campaign: Campaign
    outcomes: dict[str, UnitOutcome]
    # recovery evidence from the process work queue (empty for in-process
    # schedules): crashed/hung worker counts, requeues, speculation
    stats: dict = dataclasses.field(default_factory=dict)

    @property
    def ok(self) -> bool:
        return all(o.status in ("done", "loaded")
                   for o in self.outcomes.values())

    def failed(self) -> list[UnitOutcome]:
        return [o for o in self.outcomes.values() if o.status == "failed"]

    def tables(self) -> dict[str, LatencyTable]:
        return {k: o.table for k, o in sorted(self.outcomes.items())
                if o.table is not None}


def _ground_truth(session) -> dict[tuple[float, float], float]:
    """Max true transition latency per pair across the session's devices
    (empty when the backend keeps no history, e.g. real hardware)."""
    if hasattr(session, "ground_truth"):
        return session.ground_truth()
    # fallback for session doubles: harvest device histories directly
    from repro.core.pairtask import extract_ground_truth
    gt: dict[tuple[float, float], float] = {}
    for dev in getattr(session, "devices", []):
        for k, v in extract_ground_truth(dev).items():
            gt[k] = max(gt.get(k, 0.0), v)
    return gt


class CampaignRunner:
    def __init__(self, spec: CampaignSpec, store: ArtifactStore | None = None,
                 *, executor: str = "serial", max_workers: int = 4,
                 engine: str = "serial", trace: bool = False,
                 heartbeat_timeout_s: float = 60.0,
                 straggler_ratio: float = 3.0, speculate: bool = True,
                 fault_plan=None, retry_policy=None,
                 requeue_from_alerts: bool = False,
                 spans: bool = False):
        if engine == "batched" and executor in ("processes", "cluster"):
            raise ValueError(
                f"executor={executor!r} farms whole units out to workers, "
                "while engine='batched' already fuses each unit's sweep "
                "into one lock-stepped program; combining them would "
                "nest schedulers with nothing to gain — pick one "
                f"({executor} for many units, batched for big grids)")
        if trace and executor == "cluster":
            raise ValueError(
                "executor='cluster' cannot record traces: a trace is a "
                "host-local event stream and requeued node attempts "
                "would each hold fragments — use executor='processes' "
                "for traced campaigns")
        self.spec = spec
        self.store = store if store is not None else ArtifactStore()
        self.executor = executor
        self.max_workers = max_workers
        self.engine = engine
        # record each unit's telemetry (repro.trace) and store it as a
        # campaign artifact; the trace covers THIS run's interactions — a
        # resumed unit's already-persisted pairs are loaded, not re-measured,
        # so they do not reappear in the new trace
        self.trace = trace
        # process work-queue knobs (ignored by in-process executors)
        self.heartbeat_timeout_s = heartbeat_timeout_s
        self.straggler_ratio = straggler_ratio
        self.speculate = speculate
        self.fault_plan = fault_plan
        # cluster store-op retry policy (None -> the sim default)
        self.retry_policy = retry_policy
        # consume the monitor's requeue manifest: listed units are reset
        # (session/table/result dropped) and re-measured as fresh attempts
        self.requeue_from_alerts = requeue_from_alerts
        # span profiler (repro.obs): off by default; when on, the driver
        # records to <campaign>/spans/driver.jsonl and every worker
        # process / node thread records its own file alongside.  Span
        # files live outside the campaign's content digest, so profiled
        # and unprofiled runs stay store bit-identical.
        self.spans = spans

    def run(self, verbose: bool = False) -> CampaignResult:
        campaign = self.store.open(self.spec)
        rec = None
        if self.spans:
            rec = obs.install(obs.SpanRecorder(
                "driver", path=campaign.span_path("driver")))
        try:
            with obs.span("campaign.run", "campaign",
                          campaign_id=campaign.campaign_id,
                          executor=self.executor, engine=self.engine):
                return self._run(campaign, verbose)
        finally:
            if rec is not None:
                rec.close()
                obs.uninstall()

    def _run(self, campaign: Campaign, verbose: bool) -> CampaignResult:
        if self.requeue_from_alerts:
            requested = campaign.load_requeue().get("units", {})
            known = {u.key for u in self.spec.units()}
            for key in sorted(set(requested) & known):
                campaign.reset_unit(key)
                if verbose:
                    reason = requested[key].get("reason", "requeued")
                    print(f"  [{key}] reset for re-measurement ({reason})")
            if requested:
                campaign.clear_requeue()
        states = campaign.unit_states()
        outcomes: dict[str, UnitOutcome] = {}
        todo: list[UnitSpec] = []
        for unit in self.spec.units():
            st = states.get(unit.key, {})
            if (st.get("status") == UNIT_DONE
                    and campaign.has_unit_result(unit.key)):
                outcomes[unit.key] = UnitOutcome(
                    unit.key, "loaded", attempts=st.get("attempts", 0),
                    wall_s=st.get("wall_s", 0.0),
                    table=campaign.load_table(unit.key))
            else:
                todo.append(unit)
        if verbose and outcomes:
            print(f"campaign {campaign.campaign_id}: "
                  f"{len(outcomes)} unit(s) loaded from store, "
                  f"{len(todo)} to run")

        stats: dict = {}
        if self.executor == "processes":
            from repro.campaign.workqueue import ProcessCampaignScheduler
            sched = ProcessCampaignScheduler(
                self.spec, campaign, max_workers=self.max_workers,
                heartbeat_timeout_s=self.heartbeat_timeout_s,
                straggler_ratio=self.straggler_ratio,
                speculate=self.speculate, fault_plan=self.fault_plan,
                verbose=verbose)
            sched.trace = self.trace
            sched.spans = self.spans
            outcomes.update(sched.run(todo))
            stats = sched.stats
        elif self.executor == "cluster":
            from repro.campaign.cluster.dispatch import \
                ClusterCampaignScheduler
            kw = ({} if self.retry_policy is None
                  else {"retry_policy": self.retry_policy})
            sched = ClusterCampaignScheduler(
                self.spec, campaign, n_nodes=self.max_workers,
                heartbeat_timeout_s=self.heartbeat_timeout_s,
                straggler_ratio=self.straggler_ratio,
                speculate=self.speculate, fault_plan=self.fault_plan,
                verbose=verbose, **kw)
            sched.spans = self.spans
            outcomes.update(sched.run(todo))
            stats = sched.stats
        else:
            # capture the driver's root span: thread-pool units open
            # their attempt spans on other threads, whose ambient stacks
            # are empty — the explicit parent stitches them under it
            parent = obs.ctx()

            def one(unit: UnitSpec, worker: int) -> UnitOutcome:
                return self._run_unit(campaign, unit, verbose,
                                      obs_parent=parent)

            pool = get_executor(self.executor, self.max_workers)
            for outcome in pool.map_pairs(one, todo):
                outcomes[outcome.key] = outcome
        ordered = {u.key: outcomes[u.key] for u in self.spec.units()}
        return CampaignResult(campaign, ordered, stats)

    # -------------------------------------------------------------- #
    def _run_unit(self, campaign: Campaign, unit: UnitSpec,
                  verbose: bool, obs_parent: str | None = None
                  ) -> UnitOutcome:
        error = None
        attempts = 0
        # ground truth accumulated across attempts: a failed attempt may
        # have measured (and persisted) pairs the retry's session will
        # load instead of re-visiting, so its oracle must not be dropped
        gt_acc: dict[tuple[float, float], float] = {}
        for attempt in range(1, max(1, self.spec.retries) + 1):
            attempts = attempt
            campaign.mark_unit(unit.key, status=UNIT_RUNNING,
                               attempts=attempt)
            t0 = time.perf_counter()
            session = None
            recorder = None
            if self.trace:
                from repro.trace.recorder import TraceRecorder
                recorder = TraceRecorder(meta={
                    "campaign_id": campaign.campaign_id,
                    "unit_key": unit.key, "attempt": attempt})
            # trace= only when enabled: build_session keeps its untraced
            # call shape (and monkeypatched doubles) untouched otherwise
            kw = {} if recorder is None else {"trace": recorder}
            with obs.span("unit.attempt", "unit",
                          parent=obs_parent or obs.AMBIENT,
                          unit=unit.key, attempt=attempt) as live:
                try:
                    session = unit.build_session(
                        out_dir=campaign.session_dir(unit.key),
                        engine=self.engine, **kw)
                    table = session.run(verbose=False)
                    wall = time.perf_counter() - t0
                    gt_acc.update(_ground_truth(session))
                    campaign.save_unit_result(unit.key, table, gt_acc)
                    if recorder is not None:
                        campaign.save_trace(unit.key, recorder)
                    campaign.mark_unit(unit.key, status=UNIT_DONE,
                                       wall_s=wall,
                                       n_pairs=len(table.pairs),
                                       error=None)
                    if verbose:
                        print(f"  [{unit.key}] done: "
                              f"{len(table.pairs)} pairs "
                              f"in {wall:.1f}s (attempt {attempt})")
                    if live is not None:
                        live.attrs["status"] = "done"
                    return UnitOutcome(unit.key, "done", attempt, wall,
                                       table=table, session=session)
                except Exception as exc:  # noqa: BLE001 — unit isolation
                    if session is not None:
                        gt_acc.update(_ground_truth(session))
                    error = f"{type(exc).__name__}: {exc}"
                    if live is not None:
                        live.attrs["status"] = "failed"
                        live.attrs["error"] = type(exc).__name__
                    if verbose:
                        print(f"  [{unit.key}] attempt {attempt} failed: "
                              f"{error}")
                        traceback.print_exc()
        campaign.mark_unit(unit.key, status=UNIT_FAILED, error=error)
        return UnitOutcome(unit.key, "failed", attempts, error=error)


def run_campaign(spec: CampaignSpec, store: ArtifactStore | None = None,
                 **kw) -> CampaignResult:
    """One-call convenience: expand, schedule, persist, return."""
    verbose = kw.pop("verbose", False)
    return CampaignRunner(spec, store, **kw).run(verbose=verbose)
