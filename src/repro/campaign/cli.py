"""`python -m repro.campaign` — the fleet-measurement command surface.

    run     SPEC.json   expand + measure (resumes: same spec -> same id);
                        --spans records the orchestration span profile
    ls                  list campaigns in the store (--json for scripts)
    report  CID         cross-device markdown report (Table II analogue;
                        --json for the machine-readable document)
    diff    CID_A CID_B flag pairs whose clean latency distribution drifted
                        (exit code 1 when any pair is flagged -> CI gate;
                        --json for the machine-readable CampaignDiff)
    profile CID         span-profiler cost breakdown: merged timeline,
                        critical path, dominant cost, dead-letter links
                        (--perfetto exports a Chrome trace_event JSON)

The store root defaults to ``$REPRO_RESULTS_DIR/campaigns`` (or
``results/campaigns``); every command takes ``--store`` to override.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.campaign.aggregate import report_dict, report_markdown
from repro.campaign.regression import DiffConfig, diff_campaigns, diff_markdown
from repro.campaign.scheduler import CampaignRunner
from repro.campaign.spec import CampaignSpec
from repro.campaign.store import ArtifactStore
from repro.cliutil import emit as _emit


def _store(args) -> ArtifactStore:
    return ArtifactStore(args.store)


def cmd_run(args) -> int:
    """Exit codes (CI contract): 0 all units done/loaded; 1 any unit
    failed (``--ok-on-partial`` downgrades this to 0 for exploratory
    sweeps that tolerate holes); 2 the run could not start (bad spec,
    invalid executor/engine combination)."""
    try:
        spec = CampaignSpec.load(args.spec)
    except (OSError, ValueError, KeyError) as exc:
        print(f"error: cannot load spec {args.spec!r}: {exc}",
              file=sys.stderr)
        return 2
    nodes = args.nodes if args.executor == "cluster" else args.max_workers
    try:
        runner = CampaignRunner(spec, _store(args), executor=args.executor,
                                max_workers=nodes,
                                engine=args.engine, trace=args.trace,
                                heartbeat_timeout_s=args.heartbeat_timeout,
                                speculate=not args.no_speculate,
                                requeue_from_alerts=args.requeue_from_alerts,
                                spans=args.spans)
    except ValueError as exc:           # e.g. processes + batched
        print(f"error: {exc}", file=sys.stderr)
        return 2
    print(f"campaign {spec.campaign_id()} ({spec.name}): "
          f"{len(spec.units())} unit(s) [{args.executor}"
          + (f" x{nodes}" if args.executor != "serial" else "")
          + (f", {args.engine} engine" if args.engine != "serial" else "")
          + "]")
    result = runner.run(verbose=not args.quiet)
    for o in result.failed():
        print(f"  FAILED {o.key} after {o.attempts} attempt(s): {o.error}",
              file=sys.stderr)
    if result.stats and any(result.stats.values()):
        recovered = {k: v for k, v in result.stats.items() if v}
        print(f"recovery: {recovered}")
    print(f"{'ok' if result.ok else 'INCOMPLETE'}: "
          f"artifacts in {result.campaign.dir}")
    if not result.ok and args.ok_on_partial:
        print("(--ok-on-partial: exiting 0 despite failed units)",
              file=sys.stderr)
        return 0
    return 0 if result.ok else 1


def cmd_ls(args) -> int:
    store = _store(args)
    if args.latest:
        cid = store.latest_campaign_id()
        if cid is None:
            print(f"no campaigns under {store.root}", file=sys.stderr)
            return 1
        print(cid)
        return 0
    rows = store.list_campaigns()
    if not rows and not args.json:
        print(f"no campaigns under {store.root}")
        return 0
    docs = []
    for r in rows:
        campaign = store.load(r["campaign_id"])
        docs.append({**r,
                     "traces": sum(len(v) for v in
                                   campaign.list_traces().values()),
                     "alerts": sum(len(v) for v in
                                   campaign.list_alerts().values()),
                     "span_files": len(campaign.list_span_files())})
    if args.json:
        _emit(json.dumps(docs, indent=1, sort_keys=True), args.out)
        return 0
    for d in docs:
        extra = (f"  {d['traces']} trace(s)" if d["traces"] else "") + \
                (f"  {d['alerts']} ALERT(S)" if d["alerts"] else "") + \
                (f"  {d['span_files']} span file(s)"
                 if d["span_files"] else "")
        print(f"{d['campaign_id']}  {d['units_done']}/{d['units_total']} "
              f"units  {d['name']}{extra}")
    return 0


def cmd_report(args) -> int:
    campaign = _store(args).load(args.campaign)
    if args.json:
        _emit(json.dumps(report_dict(campaign), indent=1, sort_keys=True),
              args.out)
    else:
        _emit(report_markdown(campaign), args.out)
    return 0


def cmd_profile(args) -> int:
    """Exit codes: 0 profile rendered; 1 the campaign recorded no spans
    (run it with ``--spans`` first)."""
    from repro.obs import export_to_registry, write_trace_events
    from repro.obs.profile import (collect_span_rows, profile_campaign,
                                   profile_markdown)
    campaign = _store(args).load(args.campaign)
    doc = profile_campaign(campaign)
    rows = None
    if args.perfetto:
        rows = collect_span_rows(campaign)
        if rows:
            write_trace_events(args.perfetto, rows)
            print(f"wrote {args.perfetto} (load in ui.perfetto.dev)",
                  file=sys.stderr)
    if args.metrics_out:
        rows = collect_span_rows(campaign) if rows is None else rows
        export_to_registry(rows).write_snapshot(args.metrics_out)
        print(f"wrote {args.metrics_out}", file=sys.stderr)
    if args.json:
        _emit(json.dumps(doc, indent=1, sort_keys=True), args.out)
    else:
        _emit(profile_markdown(doc), args.out)
    return 1 if doc.get("empty") else 0


def cmd_diff(args) -> int:
    import json

    from repro.campaign.regression import diff_to_dict
    store = _store(args)
    diff = diff_campaigns(
        store.load(args.reference), store.load(args.candidate),
        DiffConfig(worst_delta_threshold=args.threshold, alpha=args.alpha))
    if args.json:
        _emit(json.dumps(diff_to_dict(diff), indent=1, sort_keys=True),
              args.out)
    else:
        _emit(diff_markdown(diff), args.out)
    return 0 if diff.clean else 1


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.campaign",
        description="Fleet-scale switching-latency measurement campaigns")
    ap.add_argument("--store", default=None,
                    help="artifact store root (default: "
                         "$REPRO_RESULTS_DIR/campaigns)")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("run", help="run (or resume) a campaign spec")
    p.add_argument("spec", help="path to a CampaignSpec JSON file")
    p.add_argument("--executor",
                   choices=("serial", "threads", "processes", "cluster"),
                   default="serial",
                   help="unit scheduler: serial (paper shape), threads "
                        "(in-process pool), processes (fault-tolerant "
                        "work queue: crash requeue, hang detection, "
                        "straggler speculation), cluster (the same "
                        "recovery core spanning simulated worker nodes "
                        "over a transport; see --nodes)")
    p.add_argument("--max-workers", "--workers", dest="max_workers",
                   type=int, default=4,
                   help="worker count for threads/processes "
                        "(--workers kept as an alias)")
    p.add_argument("--nodes", type=int, default=3,
                   help="cluster only: simulated worker node count")
    p.add_argument("--engine", choices=("serial", "batched"),
                   default="serial",
                   help="per-unit sweep engine: serial (per-pair "
                        "reference loop) or batched (the whole pair grid "
                        "as lock-stepped vectorized dispatches; "
                        "bit-identical tables, virtual backends only, "
                        "incompatible with --executor processes)")
    p.add_argument("--heartbeat-timeout", type=float, default=60.0,
                   help="processes only: seconds of worker silence "
                        "before it is declared hung and its unit "
                        "requeued; workers beat once per measured pair, "
                        "so this must exceed the longest silent phase "
                        "(calibration + one pair)")
    p.add_argument("--no-speculate", action="store_true",
                   help="processes only: disable speculative re-dispatch "
                        "of straggler units")
    p.add_argument("--trace", action="store_true",
                   help="record each unit's telemetry (repro.trace) and "
                        "store it as a campaign artifact")
    p.add_argument("--spans", action="store_true",
                   help="record the orchestration span profile "
                        "(repro.obs): per-actor timelines under "
                        "<campaign>/spans/, rendered by `campaign "
                        "profile`; never perturbs measurement artifacts")
    p.add_argument("--ok-on-partial", action="store_true",
                   help="exit 0 even when units failed (default: any "
                        "failed unit exits 1 so CI cannot green-light a "
                        "partial sweep)")
    p.add_argument("--requeue-from-alerts", action="store_true",
                   help="consume the monitor's requeue manifest "
                        "(`monitor watch --requeue`): listed units are "
                        "reset and re-measured as fresh attempts")
    p.add_argument("--quiet", action="store_true")
    p.set_defaults(fn=cmd_run)

    p = sub.add_parser("ls", help="list campaigns in the store")
    p.add_argument("--latest", action="store_true",
                   help="print only the newest campaign id (exit 1 on an "
                        "empty store) — the script/CI-friendly form")
    p.add_argument("--json", action="store_true",
                   help="machine-readable listing (one document per "
                        "campaign) instead of the table")
    p.add_argument("--out", default=None, help="write to file")
    p.set_defaults(fn=cmd_ls)

    p = sub.add_parser("report", help="cross-device markdown report")
    p.add_argument("campaign", help="campaign id (or unique prefix)")
    p.add_argument("--json", action="store_true",
                   help="machine-readable report document instead of "
                        "markdown")
    p.add_argument("--out", default=None, help="write to file")
    p.set_defaults(fn=cmd_report)

    p = sub.add_parser("profile",
                       help="span-profiler cost breakdown (record with "
                            "`run --spans`; exit 1 when no spans exist)")
    p.add_argument("campaign", help="campaign id (or unique prefix)")
    p.add_argument("--json", action="store_true",
                   help="machine-readable profile document instead of "
                        "markdown")
    p.add_argument("--perfetto", default=None, metavar="PATH",
                   help="also export the merged timeline as Chrome "
                        "trace_event JSON (load in ui.perfetto.dev)")
    p.add_argument("--metrics-out", default=None, metavar="PATH",
                   help="also export span-derived counters/gauges as a "
                        "MetricsRegistry JSON snapshot")
    p.add_argument("--out", default=None, help="write to file")
    p.set_defaults(fn=cmd_profile)

    p = sub.add_parser("diff",
                       help="flag drifted pairs between two campaigns "
                            "(exit 1 on drift)")
    p.add_argument("reference")
    p.add_argument("candidate")
    p.add_argument("--threshold", type=float,
                   default=DiffConfig.worst_delta_threshold,
                   help="relative worst-case delta to flag")
    p.add_argument("--alpha", type=float, default=DiffConfig.alpha,
                   help="Mann-Whitney significance level")
    p.add_argument("--json", action="store_true",
                   help="machine-readable CampaignDiff instead of markdown")
    p.add_argument("--out", default=None, help="write to file")
    p.set_defaults(fn=cmd_diff)
    return ap


def main(argv: list[str] | None = None) -> int:
    args = build_parser().parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main())
