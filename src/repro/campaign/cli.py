"""`python -m repro.campaign` — the fleet-measurement command surface.

    run    SPEC.json   expand + measure (resumes: same spec -> same id)
    ls                 list campaigns in the store
    report CID         cross-device markdown report (Table II analogue)
    diff   CID_A CID_B flag pairs whose clean latency distribution drifted
                       (exit code 1 when any pair is flagged -> CI gate;
                       --json for the machine-readable CampaignDiff)

The store root defaults to ``$REPRO_RESULTS_DIR/campaigns`` (or
``results/campaigns``); every command takes ``--store`` to override.
"""
from __future__ import annotations

import argparse
import sys

from repro.campaign.aggregate import report_markdown
from repro.campaign.regression import DiffConfig, diff_campaigns, diff_markdown
from repro.campaign.scheduler import CampaignRunner
from repro.campaign.spec import CampaignSpec
from repro.campaign.store import ArtifactStore
from repro.cliutil import emit as _emit


def _store(args) -> ArtifactStore:
    return ArtifactStore(args.store)


def cmd_run(args) -> int:
    """Exit codes (CI contract): 0 all units done/loaded; 1 any unit
    failed (``--ok-on-partial`` downgrades this to 0 for exploratory
    sweeps that tolerate holes); 2 the run could not start (bad spec,
    invalid executor/engine combination)."""
    try:
        spec = CampaignSpec.load(args.spec)
    except (OSError, ValueError, KeyError) as exc:
        print(f"error: cannot load spec {args.spec!r}: {exc}",
              file=sys.stderr)
        return 2
    nodes = args.nodes if args.executor == "cluster" else args.max_workers
    try:
        runner = CampaignRunner(spec, _store(args), executor=args.executor,
                                max_workers=nodes,
                                engine=args.engine, trace=args.trace,
                                heartbeat_timeout_s=args.heartbeat_timeout,
                                speculate=not args.no_speculate,
                                requeue_from_alerts=args.requeue_from_alerts)
    except ValueError as exc:           # e.g. processes + batched
        print(f"error: {exc}", file=sys.stderr)
        return 2
    print(f"campaign {spec.campaign_id()} ({spec.name}): "
          f"{len(spec.units())} unit(s) [{args.executor}"
          + (f" x{nodes}" if args.executor != "serial" else "")
          + (f", {args.engine} engine" if args.engine != "serial" else "")
          + "]")
    result = runner.run(verbose=not args.quiet)
    for o in result.failed():
        print(f"  FAILED {o.key} after {o.attempts} attempt(s): {o.error}",
              file=sys.stderr)
    if result.stats and any(result.stats.values()):
        recovered = {k: v for k, v in result.stats.items() if v}
        print(f"recovery: {recovered}")
    print(f"{'ok' if result.ok else 'INCOMPLETE'}: "
          f"artifacts in {result.campaign.dir}")
    if not result.ok and args.ok_on_partial:
        print("(--ok-on-partial: exiting 0 despite failed units)",
              file=sys.stderr)
        return 0
    return 0 if result.ok else 1


def cmd_ls(args) -> int:
    store = _store(args)
    if args.latest:
        cid = store.latest_campaign_id()
        if cid is None:
            print(f"no campaigns under {store.root}", file=sys.stderr)
            return 1
        print(cid)
        return 0
    rows = store.list_campaigns()
    if not rows:
        print(f"no campaigns under {store.root}")
        return 0
    for r in rows:
        campaign = store.load(r["campaign_id"])
        n_traces = sum(len(v) for v in campaign.list_traces().values())
        n_alerts = sum(len(v) for v in campaign.list_alerts().values())
        extra = (f"  {n_traces} trace(s)" if n_traces else "") + \
                (f"  {n_alerts} ALERT(S)" if n_alerts else "")
        print(f"{r['campaign_id']}  {r['units_done']}/{r['units_total']} "
              f"units  {r['name']}{extra}")
    return 0


def cmd_report(args) -> int:
    campaign = _store(args).load(args.campaign)
    _emit(report_markdown(campaign), args.out)
    return 0


def cmd_diff(args) -> int:
    import json

    from repro.campaign.regression import diff_to_dict
    store = _store(args)
    diff = diff_campaigns(
        store.load(args.reference), store.load(args.candidate),
        DiffConfig(worst_delta_threshold=args.threshold, alpha=args.alpha))
    if args.json:
        _emit(json.dumps(diff_to_dict(diff), indent=1, sort_keys=True),
              args.out)
    else:
        _emit(diff_markdown(diff), args.out)
    return 0 if diff.clean else 1


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.campaign",
        description="Fleet-scale switching-latency measurement campaigns")
    ap.add_argument("--store", default=None,
                    help="artifact store root (default: "
                         "$REPRO_RESULTS_DIR/campaigns)")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("run", help="run (or resume) a campaign spec")
    p.add_argument("spec", help="path to a CampaignSpec JSON file")
    p.add_argument("--executor",
                   choices=("serial", "threads", "processes", "cluster"),
                   default="serial",
                   help="unit scheduler: serial (paper shape), threads "
                        "(in-process pool), processes (fault-tolerant "
                        "work queue: crash requeue, hang detection, "
                        "straggler speculation), cluster (the same "
                        "recovery core spanning simulated worker nodes "
                        "over a transport; see --nodes)")
    p.add_argument("--max-workers", "--workers", dest="max_workers",
                   type=int, default=4,
                   help="worker count for threads/processes "
                        "(--workers kept as an alias)")
    p.add_argument("--nodes", type=int, default=3,
                   help="cluster only: simulated worker node count")
    p.add_argument("--engine", choices=("serial", "batched"),
                   default="serial",
                   help="per-unit sweep engine: serial (per-pair "
                        "reference loop) or batched (the whole pair grid "
                        "as lock-stepped vectorized dispatches; "
                        "bit-identical tables, virtual backends only, "
                        "incompatible with --executor processes)")
    p.add_argument("--heartbeat-timeout", type=float, default=60.0,
                   help="processes only: seconds of worker silence "
                        "before it is declared hung and its unit "
                        "requeued; workers beat once per measured pair, "
                        "so this must exceed the longest silent phase "
                        "(calibration + one pair)")
    p.add_argument("--no-speculate", action="store_true",
                   help="processes only: disable speculative re-dispatch "
                        "of straggler units")
    p.add_argument("--trace", action="store_true",
                   help="record each unit's telemetry (repro.trace) and "
                        "store it as a campaign artifact")
    p.add_argument("--ok-on-partial", action="store_true",
                   help="exit 0 even when units failed (default: any "
                        "failed unit exits 1 so CI cannot green-light a "
                        "partial sweep)")
    p.add_argument("--requeue-from-alerts", action="store_true",
                   help="consume the monitor's requeue manifest "
                        "(`monitor watch --requeue`): listed units are "
                        "reset and re-measured as fresh attempts")
    p.add_argument("--quiet", action="store_true")
    p.set_defaults(fn=cmd_run)

    p = sub.add_parser("ls", help="list campaigns in the store")
    p.add_argument("--latest", action="store_true",
                   help="print only the newest campaign id (exit 1 on an "
                        "empty store) — the script/CI-friendly form")
    p.set_defaults(fn=cmd_ls)

    p = sub.add_parser("report", help="cross-device markdown report")
    p.add_argument("campaign", help="campaign id (or unique prefix)")
    p.add_argument("--out", default=None, help="write to file")
    p.set_defaults(fn=cmd_report)

    p = sub.add_parser("diff",
                       help="flag drifted pairs between two campaigns "
                            "(exit 1 on drift)")
    p.add_argument("reference")
    p.add_argument("candidate")
    p.add_argument("--threshold", type=float,
                   default=DiffConfig.worst_delta_threshold,
                   help="relative worst-case delta to flag")
    p.add_argument("--alpha", type=float, default=DiffConfig.alpha,
                   help="Mann-Whitney significance level")
    p.add_argument("--json", action="store_true",
                   help="machine-readable CampaignDiff instead of markdown")
    p.add_argument("--out", default=None, help="write to file")
    p.set_defaults(fn=cmd_diff)
    return ap


def main(argv: list[str] | None = None) -> int:
    args = build_parser().parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main())
