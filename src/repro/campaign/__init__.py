# Fleet-scale measurement orchestration on top of MeasurementSession:
# declarative specs -> scheduled sessions -> content-addressed artifacts ->
# cross-device aggregation -> drift detection between campaigns.
# Multi-node dispatch (transports, remote stores, retry policies) lives in
# repro.campaign.cluster and is imported from there, not re-exported here.
from repro.campaign.spec import (CampaignSpec, DeviceSpec, MeasureSpec,
                                 UnitSpec)
from repro.campaign.store import ArtifactStore, Campaign
from repro.campaign.scheduler import (CampaignResult, CampaignRunner,
                                      UnitOutcome, run_campaign)
from repro.campaign.aggregate import (comparison_markdown, comparison_rows,
                                      report_markdown, unit_summaries)
from repro.campaign.regression import (CampaignDiff, DiffConfig, PairDrift,
                                       diff_campaigns, diff_markdown,
                                       diff_to_dict, pair_drift)

__all__ = [
    "CampaignSpec", "DeviceSpec", "MeasureSpec", "UnitSpec",
    "ArtifactStore", "Campaign",
    "CampaignResult", "CampaignRunner", "UnitOutcome", "run_campaign",
    "comparison_markdown", "comparison_rows", "report_markdown",
    "unit_summaries",
    "CampaignDiff", "DiffConfig", "PairDrift", "diff_campaigns",
    "diff_markdown", "diff_to_dict", "pair_drift",
]
