"""Declarative campaign specifications (ReFrame-style parameterization).

A :class:`CampaignSpec` declares a *matrix* of measurement work — device
axes (backend + construction options + frequency subset) crossed with
measurement-config axes — instead of imperatively scripting sweeps.  The
scheduler expands the matrix into :class:`UnitSpec` units, each of which is
exactly one :class:`repro.core.session.MeasurementSession`; the artifact
store keys everything off :meth:`CampaignSpec.campaign_id`, a content hash
of the canonical spec, so the same spec always lands in (and resumes from)
the same artifacts.

Specs are plain JSON on disk::

    {
      "name": "three-gpus",
      "devices": [
        {"key": "a100",  "backend": "vmapped-sim",
         "options": {"kind": "a100", "n_cores": 6}, "n_freqs": 3},
        {"key": "gh200", "backend": "vmapped-sim",
         "options": {"kind": "gh200", "n_cores": 6}, "n_freqs": 3}
      ],
      "measures": [{"key": "fast", "min_measurements": 5,
                    "max_measurements": 8, "rse_check_every": 5}]
    }
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import re

from repro.core.evaluation import MeasureConfig
from repro.core.freqkey import (canon_freq, format_freq, freq_domain,
                                has_domain, spec_form)
from repro.core.session import LatestConfig, MeasurementSession, SessionConfig

_KEY_RE = re.compile(r"[A-Za-z0-9._-]+")


@dataclasses.dataclass(frozen=True)
class DeviceSpec:
    """One device axis value: how to build the measurement target."""

    key: str                                  # unique label within the campaign
    backend: str = "simulated"
    options: tuple = ()                       # sorted (name, value) pairs
    frequencies: tuple | None = None          # canonical freq keys, or None
    n_freqs: int = 3                          # evenly-spaced subset when None

    @staticmethod
    def make(key: str, backend: str = "simulated", options: dict | None = None,
             frequencies=None, n_freqs: int = 3) -> "DeviceSpec":
        opts = tuple(sorted((options or {}).items()))
        if frequencies is not None:
            # any freqkey spelling is accepted ("uncore:450", ("core", 900),
            # bare MHz) and canonicalized, so equivalent specs share one
            # campaign_id; bare floats pass through untouched
            try:
                freqs = tuple(canon_freq(f) for f in frequencies)
            except (KeyError, ValueError) as e:
                raise ValueError(
                    f"device {key!r}: bad frequency spec: {e}") from None
            if not freqs:
                raise ValueError(
                    f"device {key!r}: frequencies must be non-empty when "
                    "provided (omit the field for an n_freqs subset)")
        else:
            freqs = None
        return DeviceSpec(key, backend, opts, freqs, int(n_freqs))

    @property
    def options_dict(self) -> dict:
        return dict(self.options)

    def create_device(self):
        from repro.backends import create_backend
        return create_backend(self.backend, **self.options_dict)

    def resolve_frequencies(self, device) -> list[float]:
        fs = list(device.frequencies)
        if self.frequencies is not None:
            # domain-aware devices get membership validation: a bare-MHz
            # request against a multi-domain ladder (or an op point the
            # device doesn't offer) fails here with the domain vocabulary,
            # not deep inside phase 1.  Single-domain specs keep the
            # historical pass-through.
            if any(has_domain(f) for f in fs):
                supported = set(fs)
                bad = [f for f in self.frequencies if f not in supported]
                if bad:
                    domains = sorted({freq_domain(f) for f in fs})
                    raise ValueError(
                        f"device {self.key!r}: operating point(s) "
                        f"{[format_freq(f) for f in bad]} not offered by "
                        f"backend {self.backend!r} (domains {domains}; "
                        f"spell points as 'domain:mhz', e.g. "
                        f"{format_freq(fs[0])!r})")
            return [float(f) for f in self.frequencies]
        n = max(2, min(self.n_freqs, len(fs)))
        idx = [round(i * (len(fs) - 1) / (n - 1)) for i in range(n)]
        return [float(fs[i]) for i in sorted(set(idx))]

    def to_dict(self) -> dict:
        # spec_form keeps bare MHz as JSON numbers (campaign_id stability
        # for every pre-domain spec) and renders encoded operating points
        # as "domain:mhz" strings
        return {"key": self.key, "backend": self.backend,
                "options": self.options_dict,
                "frequencies": [spec_form(f) for f in self.frequencies]
                if self.frequencies else None,
                "n_freqs": self.n_freqs}

    @classmethod
    def from_dict(cls, d: dict) -> "DeviceSpec":
        known = {f.name for f in dataclasses.fields(cls)}
        extra = set(d) - known
        if extra:
            raise ValueError(f"unknown device fields {sorted(extra)}; "
                             f"expected a subset of {sorted(known)}")
        return cls.make(d["key"], d.get("backend", "simulated"),
                        d.get("options"), d.get("frequencies"),
                        d.get("n_freqs", 3))


@dataclasses.dataclass(frozen=True)
class MeasureSpec:
    """One measurement-config axis value (phase 2/3 repetition policy)."""

    key: str = "default"
    rse_target: float = 0.05
    min_measurements: int = 8
    max_measurements: int = 24
    rse_check_every: int = 8
    base_iter_s: float = 40e-6
    delay_iters: int = 300
    confirm_iters: int = 400
    probe_pairs: int = 3

    def to_latest_config(self) -> LatestConfig:
        return LatestConfig(
            base_iter_s=self.base_iter_s, delay_iters=self.delay_iters,
            confirm_iters=self.confirm_iters, probe_pairs=self.probe_pairs,
            measure=MeasureConfig(
                rse_target=self.rse_target,
                min_measurements=self.min_measurements,
                max_measurements=self.max_measurements,
                rse_check_every=self.rse_check_every))

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "MeasureSpec":
        known = {f.name for f in dataclasses.fields(cls)}
        extra = set(d) - known
        if extra:
            raise ValueError(f"unknown measure fields {sorted(extra)}; "
                             f"expected a subset of {sorted(known)}")
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class UnitSpec:
    """One expanded cell of the matrix: device x measurement config."""

    device: DeviceSpec
    measure: MeasureSpec

    @property
    def key(self) -> str:
        return f"{self.device.key}@{self.measure.key}"

    def build_session(self, out_dir: str | None = None,
                      executor: str = "serial", trace=None,
                      engine: str = "serial") -> MeasurementSession:
        device = self.device.create_device()
        return MeasurementSession(
            device, self.device.resolve_frequencies(device),
            SessionConfig(latest=self.measure.to_latest_config(),
                          executor=executor, out_dir=out_dir),
            backend=self.device.backend,
            backend_options=self.device.options_dict,
            device_name=self.device.key, trace=trace, engine=engine)


@dataclasses.dataclass(frozen=True)
class CampaignSpec:
    name: str
    devices: tuple[DeviceSpec, ...]
    measures: tuple[MeasureSpec, ...] = (MeasureSpec(),)
    retries: int = 2                          # TOTAL attempts per unit

    def __post_init__(self):
        if not self.devices:
            raise ValueError("a campaign needs at least one device")
        for group, keys in (("device", [d.key for d in self.devices]),
                            ("measure", [m.key for m in self.measures])):
            dupes = {k for k in keys if keys.count(k) > 1}
            if dupes:
                raise ValueError(f"duplicate {group} keys {sorted(dupes)}")
            # keys become store directory names and the two halves of the
            # "<device>@<measure>" unit key — keep them path- and
            # separator-safe
            for k in keys:
                if not k or k in (".", "..") or not _KEY_RE.fullmatch(k):
                    raise ValueError(
                        f"invalid {group} key {k!r}: use only letters, "
                        "digits, '.', '_' and '-'")

    def units(self) -> list[UnitSpec]:
        return [UnitSpec(d, m) for d in self.devices for m in self.measures]

    # -------------------------------------------------------------- #
    # canonical form + content addressing
    # -------------------------------------------------------------- #
    def to_dict(self) -> dict:
        return {"name": self.name,
                "devices": [d.to_dict() for d in self.devices],
                "measures": [m.to_dict() for m in self.measures],
                "retries": self.retries}

    @classmethod
    def from_dict(cls, d: dict) -> "CampaignSpec":
        measures = tuple(MeasureSpec.from_dict(m)
                         for m in d.get("measures") or [{}])
        return cls(name=d["name"],
                   devices=tuple(DeviceSpec.from_dict(x) for x in d["devices"]),
                   measures=measures, retries=int(d.get("retries", 2)))

    def canonical_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True,
                          separators=(",", ":"))

    def campaign_id(self) -> str:
        """Content address: two campaigns share artifacts iff their specs
        are byte-identical in canonical form."""
        digest = hashlib.sha256(self.canonical_json().encode()).hexdigest()
        return f"c{digest[:12]}"

    @classmethod
    def load(cls, path: str) -> "CampaignSpec":
        with open(path) as f:
            return cls.from_dict(json.load(f))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=1, sort_keys=True)
