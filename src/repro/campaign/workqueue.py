"""Resilient campaign execution: shared dispatch policy + process fleet.

``CampaignRunner(executor="processes")`` schedules its units through this
module instead of a plain pool: a fleet campaign must survive the failure
modes a pool hides — a worker process that dies mid-unit, one that hangs,
and one that is merely slow.  The design is a driver/worker work queue:

* the **driver** (parent process) owns the manifest, the unit queue and
  all bookkeeping; it assigns one unit at a time to each worker over a
  per-worker task queue and consumes a shared result queue;
* **workers** are long-lived processes (spawn start method — they import
  only the numpy measurement stack, never the JAX runtime) that build each
  unit's :class:`MeasurementSession` locally and persist artifacts through
  the shared store.  Devices never cross the process boundary: sessions
  rebuild backends from the picklable unit spec
  (:mod:`repro.core.pairtask`);
* **liveness** is heartbeat-based (:class:`HeartbeatMonitor` from
  :mod:`repro.runtime.fault_tolerance`, monotonic clock): every measured
  pair beats.  A worker that exits (crash) or goes silent (hang) has its
  in-flight unit *requeued* to the surviving workers, bounded by the
  spec's per-unit attempt budget; exhausting the budget records a failed
  :class:`~repro.campaign.scheduler.UnitOutcome` instead of raising, so
  one cursed unit never poisons the campaign.  Replacement workers are
  respawned while work remains.  Beats mark *progress*, not merely a
  running process (a watchdog thread would keep beating through a
  genuine hang), so ``heartbeat_timeout_s`` must exceed the longest
  silent phase of a unit — calibration plus one pair measurement; the
  60 s default is orders of magnitude above the simulators' worst case;
* **stragglers** (:class:`StragglerPolicy` EWMA over completed unit wall
  times) are speculatively re-dispatched to idle workers;
  first-result-wins, the loser's identical artifacts are discarded.

All of the unit-level bookkeeping — attempt budgets, requeue on worker
loss, straggler speculation, first-result-wins dedup — lives in
:class:`DispatchCore`, parameterized over an abstract *worker* (anything
with an ``inflight`` attribute and a ``send_unit`` method).  The process
scheduler here and the multi-node cluster dispatcher
(:mod:`repro.campaign.cluster.dispatch`) drive the same core: "worker" is
a process for one and a node for the other, and the recovery semantics
are shared by construction instead of duplicated.

Correctness under all of this rests on the session layer's determinism:
every pair is measured on a pair-seeded device, so a requeued or
speculated unit resumes from the persisted pairs and lands on the exact
bytes the serial path would have produced.
"""
from __future__ import annotations

import dataclasses
import os
import queue as queue_mod
import time
from collections import deque

from repro import obs
from repro.campaign.spec import CampaignSpec, UnitSpec
from repro.campaign.store import (UNIT_DONE, UNIT_FAILED, UNIT_RUNNING,
                                  Campaign)
from repro.core.executors import SerialExecutor

_POISON = None                      # task-queue sentinel: worker shutdown
_CRASH_EXIT = 43                    # injected-crash exit code (tests/CI)


# ------------------------------------------------------------------ #
# fault injection (tests + the CI campaign-scale/distributed smoke jobs)
# ------------------------------------------------------------------ #
@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Deterministic fault injection, applied inside workers and nodes.

    Unit-keyed fault shapes (process workers AND cluster nodes):

    * ``crash_after_pairs``: number of measured pairs after which the
      worker hard-exits (``os._exit`` — no cleanup, like a real
      segfault/OOM kill);
    * ``stall_s``: seconds the worker sleeps *silently* before starting
      the unit — no heartbeats, so the driver's hang detection fires;
    * ``slow_pairs_s``: seconds slept after each measured pair, *with*
      heartbeats — a live straggler, the speculation path's target;
    * ``drift_after_pairs``: after N measured pairs, the unit's live
      device gets its transition model wrapped in a
      :class:`~repro.dvfs.transition_models.ShiftedTransitionModel` —
      switching latency silently departs the baseline mid-stream, the
      fleet monitor's detection target.  Values are ``(n_pairs, scale)``
      or ``(n_pairs, scale, f_init, f_target)`` (drift one pair only).
      Drift requires the traced shared-device path (``trace=True``):
      pair-scoped schedules rebuild a fresh device per pair, so a
      mid-unit model mutation would never be observed;
    * ``drift_ramp_pairs``: like ``drift_after_pairs`` but the shift
      ramps in *slowly* — the scale factor interpolates 1 -> ``scale``
      over the model's next ``ramp_samples`` latency draws instead of
      stepping.  Values are ``(n_pairs, scale, ramp_samples)``.  Tuned
      ramps stay inside CUSUM's per-sample allowance, so this is the
      Page-Hinkley detector's target shape;
    * ``drift_direction``: restrict any injected drift (step or ramp)
      to one transition direction — ``"up"`` shifts only
      ``f_target > f_init`` transitions, ``"down"`` only downward ones,
      ``""`` (default) both.  Models the asymmetric per-direction
      latency behavior of Fig. 4;
    * ``node_crash_after_pairs``: cluster only — the whole simulated
      *node* dies (its thread exits without a word) after N measured
      pairs of that unit, taking its local scratch with it.

    Cluster-wide fault shapes (:mod:`repro.campaign.cluster`):

    * ``transport``: sorted (name, value) pairs configuring the
      simulated transport's chaos — ``drop_rate`` (messages lost),
      ``dup_rate`` (messages/RPCs delivered twice), ``delay_s`` (max
      uniform delivery delay), ``seed`` (per-link deterministic RNG);
    * ``store_transient``: ``((unit_key, n), ...)`` — the first ``n``
      store writes of that unit's artifacts fail with a retryable
      error (the retry/backoff layer must ride them out);
    * ``store_permanent``: ``(unit_key, ...)`` — every store write for
      that unit fails forever: retries exhaust, the write is
      dead-lettered, the unit ends ``failed`` without poisoning peers;
    * ``store_partition``: ``(after_n_ops, n_ops)`` — a driver<->store
      partition that heals: after the driver's Nth store operation the
      next ``n_ops`` operations fail, then the link recovers.  Counted
      in operations, not seconds, so the window is deterministic.

    Each unit-keyed fault fires once per unit: the first attempt trips
    it and drops a marker file in the unit directory, so the requeued
    (or speculated) attempt runs clean.  (Drift is not a failure — its
    attempt completes normally — but the marker still proves the
    injection actually fired.)  Markers double as the test/CI evidence
    that the recovery path (not a lucky clean run) produced the result.
    """

    crash_after_pairs: tuple = ()       # sorted ((unit_key, n), ...)
    stall_s: tuple = ()                 # sorted ((unit_key, seconds), ...)
    slow_pairs_s: tuple = ()            # sorted ((unit_key, seconds), ...)
    drift_after_pairs: tuple = ()       # sorted ((unit_key, spec_tuple), ...)
    drift_ramp_pairs: tuple = ()        # sorted ((unit_key, (n, scale,
                                        #   ramp_samples)), ...)
    drift_direction: str = ""           # "" | "up" | "down"
    node_crash_after_pairs: tuple = ()  # sorted ((unit_key, n), ...)
    transport: tuple = ()               # sorted ((name, value), ...)
    store_transient: tuple = ()         # sorted ((unit_key, n), ...)
    store_permanent: tuple = ()         # sorted (unit_key, ...)
    store_partition: tuple = ()         # (after_n_ops, n_ops) or ()

    @staticmethod
    def make(crash_after_pairs: dict | None = None,
             stall_s: dict | None = None,
             slow_pairs_s: dict | None = None,
             drift_after_pairs: dict | None = None,
             drift_ramp_pairs: dict | None = None,
             drift_direction: str = "",
             node_crash_after_pairs: dict | None = None,
             transport: dict | None = None,
             store_transient: dict | None = None,
             store_permanent=(),
             store_partition: tuple | None = None) -> "FaultPlan":
        if drift_direction not in ("", "up", "down"):
            raise ValueError(
                f"drift_direction must be '', 'up' or 'down', "
                f"not {drift_direction!r}")
        return FaultPlan(
            tuple(sorted((crash_after_pairs or {}).items())),
            tuple(sorted((stall_s or {}).items())),
            tuple(sorted((slow_pairs_s or {}).items())),
            tuple(sorted((k, tuple(v))
                         for k, v in (drift_after_pairs or {}).items())),
            tuple(sorted((k, tuple(v))
                         for k, v in (drift_ramp_pairs or {}).items())),
            drift_direction,
            tuple(sorted((node_crash_after_pairs or {}).items())),
            tuple(sorted((transport or {}).items())),
            tuple(sorted((store_transient or {}).items())),
            tuple(sorted(store_permanent)),
            tuple(store_partition or ()))

    def crash_for(self, unit_key: str):
        return dict(self.crash_after_pairs).get(unit_key)

    def stall_for(self, unit_key: str):
        return dict(self.stall_s).get(unit_key)

    def slow_for(self, unit_key: str):
        return dict(self.slow_pairs_s).get(unit_key)

    def drift_for(self, unit_key: str):
        """``(n_pairs, scale, f_init | None, f_target | None)`` or None."""
        spec = dict(self.drift_after_pairs).get(unit_key)
        if spec is None:
            return None
        n, scale, *pair = spec
        fi, ft = pair if pair else (None, None)
        return int(n), float(scale), fi, ft

    def drift_ramp_for(self, unit_key: str):
        """``(n_pairs, scale, ramp_samples)`` or None."""
        spec = dict(self.drift_ramp_pairs).get(unit_key)
        if spec is None:
            return None
        n, scale, ramp = spec
        return int(n), float(scale), int(ramp)

    def node_crash_for(self, unit_key: str):
        return dict(self.node_crash_after_pairs).get(unit_key)

    def transport_dict(self) -> dict:
        """Chaos knobs for :class:`~repro.campaign.cluster.transport
        .TransportFaults` (empty = a clean network)."""
        return dict(self.transport)

    def store_transient_for(self, unit_key: str) -> int:
        return int(dict(self.store_transient).get(unit_key, 0))

    def store_permanent_for(self, unit_key: str) -> bool:
        return unit_key in self.store_permanent

    def partition_window(self):
        """``(after_n_ops, n_ops)`` or None."""
        if not self.store_partition:
            return None
        after, n = self.store_partition
        return int(after), int(n)

    @property
    def empty(self) -> bool:
        return not (self.crash_after_pairs or self.stall_s
                    or self.slow_pairs_s or self.drift_after_pairs
                    or self.drift_ramp_pairs
                    or self.node_crash_after_pairs or self.transport
                    or self.store_transient or self.store_permanent
                    or self.store_partition)


def fault_marker_path(campaign: Campaign, unit_key: str, kind: str) -> str:
    return os.path.join(campaign.unit_dir(unit_key), f"{kind}.injected")


def _trip_once(campaign: Campaign, unit_key: str, kind: str) -> bool:
    """Atomically claim one injected fault; False when already tripped.

    Markers live directly in the (driver-side) unit directory even for
    cluster nodes: the injector needs once-per-unit semantics *across
    attempts on different workers*, and the marker is harness
    bookkeeping/evidence, never transported artifact data."""
    path = fault_marker_path(campaign, unit_key, kind)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    try:
        fd = os.open(path, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
    except FileExistsError:
        return False
    os.close(fd)
    return True


def _hard_exit() -> None:
    """Default injected-crash action: die like a segfault/OOM kill."""
    os._exit(_CRASH_EXIT)


class _BeatingSerial(SerialExecutor):
    """Worker-side session executor: serial in-order measurement (the
    determinism contract) that emits one heartbeat per measured pair and
    hosts the injected crash/slowdown/drift hooks.  ``crash_action``
    abstracts how a crash manifests: ``os._exit`` for a process worker,
    raising the node-death exception for a simulated cluster node."""

    def __init__(self, beat, crash_after=None, on_crash=None,
                 sleep_between_s=None, drift_after=None, on_drift=None,
                 crash_action=_hard_exit):
        self.beat = beat
        self.crash_after = crash_after
        self.on_crash = on_crash
        self.sleep_between_s = sleep_between_s
        self.drift_after = drift_after
        self.on_drift = on_drift       # set post-construction (needs the
                                       # session's live device)
        self.crash_action = crash_action

    def map_pairs(self, fn, pairs, on_result=None):
        out = []
        for i, p in enumerate(pairs):
            r = fn(p, 0)
            if on_result is not None:
                on_result(p, r)
            out.append(r)
            self.beat()
            if self.crash_after is not None and i + 1 >= self.crash_after:
                if self.on_crash is None or self.on_crash():
                    # crash AFTER persistence (and the beat's upload hook):
                    # the requeued attempt must find the measured pairs —
                    # mid-unit, not before-unit, crash semantics
                    self.crash_action()
            if self.drift_after is not None and i + 1 >= self.drift_after \
                    and self.on_drift is not None:
                self.on_drift()        # idempotent; every later pair runs
                                       # on the shifted model
            if self.sleep_between_s:
                time.sleep(self.sleep_between_s)    # injected straggler:
                self.beat()                         # slow but alive
        return out


def activate_drift(session, scale: float, f_init=None, f_target=None, *,
                   ramp_samples: int = 0, direction: str = "") -> None:
    """Wrap the session's live device model in a
    :class:`~repro.dvfs.transition_models.ShiftedTransitionModel` — every
    transition sampled from here on is drifted.  ``ramp_samples`` makes
    the shift creep in over that many draws (slow-ramp injection);
    ``direction`` restricts it to up- or down-transitions.  Only
    meaningful on the shared-device path (``trace=...`` forces it);
    idempotent."""
    from repro.dvfs.transition_models import ShiftedTransitionModel
    dev = session.device
    dev = getattr(dev, "device", dev)         # unwrap TracedBackend
    if isinstance(dev.model, ShiftedTransitionModel):
        return
    only_pair = (None if f_init is None
                 else (float(f_init), float(f_target)))
    dev.model = ShiftedTransitionModel(dev.model, scale, only_pair,
                                       ramp_samples=ramp_samples,
                                       direction=direction)


# ------------------------------------------------------------------ #
# shared dispatch policy: requeue budgets, speculation, dedup
# ------------------------------------------------------------------ #
class DispatchCore:
    """Worker-kind-agnostic unit bookkeeping shared by the process
    scheduler below and the cluster dispatcher
    (:mod:`repro.campaign.cluster.dispatch`).

    A *worker* is anything with an ``inflight`` attribute (unit key or
    None) and a ``send_unit(key)`` method — a process wrapping a task
    queue, or a node handle wrapping a transport channel.  The core owns
    every decision that must behave identically for both: attempt
    budgets (``spec.retries`` TOTAL attempts), requeue on worker loss,
    straggler speculation with first-result-wins, duplicate-result
    discard, and exhaustion finalization.  Manifest writes go through
    the injected ``mark_unit`` so the cluster driver can route them over
    its (partition-prone, retry-wrapped) store client while the process
    scheduler writes locally.
    """

    #: stats keys the core maintains (schedulers add their own)
    STATS = ("requeued_units", "speculative_dispatches",
             "discarded_duplicates", "recovery_s")

    def __init__(self, campaign: Campaign, unit_keys, *, retries: int,
                 heartbeat, straggler, stats: dict,
                 mark_unit=None, load_table=None,
                 clock=time.monotonic, verbose: bool = False):
        from repro.campaign.scheduler import UnitOutcome
        self._Outcome = UnitOutcome
        self.campaign = campaign
        self.unit_keys = list(unit_keys)
        self.retries = max(1, int(retries))
        self.hb = heartbeat
        self.sp = straggler
        self.stats = stats
        for k in self.STATS:
            stats.setdefault(k, 0)
        self.mark_unit = mark_unit or campaign.mark_unit
        self.load_table = load_table or campaign.load_table
        self.clock = clock
        self.verbose = verbose

        self.pending = deque(self.unit_keys)
        self.attempts = {k: 0 for k in self.unit_keys}   # dispatches so far
        self.failures = {k: 0 for k in self.unit_keys}   # failed attempts
        self.errors: dict[str, str] = {}
        self.outcomes: dict = {}
        self.copies = {k: 0 for k in self.unit_keys}     # in-flight count
        self._lost_at: dict[str, float] = {}             # worker-loss stamp
        # open profiler spans per (worker identity, unit key): an attempt
        # span begins at dispatch and ends when the attempt's worker
        # releases the unit (done / failed / lost) — non-lexical because
        # the attempt outlives any one scheduler-loop iteration
        self._obs_spans: dict[tuple[int, str], object] = {}

    # ---------------- span-profiler hooks ---------------- #
    def _obs_begin(self, worker, key: str, speculative: bool) -> str | None:
        rec = obs.current()
        if rec is None:
            return None
        live = rec.begin("unit.attempt", "unit", unit=key,
                         attempt=self.attempts[key],
                         speculative=speculative, queue=len(self.pending))
        self._obs_spans[(id(worker), key)] = live
        return live.sid

    def _obs_end(self, worker, key: str, status: str) -> None:
        live = self._obs_spans.pop((id(worker), key), None)
        if live is None:
            return
        rec = obs.current()
        if rec is not None:
            rec.end(live, status=status)

    def _obs_elapsed(self, key: str) -> float | None:
        """Elapsed seconds of the unit's current attempt (straggler
        stamp), for requeue/speculation event records."""
        try:
            return float(self.sp.elapsed(key))
        except Exception:  # noqa: BLE001 — profiling must never raise
            return None

    def obs_close(self, status: str = "abandoned") -> None:
        """End every still-open attempt span at scheduler shutdown.
        Speculation losers are the common case: first-result-wins
        resolves the unit, the loop exits, and the loser's ack never
        drains — without this the loser's attempt (often the straggler
        the profile exists to explain) would vanish from the timeline
        and its node subtree would detach from the tree."""
        rec = obs.current()
        if rec is not None:
            for live in self._obs_spans.values():
                rec.end(live, status=status)
        self._obs_spans.clear()

    # ---------------- queries ---------------- #
    def resolved(self, key: str) -> bool:
        return key in self.outcomes

    @property
    def all_resolved(self) -> bool:
        return len(self.outcomes) >= len(self.unit_keys)

    def next_pending(self):
        """Pop the next unresolved pending key (None when drained)."""
        while self.pending:
            key = self.pending.popleft()
            if not self.resolved(key):
                return key
        return None

    def speculation_candidate(self):
        """Slowest straggling single-copy unit, or None.  Callers only
        consult this once the pending queue is empty (speculation clones
        in-flight work onto otherwise-idle capacity)."""
        cands = [k for k, n in self.copies.items()
                 if n == 1 and not self.resolved(k) and self.sp.straggling(k)]
        if not cands:
            return None
        return max(cands, key=self.sp.elapsed)

    def ordered_outcomes(self) -> dict:
        return {k: self.outcomes[k] for k in self.unit_keys}

    # ---------------- transitions ---------------- #
    def dispatch(self, worker, key: str, speculative: bool = False) -> None:
        worker.inflight = key
        self.copies[key] += 1
        self.attempts[key] += 1
        self.sp.start(key)      # idempotent: a duplicate keeps the
                                # original's start stamp
        if speculative:
            self.stats["speculative_dispatches"] += 1
            obs.event("sched.speculate", "sched", unit=key,
                      attempt=self.attempts[key],
                      elapsed_s=self._obs_elapsed(key))
        else:
            self.mark_unit(key, status=UNIT_RUNNING,
                           attempts=self.attempts[key])
        ctx = self._obs_begin(worker, key, speculative)
        worker.send_unit(key, ctx)
        if self.verbose:
            tag = " (speculative)" if speculative else ""
            print(f"  [{key}] dispatched{tag}")

    def release(self, worker, key: str, status: str = "released") -> None:
        if worker is not None and worker.inflight == key:
            worker.inflight = None
        self.copies[key] = max(0, self.copies[key] - 1)
        self._obs_end(worker, key, status)

    def finish_done(self, worker, key: str, wall: float,
                    n_pairs: int) -> None:
        self.release(worker, key, status="done")
        if self.resolved(key):          # a duplicate lost the race; its
            self.stats["discarded_duplicates"] += 1   # artifacts are
            return                      # identical bytes, nothing to undo
        self.sp.finish(key)
        if key in self._lost_at:        # this unit came back from a dead
            self.stats["recovery_s"] = max(       # worker: recovery time
                self.stats["recovery_s"],         # = loss -> completion
                self.clock() - self._lost_at.pop(key))
        self.mark_unit(key, status=UNIT_DONE, wall_s=wall,
                       n_pairs=n_pairs, error=None)
        self.outcomes[key] = self._Outcome(
            key, "done", attempts=self.attempts[key], wall_s=wall,
            table=self.load_table(key))
        if self.verbose:
            print(f"  [{key}] done: {n_pairs} pairs in {wall:.1f}s "
                  f"(attempt {self.attempts[key]})")

    def finalize_failed(self, key: str) -> None:
        self.sp.abandon(key)
        self.mark_unit(key, status=UNIT_FAILED, error=self.errors.get(key))
        self.outcomes[key] = self._Outcome(key, "failed",
                                           attempts=self.attempts[key],
                                           error=self.errors.get(key))
        if self.verbose:
            print(f"  [{key}] FAILED: {self.errors.get(key)}")

    def record_failure(self, key: str, error: str) -> None:
        """One attempt burned; requeue within budget, else finalize."""
        if self.resolved(key):
            return
        elapsed = self._obs_elapsed(key)
        # drop the in-flight stamp: the failed attempt's wall time says
        # nothing about the unit's cost, and a requeued dispatch must
        # not inherit it (sp.start is a setdefault) — a stale stamp
        # would flag the fresh attempt as straggling immediately and
        # fold cross-attempt elapsed into the EWMA on finish
        self.sp.abandon(key)
        self.failures[key] += 1
        self.errors[key] = error
        if self.failures[key] >= self.retries:
            if self.copies[key] == 0:
                self.finalize_failed(key)
            # else: a speculative copy is still in flight — it may win
        else:
            self.stats["requeued_units"] += 1
            self.pending.appendleft(key)
            obs.event("sched.requeue", "sched", unit=key, reason=error,
                      failures=self.failures[key], elapsed_s=elapsed,
                      queue=len(self.pending))
            if self.verbose:
                print(f"  [{key}] requeued after: {error}")

    def worker_lost(self, key: str, reason: str, worker=None) -> None:
        """The worker carrying ``key`` died or hung: burn the attempt and
        requeue within budget.  (The caller already removed the worker
        itself; the core only accounts for the unit.  ``worker`` is the
        reaped handle when the caller still holds it, so the attempt's
        profiler span can be closed.)"""
        self.copies[key] = max(0, self.copies[key] - 1)
        self._lost_at.setdefault(key, self.clock())
        self._obs_end(worker, key, status="lost")
        obs.event("sched.worker_lost", "sched", unit=key, reason=reason,
                  elapsed_s=self._obs_elapsed(key))
        self.record_failure(key, reason)

    def finalize_exhausted(self) -> None:
        """Units whose budget is spent and whose last in-flight copy has
        vanished (e.g. its worker was reaped while the unit was already
        out of retries)."""
        for key in self.unit_keys:
            if (not self.resolved(key) and self.failures[key] >= self.retries
                    and self.copies[key] == 0 and key not in self.pending):
                self.finalize_failed(key)


# ------------------------------------------------------------------ #
# worker process
# ------------------------------------------------------------------ #
def _worker_main(worker_id: int, spec_doc: dict, store_root: str,
                 campaign_id: str, task_q, result_q, fault_plan: FaultPlan,
                 trace: bool, span_path: str | None = None) -> None:
    """Long-lived worker loop: pull a unit key, measure it, persist, ack.

    Tasks (driver -> worker) are ``(unit_key, obs_ctx)`` — the driver's
    active attempt-span id rides along so this worker's spans stitch
    under it — or the poison sentinel.

    Messages (worker -> driver):
      ("ready",  wid)
      ("start",  wid, unit_key)
      ("beat",   wid)                        one per measured pair
      ("done",   wid, unit_key, wall_s, n_pairs)
      ("failed", wid, unit_key, error_str)
    """
    spec = CampaignSpec.from_dict(spec_doc)
    units = {u.key: u for u in spec.units()}
    campaign = Campaign(store_root, spec, campaign_id=campaign_id)
    if span_path is not None:
        obs.install(obs.SpanRecorder(f"worker{worker_id}", path=span_path))
    result_q.put(("ready", worker_id))
    while True:
        msg = task_q.get()
        if msg is _POISON:
            rec = obs.current()
            if rec is not None:
                rec.close()
            return
        unit_key, obs_ctx = msg
        unit = units[unit_key]
        result_q.put(("start", worker_id, unit_key))
        t0 = time.perf_counter()
        try:
            stall = fault_plan.stall_for(unit_key)
            if stall is not None and _trip_once(campaign, unit_key, "stall"):
                time.sleep(stall)           # silent: no heartbeats
            slow = fault_plan.slow_for(unit_key)
            if slow is not None and not _trip_once(campaign, unit_key,
                                                   "slow"):
                slow = None                 # only the first attempt drags
            crash_after = fault_plan.crash_for(unit_key)
            drift = fault_plan.drift_for(unit_key)
            ramp = fault_plan.drift_ramp_for(unit_key)
            if (drift is not None or ramp is not None) and not trace:
                raise ValueError(
                    "FaultPlan drift injection needs the traced "
                    "shared-device path (trace=True): pair-scoped "
                    "schedules rebuild a fresh device per pair, so a "
                    "mid-unit model shift would never be observed")
            drift_after = (drift[0] if drift is not None
                           else ramp[0] if ramp is not None else None)
            executor = _BeatingSerial(
                lambda: result_q.put(("beat", worker_id)),
                crash_after=crash_after,
                on_crash=(lambda: _trip_once(campaign, unit_key, "crash"))
                if crash_after is not None else None,
                sleep_between_s=slow,
                drift_after=drift_after)
            recorder = None
            kw = {}
            if trace:
                from repro.trace.recorder import TraceRecorder
                recorder = TraceRecorder(meta={
                    "campaign_id": campaign.campaign_id,
                    "unit_key": unit_key, "worker": worker_id})
                kw["trace"] = recorder
            session = unit.build_session(
                out_dir=campaign.session_dir(unit_key), executor=executor,
                **kw)
            if drift_after is not None:

                def _drift() -> None:
                    # marker = CI evidence the injection fired; activation
                    # itself is idempotent, so re-running is harmless
                    _trip_once(campaign, unit_key, "drift")
                    if drift is not None:
                        activate_drift(session, drift[1], drift[2],
                                       drift[3],
                                       direction=fault_plan.drift_direction)
                    else:
                        activate_drift(session, ramp[1],
                                       ramp_samples=ramp[2],
                                       direction=fault_plan.drift_direction)
                executor.on_drift = _drift
            with obs.span("unit.exec", "exec",
                          parent=obs_ctx or obs.AMBIENT,
                          unit=unit_key, worker=worker_id):
                table = session.run(verbose=False)
                gt = (session.ground_truth()
                      if hasattr(session, "ground_truth") else {})
                campaign.save_unit_result(unit_key, table, gt)
                if recorder is not None:
                    campaign.save_trace(unit_key, recorder)
            rec = obs.current()
            if rec is not None:
                rec.flush()     # crash-tolerant: each finished unit's
                                # spans are on disk before the next starts
            result_q.put(("done", worker_id, unit_key,
                          time.perf_counter() - t0, len(table.pairs)))
        except Exception as exc:  # noqa: BLE001 — unit isolation boundary
            rec = obs.current()
            if rec is not None:
                rec.flush()
            result_q.put(("failed", worker_id, unit_key,
                          f"{type(exc).__name__}: {exc}"))


# ------------------------------------------------------------------ #
# driver
# ------------------------------------------------------------------ #
@dataclasses.dataclass
class _Worker:
    proc: object
    task_q: object
    result_q: object                # per-worker: terminating one worker
                                    # mid-put can only corrupt ITS queue,
                                    # never the survivors' message path
    inflight: str | None = None     # unit key currently assigned

    def send_unit(self, key: str, ctx: str | None = None) -> None:
        """DispatchCore's worker protocol: hand over one unit (plus the
        dispatcher's span context, so worker spans stitch under it)."""
        self.task_q.put((key, ctx))


class ProcessCampaignScheduler:
    """Drive a campaign's pending units through a fault-tolerant process
    fleet.  Returns per-unit outcomes; all manifest writes happen here
    (single writer — workers only touch their own unit's artifact files).
    """

    def __init__(self, spec: CampaignSpec, campaign: Campaign, *,
                 max_workers: int = 4,
                 heartbeat_timeout_s: float = 60.0,
                 straggler_ratio: float = 3.0,
                 speculate: bool = True,
                 fault_plan: FaultPlan | None = None,
                 mp_context: str = "spawn",
                 poll_s: float = 0.05,
                 clock=time.monotonic,
                 verbose: bool = False):
        self.spec = spec
        self.campaign = campaign
        self.max_workers = max(1, int(max_workers))
        self.heartbeat_timeout_s = heartbeat_timeout_s
        self.straggler_ratio = straggler_ratio
        self.speculate = speculate
        self.fault_plan = fault_plan or FaultPlan()
        self.mp_context = mp_context
        self.poll_s = poll_s
        self.clock = clock
        self.verbose = verbose
        self.trace = False
        self.spans = False              # span profiling (set by the runner,
                                        # like .trace): workers record to
                                        # <campaign>/spans/worker<N>.jsonl
        # recovery evidence, surfaced on CampaignResult.stats (the core
        # adds its shared requeue/speculation/dedup counters on run)
        self.stats = {"crashed_workers": 0, "hung_workers": 0,
                      "respawned_workers": 0}

    # -------------------------------------------------------------- #
    def run(self, todo: list[UnitSpec]) -> dict:
        from repro.runtime.fault_tolerance import (HeartbeatMonitor,
                                                   StragglerPolicy)
        import multiprocessing
        if not todo:
            return {}
        ctx = multiprocessing.get_context(self.mp_context)
        self._ctx = ctx
        self._next_wid = 0
        self._workers: dict[int, _Worker] = {}
        # trace recording is a per-unit event stream: a resumed duplicate
        # records only the remainder (trace_complete=False), so duplicate
        # artifacts are NOT identical bytes and first-result-wins cannot
        # discard the loser's save — speculation stays off under trace
        speculate = self.speculate and not self.trace

        hb = HeartbeatMonitor(0, timeout_s=self.heartbeat_timeout_s,
                              clock=self.clock)
        sp = StragglerPolicy(ratio=self.straggler_ratio, clock=self.clock)
        core = DispatchCore(self.campaign, [u.key for u in todo],
                            retries=self.spec.retries, heartbeat=hb,
                            straggler=sp, stats=self.stats,
                            clock=self.clock, verbose=self.verbose)

        def reap(wid: int, reason: str) -> None:
            """A worker died (exit) or hung (heartbeat timeout): discard
            it, requeue its in-flight unit."""
            w = self._workers.pop(wid, None)
            if w is None:
                return
            hb.remove(wid)
            if w.proc.is_alive():
                w.proc.terminate()
            w.proc.join(timeout=5.0)
            key = w.inflight
            if self.verbose:
                print(f"  worker {wid} {reason}"
                      + (f" while running [{key}]" if key else ""))
            if key is not None:
                core.worker_lost(key, f"worker {reason}",    # abandons the
                                 worker=w)                   # straggler stamp

        def drain() -> int:
            """Pull every queued message from every worker's own result
            queue; sleep one poll tick when nothing arrived so the driver
            loop doesn't spin."""
            n = 0
            for wid, w in list(self._workers.items()):
                while True:
                    try:
                        msg = w.result_q.get_nowait()
                    except queue_mod.Empty:
                        break
                    except (OSError, ValueError):   # queue torn down
                        break
                    n += 1
                    kind = msg[0]
                    hb.beat(wid)
                    if kind == "done":
                        _, _, key, wall, n_pairs = msg
                        core.finish_done(self._workers.get(wid), key,
                                         wall, n_pairs)
                    elif kind == "failed":
                        _, _, key, error = msg
                        core.release(self._workers.get(wid), key,
                                     status="failed")
                        core.record_failure(key, error)
                    # "ready"/"start"/"beat" only feed the monitor
            if n == 0 and self.poll_s:
                time.sleep(self.poll_s)
            return n

        for _ in range(min(self.max_workers, len(core.pending))):
            self._spawn_worker(hb)

        try:
            while not core.all_resolved:
                # assign pending units to idle workers
                idle = [w for w in self._workers.values()
                        if w.inflight is None]
                while idle and core.pending:
                    key = core.next_pending()
                    if key is None:
                        break
                    core.dispatch(idle.pop(), key)
                # keep the fleet at strength while queued work remains
                while (core.pending
                       and len(self._workers) < min(self.max_workers,
                                                    len(core.pending))):
                    self._spawn_worker(hb)
                    self.stats["respawned_workers"] += 1
                # speculation: clone the slowest straggler onto idle
                # capacity once the queue is empty
                if speculate and not core.pending:
                    idle = [w for w in self._workers.values()
                            if w.inflight is None]
                    cand = core.speculation_candidate()
                    if idle and cand is not None:
                        core.dispatch(idle[0], cand, speculative=True)
                drain()
                # idle workers legitimately send nothing: keep them alive
                # in the monitor so only silent *busy* workers count
                for wid, w in self._workers.items():
                    if w.inflight is None:
                        hb.beat(wid)
                # crash detection: process exited (messages already
                # drained above, so a clean "done" wins over the reap)
                for wid in [w for w, st in list(self._workers.items())
                            if not st.proc.is_alive()]:
                    self.stats["crashed_workers"] += 1
                    reap(wid, "crashed")
                # hang detection: heartbeat silence past the timeout
                for wid in hb.dead():
                    if self._workers.get(wid) is not None:
                        self.stats["hung_workers"] += 1
                        reap(wid, "hung (heartbeat timeout)")
                # exhausted units whose last in-flight copy vanished
                core.finalize_exhausted()
        finally:
            self._shutdown()
            core.obs_close()
        return core.ordered_outcomes()

    # -------------------------------------------------------------- #
    def _spawn_worker(self, hb) -> None:
        wid = self._next_wid
        self._next_wid += 1
        task_q = self._ctx.Queue()
        result_q = self._ctx.Queue()
        store_root = os.path.dirname(self.campaign.dir)
        span_path = (self.campaign.span_path(f"worker{wid}")
                     if self.spans else None)
        proc = self._ctx.Process(
            target=_worker_main,
            args=(wid, self.spec.to_dict(), store_root,
                  self.campaign.campaign_id, task_q, result_q,
                  self.fault_plan, self.trace, span_path),
            daemon=True)
        proc.start()
        self._workers[wid] = _Worker(proc=proc, task_q=task_q,
                                     result_q=result_q)
        hb.register(wid)

    def _shutdown(self) -> None:
        # every unit is resolved by now, so a worker still mid-unit is a
        # losing speculative duplicate: its remaining work is discarded,
        # terminate it outright (artifact writes are atomic, a kill can
        # only leave tmp debris).  Idle workers get the poison pill and a
        # short grace period.
        for w in self._workers.values():
            if w.inflight is not None and w.proc.is_alive():
                w.proc.terminate()
                continue
            try:
                w.task_q.put(_POISON)
            except (OSError, ValueError):
                pass
        deadline = time.monotonic() + 5.0
        for w in self._workers.values():
            w.proc.join(timeout=max(0.1, deadline - time.monotonic()))
            if w.proc.is_alive():
                w.proc.terminate()
                w.proc.join(timeout=2.0)
            # drain leftovers so the queue feeder threads exit cleanly
            try:
                while True:
                    w.result_q.get_nowait()
            except (queue_mod.Empty, OSError, ValueError):
                pass
        self._workers.clear()
