"""Fleet monitor: always-on streaming drift detection over live trace
event streams, judged against a stored campaign baseline.

    DeviceStream                  events -> latency estimates (ingest)
    PairMonitor / DriftConfig     sequential drift tests     (drift)
    MonitorService                fleet service: streams, heartbeats,
                                  alert artifacts              (service)
    MetricsRegistry               counters/gauges/histograms  (metrics)
    drift_alert_doc / alert_summary   alert documents          (alerts)
    AlertSink / make_sink         push delivery with retry +
                                  dead-lettering                (sinks)

CLI: ``python -m repro.monitor {status,watch,replay}``.
"""
from repro.monitor.alerts import alert_summary, drift_alert_doc, stale_alert_doc
from repro.monitor.drift import DriftConfig, DriftEvent, PairMonitor
from repro.monitor.ingest import DeviceStream, PassEstimate, fit_baseline
from repro.monitor.metrics import (Counter, Gauge, Histogram,
                                   MetricsRegistry, start_http_server)
from repro.monitor.service import MonitorConfig, MonitorService
from repro.monitor.sinks import (AlertSink, FileSink, HttpSink, QueueSink,
                                 RetryingSink, make_sink)

__all__ = [
    "alert_summary", "drift_alert_doc", "stale_alert_doc",
    "DriftConfig", "DriftEvent", "PairMonitor",
    "DeviceStream", "PassEstimate", "fit_baseline",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "start_http_server",
    "MonitorConfig", "MonitorService",
    "AlertSink", "FileSink", "HttpSink", "QueueSink", "RetryingSink",
    "make_sink",
]
