"""The fleet monitor: many device streams, one campaign baseline, alerts.

:class:`MonitorService` is the always-on piece: it ingests live trace
event streams from any number of devices concurrently (each via a
:class:`~repro.monitor.ingest.DeviceStream`), maintains per-(device,
f_init, f_target) sequential drift tests
(:class:`~repro.monitor.drift.PairMonitor`) against a *stored* campaign's
measured tables — resolved exactly as :meth:`Governor.from_campaign`
resolves them, so the monitor watches the same table the governor is
running on — and persists every confirmed departure as a
content-addressed alert artifact in that campaign's store.

Time is the stream's own: the service clock is the max ``t_host`` seen
across all attached devices, which drives a
:class:`~repro.runtime.fault_tolerance.HeartbeatMonitor` so a device
that goes silent while its peers advance raises a ``stale-device``
alert — live and in replay alike (replay just advances the clock from
the recorded timestamps, which is why alert artifacts are bit-for-bit
reproducible).
"""
from __future__ import annotations

import dataclasses

from repro.monitor import alerts as alertdoc
from repro.monitor.drift import DriftConfig, PairMonitor
from repro.monitor.ingest import DeviceStream, replay_events
from repro.monitor.metrics import MetricsRegistry
from repro.runtime.fault_tolerance import HeartbeatMonitor

_LATENCY_BUCKETS = (1e-3, 5e-3, 1e-2, 2.5e-2, 5e-2, 0.1, 0.25, 0.5)


@dataclasses.dataclass(frozen=True)
class MonitorConfig:
    drift: DriftConfig = DriftConfig()
    k_sigma: float = 2.0                # online detection band (Alg. 2)
    heartbeat_timeout_s: float = 30.0   # stream-time silence -> stale


class _DeviceState:
    __slots__ = ("stream", "unit_key", "table", "monitors", "n_alerts",
                 "stale")

    def __init__(self, stream: DeviceStream, unit_key: str, table):
        self.stream = stream
        self.unit_key = unit_key
        self.table = table              # baseline LatencyTable
        self.monitors: dict = {}        # (fi, ft) -> PairMonitor | None
        self.n_alerts = 0
        self.stale = False


class MonitorService:
    """Streaming drift detection for a fleet against one campaign."""

    def __init__(self, campaign, cfg: MonitorConfig | None = None,
                 registry: MetricsRegistry | None = None, sink=None):
        if isinstance(campaign, str):
            from repro.campaign.store import ArtifactStore
            campaign = ArtifactStore().load(campaign)
        self.campaign = campaign
        self.cfg = cfg or MonitorConfig()
        # optional AlertSink (repro.monitor.sinks): every persisted alert
        # is also pushed — wrap external sinks in RetryingSink so a dead
        # endpoint cannot take the monitor down
        self.sink = sink
        self._devices: dict[str, _DeviceState] = {}
        self._now = 0.0                 # stream clock: max t_host seen
        self.heartbeat = HeartbeatMonitor(
            timeout_s=self.cfg.heartbeat_timeout_s, clock=lambda: self._now)
        self.alerts: list[tuple[str, str, dict]] = []  # (id, unit_key, doc)
        self.metrics = registry if registry is not None else MetricsRegistry()
        m = self.metrics
        self.m_events = m.counter(
            "monitor_events_total", "Trace events ingested")
        self.m_passes = m.counter(
            "monitor_passes_total", "Switch passes reconstructed")
        self.m_estimates = m.counter(
            "monitor_estimates_total",
            "Latency estimates emitted (kind=provisional|final)")
        self.m_alerts = m.counter(
            "monitor_alerts_total", "Alerts raised (kind=drift|stale-device)")
        self.m_score = m.gauge(
            "monitor_drift_score", "Current detector score per watched pair")
        self.m_lag = m.gauge(
            "monitor_ingest_lag_s",
            "Stream time since the device's last event")
        self.m_latency = m.histogram(
            "monitor_latency_seconds", "Final switching-latency estimates",
            buckets=_LATENCY_BUCKETS)

    # -------------------------------------------------------------- #
    # attachment
    # -------------------------------------------------------------- #
    def _resolve_unit(self, device: str, unit_key: str | None) -> str:
        """Unit key for a device's baseline table — the exact resolution
        rule Governor.from_campaign applies (a full unit key, or a device
        key matching exactly one finished unit)."""
        done = self.campaign.done_units()
        key = unit_key or device
        if key in done:
            return key
        matches = [k for k in done if k.split("@", 1)[0] == key]
        if len(matches) != 1:
            raise KeyError(
                f"device {key!r} matches {matches or 'no'} finished unit(s) "
                f"of campaign {self.campaign.campaign_id} (have: {done}); "
                "pass an explicit unit_key")
        return matches[0]

    def attach(self, device: str, unit_key: str | None = None) -> None:
        """Start monitoring one device's stream against its baseline
        table; idempotent (re-attach keeps existing stream state)."""
        if device in self._devices:
            return
        key = self._resolve_unit(device, unit_key)
        table = self.campaign.load_table(key)
        self._devices[device] = _DeviceState(
            DeviceStream(device, k_sigma=self.cfg.k_sigma), key, table)
        self.heartbeat.register(device)

    def attach_recorder(self, device: str, recorder,
                        unit_key: str | None = None):
        """Live attachment: subscribe to a :class:`TraceRecorder`'s event
        taps.  Returns the tap function (pass it to ``remove_tap`` to
        detach)."""
        self.attach(device, unit_key)

        def _tap(kind, t_host, cols, data, extra):
            self.handle_event(device, kind, t_host, cols, data, extra)

        recorder.add_tap(_tap)
        return _tap

    @property
    def devices(self) -> list[str]:
        return sorted(self._devices)

    # -------------------------------------------------------------- #
    # ingestion
    # -------------------------------------------------------------- #
    def handle_event(self, device: str, kind, t_host, cols, data=None,
                     extra=None) -> list[tuple[str, str, dict]]:
        """One raw event from ``device``; returns alerts raised by it."""
        st = self._devices[device]
        self.m_events.inc(device=device)
        before = len(self.alerts)
        prev_passes = st.stream.n_passes
        est = st.stream.feed(kind, t_host, cols, data, extra)
        if st.stream.n_passes > prev_passes:
            self.m_passes.inc(device=device)
        t = st.stream.last_t
        if t is not None:
            if t > self._now:
                self._now = t
            # beat with the SERVICE clock, not the device's own timeline:
            # devices record independent host clocks, so a device whose
            # timeline merely lags its peers is alive (events are
            # arriving) — silence means no events while the fleet's
            # stream time advances
            self.heartbeat.beat(device, self._now)
            st.stale = False            # a live event ends any silence
        if est is not None:
            self.m_estimates.inc(est.n_provisional,
                                 device=device, kind="provisional")
            if est.latency_s is not None:
                self.m_estimates.inc(device=device, kind="final")
                self.m_latency.observe(est.latency_s, device=device)
                self._observe(st, device, est)
        self._check_stale()
        return self.alerts[before:]

    def _pair_monitor(self, st: _DeviceState, fi: float,
                      ft: float) -> PairMonitor | None:
        key = (fi, ft)
        if key not in st.monitors:
            pr = st.table.pairs.get(key)
            if pr is None or pr.status != "ok" or not pr.clean.size:
                st.monitors[key] = None      # pair has no usable baseline
            else:
                st.monitors[key] = PairMonitor(st.unit_key, fi, ft, pr,
                                               self.cfg.drift)
        return st.monitors[key]

    def _observe(self, st: _DeviceState, device: str, est) -> None:
        mon = self._pair_monitor(st, est.f_init, est.f_target)
        if mon is None:
            return
        event = mon.observe(est.latency_s, t_stream=est.t_host)
        pair = f"{est.f_init:.0f}->{est.f_target:.0f}"
        self.m_score.set(mon.score, device=device, pair=pair)
        if event is not None:
            doc = alertdoc.drift_alert_doc(event, self.campaign.campaign_id,
                                           device)
            self._raise_alert(st, doc)

    def _raise_alert(self, st: _DeviceState, doc: dict) -> None:
        alert_id = self.campaign.save_alert(st.unit_key, doc)
        self.alerts.append((alert_id, st.unit_key, doc))
        st.n_alerts += 1
        self.m_alerts.inc(kind=doc["kind"], device=doc["device"])
        if self.sink is not None:
            self.sink.deliver(alert_id, st.unit_key, doc)

    def _check_stale(self) -> None:
        for device in self._devices:
            last = self.heartbeat.last.get(device)
            if last is not None:
                self.m_lag.set(max(0.0, self._now - last), device=device)
        for device in self.heartbeat.dead():
            st = self._devices.get(device)
            if st is None or st.stale:
                continue                # already alerted for this silence
            st.stale = True
            doc = alertdoc.stale_alert_doc(
                device, st.unit_key, float(self.heartbeat.last[device]),
                self._now, self.cfg.heartbeat_timeout_s,
                self.campaign.campaign_id)
            self._raise_alert(st, doc)

    # -------------------------------------------------------------- #
    # offline replay
    # -------------------------------------------------------------- #
    def replay_trace(self, trace, device: str | None = None,
                     unit_key: str | None = None
                     ) -> list[tuple[str, str, dict]]:
        """Drive the monitor from a recorded trace's event stream — the
        exact events a live tap would have delivered.  Returns the alerts
        this replay raised (content-addressing makes re-replays
        byte-identical and the saves idempotent)."""
        if device is None:
            device = trace.meta.get("sweep", {}).get("device_name", "trace")
        self.attach(device, unit_key)
        before = len(self.alerts)
        for ev in replay_events(trace):
            self.handle_event(device, *ev)
        return self.alerts[before:]

    # -------------------------------------------------------------- #
    # status
    # -------------------------------------------------------------- #
    def status(self) -> dict:
        """Live snapshot for the CLI: per-device ingest counters, watched
        pairs, current worst drift score, and alert totals."""
        devices = {}
        for name in sorted(self._devices):
            st = self._devices[name]
            s = st.stream
            worst_pair, worst_score = None, 0.0
            for (fi, ft), mon in st.monitors.items():
                if mon is not None and mon.score >= worst_score:
                    worst_pair, worst_score = f"{fi:.0f}->{ft:.0f}", mon.score
            devices[name] = {
                "unit_key": st.unit_key,
                "events": s.n_events,
                "passes": s.n_passes,
                "skipped": s.n_skipped,
                "rejected": s.n_rejected,
                "provisional": s.n_provisional,
                "baselines": len(s.baselines),
                "pairs_watched": sum(1 for m in st.monitors.values()
                                     if m is not None),
                "alerts": st.n_alerts,
                "stale": st.stale,
                "last_t": s.last_t,
                "max_score": worst_score,
                "max_score_pair": worst_pair,
            }
        return {"campaign_id": self.campaign.campaign_id,
                "now": self._now,
                "n_alerts": len(self.alerts),
                "devices": devices}
