"""Alert sinks: push alerts to the outside instead of being polled.

The monitor's alerts are durable store artifacts first — a sink is the
*delivery* side: an :class:`AlertSink` receives each alert document once
and forwards it somewhere an operator actually looks (a webhook, a
file a log shipper tails, an in-process queue).  Delivery reuses the
cluster layer's retry machinery (:mod:`repro.campaign.cluster.retry`):
a flaky endpoint gets capped-exponential seeded-jitter retries, and an
alert that exhausts its budget is appended to a dead-letter file — the
fleet never loses an alert silently, and a down webhook never wedges
the monitor (delivery failures are contained by :class:`RetryingSink`).

Shipped sinks:

* :class:`FileSink` — append-only JSONL, one alert per line.  The
  queue-shaped integration: anything that tails a file (or reads it as
  a work queue) consumes the stream;
* :class:`HttpSink` — webhook-shaped POST of the alert document as
  JSON.  The transport callable is injectable (tests inject a fake;
  the default uses urllib) and transport-level failures surface as
  retryable :class:`TransportError`;
* :class:`QueueSink` — an in-memory list for embedding the monitor in
  another process (and for tests);
* :class:`RetryingSink` — the policy wrapper every external sink should
  wear: retry with backoff, dead-letter on exhaustion, never raise.

``make_sink(spec)`` maps a CLI string to a wrapped sink: ``http(s)://``
URLs become webhooks, anything else is a JSONL file path.
"""
from __future__ import annotations

import json
import threading
from typing import Protocol

from repro.campaign.cluster.retry import (DeadLetterFile, RetriesExhausted,
                                          RetryPolicy, TransportError,
                                          call_with_retry)


class AlertSink(Protocol):
    """One-way alert delivery.  ``deliver`` is called once per alert;
    implementations raise :class:`RetryableError` subclasses for
    failures a retry may cure."""

    def deliver(self, alert_id: str, unit_key: str,
                doc: dict) -> None: ...         # pragma: no cover


def _payload(alert_id: str, unit_key: str, doc: dict) -> dict:
    return {"id": alert_id, "unit_key": unit_key, **doc}


class QueueSink:
    """In-memory sink: embedders drain ``items``; tests assert on it."""

    def __init__(self):
        self.items: list[dict] = []
        self._lock = threading.Lock()

    def deliver(self, alert_id: str, unit_key: str, doc: dict) -> None:
        with self._lock:
            self.items.append(_payload(alert_id, unit_key, doc))


class FileSink:
    """Append-only JSONL file, one alert per line (atomic line appends:
    POSIX O_APPEND interleaves whole lines across writers)."""

    def __init__(self, path: str):
        self.path = path
        self._lock = threading.Lock()

    def deliver(self, alert_id: str, unit_key: str, doc: dict) -> None:
        import os
        line = json.dumps(_payload(alert_id, unit_key, doc),
                          sort_keys=True)
        try:
            with self._lock:
                d = os.path.dirname(self.path)
                if d:
                    os.makedirs(d, exist_ok=True)
                with open(self.path, "a") as f:
                    f.write(line + "\n")
        except OSError as exc:      # full disk, dropped mount: retryable
            raise TransportError(
                f"sink file {self.path} unwritable: {exc}") from exc


def _urllib_post(url: str, body: bytes, timeout_s: float) -> int:
    """Default HTTP transport; returns the status code, raises OSError
    family on link failure."""
    import urllib.request
    req = urllib.request.Request(
        url, data=body, headers={"Content-Type": "application/json"},
        method="POST")
    with urllib.request.urlopen(req, timeout=timeout_s) as resp:  # noqa: S310
        return int(getattr(resp, "status", 200))


class HttpSink:
    """Webhook-shaped sink: POST the alert document as a JSON body.

    ``post`` is the injectable transport — ``(url, body_bytes,
    timeout_s) -> status_code``.  Link errors and non-2xx statuses are
    retryable: webhooks flake, and the retry wrapper owns the budget."""

    def __init__(self, url: str, post=None, timeout_s: float = 5.0):
        self.url = url
        self.post = post or _urllib_post
        self.timeout_s = timeout_s

    def deliver(self, alert_id: str, unit_key: str, doc: dict) -> None:
        body = json.dumps(_payload(alert_id, unit_key, doc),
                          sort_keys=True).encode()
        try:
            status = self.post(self.url, body, self.timeout_s)
        except OSError as exc:      # URLError subclasses OSError
            raise TransportError(
                f"webhook {self.url} unreachable: {exc}") from exc
        if not 200 <= int(status) < 300:
            raise TransportError(
                f"webhook {self.url} answered HTTP {status}")


class RetryingSink:
    """Delivery policy around any sink: retries with backoff, records
    exhausted deliveries as dead letters, and NEVER raises — a dead
    webhook must not take the monitor down with it.  ``delivered`` /
    ``dead`` count outcomes."""

    def __init__(self, sink, policy: RetryPolicy | None = None,
                 dead_letters: DeadLetterFile | None = None, sleep=None):
        self.sink = sink
        self.policy = policy or RetryPolicy(max_attempts=4, base_s=0.1,
                                            cap_s=2.0)
        self.dead_letters = dead_letters
        self.sleep = sleep
        self.delivered = 0
        self.dead = 0

    def deliver(self, alert_id: str, unit_key: str, doc: dict) -> None:
        kw = {} if self.sleep is None else {"sleep": self.sleep}
        try:
            call_with_retry(
                lambda: self.sink.deliver(alert_id, unit_key, doc),
                self.policy, op="alert.deliver", op_key=alert_id,
                dead_letters=self.dead_letters, **kw)
        except RetriesExhausted:
            self.dead += 1          # dead-lettered by call_with_retry
        else:
            self.delivered += 1


def make_sink(spec: str, *, dead_letter_path: str | None = None,
              policy: RetryPolicy | None = None,
              post=None) -> RetryingSink:
    """CLI string -> wrapped sink: ``http(s)://...`` is a webhook,
    anything else a JSONL file path."""
    if spec.startswith(("http://", "https://")):
        inner: AlertSink = HttpSink(spec, post=post)
    else:
        inner = FileSink(spec)
    dl = (DeadLetterFile(dead_letter_path)
          if dead_letter_path is not None else None)
    return RetryingSink(inner, policy=policy, dead_letters=dl)
