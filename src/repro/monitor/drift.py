"""Per-(device, pair) sequential drift detection against a campaign
baseline.

Each :class:`PairMonitor` watches ONE (unit, f_init, f_target) stream of
switching-latency samples (the online estimator's finals) and answers
"has this pair departed its baseline?" in two stages:

1. **trigger** — cheap sequential tests every sample: two-sided CUSUM and
   Page-Hinkley (:mod:`repro.core.stats`) over residuals standardized
   against the baseline's clean distribution.  Latency windows are
   multi-modal and outlier-ridden (Figs. 5-6), so the detectors run over
   the DBSCAN-*cleaned* sliding window — the same
   :func:`~repro.core.latency_table.analyse_pair` split the campaign
   analysis uses — recomputed per observation (the window is <= 64
   samples; the engine is O(w log w)).  The raw window's running
   mean/std/RSE come from :class:`~repro.core.stats.RunningStats` with
   O(1) add/remove on eviction.
2. **confirm** — a trigger alone never alerts.  The candidate window is
   re-analysed and judged by :func:`repro.campaign.regression.pair_drift`
   — the *identical* worst-delta + Mann-Whitney rule ``diff_campaigns``
   applies batch-wise — so streaming and batch verdicts agree on the same
   data by construction.  The monitor additionally requires a *powered
   window* (>= ``min_samples`` clean samples of evidence): the batch
   differ's "underpowered -> delta decides alone" fallback is fine for a
   human-reviewed diff but would let a 2-sample window page an operator.
   The baseline side is taken as stored — when the campaign kept fewer
   clean samples than ``min_samples``, the delta rule decides for the
   monitor as it would for ``diff_campaigns``, but against the *larger*
   ``unpowered_delta`` threshold: without a powered two-sample test a
   worst-case-only comparison must clear a much wider margin before it
   pages anyone (a human-reviewed batch diff can afford the lower bar).
   Every alert the monitor raises is therefore also flagged by
   ``diff_campaigns`` on the same data; the reverse holds whenever the
   batch verdict was test-backed.

After an alert the window and detectors reset and a cooldown suppresses
re-alerting while the pair's stream refills.  A failed confirm changes
nothing: the evidence window keeps accumulating and the confirm re-runs
on the next sample — at <= 64-sample windows the confirm costs the same
O(w log w) as the trigger, so there is nothing to debounce.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.campaign.regression import DiffConfig, pair_drift
from repro.core import stats
from repro.core.latency_table import PairResult, analyse_pair


@dataclasses.dataclass(frozen=True)
class DriftConfig:
    """Tuning for one monitor's drift tests (shared across pairs)."""
    window: int = 32              # sliding-window capacity (raw samples)
    min_window: int = 4           # samples before a confirm may run
    cusum_k: float = 0.5          # CUSUM per-sample allowance (sigmas)
    cusum_h: float = 5.0          # CUSUM trip threshold
    ph_delta: float = 0.05        # Page-Hinkley allowance
    ph_lambda: float = 5.0        # Page-Hinkley trip threshold
    cooldown: int = 8             # samples suppressed after an alert
    sigma_floor_frac: float = 0.02  # baseline sigma floor (x mean): a
                                    # degenerate tight baseline must not
                                    # turn timer jitter into huge z-scores
    unpowered_delta: float = 0.75   # |rel delta| needed to confirm when
                                    # the baseline is too small for the
                                    # Mann-Whitney test to run
    diff: DiffConfig = DiffConfig()


@dataclasses.dataclass(frozen=True)
class DriftEvent:
    """One confirmed departure of a pair from its baseline."""
    unit_key: str
    f_init: float
    f_target: float
    sample_index: int             # pair samples seen when the alert fired
    t_stream: float               # stream timestamp of the deciding sample
    cusum_score: float
    ph_score: float
    drift: object                 # the confirming PairDrift verdict
    window: tuple                 # offending window's raw samples (s)
    window_clean: tuple           # its DBSCAN-clean subset
    baseline_worst: float
    baseline_mean: float
    baseline_n: int


class PairMonitor:
    """Streaming drift test for one (unit, f_init, f_target) pair."""

    def __init__(self, unit_key: str, f_init: float, f_target: float,
                 baseline: PairResult, cfg: DriftConfig | None = None):
        self.cfg = cfg or DriftConfig()
        self.unit_key = unit_key
        self.f_init = float(f_init)
        self.f_target = float(f_target)
        self.baseline = baseline
        base = np.asarray(baseline.clean, dtype=np.float64)
        self._base_mean = float(base.mean()) if base.size else 0.0
        sigma = float(base.std(ddof=1)) if base.size > 1 else 0.0
        self._base_sigma = max(
            sigma, self.cfg.sigma_floor_frac * abs(self._base_mean), 1e-12)
        self._window: list[float] = []
        self._running = stats.RunningStats()   # raw window, O(1) add/remove
        self.n_seen = 0                        # pair samples ever observed
        self._cooldown = 0
        self.cusum_score = 0.0
        self.ph_score = 0.0

    # ------------------------------------------------------------ #
    @property
    def window_size(self) -> int:
        return len(self._window)

    @property
    def window_mean(self) -> float:
        return self._running.mean

    @property
    def score(self) -> float:
        """Max of the two detector statistics — the drift-score gauge."""
        return max(self.cusum_score, self.ph_score)

    def _clean_window(self) -> np.ndarray:
        pr = analyse_pair(self.f_init, self.f_target,
                          np.asarray(self._window), with_silhouette=False)
        return pr.clean

    def _rescore(self) -> bool:
        """Recompute CUSUM + PH over the cleaned window's standardized
        residuals (deterministic: the detectors are pure functions of the
        window's clean subset, immune to eviction-order effects)."""
        clean = self._clean_window()
        cusum = stats.Cusum(self.cfg.cusum_k, self.cfg.cusum_h)
        ph = stats.PageHinkley(self.cfg.ph_delta, self.cfg.ph_lambda)
        for v in clean:
            z = (float(v) - self._base_mean) / self._base_sigma
            cusum.update(z)
            ph.update(z)
        self.cusum_score = cusum.score
        self.ph_score = ph.score
        return cusum.tripped or ph.tripped

    def _reset_window(self) -> None:
        self.cusum_score = self.ph_score = 0.0
        self._window.clear()
        self._running = stats.RunningStats()

    # ------------------------------------------------------------ #
    def observe(self, latency_s: float,
                t_stream: float = 0.0) -> DriftEvent | None:
        """One final latency estimate for this pair; returns a confirmed
        :class:`DriftEvent` or None."""
        self.n_seen += 1
        self._window.append(float(latency_s))
        self._running.add(float(latency_s))
        if len(self._window) > self.cfg.window:
            self._running.remove(self._window.pop(0))
        if self._cooldown > 0:
            self._cooldown -= 1
            return None
        tripped = self._rescore()
        if not tripped or len(self._window) < self.cfg.min_window:
            return None
        candidate = analyse_pair(self.f_init, self.f_target,
                                 np.asarray(self._window),
                                 with_silhouette=False)
        verdict = pair_drift(self.unit_key, self.f_init, self.f_target,
                             self.baseline, candidate, self.cfg.diff)
        powered_window = candidate.clean.size >= self.cfg.diff.min_samples
        test_ran = verdict.p_value == verdict.p_value      # not NaN
        confirmed = verdict.flagged and powered_window and (
            test_ran or abs(verdict.rel_delta) > self.cfg.unpowered_delta)
        if confirmed:
            event = DriftEvent(
                self.unit_key, self.f_init, self.f_target,
                sample_index=self.n_seen, t_stream=float(t_stream),
                cusum_score=self.cusum_score, ph_score=self.ph_score,
                drift=verdict,
                window=tuple(self._window),
                window_clean=tuple(float(v) for v in candidate.clean),
                baseline_worst=self.baseline.worst_case,
                baseline_mean=self._base_mean,
                baseline_n=int(self.baseline.clean.size))
            self._reset_window()
            self._cooldown = self.cfg.cooldown
            return event
        return None
