"""Alert documents: the monitor's durable, content-addressed artifacts.

An alert is a plain JSON document persisted through
:meth:`repro.campaign.store.Campaign.save_alert` under the *baseline*
campaign's unit directory (``units/<key>/alerts/<id>.json``) — the
campaign whose table the fleet is being judged against is where the
evidence of departure belongs.  The id is the sha256 of the canonical
bytes, so replaying a recorded stream reproduces bit-identical files
(the CI determinism gate); every timestamp inside comes from the trace's
own timeline, never the wall clock.
"""
from __future__ import annotations

import math

from repro.monitor.drift import DriftEvent

DRIFT = "drift"
STALE_DEVICE = "stale-device"


def _finite(v: float) -> float:
    v = float(v)
    if not math.isfinite(v):
        raise ValueError(f"alert documents must be strict JSON: got {v!r}")
    return v


def drift_alert_doc(event: DriftEvent, campaign_id: str,
                    device: str) -> dict:
    """Canonical document for one confirmed pair drift: the verdict, the
    offending window's samples, and the baseline stats it was judged
    against — everything an operator (or a batch re-check with
    ``diff_campaigns``) needs, with no reach-back into monitor state."""
    d = event.drift
    return {
        "kind": DRIFT,
        "campaign_id": campaign_id,
        "unit_key": event.unit_key,
        "device": device,
        "f_init": _finite(event.f_init),
        "f_target": _finite(event.f_target),
        "sample_index": int(event.sample_index),
        "t_stream": _finite(event.t_stream),
        "scores": {"cusum": _finite(event.cusum_score),
                   "page_hinkley": _finite(event.ph_score)},
        "verdict": {
            "worst_baseline_s": _finite(d.worst_a),
            "worst_window_s": _finite(d.worst_b),
            "rel_delta": _finite(d.rel_delta),
            # NaN = underpowered baseline, delta decided alone (the batch
            # differ's fallback); null like diff_to_dict
            "p_value": None if d.p_value != d.p_value else _finite(d.p_value),
            "flagged": bool(d.flagged),
        },
        "window": {"samples_s": [_finite(v) for v in event.window],
                   "clean_s": [_finite(v) for v in event.window_clean]},
        "baseline": {"worst_s": _finite(event.baseline_worst),
                     "mean_s": _finite(event.baseline_mean),
                     "n_clean": int(event.baseline_n)},
    }


def stale_alert_doc(device: str, unit_key: str, last_event_t: float,
                    now_t: float, timeout_s: float,
                    campaign_id: str) -> dict:
    """A device whose stream went silent past the heartbeat timeout —
    raised once per silence (the service de-duplicates), timestamps on
    the stream's own timeline."""
    return {
        "kind": STALE_DEVICE,
        "campaign_id": campaign_id,
        "unit_key": unit_key,
        "device": device,
        "last_event_t": _finite(last_event_t),
        "now_t": _finite(now_t),
        "silent_s": _finite(now_t - last_event_t),
        "timeout_s": _finite(timeout_s),
    }


def alert_summary(doc: dict) -> str:
    """One human line per alert (``monitor status`` / ``replay``)."""
    if doc.get("kind") == DRIFT:
        v = doc["verdict"]
        p = "-" if v["p_value"] is None else f"{v['p_value']:.3g}"
        return (f"DRIFT {doc['unit_key']} "
                f"{doc['f_init']:.0f}->{doc['f_target']:.0f} MHz: "
                f"worst {v['worst_baseline_s'] * 1e3:.2f} -> "
                f"{v['worst_window_s'] * 1e3:.2f} ms "
                f"({v['rel_delta']:+.1%}, p={p}, "
                f"sample {doc['sample_index']})")
    if doc.get("kind") == STALE_DEVICE:
        return (f"STALE {doc['device']} ({doc['unit_key']}): silent "
                f"{doc['silent_s']:.1f}s > {doc['timeout_s']:.1f}s timeout")
    return f"UNKNOWN alert kind {doc.get('kind')!r}"
