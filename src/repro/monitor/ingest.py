"""Per-device stream ingestion: raw trace events in, latency estimates out.

A :class:`DeviceStream` consumes ONE device's event stream — live, via
:meth:`repro.trace.recorder.TraceRecorder.add_tap`, or offline from a
stored trace replayed event by event — and turns it into per-pair
switching-latency estimates:

* switch passes are reconstructed push-style by the same
  :class:`~repro.trace.analyze.SwitchPassAssembler` the offline analyzer
  uses, so live ingestion and ``trace analyze`` see identical passes;
* each completed pass streams through
  :func:`repro.trace.online.stream_pass` (Alg. 2 as a state machine)
  against the *learned* target baseline, yielding the final estimate the
  drift tests consume;
* baselines are learned from the stream itself: every uncrossed kernel
  (no ``set_frequency`` between its launch and wait) refits the current
  frequency's :class:`~repro.core.stats.FreqStats` with calibration's
  exact recipe — per-iteration durations, top-0.5% trim
  (:func:`repro.core.calibration.calibrate`), last kernel wins.  After
  the recorded session's calibration phase the learned table therefore
  *equals* the session's own ``cal.baselines``, with no side channel:
  the monitor needs nothing but the bytes on the wire.

The stream never buffers events — state is the assembler, one FreqStats
per seen frequency, and counters.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import stats
from repro.trace import schema
from repro.trace.analyze import SwitchPassAssembler
from repro.trace.online import stream_pass


@dataclasses.dataclass(frozen=True)
class PassEstimate:
    """One reconstructed switch pass's online estimate."""
    device: str
    f_init: float
    f_target: float
    t_host: float               # stream timestamp of the completing WAIT
    t_s: float                  # change request, accelerator timeline
    latency_s: float | None     # None: no viable core (Alg. 2 GOTO)
    n_provisional: int


def fit_baseline(data: np.ndarray, freq_mhz: float) -> stats.FreqStats:
    """Calibration's baseline recipe over one kernel's (cores, iters, 2)
    timestamps: per-iteration durations, top-0.5% driver-spike trim."""
    iters = np.diff(data, axis=-1)[..., 0].ravel()
    trimmed = iters[iters <= np.quantile(iters, 0.995)]
    return stats.mean_std(trimmed, freq_mhz=freq_mhz)


class DeviceStream:
    """Event-stream -> estimate pipeline for one device."""

    def __init__(self, name: str, *, k_sigma: float = 2.0):
        self.name = name
        self.k_sigma = float(k_sigma)
        self.asm = SwitchPassAssembler()
        self.baselines: dict[float, stats.FreqStats] = {}
        self.n_events = 0
        self.n_passes = 0               # switch passes reconstructed
        self.n_skipped = 0              # passes before their baseline existed
        self.n_rejected = 0             # passes with no viable core
        self.n_provisional = 0          # provisional estimates emitted
        self.last_t: float | None = None    # newest stream timestamp seen
        self._launch_freq: float | None = None

    def feed(self, kind: int, t_host: float, cols, data=None,
             extra=None) -> PassEstimate | None:
        """One event (the tap signature); returns the pass estimate when
        this event completed a switch pass, else None."""
        self.n_events += 1
        t_host = float(t_host)
        if self.last_t is None or t_host > self.last_t:
            self.last_t = t_host
        if kind == schema.LAUNCH:
            self._launch_freq = self.asm.current_freq
        sp = self.asm.feed(kind, cols, data)
        if kind == schema.BATCH:
            # calibration warm-up burst: its LAST kernel is the baseline
            if data is not None and self.asm.current_freq is not None:
                self.baselines[self.asm.current_freq] = fit_baseline(
                    np.asarray(data)[-1], self.asm.current_freq)
            return None
        if kind != schema.WAIT:
            return None
        if sp is None:
            # an uncrossed kernel ran wholly at one frequency: baseline
            # food — unless a set_frequency landed mid-kernel without
            # arming a pass (no sync yet), which would poison the fit
            freq = self.asm.current_freq
            if data is not None and freq is not None \
                    and self._launch_freq == freq:
                self.baselines[freq] = fit_baseline(np.asarray(data), freq)
            return None
        self.n_passes += 1
        target = self.baselines.get(sp.f_target)
        if target is None:
            self.n_skipped += 1
            return None
        final, provisional = stream_pass(sp.data, sp.t_s, target,
                                         k_sigma=self.k_sigma)
        self.n_provisional += len(provisional)
        if final is None:
            self.n_rejected += 1
        return PassEstimate(
            self.name, sp.f_init, sp.f_target, t_host, sp.t_s,
            None if final is None else float(final.latency),
            len(provisional))

    def tap(self):
        """Adapter matching :meth:`TraceRecorder.add_tap`'s callback
        signature exactly (drops the return value — live attachment goes
        through a service that reads estimates via :meth:`feed`)."""
        def _fn(kind, t_host, cols, data, extra):
            self.feed(kind, t_host, cols, data, extra)
        return _fn


def replay_events(trace) -> "iter":
    """Yield ``(kind, t_host, cols, data, extra)`` tap tuples for every
    event of a stored trace — the offline twin of a live tap subscription
    (:func:`repro.trace.analyze.trace_event_data` rebuilds each payload)."""
    from repro.trace.analyze import trace_event_data
    for i in range(trace.n_events):
        yield (int(trace.kinds[i]), float(trace.t_host[i]), trace.cols[i],
               trace_event_data(trace, i), trace.extras.get(i))
