"""`python -m repro.monitor` — the fleet monitor's command surface.

    status CID           alert + trace inventory of a campaign's units
    watch  CID           poll the store, print alerts as they appear;
                         with --sink URL, push undelivered alerts once
                         (webhook or JSONL file) and exit instead of
                         polling; --requeue records flagged drift alerts
                         in the campaign's requeue manifest for
                         `campaign run --requeue-from-alerts`
    replay CID TRACE...  drive the monitor from recorded event streams
                         (a trace directory or a unit key whose trace is
                         stored in the campaign); exit 1 with
                         --fail-on-alert when any alert fires — the CI
                         false-positive / must-detect gate

The store root defaults to ``$REPRO_RESULTS_DIR/campaigns`` (or
``results/campaigns``); every command takes ``--store`` to override.
"""
from __future__ import annotations

import argparse
import json
import os
import time

from repro.campaign.store import ArtifactStore
from repro.cliutil import emit as _emit
from repro.monitor.alerts import alert_summary
from repro.monitor.drift import DriftConfig
from repro.monitor.service import MonitorConfig, MonitorService


def _store(args) -> ArtifactStore:
    return ArtifactStore(args.store)


def _load_trace(campaign, ref: str):
    """A trace positional: a trace directory path, or a unit key whose
    stored session trace the campaign holds."""
    from repro.trace.recorder import Trace
    if os.path.isdir(ref):
        return Trace.load(ref), None
    if campaign.list_traces(ref).get(ref):
        return campaign.load_trace(ref), ref
    raise FileNotFoundError(
        f"{ref!r} is neither a trace directory nor a unit with a stored "
        f"trace in campaign {campaign.campaign_id}")


def _campaign_alerts(campaign) -> list[tuple[str, str, dict]]:
    return [(aid, unit, campaign.load_alert(unit, aid))
            for unit, ids in sorted(campaign.list_alerts().items())
            for aid in ids]


def cmd_status(args) -> int:
    campaign = _store(args).load(args.campaign)
    alerts = _campaign_alerts(campaign)
    if args.json:
        print(json.dumps({
            "campaign_id": campaign.campaign_id,
            "n_alerts": len(alerts),
            "alerts": [{"id": aid, "unit_key": unit, **doc}
                       for aid, unit, doc in alerts],
        }, indent=1, sort_keys=True))
        return 0
    traces = campaign.list_traces()
    by_unit = campaign.list_alerts()
    print(f"campaign {campaign.campaign_id}: "
          f"{len(campaign.done_units())} finished unit(s), "
          f"{len(alerts)} alert(s)")
    for unit in campaign.done_units():
        n_tr = len(traces.get(unit, []))
        n_al = len(by_unit.get(unit, []))
        flag = "  ALERTS" if n_al else ""
        print(f"  {unit}: {n_tr} trace(s), {n_al} alert(s){flag}")
    for aid, unit, doc in alerts:
        print(f"  [{aid[:12]}] {alert_summary(doc)}")
    return 0


def _maybe_requeue(args, campaign, aid: str, unit: str,
                   doc: dict) -> bool:
    """--requeue: a *flagged* drift alert invalidates the unit's data —
    record a re-measurement request (`campaign run
    --requeue-from-alerts` consumes it).  Unflagged drift scores and
    stale-device alerts do not requeue: there is nothing wrong with the
    stored measurement itself."""
    from repro.monitor.alerts import DRIFT
    if not (args.requeue and doc.get("kind") == DRIFT
            and doc.get("verdict", {}).get("flagged")):
        return False
    campaign.save_requeue({unit: {
        "reason": f"confirmed drift (alert {aid[:12]})",
        "alert_ids": [aid]}})
    return True


def cmd_watch(args) -> int:
    campaign = _store(args).load(args.campaign)

    if args.sink:
        # push mode: deliver every not-yet-delivered alert through the
        # sink once, then exit — a configured sink replaces store
        # polling (the sink's consumer owns the watching from here)
        from repro.campaign.cluster.retry import RetryPolicy
        from repro.monitor.sinks import make_sink
        sink = make_sink(
            args.sink,
            dead_letter_path=os.path.join(campaign.dir, "deadletter",
                                          "sink.jsonl"),
            policy=RetryPolicy(max_attempts=args.sink_retries,
                               base_s=0.1, cap_s=2.0))
        state_path = os.path.join(campaign.dir, "sink-delivered.json")
        delivered: set[str] = set()
        if os.path.exists(state_path):
            with open(state_path) as f:
                delivered = set(json.load(f).get("delivered", []))
        n_requeued = 0
        for aid, unit, doc in _campaign_alerts(campaign):
            if aid in delivered:
                continue
            sink.deliver(aid, unit, doc)
            delivered.add(aid)
            n_requeued += _maybe_requeue(args, campaign, aid, unit, doc)
            print(f"[{aid[:12]}] {alert_summary(doc)}", flush=True)
        from repro.core.paths import atomic_replace
        with atomic_replace(state_path) as tmp:
            with open(tmp, "w") as f:
                json.dump({"delivered": sorted(delivered)}, f, indent=1)
        print(f"sink {args.sink}: {sink.delivered} delivered, "
              f"{sink.dead} dead-lettered"
              + (f", {n_requeued} unit(s) requeued" if args.requeue
                 else "")
              + "; sink configured — store polling skipped")
        return 0 if sink.dead == 0 else 1

    seen = {aid for aid, _, _ in _campaign_alerts(campaign)}
    print(f"watching campaign {campaign.campaign_id} "
          f"({len(seen)} existing alert(s); poll every {args.interval}s)")
    rounds = 0
    while args.rounds <= 0 or rounds < args.rounds:
        rounds += 1
        for aid, unit, doc in _campaign_alerts(campaign):
            if aid in seen:
                continue
            seen.add(aid)
            _maybe_requeue(args, campaign, aid, unit, doc)
            print(f"[{aid[:12]}] {alert_summary(doc)}", flush=True)
        if args.rounds <= 0 or rounds < args.rounds:
            time.sleep(args.interval)
    return 0


def cmd_replay(args) -> int:
    campaign = _store(args).load(args.campaign)
    drift = DriftConfig(window=args.window, cooldown=args.cooldown)
    service = MonitorService(campaign, MonitorConfig(
        drift=drift, heartbeat_timeout_s=args.heartbeat_timeout))
    raised: list[tuple[str, str, dict]] = []
    for ref in args.traces:
        trace, unit_key = _load_trace(campaign, ref)
        raised += service.replay_trace(trace, device=args.device,
                                       unit_key=args.unit or unit_key)
    status = service.status()
    if args.metrics_out:
        service.metrics.write_snapshot(args.metrics_out)
    if args.prom_out:
        _emit(service.metrics.render_prometheus().rstrip("\n"),
              args.prom_out)
    if args.json:
        print(json.dumps({
            **status,
            "alerts": [{"id": aid, "unit_key": unit, **doc}
                       for aid, unit, doc in raised],
        }, indent=1, sort_keys=True))
    else:
        for name, d in status["devices"].items():
            print(f"{name} ({d['unit_key']}): {d['events']} events, "
                  f"{d['passes']} passes, {d['pairs_watched']} pair(s) "
                  f"watched, {d['alerts']} alert(s)"
                  + (", STALE" if d["stale"] else ""))
        for aid, _, doc in raised:
            print(f"[{aid[:12]}] {alert_summary(doc)}")
        print(f"{len(raised)} alert(s) raised")
    return 1 if (args.fail_on_alert and raised) else 0


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro.monitor",
        description="Fleet monitor: streaming drift detection, alerts, "
                    "live status")
    ap.add_argument("--store", default=None,
                    help="artifact store root (default: "
                         "$REPRO_RESULTS_DIR/campaigns)")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("status", help="alert + trace inventory per unit")
    p.add_argument("campaign", help="campaign id (or unique prefix)")
    p.add_argument("--json", action="store_true",
                   help="machine-readable output")
    p.set_defaults(fn=cmd_status)

    p = sub.add_parser("watch", help="poll the store, print new alerts "
                                     "(or push them to a sink)")
    p.add_argument("campaign", help="campaign id (or unique prefix)")
    p.add_argument("--interval", type=float, default=2.0,
                   help="poll period (s)")
    p.add_argument("--rounds", type=int, default=0,
                   help="stop after N polls (0 = forever)")
    p.add_argument("--sink", default=None,
                   help="push alerts instead of polling: an http(s):// "
                        "webhook URL or a JSONL file path; each alert is "
                        "delivered once (delivery state rides with the "
                        "campaign), undeliverable alerts are "
                        "dead-lettered, and the command exits instead "
                        "of polling")
    p.add_argument("--sink-retries", type=int, default=4,
                   help="delivery attempts per alert before it is "
                        "dead-lettered")
    p.add_argument("--requeue", action="store_true",
                   help="write flagged drift alerts into the campaign's "
                        "requeue manifest; `campaign run "
                        "--requeue-from-alerts` re-measures those units")
    p.set_defaults(fn=cmd_watch)

    p = sub.add_parser("replay",
                       help="drive the monitor from recorded streams")
    p.add_argument("campaign", help="baseline campaign id (or prefix)")
    p.add_argument("traces", nargs="+",
                   help="trace directory path(s) or unit key(s) with a "
                        "stored campaign trace")
    p.add_argument("--device", default=None,
                   help="stream name (default: the trace's device_name)")
    p.add_argument("--unit", default=None,
                   help="baseline unit key (default: resolve from the "
                        "device name)")
    p.add_argument("--window", type=int, default=DriftConfig.window,
                   help="drift sliding-window capacity")
    p.add_argument("--cooldown", type=int, default=DriftConfig.cooldown,
                   help="samples suppressed after an alert")
    p.add_argument("--heartbeat-timeout", type=float, default=30.0,
                   help="stream-time silence before a stale-device alert")
    p.add_argument("--fail-on-alert", action="store_true",
                   help="exit 1 when any alert fires (CI gate)")
    p.add_argument("--json", action="store_true",
                   help="machine-readable status + alerts")
    p.add_argument("--metrics-out", default=None,
                   help="write a JSON metrics snapshot")
    p.add_argument("--prom-out", default=None,
                   help="write the Prometheus text exposition")
    p.set_defaults(fn=cmd_replay)
    return ap


def main(argv: list[str] | None = None) -> int:
    args = build_parser().parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main())
