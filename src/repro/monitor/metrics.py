"""Self-contained metrics registry for the fleet monitor: counters,
gauges and histograms with label support, rendered as a Prometheus-style
text exposition and as JSON snapshots.

No client library dependency: the monitor must run in the same minimal
environment as the measurement stack.  Rendering is deterministic (metric
and label series sorted), so two replays of the same stream produce
byte-identical expositions — the same contract the alert artifacts obey.

An optional stdlib exporter (:func:`start_http_server`) serves the text
format on ``/metrics`` and the snapshot on ``/metrics.json`` from a
daemon thread, for live deployments; offline replay never needs it.
"""
from __future__ import annotations

import json
import os
import threading


def _fmt_labels(labels: tuple) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in labels)
    return "{" + inner + "}"


def _label_key(labels: dict | None) -> tuple:
    return tuple(sorted((str(k), str(v))
                        for k, v in (labels or {}).items()))


class _Metric:
    def __init__(self, name: str, help_text: str, kind: str):
        self.name = name
        self.help = help_text
        self.kind = kind
        self.series: dict[tuple, float] = {}

    def _render_series(self) -> list[str]:
        return [f"{self.name}{_fmt_labels(k)} {v:.17g}"
                for k, v in sorted(self.series.items())]

    def render(self) -> list[str]:
        return [f"# HELP {self.name} {self.help}",
                f"# TYPE {self.name} {self.kind}"] + self._render_series()

    def snapshot(self):
        return {_fmt_labels(k) or "": v for k, v in sorted(self.series.items())}


class Counter(_Metric):
    """Monotone accumulator (events ingested, alerts raised...)."""

    def __init__(self, name: str, help_text: str):
        super().__init__(name, help_text, "counter")

    def inc(self, n: float = 1.0, **labels) -> None:
        k = _label_key(labels)
        self.series[k] = self.series.get(k, 0.0) + n

    def value(self, **labels) -> float:
        return self.series.get(_label_key(labels), 0.0)


class Gauge(_Metric):
    """Last-value metric (drift score, window size, ingest lag...)."""

    def __init__(self, name: str, help_text: str):
        super().__init__(name, help_text, "gauge")

    def set(self, v: float, **labels) -> None:
        self.series[_label_key(labels)] = float(v)

    def value(self, **labels) -> float:
        return self.series.get(_label_key(labels), 0.0)


class Histogram(_Metric):
    """Fixed-bucket cumulative histogram (per-pair latency estimates)."""

    def __init__(self, name: str, help_text: str, buckets: tuple):
        super().__init__(name, help_text, "histogram")
        self.buckets = tuple(sorted(float(b) for b in buckets))
        self._counts: dict[tuple, list[int]] = {}
        self._sums: dict[tuple, float] = {}

    def observe(self, v: float, **labels) -> None:
        k = _label_key(labels)
        counts = self._counts.setdefault(k, [0] * (len(self.buckets) + 1))
        self._sums[k] = self._sums.get(k, 0.0) + float(v)
        for i, b in enumerate(self.buckets):
            if v <= b:
                counts[i] += 1
                return
        counts[-1] += 1

    def _render_series(self) -> list[str]:
        out = []
        for k in sorted(self._counts):
            counts = self._counts[k]
            cum = 0
            for i, b in enumerate(self.buckets):
                cum += counts[i]
                lab = _fmt_labels(k + (("le", f"{b:g}"),))
                out.append(f"{self.name}_bucket{lab} {cum}")
            cum += counts[-1]
            out.append(f'{self.name}_bucket{_fmt_labels(k + (("le", "+Inf"),))}'
                       f" {cum}")
            out.append(f"{self.name}_sum{_fmt_labels(k)} "
                       f"{self._sums[k]:.17g}")
            out.append(f"{self.name}_count{_fmt_labels(k)} {cum}")
        return out

    def snapshot(self):
        return {_fmt_labels(k) or "": {
            "count": sum(c), "sum": self._sums[k],
            "buckets": dict(zip([f"{b:g}" for b in self.buckets] + ["+Inf"],
                                c))}
            for k, c in sorted(self._counts.items())}


class MetricsRegistry:
    """One monitor's metric namespace; iteration order is registration
    order, rendering is fully sorted within each metric."""

    def __init__(self):
        self._metrics: dict[str, _Metric] = {}

    def counter(self, name: str, help_text: str) -> Counter:
        return self._get(name, lambda: Counter(name, help_text))

    def gauge(self, name: str, help_text: str) -> Gauge:
        return self._get(name, lambda: Gauge(name, help_text))

    def histogram(self, name: str, help_text: str,
                  buckets: tuple) -> Histogram:
        return self._get(name, lambda: Histogram(name, help_text, buckets))

    def _get(self, name: str, make):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = make()
        return m

    def render_prometheus(self) -> str:
        lines: list[str] = []
        for name in self._metrics:
            lines.extend(self._metrics[name].render())
        return "\n".join(lines) + "\n"

    def snapshot(self) -> dict:
        return {name: m.snapshot() for name, m in self._metrics.items()}

    def write_snapshot(self, path: str) -> None:
        """Periodic JSON snapshot (atomic replace, sorted keys)."""
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self.snapshot(), f, indent=1, sort_keys=True)
        os.replace(tmp, path)


def start_http_server(registry: MetricsRegistry, port: int = 0,
                      host: str = "127.0.0.1"):
    """Serve ``/metrics`` (text) and ``/metrics.json`` from a daemon
    thread; returns the live ``HTTPServer`` (``server_port`` tells the
    caller which ephemeral port ``port=0`` landed on)."""
    from http.server import BaseHTTPRequestHandler, HTTPServer

    class _Handler(BaseHTTPRequestHandler):
        def do_GET(self):  # noqa: N802 — stdlib handler interface
            if self.path.startswith("/metrics.json"):
                body = json.dumps(registry.snapshot(), indent=1,
                                  sort_keys=True).encode()
                ctype = "application/json"
            elif self.path.startswith("/metrics"):
                body = registry.render_prometheus().encode()
                ctype = "text/plain; version=0.0.4"
            else:
                self.send_error(404)
                return
            self.send_response(200)
            self.send_header("Content-Type", ctype)
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, *a):      # keep the monitor's stdout clean
            pass

    server = HTTPServer((host, port), _Handler)
    threading.Thread(target=server.serve_forever, daemon=True).start()
    return server
