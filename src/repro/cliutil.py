"""Small helpers shared by the command-line entry points
(`repro.campaign`, `repro.trace`)."""
from __future__ import annotations


def emit(text: str, out: str | None) -> None:
    """Print ``text``, or write it to ``out`` and say so."""
    if out:
        with open(out, "w") as f:
            f.write(text + "\n")
        print(f"wrote {out}")
    else:
        print(text)
