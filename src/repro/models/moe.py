"""Fine-grained Mixture-of-Experts (DeepSeekMoE-style: shared + routed top-k).

Routing (router matmul, softmax, top-k, aux loss) runs in plain pjit with
global semantics.  Dispatch + expert compute + combine run under shard_map
over ("data","model"): tokens are sharded over the data axes, experts over
"model".  The residual stream is replicated over "model" at entry, so every
model shard sees its data shard's full token set — dispatch is a purely
local sort/gather into per-expert capacity buffers (C = ceil(k*T_loc*cf/E)),
followed by grouped einsums over the shard's E/TP local experts, a local
combine-scatter, and ONE psum over "model" (the same output all-reduce a
tensor-parallel MLP needs).  No token all-to-all, no redundant compute along
the data axis — the pjit-global formulation would replicate the capacity
dimension per data shard (16x waste; see EXPERIMENTS.md #Perf).

Dispatch index math is memory traffic, not matmul FLOPs, keeping HLO_FLOPs
~= active-param FLOPs.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers


def moe_init(key, cfg):
    m = cfg.moe
    d, f, e = cfg.d_model, m.d_expert, m.n_routed
    dt = cfg.param_dtype
    ks = layers.split(key, 5)
    params, axes = {}, {}
    # experts take the "model" axis (EP); within-expert dims use FSDP ("embed")
    # only — mapping ff to "model" too would double-book the mesh axis.
    params["router"], axes["router"] = layers.dense_init(
        ks[0], (d, e), ("embed", "experts"), jnp.float32, scale=0.02)
    params["wg"], axes["wg"] = layers.dense_init(ks[1], (e, d, f), ("experts", "embed", None), dt)
    params["wu"], axes["wu"] = layers.dense_init(ks[2], (e, d, f), ("experts", "embed", None), dt)
    params["wd"], axes["wd"] = layers.dense_init(ks[3], (e, f, d), ("experts", None, "embed"), dt)
    if m.n_shared:
        sp, sa = layers.mlp_init(ks[4], cfg, d_ff=m.d_expert * m.n_shared)
        params["shared"], axes["shared"] = sp, sa
    return params, axes


def _capacity(m, n_tokens):
    return max(1, int(math.ceil(m.top_k * n_tokens * m.capacity_factor
                                / m.n_routed)))


def _dispatch_compute_combine(xt, gate, ids, wg, wu, wd, *, e0, n_experts,
                              capacity, compute_dtype):
    """Local-shard MoE core.  xt: (T,D); gate/ids: (T,k); expert weights are
    this shard's slice (E_loc, D, F).  e0 = first global expert id owned.
    Returns (T,D) partial output (zero rows for tokens routed elsewhere)."""
    t, d = xt.shape
    k = ids.shape[1]
    c = capacity
    cd = compute_dtype

    flat_e = ids.reshape(-1)                              # (t*k,) global ids
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    first = jnp.searchsorted(sorted_e, sorted_e, side="left")
    rank = jnp.arange(t * k) - first                      # slot within expert
    local_e = sorted_e - e0
    keep = (rank < c) & (local_e >= 0) & (local_e < n_experts)
    dest = jnp.where(keep, local_e * c + rank, n_experts * c)
    slot_src = jnp.full((n_experts * c + 1,), t * k, jnp.int32).at[dest].set(
        order.astype(jnp.int32))[: n_experts * c]
    src_token = jnp.where(slot_src < t * k, slot_src // k, t)

    xpad = jnp.concatenate([xt, jnp.zeros((1, d), xt.dtype)], axis=0)
    buf = jnp.take(xpad, src_token, axis=0).reshape(n_experts, c, d)

    g = jnp.einsum("ecd,edf->ecf", buf, wg.astype(cd))
    u = jnp.einsum("ecd,edf->ecf", buf, wu.astype(cd))
    h = jax.nn.silu(g) * u
    yb = jnp.einsum("ecf,efd->ecd", h, wd.astype(cd))     # (E_loc,C,D)

    flat_gate = gate.reshape(-1)[order]
    slot_gate = jnp.zeros((n_experts * c + 1,), jnp.float32).at[dest].set(
        jnp.where(keep, flat_gate, 0.0))[: n_experts * c]
    yw = yb.reshape(n_experts * c, d).astype(jnp.float32) * slot_gate[:, None]
    out = jnp.zeros((t + 1, d), jnp.float32).at[src_token].add(yw)[:t]
    return out.astype(cd)


def moe_apply(p, x, cfg, env):
    """x: (B,S,D) -> (B,S,D).  Aux loss returned separately."""
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    e, k = m.n_routed, m.top_k
    cd = cfg.compute_dtype
    xt = x.reshape(t, d)

    # ---- routing (fp32, global semantics) -------------------------------- #
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate, ids = jax.lax.top_k(probs, k)                   # (t,k)
    if m.norm_topk:
        gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch-style)
    me = probs.mean(axis=0)
    load = jnp.zeros((e,), jnp.float32).at[ids.reshape(-1)].add(1.0) / (t * k)
    aux = e * jnp.sum(me * load)

    # ---- expert compute --------------------------------------------------- #
    tp = env.tp
    if env.mesh is None or tp == 1 or (e % max(tp, 1) != 0):
        out = _dispatch_compute_combine(
            xt, gate, ids, p["wg"], p["wu"], p["wd"], e0=0, n_experts=e,
            capacity=_capacity(m, t), compute_dtype=cd)
        if env.mesh is not None and tp > 1:
            out = env.constrain(out.reshape(b, s, d), ("batch", None, None))
            out = out.reshape(t, d)
    else:
        dp_total = env.dp
        t_loc = t // dp_total if t % dp_total == 0 else t
        cap = _capacity(m, t_loc)
        e_loc = e // tp
        axis = env.model_axis
        dspec = env.data_axes if len(env.data_axes) > 1 else env.data_axes[0]
        tok_spec = P(dspec) if t % dp_total == 0 else P()

        def body(xt, gate, ids, wg, wu, wd):
            j = jax.lax.axis_index(axis)
            out = _dispatch_compute_combine(
                xt, gate, ids, wg, wu, wd, e0=j * e_loc, n_experts=e_loc,
                capacity=cap, compute_dtype=cd)
            return jax.lax.psum(out, axis)

        from repro.parallel.sharding import shard_map
        out = shard_map(
            body, mesh=env.mesh,
            in_specs=(P(*tok_spec, None), P(*tok_spec, None), P(*tok_spec, None),
                      P(axis, None, None), P(axis, None, None),
                      P(axis, None, None)),
            out_specs=P(*tok_spec, None),
            check_vma=False,
        )(xt, gate, ids, p["wg"], p["wu"], p["wd"])

    out = out.reshape(b, s, d)
    if m.n_shared:
        out = out + layers.mlp_apply(p["shared"], x, cfg)
    return out, aux
