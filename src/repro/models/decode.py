"""Prefill + single-token decode with per-family KV/state caches.

Cache layouts (M = max_len, L = n_layers):
  dense/vlm : k,v (L,B,M,KV,dh)
              - kv_heads | TP  -> cache sharded on heads over "model"
              - else           -> flash-decoding: cache sharded on *seq* over
                                  "model", LSE-combined shard_map attention
  moe       : dense cache + separate block0 entries
  mla_moe   : compressed latent cache (B,M,kv_lora[+rope]) — replicated over
              "model" (shared by all heads), sharded over batch
  ssm       : conv (L,B,W-1,C) + state h (L,B,H,P,N), O(1) per token
  hybrid    : 3 global layers with full KV + per-layer SSM states; window
              layers use a ring buffer of size `window` + always-visible meta
              K/V — decode memory is O(window), enabling long_500k.

``pos`` is the number of *text* tokens already consumed; the new token sits
at text index ``pos`` (hybrid adds the n_meta offset internally).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers, lm, mla as mla_mod, moe as moe_mod, ssm as ssm_mod


# --------------------------------------------------------------------------- #
# cache specification
# --------------------------------------------------------------------------- #
def _kv_axes(env_flash):
    if env_flash:
        return (None, "batch", "seq_kv", None, None)
    return (None, "batch", None, "kv_heads", None)


def cache_spec(cfg, batch, max_len, env=None):
    """Returns (tree of jax.ShapeDtypeStruct, tree of logical-axes tuples)."""
    fam = cfg.family
    cd = cfg.compute_dtype
    flash = bool(env is not None and env.flash_decode)
    shapes, axes = {}, {}

    def add(name, shape, ax, dtype=cd):
        shapes[name] = jax.ShapeDtypeStruct(shape, dtype)
        axes[name] = ax

    if fam in ("dense", "vlm"):
        kv = (cfg.n_layers, batch, max_len, cfg.n_kv, cfg.head_dim)
        add("k", kv, _kv_axes(flash)); add("v", kv, _kv_axes(flash))
    elif fam == "moe":
        kv = (cfg.n_layers - 1, batch, max_len, cfg.n_kv, cfg.head_dim)
        kv0 = (batch, max_len, cfg.n_kv, cfg.head_dim)
        add("k", kv, _kv_axes(flash)); add("v", kv, _kv_axes(flash))
        add("k0", kv0, _kv_axes(flash)[1:]); add("v0", kv0, _kv_axes(flash)[1:])
    elif fam == "mla_moe":
        a = cfg.mla
        add("c_lat", (cfg.n_layers - 1, batch, max_len, a.kv_lora),
            (None, "batch", None, None))
        add("k_rope", (cfg.n_layers - 1, batch, max_len, a.dh_rope),
            (None, "batch", None, None))
        add("c0", (batch, max_len, a.kv_lora), ("batch", None, None))
        add("r0", (batch, max_len, a.dh_rope), ("batch", None, None))
    elif fam == "ssm":
        st = ssm_mod.ssm_state_shape(cfg, batch)
        for nm, (shp, ax) in st.items():
            add(nm, (cfg.n_layers, *shp), (None, *ax),
                dtype=jnp.float32 if nm == "h" else cd)
    elif fam == "hybrid":
        hy = cfg.hybrid
        st = ssm_mod.ssm_state_shape(cfg, batch)
        kvg = (batch, max_len + hy.n_meta, cfg.n_kv, cfg.head_dim)
        for i in range(3):
            add(f"gk{i}", kvg, ("batch", None, None, None))
            add(f"gv{i}", kvg, ("batch", None, None, None))
            for nm, (shp, ax) in st.items():
                add(f"g{nm}{i}", shp, ax,
                    dtype=jnp.float32 if nm == "h" else cd)
        for seg, n in (("wa", lm._hybrid_seg_sizes(cfg)[0]),
                       ("wb", lm._hybrid_seg_sizes(cfg)[1])):
            ring = (n, batch, hy.window, cfg.n_kv, cfg.head_dim)
            meta = (n, batch, hy.n_meta, cfg.n_kv, cfg.head_dim)
            add(f"{seg}_k", ring, (None, "batch", None, None, None))
            add(f"{seg}_v", ring, (None, "batch", None, None, None))
            add(f"{seg}_mk", meta, (None, "batch", None, None, None))
            add(f"{seg}_mv", meta, (None, "batch", None, None, None))
            for nm, (shp, ax) in st.items():
                add(f"{seg}_{nm}", (n, *shp), (None, *ax),
                    dtype=jnp.float32 if nm == "h" else cd)
    else:
        raise ValueError(fam)
    return shapes, axes


def init_cache(cfg, batch, max_len, env=None):
    shapes, axes = cache_spec(cfg, batch, max_len, env)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shapes), axes


# --------------------------------------------------------------------------- #
# attention block: prefill (returns padded per-layer KV) + decode
# --------------------------------------------------------------------------- #
def _attn_prefill(p, x, cfg, env, positions, use_moe, max_len):
    h = layers.rms_norm(x, p["ln1"])
    q, k, v = layers.qkv_project(p["attn"], h, cfg, positions, env)
    att = layers.prefill_attention(q, k, v, kv_chunk=cfg.attn_kv_chunk)
    att = layers.attn_output(p["attn"], att, cfg)
    x = x + att
    h2 = layers.rms_norm(x, p["ln2"])
    if use_moe:
        f, _ = moe_mod.moe_apply(p["ffn"], h2, cfg, env)
    else:
        f = layers.mlp_apply(p["ffn"], h2, cfg)
    x = env.constrain(x + f, ("batch", "seq", None))
    pad = max_len - k.shape[1]
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return x, (kp, vp)


def _attn_decode(p, x, kc, vc, pos, cfg, env, use_moe):
    b = x.shape[0]
    h = layers.rms_norm(x, p["ln1"])
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k, v = layers.qkv_project(p["attn"], h, cfg, positions, env)
    if env.flash_decode and env.mesh is not None:
        att, kc, vc = layers.flash_decode_shardmap(q, kc, vc, k, v, pos, env)
    else:
        kc = jax.lax.dynamic_update_slice(kc, k, (0, pos, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v, (0, pos, 0, 0))
        att = layers.decode_attention(q, kc, vc, pos + 1)
    att = layers.attn_output(p["attn"], att, cfg)
    x = x + att
    h2 = layers.rms_norm(x, p["ln2"])
    if use_moe:
        f, _ = moe_mod.moe_apply(p["ffn"], h2, cfg, env)
    else:
        f = layers.mlp_apply(p["ffn"], h2, cfg)
    return x + f, kc, vc


def _mla_prefill(p, x, cfg, env, positions, use_moe, max_len):
    h = layers.rms_norm(x, p["ln1"])
    att, (c_lat, k_rope) = mla_mod.mla_forward(p["attn"], h, cfg, env, positions)
    x = x + att
    h2 = layers.rms_norm(x, p["ln2"])
    if use_moe:
        f, _ = moe_mod.moe_apply(p["ffn"], h2, cfg, env)
    else:
        f = layers.mlp_apply(p["ffn"], h2, cfg)
    x = env.constrain(x + f, ("batch", "seq", None))
    pad = max_len - c_lat.shape[1]
    cp = jnp.pad(c_lat, ((0, 0), (0, pad), (0, 0)))
    rp = jnp.pad(k_rope, ((0, 0), (0, pad), (0, 0)))
    return x, (cp, rp)


def _mla_decode(p, x, c_lat, k_rope, pos, cfg, env, use_moe):
    h = layers.rms_norm(x, p["ln1"])
    att, new = mla_mod.mla_decode(p["attn"], h, {"c_lat": c_lat, "k_rope": k_rope},
                                  pos, cfg, env)
    x = x + att
    h2 = layers.rms_norm(x, p["ln2"])
    if use_moe:
        f, _ = moe_mod.moe_apply(p["ffn"], h2, cfg, env)
    else:
        f = layers.mlp_apply(p["ffn"], h2, cfg)
    return x + f, new["c_lat"], new["k_rope"]


# --------------------------------------------------------------------------- #
# prefill
# --------------------------------------------------------------------------- #
def prefill(params, batch, cfg, env, max_len):
    """Run the full context; returns (last-token logits (B,V), cache)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = layers.embed_lookup(params["embed"], tokens, cfg)
    if cfg.family == "vlm":
        img = batch["img_embeds"].astype(cfg.compute_dtype)
        x = jnp.concatenate([img, x[:, img.shape[1]:]], axis=1)
    x = env.constrain(x, ("batch", "seq", None))
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    fam = cfg.family
    cache = {}

    if fam in ("dense", "vlm"):
        def body(h, p):
            h, (kp, vp) = _attn_prefill(p, h, cfg, env, positions, False, max_len)
            return h, (kp, vp)
        x, (ks, vs) = jax.lax.scan(body, x, params["blocks"])
        cache["k"], cache["v"] = ks, vs
    elif fam == "moe":
        x, (k0, v0) = _attn_prefill(params["block0"], x, cfg, env, positions,
                                    False, max_len)
        cache["k0"], cache["v0"] = k0, v0
        def body(h, p):
            h, kv = _attn_prefill(p, h, cfg, env, positions, True, max_len)
            return h, kv
        x, (ks, vs) = jax.lax.scan(body, x, params["blocks"])
        cache["k"], cache["v"] = ks, vs
    elif fam == "mla_moe":
        x, (c0, r0) = _mla_prefill(params["block0"], x, cfg, env, positions,
                                   False, max_len)
        cache["c0"], cache["r0"] = c0, r0
        def body(h, p):
            h, cr = _mla_prefill(p, h, cfg, env, positions, True, max_len)
            return h, cr
        x, (cs, rs) = jax.lax.scan(body, x, params["blocks"])
        cache["c_lat"], cache["k_rope"] = cs, rs
    elif fam == "ssm":
        def body(h, p):
            hh = layers.rms_norm(h, p["ln"])
            y, (conv, hstate) = ssm_mod.ssm_forward(p["mix"], hh, cfg, env)
            return h + y, (conv["x"], conv["B"], conv["C"], hstate)
        x, (cx, cb, cc, hs) = jax.lax.scan(body, x, params["blocks"])
        cache["conv_x"], cache["conv_B"], cache["conv_C"], cache["h"] = \
            cx, cb, cc, hs
    elif fam == "hybrid":
        x, cache = _hybrid_prefill(params, x, cfg, env, s, max_len)
    else:
        raise ValueError(fam)

    x = layers.rms_norm(x[:, -1:], params["ln_f"])
    logits = layers.unembed(params["embed"], x, cfg)[:, 0]
    return logits, cache


def _hybrid_block_prefill(p, x, cfg, env, positions, window):
    """Returns new x plus (k, v, conv, h) for cache assembly."""
    hy = cfg.hybrid
    h = layers.rms_norm(x, p["ln1"])
    q, k, v = layers.qkv_project(p["attn"], h, cfg, positions, env)
    if window is None:
        att = layers.chunked_attention(q, k, v, causal=True,
                                       kv_chunk=cfg.attn_kv_chunk)
    else:
        nm = hy.n_meta
        att_meta = layers.naive_attention(q[:, :nm], k[:, :nm], v[:, :nm],
                                          causal=True)
        att_seq = layers.windowed_attention(
            q[:, nm:], k[:, nm:], v[:, nm:], window=window,
            q_chunk=cfg.attn_q_chunk, q_pos0=nm,
            prefix_kv=(k[:, :nm], v[:, :nm]))
        att = jnp.concatenate([att_meta, att_seq], axis=1)
    att = layers.attn_output(p["attn"], att, cfg)
    sso, (conv, hstate) = ssm_mod.ssm_forward(p["mix"], h, cfg, env)
    bta = p["beta"]
    y = (0.5 * (bta[0] * layers.rms_norm(att, p["na"])
                + bta[1] * layers.rms_norm(sso, p["ns"]))).astype(cfg.compute_dtype)
    x = x + y
    h2 = layers.rms_norm(x, p["ln2"])
    x = env.constrain(x + layers.mlp_apply(p["ffn"], h2, cfg),
                      ("batch", "seq", None))
    return x, k, v, conv, hstate


def _hybrid_prefill(params, x, cfg, env, s, max_len):
    hy = cfg.hybrid
    b = x.shape[0]
    nm = hy.n_meta
    meta = jnp.broadcast_to(params["meta"].astype(cfg.compute_dtype)[None],
                            (b, nm, cfg.d_model))
    x = jnp.concatenate([meta, x], axis=1)
    sm = s + nm
    positions = jnp.broadcast_to(jnp.arange(sm, dtype=jnp.int32)[None], (b, sm))
    cache = {}
    w = hy.window
    assert s % w == 0, "prefill length must be a multiple of the window"

    def ring_of(k):  # last `window` seq tokens; s % w == 0 keeps slots aligned
        return k[:, -w:]

    gi = 0
    def run_global(x):
        nonlocal gi
        p = params[f"global{gi}"]
        x, k, v, conv, hs = _hybrid_block_prefill(p, x, cfg, env, positions, None)
        pad = (max_len + nm) - k.shape[1]
        cache[f"gk{gi}"] = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cache[f"gv{gi}"] = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cache[f"gconv_x{gi}"], cache[f"gconv_B{gi}"], cache[f"gconv_C{gi}"] = \
            conv["x"], conv["B"], conv["C"]
        cache[f"gh{gi}"] = hs
        gi += 1
        return x

    def run_window_seg(x, seg, pstack):
        def body(h, p):
            h, k, v, conv, hs = _hybrid_block_prefill(p, h, cfg, env, positions,
                                                      w)
            return h, (ring_of(k), ring_of(v), k[:, :nm], v[:, :nm],
                       conv["x"], conv["B"], conv["C"], hs)
        x, (rk, rv, mk, mv, cx, cb, cc, hs) = jax.lax.scan(body, x, pstack)
        cache[f"{seg}_k"], cache[f"{seg}_v"] = rk, rv
        cache[f"{seg}_mk"], cache[f"{seg}_mv"] = mk, mv
        cache[f"{seg}_conv_x"], cache[f"{seg}_conv_B"] = cx, cb
        cache[f"{seg}_conv_C"], cache[f"{seg}_h"] = cc, hs
        return x

    x = run_global(x)
    x = run_window_seg(x, "wa", params["win_a"])
    x = run_global(x)
    x = run_window_seg(x, "wb", params["win_b"])
    x = run_global(x)
    return x, cache


# --------------------------------------------------------------------------- #
# decode
# --------------------------------------------------------------------------- #
def decode_step(params, cache, token, pos, cfg, env):
    """token: (B,1) int32; pos: () int32.  Returns (logits (B,V), cache)."""
    fam = cfg.family
    x = layers.embed_lookup(params["embed"], token, cfg)
    x = env.constrain(x, ("batch", "seq", None))
    cache = dict(cache)

    if fam in ("dense", "vlm"):
        def body(h, inp):
            p, kc, vc = inp
            h, kc, vc = _attn_decode(p, h, kc, vc, pos, cfg, env, False)
            return h, (kc, vc)
        x, (ks, vs) = jax.lax.scan(body, x, (params["blocks"], cache["k"], cache["v"]))
        cache["k"], cache["v"] = ks, vs
    elif fam == "moe":
        x, cache["k0"], cache["v0"] = _attn_decode(
            params["block0"], x, cache["k0"], cache["v0"], pos, cfg, env, False)
        def body(h, inp):
            p, kc, vc = inp
            h, kc, vc = _attn_decode(p, h, kc, vc, pos, cfg, env, True)
            return h, (kc, vc)
        x, (ks, vs) = jax.lax.scan(body, x, (params["blocks"], cache["k"], cache["v"]))
        cache["k"], cache["v"] = ks, vs
    elif fam == "mla_moe":
        x, cache["c0"], cache["r0"] = _mla_decode(
            params["block0"], x, cache["c0"], cache["r0"], pos, cfg, env, False)
        def body(h, inp):
            p, cc, rr = inp
            h, cc, rr = _mla_decode(p, h, cc, rr, pos, cfg, env, True)
            return h, (cc, rr)
        x, (cs, rs) = jax.lax.scan(
            body, x, (params["blocks"], cache["c_lat"], cache["k_rope"]))
        cache["c_lat"], cache["k_rope"] = cs, rs
    elif fam == "ssm":
        def body(h, inp):
            p, cx, cb, cc, hs = inp
            hh = layers.rms_norm(h, p["ln"])
            y, (conv, hs) = ssm_mod.ssm_decode(
                p["mix"], hh, ({"x": cx, "B": cb, "C": cc}, hs), cfg, env)
            return h + y, (conv["x"], conv["B"], conv["C"], hs)
        x, (cx, cb, cc, hs) = jax.lax.scan(
            body, x, (params["blocks"], cache["conv_x"], cache["conv_B"],
                      cache["conv_C"], cache["h"]))
        cache["conv_x"], cache["conv_B"], cache["conv_C"], cache["h"] = \
            cx, cb, cc, hs
    elif fam == "hybrid":
        x, cache = _hybrid_decode(params, cache, x, pos, cfg, env)
    else:
        raise ValueError(fam)

    x = layers.rms_norm(x, params["ln_f"])
    logits = layers.unembed(params["embed"], x, cfg)[:, 0]
    return logits, cache


def _hybrid_global_decode(p, x, kc, vc, conv, hs, pos, cfg, env):
    hy = cfg.hybrid
    b = x.shape[0]
    h = layers.rms_norm(x, p["ln1"])
    apos = pos + hy.n_meta
    positions = jnp.full((b, 1), apos, jnp.int32)
    q, k, v = layers.qkv_project(p["attn"], h, cfg, positions, env)
    kc = jax.lax.dynamic_update_slice(kc, k, (0, apos, 0, 0))
    vc = jax.lax.dynamic_update_slice(vc, v, (0, apos, 0, 0))
    att = layers.decode_attention(q, kc, vc, apos + 1)
    att = layers.attn_output(p["attn"], att, cfg)
    sso, (conv, hs) = ssm_mod.ssm_decode(p["mix"], h, (conv, hs), cfg, env)
    bta = p["beta"]
    y = (0.5 * (bta[0] * layers.rms_norm(att, p["na"])
                + bta[1] * layers.rms_norm(sso, p["ns"]))).astype(cfg.compute_dtype)
    x = x + y
    h2 = layers.rms_norm(x, p["ln2"])
    return x + layers.mlp_apply(p["ffn"], h2, cfg), kc, vc, conv, hs


def _hybrid_window_decode(p, x, rk, rv, mk, mv, conv, hs, pos, cfg, env):
    hy = cfg.hybrid
    b = x.shape[0]
    w = hy.window
    h = layers.rms_norm(x, p["ln1"])
    apos = pos + hy.n_meta
    positions = jnp.full((b, 1), apos, jnp.int32)
    q, k, v = layers.qkv_project(p["attn"], h, cfg, positions, env)
    slot = jnp.mod(pos, w)
    rk = jax.lax.dynamic_update_slice(rk, k, (0, slot, 0, 0))
    rv = jax.lax.dynamic_update_slice(rv, v, (0, slot, 0, 0))
    # attend [meta | ring]; unfilled ring slots masked via cur_len trick:
    kall = jnp.concatenate([mk, rk], axis=1)
    vall = jnp.concatenate([mv, rv], axis=1)
    nvalid = hy.n_meta + jnp.minimum(pos + 1, w)
    # ring slots are stored unordered in time but all lie within the window,
    # so plain masked softmax over filled slots is exact.
    att = layers.decode_attention(q, kall, vall, nvalid)
    att = layers.attn_output(p["attn"], att, cfg)
    sso, (conv, hs) = ssm_mod.ssm_decode(p["mix"], h, (conv, hs), cfg, env)
    bta = p["beta"]
    y = (0.5 * (bta[0] * layers.rms_norm(att, p["na"])
                + bta[1] * layers.rms_norm(sso, p["ns"]))).astype(cfg.compute_dtype)
    x = x + y
    h2 = layers.rms_norm(x, p["ln2"])
    return x + layers.mlp_apply(p["ffn"], h2, cfg), rk, rv, conv, hs


def _hybrid_decode(params, cache, x, pos, cfg, env):
    cache = dict(cache)
    gi = 0
    def g(x):
        nonlocal gi
        p = params[f"global{gi}"]
        conv = {"x": cache[f"gconv_x{gi}"], "B": cache[f"gconv_B{gi}"],
                "C": cache[f"gconv_C{gi}"]}
        x, kc, vc, conv, hs = _hybrid_global_decode(
            p, x, cache[f"gk{gi}"], cache[f"gv{gi}"], conv,
            cache[f"gh{gi}"], pos, cfg, env)
        cache[f"gk{gi}"], cache[f"gv{gi}"] = kc, vc
        cache[f"gconv_x{gi}"], cache[f"gconv_B{gi}"], cache[f"gconv_C{gi}"] = \
            conv["x"], conv["B"], conv["C"]
        cache[f"gh{gi}"] = hs
        gi += 1
        return x

    def seg(x, name, pstack):
        def body(h, inp):
            p, rk, rv, mk, mv, cx, cb, cc, hs = inp
            conv = {"x": cx, "B": cb, "C": cc}
            h, rk, rv, conv, hs = _hybrid_window_decode(
                p, h, rk, rv, mk, mv, conv, hs, pos, cfg, env)
            return h, (rk, rv, conv["x"], conv["B"], conv["C"], hs)
        x, (rk, rv, cx, cb, cc, hs) = jax.lax.scan(
            body, x, (pstack, cache[f"{name}_k"], cache[f"{name}_v"],
                      cache[f"{name}_mk"], cache[f"{name}_mv"],
                      cache[f"{name}_conv_x"], cache[f"{name}_conv_B"],
                      cache[f"{name}_conv_C"], cache[f"{name}_h"]))
        cache[f"{name}_k"], cache[f"{name}_v"] = rk, rv
        cache[f"{name}_conv_x"], cache[f"{name}_conv_B"] = cx, cb
        cache[f"{name}_conv_C"], cache[f"{name}_h"] = cc, hs
        return x

    x = g(x)
    x = seg(x, "wa", params["win_a"])
    x = g(x)
    x = seg(x, "wb", params["win_b"])
    x = g(x)
    return x, cache
