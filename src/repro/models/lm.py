"""Generic decoder-only LM covering the dense / MoE / MLA / SSM / hybrid /
VLM families, with scan-over-layers (stacked params), remat, and the
train / prefill / decode entry points the launcher lowers.

Layer topology per family (cfg.family):
  dense   : [attn+mlp] * L                              (llama3/nemotron/chatglm3/qwen3/pixtral)
  moe     : [attn+dense-mlp] + [attn+moe] * (L-1)       (deepseek-moe-16b)
  mla_moe : [mla+dense-mlp] + [mla+moe] * (L-1)         (deepseek-v2-236b)
  ssm     : [mamba2] * L                                (mamba2-130m)
  hybrid  : 3 global-attn layers {0, L/2, L-1} + sliding-window layers,
            each = (attn || ssm) + mlp, 128 meta tokens (hymba-1.5b)
  vlm     : dense with image-patch prefix embeddings    (pixtral-12b)
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models import layers, mla as mla_mod, moe as moe_mod, ssm as ssm_mod


# --------------------------------------------------------------------------- #
# per-layer blocks
# --------------------------------------------------------------------------- #
def _attn_block_init(key, cfg, use_moe):
    ks = layers.split(key, 2)
    p, a = {}, {}
    if cfg.family == "mla_moe":
        p["attn"], a["attn"] = mla_mod.mla_init(ks[0], cfg)
    else:
        p["attn"], a["attn"] = layers.attention_init(ks[0], cfg)
    if use_moe:
        p["ffn"], a["ffn"] = moe_mod.moe_init(ks[1], cfg)
    else:
        p["ffn"], a["ffn"] = layers.mlp_init(ks[1], cfg)
    p["ln1"] = jnp.ones((cfg.d_model,), cfg.param_dtype); a["ln1"] = (None,)
    p["ln2"] = jnp.ones((cfg.d_model,), cfg.param_dtype); a["ln2"] = (None,)
    return p, a


def _attn_block_apply(p, x, cfg, env, positions, use_moe):
    h = layers.rms_norm(x, p["ln1"])
    if cfg.family == "mla_moe":
        att, _ = mla_mod.mla_forward(p["attn"], h, cfg, env, positions)
    else:
        q, k, v = layers.qkv_project(p["attn"], h, cfg, positions, env)
        att = layers.chunked_attention(q, k, v, causal=True,
                                       kv_chunk=cfg.attn_kv_chunk)
        att = layers.attn_output(p["attn"], att, cfg)
    x = x + att
    h = layers.rms_norm(x, p["ln2"])
    if use_moe:
        f, aux = moe_mod.moe_apply(p["ffn"], h, cfg, env)
    else:
        f, aux = layers.mlp_apply(p["ffn"], h, cfg), jnp.float32(0)
    x = env.constrain(x + f, ("batch", "seq", None))
    return x, aux


def _ssm_block_init(key, cfg):
    p, a = {}, {}
    p["mix"], a["mix"] = ssm_mod.ssm_init(key, cfg)
    p["ln"] = jnp.ones((cfg.d_model,), cfg.param_dtype); a["ln"] = (None,)
    return p, a


def _ssm_block_apply(p, x, cfg, env):
    h = layers.rms_norm(x, p["ln"])
    y, _ = ssm_mod.ssm_forward(p["mix"], h, cfg, env)
    return env.constrain(x + y, ("batch", "seq", None)), jnp.float32(0)


def _hybrid_block_init(key, cfg):
    ks = layers.split(key, 3)
    p, a = {}, {}
    p["attn"], a["attn"] = layers.attention_init(ks[0], cfg)
    p["mix"], a["mix"] = ssm_mod.ssm_init(ks[1], cfg)
    p["ffn"], a["ffn"] = layers.mlp_init(ks[2], cfg)
    p["ln1"] = jnp.ones((cfg.d_model,), cfg.param_dtype); a["ln1"] = (None,)
    p["ln2"] = jnp.ones((cfg.d_model,), cfg.param_dtype); a["ln2"] = (None,)
    p["na"] = jnp.ones((cfg.d_model,), cfg.param_dtype); a["na"] = (None,)
    p["ns"] = jnp.ones((cfg.d_model,), cfg.param_dtype); a["ns"] = (None,)
    p["beta"] = jnp.ones((2,), jnp.float32); a["beta"] = (None,)
    return p, a


def _hybrid_block_apply(p, x, cfg, env, positions, *, window):
    """Hymba: parallel attention + SSM heads, outputs normed and averaged.

    For window layers the 128 meta tokens (sequence prefix) stay globally
    visible: meta queries run causal attention among themselves, sequence
    queries run sliding-window attention with the meta K/V as an
    always-visible prefix.
    """
    h = layers.rms_norm(x, p["ln1"])
    q, k, v = layers.qkv_project(p["attn"], h, cfg, positions, env)
    if window is None:
        att = layers.chunked_attention(q, k, v, causal=True,
                                       kv_chunk=cfg.attn_kv_chunk)
    else:
        nm = cfg.hybrid.n_meta
        att_meta = layers.naive_attention(q[:, :nm], k[:, :nm], v[:, :nm],
                                          causal=True)
        att_seq = layers.windowed_attention(
            q[:, nm:], k[:, nm:], v[:, nm:], window=window,
            q_chunk=cfg.attn_q_chunk, q_pos0=nm,
            prefix_kv=(k[:, :nm], v[:, :nm]))
        att = jnp.concatenate([att_meta, att_seq], axis=1)
    att = layers.attn_output(p["attn"], att, cfg)
    sso, _ = ssm_mod.ssm_forward(p["mix"], h, cfg, env)
    b = p["beta"]
    y = (0.5 * (b[0] * layers.rms_norm(att, p["na"])
                + b[1] * layers.rms_norm(sso, p["ns"]))).astype(cfg.compute_dtype)
    x = x + y
    h2 = layers.rms_norm(x, p["ln2"])
    x = env.constrain(x + layers.mlp_apply(p["ffn"], h2, cfg),
                      ("batch", "seq", None))
    return x, jnp.float32(0)


# --------------------------------------------------------------------------- #
# model init
# --------------------------------------------------------------------------- #
def _stacked_init(key, n, init_fn):
    """Init n layers and stack every leaf along axis 0 (for lax.scan)."""
    keys = jax.random.split(key, n)
    p0, a0 = init_fn(keys[0])
    if n == 1:
        return jax.tree.map(lambda x: x[None], p0), _stack_axes(a0)
    ps = [p0] + [init_fn(k)[0] for k in keys[1:]]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs, 0), *ps)
    return stacked, _stack_axes(a0)


def _stack_axes(axes_tree):
    return jax.tree.map(
        lambda t: (None, *t),
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x))


def init(key, cfg):
    ks = layers.split(key, 6)
    params, axes = {}, {}
    params["embed"], axes["embed"] = layers.embed_init(ks[0], cfg)
    params["ln_f"] = jnp.ones((cfg.d_model,), cfg.param_dtype); axes["ln_f"] = (None,)
    fam = cfg.family
    if fam in ("dense", "vlm"):
        params["blocks"], axes["blocks"] = _stacked_init(
            ks[1], cfg.n_layers, lambda k: _attn_block_init(k, cfg, False))
    elif fam in ("moe", "mla_moe"):
        params["block0"], axes["block0"] = _attn_block_init(ks[1], cfg, False)
        params["blocks"], axes["blocks"] = _stacked_init(
            ks[2], cfg.n_layers - 1, lambda k: _attn_block_init(k, cfg, True))
    elif fam == "ssm":
        params["blocks"], axes["blocks"] = _stacked_init(
            ks[1], cfg.n_layers, lambda k: _ssm_block_init(k, cfg))
    elif fam == "hybrid":
        hy = cfg.hybrid
        params["meta"] = (jax.random.normal(ks[3], (hy.n_meta, cfg.d_model))
                          * 0.02).astype(cfg.param_dtype)
        axes["meta"] = (None, "embed")
        g = _global_layer_ids(cfg)
        gkeys = layers.split(ks[1], len(g))
        for i, gid in enumerate(g):
            params[f"global{i}"], axes[f"global{i}"] = _hybrid_block_init(gkeys[i], cfg)
        seg_a, seg_b = _hybrid_seg_sizes(cfg)
        params["win_a"], axes["win_a"] = _stacked_init(
            ks[2], seg_a, lambda k: _hybrid_block_init(k, cfg))
        params["win_b"], axes["win_b"] = _stacked_init(
            ks[4], seg_b, lambda k: _hybrid_block_init(k, cfg))
    else:
        raise ValueError(fam)
    return params, axes


def _global_layer_ids(cfg):
    return (0, cfg.n_layers // 2, cfg.n_layers - 1)


def _hybrid_seg_sizes(cfg):
    g = _global_layer_ids(cfg)
    seg_a = g[1] - g[0] - 1
    seg_b = cfg.n_layers - 3 - seg_a
    return seg_a, seg_b


# --------------------------------------------------------------------------- #
# forward
# --------------------------------------------------------------------------- #
def _scan_blocks(params_stacked, x, body, env):
    def f(carry, p_slice):
        h, aux = carry
        y, a = body(p_slice, h)
        return (y, aux + a), None

    fn = jax.checkpoint(f) if env.remat else f
    (x, aux), _ = jax.lax.scan(fn, (x, jnp.float32(0)), params_stacked)
    return x, aux


def forward(params, batch, cfg, env):
    """batch: dict(tokens=(B,S) int32 [, img_embeds=(B,P,D)]).

    Returns (logits, aux_loss)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = layers.embed_lookup(params["embed"], tokens, cfg)
    if cfg.family == "vlm":
        img = batch["img_embeds"].astype(cfg.compute_dtype)
        np_ = img.shape[1]
        x = jnp.concatenate([img, x[:, np_:]], axis=1)
    x = env.constrain(x, ("batch", "seq", None))
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    aux = jnp.float32(0)
    fam = cfg.family

    if fam in ("dense", "vlm"):
        body = lambda p, h: _attn_block_apply(p, h, cfg, env, positions, False)
        x, aux = _scan_blocks(params["blocks"], x, body, env)
    elif fam in ("moe", "mla_moe"):
        x, a0 = _attn_block_apply(params["block0"], x, cfg, env, positions, False)
        body = lambda p, h: _attn_block_apply(p, h, cfg, env, positions, True)
        x, aux = _scan_blocks(params["blocks"], x, body, env)
        aux = aux + a0
    elif fam == "ssm":
        body = lambda p, h: _ssm_block_apply(p, h, cfg, env)
        x, aux = _scan_blocks(params["blocks"], x, body, env)
    elif fam == "hybrid":
        hy = cfg.hybrid
        meta = jnp.broadcast_to(params["meta"].astype(cfg.compute_dtype)[None],
                                (b, hy.n_meta, cfg.d_model))
        x = jnp.concatenate([meta, x], axis=1)
        sm = s + hy.n_meta
        positions = jnp.broadcast_to(jnp.arange(sm, dtype=jnp.int32)[None], (b, sm))
        gb = partial(_hybrid_block_apply, cfg=cfg, env=env, positions=positions,
                     window=None)
        wb = lambda p, h: _hybrid_block_apply(p, h, cfg, env, positions,
                                              window=hy.window)
        x, _ = gb(params["global0"], x)
        x, _ = _scan_blocks(params["win_a"], x, wb, env)
        x, _ = gb(params["global1"], x)
        x, _ = _scan_blocks(params["win_b"], x, wb, env)
        x, _ = gb(params["global2"], x)
        x = x[:, hy.n_meta:]
    else:
        raise ValueError(fam)

    x = layers.rms_norm(x, params["ln_f"])
    logits = layers.unembed(params["embed"], x, cfg)
    logits = env.constrain(logits, ("batch", None, "vocab"))
    return logits, aux


def loss_fn(params, batch, cfg, env):
    """Next-token cross-entropy (image/meta positions masked)."""
    logits, aux = forward(params, batch, cfg, env)
    tokens = batch["tokens"]
    labels = tokens[:, 1:]
    lg = logits[:, :-1].astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    mask = jnp.ones_like(labels, jnp.float32)
    if cfg.family == "vlm":
        np_ = cfg.vlm.n_patches
        mask = mask.at[:, : np_].set(0.0)
    loss = (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return loss + cfg.aux_loss_weight * aux
